"""Batched serving example: prefill + lock-step decode with a KV cache.

Serves batched requests against a (reduced) assigned architecture with the
prefill/decode engine that the decode_* dry-run cells lower at production
scale.  Works for every family (full-attention KV caches, SWA circular
caches, RWKV/RG-LRU recurrent state).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""

import argparse
import time

import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    eng = ServingEngine(
        model,
        ServeConfig(
            batch_size=args.batch,
            max_new_tokens=args.new_tokens,
            temperature=args.temperature,
        ),
    )
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(
        np.int32
    )
    ctx_len, needed = model._context_len()
    context = (
        rng.standard_normal((args.batch, ctx_len, cfg.d_model)).astype(np.float32) * 0.1
        if needed
        else None
    )

    t0 = time.perf_counter()
    out = eng.generate(prompts, context=context)
    dt = time.perf_counter() - t0
    total_tokens = args.batch * args.new_tokens
    print(
        f"arch {cfg.name}: generated {out.shape} in {dt:.2f}s "
        f"({total_tokens / dt:.1f} tok/s incl. compile)"
    )
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
