"""End-to-end LM training driver with checkpoint/restart.

Trains an assigned architecture for a few hundred steps on the synthetic
pipeline, checkpointing periodically; re-running resumes from the latest
checkpoint.  Defaults to a reduced config sized for this CPU container —
pass ``--full`` (on real hardware) for the published config, and
``--arch`` for any of the 10 assigned architectures.

Run:  PYTHONPATH=src python examples/train_lm.py --arch smollm-360m --steps 300
"""

import argparse

import jax

from repro.configs import get_arch
from repro.models import build_model
from repro.train import AdamWConfig, TrainConfig, train
from repro.train.data import DataConfig, synthetic_batch
from repro.train.optimizer import init_opt_state
from repro.train.train_loop import make_train_step
from repro.train.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="published config (needs accelerators)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(
        f"arch {cfg.name}: {cfg.params_count() / 1e6:.1f}M params "
        f"({cfg.active_params_count() / 1e6:.1f}M active)"
    )

    tcfg = TrainConfig(
        steps=args.steps,
        opt=AdamWConfig(lr_peak=1e-3, warmup_steps=20, total_steps=args.steps),
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=100,
        log_every=20,
    )
    dcfg = DataConfig(vocab_size=cfg.vocab_size, global_batch=args.batch, seq_len=args.seq)

    step, _ = make_train_step(model, tcfg)
    params = jax.jit(model.init_fn)(jax.random.key(0))
    opt = init_opt_state(params)
    ckpt = CheckpointManager(args.ckpt_dir)
    start = ckpt.latest_step() or 0
    if start:
        restored = ckpt.restore(start, {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"resumed from checkpoint at step {start}")

    first_loss = None
    for i in range(start, args.steps):
        batch = synthetic_batch(dcfg, i)
        params, opt, metrics = step(params, opt, batch)
        if first_loss is None:
            first_loss = float(metrics["loss"])
        if (i + 1) % tcfg.log_every == 0:
            print(
                f"step {i + 1:4d}  loss {float(metrics['loss']):.4f}  "
                f"lr {float(metrics['lr']):.2e}"
            )
        if (i + 1) % tcfg.checkpoint_every == 0:
            ckpt.save(i + 1, {"params": params, "opt": opt})
    ckpt.wait()
    print(
        f"\nloss: {first_loss:.4f} -> {float(metrics['loss']):.4f} "
        f"over {args.steps - start} steps"
    )


if __name__ == "__main__":
    main()
