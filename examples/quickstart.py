"""Quickstart: count tree subgraphs in a graph with the Counter facade.

Counts 4-vertex stars in a small Erdos-Renyi graph through the unified API
(``repro.api.Counter``), compares the (eps, delta) estimate with the exact
count, and shows the paper's Table-3 complexity data for the big templates.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.api import Counter
from repro.core import erdos_renyi
from repro.core.brute_force import count_copies
from repro.core.templates import (
    TEMPLATE_TABLE3,
    partition_complexity,
    partition_tree,
    star_tree,
    template,
)


def main():
    g = erdos_renyi(200, 6.0, seed=0)
    tree = star_tree(4)
    print(f"graph: {g.n} vertices, {g.num_edges} edges; template: {tree.name}")

    # one facade over every backend; "auto" picks distributed when more
    # than one device is visible, the in-core engine otherwise
    counter = Counter.from_graph(g, tree, backend="auto")
    est = counter.estimate(n_iter=150, key=jax.random.key(0))
    exact = count_copies(g, tree)
    print(f"backend                : {est.backend}")
    print(f"exact count            : {exact:.0f}")
    print(
        f"color-coding estimate  : {est.estimate:.0f}  (mean {est.mean:.0f}, "
        f"RSD {est.relative_sd:.2f}, {est.niter} colorings)"
    )
    print(f"relative error         : {abs(est.estimate - exact) / exact:.2%}\n")

    # a whole family in ONE pass per coloring: the templates compile into a
    # deduplicated subtree DAG, shared tables are computed once, and every
    # template gets its own unbiased estimate from the shared colorings
    family = ["u3-1", "u5-2", tree]
    many = counter.estimate_many(family, n_iter=60, key=jax.random.key(1))
    print(
        f"family of {len(many)} templates, k={many.k}: "
        f"{many.unique_tables} unique tables vs {many.chain_tables} chain nodes"
    )
    for one in many:
        print(f"  {one.template:>8}: estimate {one.estimate:.0f}  (RSD {one.relative_sd:.2f})")
    print()

    print("paper Table 3 (reproduced exactly from the partition chains):")
    print(f"{'template':<8} {'memory':>8} {'compute':>9} {'intensity':>10}")
    for name in TEMPLATE_TABLE3:
        mem, comp = partition_complexity(partition_tree(template(name)))
        print(f"{name:<8} {mem:>8} {comp:>9} {comp / mem:>10.1f}")


if __name__ == "__main__":
    main()
