"""End-to-end distributed subgraph counting (the paper's workload).

Runs the distributed color-coding engine over 8 host devices on an RMAT
graph, comparing the paper's three communication modes (naive all-to-all /
pipelined adaptive-group / adaptive switch) plus the beyond-paper relay
ring, and prints per-mode wall-clock and the agreeing count estimates.

Run:  PYTHONPATH=src python examples/count_distributed.py [--template u5-2]
(device count is set below, before jax imports)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import relabel_random, rmat  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    build_distributed_plan,
    make_count_fn,
    shard_coloring,
)
from repro.core.templates import template  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--template", default="u5-2")
    ap.add_argument("--vertices", type=int, default=1 << 14)
    ap.add_argument("--edges", type=int, default=150_000)
    ap.add_argument("--iters", type=int, default=8)
    args = ap.parse_args()

    shards = 8
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((shards,), ("data",))
    g = relabel_random(rmat(args.vertices, args.edges, skew=3, seed=0), seed=1)
    tree = template(args.template)
    print(f"graph: {g.n} vertices, {g.num_edges} edges (skew {g.skewness():.0f}); "
          f"template {tree.name} (k={tree.n}); {shards} shards\n")

    plan = build_distributed_plan(g, tree, shards)
    rng = np.random.default_rng(0)
    colorings = np.stack([
        shard_coloring(plan, rng.integers(0, tree.n, g.n).astype(np.int32))
        for _ in range(args.iters)
    ])

    for mode, gf in (("alltoall", 1), ("pipeline", 1), ("pipeline", 3),
                     ("adaptive", 1), ("ring", 1)):
        f = make_count_fn(plan, mesh, mode=mode, group_factor=gf)
        counts = f(jnp.asarray(colorings))
        jax.block_until_ready(counts)
        t0 = time.perf_counter()
        counts = f(jnp.asarray(colorings))
        jax.block_until_ready(counts)
        dt = time.perf_counter() - t0
        est = float(np.mean(np.asarray(counts))) * plan.scale
        label = f"{mode}(g={gf})" if mode == "pipeline" else mode
        print(f"{label:<14} {dt * 1e3:8.1f} ms / {args.iters} colorings   "
              f"estimate ~ {est:.4g}")


if __name__ == "__main__":
    main()
