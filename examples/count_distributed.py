"""End-to-end distributed subgraph counting (the paper's workload).

Runs the unified ``Counter`` facade with ``backend="distributed"`` over 8
host devices on an RMAT graph, comparing the paper's three communication
modes (naive all-to-all / pipelined adaptive-group / adaptive switch) plus
the beyond-paper relay ring.  Every mode uses the key-based contract —
colorings are sampled on-device inside the shard_map — and reports through
the shared (eps, delta) estimator, so the printed statistics are directly
comparable across modes AND with the single-device backend.

Run:  PYTHONPATH=src python examples/count_distributed.py [--template u5-2]
(device count is set below, before jax imports)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.api import Counter  # noqa: E402
from repro.core import relabel_random, rmat  # noqa: E402
from repro.core.templates import template  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--template", default="u5-2")
    ap.add_argument("--vertices", type=int, default=1 << 14)
    ap.add_argument("--edges", type=int, default=150_000)
    ap.add_argument("--iters", type=int, default=8)
    args = ap.parse_args()

    shards = 8
    g = relabel_random(rmat(args.vertices, args.edges, skew=3, seed=0), seed=1)
    tree = template(args.template)
    print(
        f"graph: {g.n} vertices, {g.num_edges} edges (skew {g.skewness():.0f}); "
        f"template {tree.name} (k={tree.n}); {shards} shards\n"
    )

    key = jax.random.key(0)
    base = Counter.from_graph(g, tree, backend="distributed", num_shards=shards, mode="alltoall")
    for mode, gf in (
        ("alltoall", 1),
        ("pipeline", 1),
        ("pipeline", 3),
        ("adaptive", 1),
        ("ring", 1),
    ):
        # one plan build (edge bucketing) shared across all exchange modes
        counter = base.with_options(mode=mode, group_factor=gf)
        counter.sample_fn(key, args.iters)  # compile outside the timer
        t0 = time.perf_counter()
        res = counter.estimate(n_iter=args.iters, key=key, batch=args.iters)
        dt = time.perf_counter() - t0
        label = f"{mode}(g={gf})" if mode == "pipeline" else mode
        print(
            f"{label:<14} {dt * 1e3:8.1f} ms / {res.niter} colorings   "
            f"estimate ~ {res.mean:.4g}"
        )


if __name__ == "__main__":
    main()
