"""Benchmark harness — one section per paper table/figure.

Emits ``name,us_per_call,derived`` CSV lines:
  table3/*        Table 3  (template complexity — exact reproduction)
  fig6/*          Fig. 6   (template-size scaling, single node)
  spmm/*, color_combine/*, fused/*, iter/*
                  kernel-level hot-path benchmarks (bench_kernels); also
                  written machine-readable to BENCH_kernels.json at the
                  repo root — the per-PR perf trajectory record
  strong/*        Fig. 7/9/15 (strong scaling, naive vs pipeline vs adaptive)
  weak/*          Fig. 10  (weak scaling)
  fig11/*         Fig. 11  (load balance vs skew; task-size effects)
  peakmem/*       Fig. 12  (peak memory: naive vs pipeline vs ring)
  overall/*       Fig. 13  (end-to-end, naive vs adaptive, template sweep)
  multi_template/* family counting: shared-DAG reuse vs independent passes
                  (bench_multi_template; BENCH_multi_template.json)
  adaptive_policy/*, lm_coll/*  (beyond paper: LM collectives)

Multi-device sections run in subprocesses with 8 host devices; the main
process keeps a single device.
"""

from __future__ import annotations

import traceback

from . import bench_kernels, bench_load_balance, bench_multi_template, bench_templates
from .common import run_worker


def _section(name, fn):
    print(f"# --- {name} ---", flush=True)
    try:
        fn()
    except Exception as e:  # noqa: BLE001 — keep the harness going
        traceback.print_exc()
        print(f"{name}/FAILED,0.0,{type(e).__name__}", flush=True)


def main() -> None:
    _section("templates", bench_templates.run)
    _section("kernels", bench_kernels.run)
    _section("load_balance", bench_load_balance.run)
    _section("multi_template", bench_multi_template.run)
    _section(
        "strong_scaling",
        lambda: print(
            run_worker("benchmarks._scaling_worker", ["strong", "--template", "u5-2"]),
            end="",
        ),
    )
    _section(
        "weak_scaling",
        lambda: print(
            run_worker("benchmarks._scaling_worker", ["weak", "--template", "u5-2"]),
            end="",
        ),
    )
    _section(
        "peak_memory",
        lambda: print(
            run_worker("benchmarks._scaling_worker", ["peakmem", "--template", "u7-2"]),
            end="",
        ),
    )
    _section(
        "overall",
        lambda: print(run_worker("benchmarks._scaling_worker", ["overall"]), end=""),
    )

    from . import bench_lm_collectives

    _section("lm_collectives", bench_lm_collectives.run)


if __name__ == "__main__":
    main()
