"""Beyond-paper: Adaptive-Group collectives applied to LM parallelism.

Compares, from compiled HLO on an 8-device host mesh:
  * fused all-gather vs relay-ring (ppermute) weight gather — bytes and op
    mix (the FSDP-overlap trade the hillclimb exploits);
  * fp32 vs int8-compressed ring reduce-scatter for gradients — bytes on
    the wire;
  * adaptive policy decisions (Hockney model) for representative layer
    sizes of every assigned arch.
"""

from __future__ import annotations

from repro.comm import V5E_ICI, choose_mode
from repro.configs import ARCHS

from .common import emit, run_worker


def run():
    # policy table: per arch, the FSDP gather of one layer's weights vs the
    # matmul flops consuming them (train_4k per-device shapes, 16x16 mesh)
    for name, cfg in sorted(ARCHS.items()):
        d, f = cfg.d_model, cfg.d_ff
        layer_bytes = (3 if cfg.act == "swiglu" else 2) * d * f * 2 / 16  # bf16, fsdp-sharded
        tokens_dev = 256 * 4096 / 16
        flops = 2 * tokens_dev * d * f * (3 if cfg.act == "swiglu" else 2) / 16
        mode, diag = choose_mode(layer_bytes, flops, 16, V5E_ICI)
        emit(
            f"adaptive_policy/{name}",
            0.0,
            f"mode={mode} rho={diag['rho']:.2f} "
            f"intensity={diag['intensity_flops_per_byte']:.0f}",
        )

    # HLO comparison on 8 host devices (subprocess)
    out = run_worker("benchmarks._lm_collectives_worker", [], devices=8)
    print(out, end="")


def main():
    run()


if __name__ == "__main__":
    main()
