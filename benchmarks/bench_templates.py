"""Paper Table 3 + Fig. 6: per-template complexity and single-node scaling.

Reproduces Table 3 exactly (memory/compute complexity and computation
intensity per template — these are structural, from the partition chain)
and measures single-device wall-clock per coloring iteration as template
size grows on a fixed RMAT graph (Fig. 6's compute-side trend).
"""

from __future__ import annotations

import jax

from repro.core import build_counting_plan, count_fn, rmat
from repro.core.templates import TEMPLATE_TABLE3, partition_complexity, partition_tree, template

from .common import emit, time_fn

BENCH_TEMPLATES = ["u3-1", "u5-2", "u7-2", "u10-2"]  # CPU-feasible sizes


def run():
    # Table 3 (structural reproduction — exact)
    for name, (mem_want, comp_want) in TEMPLATE_TABLE3.items():
        tr = template(name)
        mem, comp = partition_complexity(partition_tree(tr))
        intensity = comp / mem
        ok = (mem, comp) == (mem_want, comp_want)
        emit(
            f"table3/{name}",
            0.0,
            f"mem={mem} comp={comp} intensity={intensity:.1f} exact={ok}",
        )

    # Fig. 6 compute trend: per-iteration time vs template size
    g = rmat(1 << 13, 80_000, skew=3, seed=0)
    for name in BENCH_TEMPLATES:
        tr = template(name)
        plan = build_counting_plan(g, tr)
        f = count_fn(plan)
        key = jax.random.key(0)
        sec = time_fn(lambda: f(key), iters=2)
        emit(f"fig6/iter_time/{name}", sec * 1e6, f"V={g.n} E={g.num_edges}")


def main():
    run()


if __name__ == "__main__":
    main()
