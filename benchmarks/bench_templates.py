"""Paper Table 3 + Fig. 6: per-template complexity and single-node scaling.

Reproduces Table 3 exactly (memory/compute complexity and computation
intensity per template — these are structural, from the partition chain)
and measures single-device wall-clock per coloring iteration as template
size grows on a fixed RMAT graph (Fig. 6's compute-side trend).
"""

from __future__ import annotations

import argparse

import jax

from repro.api import Counter
from repro.core import rmat
from repro.core.templates import TEMPLATE_TABLE3, partition_complexity, partition_tree, template

from .common import emit, time_fn

BENCH_TEMPLATES = ["u3-1", "u5-2", "u7-2", "u10-2"]  # CPU-feasible sizes


def run(smoke: bool = False):
    # Table 3 (structural reproduction — exact)
    for name, (mem_want, comp_want) in TEMPLATE_TABLE3.items():
        tr = template(name)
        mem, comp = partition_complexity(partition_tree(tr))
        intensity = comp / mem
        ok = (mem, comp) == (mem_want, comp_want)
        emit(
            f"table3/{name}",
            0.0,
            f"mem={mem} comp={comp} intensity={intensity:.1f} exact={ok}",
        )

    # Fig. 6 compute trend: per-iteration time vs template size
    if smoke:
        g = rmat(1 << 10, 10_000, skew=3, seed=0)
        names = BENCH_TEMPLATES[:2]
    else:
        g = rmat(1 << 13, 80_000, skew=3, seed=0)
        names = BENCH_TEMPLATES
    for name in names:
        tr = template(name)
        counter = Counter.from_graph(g, tr, backend="single")
        sample = counter.sample_fn
        key = jax.random.key(0)
        sec = time_fn(lambda: sample(key, 1), iters=2)
        emit(f"fig6/iter_time/{name}", sec * 1e6, f"V={g.n} E={g.num_edges}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small graph + first two templates (CI)")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
