"""Worker (8 host devices): collective bytes of fused vs ring FSDP gather
and fp32 vs int8 gradient reduce-scatter, from compiled HLO + wall clock."""

import re

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import compressed_ring_reduce_scatter, ring_allgather, ring_reduce_scatter
from repro.compat import make_mesh, shard_map


def _mesh():
    return make_mesh((8,), ("data",))


def _coll_bytes(compiled):
    txt = compiled.as_text()
    out = {}
    for kind in (
        "all-gather",
        "all-reduce",
        "reduce-scatter",
        "all-to-all",
        "collective-permute",
    ):
        total = 0
        for m in re.finditer(rf"= (\w+)\[([\d,]*)\][^\n]*? {kind}(?:-start)?\(", txt):
            dims = m.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * {"f32": 4, "bf16": 2, "s8": 1, "int8": 1}.get(m.group(1), 4)
        out[kind] = total
    return out


def main():
    mesh = _mesh()
    w = np.random.default_rng(0).standard_normal((8, 1024, 512)).astype(np.float32)

    # fused all-gather
    fused = jax.jit(
        shard_map(
            lambda x: jax.lax.all_gather(x[0], "data"),
            mesh=mesh,
            in_specs=P("data"),
            out_specs=P("data"),
        )
    )
    cf = fused.lower(w).compile()
    bf = _coll_bytes(cf)
    print(f"lm_coll/fsdp_gather/fused,0.0,bytes={bf}")

    # relay ring
    ring = jax.jit(
        shard_map(
            lambda x: ring_allgather(x[0], "data"),
            mesh=mesh,
            in_specs=P("data"),
            out_specs=P("data"),
        )
    )
    cr = ring.lower(w).compile()
    br = _coll_bytes(cr)
    print(f"lm_coll/fsdp_gather/ring,0.0,bytes={br}")

    # gradient reduce-scatter: fp32 vs int8 payloads
    g = np.random.default_rng(1).standard_normal((8, 8, 2048)).astype(np.float32)
    rs32 = jax.jit(
        shard_map(
            lambda x: ring_reduce_scatter(x[0], "data")[None],
            mesh=mesh,
            in_specs=P("data"),
            out_specs=P("data"),
        )
    )
    rs8 = jax.jit(
        shard_map(
            lambda x: compressed_ring_reduce_scatter(x[0], "data")[None],
            mesh=mesh,
            in_specs=P("data"),
            out_specs=P("data"),
        )
    )
    b32 = _coll_bytes(rs32.lower(g).compile())
    b8 = _coll_bytes(rs8.lower(g).compile())
    cp32 = b32["collective-permute"]
    cp8 = b8["collective-permute"]
    ratio = cp32 / max(cp8, 1)
    print(f"lm_coll/grad_rs/fp32,0.0,permute_bytes={cp32}")
    print(f"lm_coll/grad_rs/int8,0.0,permute_bytes={cp8} compression={ratio:.2f}x")

    # numerical error of the compressed path
    want = g.sum(axis=0)
    got = np.asarray(rs8(jnp.asarray(g)))
    rel = np.abs(got - want).max() / np.abs(want).max()
    print(f"lm_coll/grad_rs/int8_rel_err,0.0,rel={rel:.4f}")


if __name__ == "__main__":
    main()
