"""Shared benchmark helpers: timing, CSV emission, subprocess workers."""

from __future__ import annotations

import os
import subprocess
import sys
import time
from typing import Callable

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock seconds per call (after jit warmup)."""
    for _ in range(warmup):
        out = fn(*args)
        _block(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _block(out):
    import jax

    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def run_worker(module: str, args: list, devices: int = 8, timeout: int = 1200) -> str:
    """Run a benchmark worker in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", module, *map(str, args)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=ROOT,
    )
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr[-3000:])
        raise RuntimeError(f"worker {module} failed")
    return proc.stdout
