"""Counting-service benchmark: cache reuse, coalescing, request latency.

Drives the ``bench-service`` synthetic multi-tenant workload
(:data:`repro.configs.SERVICE_WORKLOADS` — three tenants, overlapping
template families, a shared default key) through a resident
:class:`~repro.serve.CountingService` and reports the service-level
quantities the tentpole claims:

  * ``hit_rate`` — plan-cache hits / lookups: cross-request compiled-plan
    reuse (must be > 0 on this workload: alice re-asks her family);
  * ``coalescing_factor`` — request-calls served per backend call
    (must be > 1: overlapping requests share coloring passes);
  * ``latency_p50_us`` / ``latency_p95_us`` — submit-to-result wall
    clock per request under fair scheduling;
  * ``svc_cancel_latency_us`` — how fast a mid-stream ``ticket.cancel()``
    turns terminal with the §20 driver thread running (the lock is
    released across backend dispatches, so this must stay far below one
    pass-call duration);
  * ``svc_shed_rate`` — deterministic shed-oldest admission math
    (bounded queue of 4, 12 scripted submits -> 8/12 shed), gated
    structurally: it must never drift.

``main()`` writes ``BENCH_service.json`` at the repo root; the CI bench
gate holds the line on it (hit rate and coalescing gate as
higher-is-better, latencies as timings, the hardening section under its
own ``svc_*`` classes).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs import SERVICE_WORKLOADS
from repro.core import rmat
from repro.serve import CountingService, ServiceConfig

from .common import ROOT, emit

JSON_PATH = os.path.join(ROOT, "BENCH_service.json")


def run_hardening(g, wl) -> dict:
    """The §20 serving-robustness metrics (cancel latency, shed rate)."""
    # deterministic shed-oldest math: queue bound 4, 12 back-to-back
    # submits with nothing draining -> exactly 8 shed
    svc = CountingService(
        g, n_colors=wl.k, backend="single",
        config=ServiceConfig(batch=wl.batch, max_pending=4, shed_oldest=True),
    )
    subs = [svc.submit("bench", "u3-1", n_iter=wl.batch) for _ in range(12)]
    shed = sum(t.status == "shed" for t in subs)
    svc.run_until_idle()
    assert all(t.done for t in subs), "shed workload left non-terminal tickets"
    assert shed == 8, f"shed-oldest admission drifted: {shed}/12"

    # cancel responsiveness under the running driver: submit a long
    # request, wait until it is mid-stream, cancel, time to terminal
    svc2 = CountingService(
        g, n_colors=wl.k, backend="single",
        config=ServiceConfig(batch=wl.batch),
    )
    svc2.start()
    lats = []
    try:
        for i in range(5):
            t = svc2.submit("bench", "u3-1", n_iter=wl.batch * 50, key=jax.random.key(1000 + i))
            while not t.updates and not t.done:
                time.sleep(0.001)
            t0 = time.perf_counter()
            t.cancel()
            t.wait(30)
            lats.append(time.perf_counter() - t0)
    finally:
        svc2.stop()
    return {
        "svc_shed_rate": shed / len(subs),
        "svc_cancel_latency_us": float(np.median(lats)) * 1e6,
    }


def run(smoke: bool = False) -> dict:
    wl = SERVICE_WORKLOADS["bench-service"]
    if smoke:
        g = rmat(2048, 15_000, skew=3, seed=0, name="bench-service-smoke")
        iter_scale = 4  # budgets shrink with the graph
    else:
        g = wl.counting_config().synthesize()
        iter_scale = 1
    svc = CountingService(
        g,
        n_colors=wl.k,
        backend="single",
        config=ServiceConfig(batch=wl.batch),
    )
    tickets = []
    t0 = time.perf_counter()
    for _ in range(wl.repeats):
        for tenant, templates, kw in wl.requests:
            kw = dict(kw)
            if "n_iter" in kw:
                kw["n_iter"] = max(wl.batch, kw["n_iter"] // iter_scale)
            tickets.append(svc.submit(tenant, templates, **kw))
    svc.run_until_idle()
    wall = time.perf_counter() - t0

    failed = [t for t in tickets if t.status != "done"]
    assert not failed, f"service left requests unserved: {failed}"
    stats = svc.stats()
    lat_us = np.array([t.latency_s for t in tickets]) * 1e6
    rec = {
        "requests": len(tickets),
        "pass_calls": stats["pass_calls"],
        "request_calls": stats["request_calls"],
        "coalescing_factor": stats["coalescing_factor"],
        "hit_rate": stats["cache"]["hit_rate"],
        "cache_hits": stats["cache"]["hits"],
        "cache_misses": stats["cache"]["misses"],
        "latency_p50_us": float(np.percentile(lat_us, 50)),
        "latency_p95_us": float(np.percentile(lat_us, 95)),
        "wall_us": wall * 1e6,
    }
    # the tentpole's acceptance floor: reuse and coalescing must engage
    assert rec["hit_rate"] > 0, "plan cache never hit on repeat requests"
    assert rec["coalescing_factor"] > 1, "no requests shared a pass"
    emit(
        "service_coalescing",
        rec["coalescing_factor"] * 100,
        f"x{rec['coalescing_factor']:.2f}",
    )
    emit("service_hit_rate", rec["hit_rate"] * 100, f"{rec['hit_rate']:.0%}")
    emit(
        "service_latency_p50",
        rec["latency_p50_us"],
        f"p95 {rec['latency_p95_us'] / 1e3:.1f}ms",
    )
    hard = run_hardening(g, wl)
    rec.update(hard)
    emit("service_shed_rate", hard["svc_shed_rate"] * 100,
         f"{hard['svc_shed_rate']:.0%} shed under overload")
    emit("service_cancel_latency", hard["svc_cancel_latency_us"],
         f"{hard['svc_cancel_latency_us'] / 1e3:.2f}ms to terminal")
    return {
        "backend": "cpu",
        "smoke": smoke,
        "graph": {"v": g.n, "e": g.num_edges},
        "k": wl.k,
        "batch": wl.batch,
        "repeats": wl.repeats,
        "service": rec,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="small graph / reduced budgets (the CI mode)",
    )
    args = ap.parse_args()
    out = run(smoke=args.smoke)
    with open(JSON_PATH, "w") as fh:
        json.dump(out, fh, indent=2)
        fh.write("\n")
    print(f"wrote {JSON_PATH}")


if __name__ == "__main__":
    main()
