"""Multi-template counting: cross-template subtree reuse (DESIGN.md §14).

Two measurements per template family:

  * structural — the compiled :class:`TemplateDag`'s unique-table count
    against the sum of the per-template partition-chain nodes (what N
    independent ``Counter.estimate`` calls would compute), plus the same
    ratio restricted to internal nodes (the tables that actually cost an
    SpMM + combine per coloring);
  * wall-clock — one shared-DAG ``estimate_many`` pass vs N independent
    per-template passes over the SAME colorings (``n_colors = k``, the
    apples-to-apples baseline) and vs today's default independent passes
    (each template with its native color budget).

``run()`` emits the usual CSV lines and returns a dict; ``main()`` writes
``BENCH_multi_template.json`` at the repo root (like the other BENCH
files) so the per-PR reuse trajectory is machine-readable and the CI
bench gate can hold the line on it.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from repro.core import rmat
from repro.core.count_engine import (
    build_counting_plan,
    build_multi_counting_plan,
    count_fn,
    count_fn_many,
)
from repro.core.templates import compile_templates, template, template_program

from .common import ROOT, emit, time_fn

JSON_PATH = os.path.join(ROOT, "BENCH_multi_template.json")

#: benchmark families: nested spiders (maximal sharing: u3-1 ⊂ u5-2 ⊂ the
#: u7-2 two-leg spider) and the named paper trio used by the config rows
FAMILIES = {
    "spiders": ("u3-1", "u5-2", "u7-2"),
    "paper": ("u5-2", "u7-2", "u10-2"),
}


def dedup_stats(names) -> dict:
    """Structural reuse: unique DAG tables vs sum of per-program nodes."""
    dag = compile_templates(names)
    chains = [template_program(template(n)) for n in names]
    chain_nodes = sum(len(c.nodes) for c in chains)
    chain_internal = sum(len(c.internal_nodes()) for c in chains)
    return {
        "k": dag.k,
        "chain_nodes_sum": chain_nodes,
        "dag_nodes": len(dag.nodes),
        "chain_internal_sum": chain_internal,
        "dag_internal": len(dag.internal_nodes()),
        "unique_table_ratio": len(dag.nodes) / chain_nodes,
        "unique_internal_ratio": len(dag.internal_nodes()) / chain_internal,
    }


def bench_family(fname: str, names, g, batch: int) -> dict:
    """Shared-pass vs independent-pass wall clock on one graph."""
    rec = dedup_stats(names)
    key = jax.random.key(0)

    mp = build_multi_counting_plan(g, names)
    f_many = count_fn_many(mp, batch=batch)
    sec_shared = time_fn(lambda: f_many(key), iters=5)
    rec["shared_us"] = sec_shared * 1e6

    # independent passes over the SAME colorings (shared k): what N
    # Counter.estimate calls recomputing the shared subtree tables cost
    sec_same_k = 0.0
    for n in names:
        p = build_counting_plan(g, template(n), n_colors=mp.k)
        f = count_fn(p, batch=batch)
        sec_same_k += time_fn(lambda f=f: f(key), iters=5)
    rec["independent_same_k_us"] = sec_same_k * 1e6

    # today's default: each template with its native color budget
    sec_native = 0.0
    for n in names:
        p = build_counting_plan(g, template(n))
        f = count_fn(p, batch=batch)
        sec_native += time_fn(lambda f=f: f(key), iters=5)
    rec["independent_native_k_us"] = sec_native * 1e6

    rec["speedup_vs_independent"] = sec_same_k / sec_shared
    rec["speedup_vs_native"] = sec_native / sec_shared
    emit(
        f"multi_template/{fname}",
        sec_shared * 1e6,
        f"dag={rec['dag_nodes']}/{rec['chain_nodes_sum']} "
        f"shared={sec_shared * 1e3:.0f}ms same_k={sec_same_k * 1e3:.0f}ms "
        f"native={sec_native * 1e3:.0f}ms "
        f"speedup={rec['speedup_vs_independent']:.2f}x",
    )
    return rec


#: treewidth-2 smoke family (DESIGN.md §19): bag-table programs carry the
#: pinned-apex axis, so their tables are [v, x * W] — the section runs on
#: its own small graph (x = |V| multiplies every bag-table width)
BAG_FAMILY = ("cycle3", "cycle5", "diamond")


def bench_bags(batch: int) -> dict:
    """Cycle/diamond family through the shared DAG: structural interning
    metrics (``bag_``-prefixed, held by the CI gate) plus one shared-pass
    timing on a bag-scale graph."""
    names = BAG_FAMILY
    dag = compile_templates(names)
    progs = [template_program(template(n)) for n in names]
    solo_nodes = sum(len(p.nodes) for p in progs)
    g = rmat(192, 1_200, skew=3, seed=0)
    mp = build_multi_counting_plan(g, names)
    f_many = count_fn_many(mp, batch=batch)
    key = jax.random.key(0)
    sec = time_fn(lambda: f_many(key), iters=3)
    rec = {
        "bag_dag_nodes": len(dag.nodes),
        "bag_solo_nodes_sum": solo_nodes,
        "bag_unique_table_ratio": len(dag.nodes) / solo_nodes,
        "bag_x_dim": g.n,
        "bag_widest_cols": max(mp.widths.values()),
        "bag_shared_us": sec * 1e6,
    }
    emit(
        "multi_template/bags",
        sec * 1e6,
        f"dag={rec['bag_dag_nodes']}/{solo_nodes} x={g.n} "
        f"widest={rec['bag_widest_cols']} shared={sec * 1e3:.0f}ms",
    )
    return rec


def run(smoke: bool = False, json_path: str = JSON_PATH):
    v, e, batch = (1 << 11, 16_000, 4) if smoke else (1 << 12, 40_000, 8)
    g = rmat(v, e, skew=3, seed=0)
    results = {
        "backend": jax.default_backend(),
        "smoke": smoke,
        "graph": {"v": g.n, "e": g.num_edges, "skew": 3},
        "batch": batch,
        "families": {},
    }
    for fname, names in FAMILIES.items():
        if smoke and fname == "paper":
            # u10-2's k=10 tables are too wide for the CI smoke budget;
            # its structural reuse is still recorded below
            results["families"][fname] = dedup_stats(names)
            continue
        results["families"][fname] = bench_family(fname, names, g, batch)
    results["bags"] = bench_bags(batch)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small graphs (CI)")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=None if args.no_json else JSON_PATH)


if __name__ == "__main__":
    main()
