"""Kernel-level benchmarks for the counting hot path + perf trajectory JSON.

Covers, across the Table-3 bench templates (u3-1 .. u10-2) on the fig6 RMAT
graph:

  spmm/*           neighbor sum: edges vs blocks vs auto plan kinds
  color_combine/*  split-table contraction, per template's heaviest node
  fused/*          fused SpMM->combine vs the two-step path
  iter/*           full per-coloring-iteration wall-clock:
                     seed        — the seed engine config (128-lane padded
                                   tables, unfused, one coloring per call)
                     batch8      — true-width tables + batch=8 colorings/call
                     fused_batch8— same plus the fused pipeline

Everything here times the XLA/CPU dispatch path (interpret-mode Pallas is
an emulator, orders of magnitude off hardware; the kernels' correctness is
covered by tests).  ``run()`` emits the usual CSV lines and returns a dict;
``main()`` / ``benchmarks.run`` additionally write ``BENCH_kernels.json``
at the repo root so the per-PR perf trajectory is machine-readable.
"""

from __future__ import annotations

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_counting_plan, count_fn, rmat
from repro.core.graphs import edge_list
from repro.core.templates import partition_tree, template
from repro.kernels import ops

from .common import ROOT, emit, time_fn

BENCH_TEMPLATES = ["u3-1", "u5-2", "u7-2", "u10-2"]
JSON_PATH = os.path.join(ROOT, "BENCH_kernels.json")


def _fig6_graph(smoke: bool = False):
    if smoke:
        return rmat(1 << 10, 10_000, skew=3, seed=0)
    return rmat(1 << 13, 80_000, skew=3, seed=0)


def _heaviest_node(tree):
    """(k, t1, t2) of the combine node with the largest S * J work term."""
    chain = partition_tree(tree)
    best, best_cost = None, -1
    for nd in chain.nodes:
        if nd.is_leaf:
            continue
        t1 = chain.nodes[nd.left].size
        t2 = chain.nodes[nd.right].size
        s = math.comb(tree.n, t1 + t2)
        j = math.comb(t1 + t2, t1)
        if s * j > best_cost:
            best, best_cost = (tree.n, t1, t2), s * j
    return best


def bench_spmm(g, results, iters=3):
    rows, cols = edge_list(g)
    rng = np.random.default_rng(0)
    out = {}
    width = 128
    plans = {
        kind: ops.build_spmm_plan(rows, cols, g.n, kind=kind)
        for kind in ("edges", "blocks", "auto")
    }
    n_pad = plans["edges"].n_pad
    t = rng.random((n_pad, width)).astype(np.float32)
    t[g.n:] = 0.0
    table = jnp.asarray(t)
    for kind, plan in plans.items():
        f = jax.jit(lambda tab, p=plan: ops.spmm(p, tab, impl="xla"))
        sec = time_fn(lambda: f(table), iters=iters)
        emit(
            f"spmm/{kind}",
            sec * 1e6,
            f"B={width} resolved={plan.kind} density="
            f"{0.0 if plan.patch_density is None else plan.patch_density:.1f}",
        )
        out[kind] = {
            "us": sec * 1e6,
            "resolved_kind": plan.kind,
            "patch_density": plan.patch_density,
        }
    return out


def bench_color_combine(g, results, iters=3):
    rng = np.random.default_rng(1)
    out = {}
    for name in results["templates"]:
        tr = template(name)
        k, t1, t2 = _heaviest_node(tr)
        tables = ops.build_combine_tables(k, t1, t2, lane=1)
        n_pad = ops.pad_to(g.n + 1, 128)
        left = jnp.asarray(rng.random((n_pad, math.comb(k, t1))).astype(np.float32))
        m = jnp.asarray(rng.random((n_pad, math.comb(k, t2))).astype(np.float32))
        f = jax.jit(lambda l, mm: ops.color_combine(l, mm, tables, impl="xla"))
        sec = time_fn(lambda: f(left, m), iters=iters)
        emit(
            f"color_combine/{name}",
            sec * 1e6,
            f"k={k} t1={t1} t2={t2} S={tables.s} J={tables.j}",
        )
        out[name] = {
            "us": sec * 1e6,
            "k": k,
            "t1": t1,
            "t2": t2,
            "s": tables.s,
            "j": tables.j,
        }
    return out


def bench_fused(g, results, iters=3):
    rows, cols = edge_list(g)
    plan = ops.build_spmm_plan(rows, cols, g.n, kind="edges")
    rng = np.random.default_rng(2)
    out = {}
    for name in results["templates"]:
        tr = template(name)
        k, t1, t2 = _heaviest_node(tr)
        tables = ops.build_combine_tables(k, t1, t2, lane=1)
        left = jnp.asarray(rng.random((plan.n_pad, math.comb(k, t1))).astype(np.float32))
        right_np = rng.random((plan.n_pad, math.comb(k, t2))).astype(np.float32)
        right_np[g.n:] = 0.0
        right = jnp.asarray(right_np)
        mask = (jnp.arange(plan.n_pad) < g.n).astype(jnp.float32)[:, None]
        fused = jax.jit(lambda l, r: ops.fused_count(plan, l, r, tables, impl="xla"))
        unfused = jax.jit(
            lambda l, r: ops.color_combine(
                l, ops.spmm(plan, r, impl="xla") * mask, tables, impl="xla"
            )
        )
        sec_f = time_fn(lambda: fused(left, right), iters=iters)
        sec_u = time_fn(lambda: unfused(left, right), iters=iters)
        emit(
            f"fused/{name}",
            sec_f * 1e6,
            f"unfused={sec_u * 1e6:.1f}us ratio={sec_u / sec_f:.2f}",
        )
        out[name] = {
            "fused_us": sec_f * 1e6,
            "unfused_us": sec_u * 1e6,
            "k": k,
            "t1": t1,
            "t2": t2,
        }
    return out


def bench_iteration(g, results, batch=8, iters=2):
    out = {}
    for name in results["templates"]:
        tr = template(name)
        # the seed engine: 128-lane padded tables, unfused, 1 coloring/call
        seed_plan = build_counting_plan(g, tr, spmm_kind="edges", lane=128)
        f_seed = count_fn(seed_plan)
        key = jax.random.key(0)
        sec_seed = time_fn(lambda: f_seed(key), iters=iters)

        # this PR's pipeline: true-width tables, batched colorings
        plan = build_counting_plan(g, tr, spmm_kind="auto")
        f_b = count_fn(plan, batch=batch)
        sec_b = time_fn(lambda: f_b(key), iters=iters) / batch

        # plus the fused SpMM->combine path (bounded-M schedule)
        fplan = build_counting_plan(g, tr, spmm_kind="edges", fuse=True)
        f_f = count_fn(fplan, batch=batch)
        sec_f = time_fn(lambda: f_f(key), iters=iters) / batch

        emit(f"iter/{name}/seed", sec_seed * 1e6, f"V={g.n} E={g.num_edges}")
        emit(f"iter/{name}/batch{batch}", sec_b * 1e6, f"speedup={sec_seed / sec_b:.2f}x")
        emit(f"iter/{name}/fused_batch{batch}", sec_f * 1e6, f"speedup={sec_seed / sec_f:.2f}x")
        out[name] = {
            "seed_us": sec_seed * 1e6,
            f"batch{batch}_us": sec_b * 1e6,
            f"fused_batch{batch}_us": sec_f * 1e6,
            f"speedup_batch{batch}": sec_seed / sec_b,
            f"speedup_fused_batch{batch}": sec_seed / sec_f,
        }
    return out


def run(smoke: bool = False, json_path: str = JSON_PATH):
    g = _fig6_graph(smoke)
    templates = BENCH_TEMPLATES[:2] if smoke else BENCH_TEMPLATES
    results = {
        "backend": jax.default_backend(),
        "smoke": smoke,
        "graph": {
            "v": g.n,
            "e": g.num_edges,
            "skew": 3,
            "name": "fig6-smoke" if smoke else "fig6",
        },
        "templates": templates,
        "batch": 8,
    }
    results["spmm"] = bench_spmm(g, results)
    results["color_combine"] = bench_color_combine(g, results)
    results["fused"] = bench_fused(g, results)
    results["iteration"] = bench_iteration(g, results)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small graph + first two templates (CI)")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=None if args.no_json else JSON_PATH)


if __name__ == "__main__":
    main()
