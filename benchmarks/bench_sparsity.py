"""Active-frontier compaction (DESIGN.md §15): density, bytes, wall-clock.

Three measurements per template on a skewed sparse R-MAT (the regime where
deep sub-template tables go sparse):

  * **density** — the per-node active-row fractions measured by the plan's
    build-time probe (the signal the compaction threshold gates on), plus
    the ``spmm_kind="auto"`` patch-density signal for the same graph;
  * **bytes on the wire** (structural) — per-iteration exchange volume of
    the 8-shard distributed plan, dense vs compacted: per-peer
    ``[r_pad, B]`` chunks vs ``[rc, B+1]`` active-row slabs
    (alltoall/pipeline) and whole-shard relays vs ``[cap, B+1]`` compacted
    relays (ring).  Pure plan math — deterministic, gated by the CI bench
    gate;
  * **wall-clock** — single-device per-iteration time with compaction off
    vs on (same keys, bit-identical counts), and in full mode the same
    comparison on 8 host devices through the pipelined exchange
    (``--dist-worker`` subprocess);
  * **checkpoint overhead** (robustness, §16) — the cost of one atomic
    synchronous save and one verified restore of the estimator state
    (``ckpt_*`` keys, gated as the robustness metric class).

``run()`` emits the usual CSV lines and returns a dict; ``main()`` writes
``BENCH_sparsity.json`` at the repo root for the CI bench gate.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.core import relabel_random, rmat
from repro.core.count_engine import build_counting_plan, count_fn
from repro.core.distributed import build_distributed_plan
from repro.core.frontier import node_exchange_bytes
from repro.core.graphs import edge_list
from repro.core.templates import template
from repro.kernels import ops

from .common import ROOT, emit, run_worker, time_fn

JSON_PATH = os.path.join(ROOT, "BENCH_sparsity.json")

#: per-template engagement thresholds: the threshold trades per-node skip
#: overhead against saved combine work, so narrow-table templates (u7-2,
#: S <= 35 columns) only win on their genuinely sparse deep nodes, while
#: wide-table templates (u10-2, S up to 252) win even at ~0.45 density.
#: The shipping default (DEFAULT_DENSITY_THRESHOLD = 0.25) is the
#: conservative always-wins setting; the bench measures each template in
#: its own engagement regime.
THRESHOLDS = {"u7-2": 0.35, "u10-2": 0.5}
#: tighter headroom than the shipping default: worst-chunk maxima on toy
#: graphs are extremal draws, and the dense fallback keeps overflow exact
CAPACITY_FACTOR = 1.25
TEMPLATES = ("u7-2", "u10-2")
SHARDS = 8
BATCH = 4


def _graph(smoke: bool):
    # avg degree 3 + skew 8: the regime where deep sub-template tables go
    # sparse; the paper's random partition (relabel) spreads the hubs so
    # per-shard/per-chunk activity tracks the global density
    v, e = (1 << 12, 6_000) if smoke else (1 << 13, 12_000)
    return relabel_random(rmat(v, e, skew=8, seed=0), seed=1)


def exchange_bytes(plan) -> dict:
    """Per-iteration, per-device wire volume of every exchange mode family,
    dense vs compacted — at every wire dtype (§18).  Plan math only
    (nothing runs); the ``*bytes*`` keys are structural in the CI bench
    gate, so the wire volume is held lower-is-better per PR."""
    spec = plan.compaction

    def totals(mode, wire):
        dense = compact = 0
        for i, nd in enumerate(plan.program.nodes):
            if nd.is_leaf:
                continue
            d, c = node_exchange_bytes(plan, i, mode, wire_dtype=wire)
            dense += d
            compact += c
        return dense, compact

    a2a_dense, a2a_compact = totals("alltoall", "float32")
    ring_dense, ring_compact = totals("ring", "float32")
    out = {
        "num_shards": plan.num_shards,
        "r_pad": plan.r_pad,
        "exchange_caps_engaged": len(spec.exchange_caps) if spec else 0,
        "ring_caps_engaged": len(spec.shard_caps) if spec else 0,
        "a2a_bytes_dense": a2a_dense,
        "a2a_bytes_compact": a2a_compact,
        "a2a_bytes_compact_frac": a2a_compact / max(a2a_dense, 1),
        "ring_bytes_dense": ring_dense,
        "ring_bytes_compact": ring_compact,
        "ring_bytes_compact_frac": ring_compact / max(ring_dense, 1),
    }
    # narrow wires: compacted+compressed volume vs the float32 dense
    # baseline (the router's own byte counts — same shared formula)
    for wire in ("int16", "int8"):
        _, a2a_w = totals("alltoall", wire)
        _, ring_w = totals("ring", wire)
        out[f"a2a_bytes_compact_{wire}"] = a2a_w
        out[f"ring_bytes_compact_{wire}"] = ring_w
        out[f"a2a_bytes_{wire}_frac"] = a2a_w / max(a2a_dense, 1)
        out[f"ring_bytes_{wire}_frac"] = ring_w / max(ring_dense, 1)
    return out


def bench_template(tname: str, g, smoke: bool) -> dict:
    key = jax.random.key(0)
    threshold = THRESHOLDS[tname]
    dense = build_counting_plan(g, template(tname))
    comp = build_counting_plan(
        g,
        template(tname),
        compact=True,
        density_threshold=threshold,
        capacity_factor=CAPACITY_FACTOR,
    )
    spec = comp.compaction
    rec = {
        "threshold": threshold,
        # leaf keys carry the "density" suffix so the CI bench gate holds
        # them as structural metrics (deterministic: seeded graph + probe)
        "node_density": {
            f"n{i}_density": round(spec.density[i], 4)
            for i in sorted(spec.density)
        },
        "single": {
            "combine_caps_engaged": len(spec.combine_caps),
            "table_caps_engaged": len(spec.table_caps),
        },
    }

    fd = count_fn(dense, batch=BATCH)
    fc = count_fn(comp, batch=BATCH)
    md, _ = fd(key)
    mc, _ = fc(key)
    assert np.array_equal(np.asarray(md), np.asarray(mc)), tname
    sec_dense = time_fn(lambda: fd(key), iters=3)
    sec_comp = time_fn(lambda: fc(key), iters=3)
    rec["single"]["dense_iter_us"] = sec_dense / BATCH * 1e6
    rec["single"]["compact_iter_us"] = sec_comp / BATCH * 1e6
    rec["single"]["speedup_compact"] = sec_dense / sec_comp

    dist = build_distributed_plan(
        g,
        template(tname),
        SHARDS,
        compact=True,
        density_threshold=threshold,
        capacity_factor=CAPACITY_FACTOR,
    )
    rec["distributed"] = exchange_bytes(dist)

    emit(
        f"sparsity/{tname}",
        sec_comp / BATCH * 1e6,
        f"dense={sec_dense / BATCH * 1e3:.0f}ms "
        f"compact={sec_comp / BATCH * 1e3:.0f}ms "
        f"speedup={rec['single']['speedup_compact']:.2f}x "
        f"a2a_bytes={rec['distributed']['a2a_bytes_compact_frac']:.2f} "
        f"ring_bytes={rec['distributed']['ring_bytes_compact_frac']:.2f} "
        f"of dense",
    )
    return rec


def bench_checkpoint(smoke: bool) -> dict:
    """Robustness overhead (DESIGN.md §16): what resumability costs.

    The estimator state banks one float64 per iteration, so the measured
    quantities are the fixed price of a checkpointed run: one atomic
    checksummed save (sync — the resume-point guarantee) and one verified
    ``load_latest`` restore, at a realistic banked-sample size.  Keys are
    ``ckpt_``-prefixed so the CI bench gate holds them in the robustness
    metric class.
    """
    import tempfile

    from repro.core.estimator import EstimatorState
    from repro.train.checkpoint import CheckpointManager

    n_iter = 1 << 10 if smoke else 1 << 14
    rng = np.random.default_rng(0)
    state = EstimatorState(
        signature=f"bench|n_iter={n_iter}|batch={BATCH}|delta=0.1|key=0,0",
        n_iter=n_iter,
        batch=BATCH,
        delta=0.1,
        cursor=n_iter // BATCH,
        samples=np.abs(rng.standard_normal(n_iter)),
    )
    payload = state.to_arrays()
    state_bytes = sum(np.asarray(a).nbytes for a in payload.values())
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        sec_save = time_fn(
            lambda: mgr.save(1, {"estimator": state.to_arrays()}), iters=5
        )
        sec_restore = time_fn(
            lambda: EstimatorState.from_arrays(mgr.load_latest()[1]["estimator"]),
            iters=5,
        )
    rec = {
        "banked_iters": n_iter,
        "ckpt_state_bytes": state_bytes,
        "ckpt_save_us": sec_save * 1e6,
        "ckpt_restore_us": sec_restore * 1e6,
    }
    emit(
        "sparsity/checkpoint",
        sec_save * 1e6,
        f"save={sec_save * 1e3:.2f}ms restore={sec_restore * 1e3:.2f}ms "
        f"state={state_bytes / 1024:.0f}KiB banked={n_iter}",
    )
    return rec


def _dist_worker(smoke: bool):
    """Runs under 8 host devices: pipelined-exchange wall clock, dense vs
    compacted (invoked via run_worker; prints one parsable line)."""
    from repro.compat import make_mesh
    from repro.core.distributed import keyed_sample_fn

    g = _graph(smoke)
    mesh = make_mesh((SHARDS,), ("data",))
    key = jax.random.key(0)
    out = {}
    for tname in TEMPLATES:
        pd = build_distributed_plan(g, template(tname), SHARDS)
        pc = build_distributed_plan(
            g,
            template(tname),
            SHARDS,
            compact=True,
            density_threshold=THRESHOLDS[tname],
            capacity_factor=CAPACITY_FACTOR,
        )
        sd = keyed_sample_fn(pd, mesh, mode="pipeline")
        sc = keyed_sample_fn(pc, mesh, mode="pipeline")
        sw = keyed_sample_fn(pc, mesh, mode="pipeline", wire_dtype="int16")
        assert np.array_equal(sd(key, BATCH), sc(key, BATCH)), tname
        assert np.array_equal(sd(key, BATCH), sw(key, BATCH)), tname
        sec_dense = time_fn(lambda: sd(key, BATCH), iters=3)
        sec_comp = time_fn(lambda: sc(key, BATCH), iters=3)
        sec_wire = time_fn(lambda: sw(key, BATCH), iters=3)
        out[tname] = {
            "dense_iter_us": sec_dense / BATCH * 1e6,
            "compact_iter_us": sec_comp / BATCH * 1e6,
            "compact_int16_iter_us": sec_wire / BATCH * 1e6,
            "speedup_compact": sec_dense / sec_comp,
            "speedup_int16": sec_dense / sec_wire,
        }
    print("DIST_RESULT " + json.dumps(out), flush=True)


def run(smoke: bool = False, json_path: str = JSON_PATH):
    g = _graph(smoke)
    rows, cols = edge_list(g)
    auto_plan = ops.build_spmm_plan(rows, cols, g.n, kind="auto")
    results = {
        "backend": jax.default_backend(),
        "smoke": smoke,
        "graph": {"v": g.n, "e": g.num_edges, "skew": 8},
        "thresholds": dict(THRESHOLDS),
        "capacity_factor": CAPACITY_FACTOR,
        "batch": BATCH,
        # the spmm_kind="auto" signal for this graph (same density family
        # the compaction threshold consumes)
        "spmm_auto": {
            "patch_density": round(auto_plan.patch_density, 2),
            "kind_chosen": auto_plan.kind,
        },
        "templates": {},
    }
    for tname in TEMPLATES:
        results["templates"][tname] = bench_template(tname, g, smoke)
    results["robustness"] = bench_checkpoint(smoke)
    if not smoke:
        # real 8-device pipelined exchange, dense vs compacted
        stdout = run_worker(
            "benchmarks.bench_sparsity", ["--dist-worker"], devices=SHARDS
        )
        for line in stdout.splitlines():
            if line.startswith("DIST_RESULT "):
                dist = json.loads(line[len("DIST_RESULT "):])
                for tname, cell in dist.items():
                    results["templates"][tname]["distributed_timed"] = cell
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small graphs (CI)")
    ap.add_argument("--no-json", action="store_true")
    ap.add_argument("--dist-worker", action="store_true",
                    help=argparse.SUPPRESS)  # run_worker entry (8 devices)
    args = ap.parse_args()
    if args.dist_worker:
        _dist_worker(smoke=False)
        return
    run(smoke=args.smoke, json_path=None if args.no_json else JSON_PATH)


if __name__ == "__main__":
    main()
