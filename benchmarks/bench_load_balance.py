"""Paper Fig. 11 + §3.3: neighbor-list partitioning under degree skew.

Three measurements:
  * structural (single-device) — per-tile load balance: with fixed-size
    edge tiles, the padding waste (padded slots / real edges) is bounded
    for every skew, while per-vertex tasks have max/mean task-size ratios
    equal to the graph skewness (the thread-imbalance the paper fixes);
  * structural (distributed) — bucket-array padding waste of the seed's
    global-max layout ([P, P, max_e]: every (src, dst)-shard bucket padded
    to the largest) vs the tiled layout (fixed-size tiles + CSR offsets,
    O(E + tiles)) across RMAT skew 1/3/8 under the paper's random
    partition;
  * wall-clock — single-device counting time across the same skews.

``run()`` emits the usual CSV lines and returns a dict; ``main()`` writes
``BENCH_load_balance.json`` at the repo root (like ``BENCH_kernels.json``)
so the per-PR load-balance trajectory is machine-readable.
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from repro.core import build_counting_plan, count_fn, relabel_random, rmat
from repro.core.distributed import build_distributed_plan
from repro.core.graphs import edge_list
from repro.core.templates import template
from repro.kernels import ops

from .common import ROOT, emit, time_fn

JSON_PATH = os.path.join(ROOT, "BENCH_load_balance.json")


def bench_single_device(smoke=False):
    tree = template("u5-2")
    out = {}
    v, e = (1 << 10, 10_000) if smoke else (1 << 13, 80_000)
    for skew in (1, 3, 8):
        g = rmat(v, e, skew=skew, seed=skew)
        rec = {"imbalance": g.skewness(), "max_deg": g.max_degree, "tiles": {}}
        # per-vertex tasks: imbalance = max/mean (paper's pathology)
        emit(
            f"fig11/per_vertex_imbalance/skew{skew}",
            0.0,
            f"max_deg={g.max_degree} avg={g.avg_degree:.1f} "
            f"imbalance={g.skewness():.1f}",
        )
        # edge tiles: every task is exactly `s` slots; waste is only padding
        for s in (16, 64, 256):
            rows, cols = edge_list(g)
            plan = ops.build_spmm_plan(rows, cols, g.n, tile_size=s)
            waste = plan.rows.shape[0] / max(len(rows), 1) - 1.0
            rec["tiles"][s] = {"pad_frac": waste}
            emit(
                f"fig11/edge_tile_waste/skew{skew}/s{s}",
                0.0,
                f"tiles={plan.rows.shape[0] // s} pad_frac={waste:.4f}",
            )
        # wall clock per coloring iteration
        plan = build_counting_plan(g, tree)
        f = count_fn(plan)
        key = jax.random.key(0)
        sec = time_fn(lambda: f(key), iters=2)
        rec["iter_us"] = sec * 1e6
        emit(f"fig11/iter_time/skew{skew}", sec * 1e6, "")
        out[f"skew{skew}"] = rec
    return out


def bench_distributed_buckets(smoke=False, shards=8, bucket_tile=128):
    """Seed [P, P, max_e] layout vs §3.3 tiled buckets: padding-waste ratio
    (stored bucket slots / true directed edges) under the paper's random
    partition.  The old layout's waste scales with the largest bucket —
    i.e. with skew — while the tiled layout is bounded by one partial tile
    per bucket plus cross-shard alignment."""
    out = {}
    v, e = (1 << 10, 10_000) if smoke else (1 << 13, 80_000)
    tree = template("u5-2")
    for skew in (1, 3, 8):
        raw = rmat(v, e, skew=skew, seed=skew)
        rec = {}
        # "random" = the paper's partition (what CountingConfig.synthesize
        # produces); "contiguous" = worst case, hubs concentrated in one
        # shard — where the old layout's global-max padding explodes
        for pname, g in (
            ("random", relabel_random(raw, seed=skew + 1)),
            ("contiguous", raw),
        ):
            plan = build_distributed_plan(
                g, tree, shards, bucket_tile=bucket_tile
            )
            e_dir = g.num_directed
            counts = plan.bucket_counts
            max_e_old = max(
                ops.pad_to(int(counts.max(initial=0)), bucket_tile),
                bucket_tile,
            )
            old_slots = shards * shards * max_e_old
            tiled_slots = shards * plan.num_tiles * bucket_tile
            waste_old = old_slots / max(e_dir, 1)
            waste_tiled = tiled_slots / max(e_dir, 1)
            # 3 index arrays in either layout (dst + two src views)
            rec[pname] = {
                "directed_edges": e_dir,
                "max_bucket": int(counts.max(initial=0)),
                "mean_bucket": float(counts.mean()),
                "old_slots": old_slots,
                "tiled_slots": tiled_slots,
                "waste_old": waste_old,
                "waste_tiled": waste_tiled,
                "old_bytes": 3 * old_slots * 4,
                "tiled_bytes": 3 * tiled_slots * 4,
                "num_tiles": plan.num_tiles,
            }
            emit(
                f"fig11/dist_bucket_waste/skew{skew}/{pname}",
                0.0,
                f"old={waste_old:.2f}x tiled={waste_tiled:.2f}x "
                f"max_bucket={int(counts.max(initial=0))} "
                f"P={shards} s={bucket_tile}",
            )
        out[f"skew{skew}"] = rec
    return out


def run(smoke: bool = False, json_path: str = JSON_PATH):
    results = {
        "backend": jax.default_backend(),
        "shards": 8,
        "bucket_tile": 128,
        "smoke": smoke,
    }
    results["single_device"] = bench_single_device(smoke=smoke)
    results["distributed_buckets"] = bench_distributed_buckets(smoke=smoke)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small graphs (CI)")
    ap.add_argument("--no-json", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=None if args.no_json else JSON_PATH)


if __name__ == "__main__":
    main()
