"""Paper Fig. 11 + §3.3: neighbor-list partitioning under degree skew.

Four measurements:
  * structural (single-device) — per-tile load balance: with fixed-size
    edge tiles, the padding waste (padded slots / real edges) is bounded
    for every skew, while per-vertex tasks have max/mean task-size ratios
    equal to the graph skewness (the thread-imbalance the paper fixes);
  * structural (distributed) — bucket-array padding waste of the seed's
    global-max layout ([P, P, max_e]: every (src, dst)-shard bucket padded
    to the largest) vs the tiled layout (fixed-size tiles + CSR offsets,
    O(E + tiles)) across RMAT skew 1/3/8 under the paper's random
    partition;
  * structural (wire, §18) — per-iteration exchange bytes of the 8-shard
    plan per wire dtype: the int16 wire ships exactly 0.5x the float32
    ring bytes (int8 0.25x), held lower-is-better by the CI bench gate;
  * wall-clock — single-device counting time across the same skews, plus
    real 8-host-device ring exchange time per wire dtype (subprocess
    worker, parity-checked against the float32 wire).

``run()`` emits the usual CSV lines and returns a dict; ``main()`` writes
``BENCH_load_balance.json`` at the repo root (like ``BENCH_kernels.json``)
so the per-PR load-balance trajectory is machine-readable.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.core import build_counting_plan, count_fn, relabel_random, rmat
from repro.core.distributed import build_distributed_plan
from repro.core.frontier import node_exchange_bytes
from repro.core.graphs import edge_list
from repro.core.templates import template
from repro.kernels import ops

from .common import ROOT, emit, run_worker, time_fn

JSON_PATH = os.path.join(ROOT, "BENCH_load_balance.json")


def bench_single_device(smoke=False):
    tree = template("u5-2")
    out = {}
    v, e = (1 << 10, 10_000) if smoke else (1 << 13, 80_000)
    for skew in (1, 3, 8):
        g = rmat(v, e, skew=skew, seed=skew)
        rec = {"imbalance": g.skewness(), "max_deg": g.max_degree, "tiles": {}}
        # per-vertex tasks: imbalance = max/mean (paper's pathology)
        emit(
            f"fig11/per_vertex_imbalance/skew{skew}",
            0.0,
            f"max_deg={g.max_degree} avg={g.avg_degree:.1f} "
            f"imbalance={g.skewness():.1f}",
        )
        # edge tiles: every task is exactly `s` slots; waste is only padding
        for s in (16, 64, 256):
            rows, cols = edge_list(g)
            plan = ops.build_spmm_plan(rows, cols, g.n, tile_size=s)
            waste = plan.rows.shape[0] / max(len(rows), 1) - 1.0
            rec["tiles"][s] = {"pad_frac": waste}
            emit(
                f"fig11/edge_tile_waste/skew{skew}/s{s}",
                0.0,
                f"tiles={plan.rows.shape[0] // s} pad_frac={waste:.4f}",
            )
        # wall clock per coloring iteration
        plan = build_counting_plan(g, tree)
        f = count_fn(plan)
        key = jax.random.key(0)
        sec = time_fn(lambda: f(key), iters=2)
        rec["iter_us"] = sec * 1e6
        emit(f"fig11/iter_time/skew{skew}", sec * 1e6, "")
        out[f"skew{skew}"] = rec
    return out


def bench_distributed_buckets(smoke=False, shards=8, bucket_tile=128):
    """Seed [P, P, max_e] layout vs §3.3 tiled buckets: padding-waste ratio
    (stored bucket slots / true directed edges) under the paper's random
    partition.  The old layout's waste scales with the largest bucket —
    i.e. with skew — while the tiled layout is bounded by one partial tile
    per bucket plus cross-shard alignment."""
    out = {}
    v, e = (1 << 10, 10_000) if smoke else (1 << 13, 80_000)
    tree = template("u5-2")
    for skew in (1, 3, 8):
        raw = rmat(v, e, skew=skew, seed=skew)
        rec = {}
        # "random" = the paper's partition (what CountingConfig.synthesize
        # produces); "contiguous" = worst case, hubs concentrated in one
        # shard — where the old layout's global-max padding explodes
        for pname, g in (
            ("random", relabel_random(raw, seed=skew + 1)),
            ("contiguous", raw),
        ):
            plan = build_distributed_plan(g, tree, shards, bucket_tile=bucket_tile)
            e_dir = g.num_directed
            counts = plan.bucket_counts
            max_e_old = max(
                ops.pad_to(int(counts.max(initial=0)), bucket_tile),
                bucket_tile,
            )
            old_slots = shards * shards * max_e_old
            tiled_slots = shards * plan.num_tiles * bucket_tile
            waste_old = old_slots / max(e_dir, 1)
            waste_tiled = tiled_slots / max(e_dir, 1)
            # 3 index arrays in either layout (dst + two src views)
            rec[pname] = {
                "directed_edges": e_dir,
                "max_bucket": int(counts.max(initial=0)),
                "mean_bucket": float(counts.mean()),
                "old_slots": old_slots,
                "tiled_slots": tiled_slots,
                "waste_old": waste_old,
                "waste_tiled": waste_tiled,
                "old_bytes": 3 * old_slots * 4,
                "tiled_bytes": 3 * tiled_slots * 4,
                "num_tiles": plan.num_tiles,
            }
            emit(
                f"fig11/dist_bucket_waste/skew{skew}/{pname}",
                0.0,
                f"old={waste_old:.2f}x tiled={waste_tiled:.2f}x "
                f"max_bucket={int(counts.max(initial=0))} "
                f"P={shards} s={bucket_tile}",
            )
        out[f"skew{skew}"] = rec
    return out


def bench_wire_volume(smoke=False, shards=8):
    """§18 narrow-wire exchange volume: per-iteration, per-device bytes of
    the 8-shard u5-2 plan at every wire dtype (plan math only).  The
    ``*bytes*``/``*ratio*`` keys are structural in the CI bench gate, so
    the wire volume — including the 0.5x int16 ring acceptance ratio on
    the skew-8 R-MAT — is held lower-is-better per PR."""
    out = {}
    v, e = (1 << 10, 10_000) if smoke else (1 << 13, 80_000)
    tree = template("u5-2")
    for skew in (1, 3, 8):
        g = relabel_random(rmat(v, e, skew=skew, seed=skew), seed=skew + 1)
        plan = build_distributed_plan(g, tree, shards)
        rec = {}
        for wire, tag in (("float32", "f32"), ("int16", "int16"), ("int8", "int8")):
            a2a = ring = 0
            for i, nd in enumerate(plan.program.nodes):
                if nd.is_leaf:
                    continue
                a2a += node_exchange_bytes(plan, i, "alltoall", wire_dtype=wire)[0]
                ring += node_exchange_bytes(plan, i, "ring", wire_dtype=wire)[0]
            rec[f"a2a_bytes_{tag}"] = a2a
            rec[f"ring_bytes_{tag}"] = ring
        rec["ring_wire_ratio_int16"] = rec["ring_bytes_int16"] / max(rec["ring_bytes_f32"], 1)
        rec["ring_wire_ratio_int8"] = rec["ring_bytes_int8"] / max(rec["ring_bytes_f32"], 1)
        emit(
            f"fig11/wire_volume/skew{skew}",
            0.0,
            f"ring f32={rec['ring_bytes_f32']} "
            f"int16={rec['ring_bytes_int16']} "
            f"({rec['ring_wire_ratio_int16']:.2f}x) "
            f"int8={rec['ring_bytes_int8']} P={shards}",
        )
        out[f"skew{skew}"] = rec
    return out


def _dist_worker(smoke: bool):
    """Runs under 8 host devices: ring exchange wall clock per wire dtype
    on the skew-8 graph, parity-checked, plus the measured calibration
    constants (invoked via run_worker; prints one parsable line)."""
    from repro.comm.adaptive import calibrate
    from repro.compat import make_mesh
    from repro.core.distributed import keyed_sample_fn

    v, e = (1 << 10, 10_000) if smoke else (1 << 13, 80_000)
    g = relabel_random(rmat(v, e, skew=8, seed=8), seed=9)
    plan = build_distributed_plan(g, template("u5-2"), 8)
    mesh = make_mesh((8,), ("data",))
    key = jax.random.key(0)
    out = {}
    base = None
    for wire, tag in (("float32", "f32"), ("int16", "int16"), ("int8", "int8")):
        f = keyed_sample_fn(plan, mesh, mode="ring", wire_dtype=wire)
        got = f(key, 2)
        if base is None:
            base = got
        assert np.array_equal(base, got), wire
        sec = time_fn(lambda: f(key, 2), iters=3)
        out[f"ring_{tag}_iter_us"] = sec / 2 * 1e6
    # the §18 probe's fitted link constants on this host (recorded, not
    # gated: no key-class suffix — raw latencies vary too much across CI
    # hosts for even the loose timing factor)
    model = calibrate(mesh)
    out["calib_alpha"] = model.alpha
    out["calib_beta"] = model.beta
    print("DIST_RESULT " + json.dumps(out), flush=True)


def run(smoke: bool = False, json_path: str = JSON_PATH):
    results = {
        "backend": jax.default_backend(),
        "shards": 8,
        "bucket_tile": 128,
        "smoke": smoke,
    }
    results["single_device"] = bench_single_device(smoke=smoke)
    results["distributed_buckets"] = bench_distributed_buckets(smoke=smoke)
    results["wire_volume"] = bench_wire_volume(smoke=smoke)
    # real 8-device ring exchange per wire dtype (runs in smoke mode too:
    # the tracked baseline carries the exchange-time columns)
    stdout = run_worker(
        "benchmarks.bench_load_balance",
        ["--dist-worker"] + (["--smoke"] if smoke else []),
        devices=8,
    )
    for line in stdout.splitlines():
        if line.startswith("DIST_RESULT "):
            results["wire_exchange"] = json.loads(line[len("DIST_RESULT "):])
            emit(
                "fig11/wire_exchange",
                results["wire_exchange"]["ring_int16_iter_us"],
                f"f32={results['wire_exchange']['ring_f32_iter_us']:.0f}us "
                f"int16={results['wire_exchange']['ring_int16_iter_us']:.0f}us "
                f"int8={results['wire_exchange']['ring_int8_iter_us']:.0f}us",
            )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small graphs (CI)")
    ap.add_argument("--no-json", action="store_true")
    ap.add_argument("--dist-worker", action="store_true",
                    help=argparse.SUPPRESS)  # run_worker entry (8 devices)
    args = ap.parse_args()
    if args.dist_worker:
        _dist_worker(smoke=args.smoke)
        return
    run(smoke=args.smoke, json_path=None if args.no_json else JSON_PATH)


if __name__ == "__main__":
    main()
