"""Paper Fig. 11 + §3.3: neighbor-list partitioning under degree skew.

Two measurements:
  * structural — per-tile load balance: with fixed-size edge tiles, the
    padding waste (padded slots / real edges) is bounded for every skew,
    while per-vertex tasks have max/mean task-size ratios equal to the
    graph skewness (the thread-imbalance the paper fixes);
  * wall-clock — single-device counting time across RMAT skew 1/3/8 and a
    task-size (tile) sweep, reproducing the paper's 40-60 sweet spot study
    (on TPU the tile is the Pallas block; on CPU the XLA segment width).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import build_counting_plan, count_fn, rmat
from repro.core.graphs import edge_list
from repro.core.templates import template
from repro.kernels import ops

from .common import emit, time_fn


def run():
    tree = template("u5-2")
    for skew in (1, 3, 8):
        g = rmat(1 << 13, 80_000, skew=skew, seed=skew)
        deg = g.degrees()
        # per-vertex tasks: imbalance = max/mean (paper's pathology)
        emit(
            f"fig11/per_vertex_imbalance/skew{skew}",
            0.0,
            f"max_deg={g.max_degree} avg={g.avg_degree:.1f} "
            f"imbalance={g.skewness():.1f}",
        )
        # edge tiles: every task is exactly `s` slots; waste is only padding
        for s in (16, 64, 256):
            rows, cols = edge_list(g)
            plan = ops.build_spmm_plan(rows, cols, g.n, tile_size=s)
            waste = plan.rows.shape[0] / max(len(rows), 1) - 1.0
            emit(
                f"fig11/edge_tile_waste/skew{skew}/s{s}",
                0.0,
                f"tiles={plan.rows.shape[0] // s} pad_frac={waste:.4f}",
            )
        # wall clock per coloring iteration
        plan = build_counting_plan(g, tree)
        f = count_fn(plan)
        key = jax.random.key(0)
        sec = time_fn(lambda: f(key), iters=2)
        emit(f"fig11/iter_time/skew{skew}", sec * 1e6, "")


def main():
    run()


if __name__ == "__main__":
    main()
