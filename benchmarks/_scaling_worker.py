"""Worker (run with N host devices): scaling + memory + overall benchmarks.

Emits CSV lines ``name,us_per_call,derived``.  Invoked by benchmarks.run via
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import relabel_random, rmat
from repro.core.distributed import build_distributed_plan, make_count_fn, shard_coloring
from repro.core.templates import template


def make_mesh(shards, iters=1):
    from repro.launch.mesh import make_mesh as _mk

    if iters > 1:
        return _mk((shards, iters), ("data", "model"))
    return _mk((shards,), ("data",))


def time_mode(g, tree, shards, mode, gf=1, iters=2):
    mesh = make_mesh(shards)
    plan = build_distributed_plan(g, tree, shards)
    f = make_count_fn(plan, mesh, mode=mode, group_factor=gf)
    rng = np.random.default_rng(0)
    coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
    cols = jnp.asarray(shard_coloring(plan, coloring)[None])
    out = f(cols)
    out.block_until_ready()  # compile + warmup
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        f(cols).block_until_ready()
        times.append(time.perf_counter() - t0)
    return min(times), float(out[0])


def bench_strong_scaling(args):
    """Paper Fig. 7/9/15: fixed graph, growing device count, mode comparison."""
    g = relabel_random(rmat(1 << 14, args.edges, skew=3, seed=1), seed=2)
    tree = template(args.template)
    for shards in (2, 4, 8):
        for mode in ("alltoall", "pipeline", "adaptive", "ring"):
            sec, count = time_mode(g, tree, shards, mode)
            print(f"strong/{args.template}/P{shards}/{mode},{sec * 1e6:.1f},count={count:.4g}")


def bench_weak_scaling(args):
    """Paper Fig. 10: per-shard workload fixed, devices growing."""
    tree = template(args.template)
    for shards in (2, 4, 8):
        g = relabel_random(
            rmat(shards * 2048, shards * args.edges_per_shard, skew=3, seed=shards),
            seed=3,
        )
        for mode in ("alltoall", "pipeline"):
            sec, _ = time_mode(g, tree, shards, mode)
            print(
                f"weak/{args.template}/P{shards}/{mode},{sec * 1e6:.1f},"
                f"V={g.n} E={g.num_edges}"
            )


def bench_peak_memory(args):
    """Paper Fig. 12: peak temp bytes, naive vs pipeline vs ring (compiled
    memory analysis of the distributed step on 8 shards)."""
    g = relabel_random(rmat(1 << 14, args.edges, skew=3, seed=5), seed=5)
    tree = template(args.template)
    shards = 8
    mesh = make_mesh(shards)
    plan = build_distributed_plan(g, tree, shards)
    rng = np.random.default_rng(0)
    cols = jnp.asarray(shard_coloring(plan, rng.integers(0, tree.n, g.n).astype(np.int32))[None])
    for mode in ("alltoall", "pipeline", "ring"):
        f = make_count_fn(plan, mesh, mode=mode)
        mem = jax.jit(f).lower(cols).compile().memory_analysis()
        print(
            f"peakmem/{args.template}/{mode},0.0,"
            f"temp_bytes={mem.temp_size_in_bytes} arg_bytes={mem.argument_size_in_bytes}"
        )


def bench_overall(args):
    """Paper Fig. 13: naive vs full-optimized across template sizes."""
    g = relabel_random(rmat(1 << 13, args.edges, skew=3, seed=7), seed=7)
    for tname in ("u3-1", "u5-2", "u7-2"):
        tree = template(tname)
        for mode in ("alltoall", "adaptive"):
            sec, _ = time_mode(g, tree, 8, mode)
            print(f"overall/{tname}/{mode},{sec * 1e6:.1f},")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("bench")
    ap.add_argument("--template", default="u5-2")
    ap.add_argument("--edges", type=int, default=120_000)
    ap.add_argument("--edges-per-shard", type=int, default=20_000)
    args = ap.parse_args()
    {
        "strong": bench_strong_scaling,
        "weak": bench_weak_scaling,
        "peakmem": bench_peak_memory,
        "overall": bench_overall,
    }[args.bench](args)


if __name__ == "__main__":
    main()
