"""Multi-device tests (8 host devices) run in a subprocess so the main test
process keeps a single device (see the dry-run/device-count policy).

The worker prints one ``CHECK <name> PASS|FAIL`` line per assertion; this
wrapper re-exposes them as a single pytest with a readable report.
"""

import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.timeout(900)
def test_multi_device_suite():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "tests", "_dist_worker.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=880,
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed worker failed (see CHECK lines)"
    assert "ALL OK" in proc.stdout
    assert "FAIL" not in proc.stdout
