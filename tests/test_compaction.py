"""Active-frontier compaction (DESIGN.md §15): parity, capacities, fallback.

The invariant under test everywhere: compaction is a pure data-layout
choice — the compact program computes **bit-identical** counts and keyed
estimator samples to the dense program whenever its capacity flags hold,
and transparently falls back to the dense program when they do not (so it
is exact even at absurd capacities).

Single-process coverage: the in-core backend across impl x fuse, the full
distributed machinery on a 1-shard mesh across all four exchange modes,
and the family (DAG) path.  Real 8-shard coverage (all modes x fuse x
pallas, compacted exchange actually crossing device boundaries) runs in
``tests/_dist_worker.py::test_compaction``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import Counter
from repro.core import rmat
from repro.core.brute_force import count_colorful_maps
from repro.core.count_engine import (
    build_counting_plan,
    build_multi_counting_plan,
    colorful_map_count,
    colorful_map_count_checked,
    count_fn,
    count_fn_many,
)
from repro.core.frontier import (
    CompactionSpec,
    capacity_for,
    model_density,
    probe_activity,
)
from repro.core.templates import path_tree, spider_tree, template


def _skewed_graph(n=1024, e=3000, seed=2):
    return rmat(n, e, skew=8, seed=seed)


@pytest.fixture
def force_floors(monkeypatch):
    """Drop the profitability floors so compaction engages on the small
    templates the tests can afford — exactness must hold regardless of
    whether compaction is a *win*, which is what these tests check."""
    import repro.core.frontier as frontier

    monkeypatch.setattr(frontier, "MIN_COMBINE_ELEMENTS", 1)
    monkeypatch.setattr(frontier, "MIN_TABLE_WIDTH", 1)


def _coloring(plan, g, k, seed=0):
    rng = np.random.default_rng(seed)
    col = np.zeros(plan.n_pad, np.int32)
    col[: g.n] = rng.integers(0, k, g.n)
    return jnp.asarray(col)


class TestProbe:
    def test_probe_matches_real_activity(self):
        """The boolean probe is exact: its active mask for a coloring equals
        the nonzero rows of the real DP's node tables (checked at the root:
        active root rows <=> the DP's count for that coloring is nonzero)."""
        g = _skewed_graph()
        tree = template("u7-2")
        plan = build_counting_plan(g, tree)
        masks = next(probe_activity(g, plan.chain, plan.combine, plan.k, probes=1, seed=5))
        rng = np.random.default_rng(5)  # the probe's own coloring stream
        coloring = rng.integers(0, plan.k, g.n).astype(np.int32)
        col = np.zeros(plan.n_pad, np.int32)
        col[: g.n] = coloring
        want = float(colorful_map_count(plan, jnp.asarray(col)))
        root = plan.chain.root_index
        # probe says the root has active rows iff the DP count is nonzero
        assert bool(masks[root].table.any()) == (want > 0)
        # densities shrink with sub-template depth on a skewed sparse graph
        dens = {i: m.table.mean() for i, m in masks.items()}
        sizes = {i: plan.chain.nodes[i].size for i in dens}
        deepest = max(sizes, key=sizes.get)
        shallowest = min(sizes, key=sizes.get)
        assert dens[deepest] <= dens[shallowest]

    def test_capacity_math(self):
        assert capacity_for(10, 1.5, 10_000) == 128  # padded + zero slot
        assert capacity_for(1000, 1.5, 1536) == 1536 or capacity_for(
            1000, 1.5, 1536
        ) is None  # at the limit: no win -> None
        assert capacity_for(1000, 1.5, 1537) == 1536
        assert capacity_for(0, 1.5, 1024) == 128
        assert capacity_for(50, 2.0, 64, multiple=8) is None

    def test_model_density_bounds(self):
        assert model_density(1, 7, 100.0) == 1.0
        for t in range(2, 8):
            rho = model_density(t, 7, 2.0)
            assert 0.0 <= rho <= 1.0
        # deep templates on low-degree graphs are sparse, high-degree dense
        assert model_density(7, 7, 1.5) < 0.2
        assert model_density(3, 7, 50.0) == 1.0

    def test_spec_enabled(self):
        empty = CompactionSpec(0.25, 1.5, {}, {}, {}, {})
        assert not empty.enabled
        assert CompactionSpec(0.25, 1.5, {}, {}, {1: 128}, {}).enabled


class TestSingleDeviceParity:
    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    @pytest.mark.parametrize("fuse", [False, True])
    def test_compact_equals_dense_bitexact(self, impl, fuse, force_floors):
        g = _skewed_graph()
        tree = template("u7-2")
        dense = build_counting_plan(g, tree, impl=impl, fuse=fuse)
        comp = build_counting_plan(
            g, tree, impl=impl, fuse=fuse, compact=True, density_threshold=0.7
        )
        assert comp.compaction is not None and comp.compaction.enabled
        col = _coloring(dense, g, dense.k)
        want = float(colorful_map_count(dense, col))
        got, ok = colorful_map_count_checked(comp, col)
        assert bool(ok)
        assert float(got) == want  # bit-exact, not approx

    def test_right_child_indirection_engages(self, force_floors):
        """u7-2's root exchanges an internal (size-3) right child: with a
        permissive threshold its table cap must engage, driving the
        SpMM through the compact row-index indirection."""
        g = _skewed_graph()
        comp = build_counting_plan(g, template("u7-2"), compact=True, density_threshold=0.7)
        spec = comp.compaction
        rights = {
            nd.right
            for nd in comp.chain.nodes
            if not nd.is_leaf and not comp.chain.nodes[nd.right].is_leaf
        }
        assert rights & set(spec.table_caps), (rights, spec.table_caps)
        # capacities are static multiples of the pallas row tile
        for cap in list(spec.table_caps.values()) + list(
            spec.combine_caps.values()
        ):
            assert cap % 128 == 0 and cap < comp.n_pad

    def test_keyed_samples_identical(self, force_floors):
        """Same key => identical per-iteration estimator samples, compact
        vs dense (the same-key contract the estimator relies on)."""
        g = _skewed_graph()
        tree = template("u7-2")
        fd = count_fn(build_counting_plan(g, tree), batch=4)
        fc = count_fn(
            build_counting_plan(g, tree, compact=True, density_threshold=0.7),
            batch=4,
        )
        key = jax.random.key(7)
        md, ed = fd(key)
        mc, ec = fc(key)
        assert np.array_equal(np.asarray(md), np.asarray(mc))
        assert np.array_equal(np.asarray(ed), np.asarray(ec))

    def test_overflow_falls_back_to_dense(self, force_floors):
        """Absurdly small capacities overflow on every coloring; the
        wrapper must re-dispatch the dense program and still be exact."""
        g = _skewed_graph()
        tree = template("u5-2")
        dense = build_counting_plan(g, tree)
        tiny = build_counting_plan(
            g, tree, compact=True, density_threshold=1.0, capacity_factor=1e-6
        )
        assert tiny.compaction.enabled
        col = _coloring(dense, g, dense.k)
        _, ok = colorful_map_count_checked(tiny, col)
        assert not bool(ok)  # the flag actually trips
        fd = count_fn(dense, batch=3)
        ft = count_fn(tiny, batch=3)
        key = jax.random.key(1)
        md, _ = fd(key)
        mt, _ = ft(key)
        assert np.array_equal(np.asarray(md), np.asarray(mt))

    def test_colorful_map_count_stays_dense(self, force_floors):
        """The unchecked entry point keeps its dense contract even on a
        compacted plan (callers that cannot consume the flag)."""
        g = _skewed_graph()
        comp = build_counting_plan(
            g,
            template("u5-2"),
            compact=True,
            density_threshold=1.0,
            capacity_factor=1e-6,
        )
        dense = build_counting_plan(g, template("u5-2"))
        col = _coloring(dense, g, dense.k)
        assert float(colorful_map_count(comp, col)) == float(colorful_map_count(dense, col))


class TestFamilyParity:
    def test_dag_compact_parity(self, force_floors):
        g = _skewed_graph()
        family = ["u3-1", "u5-2", "u7-2"]
        dense = build_multi_counting_plan(g, family)
        comp = build_multi_counting_plan(g, family, compact=True, density_threshold=0.7)
        assert comp.compaction.enabled
        fd = count_fn_many(dense, batch=3)
        fc = count_fn_many(comp, batch=3)
        key = jax.random.key(2)
        md, _ = fd(key)
        mc, _ = fc(key)
        assert np.array_equal(np.asarray(md), np.asarray(mc))

    def test_counter_facade_family(self):
        g = _skewed_graph(512, 1500, seed=3)
        family = [path_tree(3), spider_tree([2, 1])]
        k = max(t.n for t in family)
        rng = np.random.default_rng(4)
        coloring = rng.integers(0, k, g.n).astype(np.int32)
        dense = Counter.from_graph(g, family[-1], backend="single")
        comp = Counter.from_graph(
            g,
            family[-1],
            backend="single",
            compact=True,
            density_threshold=0.9,
        )
        want = dense.count_coloring_many(family, coloring)
        got = comp.count_coloring_many(family, coloring)
        assert np.array_equal(want, got)


class TestOneShardDistributed:
    """Full distributed machinery on a 1-shard mesh in-process: compacted
    exchange + compact combine vs the dense program and the oracle."""

    @pytest.mark.parametrize("mode", ["alltoall", "pipeline", "adaptive", "ring"])
    @pytest.mark.parametrize("fuse", [False, True])
    def test_compact_parity(self, mode, fuse):
        g = _skewed_graph(512, 1500, seed=4)
        tree = spider_tree([2, 1])
        rng = np.random.default_rng(0)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        dense = Counter.from_graph(
            g, tree, backend="distributed", num_shards=1, mode=mode, fuse=fuse
        )
        comp = Counter.from_graph(
            g,
            tree,
            backend="distributed",
            num_shards=1,
            mode=mode,
            fuse=fuse,
            compact=True,
            density_threshold=0.9,
        )
        assert comp.plan.compaction is not None
        d = dense.count_coloring(coloring)
        c = comp.count_coloring(coloring)
        assert d == c  # bit-exact between programs
        assert c == pytest.approx(want, rel=1e-6)

    def test_overflow_fallback_distributed(self):
        g = _skewed_graph(512, 1500, seed=4)
        tree = spider_tree([2, 1])
        rng = np.random.default_rng(1)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        dense = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode="pipeline")
        tiny = Counter.from_graph(
            g,
            tree,
            backend="distributed",
            num_shards=1,
            mode="pipeline",
            compact=True,
            density_threshold=1.0,
            capacity_factor=1e-6,
        )
        assert tiny.plan.compaction.enabled
        assert dense.count_coloring(coloring) == tiny.count_coloring(coloring)

    def test_keyed_estimate_samples_identical(self):
        g = _skewed_graph(512, 1500, seed=4)
        tree = path_tree(4)
        dense = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode="alltoall")
        comp = Counter.from_graph(
            g,
            tree,
            backend="distributed",
            num_shards=1,
            mode="alltoall",
            compact=True,
            density_threshold=0.9,
        )
        key = jax.random.key(6)
        rd = dense.estimate(n_iter=6, key=key, batch=3)
        rc = comp.estimate(n_iter=6, key=key, batch=3)
        assert np.array_equal(rd.samples, rc.samples)


class TestPlanOpts:
    def test_api_accepts_compaction_opts(self):
        g = _skewed_graph(256, 800, seed=5)
        c = Counter.from_graph(
            g,
            path_tree(3),
            backend="single",
            compact=True,
            density_threshold=0.5,
            capacity_factor=2.0,
            probes=1,
        )
        plan = c.plan
        assert plan.compaction is not None
        assert plan.compaction.threshold == 0.5
        assert plan.compaction.capacity_factor == 2.0
        assert plan.compaction.probes == 1

    def test_unknown_opt_still_rejected(self):
        g = _skewed_graph(256, 800, seed=5)
        with pytest.raises(TypeError):
            Counter.from_graph(g, path_tree(3), compacct=True)


class TestPropertyParity:
    """Hypothesis sweep: compaction on vs off agrees bit-for-bit on counts
    and keyed samples for arbitrary skewed graphs, templates, thresholds,
    and capacity factors — including factors small enough to overflow."""

    def test_compact_parity_property(self, force_floors):
        pytest.importorskip(
            "hypothesis",
            reason="property tests need hypothesis (see requirements-dev.txt)",
        )
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st

        @given(
            st.integers(100, 500),
            st.integers(3, 9),
            st.sampled_from(["p4", "sp21", "u5-2"]),
            st.floats(0.05, 2.0),
            st.integers(0, 10_000),
        )
        @settings(
            max_examples=8,
            deadline=None,
            suppress_health_check=[
                HealthCheck.too_slow,
                HealthCheck.data_too_large,
            ],
        )
        def check(n, skew, tname, cf, seed):
            g = rmat(n, 3 * n, skew=skew, seed=seed)
            tree = {
                "p4": path_tree(4),
                "sp21": spider_tree([2, 1]),
                "u5-2": template("u5-2"),
            }[tname]
            dense = build_counting_plan(g, tree)
            comp = build_counting_plan(
                g,
                tree,
                compact=True,
                density_threshold=1.0,
                capacity_factor=cf,
                probes=1,
            )
            fd = count_fn(dense, batch=2)
            fc = count_fn(comp, batch=2)
            key = jax.random.key(seed)
            md, ed = fd(key)
            mc, ec = fc(key)
            assert np.array_equal(np.asarray(md), np.asarray(mc))
            assert np.array_equal(np.asarray(ed), np.asarray(ec))

        check()
