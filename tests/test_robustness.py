"""Resumable, fault-tolerant estimation (DESIGN.md §16).

The contract under test: a killed estimate resumed from its checkpoint
returns the **bit-identical** result an uninterrupted run produces — at
every checkpoint boundary, on both backends, under compaction, and even
when the kill lands *inside* a checkpoint write.  Around it: the
supervisor's retry/validate/quarantine taxonomy, the checkpoint manager's
corrupt-skip and crash-residue handling, and the hardened graph loaders.

Every failure here is *injected deterministically* via
``repro.testing.faults`` — no timing races, no monkeypatched internals.
The real 8-shard distributed variants run in ``tests/_dist_worker.py``.
"""

import os

import numpy as np
import pytest

import jax

from repro.api import Counter
from repro.core import erdos_renyi, load_edge_file, load_npz, rmat, save_npz
from repro.core.estimator import (
    EstimationAborted,
    EstimatorState,
    ResumeMismatchError,
    estimate_counts,
    num_groups_for,
)
from repro.core.graphs import GraphFormatError
from repro.core.supervisor import (
    QuarantinedBatch,
    RetryPolicy,
    SampleValidationError,
    Supervisor,
    key_fingerprint,
)
from repro.core.templates import path_tree, template
from repro.testing import faults
from repro.train.checkpoint import CheckpointManager


def _noop_sleep(_):
    pass


class FakeClock:
    """Virtual time: ``sleep`` advances the clock instead of waiting, so
    timeout/backoff paths run in zero wall time (the Supervisor's
    injected-clock mode judges timeouts from clock readings)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def sleep(self, s: float) -> None:
        self.t += s


def _mgr(tmp_path, sub="ckpt"):
    return CheckpointManager(str(tmp_path / sub), async_save=False)


@pytest.fixture
def force_floors(monkeypatch):
    import repro.core.frontier as frontier

    monkeypatch.setattr(frontier, "MIN_COMBINE_ELEMENTS", 1)
    monkeypatch.setattr(frontier, "MIN_TABLE_WIDTH", 1)


# --------------------------------------------------------------------------
# kill-and-resume determinism
# --------------------------------------------------------------------------

BACKENDS = [
    ("single", {}),
    ("distributed", {"num_shards": 1, "mode": "pipeline"}),
]


class TestResumeDeterminism:
    """Bit-exact resume: the tentpole invariant, at every boundary."""

    def _counter(self, backend, opts, **extra):
        g = erdos_renyi(40, 4.0, seed=5)
        return Counter.from_graph(g, path_tree(3), backend=backend, **opts, **extra)

    @pytest.mark.parametrize("backend,opts", BACKENDS)
    def test_kill_and_resume_every_boundary(self, backend, opts, tmp_path):
        """n_iter=12 / batch=4 => 3 calls, mid-run checkpoints after calls
        1 and 2.  Kill after each and resume: samples, estimate, and RSD
        must equal the uninterrupted run exactly (==, not approx)."""
        key = jax.random.key(0)
        base = self._counter(backend, opts).estimate(n_iter=12, key=key, batch=4)
        for kill_at in (0, 1):
            d = tmp_path / f"{backend}-{kill_at}"
            c = self._counter(backend, opts)
            with faults.active(faults.inject("estimator.kill", at=(kill_at,))):
                with pytest.raises(faults.InjectedCrash):
                    c.estimate(n_iter=12, key=key, batch=4, checkpoint=str(d), checkpoint_every=4)
            res = self._counter(backend, opts).estimate(n_iter=12, key=key, batch=4, resume=str(d))
            assert res.resumed_from == 4 * (kill_at + 1)
            np.testing.assert_array_equal(res.samples, base.samples)
            assert res.estimate == base.estimate
            assert res.mean == base.mean
            assert res.relative_sd == base.relative_sd
            assert res.quarantined == ()

    @pytest.mark.parametrize("backend,opts", BACKENDS)
    def test_kill_inside_checkpoint_write(self, backend, opts, tmp_path):
        """The worst kill: inside ``_write``, after the tmp dir is full but
        before the atomic rename.  The ``step_*.tmp`` residue must be
        skipped/GCed and the run resumes from the last *renamed* step."""
        key = jax.random.key(1)
        base = self._counter(backend, opts).estimate(n_iter=12, key=key, batch=4)
        d = tmp_path / "midwrite"
        c = self._counter(backend, opts)
        # second checkpoint write (occurrence 1) dies mid-save: step 1 is
        # the newest *renamed* checkpoint, step 2 exists only as .tmp
        with faults.active(faults.inject("checkpoint.write_crash", at=(1,))):
            with pytest.raises(faults.InjectedCrash):
                c.estimate(n_iter=12, key=key, batch=4, checkpoint=str(d), checkpoint_every=4)
        left = sorted(os.listdir(d))
        assert "step_00000001" in left
        assert any(name.endswith(".tmp") for name in left)
        res = self._counter(backend, opts).estimate(n_iter=12, key=key, batch=4, resume=str(d))
        assert res.resumed_from == 4  # resumed from step 1, not the tmp
        np.testing.assert_array_equal(res.samples, base.samples)
        assert res.estimate == base.estimate
        # the residue is gone after load_latest's GC
        assert not any(n.endswith(".tmp") for n in os.listdir(d))

    def test_resume_under_compaction(self, tmp_path, force_floors):
        """Resume composes with §15 compaction, including a forced overflow
        storm on the resumed leg (every compact dispatch re-runs its dense
        twin) — compaction is a layout choice, so the estimate is still
        bit-identical."""
        g = rmat(256, 700, skew=8, seed=2)
        opts = dict(compact=True, density_threshold=0.7)
        key = jax.random.key(2)
        base = Counter.from_graph(g, template("u5-2"), backend="single",
                                  **opts).estimate(n_iter=8, key=key, batch=4)
        d = tmp_path / "compact"
        c = Counter.from_graph(g, template("u5-2"), backend="single", **opts)
        with faults.active(faults.inject("estimator.kill", at=(0,))):
            with pytest.raises(faults.InjectedCrash):
                c.estimate(n_iter=8, key=key, batch=4, checkpoint=str(d), checkpoint_every=4)
        c2 = Counter.from_graph(g, template("u5-2"), backend="single", **opts)
        with faults.active(faults.inject("compaction.overflow", at=None)) as plan:
            res = c2.estimate(n_iter=8, key=key, batch=4, resume=str(d))
            assert plan.fired  # the storm actually hit the fallback path
        assert res.resumed_from == 4
        np.testing.assert_array_equal(res.samples, base.samples)
        assert res.estimate == base.estimate

    def test_resume_family(self, tmp_path):
        """estimate_many banks the full [iter, T] matrix; resume is
        bit-exact per template."""
        g = erdos_renyi(40, 4.0, seed=7)
        fam = ["u3-1", "u5-2"]
        key = jax.random.key(3)
        base = Counter.from_graph(g, "u5-2", backend="single").estimate_many(
            fam, n_iter=12, key=key, batch=4
        )
        d = tmp_path / "family"
        c = Counter.from_graph(g, "u5-2", backend="single")
        with faults.active(faults.inject("estimator.kill", at=(1,))):
            with pytest.raises(faults.InjectedCrash):
                c.estimate_many(fam, n_iter=12, key=key, batch=4,
                                checkpoint=str(d), checkpoint_every=4)
        res = Counter.from_graph(g, "u5-2", backend="single").estimate_many(
            fam, n_iter=12, key=key, batch=4, resume=str(d)
        )
        assert res.resumed_from == 8
        np.testing.assert_array_equal(res.samples, base.samples)
        np.testing.assert_array_equal(res.estimates, base.estimates)
        np.testing.assert_array_equal(res.relative_sds, base.relative_sds)

    def test_completed_run_resumes_as_noop(self, tmp_path):
        """A finished checkpoint directory restores to a no-op: zero new
        backend calls, same result."""
        calls = []

        def fn(key, b):
            calls.append(1)
            return np.full(b, 7.0)

        key = jax.random.key(4)
        mgr = _mgr(tmp_path)
        est = estimate_counts(fn, 12, key, batch=4, checkpoint=mgr, checkpoint_every=4)
        assert len(calls) == 3
        latest = mgr.load_latest()
        assert latest is not None and latest[0] == 3
        state = EstimatorState.from_arrays(latest[1]["estimator"])
        res = estimate_counts(fn, 12, key, batch=4, resume=state)
        assert len(calls) == 3  # no new sampling
        assert res.resumed_from == 12 and res.niter == 12
        np.testing.assert_array_equal(res.samples, est.samples)
        assert res.estimate == est.estimate

    def test_resume_signature_mismatch_is_fatal(self, tmp_path):
        """Splicing two different runs would silently bias the estimate —
        the signature check makes it a hard error, for every knob that
        changes the sample stream."""
        g = erdos_renyi(40, 4.0, seed=5)
        d = tmp_path / "sig"
        c = Counter.from_graph(g, path_tree(3), backend="single")
        c.estimate(n_iter=12, key=jax.random.key(0), batch=4, checkpoint=str(d), checkpoint_every=4)
        fresh = Counter.from_graph(g, path_tree(3), backend="single")
        for kw in (dict(n_iter=16, key=jax.random.key(0), batch=4),
                   dict(n_iter=12, key=jax.random.key(9), batch=4),
                   dict(n_iter=12, key=jax.random.key(0), batch=6),
                   dict(n_iter=12, key=jax.random.key(0), batch=4,
                        delta=0.05)):
            with pytest.raises(ResumeMismatchError):
                fresh.estimate(resume=str(d), **kw)
        # different template: also fatal (signature_extra carries it)
        other = Counter.from_graph(g, path_tree(4), backend="single")
        with pytest.raises(ResumeMismatchError):
            other.estimate(n_iter=12, key=jax.random.key(0), batch=4, resume=str(d))

    def test_resume_without_checkpoint_dir_raises(self):
        g = erdos_renyi(30, 4.0, seed=1)
        c = Counter.from_graph(g, path_tree(3), backend="single")
        with pytest.raises(ValueError, match="resume requires"):
            c.estimate(n_iter=4, key=jax.random.key(0), resume=True)

    def test_early_stop_counts_restored_samples(self, tmp_path):
        """The ``target_rsd`` early stop (and progress) start from the
        restored bank, not from zero: a resumed run whose banked samples
        already satisfy the target makes ZERO new backend calls."""
        calls = []

        def fn(key, b):
            calls.append(1)
            return np.full(b, 7.0)  # constant stream: rse == 0 at n >= 2

        key = jax.random.key(5)
        mgr = _mgr(tmp_path)
        with faults.active(faults.inject("estimator.kill", at=(0,))):
            with pytest.raises(faults.InjectedCrash):
                estimate_counts(fn, 12, key, batch=4, checkpoint=mgr, checkpoint_every=4)
        assert len(calls) == 1
        state = EstimatorState.from_arrays(mgr.load_latest()[1]["estimator"])
        assert state.done == 4
        res = estimate_counts(fn, 12, key, batch=4, resume=state, target_rsd=0.5)
        assert len(calls) == 1  # banked samples alone met the target
        assert res.niter == 4 and res.resumed_from == 4
        assert res.mean == 7.0


# --------------------------------------------------------------------------
# supervisor: retry / validate / quarantine
# --------------------------------------------------------------------------


class TestSupervisor:
    def _fn(self, value=3.0):
        def fn(key, b):
            return np.full(b, value)

        return fn

    def test_transient_fault_retried_same_key(self):
        """A raise on the first attempt retries with the SAME key, so the
        eventual success is bit-identical to a clean first try."""
        seen = []

        def fn(key, b):
            seen.append(key_fingerprint(key))
            return np.full(b, 3.0)

        sup = Supervisor(fn, RetryPolicy(max_retries=2), sleep=_noop_sleep)
        key = jax.random.key(0)
        with faults.active(faults.inject("sample.raise", at=(0,))):
            out = sup(key, 4)
        assert isinstance(out, np.ndarray)
        np.testing.assert_array_equal(out, np.full(4, 3.0))
        assert sup.quarantined == []
        assert len(seen) == 1  # the faulted attempt raised before fn ran

    def test_persistent_fault_quarantines_with_bounded_attempts(self):
        sleeps = []
        sup = Supervisor(
            self._fn(),
            RetryPolicy(max_retries=2, backoff_s=0.01),
            sleep=sleeps.append,
        )
        with faults.active(faults.inject("sample.raise", at=None)):
            out = sup(jax.random.key(0), 4, call_index=7)
        assert isinstance(out, QuarantinedBatch)
        assert out.attempts == 3  # 1 try + 2 retries, then give up
        assert out.call_index == 7
        assert "InjectedFault" in out.reason
        assert sup.quarantined == [out]
        # exponential backoff between attempts
        assert sleeps == [0.01, 0.02]

    @pytest.mark.parametrize("site,needle", [
        ("sample.nan", "non-finite"),
        ("sample.negative", "negative copy estimate"),
    ])
    def test_corrupt_payload_is_hard_fault(self, site, needle):
        """NaN/negative payloads are data corruption, not noise: exactly
        one attempt, no retry, immediate quarantine."""
        sleeps = []
        sup = Supervisor(self._fn(), RetryPolicy(max_retries=5), sleep=sleeps.append)
        with faults.active(faults.inject(site, at=None)):
            out = sup(jax.random.key(0), 4)
        assert isinstance(out, QuarantinedBatch)
        assert out.attempts == 1
        assert needle in out.reason
        assert sleeps == []  # never backed off: hard faults don't retry

    def test_shape_violation_is_hard_fault(self):
        sup = Supervisor(lambda key, b: np.zeros(b + 1),
                         RetryPolicy(max_retries=3), sleep=_noop_sleep)
        out = sup(jax.random.key(0), 4)
        assert isinstance(out, QuarantinedBatch) and out.attempts == 1
        assert "batch=4" in out.reason

    @pytest.mark.timeout(60)
    def test_timeout_then_retry(self):
        """A hung attempt surfaces as a timeout and the retry (same key)
        succeeds — on a virtual clock, so the 0.5s "hang" and the backoff
        cost zero wall time."""
        clk = FakeClock()
        sup = Supervisor(
            self._fn(9.0),
            RetryPolicy(max_retries=1, timeout_s=0.1, backoff_s=0.0),
            sleep=clk.sleep,
            clock=clk,
        )
        with faults.active(faults.inject("sample.timeout", at=(0,), payload=0.5)) as plan:
            out = sup(jax.random.key(0), 4)
        np.testing.assert_array_equal(out, np.full(4, 9.0))
        assert sup.quarantined == []
        assert plan.fired == [("sample.timeout", 0)]  # the hang really happened

    @pytest.mark.timeout(60)
    def test_timeout_real_thread(self):
        """With the default (real) clock the attempt runs on a worker
        thread and a genuine hang is detected in real time."""
        sup = Supervisor(
            self._fn(9.0),
            RetryPolicy(max_retries=1, timeout_s=0.05, backoff_s=0.0),
        )
        with faults.active(faults.inject("sample.timeout", at=(0,), payload=0.3)):
            out = sup(jax.random.key(0), 4)
        np.testing.assert_array_equal(out, np.full(4, 9.0))
        assert sup.quarantined == []

    def test_quarantine_excluded_from_estimate(self):
        """End to end through estimate_counts: the poisoned batch is
        excluded from the aggregates and surfaced on the result, and the
        healthy batches are exactly the unfaulted run's."""
        g = erdos_renyi(40, 4.0, seed=5)
        key = jax.random.key(0)
        c = Counter.from_graph(g, path_tree(3), backend="single")
        base = c.estimate(n_iter=12, key=key, batch=4)
        sup = Supervisor(c.sample_fn, RetryPolicy(max_retries=2), sleep=_noop_sleep)
        # the second batch fails on every attempt (occurrences count
        # attempts: batch 0 is occurrence 0, batch 1's three tries are 1-3)
        with faults.active(faults.inject("sample.raise", at=(1, 2, 3))):
            est = estimate_counts(sup, 12, key, batch=4)
        assert len(est.quarantined) == 1
        q = est.quarantined[0]
        assert q.call_index == 1 and q.attempts == 3
        assert est.niter == 8
        np.testing.assert_array_equal(
            est.samples, np.concatenate([base.samples[:4], base.samples[8:]])
        )
        assert np.isfinite(est.estimate)

    def test_all_quarantined_aborts(self):
        sup = Supervisor(self._fn(), RetryPolicy(max_retries=0), sleep=_noop_sleep)
        with faults.active(faults.inject("sample.raise", at=None)):
            with pytest.raises(EstimationAborted, match="quarantined"):
                estimate_counts(sup, 8, jax.random.key(0), batch=4)

    def test_validate_directly(self):
        with pytest.raises(SampleValidationError):
            Supervisor._validate(np.array([1.0, np.inf]), 2)
        with pytest.raises(SampleValidationError):
            Supervisor._validate(np.array([1.0, -2.0]), 2)
        Supervisor._validate(np.array([0.0, 2.0]), 2)  # clean: no raise


# --------------------------------------------------------------------------
# checkpoint manager hardening
# --------------------------------------------------------------------------


class TestCheckpointManager:
    def _save(self, mgr, step, value):
        mgr.save(step, {"estimator": {"x": np.full(3, float(value))}})

    def test_load_latest_skips_corrupt_step(self, tmp_path, capsys):
        mgr = _mgr(tmp_path)
        self._save(mgr, 1, 1.0)
        self._save(mgr, 2, 2.0)
        # flip bits in the newest step's payload: sha256 must catch it
        bad = tmp_path / "ckpt" / "step_00000002" / "estimator.npz"
        bad.write_bytes(b"garbage" + bad.read_bytes()[7:])
        step, data = mgr.load_latest()
        assert step == 1
        np.testing.assert_array_equal(data["estimator"]["x"], np.full(3, 1.0))
        assert "skipping unreadable step 2" in capsys.readouterr().out

    def test_load_latest_skips_missing_manifest(self, tmp_path):
        mgr = _mgr(tmp_path)
        self._save(mgr, 1, 1.0)
        self._save(mgr, 2, 2.0)
        os.remove(tmp_path / "ckpt" / "step_00000002" / "manifest.json")
        assert mgr.load_latest()[0] == 1

    def test_empty_dir_loads_none(self, tmp_path):
        assert _mgr(tmp_path).load_latest() is None

    def test_stale_tmp_gc_on_save_and_load(self, tmp_path):
        mgr = _mgr(tmp_path)
        residue = tmp_path / "ckpt" / "step_00000009.tmp"
        residue.mkdir()
        (residue / "junk.npz").write_bytes(b"\x00")
        self._save(mgr, 1, 1.0)  # save GCs residue before writing
        assert not residue.exists()
        residue.mkdir()
        assert mgr.load_latest()[0] == 1  # load GCs it too
        assert not residue.exists()

    def test_write_crash_leaves_previous_latest_intact(self, tmp_path):
        mgr = _mgr(tmp_path)
        self._save(mgr, 1, 1.0)
        with faults.active(faults.inject("checkpoint.write_crash")):
            with pytest.raises(faults.InjectedCrash):
                self._save(mgr, 2, 2.0)
        assert (tmp_path / "ckpt" / "step_00000002.tmp").exists()
        step, data = mgr.load_latest()
        assert step == 1
        np.testing.assert_array_equal(data["estimator"]["x"], np.full(3, 1.0))

    def test_keep_pruning_spares_restored_step(self, tmp_path):
        """The checkpoint a live run restored from is never pruned, even
        when ``keep`` new checkpoints land on top of it."""
        mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2, async_save=False)
        self._save(mgr, 1, 1.0)
        assert mgr.load_latest()[0] == 1  # a resume pins step 1
        for s in range(2, 6):
            self._save(mgr, s, float(s))
        assert mgr.all_steps() == [1, 4, 5]  # 2..3 pruned, 1 protected

    def test_estimator_state_roundtrip(self):
        q = (
            QuarantinedBatch(3, (7, 11), "InjectedFault: boom", 4),
            QuarantinedBatch(5, (13, 17), "non-finite (NaN/Inf)", 1),
        )
        state = EstimatorState(
            signature="g|V=10|E=20|p3|single|n_iter=12|batch=4|delta=0.1|key=1,2",
            n_iter=12,
            batch=4,
            delta=0.1,
            cursor=6,
            samples=np.arange(20, dtype=np.float64).reshape(10, 2),
            quarantined=q,
        )
        back = EstimatorState.from_arrays(state.to_arrays())
        assert back.signature == state.signature
        assert (back.n_iter, back.batch, back.delta, back.cursor) == (12, 4, 0.1, 6)
        np.testing.assert_array_equal(back.samples, state.samples)
        assert back.quarantined == q

    def test_group_sums_match_final_grouping(self):
        """The associative per-group sums at a prefix agree with slicing
        the final sample array the way median_of_means groups it."""
        state = EstimatorState(
            signature="s",
            n_iter=12,
            batch=4,
            delta=0.1,
            cursor=2,
            samples=np.arange(8, dtype=np.float64),
        )
        g = num_groups_for(0.1, 12)
        sums, counts = state.group_sums()
        per = max(1, 12 // g)
        for i in range(g):
            part = state.samples[i * per: min((i + 1) * per, 8)]
            assert sums[i] == part.sum()
            assert counts[i] == part.shape[0]
        assert counts.sum() == 8


# --------------------------------------------------------------------------
# fault-injection harness itself
# --------------------------------------------------------------------------


class TestFaultHarness:
    def test_occurrence_indexing(self):
        with faults.active(faults.inject("x", at=(1, 3))) as plan:
            hits = [faults.fire("x") is not None for _ in range(5)]
        assert hits == [False, True, False, True, False]
        assert plan.fired == [("x", 1), ("x", 3)]

    def test_at_none_fires_always(self):
        with faults.active(faults.inject("x", at=None)):
            assert all(faults.fire("x") is not None for _ in range(4))

    def test_inactive_site_is_silent(self):
        assert faults.fire("nonexistent.site") is None
        with faults.active(faults.inject("x")):
            assert faults.fire("y") is None

    def test_no_nesting(self):
        with faults.active(faults.inject("x")):
            with pytest.raises(RuntimeError, match="already active"):
                with faults.active(faults.inject("y")):
                    pass
        assert not faults.is_active()

    def test_payload_carried(self):
        with faults.active(faults.inject("x", payload=0.25)):
            assert faults.fire("x").payload == 0.25


# --------------------------------------------------------------------------
# hardened graph ingestion
# --------------------------------------------------------------------------


class TestGraphIngestion:
    def test_truncated_line_names_lineno(self, tmp_path):
        p = tmp_path / "e.txt"
        p.write_text("0 1\n1 2\n3\n")
        with pytest.raises(GraphFormatError, match=r"e\.txt:3.*truncated"):
            load_edge_file(str(p))
        g = load_edge_file(str(p), validate=False)  # escape hatch: skip it
        assert g.num_edges == 2

    def test_non_integer_token_names_lineno(self, tmp_path):
        p = tmp_path / "e.txt"
        p.write_text("0 1\nfoo 2\n")
        with pytest.raises(GraphFormatError, match=r"e\.txt:2.*non-integer"):
            load_edge_file(str(p))
        assert load_edge_file(str(p), validate=False).num_edges == 1

    def test_out_of_range_id(self, tmp_path):
        p = tmp_path / "e.txt"
        p.write_text("0 1\n1 99\n")
        with pytest.raises(GraphFormatError, match="out of range for n=10"):
            load_edge_file(str(p), n=10)

    def test_one_indexed_zero_id(self, tmp_path):
        p = tmp_path / "e.txt"
        p.write_text("1 2\n0 3\n")
        with pytest.raises(GraphFormatError, match="below 1"):
            load_edge_file(str(p), zero_indexed=False)

    def test_empty_file(self, tmp_path):
        p = tmp_path / "e.txt"
        p.write_text("# only comments\n\n")
        with pytest.raises(GraphFormatError, match="no edges"):
            load_edge_file(str(p))
        assert load_edge_file(str(p), validate=False).num_edges == 0

    def test_npz_missing_key(self, tmp_path):
        p = tmp_path / "g.npz"
        np.savez(p, n=np.int64(3), indptr=np.zeros(4, np.int64))
        with pytest.raises(GraphFormatError, match="missing npz key 'indices'"):
            load_npz(str(p))

    def test_npz_not_an_archive(self, tmp_path):
        p = tmp_path / "g.npz"
        p.write_bytes(b"this is not a zip file")
        with pytest.raises(GraphFormatError, match="not a readable npz"):
            load_npz(str(p))

    def test_npz_inconsistent_csr(self, tmp_path):
        p = tmp_path / "g.npz"
        indptr = np.array([0, 1, 2, 5], np.int64)  # claims 5, has 2
        np.savez(p, n=np.int64(3), indptr=indptr, indices=np.array([1, 0], np.int32))
        with pytest.raises(GraphFormatError, match="truncated arrays"):
            load_npz(str(p))
        g = load_npz(str(p), validate=False)  # trusted load still works
        assert g.n == 3

    def test_npz_out_of_range_indices(self, tmp_path):
        p = tmp_path / "g.npz"
        np.savez(p, n=np.int64(2), indptr=np.array([0, 1, 2], np.int64),
                 indices=np.array([1, 7], np.int32))
        with pytest.raises(GraphFormatError, match="out of range"):
            load_npz(str(p))

    def test_roundtrip_still_clean(self, tmp_path):
        g = erdos_renyi(30, 4.0, seed=1, name="rt")
        p = tmp_path / "g.npz"
        save_npz(g, str(p))
        g2 = load_npz(str(p))
        assert g2.n == g.n and g2.name == "rt"
        np.testing.assert_array_equal(g2.indptr, g.indptr)
        np.testing.assert_array_equal(g2.indices, g.indices)
