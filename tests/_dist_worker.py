"""Worker script for multi-device tests (run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8).

Prints one line per check: ``CHECK <name> PASS|FAIL <details>``.
Exit code 0 iff all checks pass.
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# version-compat shims: jax.sharding.AxisType / jax.shard_map are not present
# on every supported JAX release (see repro.compat).
from repro.compat import make_mesh, shard_map  # noqa: E402

FAILURES = []


def check(name, ok, details=""):
    print(f"CHECK {name} {'PASS' if ok else 'FAIL'} {details}")
    if not ok:
        FAILURES.append(name)


def test_ring_collectives():
    from repro.comm import (
        compressed_ring_reduce_scatter,
        ring_allgather,
        ring_allgather_overlap,
        ring_reduce_scatter,
    )

    mesh = make_mesh((8,), ("x",))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 4, 16)).astype(np.float32)

    # ring all-gather == lax.all_gather
    f = jax.jit(
        shard_map(
            lambda a: ring_allgather(a[0], "x"),
            mesh=mesh,
            in_specs=P("x"),
            out_specs=P("x"),
        )
    )
    got = np.asarray(f(x))  # [8(dev), 8, 4... wait shapes
    want = np.broadcast_to(x[None], (8,) + x.shape).reshape(8 * 8, 4, 16)
    check("ring_allgather", np.allclose(got.reshape(8 * 8, 4, 16), want))

    # overlap consume: acc += chunk * (src+1) must equal sum_q (q+1)*x_q
    def run(a):
        def combine(acc, chunk, src):
            return acc + chunk * (src + 1).astype(jnp.float32)

        return ring_allgather_overlap(a[0], "x", combine, jnp.zeros_like(a[0]))[None]

    f = jax.jit(shard_map(run, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(x))
    want_each = sum((q + 1) * x[q] for q in range(8))
    check(
        "ring_allgather_overlap",
        np.allclose(got, np.broadcast_to(want_each, (8, 4, 16)), atol=1e-5),
    )

    # ring reduce-scatter == psum then slice
    xs = rng.standard_normal((8, 8, 4, 16)).astype(np.float32)  # [dev, chunk, ...]

    def rs(a):
        return ring_reduce_scatter(a[0], "x")[None]

    f = jax.jit(shard_map(rs, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(xs))
    want = xs.sum(axis=0)  # [chunk, 4, 16]; device p gets chunk p
    check(
        "ring_reduce_scatter",
        np.allclose(got, want, atol=1e-4),
        f"max err {np.abs(got - want).max():.2e}",
    )

    def crs(a):
        return compressed_ring_reduce_scatter(a[0], "x")[None]

    f = jax.jit(shard_map(crs, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    got = np.asarray(f(xs))
    rel = np.abs(got - want).max() / np.abs(want).max()
    check("compressed_ring_reduce_scatter", rel < 0.05, f"rel err {rel:.3f}")


def test_grouped_exchange():
    from repro.comm import fused_exchange, grouped_exchange

    mesh = make_mesh((8,), ("x",))
    rng = np.random.default_rng(1)
    # chunks[p, q] = payload device p holds for device q
    chunks = rng.standard_normal((8, 8, 4)).astype(np.float32)

    def run(mode, g=1):
        def consume(acc, chunk, src):
            w = (jnp.asarray(src) + 1).astype(jnp.float32)
            return acc + chunk * w

        def body(a):
            init = jnp.zeros((4,), jnp.float32)
            if mode == "fused":
                return fused_exchange(a[0], "x", consume, init)[None]
            return grouped_exchange(a[0], "x", consume, init, group_factor=g)[None]

        f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        return np.asarray(f(chunks))

    want = np.stack([sum((q + 1) * chunks[q, p] for q in range(8)) for p in range(8)])
    got_f = run("fused")
    check("fused_exchange", np.allclose(got_f, want, atol=1e-5))
    for g in (1, 2, 3, 7):
        got_g = run("grouped", g)
        check(f"grouped_exchange_g{g}", np.allclose(got_g, want, atol=1e-5))


def test_distributed_counting():
    from repro.core import erdos_renyi
    from repro.core.brute_force import count_colorful_maps
    from repro.core.distributed import (
        build_distributed_plan,
        make_count_fn,
        shard_coloring,
    )
    from repro.core.templates import path_tree, spider_tree

    g = erdos_renyi(97, 5.0, seed=7)  # ragged shard sizes on purpose
    rng = np.random.default_rng(3)

    for tree, tname in ((path_tree(4), "p4"), (spider_tree([2, 1]), "sp21")):
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)

        for shards, iters in ((4, 2), (8, 1)):
            mesh_names = ("data", "model") if iters > 1 else ("data",)
            mesh_shape = (shards, iters) if iters > 1 else (shards,)
            mesh = make_mesh(mesh_shape, mesh_names)
            plan = build_distributed_plan(g, tree, shards)
            cols = shard_coloring(plan, coloring)[None]  # [1, P, n_loc_pad]
            if iters > 1:
                cols = np.broadcast_to(cols, (iters,) + cols.shape[1:])
            for mode, gf in (
                ("alltoall", 1),
                ("pipeline", 1),
                ("pipeline", 3),
                ("adaptive", 1),
                ("ring", 1),
            ):
                f = make_count_fn(
                    plan,
                    mesh,
                    mode=mode,
                    iter_axis="model" if iters > 1 else None,
                    group_factor=gf,
                )
                got = np.asarray(f(jnp.asarray(cols)))
                ok = np.allclose(got, want, rtol=1e-6)
                check(
                    f"dist_{tname}_P{shards}I{iters}_{mode}_g{gf}",
                    ok,
                    f"got {got[0]} want {want}",
                )


def test_tiled_skew_parity():
    """RMAT skew-8 graph, 8 shards: distributed vs brute force across all
    four exchange modes on the §3.3 tiled bucket layout, with the fused
    (never-materialize-M) and Pallas kernel routings; plus a structural
    jaxpr scan asserting no [P, P, max_e]-shaped bucket array survives in
    the traced count program."""
    from repro.core import rmat
    from repro.core.brute_force import count_colorful_maps
    from repro.core.distributed import (
        build_distributed_plan,
        make_count_fn,
        shard_coloring,
    )
    from repro.core.templates import path_tree
    from repro.kernels import ops

    g = rmat(1024, 12_000, skew=8, seed=2)  # contiguous shards: heavy skew
    tree = path_tree(4)
    rng = np.random.default_rng(9)
    coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
    want = count_colorful_maps(g, tree, coloring)
    mesh = make_mesh((8,), ("data",))
    plan = build_distributed_plan(g, tree, 8)
    max_e_pad = max(
        ops.pad_to(int(plan.bucket_counts.max()), plan.bucket_tile),
        plan.bucket_tile,
    )
    check("tiled_plan_no_global_max",
          all(a.shape[2] < max_e_pad for a in plan.device_arrays
              if a.ndim == 3 and a.shape[:2] == (8, 8)),
          f"max_e_pad={max_e_pad}")
    cols = jnp.asarray(shard_coloring(plan, coloring)[None])

    for mode in ("alltoall", "pipeline", "adaptive", "ring"):
        for fuse in (False, True):
            f = make_count_fn(plan, mesh, mode=mode, fuse=fuse)
            got = np.asarray(f(cols))
            ok = np.allclose(got, want, rtol=1e-6)
            check(f"skew8_{mode}_fuse{int(fuse)}", ok, f"got {got[0]} want {want}")
    # Pallas routing: the edge-tile / fused kernels over the exchange
    # buffer (alltoall) and the Pallas combine on the incremental modes
    for mode, fuse in (("alltoall", False), ("alltoall", True),
                       ("pipeline", True), ("ring", False)):
        f = make_count_fn(plan, mesh, mode=mode, fuse=fuse, impl="pallas")
        got = np.asarray(f(cols))
        ok = np.allclose(got, want, rtol=1e-6)
        check(f"skew8_{mode}_fuse{int(fuse)}_pallas", ok, f"got {got[0]} want {want}")

    # structural: no traced value in the count program has the seed's
    # [P, P, max_e] global-max bucket shape (or anything at least as wide)
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from test_kernels import _iter_eqns

    for mode in ("pipeline", "alltoall", "ring"):
        f = make_count_fn(plan, mesh, mode=mode)
        jaxpr = jax.make_jaxpr(f)(cols)
        bad = [
            tuple(v.aval.shape)
            for e in _iter_eqns(jaxpr.jaxpr)
            for v in list(e.outvars) + [a for a in e.invars if hasattr(a, "aval")]
            if len(getattr(v.aval, "shape", ())) == 3
            and v.aval.shape[:2] == (8, 8)
            and v.aval.shape[2] >= max_e_pad
        ]
        check(f"jaxpr_no_global_max_{mode}", not bad, f"found {bad[:3]}")


def test_unified_api():
    """Counter facade over 8 real shards: fixed-coloring parity with the
    single-device backend, and the keyed on-device sampling path agreeing
    with the brute-force oracle through the shared estimator."""
    from repro.api import Counter
    from repro.core import erdos_renyi
    from repro.core.brute_force import count_colorful_maps, count_copies
    from repro.core.distributed import make_count_fn
    from repro.core.templates import path_tree, spider_tree

    g = erdos_renyi(97, 5.0, seed=7)  # ragged shard sizes on purpose
    rng = np.random.default_rng(11)

    # parity: single vs 8-shard distributed on a fixed coloring
    for tree, tname in ((path_tree(4), "p4"), (spider_tree([2, 1]), "sp21")):
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        single = Counter.from_graph(g, tree, backend="single")
        dist = Counter.from_graph(g, tree, backend="distributed", num_shards=8, mode="adaptive")
        got_s = single.count_coloring(coloring)
        got_d = dist.count_coloring(coloring)
        ok = np.allclose([got_s, got_d], want, rtol=1e-6)
        check(f"api_parity_{tname}_P8", ok, f"single {got_s} dist {got_d} want {want}")

    # keyed estimate: on-device coloring sampling, estimator vs oracle
    tree = path_tree(3)
    truth = count_copies(g, tree)
    dist = Counter.from_graph(g, tree, backend="distributed", num_shards=8, mode="pipeline")
    res = dist.estimate(n_iter=192, key=jax.random.key(0), batch=32)
    rel = abs(res.mean - truth) / truth
    check("api_keyed_estimate_P8", rel < 0.25,
          f"mean {res.mean:.1f} truth {truth:.1f} rel {rel:.2f}")

    # keyed fn over a 4x2 mesh: iteration axis shards the keys
    from repro.core.distributed import build_distributed_plan

    mesh = make_mesh((4, 2), ("data", "model"))
    plan4 = build_distributed_plan(g, tree, 4)
    fk = make_count_fn(plan4, mesh, mode="ring", iter_axis="model", keyed=True)
    counts = np.asarray(fk(jax.random.split(jax.random.key(5), 6)))
    ests = counts * plan4.scale
    rel = abs(ests.mean() - truth) / truth
    check("api_keyed_iter_axis", counts.shape == (6,) and rel < 0.6,
          f"ests mean {ests.mean():.1f} truth {truth:.1f}")

    # facade over an explicit 4x2 mesh: num_shards derived from the data
    # axis, count_coloring replicated over the iter axis, estimate rounding
    # an odd batch up to the iter-axis multiple
    fc = Counter.from_graph(
        g, tree, backend="distributed", mesh=mesh, iter_axis="model",
        mode="pipeline",
    )
    coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
    want = count_colorful_maps(g, tree, coloring)
    got = fc.count_coloring(coloring)
    check("api_mesh_count_coloring", np.allclose(got, want), f"got {got} want {want}")
    res = fc.estimate(n_iter=5, key=jax.random.key(6), batch=5)  # 5 % 2 != 0
    rel = abs(res.mean - truth) / truth
    check("api_mesh_estimate_odd_batch",
          res.niter == 5 and len(res.samples) == 5 and rel < 1.0,
          f"mean {res.mean:.1f} truth {truth:.1f}")


def test_multi_template():
    """Family counting over 8 real shards: one shared-DAG pass per coloring.

    Fixed-coloring parity against the brute-force oracle per template for
    all four exchange modes x fuse, plus keyed estimate_many parity: with
    the same key, per-template keyed runs (n_colors = k) must reproduce the
    family run's sample columns exactly.
    """
    from repro.api import Counter
    from repro.core import erdos_renyi
    from repro.core.brute_force import count_colorful_maps
    from repro.core.templates import path_tree, spider_tree, star_tree

    g = erdos_renyi(97, 5.0, seed=7)  # ragged shard sizes on purpose
    family = [path_tree(3), star_tree(4), spider_tree([2, 1])]
    k = max(t.n for t in family)
    rng = np.random.default_rng(13)
    coloring = rng.integers(0, k, g.n).astype(np.int32)
    want = [count_colorful_maps(g, t, coloring) for t in family]

    for mode in ("alltoall", "pipeline", "adaptive", "ring"):
        for fuse in (False, True):
            c = Counter.from_graph(
                g,
                family[-1],
                backend="distributed",
                num_shards=8,
                mode=mode,
                fuse=fuse,
            )
            got = c.count_coloring_many(family, coloring)
            ok = np.allclose(got, want, rtol=1e-6)
            check(f"multi_{mode}_fuse{int(fuse)}_P8", ok, f"got {got} want {want}")

    # keyed estimate_many == per-template keyed estimates, sample for sample
    cd = Counter.from_graph(
        g, family[-1], backend="distributed", num_shards=8, mode="pipeline"
    )
    res = cd.estimate_many(family, n_iter=12, key=jax.random.key(3), batch=6)
    ok_shape = res.samples.shape == (12, 3)
    parity = True
    for i, t in enumerate(family):
        ci = Counter.from_graph(
            g,
            t,
            backend="distributed",
            num_shards=8,
            mode="pipeline",
            n_colors=res.k,
        )
        ri = ci.estimate(n_iter=12, key=jax.random.key(3), batch=6)
        parity = parity and np.allclose(ri.samples, res.samples[:, i], rtol=1e-6)
    check("multi_keyed_estimate_parity_P8", ok_shape and parity, f"shape {res.samples.shape}")


def test_compaction():
    """Active-frontier compaction over 8 real shards (DESIGN.md §15).

    The compacted exchange (per-peer [rc, B+1] slabs on alltoall/pipeline,
    compacted whole-shard relays on ring) and the compact combine must be
    bit-identical to the dense program on every mode x fuse, the keyed
    estimator must produce identical samples from the same key, and an
    absurdly small capacity_factor must fall back to the dense program
    without changing a single count.
    """
    from repro.core import frontier

    # drop the profitability floors (restored in the finally below): this
    # checks exactness of all three capacity kinds (exchange, ring relay,
    # combine) on a template small enough to afford at 8 shards, not
    # whether compaction wins
    saved_floors = (frontier.MIN_COMBINE_ELEMENTS, frontier.MIN_TABLE_WIDTH)
    frontier.MIN_COMBINE_ELEMENTS = 1
    frontier.MIN_TABLE_WIDTH = 1
    try:
        _run_compaction_checks()
    finally:
        frontier.MIN_COMBINE_ELEMENTS, frontier.MIN_TABLE_WIDTH = saved_floors


def _run_compaction_checks():
    from repro.core import relabel_random, rmat
    from repro.core.distributed import (
        build_distributed_plan,
        keyed_sample_fn,
        make_count_fn,
        shard_coloring,
    )
    from repro.core.templates import template

    # sparse skewed R-MAT under the paper's random partition: u7-2's deep
    # tables measure 0.10-0.43 active, so every capacity kind engages
    g = relabel_random(rmat(4096, 6000, skew=8, seed=0), seed=1)
    tree = template("u7-2")  # root's cut child is internal: exchange caps
    rng = np.random.default_rng(21)
    coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
    mesh = make_mesh((8,), ("data",))
    dense_plan = build_distributed_plan(g, tree, 8)
    plan = build_distributed_plan(
        g,
        tree,
        8,
        compact=True,
        density_threshold=0.5,
        capacity_factor=1.25,
    )
    spec = plan.compaction
    check(
        "compact_caps_engaged",
        bool(spec.exchange_caps) and bool(spec.shard_caps)
        and bool(spec.combine_caps),
        f"exchange={spec.exchange_caps} ring={spec.shard_caps} "
        f"combine={spec.combine_caps}",
    )
    check(
        "compact_caps_shrink",
        all(c < plan.r_pad for c in spec.exchange_caps.values())
        and all(c < plan.n_loc_pad for c in spec.shard_caps.values()),
        f"r_pad={plan.r_pad} n_loc_pad={plan.n_loc_pad}",
    )
    cols = jnp.asarray(shard_coloring(plan, coloring)[None])

    # compact == dense bit-for-bit (dense-vs-oracle parity is covered by
    # the other worker tests; u7-2 is beyond the exponential oracle)
    cases = [
        ("alltoall", False, "xla"), ("alltoall", True, "pallas"),
        ("pipeline", False, "pallas"), ("pipeline", True, "xla"),
        ("adaptive", False, "xla"), ("ring", False, "xla"),
        ("ring", True, "xla"),
    ]
    for mode, fuse, impl in cases:
        fd = make_count_fn(dense_plan, mesh, mode=mode, fuse=fuse, impl=impl)
        fc = make_count_fn(plan, mesh, mode=mode, fuse=fuse, impl=impl)
        d = np.asarray(fd(cols))
        c = np.asarray(fc(cols))
        ok = np.array_equal(d, c)
        check(
            f"compact_{mode}_fuse{int(fuse)}_{impl}_P8",
            ok,
            f"dense {d[0]} compact {c[0]}",
        )

    # keyed estimator: same key => identical samples, compact vs dense
    sd = keyed_sample_fn(dense_plan, mesh, mode="pipeline")
    sc = keyed_sample_fn(plan, mesh, mode="pipeline")
    a = sd(jax.random.key(4), 6)
    b = sc(jax.random.key(4), 6)
    check("compact_keyed_samples_P8", np.array_equal(a, b), f"{a[:2]} {b[:2]}")

    # overflow: tiny capacities must trip the flag and re-dispatch dense
    tiny = build_distributed_plan(
        g, tree, 8, compact=True, density_threshold=1.0, capacity_factor=1e-6
    )
    ft = make_count_fn(tiny, mesh, mode="pipeline")
    fd = make_count_fn(dense_plan, mesh, mode="pipeline")
    check(
        "compact_overflow_fallback_P8",
        np.array_equal(np.asarray(ft(cols)), np.asarray(fd(cols))),
        "",
    )


def test_compressed_exchange():
    """Narrow-wire exchange over 8 real shards (DESIGN.md §18).

    int16/int8 slabs (dense and compacted+bitmapped) must be bit-identical
    to the float32 wire on every mode, a forced saturation storm must
    escalate through the wider-wire ladder without changing a count, and
    the measured-adaptive router must calibrate and still count exactly.
    """
    from repro.core import frontier

    saved_floors = (frontier.MIN_COMBINE_ELEMENTS, frontier.MIN_TABLE_WIDTH)
    frontier.MIN_COMBINE_ELEMENTS = 1
    frontier.MIN_TABLE_WIDTH = 1
    try:
        _run_compressed_checks()
    finally:
        frontier.MIN_COMBINE_ELEMENTS, frontier.MIN_TABLE_WIDTH = saved_floors


def _run_compressed_checks():
    from repro.core import relabel_random, rmat
    from repro.core.distributed import (
        build_distributed_plan,
        make_count_fn,
        plan_route_report,
        shard_coloring,
    )
    from repro.core.templates import template
    from repro.testing import faults

    g = relabel_random(rmat(2048, 4000, skew=8, seed=2), seed=3)
    tree = template("u7-2")
    rng = np.random.default_rng(33)
    coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
    mesh = make_mesh((8,), ("data",))
    plan_d = build_distributed_plan(g, tree, 8)
    plan_c = build_distributed_plan(
        g, tree, 8, compact=True, density_threshold=0.5, capacity_factor=1.25
    )
    cols = jnp.asarray(shard_coloring(plan_d, coloring)[None])

    # wide baseline per (mode, fuse); narrow wires must match bit for bit
    cases = [
        ("alltoall", False), ("alltoall", True),
        ("pipeline", False), ("pipeline", True),
        ("adaptive", False), ("ring", False), ("ring", True),
    ]
    for mode, fuse in cases:
        base = np.asarray(make_count_fn(plan_d, mesh, mode=mode, fuse=fuse)(cols))
        for wire in ("int16", "int8"):
            for plan, tag in ((plan_d, "dense"), (plan_c, "compact")):
                got = np.asarray(make_count_fn(
                    plan, mesh, mode=mode, fuse=fuse, wire_dtype=wire
                )(cols))
                check(
                    f"wire_{mode}_fuse{int(fuse)}_{wire}_{tag}_P8",
                    np.array_equal(base, got),
                    f"wide {base[0]} narrow {got[0]}",
                )

    # forced saturation storm: int8 escalates int16 -> (if needed) float32;
    # the ladder must converge on the wide answer and log the fired site
    base = np.asarray(make_count_fn(plan_d, mesh, mode="pipeline")(cols))
    fn8 = make_count_fn(plan_c, mesh, mode="pipeline", wire_dtype="int8")
    with faults.active(faults.inject("compression.saturate", at=(0, 1))) as fp:
        got = np.asarray(fn8(cols))
    check(
        "wire_saturation_storm_P8",
        np.array_equal(base, got) and [s for s, _ in fp.fired].count("compression.saturate") == 2,
        f"fired {fp.fired}",
    )

    # measured-adaptive routing: the calibrated router must pick real modes
    # and count exactly
    rep = plan_route_report(
        plan_c, mode="adaptive", wire_dtype="int16", adaptive="measured",
        mesh=mesh,
    )
    modes = {r["mode"] for r in rep["per_node"].values()}
    check(
        "wire_measured_router_P8",
        rep["calibrated"] and modes <= {"alltoall", "pipeline", "ring"},
        f"model {rep['model']} modes {modes}",
    )
    got = np.asarray(make_count_fn(
        plan_c, mesh, mode="adaptive", adaptive="measured", wire_dtype="int16"
    )(cols))
    check(
        "wire_measured_counts_P8",
        np.array_equal(base, got),
        f"wide {base[0]} measured {got[0]}",
    )


def test_moe_manual_vs_dense():
    """moe_block_manual (EP token-sharded / TP / pipelined) == dense oracle."""
    import dataclasses

    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_arch
    from repro.models.layers import Initializer
    from repro.models.moe import moe_block, moe_block_manual, moe_init

    mesh = make_mesh((2, 4), ("data", "model"))
    base = get_arch("phi3.5-moe-42b-a6.6b").reduced()
    rng = np.random.default_rng(0)

    for moe_sharding, pipeline, gf, tname in (
        ("ep", False, 1, "ep_fused"),
        ("ep", True, 1, "ep_pipe_g1"),
        ("ep", True, 3, "ep_pipe_g3"),
        ("tp", False, 1, "tp"),
    ):
        cfg = dataclasses.replace(
            base,
            num_experts=4,
            experts_per_token=2,
            moe_sharding=moe_sharding,
            capacity_factor=64.0,
        )
        init = Initializer(jax.random.key(7))
        params = moe_init(init, cfg)
        x = jnp.asarray(rng.standard_normal((4, 8, cfg.d_model)).astype(np.float32) * 0.3)
        want, _ = jax.jit(
            lambda p_, x_: moe_block(p_, x_, cfg, dtype=jnp.float32)
        )(params, x)

        def body(p_, x_):
            out, aux = moe_block_manual(
                p_,
                x_,
                cfg,
                dp_axes=("data",),
                model_axis="model",
                fsdp_axis=None,
                pipeline=pipeline,
                group_factor=gf,
                dtype=jnp.float32,
            )
            return out

        pspecs = {
            "router": P(),
            "w_gate": P("model") if moe_sharding == "ep" else P(None, None, "model"),
            "w_up": P("model") if moe_sharding == "ep" else P(None, None, "model"),
            "w_down": P("model") if moe_sharding == "ep" else P(None, "model", None),
        }
        f = jax.jit(
            shard_map(
                body, mesh=mesh,
                in_specs=(pspecs, P("data", None, None)),
                out_specs=P("data", None, None),
                check_vma=False,
            )
        )
        got = np.asarray(f(params, x))
        ok = np.allclose(got, np.asarray(want), rtol=2e-4, atol=2e-4)
        check(f"moe_manual_{tname}", ok, f"max err {np.abs(got - np.asarray(want)).max():.2e}")


def test_elastic_restore():
    """Checkpoint saved from one mesh restores (re-sharded) onto another."""
    import tempfile

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.train import CheckpointManager

    rng = np.random.default_rng(5)
    tree = {"w": jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32)),
            "b": jnp.asarray(rng.standard_normal((8,)).astype(np.float32))}
    mesh_a = make_mesh((4,), ("data",))
    sha = {"w": NamedSharding(mesh_a, P("data", None)), "b": NamedSharding(mesh_a, P())}
    tree_a = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sha)

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(1, {"params": tree_a})
        mesh_b = make_mesh((8,), ("data",))
        shb = {"w": NamedSharding(mesh_b, P("data", None)), "b": NamedSharding(mesh_b, P())}
        out = mgr.restore(1, {"params": tree}, shardings={"params": shb})
        got = out["params"]
        ok = np.allclose(np.asarray(got["w"]), np.asarray(tree["w"])) and np.allclose(
            np.asarray(got["b"]), np.asarray(tree["b"])
        )
        resharded = got["w"].sharding.num_devices == 8
        check("elastic_restore", ok and resharded, f"devices={got['w'].sharding.num_devices}")


def test_robustness():
    """Kill-and-resume over 8 real shards (DESIGN.md §16).

    The resume invariant must hold when the sample stream crosses the full
    shard_map/exchange machinery: a run killed right after a mid-run
    checkpoint and resumed from the directory reproduces the uninterrupted
    run's samples and estimate bit for bit; a supervised run with a
    persistently failing batch quarantines it and keeps the healthy
    samples identical to the clean run's.
    """
    import tempfile

    from repro.api import Counter
    from repro.core import erdos_renyi
    from repro.core.estimator import estimate_counts
    from repro.core.supervisor import RetryPolicy, Supervisor
    from repro.core.templates import path_tree
    from repro.testing import faults

    g = erdos_renyi(97, 5.0, seed=7)  # ragged shard sizes on purpose
    tree = path_tree(3)
    key = jax.random.key(17)

    def counter():
        return Counter.from_graph(g, tree, backend="distributed", num_shards=8, mode="pipeline")

    base = counter().estimate(n_iter=12, key=key, batch=4)

    with tempfile.TemporaryDirectory() as d:
        with faults.active(faults.inject("estimator.kill", at=(0,))):
            try:
                counter().estimate(n_iter=12, key=key, batch=4, checkpoint=d, checkpoint_every=4)
                crashed = False
            except faults.InjectedCrash:
                crashed = True
        check("robust_kill_fired_P8", crashed)
        res = counter().estimate(n_iter=12, key=key, batch=4, resume=d)
        check(
            "robust_resume_bitexact_P8",
            res.resumed_from == 4
            and np.array_equal(res.samples, base.samples)
            and res.estimate == base.estimate
            and res.relative_sd == base.relative_sd,
            f"resumed_from={res.resumed_from} "
            f"est {res.estimate} want {base.estimate}",
        )

    # supervised 8-shard pipeline: batch 1 fails every attempt (occurrences
    # count attempts: batch 0 is 0, batch 1's three tries are 1-3)
    sup = Supervisor(counter().sample_fn, RetryPolicy(max_retries=2),
                     sleep=lambda _: None)
    with faults.active(faults.inject("sample.raise", at=(1, 2, 3))):
        est = estimate_counts(sup, 12, key, batch=4)
    healthy = np.concatenate([base.samples[:4], base.samples[8:]])
    check(
        "robust_quarantine_P8",
        len(est.quarantined) == 1
        and est.quarantined[0].call_index == 1
        and est.quarantined[0].attempts == 3
        and est.niter == 8
        and np.array_equal(est.samples, healthy),
        f"quarantined={[str(q) for q in est.quarantined]} niter={est.niter}",
    )


def test_elastic_coloring():
    """Shard-count independence of the keyed coloring stream.

    ``global_coloring`` makes the per-call coloring a function of
    ``(key, n, k)`` only: the same key must yield the same samples on a
    1-shard and an 8-shard plan (the ROADMAP elasticity contract), and both
    must equal the host-reconstructed coloring fed to the brute-force
    oracle.
    """
    from repro.core import erdos_renyi
    from repro.core.brute_force import count_colorful_maps
    from repro.core.distributed import (
        build_distributed_plan,
        global_coloring,
        keyed_sample_fn,
    )
    from repro.core.templates import path_tree

    g = erdos_renyi(97, 5.0, seed=7)  # ragged shard sizes on purpose
    tree = path_tree(3)
    key, batch = jax.random.key(23), 6

    samples = {}
    for shards in (1, 8):
        mesh = make_mesh((shards,), ("data",))
        plan = build_distributed_plan(g, tree, shards)
        samples[shards] = np.asarray(keyed_sample_fn(plan, mesh, mode="pipeline")(key, batch))
    check(
        "elastic_coloring_P1_vs_P8",
        np.allclose(samples[1], samples[8], rtol=1e-6),
        f"P1 {samples[1][:3]} P8 {samples[8][:3]}",
    )

    # host reconstruction: the same split + global_coloring draw, counted
    # by the exponential oracle
    plan = build_distributed_plan(g, tree, 8)
    want = np.array([
        count_colorful_maps(
            g, tree, np.asarray(global_coloring(kd, g.n, tree.n))
        ) * plan.scale
        for kd in jax.random.split(key, batch)
    ])
    check(
        "elastic_coloring_host_oracle",
        np.allclose(samples[8], want, rtol=1e-6),
        f"got {samples[8][:3]} want {want[:3]}",
    )


def test_service():
    """Counting service over 8 real shards: coalesced family passes must
    match solo runs (same key/batch/n_colors) sample for sample."""
    from repro.api import Counter
    from repro.core import erdos_renyi
    from repro.core.templates import path_tree
    from repro.serve import CountingService, ServiceConfig

    g = erdos_renyi(97, 5.0, seed=7)
    k, batch = 4, 4
    p4 = path_tree(4)
    svc = CountingService(
        g,
        n_colors=k,
        backend="distributed",
        plan_opts={"num_shards": 8, "mode": "pipeline"},
        config=ServiceConfig(batch=batch),
    )
    ta = svc.client("alice").submit("u3-1", n_iter=16)
    tb = svc.client("bob").submit(("u3-1", p4), n_iter=8)
    svc.run_until_idle()
    coalesced = svc.stats()["coalescing_factor"]

    key = jax.random.key(0)
    sa = Counter.from_graph(
        g, "u3-1", backend="distributed", num_shards=8, mode="pipeline",
        n_colors=k,
    ).estimate(16, key=key, batch=batch)
    sb = Counter.from_graph(
        g, "u3-1", backend="distributed", num_shards=8, mode="pipeline",
        n_colors=k,
    ).estimate_many(("u3-1", p4), 8, key=key, batch=batch)
    ra, rb = ta.result(), tb.result()
    check(
        "service_solo_scalar_P8",
        np.allclose(np.asarray(ra.samples), np.asarray(sa.samples), rtol=1e-6),
        f"svc {np.asarray(ra.samples)[:3]} solo {np.asarray(sa.samples)[:3]}",
    )
    check(
        "service_solo_family_P8",
        np.allclose(np.asarray(rb.samples), np.asarray(sb.samples), rtol=1e-6),
        f"svc {np.asarray(rb.samples)[0]} solo {np.asarray(sb.samples)[0]}",
    )
    check("service_coalesced_P8", coalesced > 1.0, f"factor {coalesced:.2f}")


def test_treewidth2():
    """Treewidth-2 bag programs over 8 real shards (DESIGN.md §19).

    Fixed-coloring oracle parity for cycle/diamond templates across the
    exchange modes (the bag_combine exchange rides the same wire; collapse
    psums the pinned-apex table), a mixed tree+cycle family through one
    shared DAG, the narrow int16 wire, fuse-bypass parity, and 1-vs-8
    shard parity on the single backend's exact counts.
    """
    from repro.api import Counter
    from repro.core import erdos_renyi
    from repro.core.brute_force import count_colorful_maps
    from repro.core.templates import template

    g = erdos_renyi(61, 6.0, seed=11)  # ragged last shard on purpose
    fam = ["cycle5", "diamond"]
    k = max(template(n).n for n in fam)
    rng = np.random.default_rng(29)
    coloring = rng.integers(0, k, g.n).astype(np.int32)
    want = [count_colorful_maps(g, template(n), coloring) for n in fam]

    for mode in ("alltoall", "pipeline", "ring", "adaptive"):
        c = Counter.from_graph(
            g,
            fam[0],
            backend="distributed",
            num_shards=8,
            mode=mode,
        )
        got = c.count_coloring_many(fam, coloring)
        check(f"tw2_{mode}_P8", np.allclose(got, want, rtol=1e-6), f"got {got} want {want}")

    # fuse is force-bypassed per bag node but must stay on for tree nodes
    mixed = ["u3-1", "cycle4", "cycle5"]
    km = max(template(n).n for n in mixed)
    colm = rng.integers(0, km, g.n).astype(np.int32)
    wantm = [count_colorful_maps(g, template(n), colm) for n in mixed]
    c = Counter.from_graph(
        g,
        mixed[-1],
        backend="distributed",
        num_shards=8,
        mode="pipeline",
        fuse=True,
    )
    gotm = c.count_coloring_many(mixed, colm)
    check("tw2_mixed_fuse_P8", np.allclose(gotm, wantm, rtol=1e-6), f"got {gotm} want {wantm}")

    # narrow wire: int16 slabs round-trip the bag exchange bit-exactly
    c16 = Counter.from_graph(
        g, fam[0], backend="distributed", num_shards=8, mode="alltoall",
        wire_dtype="int16",
    )
    got16 = c16.count_coloring_many(fam, coloring)
    check("tw2_int16_P8", np.allclose(got16, want, rtol=1e-6), f"got {got16} want {want}")

    # 1-vs-8 parity: the sharded bag strategy equals the in-core engine
    cs = Counter.from_graph(g, fam[0], backend="single")
    gots = cs.count_coloring_many(fam, coloring)
    check("tw2_single_vs_P8", np.allclose(gots, want, rtol=1e-6), f"got {gots} want {want}")


def main():
    # positional args select tests by substring (e.g. ``compressed_exchange``
    # runs only test_compressed_exchange — the CI distributed smoke step);
    # no args runs everything
    tests = [
        test_ring_collectives,
        test_grouped_exchange,
        test_distributed_counting,
        test_tiled_skew_parity,
        test_unified_api,
        test_multi_template,
        test_compaction,
        test_compressed_exchange,
        test_robustness,
        test_elastic_coloring,
        test_service,
        test_moe_manual_vs_dense,
        test_elastic_restore,
        test_treewidth2,
    ]
    wanted = sys.argv[1:]
    if wanted:
        tests = [t for t in tests if any(w in t.__name__ for w in wanted)]
        if not tests:
            print(f"no tests match {wanted}")
            sys.exit(2)
    for t in tests:
        t()
    if FAILURES:
        print(f"FAILED: {FAILURES}")
        sys.exit(1)
    print("ALL OK")


if __name__ == "__main__":
    main()
