"""Counting-service tests (DESIGN.md §17): solo-equivalence of coalesced
passes, mid-stream joins, plan-cache behavior, fair scheduling, admission
errors, quarantine surfacing, and state export.

Everything here runs on the single-device backend, where the shared-k
family contract is bit-exact — the solo comparisons use
``np.testing.assert_array_equal``, not allclose.  The 8-shard analogues
(rtol 1e-6 across psum orderings) live in ``_dist_worker.py``.
"""

import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import Counter  # noqa: E402
from repro.core import erdos_renyi  # noqa: E402
from repro.core.estimator import estimate_counts  # noqa: E402
from repro.serve import (  # noqa: E402
    CountingService,
    PlanCache,
    QueueFullError,
    ServiceConfig,
    UnsatisfiableRequestError,
)
from repro.testing import faults  # noqa: E402

K = 5  # service-wide color budget for every test service
BATCH = 4


@pytest.fixture(scope="module")
def graph():
    return erdos_renyi(60, 8.0, seed=1)


def service(graph, **cfg_kw):
    cfg = ServiceConfig(batch=BATCH, **cfg_kw)
    return CountingService(graph, n_colors=K, backend="single", config=cfg)


class FakeClock:
    """Virtual time shared by service deadlines and the pass supervisor:
    ``sleep`` advances the clock instead of waiting, so timeout/expiry
    paths run in zero wall time."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def sleep(self, s: float) -> None:
        self.t += s


def vservice(graph, clock, **cfg_kw):
    """A service on a virtual clock (deadlines + supervisor timeouts)."""
    cfg = ServiceConfig(batch=BATCH, **cfg_kw)
    return CountingService(graph, n_colors=K, backend="single", config=cfg,
                           clock=clock, sleep=clock.sleep)


def solo(graph, template, n_iter, **kw):
    c = Counter.from_graph(graph, template, backend="single", n_colors=K)
    return c.estimate(n_iter, key=jax.random.key(0), batch=BATCH, **kw)


def solo_many(graph, templates, n_iter, **kw):
    c = Counter.from_graph(graph, templates[0], backend="single", n_colors=K)
    return c.estimate_many(templates, n_iter, key=jax.random.key(0), batch=BATCH, **kw)


class TestSoloEquivalence:
    def test_three_tenant_coalesced_bit_identical(self, graph):
        """The acceptance workload: three tenants, overlapping templates,
        one shared key — every request's samples and estimate must equal
        the solo run's bit for bit."""
        svc = service(graph)
        ta = svc.client("alice").submit("u3-1", n_iter=24)
        tb = svc.client("bob").submit(("u3-1", "u5-2"), n_iter=16)
        tc = svc.client("carol").submit("u5-2", n_iter=20)
        svc.run_until_idle()

        ra, rb, rc = ta.result(), tb.result(), tc.result()
        sa = solo(graph, "u3-1", 24)
        sb = solo_many(graph, ("u3-1", "u5-2"), 16)
        sc = solo(graph, "u5-2", 20)
        np.testing.assert_array_equal(np.asarray(ra.samples), np.asarray(sa.samples))
        np.testing.assert_array_equal(np.asarray(rb.samples), np.asarray(sb.samples))
        np.testing.assert_array_equal(np.asarray(rc.samples), np.asarray(sc.samples))
        assert ra.estimate == sa.estimate
        assert np.array_equal(rb.estimates, sb.estimates)
        assert rc.estimate == sc.estimate
        # and the passes actually coalesced: fewer backend calls than the
        # three solo runs would have made
        stats = svc.stats()
        assert stats["coalescing_factor"] > 1.0
        assert stats["pass_calls"] < 24 // BATCH + 16 // BATCH + 20 // BATCH

    def test_early_stop_matches_solo(self, graph):
        """target_rsd stopping inside a coalesced pass truncates at the
        same call as the stand-alone estimator."""
        svc = service(graph)
        t1 = svc.client("a").submit("u3-1", n_iter=60, target_rsd=0.25)
        t2 = svc.client("b").submit("u5-2", n_iter=60)
        svc.run_until_idle()
        s1 = solo(graph, "u3-1", 60, target_rsd=0.25)
        r1 = t1.result()
        assert r1.niter == s1.niter  # stopped at the same call boundary
        np.testing.assert_array_equal(np.asarray(r1.samples), np.asarray(s1.samples))
        assert r1.estimate == s1.estimate
        # the co-tenant keeps running to its own budget, unperturbed
        r2 = t2.result()
        s2 = solo(graph, "u5-2", 60)
        np.testing.assert_array_equal(np.asarray(r2.samples), np.asarray(s2.samples))

    def test_distinct_keys_distinct_streams(self, graph):
        """Requests with different keys get different passes and still
        match their own solo runs."""
        svc = service(graph)
        t1 = svc.client("a").submit("u3-1", n_iter=12)
        t2 = svc.client("a").submit("u3-1", n_iter=12, key=jax.random.key(9))
        svc.run_until_idle()
        assert not np.array_equal(np.asarray(t1.result().samples), np.asarray(t2.result().samples))
        c = Counter.from_graph(graph, "u3-1", backend="single", n_colors=K)
        s2 = c.estimate(12, key=jax.random.key(9), batch=BATCH)
        np.testing.assert_array_equal(np.asarray(t2.result().samples), np.asarray(s2.samples))


class TestMidStreamJoin:
    def test_join_rides_history(self, graph):
        """A later request whose templates are already in the pass
        backfills from history without any backend call."""
        svc = service(graph)
        ta = svc.client("a").submit(("u3-1", "u5-2"), n_iter=40)
        for _ in range(4):
            svc.step()
        tb = svc.client("b").submit("u3-1", n_iter=16)
        svc.run_until_idle()
        stats = svc.stats()
        assert stats.get("history_rides", 0) > 0
        assert stats.get("backfill_calls", 0) == 0
        np.testing.assert_array_equal(
            np.asarray(tb.result().samples),
            np.asarray(solo(graph, "u3-1", 16).samples),
        )
        np.testing.assert_array_equal(
            np.asarray(ta.result().samples),
            np.asarray(solo_many(graph, ("u3-1", "u5-2"), 40).samples),
        )

    def test_join_backfills_missing_columns(self, graph):
        """A later request with a template the pass has not computed
        recomputes the consumed prefix at the same per-call keys."""
        svc = service(graph)
        ta = svc.client("a").submit("u3-1", n_iter=40)
        for _ in range(4):
            svc.step()
        tb = svc.client("b").submit("u5-2", n_iter=16)
        svc.run_until_idle()
        assert svc.stats().get("backfill_calls", 0) > 0
        np.testing.assert_array_equal(
            np.asarray(tb.result().samples),
            np.asarray(solo(graph, "u5-2", 16).samples),
        )

    def test_join_with_target_rsd_stops_consistently(self, graph):
        """The issue's bugfix: a request joining mid-stream applies the
        stop rule during backfill exactly as the solo loop would — it must
        not consume the whole banked prefix first."""
        svc = service(graph)
        svc.client("a").submit("u3-1", n_iter=80)
        for _ in range(12):
            svc.step()
        tb = svc.client("b").submit("u3-1", n_iter=80, target_rsd=0.25)
        svc.run_until_idle()
        sb = solo(graph, "u3-1", 80, target_rsd=0.25)
        rb = tb.result()
        assert rb.niter == sb.niter
        np.testing.assert_array_equal(np.asarray(rb.samples), np.asarray(sb.samples))
        assert rb.estimate == sb.estimate


class TestPlanCache:
    def test_repeat_requests_hit(self, graph):
        svc = service(graph)
        svc.client("a").submit(("u3-1", "u5-2"), n_iter=8)
        svc.run_until_idle()
        svc.client("b").submit(("u5-2", "u3-1"), n_iter=8)  # order-insensitive
        svc.run_until_idle()
        assert svc.plan_cache.hits > 0
        assert svc.plan_cache.misses == 1
        assert svc.plan_cache.hit_rate > 0

    def test_lru_eviction_purges_family_state(self, graph):
        svc = service(graph, plan_cache_capacity=1)
        svc.client("a").submit("u3-1", n_iter=8)
        svc.run_until_idle()
        svc.client("a").submit("u5-2", n_iter=8)
        svc.run_until_idle()
        assert svc.plan_cache.evictions >= 1
        assert len(svc.plan_cache) == 1
        # the Counter-side compiled state went with it
        assert len(svc._counter._families) <= 1

    def test_unit_cache_standalone(self):
        calls = []
        cache = PlanCache(2, on_evict=lambda e: calls.append(e["trees"]))
        cache.get(("a",), lambda: {"trees": "A"})
        cache.get(("b",), lambda: {"trees": "B"})
        cache.get(("a",), lambda: {"trees": "A2"})  # hit; refreshes LRU slot
        cache.get(("c",), lambda: {"trees": "C"})  # evicts b, not a
        assert cache.hits == 1 and cache.misses == 3
        assert calls == ["B"]
        assert ("a",) in cache and ("b",) not in cache


class TestResultMemo:
    def test_identical_resubmit_served_from_memo(self, graph):
        """An identical finished request returns the cached result at
        submit time — done immediately, zero extra backend calls — and the
        answer is the one a recomputation would produce."""
        svc = service(graph)
        t1 = svc.client("a").submit("u3-1", n_iter=24)
        svc.run_until_idle()
        calls_before = svc.stats().get("pass_calls", 0)
        t2 = svc.client("b").submit("u3-1", n_iter=24)  # any tenant hits
        assert t2.done  # no scheduling round needed
        assert svc.stats().get("pass_calls", 0) == calls_before
        r1, r2 = t1.result(), t2.result()
        np.testing.assert_array_equal(np.asarray(r1.samples), np.asarray(r2.samples))
        assert r2.estimate == r1.estimate
        s = svc.stats()["results"]
        assert s["hits"] == 1 and s["entries"] == 1
        assert 0 < s["hit_rate"] < 1
        # the memo ticket still exports a valid solo-resumable state
        st = t2.state()
        assert st.samples.shape[0] == st.cursor * BATCH

    def test_different_budget_or_key_misses(self, graph):
        """The memo key is the full stream identity: a different n_iter or
        coloring key recomputes."""
        svc = service(graph)
        svc.client("a").submit("u3-1", n_iter=8)
        svc.run_until_idle()
        t2 = svc.client("a").submit("u3-1", n_iter=12)
        assert not t2.done
        t3 = svc.client("a").submit("u3-1", n_iter=8, key=jax.random.key(7))
        assert not t3.done
        svc.run_until_idle()
        assert svc.stats()["results"]["hits"] == 0

    def test_capacity_zero_disables(self, graph):
        svc = service(graph, result_cache_capacity=0)
        svc.client("a").submit("u3-1", n_iter=8)
        svc.run_until_idle()
        t2 = svc.client("a").submit("u3-1", n_iter=8)
        assert not t2.done
        svc.run_until_idle()
        assert svc.stats()["results"]["entries"] == 0

    def test_lru_eviction_bounds_entries(self, graph):
        svc = service(graph, result_cache_capacity=1)
        svc.client("a").submit("u3-1", n_iter=8)
        svc.run_until_idle()
        svc.client("a").submit("u5-2", n_iter=8)  # evicts the u3-1 result
        svc.run_until_idle()
        t3 = svc.client("a").submit("u3-1", n_iter=8)
        assert not t3.done  # evicted: recomputes
        svc.run_until_idle()
        s = svc.stats()["results"]
        assert s["entries"] == 1 and s["evictions"] >= 1


class TestScheduling:
    def test_drr_weights_bias_service_rate(self, graph):
        """Distinct keys → distinct passes; the weight-3 tenant gets ~3x
        the backend calls over any window."""
        svc = service(graph)
        svc.set_weight("heavy", 3.0)
        svc.client("light").submit("u3-1", n_iter=96, key=jax.random.key(1))
        svc.client("heavy").submit("u3-1", n_iter=96, key=jax.random.key(2))
        for _ in range(17):  # partial window: both still running
            svc.step()
        ts = svc.stats()["tenants"]
        assert ts["heavy"]["charged"] >= 2 * ts["light"]["charged"]
        svc.run_until_idle()

    def test_coalesced_pass_charges_scheduler_once(self, graph):
        """Co-tenants of one pass ride free: request_calls grows per rider,
        charged grows only for the scheduling tenant."""
        svc = service(graph)
        svc.client("a").submit("u3-1", n_iter=24)
        svc.client("b").submit("u3-1", n_iter=24)
        svc.run_until_idle()
        stats = svc.stats()
        assert stats["request_calls"] == 2 * stats["pass_calls"]
        total_charged = sum(t["charged"] for t in stats["tenants"].values())
        assert total_charged == stats["pass_calls"]

    def test_bounded_queue_rejects(self, graph):
        svc = service(graph, max_pending=2)
        svc.client("a").submit("u3-1", n_iter=8)
        svc.client("a").submit("u3-1", n_iter=8)
        with pytest.raises(QueueFullError):
            svc.client("b").submit("u3-1", n_iter=8)
        svc.run_until_idle()
        svc.client("b").submit("u3-1", n_iter=8)  # drained: admits again
        svc.run_until_idle()


class TestAdmissionErrors:
    def test_unsatisfiable_eps_raises_at_submit(self, graph):
        svc = service(graph, max_iters=1000)
        with pytest.raises(UnsatisfiableRequestError) as ei:
            svc.client("a").submit("u5-2", eps=0.01, delta=0.1)
        msg = str(ei.value)
        assert "max_iters" in msg and "eps" in msg

    def test_unsatisfiable_n_iter_raises_at_submit(self, graph):
        svc = service(graph, max_iters=100)
        with pytest.raises(UnsatisfiableRequestError):
            svc.client("a").submit("u3-1", n_iter=101)

    def test_oversized_template_rejected(self, graph):
        svc = service(graph)  # K = 5
        with pytest.raises(ValueError, match="color budget"):
            svc.client("a").submit("u7-2", n_iter=8)

    def test_satisfiable_eps_admits(self, graph):
        svc = service(graph, max_iters=10_000)
        t = svc.client("a").submit("u3-1", eps=2.0, delta=0.5)
        svc.run_until_idle()
        assert t.status == "done"


class TestStreamingAndState:
    def test_progress_updates_stream(self, graph):
        svc = service(graph)
        t = svc.client("a").submit("u3-1", n_iter=24)
        svc.run_until_idle()
        assert len(t.updates) == 24 // BATCH
        niters = [u.niter for u in t.updates]
        assert niters == sorted(niters) and niters[-1] == 24
        assert t.latency_s is not None and t.latency_s >= 0

    def test_state_export_resumes_solo(self, graph):
        """A partially-served request drains into the stand-alone
        estimator and finishes bit-exact with the uninterrupted solo run."""
        svc = service(graph)
        t = svc.client("a").submit("u5-2", n_iter=32)
        for _ in range(4):
            svc.step()
        st = t.state()
        assert 0 < st.cursor < 32 // BATCH
        c = Counter.from_graph(graph, "u5-2", backend="single", n_colors=K)
        full = c.estimate(32, key=jax.random.key(0), batch=BATCH)
        res = estimate_counts(c.sample_fn, 32, jax.random.key(0), batch=BATCH,
                              resume=st, signature_extra=c._signature_extra())
        assert res.resumed_from == st.cursor * BATCH  # iterations, not calls
        np.testing.assert_array_equal(res.samples, np.asarray(full.samples))
        assert res.estimate == full.estimate

    def test_result_before_done_raises(self, graph):
        svc = service(graph)
        t = svc.client("a").submit("u3-1", n_iter=8)
        with pytest.raises(RuntimeError, match="queued"):
            t.result()


class TestQuarantine:
    def test_persistent_fault_quarantined_per_request(self, graph):
        """A batch that fails every retry is quarantined; the request
        completes on the healthy samples and surfaces the record."""
        svc = service(graph, max_retries=1)
        svc._sleep = lambda _: None
        t = svc.client("a").submit("u3-1", n_iter=12)
        # occurrences count attempts: call 0 is attempts 0-1 (1 + 1 retry)
        with faults.active(faults.inject("sample.raise", at=(0, 1))):
            svc.run_until_idle()
        r = t.result()
        assert t.status == "done"
        assert len(r.quarantined) == 1
        assert r.quarantined[0].call_index == 0
        assert r.niter == 8  # 12 budgeted minus the quarantined batch
        # healthy samples are the solo run's calls 1..2 (same keys)
        s = solo(graph, "u3-1", 12)
        np.testing.assert_array_equal(np.asarray(r.samples), np.asarray(s.samples)[BATCH:])

    def test_all_quarantined_fails_clearly(self, graph):
        svc = service(graph, max_retries=0)
        svc._sleep = lambda _: None
        t = svc.client("a").submit("u3-1", n_iter=4)
        with faults.active(faults.inject("sample.raise", at=None)):
            svc.run_until_idle()
        assert t.status == "failed"
        assert "quarantined" in t.error
        with pytest.raises(RuntimeError, match="failed"):
            t.result()


class TestFacade:
    def test_counter_serve_roundtrip(self, graph):
        c = Counter.from_graph(graph, "u5-2", backend="single", n_colors=K)
        svc = c.serve(config=ServiceConfig(batch=BATCH))
        assert svc.k == K  # inherited the Counter's n_colors
        t = svc.client("a").submit("u3-1", n_iter=8)
        svc.run_until(t)
        np.testing.assert_array_equal(np.asarray(t.result().samples),
                                      np.asarray(solo(graph, "u3-1", 8).samples))

    def test_client_count_convenience(self, graph):
        svc = service(graph)
        r = svc.client("a").count("u3-1", n_iter=8)
        assert r.niter == 8

    def test_api_reexports(self):
        import repro.api as api

        assert api.CountingService is CountingService
        assert api.ServiceConfig is ServiceConfig

    def test_counter_serve_config_kwargs_and_start(self, graph):
        c = Counter.from_graph(graph, "u3-1", backend="single", n_colors=K)
        svc = c.serve(batch=BATCH, max_pending=4, shed_oldest=True, start=True)
        try:
            assert svc.running
            assert svc.config.max_pending == 4 and svc.config.shed_oldest
            with pytest.raises(ValueError, match="not both"):
                c.serve(config=ServiceConfig(), batch=2)
        finally:
            svc.stop()


# --------------------------------------------------------------------------
# §20 hardening: errors, driver thread, deadlines/cancellation, backpressure
# --------------------------------------------------------------------------


class TestErrorReprs:
    def test_queue_full_fields_and_repr(self, graph):
        svc = service(graph, max_pending=1)
        svc.client("a").submit("u3-1", n_iter=8)
        with pytest.raises(QueueFullError) as ei:
            svc.client("b").submit("u5-2", n_iter=8)
        e = ei.value
        assert e.tenant == "b" and e.scope == "service"
        assert e.depth == 1 and e.limit == 1 and e.retry_after_s > 0
        assert "'b'" in str(e) and "limit 1" in str(e)
        r = repr(e)
        assert r.startswith("QueueFullError(") and "tenant='b'" in r and "limit=1" in r

    def test_per_tenant_bound_scopes_error(self, graph):
        svc = service(graph, max_pending=8, max_pending_per_tenant=1)
        svc.client("a").submit("u3-1", n_iter=8)
        with pytest.raises(QueueFullError) as ei:
            svc.client("a").submit("u5-2", n_iter=8)
        assert ei.value.scope == "tenant" and ei.value.tenant == "a"
        # another tenant's budget is untouched
        t = svc.client("b").submit("u5-2", n_iter=8)
        assert t.status == "queued"

    def test_unsatisfiable_fields_and_repr(self, graph):
        svc = service(graph, max_iters=100)
        with pytest.raises(UnsatisfiableRequestError) as ei:
            svc.client("a").submit("u3-1", n_iter=101)
        e = ei.value
        assert (e.tenant, e.parameter, e.value, e.limit) == ("a", "n_iter", 101, 100)
        assert "'a'" in str(e) and "n_iter=101" in str(e) and "max_iters=100" in str(e)
        assert "parameter='n_iter'" in repr(e)
        with pytest.raises(UnsatisfiableRequestError) as ei2:
            svc.client("bob").submit("u5-2", eps=1e-9)
        e2 = ei2.value
        assert e2.tenant == "bob" and e2.parameter == "eps" and e2.value == 1e-9
        assert "parameter='eps'" in repr(e2)


class TestDriverThread:
    def test_driver_drains_and_matches_solo(self, graph):
        svc = service(graph).start()
        try:
            assert svc.running and svc.stats()["driver"]["running"]
            t = svc.client("a").submit("u3-1", n_iter=8)
            assert t.wait(60)
            assert svc.join_idle(60)
        finally:
            svc.stop()
        assert not svc.running
        assert t.status == "done"
        np.testing.assert_array_equal(np.asarray(t.result().samples),
                                      np.asarray(solo(graph, "u3-1", 8).samples))

    def test_concurrent_submits_all_solo_exact(self, graph):
        svc = service(graph).start()
        try:
            tickets = [svc.client(f"t{i}").submit("u3-1", n_iter=16) for i in range(4)]
            assert all(t.wait(60) for t in tickets)
        finally:
            svc.stop()
        s = solo(graph, "u3-1", 16)
        for t in tickets:
            np.testing.assert_array_equal(np.asarray(t.result().samples), np.asarray(s.samples))

    def test_run_until_idle_delegates_to_driver(self, graph):
        svc = service(graph).start()
        try:
            t = svc.client("a").submit("u3-1", n_iter=8)
            svc.run_until_idle()  # must wait for the driver, not co-step
            assert t.status == "done"
            svc.run_until(t)  # no-op on a done ticket
        finally:
            svc.stop()

    def test_step_crash_recorded_and_survived(self, graph):
        """The ``service.step_crash`` site: the driver records the fault
        and keeps scheduling — the request still completes."""
        svc = service(graph).start()
        try:
            with faults.active(faults.inject("service.step_crash", at=(0,))) as plan:
                t = svc.client("a").submit("u3-1", n_iter=8)
                assert t.wait(60)
                assert plan.fired  # the crash really happened
        finally:
            svc.stop()
        assert t.status == "done"
        assert svc.stats()["driver"]["errors"] >= 1
        assert any("InjectedFault" in e for e in svc.driver_errors)


class TestDeadlinesCancellation:
    def test_cancel_detaches_without_touching_corider(self, graph):
        svc = service(graph)
        ta = svc.client("a").submit("u3-1", n_iter=24)
        tb = svc.client("b").submit("u3-1", n_iter=24)
        for _ in range(3):
            svc.step()
        assert ta.cancel() is True
        assert ta.status == "cancelled" and ta.done
        assert ta.cancel() is False  # already terminal
        svc.run_until_idle()
        # the co-rider is untouched and solo-exact
        np.testing.assert_array_equal(np.asarray(tb.result().samples),
                                      np.asarray(solo(graph, "u3-1", 24).samples))
        with pytest.raises(RuntimeError, match="cancelled"):
            ta.result()
        assert svc.stats()["cancelled"] == 1

    def test_cancelled_state_resumes_solo(self, graph, tmp_path):
        """The partial EstimatorState of a cancelled ticket finishes under
        the stand-alone estimator bit-exactly — including through the
        on-disk checkpoint path (``ticket.checkpoint`` -> ``resume=DIR``)."""
        svc = service(graph)
        t = svc.client("a").submit("u5-2", n_iter=32)
        for _ in range(3):
            svc.step()
        t.cancel()
        st = t.state()
        assert st.status == "cancelled"
        assert 0 < st.cursor < 32 // BATCH
        c = Counter.from_graph(graph, "u5-2", backend="single", n_colors=K)
        full = c.estimate(32, key=jax.random.key(0), batch=BATCH)
        res = estimate_counts(c.sample_fn, 32, jax.random.key(0), batch=BATCH,
                              resume=st, signature_extra=c._signature_extra())
        np.testing.assert_array_equal(res.samples, np.asarray(full.samples))
        assert res.estimate == full.estimate
        # and via the persisted checkpoint directory (the --resume path)
        st2 = t.checkpoint(str(tmp_path / "ck"))
        assert st2.cursor == st.cursor
        res2 = c.estimate(32, key=jax.random.key(0), batch=BATCH, resume=str(tmp_path / "ck"))
        assert res2.resumed_from == st.cursor * BATCH
        np.testing.assert_array_equal(np.asarray(res2.samples), np.asarray(full.samples))

    def test_deadline_expires_mid_stream(self, graph):
        clk = FakeClock()
        svc = vservice(graph, clk)
        t = svc.client("a").submit("u3-1", n_iter=40, timeout_s=10.0)
        for _ in range(3):
            svc.step()
        assert t.status == "active"
        clk.t += 11.0
        svc.run_until_idle()
        assert t.status == "deadline_exceeded"
        assert "deadline" in t.error
        st = t.state()
        assert st.status == "deadline_exceeded"
        assert 0 < st.cursor < 40 // BATCH
        assert svc.stats()["deadline_exceeded"] == 1
        with pytest.raises(RuntimeError, match="deadline_exceeded"):
            t.result()

    def test_dead_on_arrival_deadline(self, graph):
        clk = FakeClock()
        clk.t = 100.0
        svc = vservice(graph, clk)
        t = svc.client("a").submit("u3-1", n_iter=8, deadline_s=50.0)
        assert t.status == "deadline_exceeded"
        assert "at submit" in t.error
        assert svc._pending() == 0  # never entered the queue


class TestMemoInterplay:
    """Result-memoization x quarantine x cancellation (ISSUE satellites)."""

    def test_memo_hit_honors_expired_deadline(self, graph):
        clk = FakeClock()
        svc = vservice(graph, clk)
        t1 = svc.client("a").submit("u3-1", n_iter=8)
        svc.run_until_idle()
        assert t1.status == "done"
        t2 = svc.client("a").submit("u3-1", n_iter=8)
        assert t2.status == "done"  # memo hit, served at submit
        assert svc.stats()["results"]["hits"] == 1
        clk.t = 100.0
        t3 = svc.client("a").submit("u3-1", n_iter=8, deadline_s=50.0)
        assert t3.status == "deadline_exceeded"  # expiry beats the memo
        assert svc.stats()["results"]["hits"] == 1  # memo never consulted

    def test_cancelled_never_seeds_memo(self, graph):
        svc = service(graph)
        t = svc.client("a").submit("u3-1", n_iter=24)
        svc.step()
        svc.step()
        t.cancel()
        svc.run_until_idle()
        assert svc.stats()["results"]["entries"] == 0
        # an identical resubmission recomputes from scratch...
        t2 = svc.client("a").submit("u3-1", n_iter=24)
        assert t2.status == "queued"
        svc.run_until_idle()
        assert t2.status == "done"
        # ...and only the completed run seeds the memo
        assert svc.stats()["results"]["entries"] == 1

    def test_quarantined_never_seeds_memo(self, graph):
        svc = service(graph, max_retries=0)
        svc._sleep = lambda _: None
        t = svc.client("a").submit("u3-1", n_iter=8)
        with faults.active(faults.inject("sample.raise", at=(0,))):
            svc.run_until_idle()
        assert t.status == "done" and len(t.result().quarantined) == 1
        assert svc.stats()["results"]["entries"] == 0


class TestBackpressure:
    def test_shed_oldest_policy(self, graph):
        svc = service(graph, max_pending=2, shed_oldest=True)
        t1 = svc.client("a").submit("u3-1", n_iter=8)
        t2 = svc.client("a").submit("u5-2", n_iter=8)
        t3 = svc.client("b").submit("u3-1", n_iter=8)  # sheds t1, admits t3
        assert t1.status == "shed" and "shed" in t1.error
        with pytest.raises(RuntimeError, match="shed"):
            t1.result()
        svc.run_until_idle()
        assert t2.status == "done" and t3.status == "done"
        assert svc.stats()["shed"] == 1

    def test_backpressure_signals_in_stats(self, graph):
        svc = service(graph, max_pending=8, max_pending_per_tenant=2)
        svc.client("a").submit("u3-1", n_iter=8)
        svc.client("a").submit("u5-2", n_iter=8)
        ts = svc.stats()["tenants"]["a"]
        assert ts["depth"] == 2 and ts["limit"] == 2
        assert ts["saturation"] == pytest.approx(1.0)
        assert ts["retry_after_s"] > 0


# --------------------------------------------------------------------------
# chaos soak (CI runs these via `pytest -k chaos`)
# --------------------------------------------------------------------------


def _drop_quarantined(solo_samples, quarantined, batch):
    """Solo samples with a request's quarantined call rows excluded — what
    a surviving degraded result must equal bit for bit."""
    arr = np.asarray(solo_samples)
    drop = {q.call_index for q in quarantined}
    keep = [arr[i * batch:(i + 1) * batch] for i in range(arr.shape[0] // batch) if i not in drop]
    return np.concatenate(keep, axis=0) if keep else arr[:0]


class TestServiceChaos:
    @pytest.mark.timeout(120)
    def test_chaos_soak_deterministic(self, graph):
        """The acceptance soak: >= 50 injected events across five fault
        sites (raise / supervisor timeout / slow pass / poisoned pass /
        step crash) plus mid-soak cancellations, on the synchronous core
        with a virtual clock — fully deterministic, zero wall-clock
        sleeping.  Every request must reach a terminal state, and every
        completing request's samples must equal the solo run's with its
        own quarantined call rows excluded."""
        clk = FakeClock()
        svc = vservice(graph, clk, max_retries=1, timeout_s=0.1, max_active=6)
        tickets = []
        for i in range(8):
            tickets.append(svc.client(f"t{i % 3}").submit(
                "u3-1", n_iter=24, key=jax.random.key(10 + i)))
        for i in range(4):
            tickets.append(svc.client(f"t{i % 3}").submit(
                ("u3-1", "u5-2"), n_iter=16, key=jax.random.key(50 + i)))
        cancels = {15: tickets[2], 30: tickets[9]}
        crashes = 0
        with faults.active(
            faults.inject("sample.raise", at=tuple(range(0, 400, 3))),
            faults.inject("sample.timeout", at=tuple(range(3, 400, 7))),
            faults.inject("service.slow_pass", at=tuple(range(2, 400, 5))),
            faults.inject("service.pass_poison", at=tuple(range(1, 400, 4))),
            faults.inject("service.step_crash", at=tuple(range(4, 400, 6))),
        ) as plan:
            for step_no in range(4000):
                if step_no in cancels:
                    cancels[step_no].cancel()
                try:
                    busy = svc.step()
                except faults.InjectedFault:
                    crashes += 1
                    busy = True
                if not busy:
                    break
            fired = len(plan.fired)
        assert fired >= 50, f"only {fired} injected events"
        assert crashes >= 1
        # no request stuck in a non-terminal state
        assert all(t.done for t in tickets), [t.status for t in tickets]
        for key, t in cancels.items():
            assert t.status in ("cancelled", "done")
        # every survivor is solo-exact modulo its own quarantined calls
        c1 = Counter.from_graph(graph, "u3-1", backend="single", n_colors=K)
        for t in tickets:
            if t.status != "done":
                continue
            r = t.result()
            req = t._request
            if len(req.trees) == 1:
                s = c1.estimate(24, key=req.key, batch=BATCH)
            else:
                s = c1.estimate_many(("u3-1", "u5-2"), 16, key=req.key, batch=BATCH)
            np.testing.assert_array_equal(
                np.asarray(r.samples),
                _drop_quarantined(s.samples, r.quarantined, BATCH))

    @pytest.mark.timeout(120)
    def test_chaos_threaded_driver_survives(self, graph):
        """Driver-thread soak: step crashes, poisoned passes, a supervisor
        timeout, and a mid-flight cancel — the driver must survive, drain
        everything to a terminal state, and keep surviving results
        solo-exact."""
        clk = FakeClock()
        svc = vservice(graph, clk, max_retries=1, timeout_s=0.1)
        tickets = []
        with faults.active(
            faults.inject("service.step_crash", at=tuple(range(0, 60, 9))),
            faults.inject("service.pass_poison", at=(1, 5)),
            faults.inject("sample.timeout", at=(3,)),
        ) as plan:
            svc.start()
            try:
                for i in range(6):
                    tickets.append(svc.client(f"c{i % 2}").submit(
                        "u3-1", n_iter=16, key=jax.random.key(100 + i)))
                tickets[3].cancel()
                assert svc.join_idle(90), "driver failed to drain (deadlock?)"
            finally:
                svc.stop()
            assert ("service.step_crash", 0) in plan.fired
        assert all(t.done for t in tickets), [t.status for t in tickets]
        assert tickets[3].status in ("cancelled", "done")
        assert svc.stats()["driver"]["errors"] >= 1
        c = Counter.from_graph(graph, "u3-1", backend="single", n_colors=K)
        for t in tickets:
            if t.status != "done":
                continue
            r = t.result()
            s = c.estimate(16, key=t._request.key, batch=BATCH)
            np.testing.assert_array_equal(
                np.asarray(r.samples),
                _drop_quarantined(s.samples, r.quarantined, BATCH))
