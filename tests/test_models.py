"""Per-arch smoke tests (reduced configs) + layer-level oracles.

Every assigned architecture: one forward pass + one train-loss/grad step on
CPU with the reduced config, asserting shapes and finiteness; decode paths
checked against full-forward logits where the family supports it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model
from repro.models.transformer import forward

ALL_ARCHS = sorted(ARCHS)


def _batch_for(model, cfg, b=2, s=32, key=0):
    rng = np.random.default_rng(key)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    ctx_len, needed = model._context_len()
    if needed:
        batch["context"] = jnp.asarray(
            rng.standard_normal((b, ctx_len, cfg.d_model)).astype(np.float32) * 0.1
        )
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = jax.jit(model.init_fn)(jax.random.key(0))
    batch = _batch_for(model, cfg)

    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    gnorm = jax.tree.reduce(
        lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))), grads, 0.0
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_param_count_positive(arch):
    cfg = get_arch(arch)
    n = cfg.params_count()
    na = cfg.active_params_count()
    assert n > 0 and 0 < na <= n, (arch, n, na)


# published parameter-count sanity (order of magnitude against the name)
@pytest.mark.parametrize(
    "arch,lo,hi",
    [
        ("rwkv6-3b", 2.5e9, 4e9),
        ("internlm2-1.8b", 1.4e9, 2.4e9),
        ("smollm-360m", 0.25e9, 0.5e9),
        ("qwen1.5-0.5b", 0.35e9, 0.8e9),
        ("granite-3-8b", 6.5e9, 10e9),
        ("phi3.5-moe-42b-a6.6b", 35e9, 50e9),
        ("mixtral-8x22b", 120e9, 160e9),
        ("llama-3.2-vision-90b", 70e9, 110e9),
        ("whisper-base", 0.04e9, 0.12e9),
        ("recurrentgemma-2b", 2e9, 3.6e9),
    ],
)
def test_param_count_matches_name(arch, lo, hi):
    n = get_arch(arch).params_count()
    assert lo <= n <= hi, (arch, f"{n:.3g}")


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen1.5-0.5b", "mixtral-8x22b",
                                  "recurrentgemma-2b", "rwkv6-3b", "whisper-base"])
def test_decode_matches_forward(arch):
    """prefill(s tokens) + decode(1 token) logits == forward(s+1 tokens) last."""
    import dataclasses

    cfg = get_arch(arch).reduced()
    if cfg.num_experts:
        # ample capacity: the full forward must not drop tokens, or its
        # logits legitimately differ from the drop-free decode path
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    model = build_model(cfg)
    params = jax.jit(model.init_fn)(jax.random.key(1))
    b, s = 2, 16
    batch = _batch_for(model, cfg, b=b, s=s + 1, key=3)
    tokens = batch["tokens"]

    full_batch = dict(batch, tokens=tokens)
    prefill_batch = dict(batch, tokens=tokens[:, :s])
    logits_s, caches = jax.jit(model.prefill_fn)(params, prefill_batch)
    dec_batch = {
        "tokens": tokens[:, s : s + 1],
        "pos": jnp.asarray(s, jnp.int32),
        "caches": caches,
    }
    logits_dec, _ = jax.jit(model.decode_fn)(params, dec_batch)

    # reference: full forward over s+1 tokens
    def ref(p, bt):
        ctx2 = bt.get("context")
        if ctx2 is not None and cfg.family == "audio":
            from repro.models.transformer import encode

            ctx2 = encode(p, cfg, ctx2)
        logits, _, _ = forward(p, cfg, bt["tokens"], context=ctx2, mode="train")
        return logits

    full = jax.jit(ref)(params, full_batch)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(full[:, -1]), rtol=2e-2, atol=2e-2
    )


class TestRwkvOracle:
    def test_chunked_matches_scan(self):
        from repro.models.rwkv6 import wkv_chunked, wkv_scan_ref

        rng = np.random.default_rng(0)
        b, h, l, d = 2, 3, 96, 16
        r, k, v = (
            jnp.asarray(rng.standard_normal((b, h, l, d)).astype(np.float32))
            for _ in range(3)
        )
        logw = jnp.asarray(
            -np.exp(rng.standard_normal((b, h, l, d)).astype(np.float32) * 0.5 - 1.5)
        )
        u = jnp.asarray(rng.standard_normal((h, d)).astype(np.float32) * 0.3)
        s0 = jnp.asarray(rng.standard_normal((b, h, d, d)).astype(np.float32) * 0.1)
        o1, s1 = wkv_scan_ref(r, k, v, logw, u, s0)
        o2, s2 = wkv_chunked(r, k, v, logw, u, s0, chunk=32)
        np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s1, s2, rtol=1e-4, atol=1e-4)


class TestRglruOracle:
    def test_assoc_scan_matches_loop(self):
        from repro.models.rglru import _lru_scan

        rng = np.random.default_rng(1)
        b, l, d = 2, 40, 8
        a = jnp.asarray(rng.random((b, l, d)).astype(np.float32) * 0.9)
        bx = jnp.asarray(rng.standard_normal((b, l, d)).astype(np.float32))
        h0 = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
        got = _lru_scan(a, bx, h0)
        h = h0
        outs = []
        for t in range(l):
            h = a[:, t] * h + bx[:, t]
            outs.append(h)
        want = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestMoE:
    def test_mass_conservation_no_drop(self):
        """With huge capacity, MoE(x) equals dense mixture computed naively."""
        from repro.models.layers import Initializer
        from repro.models.moe import moe_block, moe_init

        cfg = get_arch("phi3.5-moe-42b-a6.6b").reduced()
        cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 64.0})
        init = Initializer(jax.random.key(0))
        p = moe_init(init, cfg)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)).astype(np.float32))
        out, aux = jax.jit(lambda p, x: moe_block(p, x, cfg, dtype=jnp.float32))(p, x)

        # naive: per token, weighted sum of top-k expert FFNs
        logits = x.reshape(-1, cfg.d_model) @ np.asarray(p["router"], np.float32)
        probs = jax.nn.softmax(logits, -1)
        top_w, top_e = jax.lax.top_k(probs, cfg.experts_per_token)
        top_w = top_w / top_w.sum(-1, keepdims=True)
        xt = np.asarray(x.reshape(-1, cfg.d_model))
        want = np.zeros_like(xt)
        wg, wu, wd = (np.asarray(p[k], np.float32) for k in ("w_gate", "w_up", "w_down"))
        for t in range(xt.shape[0]):
            for j in range(cfg.experts_per_token):
                e = int(top_e[t, j])
                h = jax.nn.silu(xt[t] @ wg[e]) * (xt[t] @ wu[e])
                want[t] += float(top_w[t, j]) * np.asarray(h @ wd[e])
        np.testing.assert_allclose(
            np.asarray(out).reshape(-1, cfg.d_model), want, rtol=2e-3, atol=2e-3
        )
        assert float(aux) > 0
