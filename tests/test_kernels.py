"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles.

Each kernel sweeps shapes (and dtypes where meaningful) and asserts
allclose against ref.py.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import erdos_renyi, rmat
from repro.core.graphs import edge_list
from repro.kernels import ops, ref
from repro.kernels.color_combine import color_combine_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.spmm_edgetile import spmm_block_pallas, spmm_gather_pallas


def _random_table(rng, n_pad, width, n_valid, dtype=np.float32):
    t = rng.random((n_pad, width)).astype(dtype)
    t[n_valid:] = 0.0
    return jnp.asarray(t)


class TestSpmmKernels:
    @pytest.mark.parametrize("n,deg,width", [(100, 5.0, 128), (300, 8.0, 256), (64, 3.0, 384)])
    def test_gather_kernel_matches_ref(self, n, deg, width):
        g = erdos_renyi(n, deg, seed=n)
        plan = ops.build_spmm_plan(*edge_list(g), g.n, kind="edges")
        rng = np.random.default_rng(0)
        table = _random_table(rng, plan.n_pad, width, g.n)
        got = spmm_gather_pallas(
            plan.rows, plan.cols, table, num_rows=plan.n_pad - 1, interpret=True
        )[: plan.n_pad]
        got = jnp.where(plan.written_mask[:, None], got, 0)
        want = ref.spmm_segment_ref(plan.rows, plan.cols, table, plan.n_pad - 1)[
            : plan.n_pad
        ]
        np.testing.assert_allclose(got[: g.n], want[: g.n], rtol=1e-6)

    @pytest.mark.parametrize("n,deg,width", [(200, 6.0, 128), (500, 10.0, 256)])
    def test_block_kernel_matches_ref(self, n, deg, width):
        g = rmat(n, int(n * deg / 2), skew=3, seed=n)
        rows, cols = edge_list(g)
        plan = ops.build_spmm_plan(rows, cols, g.n, kind="blocks")
        rng = np.random.default_rng(1)
        table = _random_table(rng, plan.n_pad, width, g.n)
        got = spmm_block_pallas(
            plan.block_rows,
            plan.block_cols,
            plan.patches,
            table,
            num_row_blocks=plan.n_pad // plan.block_size,
            interpret=True,
        )[: plan.n_pad]
        got = jnp.where(plan.written_mask[:, None], got, 0)
        eplan = ops.build_spmm_plan(rows, cols, g.n, kind="edges")
        want = ref.spmm_segment_ref(eplan.rows, eplan.cols, table, plan.n_pad - 1)[
            : plan.n_pad
        ]
        np.testing.assert_allclose(got[: g.n], want[: g.n], rtol=1e-5)

    def test_xla_block_path_matches_edges_path(self):
        g = erdos_renyi(150, 7.0, seed=5)
        rows, cols = edge_list(g)
        bplan = ops.build_spmm_plan(rows, cols, g.n, kind="blocks")
        eplan = ops.build_spmm_plan(rows, cols, g.n, kind="edges")
        rng = np.random.default_rng(2)
        table = _random_table(rng, bplan.n_pad, 128, g.n)
        a = ops.spmm(bplan, table, impl="xla")
        b = ops.spmm(eplan, table, impl="xla")
        np.testing.assert_allclose(a[: g.n], b[: g.n], rtol=1e-6)


class TestColorCombine:
    @pytest.mark.parametrize("k,t1,t2", [(5, 2, 2), (7, 3, 2), (10, 3, 3), (12, 4, 3)])
    def test_matches_ref(self, k, t1, t2):
        tables = ops.build_combine_tables(k, t1, t2)
        n_pad = 256
        a_pad = ops.pad_to(math.comb(k, t1), 128)
        b_pad = ops.pad_to(math.comb(k, t2), 128)
        rng = np.random.default_rng(k)
        left = jnp.asarray(rng.random((n_pad, a_pad)).astype(np.float32))
        m = jnp.asarray(rng.random((n_pad, b_pad)).astype(np.float32))
        got = color_combine_pallas(
            left, m, tables.idx1_t, tables.idx2_t, num_splits=tables.j, interpret=True
        )
        want = ref.color_combine_ref(left, m, tables.idx1, tables.idx2)
        np.testing.assert_allclose(got[:, : tables.s], want, rtol=1e-5)

    def test_xla_chunked_matches_einsum(self):
        # force the chunked path by a tiny chunk threshold
        tables = ops.build_combine_tables(9, 4, 3)
        n_pad = 128
        rng = np.random.default_rng(3)
        left = jnp.asarray(rng.random((n_pad, ops.pad_to(math.comb(9, 4), 128))).astype(np.float32))
        m = jnp.asarray(rng.random((n_pad, ops.pad_to(math.comb(9, 3), 128))).astype(np.float32))
        want = ref.color_combine_ref(left, m, tables.idx1, tables.idx2)

        def chunked(jc=5):
            s, j = tables.idx1.shape
            acc = jnp.zeros((n_pad, s), jnp.float32)
            for j0 in range(0, j, jc):
                i1 = tables.idx1[:, j0 : j0 + jc]
                i2 = tables.idx2[:, j0 : j0 + jc]
                acc = acc + jnp.einsum("vsj,vsj->vs", left[:, i1], m[:, i2])
            return acc

        np.testing.assert_allclose(chunked(), want, rtol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,hq,hkv,l,d", [(1, 4, 4, 256, 64), (2, 8, 2, 128, 64), (1, 6, 2, 384, 128)]
    )
    def test_causal_matches_ref(self, b, hq, hkv, l, d):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((b, hq, l, d)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((b, hkv, l, d)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((b, hkv, l, d)).astype(np.float32))
        got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [64, 128, 200])
    def test_sliding_window(self, window):
        rng = np.random.default_rng(1)
        b, h, l, d = 1, 2, 256, 64
        q = jnp.asarray(rng.standard_normal((b, h, l, d)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((b, h, l, d)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((b, h, l, d)).astype(np.float32))
        got = flash_attention_pallas(q, k, v, causal=True, window=window, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        rng = np.random.default_rng(2)
        b, h, l, d = 1, 2, 128, 64
        q = jnp.asarray(rng.standard_normal((b, h, l, d)), dtype=jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((b, h, l, d)), dtype=jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((b, h, l, d)), dtype=jnp.bfloat16)
        got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            got.astype(np.float32), want.astype(np.float32), rtol=5e-2, atol=5e-2
        )
