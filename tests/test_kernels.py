"""Pallas kernel validation: interpret-mode kernels vs pure-jnp oracles.

Each kernel sweeps shapes (and dtypes where meaningful) and asserts
allclose against ref.py.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import erdos_renyi, rmat
from repro.core.graphs import edge_list
from repro.kernels import ops, ref
from repro.kernels.color_combine import color_combine_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_count import fused_count_pallas
from repro.kernels.spmm_edgetile import spmm_block_pallas, spmm_edge_tile_pallas


def _random_table(rng, n_pad, width, n_valid, dtype=np.float32):
    t = rng.random((n_pad, width)).astype(dtype)
    t[n_valid:] = 0.0
    return jnp.asarray(t)


class TestSpmmKernels:
    @pytest.mark.parametrize(
        "n,deg,width,tile",
        [(100, 5.0, 128, 128), (300, 8.0, 256, 64), (64, 3.0, 384, 32)],
    )
    def test_edge_tile_kernel_matches_ref(self, n, deg, width, tile):
        g = erdos_renyi(n, deg, seed=n)
        plan = ops.build_spmm_plan(*edge_list(g), g.n, kind="edges", tile_size=tile)
        rng = np.random.default_rng(0)
        table = _random_table(rng, plan.n_pad, width, g.n)
        got = spmm_edge_tile_pallas(
            plan.slab_dst,
            plan.slab_cols,
            table,
            slabs_per_block=plan.slabs_per_block,
            interpret=True,
        )
        want = ref.spmm_segment_ref(plan.rows, plan.cols, table, plan.n_pad - 1)[: plan.n_pad]
        np.testing.assert_allclose(got[: g.n], want[: g.n], rtol=1e-6)
        # zero-degree and pad rows come out exactly zero (pad slabs no-op)
        np.testing.assert_array_equal(np.asarray(got[g.n :]), 0.0)

    def test_slab_layout_skewed_graph(self):
        # a supernode row owns many slabs; every slab is still tile_size slots
        g = rmat(200, 3000, skew=8, seed=3)
        plan = ops.build_spmm_plan(*edge_list(g), g.n, kind="edges", tile_size=64)
        assert plan.slab_dst.shape == (
            (plan.n_pad // plan.row_tile) * plan.slabs_per_block,
            64,
        )
        rng = np.random.default_rng(4)
        table = _random_table(rng, plan.n_pad, 128, g.n)
        got = spmm_edge_tile_pallas(
            plan.slab_dst,
            plan.slab_cols,
            table,
            slabs_per_block=plan.slabs_per_block,
            interpret=True,
        )
        want = ref.spmm_segment_ref(plan.rows, plan.cols, table, plan.n_pad - 1)
        np.testing.assert_allclose(got[: g.n], want[: g.n], rtol=1e-5)

    def test_auto_plan_kind_adapts_to_density(self):
        # dense small graph: occupied patches are heavy -> block-dense plan
        dense = rmat(512, 30_000, skew=3, seed=1)
        p_dense = ops.build_spmm_plan(*edge_list(dense), dense.n, kind="auto")
        assert p_dense.kind == "blocks"
        assert p_dense.patch_density >= ops.AUTO_DENSITY_THRESHOLD
        # large sparse graph: patches nearly empty -> edge-tiled plan
        sparse = erdos_renyi(5000, 3.0, seed=2)
        p_sparse = ops.build_spmm_plan(*edge_list(sparse), sparse.n, kind="auto")
        assert p_sparse.kind == "edges"
        assert p_sparse.patch_density < ops.AUTO_DENSITY_THRESHOLD
        # both dispatch paths agree with the oracle
        rng = np.random.default_rng(5)
        table = _random_table(rng, p_dense.n_pad, 128, dense.n)
        got = ops.spmm(p_dense, table, impl="xla")
        eplan = ops.build_spmm_plan(*edge_list(dense), dense.n, kind="edges")
        want = ops.spmm(eplan, table, impl="xla")
        np.testing.assert_allclose(got[: dense.n], want[: dense.n], rtol=1e-5)

    @pytest.mark.parametrize("n,deg,width", [(200, 6.0, 128), (500, 10.0, 256)])
    def test_block_kernel_matches_ref(self, n, deg, width):
        g = rmat(n, int(n * deg / 2), skew=3, seed=n)
        rows, cols = edge_list(g)
        plan = ops.build_spmm_plan(rows, cols, g.n, kind="blocks")
        rng = np.random.default_rng(1)
        table = _random_table(rng, plan.n_pad, width, g.n)
        got = spmm_block_pallas(
            plan.block_rows,
            plan.block_cols,
            plan.patches,
            table,
            num_row_blocks=plan.n_pad // plan.block_size,
            interpret=True,
        )[: plan.n_pad]
        got = jnp.where(plan.written_mask[:, None], got, 0)
        eplan = ops.build_spmm_plan(rows, cols, g.n, kind="edges")
        want = ref.spmm_segment_ref(eplan.rows, eplan.cols, table, plan.n_pad - 1)[: plan.n_pad]
        np.testing.assert_allclose(got[: g.n], want[: g.n], rtol=1e-5)

    def test_xla_block_path_matches_edges_path(self):
        g = erdos_renyi(150, 7.0, seed=5)
        rows, cols = edge_list(g)
        bplan = ops.build_spmm_plan(rows, cols, g.n, kind="blocks")
        eplan = ops.build_spmm_plan(rows, cols, g.n, kind="edges")
        rng = np.random.default_rng(2)
        table = _random_table(rng, bplan.n_pad, 128, g.n)
        a = ops.spmm(bplan, table, impl="xla")
        b = ops.spmm(eplan, table, impl="xla")
        np.testing.assert_allclose(a[: g.n], b[: g.n], rtol=1e-6)


class TestColorCombine:
    @pytest.mark.parametrize("k,t1,t2", [(5, 2, 2), (7, 3, 2), (10, 3, 3), (12, 4, 3)])
    def test_matches_ref(self, k, t1, t2):
        tables = ops.build_combine_tables(k, t1, t2)
        n_pad = 256
        a_pad = ops.pad_to(math.comb(k, t1), 128)
        b_pad = ops.pad_to(math.comb(k, t2), 128)
        rng = np.random.default_rng(k)
        left = jnp.asarray(rng.random((n_pad, a_pad)).astype(np.float32))
        m = jnp.asarray(rng.random((n_pad, b_pad)).astype(np.float32))
        got = color_combine_pallas(
            left, m, tables.idx1_t, tables.idx2_t, num_splits=tables.j, interpret=True
        )
        want = ref.color_combine_ref(left, m, tables.idx1, tables.idx2)
        np.testing.assert_allclose(got[:, : tables.s], want, rtol=1e-5)

    def test_xla_chunked_matches_einsum(self):
        # force the chunked path by a tiny chunk threshold
        tables = ops.build_combine_tables(9, 4, 3)
        n_pad = 128
        rng = np.random.default_rng(3)
        left = jnp.asarray(rng.random((n_pad, ops.pad_to(math.comb(9, 4), 128))).astype(np.float32))
        m = jnp.asarray(rng.random((n_pad, ops.pad_to(math.comb(9, 3), 128))).astype(np.float32))
        want = ref.color_combine_ref(left, m, tables.idx1, tables.idx2)

        def chunked(jc=5):
            s, j = tables.idx1.shape
            acc = jnp.zeros((n_pad, s), jnp.float32)
            for j0 in range(0, j, jc):
                i1 = tables.idx1[:, j0 : j0 + jc]
                i2 = tables.idx2[:, j0 : j0 + jc]
                acc = acc + jnp.einsum("vsj,vsj->vs", left[:, i1], m[:, i2])
            return acc

        np.testing.assert_allclose(chunked(), want, rtol=1e-5)


def _iter_eqns(jaxpr):
    """All equations of a jaxpr, recursing into sub-jaxprs (scan/cond/...)."""
    from jax.core import ClosedJaxpr, Jaxpr

    def subs(v):
        if isinstance(v, Jaxpr):
            return [v]
        if isinstance(v, ClosedJaxpr):
            return [v.jaxpr]
        if isinstance(v, (tuple, list)):
            return [s for item in v for s in subs(item)]
        return []

    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in subs(val):
                yield from _iter_eqns(sub)


class TestFusedCount:
    """Fused SpMM->combine vs the unfused oracle, k in {3, 5, 7, 10}."""

    CASES = [(3, 1, 1), (5, 2, 2), (7, 3, 2), (10, 4, 3)]

    def _setup(self, k, t1, t2, n=150, deg=6.0, lane=128):
        g = erdos_renyi(n, deg, seed=k)
        plan = ops.build_spmm_plan(*edge_list(g), g.n, kind="edges")
        tables = ops.build_combine_tables(k, t1, t2, lane=lane)
        rng = np.random.default_rng(k)
        a_pad = ops.pad_to(math.comb(k, t1), lane)
        b_pad = ops.pad_to(math.comb(k, t2), lane)
        left = _random_table(rng, plan.n_pad, a_pad, g.n)
        right = _random_table(rng, plan.n_pad, b_pad, g.n)
        return g, plan, tables, left, right

    @pytest.mark.parametrize("k,t1,t2", CASES)
    def test_pallas_matches_ref(self, k, t1, t2):
        g, plan, tbl, left, right = self._setup(k, t1, t2)
        want = ref.fused_count_ref(plan.rows, plan.cols, left, right, tbl.idx1, tbl.idx2)
        got = fused_count_pallas(
            plan.slab_dst,
            plan.slab_cols,
            left,
            right,
            tbl.idx1_t,
            tbl.idx2_t,
            num_splits=tbl.j,
            slabs_per_block=plan.slabs_per_block,
            interpret=True,
        )
        np.testing.assert_allclose(got[: g.n, : tbl.s], want[: g.n], rtol=1e-5)

    @pytest.mark.parametrize("k,t1,t2", CASES)
    def test_xla_matches_ref(self, k, t1, t2):
        g, plan, tbl, left, right = self._setup(k, t1, t2, lane=1)
        want = ref.fused_count_ref(plan.rows, plan.cols, left, right, tbl.idx1, tbl.idx2)
        got = ops.fused_count(plan, left, right, tbl, impl="xla")
        np.testing.assert_allclose(got[: g.n, : tbl.s], want[: g.n], rtol=1e-5)

    def test_block_plan_falls_back(self):
        # a block-dense plan has no edge slabs; the wrapper must still give
        # the fused result via the two-step path
        k, t1, t2 = 5, 2, 2
        g = erdos_renyi(100, 6.0, seed=11)
        eplan = ops.build_spmm_plan(*edge_list(g), g.n, kind="edges")
        bplan = ops.build_spmm_plan(*edge_list(g), g.n, kind="blocks")
        tbl = ops.build_combine_tables(k, t1, t2)
        rng = np.random.default_rng(6)
        left = _random_table(rng, eplan.n_pad, 128, g.n)
        right = _random_table(rng, eplan.n_pad, 128, g.n)
        want = ops.fused_count(eplan, left, right, tbl, impl="xla")
        got = ops.fused_count(bplan, left, right, tbl, impl="xla")
        np.testing.assert_allclose(got[: g.n, : tbl.s], want[: g.n, : tbl.s], rtol=1e-5)

    def test_never_materializes_m(self):
        """The fused jaxpr has no [n_pad, B] intermediate; the unfused one
        does (which also proves the detector works)."""
        k, t1, t2 = 7, 2, 2  # C(7,2)=21 != C(7,4)=35: B and S shapes distinct
        g, plan, tbl, left, right = self._setup(k, t1, t2, n=300, deg=5.0, lane=1)
        b = right.shape[1]
        forbidden = (plan.n_pad, b)
        # test validity: neither the output nor the per-block edge-slab
        # gather may coincidentally have the forbidden shape
        assert tbl.s != b
        assert plan.slabs_per_block * plan.tile_size != plan.n_pad

        def shapes_of(fn):
            jaxpr = jax.make_jaxpr(fn)(left, right)
            return [tuple(v.aval.shape) for e in _iter_eqns(jaxpr.jaxpr) for v in e.outvars]

        fused = lambda l, r: ops.fused_count(plan, l, r, tbl, impl="xla")
        mask = (jnp.arange(plan.n_pad) < plan.n).astype(jnp.float32)[:, None]
        unfused = lambda l, r: ops.color_combine(
            l, ops.spmm(plan, r, impl="xla") * mask, tbl, impl="xla"
        )
        assert forbidden in shapes_of(unfused)  # detector sanity
        assert forbidden not in shapes_of(fused)

        # the Pallas kernel only ever allocates M as a [row_tile, B] VMEM
        # scratch: at the HBM level (top-level jaxpr; the interpret-mode
        # kernel internals emulate VMEM with host arrays and are not HBM
        # traffic) its only output is the [n_pad, S] table
        fused_p = lambda l, r: fused_count_pallas(
            plan.slab_dst,
            plan.slab_cols,
            l,
            r,
            tbl.idx1_t,
            tbl.idx2_t,
            num_splits=tbl.j,
            slabs_per_block=plan.slabs_per_block,
            interpret=True,
        )
        top = jax.make_jaxpr(fused_p)(left, right).jaxpr
        top_shapes = [tuple(v.aval.shape) for e in top.eqns for v in e.outvars]
        assert forbidden not in top_shapes
        assert (plan.n_pad, tbl.s_pad) in top_shapes  # the fused output


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,hq,hkv,l,d", [(1, 4, 4, 256, 64), (2, 8, 2, 128, 64), (1, 6, 2, 384, 128)]
    )
    def test_causal_matches_ref(self, b, hq, hkv, l, d):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((b, hq, l, d)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((b, hkv, l, d)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((b, hkv, l, d)).astype(np.float32))
        got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [64, 128, 200])
    def test_sliding_window(self, window):
        rng = np.random.default_rng(1)
        b, h, l, d = 1, 2, 256, 64
        q = jnp.asarray(rng.standard_normal((b, h, l, d)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((b, h, l, d)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((b, h, l, d)).astype(np.float32))
        got = flash_attention_pallas(q, k, v, causal=True, window=window, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        rng = np.random.default_rng(2)
        b, h, l, d = 1, 2, 128, 64
        q = jnp.asarray(rng.standard_normal((b, h, l, d)), dtype=jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((b, h, l, d)), dtype=jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((b, h, l, d)), dtype=jnp.bfloat16)
        got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            got.astype(np.float32), want.astype(np.float32), rtol=5e-2, atol=5e-2
        )
