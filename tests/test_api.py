"""The unified ``Counter`` facade: backend parity, estimator agreement with
the brute-force oracle, config resolution, graph I/O round trips.

Backend parity is the core invariant of the API layer: for a FIXED
coloring, ``backend="single"`` and ``backend="distributed"`` must produce
the identical colorful map count (both compute the same deterministic
integer).  These tests run in the main (single-device) process with a
1-shard mesh — the full shard_map/exchange machinery still executes; the
multi-shard variants run in tests/_dist_worker.py.
"""

import numpy as np
import pytest

import jax

from repro.api import CountRequest, CountResult, Counter, run
from repro.configs import COUNTING_CONFIGS
from repro.core import erdos_renyi, load_edge_file, load_npz, save_npz
from repro.core.brute_force import count_colorful_maps, count_copies
from repro.core.distributed import build_distributed_plan, shard_coloring
from repro.core.templates import path_tree, spider_tree, star_tree


class TestBackendParity:
    @pytest.mark.parametrize(
        "tree_fn", [lambda: path_tree(4), lambda: star_tree(4),
                    lambda: spider_tree([2, 1])]
    )
    def test_fixed_coloring_parity(self, tree_fn):
        tree = tree_fn()
        g = erdos_renyi(57, 4.0, seed=3)  # 57 not divisible: ragged shard
        rng = np.random.default_rng(0)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)

        single = Counter.from_graph(g, tree, backend="single")
        dist = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode="alltoall")
        got_s = single.count_coloring(coloring)
        got_d = dist.count_coloring(coloring)
        assert got_s == pytest.approx(want)
        assert got_d == pytest.approx(want)
        assert got_s == pytest.approx(got_d)

    def test_estimate_matches_oracle_both_backends(self):
        tree = path_tree(3)
        g = erdos_renyi(40, 4.0, seed=5)
        truth = count_copies(g, tree)
        for backend, opts in (
            ("single", {}),
            ("distributed", {"num_shards": 1, "mode": "pipeline"}),
        ):
            c = Counter.from_graph(g, tree, backend=backend, **opts)
            res = c.estimate(n_iter=200, key=jax.random.key(0), batch=32)
            assert isinstance(res, CountResult)
            assert res.backend == backend
            assert res.niter == 200 and len(res.samples) == 200
            assert res.mean == pytest.approx(truth, rel=0.2), (backend, res)

    def test_count_one_and_stream(self):
        tree = path_tree(3)
        g = erdos_renyi(30, 4.0, seed=1)
        c = Counter.from_graph(g, tree, backend="single")
        est = c.count_one(jax.random.key(0))
        assert np.isfinite(est) and est >= 0
        stream = c.sample_stream(jax.random.key(1), batch=4)
        a, b = next(stream), next(stream)
        assert a.shape == (4,) and b.shape == (4,)
        # key-split stream: consecutive batches are distinct draws
        assert not np.array_equal(a, b)
        # reproducible from the same key
        a2 = next(c.sample_stream(jax.random.key(1), batch=4))
        np.testing.assert_array_equal(a, a2)


class TestRequests:
    def test_config_resolves_to_request(self):
        ccfg = COUNTING_CONFIGS["bench-small"]
        g = erdos_renyi(60, 4.0, seed=2)
        req = ccfg.to_request(g, backend="single", n_iter=8)
        assert isinstance(req, CountRequest)
        assert req.template == ccfg.template
        # distributed-only opts ride along and are dropped by the facade
        res = run(req, key=jax.random.key(0))
        assert res.backend == "single" and res.niter == 8

    def test_unknown_plan_opt_raises(self):
        g = erdos_renyi(20, 3.0, seed=0)
        with pytest.raises(TypeError, match="unknown plan_opts"):
            Counter.from_graph(g, path_tree(3), typo_opt=1)

    def test_iter_axis_must_be_a_mesh_axis(self):
        g = erdos_renyi(20, 3.0, seed=0)
        c = Counter.from_graph(
            g, path_tree(3), backend="distributed", num_shards=1,
            iter_axis="model",  # auto-built mesh only has the data axis
        )
        with pytest.raises(ValueError, match="iter_axis"):
            _ = c.plan
        base = Counter.from_graph(g, path_tree(3), backend="distributed", num_shards=1)
        with pytest.raises(ValueError, match="iter_axis"):
            base.with_options(iter_axis="model")
        with pytest.raises(TypeError, match="only swaps"):
            base.with_options(num_shards=2)

    def test_with_options_distributed_knobs(self):
        """The distributed with_options allow-list covers the shared kernel
        knobs (impl/fuse) and the §3.3 tile size; unknown keys are rejected
        with a message naming the backend; bucket_tile rebuilds the plan."""
        g = erdos_renyi(60, 4.0, seed=8)
        tree = path_tree(3)
        rng = np.random.default_rng(2)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        base = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode="pipeline")
        # exchange/kernel knobs share the built plan
        fused = base.with_options(mode="ring", fuse=True, impl="xla")
        assert fused.plan is base.plan
        assert fused.count_coloring(coloring) == pytest.approx(want)
        # bucket_tile changes the tiled layout itself -> plan rebuilds
        retiled = base.with_options(bucket_tile=64)
        assert retiled.plan is not base.plan
        assert retiled.plan.bucket_tile == 64
        assert retiled.count_coloring(coloring) == pytest.approx(want)
        # unknown keys: rejected, message names the backend
        with pytest.raises(TypeError, match="distributed"):
            base.with_options(spmm_kind="edges")
        single = Counter.from_graph(g, tree, backend="single")
        with pytest.raises(ValueError, match="single"):
            single.with_options(mode="ring")

    def test_estimate_requires_budget_or_eps(self):
        g = erdos_renyi(20, 3.0, seed=0)
        c = Counter.from_graph(g, path_tree(3), backend="single")
        with pytest.raises(ValueError, match="n_iter or eps"):
            c.estimate()
        # eps derives the worst-case bound; k=3 keeps it small enough to run
        res = c.estimate(eps=2.0, delta=0.5, key=jax.random.key(0))
        assert res.niter >= 1 and res.eps == 2.0


class TestGraphIO:
    def test_npz_roundtrip(self, tmp_path):
        g = erdos_renyi(50, 5.0, seed=4, name="roundtrip")
        path = str(tmp_path / "g.npz")
        save_npz(g, path)
        g2 = load_npz(path)
        assert g2.n == g.n and g2.name == g.name
        np.testing.assert_array_equal(g2.indptr, g.indptr)
        np.testing.assert_array_equal(g2.indices, g.indices)

    def test_load_edge_file(self, tmp_path):
        path = tmp_path / "edges.txt"
        path.write_text(
            "# comment line\n"
            "% another comment\n"
            "0 1\n"
            "1 2 0.5\n"  # extra columns ignored
            "\n"
            "2 0\n"
            "2 0\n"  # duplicate removed
            "3 3\n"  # self loop removed
        )
        g = load_edge_file(str(path))
        assert g.n == 4 and g.num_edges == 3
        assert set(map(int, g.neighbors(2))) == {0, 1}

    def test_load_edge_file_one_indexed(self, tmp_path):
        path = tmp_path / "edges1.txt"
        path.write_text("1 2\n2 3\n")
        g = load_edge_file(str(path), zero_indexed=False)
        assert g.n == 3 and g.num_edges == 2

    def test_loaded_graph_counts(self, tmp_path):
        # the API accepts real (file-loaded) datasets end to end
        g = erdos_renyi(40, 4.0, seed=6)
        path = str(tmp_path / "g.npz")
        save_npz(g, path)
        g2 = load_npz(path)
        tree = path_tree(3)
        c = Counter.from_graph(g2, tree, backend="single")
        rng = np.random.default_rng(1)
        coloring = rng.integers(0, tree.n, g2.n).astype(np.int32)
        assert c.count_coloring(coloring) == pytest.approx(count_colorful_maps(g, tree, coloring))


class TestShardColoring:
    @pytest.mark.parametrize("n,shards", [(97, 4), (96, 4), (5, 2), (64, 8)])
    def test_vectorized_matches_reference(self, n, shards):
        g = erdos_renyi(n, 3.0, seed=0)
        plan = build_distributed_plan(g, path_tree(3), shards)
        rng = np.random.default_rng(7)
        coloring = rng.integers(0, 3, n).astype(np.int32)
        got = shard_coloring(plan, coloring)
        # reference: the original per-shard python loop
        want = np.zeros((plan.num_shards, plan.n_loc_pad), np.int32)
        for p in range(plan.num_shards):
            lo = p * plan.shard_size
            hi = min((p + 1) * plan.shard_size, plan.n)
            want[p, : hi - lo] = coloring[lo:hi]
        np.testing.assert_array_equal(got, want)
