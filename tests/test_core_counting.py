"""Correctness of the color-coding DP against brute-force oracles.

The strongest invariant: for a FIXED coloring, the DP's colorful map count
equals the brute-force colorful map count exactly (both are deterministic
integers represented in f32).  This holds for every graph/template/coloring
and is the core soundness test of the whole engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_counting_plan,
    colorful_map_count,
    erdos_renyi,
    from_edges,
    path_tree,
    random_tree,
    rmat,
    star_tree,
    template,
)
from repro.core.brute_force import count_colorful_maps, count_copies
from repro.core.estimator import estimate_counts
from repro.core.templates import (
    TEMPLATE_TABLE3,
    automorphism_count,
    partition_complexity,
    partition_tree,
    spider_tree,
)


def _dp_count(g, tree, coloring, **kw):
    plan = build_counting_plan(g, tree, **kw)
    col = np.zeros(plan.n_pad, np.int32)
    col[: g.n] = coloring
    return float(colorful_map_count(plan, jnp.asarray(col)))


class TestColorfulExactness:
    @pytest.mark.parametrize("tree_fn", [lambda: path_tree(3), lambda: path_tree(4),
                                         lambda: star_tree(4), lambda: spider_tree([2, 1])])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_small_graphs(self, tree_fn, seed):
        tree = tree_fn()
        g = erdos_renyi(24, 4.0, seed=seed)
        rng = np.random.default_rng(seed)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        got = _dp_count(g, tree, coloring)
        assert got == pytest.approx(want), (got, want)

    def test_triangle_graph_path3(self):
        # triangle contains 3 paths-of-3 (as copies); maps = 6
        g = from_edges(3, np.array([[0, 1], [1, 2], [2, 0]]))
        tree = path_tree(3)
        coloring = np.array([0, 1, 2], np.int32)
        want = count_colorful_maps(g, tree, coloring)
        got = _dp_count(g, tree, coloring)
        assert got == want == 6

    @pytest.mark.parametrize("seed", range(4))
    def test_random_trees_random_graphs(self, seed):
        rng = np.random.default_rng(100 + seed)
        tree = random_tree(int(rng.integers(2, 7)), seed=seed)
        g = erdos_renyi(18, 3.5, seed=seed + 50)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        got = _dp_count(g, tree, coloring)
        assert got == pytest.approx(want), (tree, got, want)

    @pytest.mark.parametrize("root", [0, 1, 2])
    def test_root_invariance(self, root):
        tree = spider_tree([2, 2])
        g = erdos_renyi(20, 4.0, seed=3)
        coloring = np.random.default_rng(7).integers(0, tree.n, g.n).astype(np.int32)
        got = _dp_count(g, tree, coloring, root=root)
        want = count_colorful_maps(g, tree, coloring)
        assert got == pytest.approx(want)

    def test_spmm_block_plan_matches(self):
        tree = path_tree(4)
        g = erdos_renyi(40, 5.0, seed=9)
        coloring = np.random.default_rng(2).integers(0, 4, g.n).astype(np.int32)
        a = _dp_count(g, tree, coloring, spmm_kind="edges")
        b = _dp_count(g, tree, coloring, spmm_kind="blocks")
        c = _dp_count(g, tree, coloring, spmm_kind="auto")
        assert a == pytest.approx(b)
        assert a == pytest.approx(c)

    @pytest.mark.parametrize("tree_fn", [lambda: path_tree(4), lambda: star_tree(5),
                                         lambda: spider_tree([2, 2, 1])])
    def test_fused_engine_matches_bruteforce(self, tree_fn):
        # the fused SpMM->combine path is exact, like the unfused one
        tree = tree_fn()
        g = erdos_renyi(30, 4.0, seed=21)
        rng = np.random.default_rng(8)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        got = _dp_count(g, tree, coloring, fuse=True)
        assert got == pytest.approx(want), (got, want)

    def test_fused_pallas_engine_matches(self):
        # fused Pallas kernel (interpret mode) through the full engine
        tree = spider_tree([2, 1])
        g = erdos_renyi(25, 4.0, seed=13)
        coloring = np.random.default_rng(9).integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        got = _dp_count(g, tree, coloring, fuse=True, impl="pallas")
        assert got == pytest.approx(want), (got, want)


class TestEstimator:
    def test_unbiased_small(self):
        # average over all-iterations estimate converges to the true count
        tree = path_tree(3)
        g = erdos_renyi(30, 4.0, seed=11)
        truth = count_copies(g, tree)
        plan = build_counting_plan(g, tree)
        est = estimate_counts(plan, 300, jax.random.key(0))
        assert est.mean == pytest.approx(truth, rel=0.15), (est.mean, truth)
        assert est.estimate == pytest.approx(truth, rel=0.25)

    def test_scale_factor(self):
        tree = star_tree(4)
        plan_scale = (4 ** 4) / 24 / automorphism_count(tree)
        g = erdos_renyi(16, 3.0, seed=1)
        plan = build_counting_plan(g, tree)
        assert plan.scale == pytest.approx(plan_scale)

    def test_batched_count_fn_matches_loop(self):
        # count_fn(plan, batch=B) evaluates the identical DP per row: a
        # fixed batch of colorings must reproduce the one-at-a-time counts
        tree = path_tree(4)
        g = erdos_renyi(30, 4.0, seed=15)
        plan = build_counting_plan(g, tree)
        rng = np.random.default_rng(3)
        cols = rng.integers(0, tree.n, (5, plan.n_pad)).astype(np.int32)
        cols[:, g.n :] = 0
        want = np.array([float(colorful_map_count(plan, jnp.asarray(c))) for c in cols])
        got = np.asarray(
            jax.vmap(lambda c: colorful_map_count(plan, c))(jnp.asarray(cols))
        )
        np.testing.assert_allclose(got, want, rtol=1e-6)
        # and the key-driven batched sampler agrees with the estimator math
        from repro.core.count_engine import count_fn as _count_fn

        maps, ests = _count_fn(plan, batch=4)(jax.random.key(0))
        assert maps.shape == (4,) and ests.shape == (4,)
        np.testing.assert_allclose(np.asarray(ests), np.asarray(maps) * plan.scale, rtol=1e-6)

    def test_batched_estimator_unbiased(self):
        tree = path_tree(3)
        g = erdos_renyi(30, 4.0, seed=11)
        truth = count_copies(g, tree)
        plan = build_counting_plan(g, tree)
        est = estimate_counts(plan, 300, jax.random.key(1), batch=32)
        assert est.niter == 300 and len(est.samples) == 300
        assert est.mean == pytest.approx(truth, rel=0.15), (est.mean, truth)

    def test_batched_fused_estimator(self):
        tree = spider_tree([2, 1])
        g = erdos_renyi(24, 4.0, seed=12)
        truth = count_copies(g, tree)
        plan = build_counting_plan(g, tree, fuse=True)
        est = estimate_counts(plan, 200, jax.random.key(2), batch=16)
        assert est.mean == pytest.approx(truth, rel=0.25), (est.mean, truth)


class TestTemplates:
    def test_table3_reproduction(self):
        for name, (mem, comp) in TEMPLATE_TABLE3.items():
            tr = template(name)
            chain = partition_tree(tr)
            m, c = partition_complexity(chain)
            assert (m, c) == (mem, comp), name

    def test_automorphisms_brute(self):
        from itertools import permutations

        for seed in range(6):
            tree = random_tree(6, seed=seed)
            edges = {frozenset(e) for e in tree.edges}
            count = 0
            for perm in permutations(range(tree.n)):
                if all(frozenset((perm[a], perm[b])) in edges for a, b in edges):
                    count += 1
            assert automorphism_count(tree) == count, tree

    def test_partition_sizes(self):
        for name in TEMPLATE_TABLE3:
            tr = template(name)
            chain = partition_tree(tr)
            for nd in chain.nodes:
                if not nd.is_leaf:
                    assert (chain.nodes[nd.left].size + chain.nodes[nd.right].size == nd.size)
            assert chain.nodes[chain.root_index].size == tr.n


class TestGraphs:
    def test_rmat_skewness_ordering(self):
        gs = {k: rmat(1 << 12, 40_000, skew=k, seed=0) for k in (1, 3, 8)}
        sk = {k: g.skewness() for k, g in gs.items()}
        assert sk[1] < sk[3] < sk[8], sk

    def test_csr_roundtrip(self):
        g = erdos_renyi(50, 6.0, seed=4)
        deg = g.degrees()
        assert deg.sum() == g.num_directed
        # symmetry
        for v in range(g.n):
            for u in g.neighbors(v):
                assert v in g.neighbors(int(u))
