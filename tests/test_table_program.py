"""The shared table-program executor and the §3.3 tiled bucket layout.

Three invariants of this layer:

* **one DP loop** — the partition-chain node recursion exists exactly once
  in ``src/`` (``core/table_program.py``); both engines are strategies over
  it (guarded at the source level, mirroring the grep-level acceptance
  criterion);
* **no global-max bucket padding** — the distributed plan stores its edge
  buckets as fixed-size tiles with CSR offsets, so no ``[P, P, max_e]``
  array (padded to the globally largest bucket) exists in the plan, and
  bucket storage is O(E + tiles) even at heavy skew;
* **the tiled layout is lossless** — reconstructing edges from the tile
  arrays (all three source views) and from the alltoall slab layout gives
  back exactly the graph's edge list.

Multi-shard execution parity for the tiled layout runs in
``tests/_dist_worker.py`` (8 host devices); here the 1-shard mesh exercises
the full machinery in the main single-device process.
"""

import os
import re

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import Counter
from repro.core import erdos_renyi, rmat
from repro.core.brute_force import count_colorful_maps
from repro.core.distributed import build_distributed_plan
from repro.core.graphs import edge_list
from repro.core.templates import path_tree, spider_tree
from repro.kernels import ops

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def _skewed_plan(bucket_tile=128, shards=8):
    g = rmat(2048, 30_000, skew=8, seed=2)  # contiguous shards: heavy skew
    tree = path_tree(4)
    return g, build_distributed_plan(g, tree, shards, bucket_tile=bucket_tile)


class TestOneTableProgram:
    def test_node_recursion_lives_only_in_table_program(self):
        """Grep-level: the chain-node table recursion (indexing a live-table
        dict by a node's children) appears in exactly one module."""
        pat = re.compile(r"tables\[nd\.(left|right)\]")
        hits = []
        for root, _, files in os.walk(_SRC):
            for fname in files:
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(root, fname)
                with open(path) as fh:
                    if pat.search(fh.read()):
                        hits.append(os.path.relpath(path, _SRC))
        assert hits == [os.path.join("repro", "core", "table_program.py")], hits


class TestTiledBucketLayout:
    def test_no_global_max_bucket_array_in_plan(self):
        """No plan array is padded to the globally largest bucket: the seed
        layout's [P, P, max_e] shape (and anything at least that large in
        the trailing dim) must not exist anywhere in the plan."""
        g, plan = _skewed_plan()
        Pn = plan.num_shards
        max_e = int(plan.bucket_counts.max())
        max_e_pad = max(ops.pad_to(max_e, plan.bucket_tile), plan.bucket_tile)
        assert max_e > plan.r_pad  # the graph is skewed enough to detect it
        for field in dataclass_arrays(plan):
            arr = getattr(plan, field)
            bad = (arr.ndim == 3 and arr.shape[0] == Pn and arr.shape[1] == Pn
                   and arr.shape[2] >= max_e_pad)
            assert not bad, (field, arr.shape, max_e_pad)

    def test_bucket_storage_is_o_edges_plus_tiles(self):
        """Under the paper's random partition (relabel_random), tile-array
        slots stay within 2x of the true edge count at skew 8, while the
        seed's global-max-bucket layout blows far past it.  (A contiguous
        partition of a skewed RMAT still beats the old layout, but carries
        cross-shard alignment padding — that residual imbalance is exactly
        what the random partition exists to remove.)"""
        from repro.core import relabel_random

        g = relabel_random(rmat(2048, 30_000, skew=8, seed=2), seed=3)
        plan = build_distributed_plan(g, path_tree(4), 8)
        Pn = plan.num_shards
        e_dir = g.num_directed
        tile_slots = Pn * plan.num_tiles * plan.bucket_tile
        old_slots = Pn * Pn * max(
            ops.pad_to(int(plan.bucket_counts.max()), plan.bucket_tile),
            plan.bucket_tile,
        )
        assert tile_slots <= 2 * e_dir, (tile_slots, e_dir)
        assert tile_slots < old_slots
        # contiguous partition: still strictly better than global-max padding
        _, plan_c = _skewed_plan()
        tile_slots_c = Pn * plan_c.num_tiles * plan_c.bucket_tile
        old_slots_c = Pn * Pn * max(
            ops.pad_to(int(plan_c.bucket_counts.max()), plan_c.bucket_tile),
            plan_c.bucket_tile,
        )
        assert tile_slots_c < old_slots_c

    @pytest.mark.parametrize("bucket_tile", [64, 128])
    def test_tiles_reconstruct_edge_list(self, bucket_tile):
        """All three tile views (dst, src-local, compact slot) decode back
        to exactly the graph's directed edge list."""
        g, plan = _skewed_plan(bucket_tile=bucket_tile)
        Pn, ss = plan.num_shards, plan.shard_size
        tile_dst = np.asarray(plan.tile_dst)
        tile_src_local = np.asarray(plan.tile_src_local)
        tile_src_compact = np.asarray(plan.tile_src_compact)
        tile_off = np.asarray(plan.tile_off)
        send_idx = np.asarray(plan.send_idx)
        got_local, got_compact = [], []
        for p in range(Pn):
            for q in range(Pn):
                for t in range(tile_off[p, q], tile_off[p, q + 1]):
                    live = tile_dst[p, t] != ss  # pad slots
                    dsts = tile_dst[p, t][live] + p * ss
                    srcs_l = tile_src_local[p, t][live] + q * ss
                    # compact slots decode through q's send list for p
                    slots = tile_src_compact[p, t][live]
                    srcs_c = send_idx[q, p, slots] + q * ss
                    got_local += list(zip(dsts.tolist(), srcs_l.tolist()))
                    got_compact += list(zip(dsts.tolist(), srcs_c.tolist()))
                # pad slots carry the guaranteed-zero sentinel slot
                pads = tile_dst[p, t] == ss
                assert (tile_src_compact[p, t][pads] == plan.r_pad - 1).all()
        rows, cols = edge_list(g)
        want = sorted(zip(rows.tolist(), cols.tolist()))
        assert sorted(got_local) == want
        assert sorted(got_compact) == want

    def test_a2a_slabs_reconstruct_edge_list(self):
        """The alltoall slab layout (columns into the [P * r_pad] exchange
        buffer) decodes back to exactly the directed edge list."""
        g, plan = _skewed_plan()
        Pn, ss, rp = plan.num_shards, plan.shard_size, plan.r_pad
        slab_dst = np.asarray(plan.a2a_slab_dst)
        slab_cols = np.asarray(plan.a2a_slab_cols)
        send_idx = np.asarray(plan.send_idx)
        spb = plan.slabs_per_block
        got = []
        for p in range(Pn):
            for s in range(slab_dst.shape[1]):
                block = s // spb
                live = slab_dst[p, s] >= 0
                dsts = slab_dst[p, s][live] + block * 128 + p * ss
                q = slab_cols[p, s][live] // rp
                slot = slab_cols[p, s][live] % rp
                srcs = send_idx[q, p, slot] + q * ss
                got += list(zip(dsts.tolist(), srcs.tolist()))
                # pad slots point at the guaranteed-zero sentinel column
                assert (slab_cols[p, s][~live] == rp - 1).all()
        rows, cols = edge_list(g)
        assert sorted(got) == sorted(zip(rows.tolist(), cols.tolist()))

    def test_request_slot_sentinel_is_a_pad_row(self):
        """r_pad reserves a strict pad slot: slot r_pad-1 of every chunk
        resolves to the shard's zero sentinel row."""
        g, plan = _skewed_plan()
        send_idx = np.asarray(plan.send_idx)
        assert (send_idx[:, :, plan.r_pad - 1] == plan.shard_size).all()


class TestOneShardParity:
    """The full distributed machinery on a 1-shard mesh in-process: every
    exchange mode x fuse against the brute-force oracle on a skewed graph."""

    @pytest.mark.parametrize("mode", ["alltoall", "pipeline", "adaptive", "ring"])
    @pytest.mark.parametrize("fuse", [False, True])
    def test_skewed_parity(self, mode, fuse):
        g = rmat(512, 4000, skew=8, seed=4)
        tree = spider_tree([2, 1])
        rng = np.random.default_rng(0)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        c = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode=mode, fuse=fuse)
        assert c.count_coloring(coloring) == pytest.approx(want, rel=1e-6)

    def test_bucket_tile_sweep_parity(self):
        g = erdos_renyi(200, 5.0, seed=1)
        tree = path_tree(3)
        rng = np.random.default_rng(5)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        base = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode="pipeline")
        for tile in (32, 64, 256):
            c = base.with_options(bucket_tile=tile)
            assert c.plan.bucket_tile == tile
            assert c.count_coloring(coloring) == pytest.approx(want, rel=1e-6)


def dataclass_arrays(plan):
    """Names of the plan's array-valued dataclass fields."""
    import dataclasses

    out = []
    for f in dataclasses.fields(plan):
        v = getattr(plan, f.name)
        if isinstance(v, (np.ndarray, jnp.ndarray)) or hasattr(v, "shape"):
            out.append(f.name)
    return out
