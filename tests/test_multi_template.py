"""Multi-template counting: the template-set compiler + shared-DAG executor.

Four invariants of the family-counting layer (DESIGN.md §14):

* **dedup** — compiling a family whose templates share canonically-identical
  rooted subtrees (u3-path ⊂ u5-2 ⊂ the u7-2 two-leg spider) produces
  strictly fewer DAG nodes than the sum of the per-template chains, and a
  symmetric template's identical branches collapse to one node (a parent
  whose left and right children are the SAME node);
* **singleton ≡ chain** — a one-template family counts exactly what the
  original partition-chain engine counts (and the node recursion itself
  still exists exactly once in src/, guarded by test_table_program);
* **fixed-coloring parity** — ``count_coloring_many`` equals the
  brute-force oracle per template on BOTH backends;
* **estimate ≡ estimate_many** — with the same key, the family run and
  per-template runs on ``n_colors = k`` Counters see identical colorings
  and produce identical per-iteration samples.

The 8-shard distributed case (all exchange modes x fuse) runs in
``tests/_dist_worker.py``.
"""

import numpy as np
import pytest

import jax

from repro.api import Counter
from repro.core import erdos_renyi
from repro.core.brute_force import count_colorful_maps, count_copies
from repro.core.count_engine import copy_scale
from repro.core.templates import (
    compile_templates,
    partition_tree,
    path_tree,
    spider_tree,
    star_tree,
    template,
)

SPIDERS = ("u3-1", "u5-2", "u7-2")


@pytest.fixture(scope="module")
def graph():
    return erdos_renyi(120, 5.0, seed=2)


class TestCompiler:
    def test_nested_spiders_dedup(self):
        """u3-1 ⊂ u5-2 ⊂ u7-2: every subtree of the smaller templates is
        canonically present in the larger, so the DAG carries exactly the
        largest template's unique subtrees."""
        dag = compile_templates(SPIDERS)
        chains = [partition_tree(template(n)) for n in SPIDERS]
        chain_nodes = sum(len(c.nodes) for c in chains)
        assert len(dag.nodes) < chain_nodes
        # the family shares one leaf + the path-2/3/4 spine: 6 unique nodes
        assert len(dag.nodes) == 6
        assert dag.k == 7
        # every template root reads its own node; sizes match the templates
        assert [dag.nodes[r].size for r in dag.roots] == [3, 5, 7]

    def test_chain_is_a_prefix_of_sharing(self):
        """Each chain's internal-node signature multiset is covered by the
        DAG (no table the chains need is missing)."""
        dag = compile_templates(SPIDERS)
        sizes = {nd.size for nd in dag.nodes}
        for n in SPIDERS:
            for _, nd in partition_tree(template(n)).internal_nodes():
                assert nd.size in sizes

    def test_symmetric_template_collapses(self):
        """spider(2,2): the two identical legs collapse — some internal
        node has left == right, and the DAG is smaller than the chain."""
        tree = spider_tree([2, 2])
        dag = compile_templates([tree])
        chain = partition_tree(tree)
        assert len(dag.nodes) < len(chain.nodes)
        assert any(nd.left == nd.right for nd in dag.nodes if not nd.is_leaf)

    def test_star_collapses_leaves(self):
        """All of a star's leaf children share one leaf node."""
        dag = compile_templates([star_tree(5)])
        assert sum(nd.is_leaf for nd in dag.nodes) == 1
        assert len(dag.nodes) < len(partition_tree(star_tree(5)).nodes)

    def test_table_reads_refcounts(self):
        """reads = parent reads + root deliveries, for every node."""
        dag = compile_templates(SPIDERS)
        reads = dag.table_reads()
        want = [0] * len(dag.nodes)
        for nd in dag.nodes:
            if not nd.is_leaf:
                want[nd.left] += 1
                want[nd.right] += 1
        for r in dag.roots:
            want[r] += 1
        assert reads == want
        assert all(r > 0 for r in reads)

    def test_n_colors_validation(self):
        with pytest.raises(ValueError, match="n_colors"):
            compile_templates(SPIDERS, n_colors=5)
        assert compile_templates(SPIDERS, n_colors=9).k == 9


class TestSingletonEqualsChain:
    def test_count_matches_chain_engine(self, graph):
        tree = spider_tree([2, 1])
        rng = np.random.default_rng(0)
        coloring = rng.integers(0, tree.n, graph.n).astype(np.int32)
        single = Counter.from_graph(graph, tree, backend="single")
        chain_count = single.count_coloring(coloring)
        (dag_count,) = single.count_coloring_many([tree], coloring)
        assert dag_count == pytest.approx(chain_count, rel=1e-6)


class TestFixedColoringParity:
    """count_coloring_many == brute force per template, both backends."""

    @pytest.mark.parametrize("backend", ["single", "distributed"])
    def test_family_matches_oracle(self, graph, backend):
        family = [path_tree(3), star_tree(4), spider_tree([2, 1])]
        kw = {"num_shards": 1, "mode": "adaptive"} if backend == "distributed" else {}
        c = Counter.from_graph(graph, family[-1], backend=backend, **kw)
        k = max(t.n for t in family)
        rng = np.random.default_rng(1)
        coloring = rng.integers(0, k, graph.n).astype(np.int32)
        got = c.count_coloring_many(family, coloring)
        want = [count_colorful_maps(graph, t, coloring) for t in family]
        assert np.allclose(got, want, rtol=1e-6), (got, want)

    def test_fuse_parity(self, graph):
        family = [path_tree(3), spider_tree([2, 1])]
        rng = np.random.default_rng(3)
        coloring = rng.integers(0, 4, graph.n).astype(np.int32)
        want = [count_colorful_maps(graph, t, coloring) for t in family]
        for backend, kw in (
            ("single", {"fuse": True, "spmm_kind": "edges"}),
            ("distributed", {"fuse": True, "num_shards": 1, "mode": "pipeline"}),
        ):
            c = Counter.from_graph(graph, family[-1], backend=backend, **kw)
            got = c.count_coloring_many(family, coloring)
            assert np.allclose(got, want, rtol=1e-6), (backend, got, want)


class TestEstimateMany:
    def test_matches_per_template_estimate_exactly(self, graph):
        """Same key => identical colorings => per-template samples match
        the family run sample for sample (single backend; the 8-shard
        distributed version runs in _dist_worker)."""
        family = [path_tree(3), star_tree(4), spider_tree([2, 1])]
        c = Counter.from_graph(graph, family[-1], backend="single")
        res = c.estimate_many(family, n_iter=24, key=jax.random.key(7), batch=8)
        assert res.samples.shape == (24, 3)
        for i, t in enumerate(family):
            ci = Counter.from_graph(graph, t, backend="single", n_colors=res.k)
            ri = ci.estimate(n_iter=24, key=jax.random.key(7), batch=8)
            assert np.allclose(ri.samples, res.samples[:, i], rtol=1e-6)
            assert ri.estimate == pytest.approx(res[i].estimate, rel=1e-6)

    def test_estimator_is_unbiased_per_template(self, graph):
        """Family means approach the exact copy counts (shared coloring,
        per-template scales)."""
        family = [path_tree(3), star_tree(4)]
        c = Counter.from_graph(graph, family[-1], backend="single")
        res = c.estimate_many(family, n_iter=400, key=jax.random.key(0), batch=50)
        for i, t in enumerate(family):
            truth = count_copies(graph, t)
            assert abs(res.means[i] - truth) / truth < 0.25, (t.name, res.means[i], truth)

    def test_scales_reduce_to_paper_formula(self):
        """k == t reduces to k^k/k!/|Aut|; widening k rescales correctly."""
        import math

        assert copy_scale(4, 4, 2) == pytest.approx(4 ** 4 / math.factorial(4) / 2)
        # t=2, k=4: inverse P[2 vertices distinctly colored] = 16/12
        assert copy_scale(4, 2, 1) == pytest.approx(16 / 12)

    def test_result_views(self, graph):
        family = ["u3-1", path_tree(4)]
        c = Counter.from_graph(graph, "u3-1", backend="single")
        res = c.estimate_many(family, n_iter=8, key=jax.random.key(1), batch=4)
        assert len(res) == 2
        assert [one.template for one in res] == ["u3-1", "path-4"]
        assert res.unique_tables < res.chain_tables
        one = res[1]
        assert one.samples.shape == (8,)
        assert "path-4" in str(res)
