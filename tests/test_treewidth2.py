"""Treewidth-2 templates (DESIGN.md §19): the apex-pinned bag-table programs.

Correctness is anchored to the exponential brute-force oracle: for a FIXED
coloring the DP is deterministic, so ``colorful_map_count`` must equal
``count_colorful_maps`` exactly — on cycles, the diamond, the bowtie, the
house, with widened color budgets, under ``fuse``, and inside mixed
tree+cycle families compiled into one shared DAG.  Tree-shaped ``Template``
objects must lower to the *identical* ``PartitionChain`` as their ``Tree``
twin (the front-end is a strict superset, bit-identically).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import Counter
from repro.core import build_counting_plan, colorful_map_count, erdos_renyi
from repro.core.brute_force import (
    count_colorful_maps,
    count_copies,
    count_embedding_maps,
)
from repro.core.count_engine import (
    build_multi_counting_plan,
    colorful_map_count_many,
)
from repro.core.templates import (
    TEMPLATES,
    BagNode,
    Template,
    Tree,
    automorphism_count,
    bag_program,
    compile_templates,
    cycle_template,
    partition_tree,
    program_has_bags,
    template,
    template_program,
)

BAG_NAMES = ["cycle3", "cycle4", "cycle5", "cycle6", "diamond", "bowtie", "house"]


def _dp(g, t, coloring, **kw):
    plan = build_counting_plan(g, t, **kw)
    col = np.zeros(plan.n_pad, np.int32)
    col[: g.n] = coloring
    return float(colorful_map_count(plan, jnp.asarray(col)))


@pytest.fixture(scope="module")
def graph():
    return erdos_renyi(22, 7.0, seed=5)


class TestTemplateType:
    def test_registry_has_nontrees(self):
        for name in BAG_NAMES:
            t = template(name)
            assert isinstance(t, Template) and not t.is_tree

    def test_validation(self):
        with pytest.raises(ValueError):
            Template(3, ((0, 0), (0, 1)))  # self loop
        with pytest.raises(ValueError):
            Template(3, ((0, 1), (0, 1), (1, 2)))  # duplicate edge
        with pytest.raises(ValueError):
            Template(4, ((0, 1), (2, 3)))  # disconnected
        with pytest.raises(ValueError):
            Template(3, ((0, 1), (1, 7)))  # out of range

    def test_automorphism_counts_by_hand(self):
        # |Aut(C_n)| = 2n (dihedral); diamond 4; bowtie 8; house 2
        want = {"cycle3": 6, "cycle4": 8, "cycle5": 10, "cycle6": 12,
                "diamond": 4, "bowtie": 8, "house": 2}
        for name, aut in want.items():
            assert automorphism_count(template(name)) == aut, name

    def test_non_apex_reducible_rejected(self):
        # K4 minus nothing: removing any one vertex leaves a triangle
        k4 = [(a, b) for a in range(4) for b in range(a + 1, 4)]
        with pytest.raises(ValueError, match="apex-reducible"):
            bag_program(Template(4, tuple(k4)))

    def test_tree_shaped_template_is_a_tree(self):
        t = Template(4, ((0, 1), (1, 2), (2, 3)))
        assert t.is_tree
        tr = t.as_tree()
        assert isinstance(tr, Tree) and tr.edges == t.edges


class TestFrontEnd:
    def test_secret_tree_identical_chain(self):
        # a tree disguised as a Template lowers to the IDENTICAL chain
        edges = ((0, 1), (1, 2), (1, 3), (3, 4))
        a = template_program(Template(5, edges))
        b = partition_tree(Tree(5, edges))
        assert a.nodes == b.nodes and a.k == b.k

    def test_bag_program_shape(self):
        p = bag_program(template("cycle5"))
        assert program_has_bags(p)
        kinds = [nd.kind for nd in p.nodes]
        assert kinds.count("bag_collapse") == 1
        assert p.nodes[p.root_index].kind == "bag_collapse"
        # forest = path on 4 vertices -> collapse covers size n-1
        assert p.nodes[p.root_index].size == 4

    def test_bowtie_joins_forest_trees(self):
        p = bag_program(template("bowtie"))
        kinds = [nd.kind for nd in p.nodes]
        assert "bag_join" in kinds  # two triangles share only the apex

    def test_family_interning_shares_bag_nodes(self):
        solo = len(bag_program(template("cycle5")).nodes) + len(
            bag_program(template("cycle6")).nodes
        )
        dag = compile_templates(["cycle5", "cycle6"])
        assert len(dag.nodes) < solo  # shared bag-leaf/combine prefixes

    def test_mixed_family_keeps_tree_nodes_untagged(self):
        dag = compile_templates(["u3-1", "cycle4"])
        assert program_has_bags(dag)
        assert any(not isinstance(nd, BagNode) for nd in dag.nodes)


class TestOracleParity:
    @pytest.mark.parametrize("name", BAG_NAMES)
    def test_fixed_coloring_exact(self, graph, name):
        t = template(name)
        rng = np.random.default_rng(hash(name) % 2**31)
        for trial in range(2):
            coloring = rng.integers(0, t.n, graph.n).astype(np.int32)
            want = count_colorful_maps(graph, t, coloring)
            got = _dp(graph, t, coloring)
            assert got == pytest.approx(want), (name, trial, got, want)

    def test_triangle_by_hand(self):
        from repro.core import from_edges

        g = from_edges(3, np.array([[0, 1], [1, 2], [2, 0]]))
        t = template("cycle3")
        got = _dp(g, t, np.array([0, 1, 2], np.int32))
        # one triangle, all 3! vertex orders colorful
        assert got == count_colorful_maps(g, t, np.array([0, 1, 2])) == 6
        assert count_embedding_maps(g, t) == 6
        assert count_copies(g, t) == 1.0

    @pytest.mark.parametrize("name", ["cycle4", "diamond"])
    def test_widened_colors_exact(self, graph, name):
        t = template(name)
        rng = np.random.default_rng(11)
        k = t.n + 2
        coloring = rng.integers(0, k, graph.n).astype(np.int32)
        want = count_colorful_maps(graph, t, coloring)
        got = _dp(graph, t, coloring, n_colors=k)
        assert got == pytest.approx(want), (got, want)

    def test_fuse_parity(self, graph):
        t = template("cycle5")
        rng = np.random.default_rng(3)
        coloring = rng.integers(0, t.n, graph.n).astype(np.int32)
        base = _dp(graph, t, coloring)
        fused = _dp(graph, t, coloring, fuse=True)
        assert base == pytest.approx(fused)

    def test_compaction_request_bypassed(self, graph):
        # §15 probes cannot model bag nodes: compact=True must degrade to
        # the dense plan, bit-exactly, not crash
        t = template("diamond")
        rng = np.random.default_rng(4)
        coloring = rng.integers(0, t.n, graph.n).astype(np.int32)
        plan = build_counting_plan(graph, t, compact=True)
        assert plan.compaction is None
        want = count_colorful_maps(graph, t, coloring)
        col = np.zeros(plan.n_pad, np.int32)
        col[: graph.n] = coloring
        assert float(colorful_map_count(plan, jnp.asarray(col))) == pytest.approx(want)

    def test_mixed_family_one_dag_exact(self, graph):
        fam = ["u3-1", "cycle4", "u5-2", "cycle5"]
        plan = build_multi_counting_plan(graph, fam, n_colors=6)
        rng = np.random.default_rng(9)
        coloring = rng.integers(0, plan.k, graph.n).astype(np.int32)
        col = np.zeros(plan.n_pad, np.int32)
        col[: graph.n] = coloring
        got = np.asarray(colorful_map_count_many(plan, jnp.asarray(col)))
        want = [count_colorful_maps(graph, template(n), coloring) for n in fam]
        assert np.allclose(got, want), (got, want)


class TestEstimates:
    def test_estimate_converges_to_copies(self, graph):
        t = template("diamond")
        c = Counter.from_graph(graph, t, backend="single")
        res = c.estimate(400, key=jax.random.key(2), batch=50)
        truth = count_copies(graph, t)
        assert truth > 0
        assert res.estimate == pytest.approx(truth, rel=0.2), (
            res.estimate, truth,
        )

    def test_family_estimate_by_name(self, graph):
        c = Counter.from_graph(graph, "cycle5", backend="single")
        res = c.estimate_many(["cycle3", "cycle5"], 64, key=jax.random.key(0))
        assert res.templates == ("cycle3", "cycle5")
        assert all(np.asarray(res.estimates) >= 0)


class TestLauncherValidation:
    def _argv(self, extra):
        return ["--config", "bench-small", "--iters", "1"] + extra

    def test_unknown_template_rejected(self, monkeypatch, capsys):
        import sys

        from repro.launch import count as launch_count

        monkeypatch.setattr(
            sys,
            "argv",
            ["count"] + self._argv(["--templates", "cycle5,notatmpl"]),
        )
        with pytest.raises(SystemExit):
            launch_count.main()
        err = capsys.readouterr().err
        assert "notatmpl" in err and "registry" in err

    def test_duplicate_template_rejected(self, monkeypatch, capsys):
        import sys

        from repro.launch import count as launch_count

        monkeypatch.setattr(
            sys,
            "argv",
            ["count"] + self._argv(["--templates", "cycle5,cycle5"]),
        )
        with pytest.raises(SystemExit):
            launch_count.main()
        assert "duplicate" in capsys.readouterr().err

    def test_registry_sorted_in_message(self):
        assert "cycle5" in TEMPLATES and "diamond" in TEMPLATES


def test_cycle_template_helper():
    c4 = cycle_template(4)
    assert c4.n == 4 and len(c4.edges) == 4
    with pytest.raises(ValueError):
        cycle_template(2)


def test_grep_guard_single_recursion_source():
    """The node recursion lives in table_program.py ONLY (one-recursion
    invariant): the bag kinds must not have grown a second executor."""
    import pathlib
    import re

    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    hits = set()
    for p in src.rglob("*.py"):
        if re.search(r"tables\[nd\.(left|right)\]", p.read_text()):
            hits.add(p.name)
    assert hits == {"table_program.py"}, hits
