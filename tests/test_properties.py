"""Hypothesis property tests on system invariants.

The central property — DP colorful count == brute-force colorful count for
arbitrary (graph, template, coloring) — plus structural invariants of the
color-set algebra, partition chains, graph substrate, and estimator math.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (see requirements-dev.txt)",
)

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import build_counting_plan, colorful_map_count, from_edges
from repro.core.brute_force import count_colorful_maps
from repro.core.colorsets import num_sets, rank_of_mask, set_masks, split_tables
from repro.core.estimator import median_of_means
from repro.core.graphs import edge_list, erdos_renyi
from repro.core.templates import (
    automorphism_count,
    partition_tree,
    random_tree,
)

SETTINGS = settings(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


class TestColorsetAlgebra:
    @given(st.integers(3, 12), st.data())
    @SETTINGS
    def test_rank_bijection(self, k, data):
        t = data.draw(st.integers(1, min(k, 6)))
        masks = set_masks(k, t)
        assert len(masks) == num_sets(k, t)
        assert len(set(masks)) == len(masks)
        for i, m in enumerate(masks[:: max(1, len(masks) // 7)]):
            assert rank_of_mask(k, t, m) == masks.index(m)

    @given(st.integers(4, 10), st.data())
    @SETTINGS
    def test_split_tables_partition(self, k, data):
        t1 = data.draw(st.integers(1, k - 2))
        t2 = data.draw(st.integers(1, min(k - t1, 4)))
        idx1, idx2 = split_tables(k, t1, t2)
        t = t1 + t2
        assert idx1.shape == (num_sets(k, t), math.comb(t, t1))
        m1 = set_masks(k, t1)
        m2 = set_masks(k, t2)
        mo = set_masks(k, t)
        # each split row reassembles the output set exactly, disjointly
        for s in range(0, idx1.shape[0], max(1, idx1.shape[0] // 9)):
            for j in range(idx1.shape[1]):
                a, b = m1[idx1[s, j]], m2[idx2[s, j]]
                assert a & b == 0
                assert a | b == mo[s]

    @given(st.integers(3, 9))
    @SETTINGS
    def test_vandermonde_identity(self, k):
        # sum over splits of C(k,t) entries == C(t, t1) per output set
        idx1, _ = split_tables(k, 2, 1)
        assert idx1.shape[1] == math.comb(3, 2)


class TestPartitionInvariants:
    @given(st.integers(2, 10), st.integers(0, 10_000))
    @SETTINGS
    def test_chain_structure(self, n, seed):
        tree = random_tree(n, seed=seed)
        chain = partition_tree(tree)
        leaves = sum(1 for nd in chain.nodes if nd.is_leaf)
        internal = [nd for nd in chain.nodes if not nd.is_leaf]
        assert leaves == n  # one leaf per template vertex
        assert len(internal) == n - 1  # binary tree
        for nd in internal:
            assert chain.nodes[nd.left].size + chain.nodes[nd.right].size == nd.size
        assert chain.nodes[chain.root_index].size == n

    @given(st.integers(2, 7), st.integers(0, 1000))
    @SETTINGS
    def test_aut_divides_factorial(self, n, seed):
        tree = random_tree(n, seed=seed)
        a = automorphism_count(tree)
        assert math.factorial(n) % a == 0


class TestDPExactness:
    @given(
        st.integers(10, 26),
        st.floats(1.5, 4.0),
        st.integers(2, 5),
        st.integers(0, 10_000),
    )
    @settings(
        max_examples=12,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    def test_dp_equals_bruteforce(self, n, deg, k, seed):
        g = erdos_renyi(n, deg, seed=seed)
        tree = random_tree(k, seed=seed + 1)
        rng = np.random.default_rng(seed + 2)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        plan = build_counting_plan(g, tree)
        col = np.zeros(plan.n_pad, np.int32)
        col[: g.n] = coloring
        got = float(colorful_map_count(plan, jnp.asarray(col)))
        want = count_colorful_maps(g, tree, coloring)
        assert got == pytest.approx(want), (n, deg, k, seed)


class TestGraphInvariants:
    @given(st.integers(5, 60), st.integers(0, 500), st.data())
    @SETTINGS
    def test_from_edges_symmetry_dedup(self, n, seed, data):
        rng = np.random.default_rng(seed)
        m = data.draw(st.integers(0, 80))
        edges = rng.integers(0, n, (m, 2))
        g = from_edges(n, edges)
        rows, cols = edge_list(g)
        assert len(rows) == 2 * g.num_edges
        pairs = set(zip(rows.tolist(), cols.tolist()))
        assert all((c, r) in pairs for r, c in pairs)  # symmetric
        assert all(r != c for r, c in pairs)  # no self loops
        assert len(pairs) == len(rows)  # dedup


class TestEstimatorMath:
    @given(st.lists(st.floats(0, 1e6), min_size=1, max_size=50), st.integers(1, 7))
    @SETTINGS
    def test_median_of_means_bounds(self, xs, groups):
        xs_arr = np.asarray(xs)
        mom = median_of_means(xs_arr, groups)
        assert xs_arr.min() - 1e-9 <= mom <= xs_arr.max() + 1e-9

    @given(st.integers(2, 8))
    @SETTINGS
    def test_scale_factor_formula(self, k):
        # P[colorful] = k!/k^k; estimator scale is its inverse
        from repro.core.templates import path_tree

        tree = path_tree(k)
        g = erdos_renyi(12, 2.0, seed=0)
        plan = build_counting_plan(g, tree)
        expected = (k ** k) / math.factorial(k) / automorphism_count(tree)
        assert plan.scale == pytest.approx(expected)
