"""Narrow-wire exchange + measured adaptive routing (DESIGN.md §18).

The invariant under test everywhere: the wire dtype is a pure transport
choice — int16/int8 slabs (dense, and compacted with bit-packed activity
bitmaps) produce **bit-identical** counts to the float32 wire, with
saturation escalating through the wider-wire ladder transparently.  The
single-process coverage here runs the full distributed machinery on a
1-shard mesh; real 8-shard coverage (slabs actually crossing device
boundaries, the calibration probe timing a real ppermute) runs in
``tests/_dist_worker.py::test_compressed_exchange``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import Counter
from repro.comm.adaptive import V5E_ICI, calibrate, choose_mode_full
from repro.comm.compress import (
    WIRE_DTYPES,
    WIRE_ESCALATION,
    mask_column_count,
    mask_columns,
    mask_from_columns,
    narrow_cast,
    widen,
    wire_itemsize,
)
from repro.compat import make_mesh
from repro.core import erdos_renyi, rmat
from repro.core.brute_force import count_colorful_maps
from repro.core.frontier import node_exchange_bytes, sampled_density
from repro.core.templates import path_tree, spider_tree
from repro.testing import faults

WIRES = ["int16", "int8"]


def _skewed_graph(n=512, e=1500, seed=4):
    return rmat(n, e, skew=8, seed=seed)


class TestWireHelpers:
    @pytest.mark.parametrize("wire", WIRES)
    def test_narrow_cast_exact_below_max(self, wire):
        maxv = WIRE_DTYPES[wire][2]
        x = jnp.asarray([[0.0, 1.0, float(maxv)], [2.0, 3.0, 5.0]])
        flags = []
        y = narrow_cast(x, wire, flags)
        assert y.dtype == WIRE_DTYPES[wire][0]
        assert bool(flags[0])  # within range: flag holds
        np.testing.assert_array_equal(np.asarray(widen(y)), np.asarray(x))

    @pytest.mark.parametrize("wire", WIRES)
    def test_narrow_cast_flags_saturation(self, wire):
        maxv = WIRE_DTYPES[wire][2]
        flags = []
        narrow_cast(jnp.asarray([[float(maxv + 1)]]), wire, flags)
        assert not bool(flags[0])

    def test_float32_wire_is_identity(self):
        x = jnp.asarray([[1.5, -2.0]])
        flags = []
        assert narrow_cast(x, "float32", flags) is x
        assert flags == []  # no flag: the wide wire cannot saturate
        assert widen(x) is x

    def test_escalation_ladder_terminates(self):
        wire = "int8"
        seen = {wire}
        while wire in WIRE_ESCALATION:
            wire = WIRE_ESCALATION[wire]
            assert wire not in seen, "escalation must not cycle"
            seen.add(wire)
        assert wire == "float32"

    @pytest.mark.parametrize("wire", WIRES)
    @pytest.mark.parametrize("r_len", [1, 7, 8, 17, 64, 100])
    def test_mask_columns_roundtrip(self, wire, r_len):
        rng = np.random.default_rng(r_len)
        mask = jnp.asarray(rng.integers(0, 2, (3, r_len)).astype(bool))
        cap = 4
        cols = mask_columns(mask, cap, wire)
        assert cols.dtype == WIRE_DTYPES[wire][0]
        assert cols.shape == (3, cap, mask_column_count(r_len, cap, wire))
        back = mask_from_columns(cols, r_len, wire)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(mask))

    def test_mask_column_count_math(self):
        # 100 rows -> 13 int8 words -> ceil(13/4) = 4 payload columns
        assert mask_column_count(100, 4, "int8") == 4
        # int16 words halve the column count's word term
        assert mask_column_count(100, 4, "int16") == 2
        assert mask_column_count(8, 8, "int8") == 1

    def test_wire_itemsize(self):
        assert wire_itemsize("float32") == 4
        assert wire_itemsize("int16") == 2
        assert wire_itemsize("int8") == 1


class TestBytesModel:
    def test_narrow_wire_halves_dense_bytes(self):
        from repro.core.distributed import build_distributed_plan

        g = _skewed_graph()
        plan = build_distributed_plan(g, spider_tree([2, 1]), 8)
        i = plan.program.root_index
        d32, _ = node_exchange_bytes(plan, i, "ring")
        d16, _ = node_exchange_bytes(plan, i, "ring", wire_dtype="int16")
        d8, _ = node_exchange_bytes(plan, i, "ring", wire_dtype="int8")
        assert d16 * 2 == d32  # the acceptance ratio: exactly 0.5x
        assert d8 * 4 == d32

    def test_compact_bytes_include_mask_columns(self):
        from repro.core.distributed import build_distributed_plan

        g = _skewed_graph()
        plan = build_distributed_plan(
            g, spider_tree([2, 1]), 8, compact=True, density_threshold=0.9
        )
        spec = plan.compaction
        assert spec is not None and spec.shard_caps
        i = next(
            i for i, nd in enumerate(plan.program.nodes)
            if not nd.is_leaf and nd.right in spec.shard_caps
        )
        dense, compact = node_exchange_bytes(plan, i, "ring", wire_dtype="int16")
        assert 0 < compact < dense
        b = plan.widths[plan.program.nodes[i].right]
        cap = spec.shard_caps[plan.program.nodes[i].right]
        ncols = mask_column_count(plan.n_loc_pad, cap, "int16")
        assert compact == (plan.num_shards - 1) * cap * (b + ncols) * 2


class TestRouter:
    def test_latency_bound_picks_alltoall(self):
        mode, diag = choose_mode_full(1024, 1024, 0.0, 8)
        assert mode == "alltoall"
        assert diag["predicted_s"] == min(diag["costs_s"].values())

    def test_compute_bound_picks_overlap(self):
        mode, _ = choose_mode_full(1e6, 1e6, 1e15, 8)
        assert mode in ("pipeline", "ring")

    def test_cheap_ring_bytes_pick_ring(self):
        mode, _ = choose_mode_full(1e9, 1e3, 0.0, 8)
        assert mode == "ring"

    def test_calibrate_single_device_returns_base(self):
        mesh = make_mesh((1,), ("data",))
        assert calibrate(mesh, "data") is V5E_ICI


class TestSampledDensity:
    def test_probe_density_in_range_and_sparser_when_deep(self):
        from repro.core.count_engine import build_counting_plan

        g = _skewed_graph(1024, 3000, seed=2)
        plan = build_counting_plan(g, spider_tree([2, 1]))
        dens = sampled_density(
            g.n,
            2.0 * g.num_edges / g.n,
            plan.chain,
            plan.combine,
            plan.k,
            sample_vertices=256,
            probes=1,
        )
        assert dens and all(0.0 <= d <= 1.0 for d in dens.values())
        # the probe is exact where the Markov model saturates: deep nodes
        # on a skewed sparse graph come back measurably below 1.0
        sizes = {i: plan.chain.nodes[i].size for i in dens}
        deepest = max(sizes, key=sizes.get)
        assert dens[deepest] < 1.0


class TestOneShardParity:
    """Full distributed machinery on a 1-shard mesh: narrow slabs vs the
    float32 wire and the oracle, with counts large enough that int8 (and
    on the denser graph int16) genuinely saturates and the wider-wire
    redispatch carries the batch."""

    @pytest.mark.parametrize("mode", ["alltoall", "pipeline", "adaptive", "ring"])
    @pytest.mark.parametrize("wire", WIRES)
    def test_wire_parity(self, mode, wire):
        g = _skewed_graph()
        tree = spider_tree([2, 1])
        rng = np.random.default_rng(0)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        want = count_colorful_maps(g, tree, coloring)
        wide = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode=mode)
        narrow = Counter.from_graph(
            g,
            tree,
            backend="distributed",
            num_shards=1,
            mode=mode,
            wire_dtype=wire,
        )
        d = wide.count_coloring(coloring)
        c = narrow.count_coloring(coloring)
        assert d == c  # bit-exact between wires
        assert c == pytest.approx(want, rel=1e-6)

    @pytest.mark.parametrize("wire", WIRES)
    def test_compact_narrow_parity(self, wire):
        g = _skewed_graph()
        tree = spider_tree([2, 1])
        rng = np.random.default_rng(1)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        wide = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode="pipeline")
        narrow = Counter.from_graph(
            g,
            tree,
            backend="distributed",
            num_shards=1,
            mode="pipeline",
            wire_dtype=wire,
            compact=True,
            density_threshold=0.9,
        )
        assert narrow.plan.compaction is not None
        assert wide.count_coloring(coloring) == narrow.count_coloring(coloring)

    def test_dense_graph_saturates_and_escalates(self):
        # avg degree 20: DP table entries far exceed 127, so the int8 wire
        # saturates for real (no fault injection) and the ladder redispatch
        # must deliver the wide answer bit for bit
        g = erdos_renyi(128, 20.0, seed=3)
        tree = path_tree(4)
        rng = np.random.default_rng(2)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        wide = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode="alltoall")
        n8 = Counter.from_graph(
            g,
            tree,
            backend="distributed",
            num_shards=1,
            mode="alltoall",
            wire_dtype="int8",
        )
        assert wide.count_coloring(coloring) == n8.count_coloring(coloring)

    def test_keyed_estimate_samples_identical(self):
        g = _skewed_graph()
        tree = path_tree(4)
        wide = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode="ring")
        narrow = Counter.from_graph(
            g,
            tree,
            backend="distributed",
            num_shards=1,
            mode="ring",
            wire_dtype="int16",
        )
        key = jax.random.key(6)
        rd = wide.estimate(n_iter=6, key=key, batch=3)
        rc = narrow.estimate(n_iter=6, key=key, batch=3)
        assert np.array_equal(rd.samples, rc.samples)

    def test_forced_saturation_storm(self):
        """The ``compression.saturate`` site forces the redispatch even when
        no slab saturated; two consecutive storms walk int8 -> int16 ->
        float32 and the counts never change."""
        g = _skewed_graph()
        tree = spider_tree([2, 1])
        rng = np.random.default_rng(5)
        coloring = rng.integers(0, tree.n, g.n).astype(np.int32)
        wide = Counter.from_graph(g, tree, backend="distributed", num_shards=1, mode="pipeline")
        n8 = Counter.from_graph(
            g,
            tree,
            backend="distributed",
            num_shards=1,
            mode="pipeline",
            wire_dtype="int8",
        )
        want = wide.count_coloring(coloring)
        with faults.active(faults.inject("compression.saturate", at=(0, 1))) as fp:
            got = n8.count_coloring(coloring)
        assert got == want
        fired = [s for s, _ in fp.fired]
        assert fired.count("compression.saturate") == 2


class TestPlanOpts:
    def test_api_accepts_wire_opts(self):
        g = _skewed_graph(256, 800, seed=5)
        c = Counter.from_graph(
            g,
            path_tree(3),
            backend="distributed",
            num_shards=1,
            wire_dtype="int16",
            adaptive="measured",
        )
        assert c.plan_opts["wire_dtype"] == "int16"
        assert c.plan_opts["adaptive"] == "measured"

    def test_with_options_swaps_wire(self):
        g = _skewed_graph(256, 800, seed=5)
        c = Counter.from_graph(
            g,
            path_tree(3),
            backend="distributed",
            num_shards=1,
            mode="pipeline",
        )
        rng = np.random.default_rng(3)
        coloring = rng.integers(0, 3, g.n).astype(np.int32)
        want = c.count_coloring(coloring)
        c16 = c.with_options(wire_dtype="int16")
        assert c16._plan is c._plan  # the built plan is shared
        assert c16.count_coloring(coloring) == want

    def test_invalid_wire_dtype_rejected(self):
        from repro.core.distributed import make_count_fn

        g = _skewed_graph(256, 800, seed=5)
        c = Counter.from_graph(g, path_tree(3), backend="distributed", num_shards=1)
        mesh = make_mesh((1,), ("data",))
        with pytest.raises(ValueError, match="wire_dtype"):
            make_count_fn(c.plan, mesh, wire_dtype="int4")
        with pytest.raises(ValueError, match="adaptive"):
            make_count_fn(c.plan, mesh, adaptive="oracle")
