"""Train substrate: optimizer math, checkpoint atomicity/elasticity,
data determinism, end-to-end loss decrease on a tiny model."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.train import (
    AdamWConfig,
    CheckpointManager,
    DataConfig,
    TrainConfig,
    adamw_update,
    init_opt_state,
    make_train_step,
    synthetic_batch,
    train,
)
from repro.train.optimizer import cosine_schedule, opt_state_pspecs
from jax.sharding import PartitionSpec as P


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        cfg = AdamWConfig(lr_peak=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(params)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
        assert float(cosine_schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(cosine_schedule(cfg, jnp.asarray(10))) == pytest.approx(1e-3)
        assert float(cosine_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.0, abs=1e-9)

    def test_clip(self):
        from repro.train.optimizer import clip_by_global_norm

        g = {"a": jnp.full((4,), 10.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert float(gn) == pytest.approx(20.0)
        norm = float(jnp.linalg.norm(clipped["a"]))
        assert norm == pytest.approx(1.0, rel=1e-5)

    def test_zero1_specs_divisibility(self):
        specs = {"w": P(None, "model"), "s": P()}
        shapes = {
            "w": jax.ShapeDtypeStruct((24, 8), jnp.float32),
            "s": jax.ShapeDtypeStruct((), jnp.float32),
        }
        out = opt_state_pspecs(specs, shapes, zero1=True, data_size=16)
        # 24 % 16 != 0 -> stays unsharded on dim0
        assert out["m"]["w"] == P(None, "model")
        shapes2 = {"w": jax.ShapeDtypeStruct((32, 8), jnp.float32), "s": shapes["s"]}
        out2 = opt_state_pspecs(specs, shapes2, zero1=True, data_size=16)
        assert out2["m"]["w"] == P("data", "model")


class TestData:
    def test_deterministic_and_step_dependent(self):
        cfg = DataConfig(vocab_size=1000, global_batch=4, seq_len=16, seed=7)
        a = synthetic_batch(cfg, 3)["tokens"]
        b = synthetic_batch(cfg, 3)["tokens"]
        c = synthetic_batch(cfg, 4)["tokens"]
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, c)
        assert int(a.max()) < 1000 and int(a.min()) >= 0


class TestCheckpoint:
    def test_roundtrip_and_atomicity(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
        mgr.save(10, {"params": tree})
        mgr.save(20, {"params": jax.tree.map(lambda x: x * 2, tree)})
        assert mgr.all_steps() == [10, 20]
        out = mgr.restore(20, {"params": tree})
        np.testing.assert_allclose(out["params"]["a"], np.arange(6.0).reshape(2, 3) * 2)

    def test_gc_keeps_last(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        tree = {"a": jnp.ones((2,))}
        for s in (1, 2, 3, 4):
            mgr.save(s, {"params": tree})
        assert mgr.all_steps() == [3, 4]

    def test_checksum_detects_corruption(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        tree = {"a": jnp.ones((8,))}
        mgr.save(1, {"params": tree})
        path = os.path.join(str(tmp_path), "step_00000001", "params.npz")
        with open(path, "r+b") as f:
            f.seek(-1, 2)
            last = f.read(1)
            f.seek(-1, 2)
            f.write(bytes([last[0] ^ 0xFF]))  # guaranteed bit flip
        with pytest.raises(IOError):
            mgr.restore(1, {"params": tree})

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, {"params": {"a": jnp.ones((8,))}})
        with pytest.raises(ValueError):
            mgr.restore(1, {"params": {"a": jnp.ones((4,))}})


class TestEndToEnd:
    def test_loss_decreases_and_resume(self, tmp_path):
        cfg = get_arch("smollm-360m").reduced()
        model = build_model(cfg)
        tcfg = TrainConfig(
            steps=12,
            opt=AdamWConfig(lr_peak=3e-3, warmup_steps=2, total_steps=12),
            checkpoint_dir=str(tmp_path),
            checkpoint_every=6,
            log_every=100,
        )
        out = train(model, tcfg, log=lambda s: None)
        # restart resumes from the step-12 checkpoint and trains 6 more steps
        import dataclasses

        tcfg2 = dataclasses.replace(tcfg, steps=18)
        out2 = train(model, tcfg2, log=lambda s: None)
        assert np.isfinite(float(out["metrics"]["loss"]))
        assert np.isfinite(float(out2["metrics"]["loss"]))

    def test_microbatch_equivalence(self):
        cfg = get_arch("qwen1.5-0.5b").reduced()
        model = build_model(cfg)
        params = jax.jit(model.init_fn)(jax.random.key(0))
        opt = init_opt_state(params)
        batch = {
            "tokens": jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 16)), jnp.int32
            )
        }
        s1, _ = make_train_step(model, TrainConfig(microbatches=1))
        s2, _ = make_train_step(model, TrainConfig(microbatches=2))
        p1, _, m1 = s1(jax.tree.map(jnp.copy, params), jax.tree.map(jnp.copy, opt), batch)
        p2, _, m2 = s2(jax.tree.map(jnp.copy, params), jax.tree.map(jnp.copy, opt), batch)
        # losses are means over the same tokens; averaged grads ~ equal
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
        d = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
            p1,
            p2,
        )
        assert max(jax.tree.leaves(d)) < 5e-3
