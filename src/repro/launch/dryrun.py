import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The first two lines above MUST precede any other import (jax locks the
device count at first init).  For each cell this lowers the jitted
train_step / prefill / decode function against ShapeDtypeStruct inputs with
production shardings, compiles it, and records:

  * ``compiled.memory_analysis()``  (bytes per device — proves it fits)
  * ``compiled.cost_analysis()``    (per-device FLOPs / bytes for §Roofline)
  * collective ops parsed from the post-SPMD HLO (bytes for the
    collective roofline term)

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
  python -m repro.launch.dryrun --counting twitter-u12-2 [--multi-pod]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, COUNTING_CONFIGS, get_arch  # noqa: E402
from repro.configs.base import SHAPES, ShardingConfig  # noqa: E402
from repro.launch.mesh import make_mesh, make_production_mesh  # noqa: E402

FSDP_THRESHOLD = 2e9  # params above this get ZeRO-3 weight sharding


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Per-device bytes moved, by collective kind (ring-algorithm model)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if kind.endswith("-done"):
            continue
        size = _shape_bytes(dtype, dims)
        # group size from the first replica group on this line
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start() : line_end if line_end > 0 else m.end() + 512]
        gm = _GROUPS_RE.search(line)
        n = len(gm.group(1).split(",")) if gm else 2
        if kind == "all-gather":
            moved = size * (n - 1) / max(n, 1)  # result size x (n-1)/n
        elif kind == "all-reduce":
            moved = 2 * size * (n - 1) / max(n, 1)
        elif kind == "reduce-scatter":
            moved = size * (n - 1)  # result is the shard
        elif kind == "all-to-all":
            moved = size * (n - 1) / max(n, 1)
        else:  # collective-permute
            moved = size
        out[kind] += moved
        counts[kind] += 1
    out["ops"] = counts
    return out


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


def sharding_for(arch_name: str, shape_name: str, multi_pod: bool) -> ShardingConfig:
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    dp_size = (2 * 16 if multi_pod else 16)
    if shape.global_batch < dp_size:
        dp_axes = ()  # long_500k b=1: no batch sharding
    return ShardingConfig(
        batch_axes=dp_axes,
        fsdp=cfg.params_count() >= FSDP_THRESHOLD,
        remat="full" if shape.kind == "train" else "none",
        # sequence parallelism: shard scan-carry activations over the model
        # axis during training (remat carries dominate HBM otherwise)
        seq_axis="model" if shape.kind == "train" else None,
        # hillclimb knobs (env overrides, see EXPERIMENTS.md §Perf)
        sp_dim=int(os.environ.get("DRYRUN_SP_DIM", "1")),
        moe_pipeline=os.environ.get("DRYRUN_MOE_PIPELINE", "") == "1",
        attn_chunk=int(os.environ.get("DRYRUN_ATTN_CHUNK", "1024")),
    )


def skip_reason(arch_name: str, shape_name: str):
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return "long_500k skipped: pure full-attention arch (see DESIGN.md §5)"
    if shape.kind == "decode" and cfg.family == "audio" and shape_name == "long_500k":
        return "long_500k skipped: enc-dec audio arch"
    return None


def lower_cell(
    arch_name: str,
    shape_name: str,
    multi_pod: bool,
    *,
    depth_groups: int = 0,  # probe: override depth to N pattern periods
    unroll: bool = False,
):
    """Returns (lowered, mesh, meta) for one cell."""
    import dataclasses as _dc

    from repro.models import build_model
    from repro.train import AdamWConfig, TrainConfig, make_train_step

    cfg = get_arch(arch_name)
    if depth_groups:
        cfg = _dc.replace(cfg, num_layers=depth_groups * len(cfg.block_pattern))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    sh = sharding_for(arch_name, shape_name, multi_pod)
    model = build_model(cfg, sh, mesh, unroll=unroll)
    params_shapes = jax.eval_shape(model.init_fn, jax.random.key(0))
    pspecs = model.param_specs(params_shapes)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    structs, in_specs = model.input_specs(shape)
    in_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, s), in_specs,
        is_leaf=lambda x: isinstance(x, P),
    )

    if shape.kind == "train":
        from repro.train.optimizer import init_opt_state, opt_state_pspecs

        tcfg = TrainConfig(
            opt=AdamWConfig(),
            microbatches=int(os.environ.get("DRYRUN_MICROBATCHES", "1")),
        )
        step_raw, _ = make_train_step(model, tcfg, jit=False)
        opt_shapes = jax.eval_shape(init_opt_state, params_shapes)
        data_size = 16
        ospecs = opt_state_pspecs(pspecs, params_shapes, zero1=sh.zero1, data_size=data_size)
        oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs)
        fn = jax.jit(
            step_raw,
            in_shardings=(pshard, oshard, in_shard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = fn.lower(params_shapes, opt_shapes, structs)
    elif shape.kind == "prefill":
        fn = jax.jit(model.prefill_fn, in_shardings=(pshard, in_shard))
        with mesh:
            lowered = fn.lower(params_shapes, structs)
    else:  # decode
        fn = jax.jit(model.decode_fn, in_shardings=(pshard, in_shard))
        with mesh:
            lowered = fn.lower(params_shapes, structs)
    meta = {
        "arch": arch_name,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "params": cfg.params_count(),
        "active_params": cfg.active_params_count(),
        "fsdp": sh.fsdp,
        "global_batch": shape.global_batch,
        "seq_len": shape.seq_len,
    }
    return lowered, mesh, meta


def _cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` returns a per-device list on some JAX
    releases and a plain dict on others; normalize to one dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _measure(lowered) -> dict:
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled)
    coll = parse_collectives(compiled.as_text())
    return {
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "cost": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
        },
        "collectives": coll,
    }


def _corrected(real: dict, p1: dict, p2: dict, n_full: int) -> dict:
    """XLA's cost analysis counts a scan body ONCE regardless of trip count.

    Unrolled probes at depths 1 and 2 pattern-periods give the true
    per-group cost (body = p2 - p1); the real cell already counts the body
    once, so the correction adds (n_full - 1) bodies to flops/bytes and to
    each collective class.
    """
    extra = max(n_full - 1, 0)
    out = {"cost": {}, "collectives": {}}
    for k in ("flops", "bytes_accessed", "transcendentals"):
        body = max(p2["cost"][k] - p1["cost"][k], 0.0)
        out["cost"][k] = real["cost"][k] + extra * body
    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"):
        body = max(p2["collectives"][k] - p1["collectives"][k], 0.0)
        out["collectives"][k] = real["collectives"][k] + extra * body
    out["collectives"]["ops"] = real["collectives"]["ops"]
    return out


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, out_dir=None, probes: bool = True):
    reason = skip_reason(arch_name, shape_name)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    if reason:
        rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
               "status": "skipped", "reason": reason}
        _emit(rec, out_dir)
        return rec
    t0 = time.time()
    try:
        from repro.models.transformer import layer_plan

        lowered, mesh, meta = lower_cell(arch_name, shape_name, multi_pod)
        t_lower = time.time() - t0
        real = _measure(lowered)
        t_compile = time.time() - t0 - t_lower
        cfg = get_arch(arch_name)
        n_full = layer_plan(cfg)[0]
        rec = dict(meta, status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), **real)
        rec["cost_raw"] = dict(real["cost"])
        if probes and n_full > 1:
            p1, _, _ = lower_cell(arch_name, shape_name, multi_pod, depth_groups=1, unroll=True)
            p2, _, _ = lower_cell(arch_name, shape_name, multi_pod, depth_groups=2, unroll=True)
            m1, m2 = _measure(p1), _measure(p2)
            corr = _corrected(real, m1, m2, n_full)
            rec["cost"] = corr["cost"]
            rec["collectives"] = corr["collectives"]
            rec["probe"] = {"n_full": n_full,
                            "body_flops": m2["cost"]["flops"] - m1["cost"]["flops"]}
            rec["probe_s"] = round(time.time() - t0 - t_lower - t_compile, 1)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec = {
            "arch": arch_name,
            "shape": shape_name,
            "mesh": mesh_tag,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }
    _emit(rec, out_dir)
    return rec


def _compaction_report(plan, mode: str, wire_dtype: str = "float32"):
    """Per-node density / capacity / bytes-saved cells for a counting plan
    with active-frontier compaction (DESIGN.md §15); None when dense."""
    spec = plan.compaction
    if spec is None:
        return None
    from repro.core.frontier import node_exchange_bytes

    per_node = {}
    bytes_dense = bytes_compact = 0
    for i, nd in enumerate(plan.program.nodes):
        if nd.is_leaf:
            continue
        nb_dense, nb_compact = node_exchange_bytes(plan, i, mode, wire_dtype=wire_dtype)
        caps = spec.shard_caps if mode == "ring" else spec.exchange_caps
        bytes_dense += nb_dense
        bytes_compact += nb_compact
        per_node[str(i)] = {
            "size": nd.size,
            "density": round(spec.density.get(i, 1.0), 4),
            "exchange_cap": caps.get(nd.right),
            "combine_cap": spec.combine_caps.get(i),
        }
    return {
        "threshold": spec.threshold,
        "capacity_factor": spec.capacity_factor,
        "per_node": per_node,
        "exchange_bytes_dense": bytes_dense,
        "exchange_bytes_compact": bytes_compact,
        "exchange_bytes_saved_frac": round(1.0 - bytes_compact / max(bytes_dense, 1), 4),
    }


def run_counting_cell(name: str, multi_pod: bool, out_dir=None, mode=None):
    """Dry-run the distributed counting engine at paper-scale shapes."""
    from repro.core.distributed import (
        abstract_plan,
        make_count_fn,
        plan_route_report,
    )
    from repro.core.templates import template

    ccfg = COUNTING_CONFIGS[name]
    mode = mode or ccfg.mode
    chips = 512 if multi_pod else 256
    if ccfg.mesh_kind == "flat":
        # graph over ALL chips; O(1)-HLO relay ring (beyond-paper mode)
        mesh = make_mesh((chips,), ("data",))
        num_shards = chips
        iter_axis = None
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        num_shards = ccfg.num_shards
        iter_axis = ("pod", "model") if multi_pod else "model"
    mesh_tag = ("flat" if ccfg.mesh_kind == "flat" else "") + ("2x16x16" if multi_pod else "16x16")
    # a family row lowers the multi-template shared-DAG counter
    tmpl = (
        [template(t) for t in ccfg.templates]
        if ccfg.templates
        else template(ccfg.template)
    )
    t0 = time.time()
    try:
        plan = abstract_plan(
            ccfg.num_vertices,
            ccfg.num_edges,
            tmpl,
            num_shards,
            compact_requests=mode != "ring",
            compact=ccfg.compact,
            density_threshold=ccfg.density_threshold,
            capacity_factor=ccfg.capacity_factor,
        )
        fn, structs, in_shard = make_count_fn(
            plan,
            mesh,
            mode=mode,
            iter_axis=iter_axis,
            group_factor=ccfg.group_factor,
            wire_dtype=ccfg.wire_dtype,
            return_raw=True,
        )
        with mesh:
            lowered = fn.lower(*structs)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        coll = parse_collectives(compiled.as_text())
        from repro.kernels import ops as kops

        rec = {
            "arch": f"counting:{name}",
            "shape": "+".join(ccfg.templates) if ccfg.templates else ccfg.template,
            "mesh": mesh_tag,
            "mode": mode, "status": "ok",
            "chips": chips,
            "num_templates": max(len(ccfg.templates), 1),
            # the spmm_kind="auto" signal at this cell's shape (a real plan
            # measures it; shape-only cells carry the placement model)
            "spmm_auto_density_model": round(
                kops.expected_patch_density(
                    ccfg.num_vertices, 2 * ccfg.num_edges
                ), 2,
            ),
            "compaction": _compaction_report(plan, mode, ccfg.wire_dtype),
            # §18 exchange routing at this cell's shape (model costs; a
            # shape-only cell never runs the measured calibration probe)
            "routing": plan_route_report(
                plan, mode=mode, group_factor=ccfg.group_factor,
                wire_dtype=ccfg.wire_dtype,
            ),
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
            },
            "cost": {
                "flops": cost.get("flops", 0.0),
                "bytes_accessed": cost.get("bytes accessed", 0.0),
            },
            "collectives": coll,
        }
    except Exception as e:  # noqa: BLE001
        rec = {"arch": f"counting:{name}", "mesh": mesh_tag, "mode": mode,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    _emit(rec, out_dir)
    return rec


def _emit(rec, out_dir):
    line = json.dumps(rec)
    print(line, flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{rec['arch'].replace(':', '_')}_{rec.get('shape', 'x')}_{rec['mesh']}"
        if rec.get("mode"):
            tag += f"_{rec['mode']}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            f.write(line)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--counting")
    ap.add_argument("--counting-mode")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.counting:
        run_counting_cell(args.counting, args.multi_pod, args.out, args.counting_mode)
        return
    if args.all:
        ok = err = skip = 0
        for arch in sorted(ARCHS):
            for shape in SHAPES:
                rec = run_cell(arch, shape, args.multi_pod, args.out)
                s = rec["status"]
                ok += s == "ok"
                err += s == "error"
                skip += s == "skipped"
        print(f"# dry-run summary: {ok} ok, {skip} skipped, {err} errors", flush=True)
        raise SystemExit(1 if err else 0)
    run_cell(args.arch, args.shape, args.multi_pod, args.out)


if __name__ == "__main__":
    main()
