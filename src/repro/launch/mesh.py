"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; ``dryrun.py`` sets the 512-host-device XLA flag
before importing anything.
"""

from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_mesh", "make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests/examples)."""
    return make_mesh((data, model), ("data", "model"))
