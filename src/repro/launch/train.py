"""Training launcher: ``python -m repro.launch.train --arch <id> [options]``.

Local run (CPU container / small meshes): trains the reduced or published
config with the fault-tolerant loop (checkpoint/restart, preemption hook).
On a real multi-host pod this same entry point runs under the usual
``jax.distributed.initialize()`` bootstrap (one process per host), with the
production mesh from ``repro.launch.mesh``.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.configs.base import ShardingConfig
from repro.models import build_model
from repro.train import AdamWConfig, TrainConfig, train
from .mesh import make_local_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--full", action="store_true", help="published config")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--data", type=int, default=1, help="local mesh data axis")
    ap.add_argument("--model", type=int, default=1, help="local mesh model axis")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() first (multi-host)")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        sharding = ShardingConfig(
            batch_axes=("pod", "data") if args.multi_pod else ("data",),
            fsdp=cfg.params_count() >= 2e9,
            seq_axis="model",
        )
    elif args.data * args.model > 1:
        mesh = make_local_mesh(args.data, args.model)
        sharding = ShardingConfig(batch_axes=("data",))
    else:
        mesh, sharding = None, None

    model = build_model(cfg, sharding, mesh)
    tcfg = TrainConfig(
        steps=args.steps,
        microbatches=args.microbatches,
        opt=AdamWConfig(total_steps=args.steps),
        checkpoint_dir=args.ckpt_dir,
    )
    train(model, tcfg, mesh)


if __name__ == "__main__":
    main()
