"""Subgraph-counting launcher: the paper's workload end to end.

``python -m repro.launch.count --config bench-small --mode adaptive``

Synthesizes the configured RMAT graph, builds the distributed plan over the
locally available devices (or 1), runs N coloring iterations through the
selected communication mode and prints the (eps, delta) estimate.

With one shard (``mode=single`` or a single device) the launcher skips
shard_map entirely and drives the single-device engine's batched fused
pipeline: ``count_fn(plan, batch=B)`` evaluates B colorings per jit call
(``--batch``), with ``--fuse`` routing every internal node through the
fused SpMM->combine kernel and ``--spmm-kind`` selecting the SpMM plan
(``auto`` adapts edges/blocks to measured patch density).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import COUNTING_CONFIGS
from repro.core import build_counting_plan, count_fn, relabel_random, rmat
from repro.core.distributed import build_distributed_plan, make_count_fn, shard_coloring
from repro.core.estimator import median_of_means
from repro.core.templates import template
from repro.launch.mesh import make_mesh


def _report(mode, shards, iters, dt, ests):
    print(f"mode={mode} shards={shards}: {iters} colorings in {dt:.2f}s "
          f"({dt / max(iters, 1) * 1e3:.1f} ms/coloring)")
    print(f"estimate (median-of-means): {median_of_means(ests, 4):.6g}")
    print(f"estimate (mean)           : {ests.mean():.6g}  "
          f"RSD {ests.std() / max(ests.mean(), 1e-12):.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="bench-small", choices=sorted(COUNTING_CONFIGS))
    ap.add_argument("--mode", default=None,
                    choices=[None, "alltoall", "pipeline", "adaptive", "ring",
                             "single"])
    ap.add_argument("--iters", type=int, default=16)
    ap.add_argument("--group-factor", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8,
                    help="colorings per jit call on the single-device path")
    ap.add_argument("--fuse", action="store_true",
                    help="fused SpMM->combine (never materializes M)")
    ap.add_argument("--spmm-kind", default="auto",
                    choices=["auto", "edges", "blocks"])
    args = ap.parse_args()

    ccfg = COUNTING_CONFIGS[args.config]
    shards = min(ccfg.num_shards, jax.device_count())
    tree = template(ccfg.template)
    print(f"synthesizing RMAT: V={ccfg.num_vertices} E={ccfg.num_edges} "
          f"skew={ccfg.skew}")
    g = relabel_random(
        rmat(ccfg.num_vertices, ccfg.num_edges, skew=ccfg.skew, seed=0), seed=1
    )

    # explicit distributed modes still run through shard_map on one device
    # (a cheap smoke of those code paths); only mode=single or the default
    # on a single-device host takes the batched single-device engine
    if args.mode == "single" or (args.mode is None and shards == 1):
        if args.batch < 1:
            ap.error(f"--batch must be >= 1 (got {args.batch})")
        # a block-dense plan has no edge slabs, so fused_count would fall
        # back to the unfused path: when fusing, steer 'auto' to 'edges'
        spmm_kind = args.spmm_kind
        if args.fuse and spmm_kind == "auto":
            spmm_kind = "edges"
        plan = build_counting_plan(g, tree, spmm_kind=spmm_kind, fuse=args.fuse)
        fused = args.fuse and plan.spmm_plan.slab_dst is not None
        f = count_fn(plan, batch=args.batch)
        # hand-rolled sampling loop rather than estimator.estimate_counts:
        # this is a perf launcher, so compile must stay outside the timer,
        # which needs the count_fn warm-started and reused across calls
        n_calls = -(-args.iters // args.batch)
        keys = jax.random.split(jax.random.key(0), n_calls)
        f(keys[0])[0].block_until_ready()  # compile outside the timer
        t0 = time.perf_counter()
        ests = np.concatenate(
            [np.asarray(f(k)[1], np.float64) for k in keys]
        )
        dt = time.perf_counter() - t0
        # the timer covers n_calls * batch colorings (the last call may
        # overshoot --iters); report per-coloring time on what actually ran
        _report(f"single(batch={args.batch},fuse={fused},"
                f"spmm={plan.spmm_plan.kind})", 1,
                n_calls * args.batch, dt, ests[: args.iters])
        return

    mesh = make_mesh((shards,), ("data",))
    plan = build_distributed_plan(g, tree, shards)
    mode = args.mode or ccfg.mode
    f = make_count_fn(plan, mesh, mode=mode, group_factor=args.group_factor)

    rng = np.random.default_rng(0)
    cols = np.stack([
        shard_coloring(plan, rng.integers(0, tree.n, g.n).astype(np.int32))
        for _ in range(args.iters)
    ])
    t0 = time.perf_counter()
    counts = np.asarray(f(jnp.asarray(cols)))
    dt = time.perf_counter() - t0
    ests = counts * plan.scale
    _report(mode, shards, args.iters, dt, ests)


if __name__ == "__main__":
    main()
