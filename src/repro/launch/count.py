"""Subgraph-counting launcher: the paper's workload end to end.

``python -m repro.launch.count --config bench-small --mode adaptive``

Synthesizes the configured RMAT graph, builds the distributed plan over the
locally available devices (or 1), runs N coloring iterations through the
selected communication mode and prints the (eps, delta) estimate.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import COUNTING_CONFIGS
from repro.core import relabel_random, rmat
from repro.core.distributed import build_distributed_plan, make_count_fn, shard_coloring
from repro.core.estimator import median_of_means
from repro.core.templates import template


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="bench-small", choices=sorted(COUNTING_CONFIGS))
    ap.add_argument("--mode", default=None,
                    choices=[None, "alltoall", "pipeline", "adaptive", "ring"])
    ap.add_argument("--iters", type=int, default=16)
    ap.add_argument("--group-factor", type=int, default=1)
    args = ap.parse_args()

    ccfg = COUNTING_CONFIGS[args.config]
    shards = min(ccfg.num_shards, jax.device_count())
    mesh = jax.make_mesh((shards,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    tree = template(ccfg.template)
    print(f"synthesizing RMAT: V={ccfg.num_vertices} E={ccfg.num_edges} "
          f"skew={ccfg.skew}")
    g = relabel_random(
        rmat(ccfg.num_vertices, ccfg.num_edges, skew=ccfg.skew, seed=0), seed=1
    )
    plan = build_distributed_plan(g, tree, shards)
    mode = args.mode or ccfg.mode
    f = make_count_fn(plan, mesh, mode=mode, group_factor=args.group_factor)

    rng = np.random.default_rng(0)
    cols = np.stack([
        shard_coloring(plan, rng.integers(0, tree.n, g.n).astype(np.int32))
        for _ in range(args.iters)
    ])
    t0 = time.perf_counter()
    counts = np.asarray(f(jnp.asarray(cols)))
    dt = time.perf_counter() - t0
    ests = counts * plan.scale
    print(f"mode={mode} shards={shards}: {args.iters} colorings in {dt:.2f}s")
    print(f"estimate (median-of-means): {median_of_means(ests, 4):.6g}")
    print(f"estimate (mean)           : {ests.mean():.6g}  RSD {ests.std()/max(ests.mean(),1e-12):.2f}")


if __name__ == "__main__":
    main()
