"""Subgraph-counting launcher: the paper's workload end to end.

``python -m repro.launch.count --config bench-small --mode adaptive``

Resolves the configured graph (synthesized RMAT, or a real dataset via
``--graph edges.txt|graph.npz``) into a ``repro.api.CountRequest`` and runs
it through the unified ``Counter`` facade: the same key-based contract,
on-device coloring sampling, and (eps, delta) estimator on BOTH backends.

``--mode single`` (or the default on a single-device host) drives the
in-core batched/fused engine (``--batch``/``--fuse``/``--spmm-kind``);
any other mode drives the shard_map engine with that exchange schedule.
``--templates u3-1,u5-2,u7-2`` (or a config row with a ``templates``
family) counts the whole family in ONE pass per coloring over the shared
subtree DAG (``Counter.estimate_many``) and reports per-template
estimates plus the unique-table reuse the compiled DAG achieved.
Either way the report comes from one place — the shared estimator — so the
median-of-means (over ``log(1/delta)`` groups), mean, and RSD are computed
identically no matter where the counting ran.  Compilation is warmed
outside the timer via ``counter.sample_fn``, so the printed wall-clock is
pure counting.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.api import Counter
from repro.configs import COUNTING_CONFIGS
from repro.core import load_edge_file, load_npz
from repro.core.estimator import num_groups_for
from repro.core.templates import TEMPLATES


def _plan_report(plan):
    """Surface the density signals the plan's adaptive choices used: the
    spmm auto patch density and the per-node table densities / capacities
    of active-frontier compaction (§15)."""
    spmm = getattr(plan, "spmm_plan", None)
    if spmm is not None and spmm.patch_density is not None:
        print(f"spmm auto: {spmm.patch_density:.1f} edges/patch "
              f"-> kind={spmm.kind}")
    spec = getattr(plan, "compaction", None)
    if spec is None:
        return
    dens = " ".join(f"n{i}={spec.density[i]:.3f}" for i in sorted(spec.density))
    caps = {}
    for tag, m in (("combine", spec.combine_caps),
                   ("table", spec.table_caps),
                   ("exchange", spec.exchange_caps),
                   ("ring", spec.shard_caps)):
        for i, c in sorted(m.items()):
            caps[f"{tag}[{i}]"] = c
    print(f"compaction: threshold {spec.threshold} node densities: {dens}")
    print(f"compaction caps: {caps if caps else 'none engaged'}")


def _route_report(counter, request):
    """Exchange-routing provenance (§18): per-node schedule choices and
    the cost model behind them — calibrated when --adaptive measured."""
    from repro.core.distributed import plan_route_report

    opts = request.plan_opts
    rep = plan_route_report(
        counter.plan,
        mode=opts.get("mode", "adaptive"),
        group_factor=opts.get("group_factor", 1),
        wire_dtype=opts.get("wire_dtype", "float32"),
        adaptive=opts.get("adaptive", "model"),
        mesh=counter._mesh,
        data_axis=opts.get("data_axis", "data"),
    )
    m = rep["model"]
    src = "calibrated" if rep["calibrated"] else "assumed"
    print(f"routing: wire={rep['wire_dtype']} {src} model "
          f"alpha={m['alpha']:.3g}s beta={m['beta']:.3g}s/B "
          f"flops={m['flops_per_s']:.3g}/s")
    for i, row in sorted(rep["per_node"].items()):
        print(f"  node {i}: {row['mode']:<8} "
              f"a2a {row['a2a_bytes'] / 1e6:.3f} MB "
              f"ring {row['ring_bytes'] / 1e6:.3f} MB "
              f"predicted {row['predicted_s'] * 1e6:.1f} us")


def _robust_report(res):
    """Recovery provenance: what was restored, what was given up on."""
    if res.resumed_from:
        print(f"resumed: {res.resumed_from} colorings restored from "
              f"checkpoint (progress/RSD include them)")
    for q in res.quarantined:
        print(f"quarantined: {q}")


def _report(label, shards, res, dt, ran):
    # the timer covers every coloring that actually executed (the last
    # batched dispatch may overshoot --iters); the statistics use --iters
    print(f"mode={label} shards={shards}: {ran} colorings in {dt:.2f}s "
          f"({dt / max(ran, 1) * 1e3:.1f} ms/coloring)")
    groups = num_groups_for(res.delta, res.niter)
    print(f"estimate (median-of-means, {groups} groups): {res.estimate:.6g}")
    print(f"estimate (mean)           : {res.mean:.6g}  RSD {res.relative_sd:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="bench-small", choices=sorted(COUNTING_CONFIGS))
    ap.add_argument("--graph", default=None, metavar="PATH",
                    help="real dataset (.npz from save_npz, else an edge-list "
                         "text file); default: synthesize the config's RMAT")
    # default None means "unset": pick the backend from the device count
    # and the exchange schedule from the config row
    ap.add_argument("--mode", default=None,
                    choices=["alltoall", "pipeline", "adaptive", "ring",
                             "single"])
    ap.add_argument("--templates", default=None, metavar="A,B,C",
                    help="comma-separated template family (trees AND "
                         "treewidth-2 names like cycle5,diamond): count them "
                         "all in ONE pass over the shared subtree DAG "
                         "(Counter.estimate_many); names are validated "
                         "against the registry; default: the config's "
                         "family, else its single template")
    ap.add_argument("--iters", type=int, default=16)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--group-factor", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8,
                    help="colorings per jit dispatch (both backends)")
    ap.add_argument("--fuse", action="store_true",
                    help="fused SpMM->combine: never materializes the "
                         "neighbor sum M (both backends)")
    ap.add_argument("--impl", default=None, choices=["auto", "xla", "pallas"],
                    help="kernel routing (both backends; default: "
                         "backend-appropriate)")
    ap.add_argument("--spmm-kind", default="auto", choices=["auto", "edges", "blocks"])
    ap.add_argument("--bucket-tile", type=int, default=128,
                    help="distributed §3.3 task size: edges per bucket tile")
    ap.add_argument("--compact", action="store_true", default=None,
                    help="active-frontier compaction (§15): probe per-node "
                         "table densities and compact tables/exchange below "
                         "--density-threshold (both backends)")
    ap.add_argument("--density-threshold", type=float, default=None,
                    help="compact a node once its active-row fraction is at "
                         "or below this (default: config row's)")
    ap.add_argument("--capacity-factor", type=float, default=None,
                    help="capacity headroom over the probed active maximum "
                         "before the dense overflow fallback")
    ap.add_argument("--wire-dtype", default=None,
                    choices=["float32", "int16", "int8"],
                    help="narrow-wire exchange (§18): ship distributed "
                         "exchange slabs at this width, exactness kept by "
                         "saturation checking + wider-wire redispatch")
    ap.add_argument("--adaptive", default=None,
                    choices=["model", "measured"],
                    help="adaptive router cost model: assumed link constants "
                         "or a one-shot calibration probe at plan build")
    # robustness (DESIGN.md §16): estimator state survives kills and flaky
    # shards; a killed run resumed via --resume returns the bit-identical
    # estimate an uninterrupted run produces
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="persist estimator state (atomic, checksummed) "
                         "under DIR every --checkpoint-every colorings")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume from the latest readable checkpoint in DIR "
                         "(implies --checkpoint-dir DIR); bit-exact vs an "
                         "uninterrupted run with the same seed")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint cadence in colorings (default: every "
                         "batch when a checkpoint dir is set)")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="supervise the sample pipeline: retry transient "
                         "per-batch faults up to N times with backoff, then "
                         "quarantine the batch and report it")
    ap.add_argument("--target-rsd", type=float, default=None,
                    help="stop early once the running relative standard "
                         "error of the mean reaches this (resume-aware)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.batch < 1:
        ap.error(f"--batch must be >= 1 (got {args.batch})")
    ckpt_dir = args.resume or args.checkpoint_dir
    ckpt_every = args.checkpoint_every or (args.batch if ckpt_dir else 0)
    robust_kw = dict(
        checkpoint=ckpt_dir,
        checkpoint_every=ckpt_every,
        resume=bool(args.resume),
        max_retries=args.max_retries,
        target_rsd=args.target_rsd,
    )

    ccfg = COUNTING_CONFIGS[args.config]
    _family_arg = None
    if args.templates:
        # fail fast, before any graph is synthesized or plan compiled:
        # unknown/duplicate names are a typo, not a workload
        _family_arg = [s.strip() for s in args.templates.split(",") if s.strip()]
        unknown = [s for s in _family_arg if s not in TEMPLATES]
        if unknown:
            ap.error(
                f"unknown template(s) {', '.join(sorted(set(unknown)))}; "
                f"registry has: {', '.join(sorted(TEMPLATES))}"
            )
        dups = sorted({s for s in _family_arg if _family_arg.count(s) > 1})
        if dups:
            ap.error(f"duplicate template(s) in --templates: {', '.join(dups)}")
        if not _family_arg:
            ap.error("--templates is empty after parsing")
    if args.graph:
        g = load_npz(args.graph) if args.graph.endswith(".npz") else load_edge_file(args.graph)
        print(f"loaded {g.name}: V={g.n} E={g.num_edges} skew={g.skewness():.0f}")
    else:
        print(f"synthesizing RMAT: V={ccfg.num_vertices} E={ccfg.num_edges} "
              f"skew={ccfg.skew}")
        g = ccfg.synthesize()

    single = args.mode == "single" or (args.mode is None and jax.device_count() == 1)
    impl_opt = {"impl": args.impl} if args.impl else {}
    for name, val in (("compact", args.compact),
                      ("density_threshold", args.density_threshold),
                      ("capacity_factor", args.capacity_factor),
                      ("wire_dtype", args.wire_dtype),
                      ("adaptive", args.adaptive)):
        if val is not None:
            impl_opt[name] = val
    if single:
        # a block-dense plan has no edge slabs, so fused_count would fall
        # back to the unfused path: when fusing, steer 'auto' to 'edges'
        spmm_kind = args.spmm_kind
        if args.fuse and spmm_kind == "auto":
            spmm_kind = "edges"
        request = ccfg.to_request(
            g,
            backend="single",
            n_iter=args.iters,
            delta=args.delta,
            batch=args.batch,
            spmm_kind=spmm_kind,
            fuse=args.fuse,
            **impl_opt,
        )
    else:
        request = ccfg.to_request(
            g,
            backend="distributed",
            n_iter=args.iters,
            delta=args.delta,
            batch=args.batch,
            mode=args.mode or ccfg.mode,
            group_factor=args.group_factor,
            fuse=args.fuse,
            bucket_tile=args.bucket_tile,
            **impl_opt,
        )
    counter = Counter.from_request(request)
    key = jax.random.key(args.seed)
    family = _family_arg if args.templates else list(ccfg.templates)
    ran = -(-args.iters // args.batch) * args.batch
    if family:
        # family mode never builds the single-template plan (the label comes
        # from the request, not from counter.plan): one shared-DAG pass per
        # coloring does all the counting
        if single:
            shards = 1
            label = f"single(batch={args.batch},fuse={args.fuse})"
        else:
            shards = min(request.plan_opts["num_shards"], jax.device_count())
            label = (f"{request.plan_opts['mode']}(fuse={args.fuse},"
                     f"impl={args.impl or 'xla'})")
        # warm the jit at the REAL batch size (both backends cache compiled
        # programs per batch), so compile stays outside the timer
        b = request.batch or min(8, request.n_iter)
        counter.estimate_many(family, n_iter=b, key=key, batch=b)
        t0 = time.perf_counter()
        res = counter.estimate_many(
            family,
            n_iter=request.n_iter,
            delta=request.delta,
            key=key,
            batch=request.batch,
            **robust_kw,
        )
        dt = time.perf_counter() - t0
        _robust_report(res)
        print(f"mode={label} shards={shards}: family of {len(res)} templates, "
              f"k={res.k}, {res.unique_tables} unique tables "
              f"(vs {res.chain_tables} chain nodes), {ran} colorings in "
              f"{dt:.2f}s ({dt / max(ran, 1) * 1e3:.1f} ms/coloring)")
        groups = num_groups_for(res.delta, res.niter)
        for one in res:
            print(f"  {one.template:>10}: median-of-means {one.estimate:.6g} "
                  f"({groups} groups)  mean {one.mean:.6g} "
                  f"RSD {one.relative_sd:.2f}")
        return
    if single:
        shards = 1
        # fusion needs the edge-slab layout; report whether it engaged
        fused = args.fuse and counter.plan.spmm_plan.slab_dst is not None
        label = (f"single(batch={args.batch},fuse={fused},"
                 f"spmm={counter.plan.spmm_plan.kind})")
    else:
        shards = counter.plan.num_shards
        label = (f"{request.plan_opts['mode']}(fuse={args.fuse},"
                 f"impl={args.impl or 'xla'},"
                 f"tile={counter.plan.bucket_tile}x{counter.plan.num_tiles})")
    _plan_report(counter.plan)
    if not single:
        _route_report(counter, request)
    counter.sample_fn(key, args.batch)  # compile outside the timer
    t0 = time.perf_counter()
    res = counter.estimate(
        n_iter=request.n_iter,
        delta=request.delta,
        key=key,
        batch=request.batch,
        **robust_kw,
    )
    dt = time.perf_counter() - t0
    _robust_report(res)
    _report(label, shards, res, dt, ran)


if __name__ == "__main__":
    main()
