"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the batched prefill/decode engine (reduced configs locally; the
production-mesh decode path is exercised by ``repro.launch.dryrun``).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_arch
from repro.models import build_model
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    eng = ServingEngine(
        model, ServeConfig(batch_size=args.batch, max_new_tokens=args.new_tokens)
    )
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(
        np.int32
    )
    ctx_len, needed = model._context_len()
    ctx = (
        (rng.standard_normal((args.batch, ctx_len, cfg.d_model)) * 0.1).astype(
            np.float32
        )
        if needed
        else None
    )
    out = eng.generate(prompts, context=ctx)
    print(f"{cfg.name}: generated {out.shape[0]}x{out.shape[1]} tokens")
    print(out)


if __name__ == "__main__":
    main()
