"""Counting-service launcher: ``python -m repro.launch.serve``.

Boots a resident :class:`~repro.serve.CountingService` over a synthesized
graph and drives a scripted multi-tenant request stream through it
(:data:`repro.configs.SERVICE_WORKLOADS`), printing per-request results
and the service's cache/coalescing/fairness counters.  This is the
synthetic driver for the serving layer — the single-process analogue of N
clients sharing one resident engine.

Run::

    PYTHONPATH=src python -m repro.launch.serve --workload bench-service
    PYTHONPATH=src python -m repro.launch.serve --workload smoke-service \
        --backend single --repeats 1
    PYTHONPATH=src python -m repro.launch.serve --threaded --timeout-s 30 \
        --deadline-s 60 --shed-oldest --max-pending 16
"""

from __future__ import annotations

import argparse
import json

from repro.configs import SERVICE_WORKLOADS
from repro.serve import CountingService, ServiceConfig


def run_workload(
    wl,
    *,
    backend: str = "auto",
    repeats: int | None = None,
    batch: int | None = None,
    seed: int = 0,
    verbose: bool = True,
    threaded: bool = False,
    deadline_s: float | None = None,
    **config_kw,
):
    """Drive one scripted workload; returns ``(tickets, service)``.

    ``threaded`` runs the §20 driver thread (submits race the scheduler —
    the production shape) instead of the synchronous drain; ``deadline_s``
    applies a per-request relative deadline; extra keywords land on
    :class:`~repro.serve.ServiceConfig` (``shed_oldest=``, ``timeout_s=``,
    ``max_pending=``, ...).
    """
    cfg = wl.counting_config()
    graph = cfg.synthesize(seed=seed)
    svc = CountingService(
        graph,
        n_colors=wl.k,
        backend=backend,
        plan_opts={"num_shards": cfg.num_shards} if backend == "distributed" else None,
        config=ServiceConfig(batch=batch or wl.batch, **config_kw),
    )
    if threaded:
        svc.start()
    tickets = []
    for _ in range(repeats if repeats is not None else wl.repeats):
        for tenant, templates, kw in wl.requests:
            if deadline_s is not None:
                kw = dict(kw, timeout_s=deadline_s)
            tickets.append(svc.submit(tenant, templates, **kw))
    svc.run_until_idle()
    if threaded:
        svc.stop()
    if verbose:
        for t in tickets:
            if t.status != "done":
                print(f"  {t}: {t.status.upper()} — {t.error}")
                continue
            r = t.result()
            ests = getattr(r, "estimates", None)
            shown = (f"{r.estimate:.6g}" if ests is None
                     else "[" + ", ".join(f"{e:.6g}" for e in ests) + "]")
            print(f"  {t}: {shown}  niter={r.niter}  "
                  f"latency={t.latency_s:.3f}s")
    return tickets, svc


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--workload", default="bench-service", choices=sorted(SERVICE_WORKLOADS))
    ap.add_argument("--backend", default="auto", choices=("auto", "single", "distributed"))
    ap.add_argument("--repeats", type=int, default=None,
                    help="override the workload's request-stream repeats")
    ap.add_argument("--batch", type=int, default=None, help="override the per-call coloring batch")
    ap.add_argument("--seed", type=int, default=0, help="graph synthesis seed")
    ap.add_argument("--json", action="store_true",
                    help="print the stats dict as JSON (for scripting)")
    ap.add_argument("--threaded", action="store_true",
                    help="drive the service on the background driver thread")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (relative seconds from submit)")
    ap.add_argument("--timeout-s", type=float, default=None,
                    help="per-pass-call supervisor timeout (hang detection)")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="pass-call retries before quarantine (default 0)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="global bounded-queue depth (queued + active)")
    ap.add_argument("--max-pending-per-tenant", type=int, default=None,
                    help="per-tenant bounded-queue depth")
    ap.add_argument("--shed-oldest", action="store_true",
                    help="under overload, shed the oldest queued request "
                         "instead of rejecting the new submit")
    args = ap.parse_args()

    cfg_kw = {}
    if args.timeout_s is not None:
        cfg_kw["timeout_s"] = args.timeout_s
    if args.max_retries is not None:
        cfg_kw["max_retries"] = args.max_retries
    if args.max_pending is not None:
        cfg_kw["max_pending"] = args.max_pending
    if args.max_pending_per_tenant is not None:
        cfg_kw["max_pending_per_tenant"] = args.max_pending_per_tenant
    if args.shed_oldest:
        cfg_kw["shed_oldest"] = True

    wl = SERVICE_WORKLOADS[args.workload]
    print(f"workload {wl.name}: graph={wl.graph} k={wl.k} "
          f"{len(wl.requests)} requests x {args.repeats or wl.repeats}")
    tickets, svc = run_workload(
        wl,
        backend=args.backend,
        repeats=args.repeats,
        batch=args.batch,
        seed=args.seed,
        threaded=args.threaded,
        deadline_s=args.deadline_s,
        **cfg_kw,
    )
    stats = svc.stats()
    if args.json:
        print(json.dumps(stats, indent=2, default=str))
    else:
        cache = stats["cache"]
        print(f"served {stats.get('completed', 0)} "
              f"(failed {stats.get('failed', 0)}, "
              f"cancelled {stats.get('cancelled', 0)}, "
              f"expired {stats.get('deadline_exceeded', 0)}, "
              f"shed {stats.get('shed', 0)}) | "
              f"coalescing x{stats['coalescing_factor']:.2f} | "
              f"plan cache {cache['hits']}/{cache['hits'] + cache['misses']} "
              f"hits ({cache['hit_rate']:.0%}), "
              f"{cache['evictions']} evictions | "
              f"backfill {stats.get('backfill_calls', 0)} calls | "
              f"driver errors {stats['driver']['errors']}")
        for name, ts in stats["tenants"].items():
            print(f"  tenant {name}: charged={ts['charged']} "
                  f"weight={ts['weight']} "
                  f"saturation={ts['saturation']:.0%}")


if __name__ == "__main__":
    main()
