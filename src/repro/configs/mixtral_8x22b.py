"""mixtral-8x22b — 8 experts top-2, SWA [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2,
sliding window 4096.  8 experts do not divide the 16-way model axis, so
experts are TP-sharded (d_ff split over the model axis) — see DESIGN.md §5;
SWA bounds the KV cache, making long_500k runnable.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    moe_sharding="tp",
    window=4096,
    source="arXiv:2401.04088; hf",
)
