"""Config registry: ``get_arch(name)`` resolves any assigned architecture."""

from .base import SHAPES, ArchConfig, ShapeSpec, ShardingConfig  # noqa: F401
from . import (
    granite_3_8b,
    internlm2_1_8b,
    llama3_2_vision_90b,
    mixtral_8x22b,
    phi3_5_moe,
    qwen1_5_0_5b,
    recurrentgemma_2b,
    rwkv6_3b,
    smollm_360m,
    whisper_base,
)
from .subgraph import (  # noqa: F401
    COUNTING_CONFIGS,
    SERVICE_WORKLOADS,
    CountingConfig,
    ServiceWorkloadConfig,
)

ARCHS = {
    c.name: c
    for c in (
        rwkv6_3b.CONFIG,
        internlm2_1_8b.CONFIG,
        smollm_360m.CONFIG,
        qwen1_5_0_5b.CONFIG,
        granite_3_8b.CONFIG,
        phi3_5_moe.CONFIG,
        mixtral_8x22b.CONFIG,
        llama3_2_vision_90b.CONFIG,
        whisper_base.CONFIG,
        recurrentgemma_2b.CONFIG,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
