"""rwkv6-3b — Finch, data-dependent decay [arXiv:2404.05892; hf].

Attention-free: every block is an RWKV6 time-mix + channel-mix pair.
32L d_model=2560 d_ff=8960 vocab=65536; head_dim 64 -> 40 wkv heads.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv",),
    act="swiglu",  # channel-mix uses squared-relu internally; d_ff honored
    source="arXiv:2404.05892; hf",
)
