"""whisper-base — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

6L encoder + 6L decoder, d_model=512 8H d_ff=2048 vocab=51865.  The conv
frontend is a stub: input_specs() provides precomputed frame embeddings fed
straight to the encoder.  Decoder cross-attends to the encoder output.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    encoder_layers=6,
    encoder_context=1500,
    block_pattern=("attn", "cross"),
    act="gelu",
    source="arXiv:2212.04356; unverified",
)
