"""Counting-workload configs — the paper's own experiment grid (Table 2/Fig 5).

These drive the subgraph-counting dry-runs and benchmarks.  Graph sizes are
the paper's datasets; at dry-run time only shapes matter (ShapeDtypeStruct),
so the billion-edge rows compile without materializing data.  Benchmark runs
use the scaled-down rows (CPU container).
"""

from __future__ import annotations

import dataclasses

__all__ = [
    "CountingConfig",
    "COUNTING_CONFIGS",
    "PAPER_DATASETS",
    "ServiceWorkloadConfig",
    "SERVICE_WORKLOADS",
]


@dataclasses.dataclass(frozen=True)
class CountingConfig:
    name: str
    num_vertices: int
    num_edges: int  # undirected
    template: str  # name in core.templates.TEMPLATES
    num_shards: int  # graph shards over the data axis
    mode: str = "adaptive"  # alltoall | pipeline | adaptive | ring
    group_factor: int = 1
    bucket_tile: int = 128  # §3.3 task size of the tiled bucket layout
    skew: int = 3  # RMAT skew when synthesized
    #: active-frontier compaction (DESIGN.md §15): probe per-node table
    #: densities at plan build and compact tables/exchange below the
    #: threshold, with capacity_factor headroom before the dense fallback
    compact: bool = False
    density_threshold: float = 0.25
    capacity_factor: float = 1.5
    #: narrow-wire exchange (DESIGN.md §18): ship exchange slabs as int16 /
    #: int8 with per-batch saturation checking and wider-wire redispatch;
    #: ``adaptive`` picks the router's cost model — 'model' uses the assumed
    #: link constants, 'measured' calibrates alpha/beta with a one-shot probe
    wire_dtype: str = "float32"
    adaptive: str = "model"
    #: multi-template family (template names): when non-empty, the row is a
    #: one-pass family-counting workload over the shared subtree DAG
    #: (``Counter.estimate_many`` / the multi-template dry-run cell);
    #: ``template`` stays the row's representative single template.
    templates: tuple = ()
    #: 'grid' — graph over data(16), colorings over model(16) with the
    #: unrolled grouped exchange; 'flat' — graph over all chips with the
    #: O(1)-HLO relay ring (the beyond-paper mode for big-V datasets)
    mesh_kind: str = "grid"
    #: robustness spec (DESIGN.md §16): bounded retry of transient sample
    #: faults (None disables supervision), checkpoint cadence in colorings
    #: (0 = only a final checkpoint when a directory is given at run time),
    #: and optional early stop at a target relative standard error
    max_retries: int | None = None
    checkpoint_every: int = 0
    target_rsd: float | None = None

    @property
    def avg_degree(self) -> float:
        return 2 * self.num_edges / self.num_vertices

    def synthesize(self, seed: int = 0):
        """Materialize the configured RMAT graph (randomly relabeled)."""
        from repro.core.graphs import relabel_random, rmat

        g = rmat(self.num_vertices, self.num_edges, skew=self.skew, seed=seed, name=self.name)
        return relabel_random(g, seed=seed + 1)

    def to_request(self, graph=None, *, backend: str = "auto",
                   n_iter=None, eps=None, delta: float = 0.1, batch=None,
                   **plan_opts):
        """Resolve this config row to a ``repro.api.CountRequest``.

        ``graph`` defaults to the synthesized RMAT dataset; pass a loaded
        real graph (``load_edge_file``/``load_npz``) to run the same grid
        row on real data.  The request carries both backends' options —
        the ``Counter`` facade keeps whichever subset its resolved backend
        understands — and ``plan_opts`` overrides/extends the config's own
        (e.g. ``mode=...`` to try another exchange schedule, ``fuse=True``
        for the single-device fused kernels).
        """
        from repro.api import CountRequest

        if graph is None:
            graph = self.synthesize()
        return CountRequest(
            graph=graph,
            template=self.template,
            backend=backend,
            n_iter=n_iter,
            eps=eps,
            delta=delta,
            batch=batch,
            max_retries=self.max_retries,
            checkpoint_every=self.checkpoint_every,
            target_rsd=self.target_rsd,
            plan_opts={
                "num_shards": self.num_shards,
                "mode": self.mode,
                "group_factor": self.group_factor,
                "bucket_tile": self.bucket_tile,
                "compact": self.compact,
                "density_threshold": self.density_threshold,
                "capacity_factor": self.capacity_factor,
                "wire_dtype": self.wire_dtype,
                "adaptive": self.adaptive,
                **plan_opts,
            },
        )


# Paper Table 2 datasets (name -> (V, E, source))
PAPER_DATASETS = {
    "miami": (2_100_000, 51_000_000, "social network"),
    "orkut": (3_000_000, 230_000_000, "social network"),
    "nyc": (18_000_000, 480_000_000, "social network"),
    "twitter": (44_000_000, 2_000_000_000, "Twitter users"),
    "sk-2005": (50_000_000, 3_800_000_000, "UbiCrawler"),
    "friendster": (66_000_000, 5_000_000_000, "social network"),
    "rmat-250m": (5_000_000, 250_000_000, "PaRMAT"),
    "rmat-500m": (5_000_000, 500_000_000, "PaRMAT"),
}

COUNTING_CONFIGS = {
    # dry-run rows (paper scale; shapes only)
    "rmat500-u10-2": CountingConfig("rmat500-u10-2", *PAPER_DATASETS["rmat-500m"][:2],
                                    template="u10-2", num_shards=16,
                                    mode="pipeline", mesh_kind="grid"),
    "rmat500-u12-2": CountingConfig("rmat500-u12-2", *PAPER_DATASETS["rmat-500m"][:2],
                                    template="u12-2", num_shards=16,
                                    mode="alltoall", mesh_kind="grid"),
    "twitter-u12-2": CountingConfig("twitter-u12-2", *PAPER_DATASETS["twitter"][:2],
                                    template="u12-2", num_shards=256,
                                    mode="ring", mesh_kind="flat"),
    # u12-2's |V|/P table term exceeds v5e HBM at 16 shards (Eq. 12);
    # the 256-shard flat ring is the config that fits
    "rmat500-u12-2-ring": CountingConfig(
        "rmat500-u12-2-ring", *PAPER_DATASETS["rmat-500m"][:2],
        template="u12-2", num_shards=256, mode="ring", mesh_kind="flat"),
    "friendster-u12-1": CountingConfig(
        "friendster-u12-1", *PAPER_DATASETS["friendster"][:2],
        template="u12-1", num_shards=256, mode="ring", mesh_kind="flat"),
    # sparse what-if row for the compacted-exchange dry-run cell (§15):
    # at avg degree 1 the analytic density model goes sparse for the
    # deep sub-templates, so the lowered cell ships compacted slabs
    "rmat-sparse-u10-2": CountingConfig(
        "rmat-sparse-u10-2", 50_000_000, 25_000_000, template="u10-2",
        num_shards=16, mode="pipeline", compact=True),
    # multi-template family rows: one shared-DAG pass per coloring
    # (nested spiders: u3-1 ⊂ u5-2 ⊂ u7-2, maximal subtree reuse)
    "rmat500-family": CountingConfig(
        "rmat500-family", *PAPER_DATASETS["rmat-500m"][:2],
        template="u10-2", num_shards=16, mode="pipeline",
        templates=("u5-2", "u7-2", "u10-2")),
    # sparse skewed row: deep wide-table template on a low-degree RMAT —
    # the regime where active-frontier compaction engages (§15; same graph
    # family as benchmarks/bench_sparsity.py)
    "bench-sparse": CountingConfig("bench-sparse", 4_096, 6_000,
                                   template="u10-2", num_shards=8, skew=8,
                                   compact=True, density_threshold=0.5),
    # benchmark rows (CPU-scale, same shape family)
    "bench-small": CountingConfig("bench-small", 20_000, 200_000, template="u5-2",
                                  num_shards=8),
    "bench-medium": CountingConfig("bench-medium", 50_000, 1_000_000,
                                   template="u10-2", num_shards=8),
    "bench-family": CountingConfig("bench-family", 20_000, 200_000,
                                   template="u7-2", num_shards=8,
                                   templates=("u3-1", "u5-2", "u7-2")),
    # treewidth-2 rows (DESIGN.md §19): apex-pinned bag programs.  The
    # cycle row is the pure non-tree workload; the mixed row compiles
    # trees and cycles into ONE shared DAG (tree nodes keep the classic
    # chain path bit-identically, bag nodes run the pinned-apex strategy)
    # bag-scale graphs: the pinned-apex axis multiplies every bag-table
    # width by |V|, so treewidth-2 rows stay small (|V|^2 * W floats)
    "bench-cycles": CountingConfig(
        "bench-cycles",
        256,
        2_000,
        template="cycle5",
        num_shards=8,
        templates=("cycle3", "cycle5", "diamond"),
    ),
    "bench-tw2-mixed": CountingConfig(
        "bench-tw2-mixed",
        256,
        2_000,
        template="cycle6",
        num_shards=8,
        templates=("u3-1", "cycle4", "u5-2", "cycle6", "diamond"),
    ),
}


@dataclasses.dataclass(frozen=True)
class ServiceWorkloadConfig:
    """A synthetic multi-tenant request stream for the counting service.

    ``graph`` names a :data:`COUNTING_CONFIGS` row (synthesized at run
    time); ``requests`` is the admission script — ``(tenant, templates,
    kwargs)`` tuples submitted in order, each repeated ``repeats`` times so
    the plan cache and the coalescer have something to chew on.  The
    service runs with ``n_colors = k`` and per-call batch ``batch``.
    """

    name: str
    graph: str  # COUNTING_CONFIGS row to synthesize
    k: int  # service-wide shared color budget
    batch: int = 8
    repeats: int = 1
    requests: tuple = ()  # ((tenant, templates, kwargs), ...)

    def counting_config(self) -> CountingConfig:
        return COUNTING_CONFIGS[self.graph]


SERVICE_WORKLOADS = {
    # three tenants, overlapping template families and shared default key:
    # alice re-asks the same family (plan-cache hits), bob's family shares
    # subtrees with alice's, carol's scalar queries coalesce into whatever
    # family pass is in flight
    "bench-service": ServiceWorkloadConfig(
        "bench-service", graph="bench-small", k=7, batch=8, repeats=2,
        requests=(
            ("alice", ("u3-1", "u5-2"), {"n_iter": 48}),
            ("bob", ("u5-2", "u7-2"), {"n_iter": 32}),
            ("carol", ("u3-1",), {"n_iter": 64, "target_rsd": 0.2}),
            ("alice", ("u3-1", "u5-2"), {"n_iter": 24}),
            ("carol", ("u5-2",), {"n_iter": 40}),
        ),
    ),
    # single-tenant smoke row for CI (small budgets, tiny graph)
    "smoke-service": ServiceWorkloadConfig(
        "smoke-service", graph="bench-small", k=5, batch=4,
        requests=(
            ("alice", ("u3-1", "u5-2"), {"n_iter": 8}),
            ("bob", ("u5-2",), {"n_iter": 8}),
            ("alice", ("u3-1",), {"n_iter": 12}),
        ),
    ),
}
