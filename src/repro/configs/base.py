"""Architecture & parallelism configuration schema.

Every assigned architecture is a frozen :class:`ArchConfig`; ``reduced()``
derives the CPU smoke-test configuration (same family/topology, tiny dims).

Parallelism is configured separately (:class:`ShardingConfig`) so one arch
can be dry-run under different layouts during the perf hillclimb.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShardingConfig", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention details
    attn_bias: bool = False  # qwen-style QKV bias
    window: int = 0  # sliding-window attention (mixtral); 0 = full
    local_window: int = 2048  # hybrid local-attention window
    rope_theta: float = 10_000.0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_sharding: str = "ep"  # 'ep' (experts over model axis) | 'tp'
    capacity_factor: float = 1.25

    # layer pattern, cycled over depth.  elements:
    #   'attn' (global self-attn block), 'local' (windowed attn),
    #   'rwkv' (RWKV6 time/channel mix), 'rglru' (RG-LRU recurrent block),
    #   'cross' (cross-attention block consuming encoder/vision context)
    block_pattern: Tuple[str, ...] = ("attn",)

    # encoder-decoder (whisper): encoder layers with bidirectional attn
    encoder_layers: int = 0
    encoder_context: int = 1500  # default frames for stub frontend tests

    # vlm: stubbed number of image tokens prepended as cross-attn context
    num_image_tokens: int = 0

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "swiglu"  # swiglu | gelu

    # source annotation (public literature reference)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so embed/lm_head shard over the model axis
        (e.g. whisper's 51865, granite's 49155); pad logits are masked."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def attention_free(self) -> bool:
        return all(b in ("rwkv", "rglru") for b in self.block_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if decode-state is O(1)/bounded (long_500k eligible)."""
        has_global_attn = any(b in ("attn", "cross") for b in self.block_pattern)
        return (not has_global_attn) or (self.window > 0)

    def params_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qkv = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        dense_ff = 3 * d * f if self.act == "swiglu" else 2 * d * f
        per_layer = 0
        counts = {
            "attn": qkv + dense_ff,
            "local": qkv + dense_ff,
            "cross": qkv + dense_ff,
            "rwkv": 4 * d * d + 2 * d * self.d_ff,  # time-mix + channel-mix
            "rglru": 2 * d * d + d * self.d_ff * 3,  # conv/gates + mlp
        }
        if self.num_experts:
            counts["attn"] = qkv + self.num_experts * dense_ff
        n = 0
        for i in range(self.num_layers):
            n += counts[self.block_pattern[i % len(self.block_pattern)]]
        n += v * d * (1 if self.tie_embeddings else 2)
        n += self.encoder_layers * (qkv + dense_ff)
        return n

    def active_params_count(self) -> int:
        """Active (per-token) parameters — MoE uses experts_per_token."""
        if not self.num_experts:
            return self.params_count()
        d, f = self.d_model, self.d_ff
        dense_ff = 3 * d * f if self.act == "swiglu" else 2 * d * f
        inactive = (self.num_experts - self.experts_per_token) * dense_ff
        return self.params_count() - self.num_layers * inactive

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat_len = len(self.block_pattern)
        layers = max(pat_len, 2)
        if self.encoder_layers:
            layers = 2
        kv = max(1, min(self.num_kv_heads, 2))
        heads = max(kv, 4)
        heads = (heads // kv) * kv
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.num_experts else 0,
            window=min(self.window, 32) if self.window else 0,
            local_window=32,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_context=16,
            num_image_tokens=min(self.num_image_tokens, 8),
        )


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """How a model maps onto the mesh."""

    batch_axes: Tuple[str, ...] = ("pod", "data")  # DP axes (present subset used)
    model_axis: str = "model"  # TP / EP axis
    fsdp: bool = False  # shard weights over the data axis (ZeRO-3)
    zero1: bool = True  # shard optimizer state over the data axis
    seq_axis: Optional[str] = None  # sequence parallelism axis (long prefill)
    remat: str = "full"  # full | dots | none
    moe_pipeline: bool = False  # pipelined (grouped) MoE all-to-all
    grad_compression: Optional[str] = None  # None | 'int8'
    attn_anchor: bool = False  # explicit head sharding anchors (see §Perf)
    attn_chunk: int = 1024  # chunked-attention tile (q and kv)
    #: which activation dim shards over ``seq_axis``: 1 = sequence
    #: (Megatron SP), 2 = channels (natural for per-channel recurrent archs)
    sp_dim: int = 1


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}
