"""recurrentgemma-2b — RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000; block pattern is
two RG-LRU recurrent blocks per local-attention block (window 2048).
head_dim 256 (10 x 256 = 2560).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    act="gelu",
    source="arXiv:2402.19427; hf",
)
