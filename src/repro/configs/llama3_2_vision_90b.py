"""llama-3.2-vision-90b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; every 5th layer
is a cross-attention block over stub-provided image-patch embeddings
(frontend is a stub per the task spec: input_specs() supplies precomputed
patch embeddings).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=("attn", "attn", "attn", "attn", "cross"),
    num_image_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
