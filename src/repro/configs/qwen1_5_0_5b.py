"""qwen1.5-0.5b — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    attn_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)
