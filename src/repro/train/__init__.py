"""Training substrate: optimizer, loop, checkpointing, data pipeline."""

from .optimizer import AdamWConfig, adamw_update, init_opt_state, opt_state_pspecs  # noqa: F401
from .train_loop import TrainConfig, make_train_step, train  # noqa: F401
from .checkpoint import CheckpointManager  # noqa: F401
from .data import DataConfig, data_iterator, synthetic_batch  # noqa: F401
