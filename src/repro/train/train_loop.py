"""Training loop: jitted train_step builder + fault-tolerant driver.

``make_train_step`` returns the jitted (params, opt, batch) -> step function
with donated arguments and sharding-annotated inputs/outputs; the driver
adds checkpointing, preemption handling (SIGTERM -> save -> exit), and
deterministic resume.

Gradient accumulation runs as a ``lax.scan`` over microbatches (constant
HLO size).  Gradient compression (int8 ring reduce-scatter over the data
axis) is available behind ``ShardingConfig.grad_compression`` — see
``comm.compress``; it runs inside a shard_map region over the data axes.
"""

from __future__ import annotations

import dataclasses
import signal
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.factory import Model
from .checkpoint import CheckpointManager
from .data import DataConfig, synthetic_batch
from .optimizer import AdamWConfig, adamw_update, init_opt_state, opt_state_pspecs

__all__ = ["TrainConfig", "make_train_step", "train"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1
    opt: AdamWConfig = AdamWConfig()
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 10
    seed: int = 0


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def make_train_step(
    model: Model,
    tcfg: TrainConfig,
    mesh=None,
    *,
    batch_spec: Optional[P] = None,
    jit: bool = True,
):
    """Returns (train_step, shardings dict).  train_step(params, opt, batch)
    -> (params, opt, metrics).  ``jit=False`` returns the raw step function
    (the dry-run applies its own jit with production shardings)."""

    def loss_of(params, batch):
        return model.loss_fn(params, batch)

    grad_constraint = lambda g: g
    if model.mesh is not None:
        gspecs = model.param_specs(jax.eval_shape(model.init_fn, jax.random.key(0)))
        gshard = jax.tree.map(lambda s: NamedSharding(model.mesh, s), gspecs)
        # without this, XLA may materialize full-size (unsharded) f32 grads
        # between the backward pass and the optimizer update
        grad_constraint = lambda g: jax.lax.with_sharding_constraint(g, gshard)

    def step_fn(params, opt_state, batch):
        if tcfg.microbatches > 1:
            tokens = batch["tokens"]
            gb = tokens.shape[0]
            mb = gb // tcfg.microbatches
            micro = {k: v.reshape((tcfg.microbatches, mb) + v.shape[1:]) for k, v in batch.items()}

            def accum(carry, mb_batch):
                loss_sum, grad_sum = carry
                loss, grads = jax.value_and_grad(loss_of)(params, mb_batch)
                grads = grad_constraint(grads)
                return (
                    loss_sum + loss,
                    jax.tree.map(jnp.add, grad_sum, grads),
                ), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss_sum, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), zero_grads), micro)
            loss = loss_sum / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        grads = grad_constraint(grads)
        new_params, new_opt, stats = adamw_update(tcfg.opt, params, grads, opt_state)
        metrics = {"loss": loss, **stats}
        return new_params, new_opt, metrics

    if not jit:
        return step_fn, None
    if mesh is None:
        return jax.jit(step_fn, donate_argnums=(0, 1)), None

    params_shapes = jax.eval_shape(model.init_fn, jax.random.key(0))
    pspecs = model.param_specs(params_shapes)
    data_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 0)
    ospecs = opt_state_pspecs(
        pspecs, params_shapes, zero1=model.sharding.zero1, data_size=data_size
    )
    bspec = batch_spec or P(model.sharding.batch_axes, None)
    shardings = {
        "params": _named(mesh, pspecs),
        "opt": _named(mesh, ospecs),
        "batch": {"tokens": NamedSharding(mesh, bspec)},
    }
    step = jax.jit(
        step_fn,
        in_shardings=(shardings["params"], shardings["opt"], None),
        out_shardings=(shardings["params"], shardings["opt"], None),
        donate_argnums=(0, 1),
    )
    return step, shardings


def train(
    model: Model,
    tcfg: TrainConfig,
    mesh=None,
    *,
    log: Callable[[str], None] = print,
) -> Dict[str, Any]:
    """Driver: init-or-restore, step loop, periodic + preemption checkpoints."""
    cfg = model.cfg
    dcfg = DataConfig(
        vocab_size=cfg.vocab_size,
        global_batch=max(2, 2),  # driver-scale batch; launcher overrides
        seq_len=128,
        seed=tcfg.seed,
    )
    train_step, _ = make_train_step(model, tcfg, mesh)
    params = jax.jit(model.init_fn)(jax.random.key(tcfg.seed))
    opt = init_opt_state(params)
    start = 0

    ckpt = None
    if tcfg.checkpoint_dir:
        ckpt = CheckpointManager(tcfg.checkpoint_dir)
        latest = ckpt.latest_step()
        if latest is not None:
            restored = ckpt.restore(latest, {"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            start = latest
            log(f"restored checkpoint at step {latest}")

    preempted = {"flag": False}

    def _on_sigterm(signum, frame):  # preemption hook
        preempted["flag"] = True

    old_handler = signal.signal(signal.SIGTERM, _on_sigterm)
    metrics = {}
    try:
        for step_i in range(start, tcfg.steps):
            batch = synthetic_batch(dcfg, step_i)
            params, opt, metrics = train_step(params, opt, batch)
            if (step_i + 1) % tcfg.log_every == 0:
                log(
                    f"step {step_i + 1}: loss {float(metrics['loss']):.4f} "
                    f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f}"
                )
            if ckpt and ((step_i + 1) % tcfg.checkpoint_every == 0 or preempted["flag"]):
                ckpt.save(step_i + 1, {"params": params, "opt": opt})
            if preempted["flag"]:
                log(f"preemption: checkpoint saved at step {step_i + 1}; exiting")
                break
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        if ckpt:
            ckpt.wait()
    return {"params": params, "opt": opt, "metrics": metrics}
