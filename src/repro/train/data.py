"""Deterministic synthetic token pipeline — shardable and resumable.

Batches are a pure function of (seed, step), so a restarted/elastically
rescaled job regenerates exactly the stream it would have seen: fault
tolerance needs no data-loader state beyond the step counter.
Tokens follow a Zipfian-ish distribution (realistic softmax/embedding load).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp

__all__ = ["DataConfig", "synthetic_batch", "data_iterator"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    zipf_alpha: float = 1.2


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """Batch for ``step`` (jit-friendly; device-side PRNG)."""
    key = jax.random.fold_in(jax.random.key(cfg.seed), step)
    # inverse-CDF Zipf over the vocab (approximate, vectorized)
    u = jax.random.uniform(key, (cfg.global_batch, cfg.seq_len), minval=1e-6)
    ranks = jnp.exp(jnp.log(u) / (1.0 - cfg.zipf_alpha))  # heavy-tailed
    tokens = (ranks * cfg.vocab_size).astype(jnp.int32) % cfg.vocab_size
    return {"tokens": tokens}


def data_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, step)
        step += 1
