"""Fault-tolerant, mesh-elastic checkpointing.

Design goals (DESIGN.md §7):

* **atomic** — writes go to ``step_XXXX.tmp/`` and are renamed only after a
  manifest with content checksums is fsynced; a crash mid-save never
  corrupts the latest checkpoint;
* **async** — the train loop hands off host copies to a writer thread and
  keeps stepping;
* **mesh-elastic** — arrays are saved *unsharded* (gathered per leaf) with
  the logical pytree structure; restore re-shards onto whatever mesh/specs
  the new job uses, so a job can resume on a different pod count;
* **bounded** — keeps the last ``keep`` checkpoints.

Storage is ``.npz`` per pytree (flattened by path) — no external deps.
At true 1000-node scale this single-writer gather becomes per-host sharded
writes; the manifest/atomic-rename/restart protocol is the part that carries
over unchanged (noted in DESIGN.md).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.testing import faults

__all__ = ["CheckpointManager"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        #: the step load_latest()/restore() last read: keep-pruning never
        #: deletes the checkpoint a live run was restored from
        self._protected: Optional[int] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, trees: Dict[str, Any], *, block: bool = False):
        """``trees``: name -> pytree (e.g. {'params': ..., 'opt': ...})."""
        host = {name: _flatten(jax.device_get(t)) for name, t in trees.items()}
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host: Dict[str, Dict[str, np.ndarray]]):
        self._gc_tmp()  # crash residue from a previously killed writer
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "trees": {}}
        for name, flat in host.items():
            path = os.path.join(tmp, f"{name}.npz")
            np.savez(path, **flat)
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            manifest["trees"][name] = {"file": f"{name}.npz", "sha256": digest}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        spec = faults.fire("checkpoint.write_crash")
        if spec is not None:
            # simulate a kill between the tmp write and the atomic rename:
            # the .tmp dir stays behind, the previous checkpoint stays latest
            raise faults.InjectedCrash(
                f"injected writer kill before renaming {tmp}"
            )
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            if s == self._protected:
                continue  # never delete the checkpoint a run restored from
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    def _gc_tmp(self):
        """Remove ``step_*.tmp`` residue left by a killed writer.

        Only called with no writer thread in flight (save() joins the
        previous writer first; load_latest() waits too), so any tmp dir on
        disk is from a dead process and can never become a valid
        checkpoint — its rename never happened.
        """
        for d in os.listdir(self.dir):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # ---------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _try_load(self, step: int) -> Optional[Dict[str, Dict[str, np.ndarray]]]:
        """Load one checkpoint as raw flat arrays; ``None`` if unreadable.

        Verifies every tree file against the manifest's sha256 — a
        truncated npz, a flipped bit, or a missing file all read as "this
        checkpoint does not exist", never as wrong data.
        """
        base = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(base, "manifest.json")) as f:
                manifest = json.load(f)
            out: Dict[str, Dict[str, np.ndarray]] = {}
            for name, meta in manifest["trees"].items():
                path = os.path.join(base, meta["file"])
                with open(path, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                if digest != meta["sha256"]:
                    raise IOError(f"checksum mismatch for {name}")
                with np.load(path, allow_pickle=False) as z:
                    out[name] = {k: np.asarray(z[k]) for k in z.files}
            return out
        except Exception as e:  # corrupt/partial: caller falls back a step
            print(f"checkpoint: skipping unreadable step {step} "
                  f"({type(e).__name__}: {e})")
            return None

    def load_latest(
        self,
    ) -> Optional[Tuple[int, Dict[str, Dict[str, np.ndarray]]]]:
        """``(step, {tree: {leaf: array}})`` of the newest *readable*
        checkpoint, or ``None`` when the directory holds none.

        Walks steps newest-first, garbage-collecting ``step_*.tmp`` crash
        residue and skipping any checkpoint whose manifest is missing or
        whose sha256s don't verify — a run killed mid-save (or a partially
        synced directory) resumes from the last *good* state instead of
        crashing or reading garbage.  Arrays come back raw (no shape
        templates needed — the schema lives with the caller, e.g.
        ``EstimatorState.from_arrays``); use :meth:`restore` when re-sharding
        pytrees onto a mesh.  The returned step is protected from
        ``keep``-pruning for this manager's lifetime.
        """
        self.wait()
        self._gc_tmp()
        for step in reversed(self.all_steps()):
            data = self._try_load(step)
            if data is not None:
                self._protected = step
                return step, data
        return None

    def restore(
        self,
        step: int,
        templates: Dict[str, Any],
        *,
        shardings: Optional[Dict[str, Any]] = None,
        verify: bool = True,
    ) -> Dict[str, Any]:
        """Restore pytrees shaped like ``templates`` (shape/dtype trees OK).

        ``shardings``: matching pytrees of NamedSharding — arrays are placed
        (re-sharded) accordingly, enabling elastic restore onto a different
        mesh than the one that saved.
        """
        base = os.path.join(self.dir, f"step_{step:08d}")
        self._protected = step  # keep-pruning must not delete it mid-restore
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        out = {}
        for name, template in templates.items():
            meta = manifest["trees"][name]
            path = os.path.join(base, meta["file"])
            if verify:
                with open(path, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                if digest != meta["sha256"]:
                    raise IOError(f"checksum mismatch for {name} at step {step}")
            loaded = np.load(path)
            leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
            treedef = jax.tree_util.tree_structure(template)
            shard_tree = shardings.get(name) if shardings else None
            shard_leaves = jax.tree_util.tree_flatten(shard_tree)[0] if shard_tree else None
            new_leaves = []
            for i, (pth, leaf) in enumerate(leaves_with_path):
                key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in pth)
                arr = loaded[key]
                if tuple(arr.shape) != tuple(leaf.shape):
                    raise ValueError(f"{name}:{key} shape {arr.shape} != template {leaf.shape}")
                if shard_leaves is not None:
                    new_leaves.append(jax.device_put(arr, shard_leaves[i]))
                else:
                    new_leaves.append(jax.numpy.asarray(arr))
            out[name] = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return out
