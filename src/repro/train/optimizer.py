"""AdamW with ZeRO-sharded state, global-norm clipping, cosine schedule.

Implemented directly (no external deps): optimizer state is a pytree
mirroring params (m, v) plus a step counter.  Sharding: state inherits the
param PartitionSpec; with ``zero1`` and a replicated-over-data param, the
state's first shardable dim gets the data axis instead (ZeRO-1).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "AdamWConfig",
    "init_opt_state",
    "adamw_update",
    "cosine_schedule",
    "opt_state_pspecs",
    "clip_by_global_norm",
]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    return cfg.lr_peak * warm * 0.5 * (1.0 + jnp.cos(math.pi * frac))


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        jax.tree.reduce(
            lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0
        )
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}


def opt_state_pspecs(
    param_specs,
    param_shapes=None,
    *,
    zero1: bool,
    data_axis: str = "data",
    data_size: int = 0,
):
    """State PartitionSpecs: inherit the param spec; with ``zero1`` the m/v
    of a data-replicated param additionally shard their first free dim over
    the data axis — only when that dim's size divides ``data_size``
    (``param_shapes``/``data_size`` required for that check)."""

    def used_axes(spec: P):
        out = set()
        for p in spec:
            if p is None:
                continue
            out.update(p if isinstance(p, tuple) else (p,))
        return out

    def shard_state(spec: P, shape=None):
        if not zero1 or shape is None or not data_size:
            return spec
        parts = list(spec) if spec else [None] * len(shape)
        if data_axis in used_axes(spec):
            return spec  # already sharded over data (fsdp)
        for i, (p, d) in enumerate(zip(parts, shape)):
            if p is None and d % data_size == 0 and d > 0:
                parts[i] = data_axis
                return P(*parts)
        return spec

    if param_shapes is not None:
        mv = jax.tree.map(
            lambda s, t: shard_state(s, t.shape), param_specs, param_shapes
        )
    else:
        mv = jax.tree.map(shard_state, param_specs)
    return {"m": mv, "v": mv, "step": P()}
