"""Grouped direct-send exchange — the paper's Algorithm 3 / Figure 2.

The all-to-all among P processes is decoupled into W steps; at step ``w``
process ``p`` sends its chunk for destination ``r = p + w`` and receives the
chunk addressed to it from ``p - w`` (the paper's ``C_{2p-r,p}``).  Each
step is one static ``ppermute`` with shift ``w``; with group factor ``g``
(the paper's communication-group size, ``m = g + 1``), ``g`` shifts are
issued per step, so ``W = ceil((P-1)/g)`` and peak in-flight payload is
``g`` chunks.

The consume callback runs on chunks from step ``w`` while step ``w+1``'s
permutes are in flight (paper Fig. 3).  Because the shift differs per step
the schedule is unrolled (W steps of HLO) — identical to the paper, where
each step has a distinct communication group; use the relay ring
(``comm.ring``) when O(1) program size matters more than direct delivery.
"""

from __future__ import annotations

from typing import Callable

import jax

from repro.compat import axis_size

__all__ = ["grouped_exchange", "fused_exchange"]


def _shift_perm(P: int, shift: int):
    return [(i, (i + shift) % P) for i in range(P)]


def fused_exchange(
    chunks: jax.Array,
    axis_name: str,
    consume: Callable[[jax.Array, jax.Array, int], jax.Array],
    init: jax.Array,
) -> jax.Array:
    """Monolithic all-to-all then consume — the paper's Naive mode.

    ``chunks``: [P, ...] where ``chunks[q]`` is this device's payload for
    device ``q``.  ``consume(acc, chunk, src)`` folds the chunk received
    from ``src`` (static int).  All P received chunks are materialized
    before compute starts (the paper's peak-memory pathology, kept
    deliberately for the Naive baseline).
    """
    P = axis_size(axis_name)
    received = jax.lax.all_to_all(chunks, axis_name, split_axis=0, concat_axis=0)
    p = jax.lax.axis_index(axis_name)
    acc = init
    for q in range(P):
        # received[q] is the chunk sent by device q to this device
        acc = consume(acc, received[q], q)
    return acc


def grouped_exchange(
    chunks: jax.Array,
    axis_name: str,
    consume: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
    init: jax.Array,
    *,
    group_factor: int = 1,
    include_local: bool = True,
) -> jax.Array:
    """Pipelined Adaptive-Group exchange (paper Algorithm 3, large-|T| arm).

    ``chunks``: [P, ...]; ``chunks[q]`` is the payload for device ``q``
    (``chunks[p]`` is consumed locally at the cold-start stage when
    ``include_local``).  ``consume(acc, chunk, src_index)`` gets a traced
    src index.  Peak received-payload memory is ``group_factor`` chunks
    instead of P (Eq. 12); each group's sends overlap the previous group's
    consumes (Eq. 13/14).
    """
    P = axis_size(axis_name)
    p = jax.lax.axis_index(axis_name)
    g = max(1, min(group_factor, P - 1))

    acc = init
    pending = []  # list of (chunk, src) received in the in-flight group
    if include_local:
        pending.append((jax.lax.dynamic_index_in_dim(chunks, p, 0, keepdims=False), p))

    for w0 in range(1, P, g):
        shifts = [s for s in range(w0, min(w0 + g, P))]
        arrived = []
        for s in shifts:
            # send chunk for (p + s), receive the chunk addressed to us
            # from (p - s)  — one permute per group member, issued before
            # the consumes below so the transfer overlaps them.
            outgoing = jax.lax.dynamic_index_in_dim(
                chunks, (p + s) % P, 0, keepdims=False
            )
            incoming = jax.lax.ppermute(outgoing, axis_name, _shift_perm(P, s))
            arrived.append((incoming, (p - s) % P))
        for chunk, src in pending:
            acc = consume(acc, chunk, src)
        pending = arrived
    for chunk, src in pending:
        acc = consume(acc, chunk, src)
    return acc
