"""Narrow wire formats for the exchange (exact) and gradients (lossy).

Two families live here:

**Exact narrow exchange** (the counting engine, DESIGN.md §18).  DP table
entries are nonnegative integer counts stored in float32, so any slab
whose maximum fits the target integer range round-trips bit-exactly
through ``int16``/``int8``.  ``narrow_cast`` ships a slab at wire width
and appends a saturation flag (``max <= dtype max``) to the caller's
speculate-check flag list — on overflow the whole batch re-runs on a
wider twin, the same contract as compaction overflow.  Compacted slabs
additionally carry their active-row bitmaps bit-packed into extra payload
*columns* of the same wire dtype (``mask_columns``/``mask_from_columns``),
replacing the float32 slot column: the receiver re-derives slot indices
from the mask with the same deterministic ``nonzero`` the sender used.

**Lossy int8 gradient compression** (the original beyond-paper ring
reduce-scatter): per-block fp32 scales, one quantization error per hop,
used by the train loop when ``grad_compression="int8"``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

__all__ = [
    "WIRE_DTYPES",
    "WIRE_ESCALATION",
    "wire_itemsize",
    "narrow_cast",
    "widen",
    "mask_column_count",
    "mask_columns",
    "mask_from_columns",
    "int8_compress",
    "int8_decompress",
    "compressed_ring_reduce_scatter",
]

# wire_dtype -> (jnp dtype, bytes per element, max exactly-held count)
# float32 is the wide (identity) wire; int widths hold counts exactly up
# to their max, guarded by the narrow_cast saturation flag.
WIRE_DTYPES: Dict[str, tuple] = {
    "float32": (jnp.float32, 4, None),
    "int16": (jnp.int16, 2, 32767),
    "int8": (jnp.int8, 1, 127),
}

# On saturation the batch re-dispatches one rung up this ladder (the
# float32 rung still speculates on compaction; its own twin is dense).
WIRE_ESCALATION: Dict[str, str] = {"int8": "int16", "int16": "float32"}

_WORD_BITS = {"int8": 8, "int16": 16}
_WORD_UINT = {"int8": jnp.uint8, "int16": jnp.uint16}


def wire_itemsize(wire_dtype: str) -> int:
    """Bytes per exchanged element for a wire dtype name."""
    return WIRE_DTYPES[wire_dtype][1]


def narrow_cast(
    x: jax.Array, wire_dtype: str, flags: Optional[List[jax.Array]] = None
) -> jax.Array:
    """Cast a nonnegative integer-valued float32 slab to the wire dtype.

    Appends the exactness guard ``max(x) <= dtype max`` to ``flags``;
    under that flag the cast round-trips bit-exactly (the clip makes the
    overflowing trace deterministic — its result is discarded by the
    redispatch anyway).  ``float32`` is the identity.
    """
    dt, _, maxv = WIRE_DTYPES[wire_dtype]
    if maxv is None:
        return x
    if flags is not None:
        flags.append(jnp.max(x) <= maxv)
    return jnp.clip(x, 0, maxv).astype(dt)


def widen(x: jax.Array) -> jax.Array:
    """Receiver-side inverse of ``narrow_cast`` (exact for in-range ints)."""
    return x if x.dtype == jnp.float32 else x.astype(jnp.float32)


def _pack_mask_words(mask: jax.Array, wire_dtype: str) -> jax.Array:
    """[..., r] activity mask -> bit-packed words of the wire dtype."""
    wb = _WORD_BITS[wire_dtype]
    r = mask.shape[-1]
    r_pad = -(-r // wb) * wb
    bits = jnp.asarray(mask, jnp.uint32)
    if r_pad != r:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (r_pad - r,), bits.dtype)], axis=-1
        )
    bits = bits.reshape(bits.shape[:-1] + (-1, wb))
    words = jnp.sum(bits << jnp.arange(wb, dtype=jnp.uint32), axis=-1)
    wdt = WIRE_DTYPES[wire_dtype][0]
    return jax.lax.bitcast_convert_type(words.astype(_WORD_UINT[wire_dtype]), wdt)


def mask_column_count(r_len: int, cap: int, wire_dtype: str) -> int:
    """How many payload columns carry a length-``r_len`` bitmap at ``cap`` rows."""
    n_words = -(-r_len // _WORD_BITS[wire_dtype])
    return -(-n_words // cap)


def mask_columns(mask: jax.Array, cap: int, wire_dtype: str) -> jax.Array:
    """Pack ``mask [..., r]`` into ``[..., cap, ncols]`` wire-dtype columns.

    The columns concatenate onto a ``[..., cap, B]`` compact slab so the
    bitmap rides the same collective as the rows it describes.
    """
    words = _pack_mask_words(mask, wire_dtype)
    n_words = words.shape[-1]
    ncols = -(-n_words // cap)
    pad = ncols * cap - n_words
    if pad:
        words = jnp.concatenate([words, jnp.zeros(words.shape[:-1] + (pad,), words.dtype)], axis=-1)
    cols = words.reshape(words.shape[:-1] + (ncols, cap))
    return jnp.swapaxes(cols, -1, -2)


def mask_from_columns(cols: jax.Array, r_len: int, wire_dtype: str) -> jax.Array:
    """Inverse of ``mask_columns``: ``[..., cap, ncols]`` -> bool ``[..., r_len]``."""
    wb = _WORD_BITS[wire_dtype]
    n_words = -(-r_len // wb)
    flat = jnp.swapaxes(cols, -1, -2).reshape(cols.shape[:-2] + (-1,))
    u = jax.lax.bitcast_convert_type(flat[..., :n_words], _WORD_UINT[wire_dtype]).astype(jnp.uint32)
    bits = (u[..., None] >> jnp.arange(wb, dtype=jnp.uint32)) & 1
    return bits.reshape(bits.shape[:-2] + (-1,))[..., :r_len] != 0


def _shift_perm(P: int, shift: int = 1):
    return [(i, (i + shift) % P) for i in range(P)]


def int8_compress(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Flat int8 quantization with per-block scales.

    Returns (q [N], scales [N/block]) for flattened input padded to a block
    multiple by the caller.
    """
    flat = x.reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0].astype(jnp.float32)


def int8_decompress(q: jax.Array, scale: jax.Array, block: int = 256) -> jax.Array:
    flat = q.reshape(-1, block).astype(jnp.float32)
    return (flat * scale[:, None]).reshape(-1)


def compressed_ring_reduce_scatter(x: jax.Array, axis_name: str, *, block: int = 256) -> jax.Array:
    """Ring reduce-scatter with int8 payloads; input [P, chunk...] per device.

    Output: this device's fully reduced chunk (fp32).  Chunk sizes must be a
    multiple of ``block`` elements.
    """
    P = axis_size(axis_name)
    p = jax.lax.axis_index(axis_name)
    chunk_shape = x.shape[1:]
    total = 1
    for d in chunk_shape:
        total *= d
    while total % block:  # shrink block to divide small chunks
        block //= 2
    block = max(block, 1)

    def quant(c):
        return int8_compress(c.reshape(-1), block)

    def dequant(q, s):
        return int8_decompress(q, s, block).reshape(chunk_shape)

    def body(w, carry):
        q, s = carry
        q = jax.lax.ppermute(q, axis_name, _shift_perm(P))
        s = jax.lax.ppermute(s, axis_name, _shift_perm(P))
        c = (p - w - 2) % P
        acc = dequant(q, s) + jax.lax.dynamic_index_in_dim(x, c, 0, keepdims=False)
        return quant(acc)

    q0, s0 = quant(jax.lax.dynamic_index_in_dim(x, (p - 1) % P, 0, keepdims=False))
    q, s = jax.lax.fori_loop(0, P - 1, body, (q0, s0))
    return dequant(q, s)
