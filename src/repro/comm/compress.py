"""int8-compressed ring reduce-scatter (beyond-paper gradient compression).

Each hop of the ring carries the chunk quantized to int8 with a per-row
(block) fp32 scale — 4x less ICI traffic than fp32 (2x vs bf16) at the
cost of one quantization error per hop.  Dequantize-accumulate keeps the
running sum in fp32, so errors add linearly in P rather than compounding.

Used by the train loop when ``grad_compression="int8"``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

__all__ = ["int8_compress", "int8_decompress", "compressed_ring_reduce_scatter"]


def _shift_perm(P: int, shift: int = 1):
    return [(i, (i + shift) % P) for i in range(P)]


def int8_compress(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Flat int8 quantization with per-block scales.

    Returns (q [N], scales [N/block]) for flattened input padded to a block
    multiple by the caller.
    """
    flat = x.reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0].astype(jnp.float32)


def int8_decompress(q: jax.Array, scale: jax.Array, block: int = 256) -> jax.Array:
    flat = q.reshape(-1, block).astype(jnp.float32)
    return (flat * scale[:, None]).reshape(-1)


def compressed_ring_reduce_scatter(
    x: jax.Array, axis_name: str, *, block: int = 256
) -> jax.Array:
    """Ring reduce-scatter with int8 payloads; input [P, chunk...] per device.

    Output: this device's fully reduced chunk (fp32).  Chunk sizes must be a
    multiple of ``block`` elements.
    """
    P = axis_size(axis_name)
    p = jax.lax.axis_index(axis_name)
    chunk_shape = x.shape[1:]
    total = 1
    for d in chunk_shape:
        total *= d
    while total % block:  # shrink block to divide small chunks
        block //= 2
    block = max(block, 1)

    def quant(c):
        return int8_compress(c.reshape(-1), block)

    def dequant(q, s):
        return int8_decompress(q, s, block).reshape(chunk_shape)

    def body(w, carry):
        q, s = carry
        q = jax.lax.ppermute(q, axis_name, _shift_perm(P))
        s = jax.lax.ppermute(s, axis_name, _shift_perm(P))
        c = (p - w - 2) % P
        acc = dequant(q, s) + jax.lax.dynamic_index_in_dim(x, c, 0, keepdims=False)
        return quant(acc)

    q0, s0 = quant(jax.lax.dynamic_index_in_dim(x, (p - 1) % P, 0, keepdims=False))
    q, s = jax.lax.fori_loop(0, P - 1, body, (q0, s0))
    return dequant(q, s)
