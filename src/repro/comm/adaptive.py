"""Adaptive mode selection — the paper's §3.2.2 complexity model.

The paper switches between the monolithic all-to-all and the pipelined
grouped exchange based on the sub-template's computation intensity: the
pipeline wins when per-chunk compute can hide per-chunk transfer
(overlap ratio rho_w -> 1, Eq. 14) and the extra per-step latency
``alpha * W`` is amortized; the fused collective wins for small payloads
that cannot exploit overlap but do exploit full link bandwidth.

The decision is made at trace time (per sub-template / per layer), which is
the same granularity as the paper's runtime router — under SPMD the
schedule must be static anyway (DESIGN.md §10).

Costs follow the Hockney model (Eq. 8):
    T_fused    = alpha + beta * B_total + T_comp_total
    T_pipeline = W * alpha + beta * B_chunk            (cold start, Eq. 15)
                 + sum_w max(T_comp_chunk, beta * B_chunk)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

__all__ = [
    "HockneyModel",
    "V5E_ICI",
    "V5E_DCI",
    "overlap_ratio",
    "pipeline_cost",
    "fused_cost",
    "choose_mode",
]


@dataclasses.dataclass(frozen=True)
class HockneyModel:
    """alpha/beta link model + compute rate for one mesh axis."""

    alpha: float  # per-operation latency, seconds
    beta: float  # seconds per byte (1 / link bandwidth)
    flops_per_s: float  # effective compute rate of one device


# TPU v5e constants used throughout the roofline analysis: 197 TFLOP/s bf16,
# ~50 GB/s per ICI link; inter-pod DCI assumed 2x slower.  alpha from typical
# ICI collective latencies (~5 us per hop).
V5E_ICI = HockneyModel(alpha=5e-6, beta=1.0 / 50e9, flops_per_s=197e12)
V5E_DCI = HockneyModel(alpha=20e-6, beta=1.0 / 25e9, flops_per_s=197e12)


def overlap_ratio(comp_chunk_s: float, comm_chunk_s: float) -> float:
    """rho_w of Eq. 14: fraction of a chunk transfer hidden by compute."""
    if comm_chunk_s <= 0:
        return 1.0
    return min(comp_chunk_s, comm_chunk_s) / comm_chunk_s


def pipeline_cost(
    total_bytes: float,
    total_flops: float,
    P: int,
    model: HockneyModel,
    group_factor: int = 1,
) -> float:
    """Estimated wall time of the grouped pipelined exchange (Eq. 13/15)."""
    W = max(1, math.ceil((P - 1) / max(1, group_factor)))
    b_chunk = total_bytes / max(1, P - 1) * group_factor
    comp_chunk = total_flops / max(1, P) / model.flops_per_s
    comm_chunk = model.alpha + model.beta * b_chunk
    # cold start pays one full transfer; subsequent steps overlap
    return comm_chunk + sum(
        max(comp_chunk, comm_chunk) for _ in range(W - 1)
    ) + comp_chunk


def fused_cost(total_bytes: float, total_flops: float, model: HockneyModel) -> float:
    """Estimated wall time of all-to-all + full compute (no overlap)."""
    return model.alpha + model.beta * total_bytes + total_flops / model.flops_per_s


def choose_mode(
    total_bytes: float,
    total_flops: float,
    P: int,
    model: HockneyModel = V5E_ICI,
    group_factor: int = 1,
) -> Tuple[str, dict]:
    """Pick 'pipeline' or 'alltoall' for one exchange; returns diagnostics.

    ``total_bytes``: payload this device exchanges across the axis;
    ``total_flops``: compute consuming that payload on this device.
    """
    tp = pipeline_cost(total_bytes, total_flops, P, model, group_factor)
    tf = fused_cost(total_bytes, total_flops, model)
    comp_chunk = total_flops / max(1, P) / model.flops_per_s
    comm_chunk = model.alpha + model.beta * total_bytes / max(1, P - 1)
    diag = {
        "pipeline_cost_s": tp,
        "fused_cost_s": tf,
        "rho": overlap_ratio(comp_chunk, comm_chunk),
        "intensity_flops_per_byte": total_flops / max(total_bytes, 1.0),
    }
    return ("pipeline" if tp <= tf else "alltoall"), diag
