"""Adaptive mode selection — the paper's §3.2.2 complexity model.

The paper switches between the monolithic all-to-all and the pipelined
grouped exchange based on the sub-template's computation intensity: the
pipeline wins when per-chunk compute can hide per-chunk transfer
(overlap ratio rho_w -> 1, Eq. 14) and the extra per-step latency
``alpha * W`` is amortized; the fused collective wins for small payloads
that cannot exploit overlap but do exploit full link bandwidth.

The decision is made at trace time (per sub-template / per layer), which is
the same granularity as the paper's runtime router — under SPMD the
schedule must be static anyway (DESIGN.md §10).

Costs follow the Hockney model (Eq. 8):
    T_fused    = alpha + beta * B_total + T_comp_total
    T_pipeline = W * alpha + beta * B_chunk            (cold start, Eq. 15)
                 + sum_w max(T_comp_chunk, beta * B_chunk)
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, Tuple

__all__ = [
    "HockneyModel",
    "V5E_ICI",
    "V5E_DCI",
    "overlap_ratio",
    "pipeline_cost",
    "fused_cost",
    "choose_mode",
    "choose_mode_full",
    "calibrate",
]


@dataclasses.dataclass(frozen=True)
class HockneyModel:
    """alpha/beta link model + compute rate for one mesh axis."""

    alpha: float  # per-operation latency, seconds
    beta: float  # seconds per byte (1 / link bandwidth)
    flops_per_s: float  # effective compute rate of one device


# TPU v5e constants used throughout the roofline analysis: 197 TFLOP/s bf16,
# ~50 GB/s per ICI link; inter-pod DCI assumed 2x slower.  alpha from typical
# ICI collective latencies (~5 us per hop).
V5E_ICI = HockneyModel(alpha=5e-6, beta=1.0 / 50e9, flops_per_s=197e12)
V5E_DCI = HockneyModel(alpha=20e-6, beta=1.0 / 25e9, flops_per_s=197e12)


def overlap_ratio(comp_chunk_s: float, comm_chunk_s: float) -> float:
    """rho_w of Eq. 14: fraction of a chunk transfer hidden by compute."""
    if comm_chunk_s <= 0:
        return 1.0
    return min(comp_chunk_s, comm_chunk_s) / comm_chunk_s


def pipeline_cost(
    total_bytes: float,
    total_flops: float,
    P: int,
    model: HockneyModel,
    group_factor: int = 1,
) -> float:
    """Estimated wall time of the grouped pipelined exchange (Eq. 13/15)."""
    W = max(1, math.ceil((P - 1) / max(1, group_factor)))
    b_chunk = total_bytes / max(1, P - 1) * group_factor
    comp_chunk = total_flops / max(1, P) / model.flops_per_s
    comm_chunk = model.alpha + model.beta * b_chunk
    # cold start pays one full transfer; subsequent steps overlap
    return comm_chunk + sum(
        max(comp_chunk, comm_chunk) for _ in range(W - 1)
    ) + comp_chunk


def fused_cost(total_bytes: float, total_flops: float, model: HockneyModel) -> float:
    """Estimated wall time of all-to-all + full compute (no overlap)."""
    return model.alpha + model.beta * total_bytes + total_flops / model.flops_per_s


def choose_mode(
    total_bytes: float,
    total_flops: float,
    P: int,
    model: HockneyModel = V5E_ICI,
    group_factor: int = 1,
) -> Tuple[str, dict]:
    """Pick 'pipeline' or 'alltoall' for one exchange; returns diagnostics.

    ``total_bytes``: payload this device exchanges across the axis;
    ``total_flops``: compute consuming that payload on this device.
    """
    tp = pipeline_cost(total_bytes, total_flops, P, model, group_factor)
    tf = fused_cost(total_bytes, total_flops, model)
    comp_chunk = total_flops / max(1, P) / model.flops_per_s
    comm_chunk = model.alpha + model.beta * total_bytes / max(1, P - 1)
    diag = {
        "pipeline_cost_s": tp,
        "fused_cost_s": tf,
        "rho": overlap_ratio(comp_chunk, comm_chunk),
        "intensity_flops_per_byte": total_flops / max(total_bytes, 1.0),
    }
    return ("pipeline" if tp <= tf else "alltoall"), diag


def choose_mode_full(
    a2a_bytes: float,
    ring_bytes: float,
    total_flops: float,
    P: int,
    model: HockneyModel = V5E_ICI,
    group_factor: int = 1,
) -> Tuple[str, dict]:
    """Pick among all three exchange schedules for one tree node.

    ``a2a_bytes`` is what the alltoall/pipeline schedules ship (per-peer
    request slabs, compacted+compressed); ``ring_bytes`` is the ring
    relay's whole-table volume — usually larger, but the ring's O(1)-HLO
    shift overlaps every step, so it wins when compute dominates.  The
    ring is costed as a fully pipelined (group 1) schedule over its own
    byte count.
    """
    costs: Dict[str, float] = {
        "alltoall": fused_cost(a2a_bytes, total_flops, model),
        "pipeline": pipeline_cost(a2a_bytes, total_flops, P, model, group_factor),
        "ring": pipeline_cost(ring_bytes, total_flops, P, model, 1),
    }
    mode = min(costs, key=costs.get)
    comp_chunk = total_flops / max(1, P) / model.flops_per_s
    comm_chunk = model.alpha + model.beta * a2a_bytes / max(1, P - 1)
    diag = {
        "costs_s": costs,
        "predicted_s": costs[mode],
        "rho": overlap_ratio(comp_chunk, comm_chunk),
        "intensity_flops_per_byte": total_flops / max(a2a_bytes, 1.0),
    }
    return mode, diag


# one-shot probe results, keyed by (platform, device kind, axis size):
# calibration is a property of the link, not of the plan being built
_CALIBRATION_CACHE: Dict[tuple, HockneyModel] = {}


def _time_call(fn, *args, repeats: int = 3) -> float:
    """Min-of-N wall time of a jitted call (after one warmup)."""
    import jax

    jax.block_until_ready(fn(*args))  # compile + warm
    best = math.inf
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate(
    mesh,
    data_axis: str = "data",
    *,
    payload_bytes: Tuple[int, ...] = (1 << 16, 1 << 19, 1 << 22),
    repeats: int = 3,
    base: HockneyModel = V5E_ICI,
) -> HockneyModel:
    """Fit alpha/beta (and a matmul flop rate) from a measured probe.

    Times one ring-shift ``ppermute`` across ``data_axis`` at each payload
    size, least-squares fits ``t = alpha + beta * bytes``, and times a
    single [n, n] matmul for ``flops_per_s``.  Runs once per
    ``(platform, device kind, P)`` — results are cached for the process.
    On a single-device axis the assumed ``base`` model is returned
    unchanged (there is no link to measure).
    """
    import jax
    import jax.numpy as jnp

    from repro.compat import shard_map

    P = int(mesh.shape[data_axis])
    if P <= 1:
        return base
    dev = jax.devices()[0]
    cache_key = (dev.platform, getattr(dev, "device_kind", ""), P, payload_bytes)
    hit = _CALIBRATION_CACHE.get(cache_key)
    if hit is not None:
        return hit

    from jax.sharding import PartitionSpec as PS

    perm = [(i, (i + 1) % P) for i in range(P)]
    times = []
    for nbytes in payload_bytes:
        n = max(1, nbytes // 4)

        def shift(x):
            return jax.lax.ppermute(x, data_axis, perm)

        fn = jax.jit(
            shard_map(
                shift,
                mesh=mesh,
                in_specs=PS(data_axis),
                out_specs=PS(data_axis),
                check_vma=False,
            )
        )
        x = jnp.ones((P * n,), jnp.float32)
        times.append(_time_call(fn, x, repeats=repeats))
    # least-squares t = alpha + beta * S over the probe sizes
    m = len(payload_bytes)
    sx = sum(float(s) for s in payload_bytes)
    sy = sum(times)
    sxx = sum(float(s) ** 2 for s in payload_bytes)
    sxy = sum(float(s) * t for s, t in zip(payload_bytes, times))
    denom = m * sxx - sx * sx
    beta = (m * sxy - sx * sy) / denom if denom else base.beta
    alpha = (sy - beta * sx) / m
    alpha = min(max(alpha, 1e-8), 1.0)
    beta = min(max(beta, 1e-13), 1e-3)

    nmm = 512
    a = jnp.ones((nmm, nmm), jnp.float32)
    t_mm = _time_call(jax.jit(lambda u: u @ u), a, repeats=repeats)
    flops = 2.0 * nmm**3 / max(t_mm, 1e-9)
    flops = min(max(flops, 1e9), 1e16)

    fitted = HockneyModel(alpha=alpha, beta=beta, flops_per_s=flops)
    _CALIBRATION_CACHE[cache_key] = fitted
    return fitted
