"""Adaptive-Group communication library (the paper's §3.2, generalized).

The paper decomposes a monolithic all-to-all into W ring-ordered steps of
small communication groups, overlapping each step's transfer with compute on
the previously received chunk, and switches back to the fused collective
when the workload's computation intensity is too low to hide the latency.

This package provides that pattern as reusable JAX collectives (usable under
``shard_map``), consumed by three call sites:

  * the distributed counting engine (``core.distributed``) — the faithful
    reproduction;
  * MoE token dispatch (``models.moe``) — the same exchange shape applied to
    transformers (beyond paper);
  * gradient reduction (``train``) — ring reduce-scatter, optionally
    int8-compressed (beyond paper).
"""

from .ring import ring_allgather, ring_allgather_overlap, ring_reduce_scatter  # noqa: F401
from .pipelined import grouped_exchange, fused_exchange  # noqa: F401
from .adaptive import (  # noqa: F401
    HockneyModel,
    V5E_ICI,
    V5E_DCI,
    calibrate,
    choose_mode,
    choose_mode_full,
    overlap_ratio,
    pipeline_cost,
    fused_cost,
)
from .compress import (  # noqa: F401
    WIRE_DTYPES,
    WIRE_ESCALATION,
    int8_compress,
    int8_decompress,
    compressed_ring_reduce_scatter,
    mask_column_count,
    mask_columns,
    mask_from_columns,
    narrow_cast,
    widen,
    wire_itemsize,
)
