"""Ring collectives via ``ppermute`` with structural compute/comm overlap.

These are the *relay* form of the paper's pipeline: a static shift-by-one
permutation applied W = P-1 times inside a ``fori_loop`` (HLO size is
O(1) in P), double-buffered so the next hop's ``ppermute`` is issued before
the compute on the current chunk — XLA's async collective scheduler then
overlaps the DMA with the compute, which is the paper's comm-thread /
compute-threads split realized structurally (DESIGN.md §2).

The cold-start stage (paper Fig. 3, stage 0) is the local-chunk compute
issued before the first hop.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.compat import axis_size, pvary_like

__all__ = ["ring_allgather", "ring_allgather_overlap", "ring_reduce_scatter"]


def _shift_perm(P: int, shift: int = 1):
    return [(i, (i + shift) % P) for i in range(P)]


def ring_allgather(x: jax.Array, axis_name: str, *, tiled: bool = False) -> jax.Array:
    """All-gather via P-1 ring hops (reference; prefer lax.all_gather when
    no overlap is wanted — this exists to bound peak memory per step in
    callers that consume chunks immediately)."""
    P = axis_size(axis_name)
    p = jax.lax.axis_index(axis_name)

    def body(w, carry):
        out, buf = carry
        buf = jax.lax.ppermute(buf, axis_name, _shift_perm(P))
        src = (p - w - 1) % P
        out = jax.lax.dynamic_update_index_in_dim(out, buf, src, 0)
        return out, buf

    out0 = jnp.zeros((P,) + x.shape, x.dtype)
    out0 = jax.lax.dynamic_update_index_in_dim(out0, x, p, 0)
    out, _ = jax.lax.fori_loop(0, P - 1, body, (out0, x))
    if tiled:
        out = out.reshape((P * x.shape[0],) + x.shape[1:])
    return out


def ring_allgather_overlap(
    x: jax.Array,
    axis_name: str,
    combine: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
    init: jax.Array,
) -> jax.Array:
    """Pipelined all-gather-and-consume: never materializes all P chunks.

    ``combine(acc, chunk, src_index) -> acc`` is invoked once per shard, with
    the shard of device ``src_index`` (traced int32).  Peak live memory is
    ``|acc| + 2 * |chunk|`` (double buffer) versus ``|acc| + P * |chunk|``
    for gather-then-consume — the paper's Eq. 12 peak-memory reduction.

    The hop-w ``ppermute`` is issued *before* the chunk-w compute, so the
    transfer overlaps the combine (paper Fig. 3 pipeline; ratio rho_w of
    Eq. 14 is realized by XLA async scheduling).
    """
    P = axis_size(axis_name)
    p = jax.lax.axis_index(axis_name)

    def body(w, carry):
        acc, buf = carry
        nxt = jax.lax.ppermute(buf, axis_name, _shift_perm(P))  # hop w+1 in flight
        src = (p - w) % P  # buf currently holds the shard of device (p - w)
        acc = combine(acc, buf, src)  # overlaps with the permute
        return acc, nxt

    # w = 0 consumes the local shard (the paper's cold-start stage) while the
    # first hop flies; the final received chunk is consumed after the loop
    # without issuing another hop (P-1 permutes, P combines total).
    acc, buf = jax.lax.fori_loop(0, P - 1, body, (pvary_like(init, x), x))
    acc = combine(acc, buf, (p + 1) % P)
    return acc


def ring_reduce_scatter(x: jax.Array, axis_name: str, *, chunk_axis: int = 0) -> jax.Array:
    """Ring reduce-scatter: input [P, ...] per device, output chunk ``p``.

    Chunk ``c`` starts at device ``c+1`` and accumulates around the ring,
    arriving fully reduced at device ``c``.  Peak live memory is one chunk
    (plus the input), and each hop's ppermute can overlap the local add.
    """
    if chunk_axis != 0:
        x = jnp.moveaxis(x, chunk_axis, 0)
    P = axis_size(axis_name)
    p = jax.lax.axis_index(axis_name)

    def body(w, buf):
        buf = jax.lax.ppermute(buf, axis_name, _shift_perm(P))
        # after this hop, buf holds the partial sum of chunk (p - w - 2)
        c = (p - w - 2) % P
        return buf + jax.lax.dynamic_index_in_dim(x, c, 0, keepdims=False)

    # device p initiates chunk (p - 1): sends x[p-1] to p+1
    buf0 = jax.lax.dynamic_index_in_dim(x, (p - 1) % P, 0, keepdims=False)
    buf = jax.lax.fori_loop(0, P - 1, body, buf0)
    return buf
