"""Counting-as-a-service: a resident multi-tenant subgraph-count engine.

The paper amortizes one expensive counting pass across a massive graph;
this module amortizes *across a request stream*: a :class:`CountingService`
loads a graph once, keeps compiled family plans in a signature-keyed LRU
cache (extending the cross-template interning of DESIGN.md §14 to
cross-*request* reuse), admits queries from named tenants through bounded
queues with deficit-round-robin fairness, and coalesces compatible pending
requests into shared-coloring family passes — one backend dispatch serves
every request that wants the same coloring stream.

Solo-equivalence contract
-------------------------
Every request's numbers are **bit-identical** to a stand-alone
``Counter.estimate`` / ``estimate_many`` call with the same
``(key, batch, n_colors=k, n_iter, delta, target_rsd)``.  Three properties
make that hold by construction rather than by coincidence:

* the per-call key stream is prefix-stable
  (:func:`repro.core.estimator.call_key` — ``fold_in(key, i)``), so call
  ``i``'s coloring never depends on any request's total budget;
* a compiled family's per-template sample columns depend only on the rooted
  sub-template's isomorphism class and the shared color budget ``k``
  (the §14 shared-``k`` contract), never on which *other* templates rode in
  the same pass — so coalescing mates cannot perturb each other;
* per-request stopping and aggregation reuse the estimator's own
  helpers (:func:`~repro.core.estimator.relative_se`,
  :func:`~repro.core.estimator.aggregate_single`) applied to the request's
  own banked samples, including during a mid-stream join: a late request
  backfills the pass history call by call, checking the stop rule before
  each consumed call, exactly as the solo loop would have.

Scheduling and the thread model (DESIGN.md §20)
-----------------------------------------------
The deterministic core is unchanged from §17: :meth:`CountingService.step`
performs one admission round plus one pass advance, chosen by deficit
round-robin over tenants, and ``run_until_idle`` drives the loop to
quiescence — single-stepped, reproducible, what the solo-equivalence and
coalescing tests check.

Production shape is layered *on top* of that core, never instead of it:
``start()`` runs the same ``step()`` on a background **driver thread**
(``stop()`` / ``join_idle()`` manage it), every public surface —
``submit``, ``Ticket`` reads, ``cancel``, ``stats`` — is safe to call from
any thread (one service ``RLock``; the lock is *released* around each
backend dispatch so submits and cancellations stay responsive while a pass
call runs), requests carry **deadlines** (``deadline_s``/``timeout_s``)
and support **cancellation** (``ticket.cancel()``), both of which detach
the request from its coalesced pass at a call boundary and leave a
terminal ``cancelled``/``deadline_exceeded`` status plus a partial,
solo-resumable :class:`~repro.core.estimator.EstimatorState`.  Admission
is **backpressured** per tenant and globally (:class:`QueueFullError`
carries the tenant, depth/limit, and a retry-after hint; ``shed_oldest``
optionally evicts the oldest queued request instead of rejecting the new
one), and every pass call routes through a §16 :class:`Supervisor`, so a
faulted batch — raise, hang, NaN — quarantines or retries without killing
the co-riding requests or the driver thread.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.estimator import (
    EstimatorState,
    aggregate_single,
    call_key,
    median_of_means,
    niter_bound,
    num_groups_for,
    relative_se,
    run_signature,
)
from repro.core.graphs import Graph
from repro.core.supervisor import (
    QuarantinedBatch,
    RetryPolicy,
    Supervisor,
    key_fingerprint,
)
from repro.core.templates import (
    Tree,
    rooted_signature,
    template as resolve_template,
)
from repro.testing import faults

__all__ = [
    "ServiceConfig",
    "CountingService",
    "ServiceClient",
    "Ticket",
    "PlanCache",
    "ProgressUpdate",
    "QueueFullError",
    "UnsatisfiableRequestError",
    "CANCELLED",
    "DEADLINE_EXCEEDED",
    "SHED",
    "TERMINAL_STATUSES",
]

#: Ticket lifecycle: ``queued -> active -> <terminal>``.  ``done`` and
#: ``failed`` are §17's terminals; §20 adds the three control-plane ones.
CANCELLED = "cancelled"
DEADLINE_EXCEEDED = "deadline_exceeded"
SHED = "shed"
TERMINAL_STATUSES = frozenset({"done", "failed", CANCELLED, DEADLINE_EXCEEDED, SHED})


class QueueFullError(RuntimeError):
    """The service's bounded admission queue rejected a submit.

    Carries the backpressure signal the caller needs to react sensibly:
    which ``tenant`` hit which ``scope`` (``"tenant"`` or ``"service"``),
    the observed ``depth`` against the configured ``limit``, and a
    ``retry_after_s`` hint derived from the measured per-pass-call latency
    (how long the queue needs to drain one slot at the current service
    rate — a hint, not a promise).
    """

    def __init__(self, *, tenant: str, depth: int, limit: int,
                 retry_after_s: float, scope: str = "service"):
        self.tenant = tenant
        self.depth = depth
        self.limit = limit
        self.retry_after_s = retry_after_s
        self.scope = scope
        super().__init__(
            f"{scope} queue is full for tenant {tenant!r}: depth {depth} >= "
            f"limit {limit}; retry after ~{retry_after_s:.3g}s, or enable "
            f"ServiceConfig.shed_oldest to evict the oldest queued request"
        )

    def __repr__(self) -> str:
        return (f"QueueFullError(tenant={self.tenant!r}, scope={self.scope!r}, "
                f"depth={self.depth}, limit={self.limit}, "
                f"retry_after_s={self.retry_after_s:.3g})")


class UnsatisfiableRequestError(ValueError):
    """The request cannot be satisfied within the service's iteration budget.

    Raised at submit time — never discovered after hours of silent
    over-sampling — when an ``eps``-derived worst-case budget
    (:func:`~repro.core.estimator.niter_bound`, exponential in the template
    size) or an explicit ``n_iter`` exceeds ``ServiceConfig.max_iters``.
    Carries the ``tenant``, the offending ``parameter`` name and ``value``,
    and the ``limit`` it overran.
    """

    def __init__(self, message: str, *, tenant: Optional[str] = None,
                 parameter: Optional[str] = None, value: Any = None,
                 limit: Optional[int] = None):
        super().__init__(message)
        self.tenant = tenant
        self.parameter = parameter
        self.value = value
        self.limit = limit

    def __repr__(self) -> str:
        return (f"UnsatisfiableRequestError(tenant={self.tenant!r}, "
                f"parameter={self.parameter!r}, value={self.value!r}, "
                f"limit={self.limit!r})")


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Resident-service knobs.  All of these are part of service identity.

    ``batch`` is part of each request's *stream* identity (a backend call
    draws its colorings from ``(key, batch, n, k)``), so the solo-equivalent
    call must pass the same batch.  ``n_colors`` is pinned on the service,
    not per request: a fixed shared color budget is what lets any two
    requests share a coloring stream and what keeps a request's estimates
    independent of its coalescing mates.
    """

    batch: int = 8  # colorings per backend call (stream identity)
    max_iters: int = 100_000  # per-request iteration budget ceiling
    max_pending: int = 64  # bounded queue: queued + active requests
    max_active: int = 8  # requests concurrently attached to passes
    quantum: float = 1.0  # DRR deficit replenished per tenant visit
    plan_cache_capacity: int = 8  # LRU entries (compiled family plans)
    #: LRU entries of finished *results*: a re-submitted identical request
    #: (same family, key, batch, and budget — the full stream identity, so
    #: the answer is deterministic) returns the cached CountResult at
    #: submit time instead of recomputing its samples; 0 disables
    result_cache_capacity: int = 16
    seed: int = 0  # default request key = jax.random.key(seed)
    max_retries: Optional[int] = None  # pass-call retries (None = 0: no retry)
    #: bounded per-tenant queue (queued + active); None = only the global
    #: ``max_pending`` bound applies
    max_pending_per_tenant: Optional[int] = None
    #: under overload, evict the oldest *queued* request (terminal status
    #: ``"shed"``) instead of raising QueueFullError at the new submitter
    shed_oldest: bool = False
    #: per-pass-call supervisor timeout (§16 worker-thread hang detection);
    #: None disables — a genuinely hung backend then wedges its pass
    timeout_s: Optional[float] = None
    backoff_s: float = 0.05  # first-retry backoff of the pass supervisor
    poll_s: float = 0.02  # driver-thread idle poll (wake latency ceiling)


@dataclasses.dataclass(frozen=True)
class ProgressUpdate:
    """One streamed increment of a request's running estimate."""

    niter: int  # iterations banked so far
    estimates: Tuple[float, ...]  # per-template median-of-means so far
    rse: float  # worst-template relative standard error
    target_met: bool


class PlanCache:
    """Signature-keyed LRU over compiled family plans.

    Keys are :func:`~repro.core.templates.family_signature` values — order-
    insensitive, label-insensitive — so a request hits whenever *any*
    earlier request compiled the same family, regardless of template order,
    vertex labeling, or tenant.  ``get`` returns the cached entry or builds
    one via the supplied builder; eviction notifies ``on_evict`` so the
    owner can drop its own per-family state too.
    """

    def __init__(self, capacity: int, on_evict=None):
        self.capacity = max(1, int(capacity))
        self._entries: "collections.OrderedDict[tuple, dict]" = collections.OrderedDict()
        self._on_evict = on_evict
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, sig: tuple) -> bool:
        return sig in self._entries

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def get(self, sig: tuple, build) -> dict:
        entry = self._entries.get(sig)
        if entry is not None:
            self.hits += 1
            self._entries.move_to_end(sig)
            return entry
        self.misses += 1
        entry = build()
        self._entries[sig] = entry
        while len(self._entries) > self.capacity:
            _, evicted = self._entries.popitem(last=False)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(evicted)
        return entry


@dataclasses.dataclass
class _Request:
    """Internal per-request state (the public view is :class:`Ticket`)."""

    ticket: "Ticket"
    tenant: str
    trees: Tuple[Tree, ...]  # as submitted (deduplicated by signature)
    sigs: Tuple[tuple, ...]  # rooted signature per tree
    n_iter: int
    delta: float
    eps: Optional[float]
    target_rsd: Optional[float]
    key: jax.Array
    key_fp: Tuple[int, ...]
    batch: int
    samples: np.ndarray  # [done, T_req] banked per-call estimates
    deadline: Optional[float] = None  # absolute, on the service clock
    quarantined: Tuple[QuarantinedBatch, ...] = ()
    cursor: int = 0  # backend calls consumed (absolute call index)
    satisfied: bool = False  # target_rsd hit (checked before each call)

    @property
    def n_calls(self) -> int:
        return -(-self.n_iter // self.batch)

    @property
    def is_multi(self) -> bool:
        return len(self.trees) > 1


class Ticket:
    """Handle on one submitted request: status, streamed progress, result.

    Thread-safe: every field the service mutates is written under the
    ticket lock and terminal transitions set an event, so any thread can
    ``wait(timeout=)`` for completion (requires a driver — ``svc.start()``
    — or another thread stepping the service), poll ``status``/``done``,
    or read the streamed ``updates`` while the driver runs.

    ``updates`` grows by one :class:`ProgressUpdate` per consumed backend
    call — the streaming surface; ``result()`` raises until the request is
    done.  ``state()`` exports a solo-compatible
    :class:`~repro.core.estimator.EstimatorState` at any time — including
    after ``cancel()`` or a deadline expiry, which is what lets a
    ``--resume`` run pick the abandoned work back up bit-exactly — and
    ``checkpoint(dir)`` persists it where the stand-alone estimator's
    ``resume=DIR`` looks.
    """

    def __init__(self, ticket_id: int, tenant: str, templates: Tuple[str, ...]):
        self.id = ticket_id
        self.tenant = tenant
        self.templates = templates
        # queued | active | done | failed | cancelled | deadline_exceeded | shed
        self.status = "queued"
        self.updates: List[ProgressUpdate] = []
        self.error: Optional[str] = None
        self.submitted_at = time.perf_counter()
        self.finished_at: Optional[float] = None
        self._result = None
        self._request: Optional[_Request] = None
        self._service: Optional["CountingService"] = None
        self._lock = threading.Lock()
        self._done_evt = threading.Event()

    @property
    def done(self) -> bool:
        return self.status in TERMINAL_STATUSES

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at

    @property
    def progress(self) -> Optional[ProgressUpdate]:
        return self.updates[-1] if self.updates else None

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the ticket reaches a terminal status; True if it did."""
        return self._done_evt.wait(timeout)

    def cancel(self) -> bool:
        """Cancel this request; True if the cancellation took effect.

        Cooperative and call-granular: a backend call already in flight
        completes (its samples are simply not consumed for this request),
        then the request detaches from its coalesced pass — co-riding
        requests are untouched.  The ticket lands in the terminal
        ``cancelled`` status with its partial progress still exported by
        ``state()``.  Returns False when the ticket was already terminal.
        """
        svc, req = self._service, self._request
        if svc is None or req is None:
            return False
        with svc._lock:
            if self.done:
                return False
            svc._terminate(req, CANCELLED, "cancelled by caller")
            return True

    def result(self):
        """The final estimate (CountResult / MultiCountResult shaped)."""
        if self.status == "failed":
            raise RuntimeError(f"request failed: {self.error}")
        if self.status in (CANCELLED, DEADLINE_EXCEEDED, SHED):
            raise RuntimeError(
                f"request is {self.status}"
                + (f" ({self.error})" if self.error else "")
                + "; partial progress is available via state()"
            )
        if self._result is None:
            raise RuntimeError(f"request is {self.status}; drive the "
                               f"service (step/run_until_idle) first")
        return self._result

    def state(self) -> EstimatorState:
        """Solo-compatible estimator state of the banked progress."""
        if self._request is None or self._service is None:
            raise RuntimeError("request has no banked state yet")
        return self._service._export_state(self._request)

    def checkpoint(self, directory: str) -> EstimatorState:
        """Persist ``state()`` where ``--resume DIR`` finds it.

        Writes one atomic, sha256-manifested checkpoint step (the §16
        format) at the request's call cursor, so a cancelled or
        deadline-expired ticket's partial work finishes under the
        stand-alone estimator: ``Counter.estimate(..., resume=DIR)`` with
        the solo-equivalent arguments is bit-identical to a never-submitted
        solo run.
        """
        from repro.train.checkpoint import CheckpointManager

        st = self.state()
        mgr = CheckpointManager(directory, async_save=False)
        mgr.save(st.cursor, {"estimator": st.to_arrays()})
        return st

    def _finish(self, status: str, error: Optional[str] = None) -> None:
        with self._lock:
            if self.status in TERMINAL_STATUSES:
                return
            self.status = status
            if error is not None:
                self.error = error
            self.finished_at = time.perf_counter()
        self._done_evt.set()

    def __repr__(self) -> str:
        return (f"Ticket(#{self.id} {self.tenant}: "
                f"{','.join(self.templates)} [{self.status}])")


class _Pass:
    """One shared coloring stream: requests coalesced on (key, batch).

    ``history[i]`` banks call ``i``'s per-template columns by rooted
    signature (plus any quarantine record), which is what lets a late
    request join mid-stream: templates already riding the pass backfill
    for free; missing templates recompute their own columns at the same
    per-call keys (prefix-stable, so the values are the solo values).

    ``inflight`` marks a backend call dispatched with the service lock
    released (§20); the scheduler skips in-flight passes, and requests
    that join or leave meanwhile are reconciled at the call boundary.
    """

    def __init__(self, key: jax.Array, key_fp: Tuple[int, ...], batch: int):
        self.key = key
        self.key_fp = key_fp
        self.batch = batch
        self.requests: List[_Request] = []
        self.cursor = 0  # next call index
        self.history: List[dict] = []  # per call: {"cols": {sig: [b]}, "quarantine": ...}
        self.inflight = False

    def active(self) -> List[_Request]:
        return [r for r in self.requests
                if not r.satisfied and not r.ticket.done and r.cursor < r.n_calls]


class ServiceClient:
    """A tenant-bound view of a :class:`CountingService`.

    The convenience surface for callers that do not care about the
    scheduling loop: ``submit`` tags requests with the tenant name;
    ``count`` submits and drives the service until the request completes.
    """

    def __init__(self, service: "CountingService", tenant: str):
        self.service = service
        self.tenant = tenant

    def submit(self, templates, **kw) -> Ticket:
        return self.service.submit(self.tenant, templates, **kw)

    def count(self, templates, **kw):
        ticket = self.submit(templates, **kw)
        self.service.run_until(ticket)
        return ticket.result()


class CountingService:
    """A resident multi-tenant query engine over one loaded graph.

    Parameters
    ----------
    graph:
        The resident graph (loaded once; every request counts against it).
    n_colors:
        The service-wide shared color budget ``k``.  Fixed at construction:
        all passes, all plans, and all solo-equivalent calls use this
        ``k``, which is what makes coloring streams shareable and request
        results independent of coalescing.  Requests with templates larger
        than ``k`` are rejected.
    backend / plan_opts:
        Forwarded to the ``Counter`` facade — the service runs unmodified
        on the single-device and the distributed backend.
    config:
        :class:`ServiceConfig` (queue bounds, fairness, cache capacity,
        supervision, driver cadence).
    clock / sleep:
        Injectable time seams (default ``time.monotonic`` / ``time.sleep``)
        shared by request deadlines and the pass supervisor's
        backoff/timeout, so deadline- and retry-path tests run on a
        virtual clock instead of the wall.
    """

    def __init__(
        self,
        graph: Graph,
        *,
        n_colors: int,
        backend: str = "auto",
        plan_opts: Optional[Mapping[str, Any]] = None,
        config: Optional[ServiceConfig] = None,
        clock: Optional[Callable[[], float]] = None,
        sleep: Optional[Callable[[float], None]] = None,
    ):
        from repro.api import Counter
        from repro.core.templates import path_tree

        self.graph = graph
        self.k = int(n_colors)
        self.config = config or ServiceConfig()
        opts = dict(plan_opts or {})
        opts["n_colors"] = self.k
        # the facade needs a representative template; the service only ever
        # builds family plans, so any tree within the budget works
        self._counter = Counter.from_graph(
            graph, path_tree(min(2, self.k) if self.k >= 2 else 1),
            backend=backend, **opts,
        )
        self.backend = self._counter.backend
        self._clock = clock or time.monotonic
        self._sleep = sleep or time.sleep  # injectable: tests retry without waiting
        # every pass call is supervised (§16 taxonomy at the service level):
        # max_retries=None still means "no retries", but a faulted batch
        # quarantines instead of unwinding the scheduler/driver
        self._policy = RetryPolicy(
            max_retries=self.config.max_retries or 0,
            backoff_s=self.config.backoff_s,
            timeout_s=self.config.timeout_s,
        )

        def _evict(entry):
            self._counter._families.pop(entry["trees"], None)

        self.plan_cache = PlanCache(self.config.plan_cache_capacity, _evict)
        # finished-result memo: stream-identity key -> result snapshot (LRU)
        self._result_cache: "collections.OrderedDict[tuple, dict]" = (
            collections.OrderedDict()
        )
        self._rep: Dict[tuple, Tree] = {}  # rooted sig -> representative Tree
        self._passes: Dict[tuple, _Pass] = {}  # (key_fp) -> pass
        self._tenants: Dict[str, dict] = {}
        self._tenant_order: List[str] = []
        self._admit_ptr = 0
        self._drr_ptr = 0
        self._next_id = 1
        self.completed: List[Ticket] = []
        self._stats = collections.Counter()
        # ---- §20 concurrency plumbing
        self._lock = threading.RLock()
        self._driver: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._wake = threading.Event()
        self._idle_evt = threading.Event()
        self.driver_errors: List[str] = []
        self._call_ewma_s: Optional[float] = None  # measured per-pass-call latency

    # ------------------------------------------------------------ admission
    def client(self, tenant: str) -> ServiceClient:
        return ServiceClient(self, tenant)

    def set_weight(self, tenant: str, weight: float) -> None:
        """DRR weight: a tenant's deficit grows by ``quantum * weight``."""
        with self._lock:
            self._tenant(tenant)["weight"] = float(weight)

    def _tenant(self, name: str) -> dict:
        st = self._tenants.get(name)
        if st is None:
            st = self._tenants[name] = {
                "queue": collections.deque(), "active": [],
                "deficit": 0.0, "weight": 1.0, "charged": 0,
            }
            self._tenant_order.append(name)
        return st

    def _pending(self) -> int:
        return sum(len(t["queue"]) + len(t["active"]) for t in self._tenants.values())

    def _retry_after(self, depth: int) -> float:
        """Backpressure hint: time to drain one queue slot at the measured
        service rate (EWMA of pass-call latency; a coarse prior pre-first-
        call)."""
        return (self._call_ewma_s if self._call_ewma_s is not None else 0.05) * max(1, depth)

    def submit(
        self,
        tenant: str,
        templates,
        *,
        n_iter: Optional[int] = None,
        eps: Optional[float] = None,
        delta: float = 0.1,
        target_rsd: Optional[float] = None,
        key: Optional[jax.Array] = None,
        deadline_s: Optional[float] = None,
        timeout_s: Optional[float] = None,
    ) -> Ticket:
        """Admit one query ``(templates, eps/n_iter, delta, target_rsd)``.

        Validation happens here, synchronously: an oversized template, an
        unsatisfiable ``eps``, or a full queue raise immediately — the
        queue only ever holds servable work.  ``key`` defaults to the
        service seed; requests sharing a key (the default) share one
        coloring stream and coalesce into one family pass.

        ``timeout_s`` (relative to now) / ``deadline_s`` (absolute, on the
        service clock) bound the request's lifetime: past the deadline it
        detaches from its pass at the next call boundary with terminal
        status ``deadline_exceeded`` and its partial state intact.  A
        deadline already expired at submit wins over everything — even a
        result-memo hit.
        """
        if isinstance(templates, (str, Tree)):
            templates = (templates,)
        trees_raw = tuple(resolve_template(t) if isinstance(t, str) else t for t in templates)
        if not trees_raw:
            raise ValueError("submit needs at least one template")
        for t in trees_raw:
            if t.n > self.k:
                raise ValueError(
                    f"template {t.name or t.n} has {t.n} vertices; the "
                    f"service color budget is k={self.k}"
                )
        # deduplicate by rooted signature (isomorphic duplicates share a
        # column; the ticket reports the deduplicated family)
        with self._lock:
            sigs, trees = [], []
            for t in trees_raw:
                s = rooted_signature(t)
                if s not in sigs:
                    sigs.append(s)
                    trees.append(t)
                    self._rep.setdefault(s, t)
            trees, sigs = tuple(trees), tuple(sigs)

            if n_iter is None and eps is not None:
                bound_k = trees[0].n if len(trees) == 1 else self.k
                n_iter = niter_bound(bound_k, eps, delta)
                if n_iter > self.config.max_iters:
                    raise UnsatisfiableRequestError(
                        f"tenant {tenant!r}: eps={eps} (delta={delta}) needs "
                        f"{n_iter} iterations (niter_bound at k={bound_k}); "
                        f"the service budget is "
                        f"max_iters={self.config.max_iters}.  Relax eps, raise "
                        f"the budget, or pass target_rsd for empirical stopping.",
                        tenant=tenant, parameter="eps", value=eps,
                        limit=self.config.max_iters,
                    )
            if n_iter is None:
                if target_rsd is None:
                    raise ValueError("pass n_iter, eps, or target_rsd")
                n_iter = self.config.max_iters
            if n_iter > self.config.max_iters:
                raise UnsatisfiableRequestError(
                    f"tenant {tenant!r}: n_iter={n_iter} exceeds the service "
                    f"budget max_iters={self.config.max_iters}",
                    tenant=tenant, parameter="n_iter", value=int(n_iter),
                    limit=self.config.max_iters,
                )
            if key is None:
                key = jax.random.key(self.config.seed)
            deadline = deadline_s
            if timeout_s is not None:
                rel = self._clock() + timeout_s
                deadline = rel if deadline is None else min(deadline, rel)
            names = tuple(t.name or f"tree{i}" for i, t in enumerate(trees))
            ticket = Ticket(self._next_id, tenant, names)
            self._next_id += 1
            req = _Request(
                ticket=ticket,
                tenant=tenant,
                trees=trees,
                sigs=sigs,
                n_iter=int(n_iter),
                delta=float(delta),
                eps=eps,
                target_rsd=target_rsd,
                key=key,
                key_fp=key_fingerprint(key),
                batch=self.config.batch,
                samples=np.zeros((0, len(trees)), np.float64),
                deadline=deadline,
            )
            ticket._request = req
            ticket._service = self
            self._stats["submitted"] += 1
            # a dead-on-arrival deadline beats even a memoized answer: the
            # caller asked for "by then or not at all", and "not at all"
            # must be reported honestly
            if req.deadline is not None and self._clock() >= req.deadline:
                self._terminate(req, DEADLINE_EXCEEDED, "deadline already expired at submit")
                return ticket
            if self._memo_hit(req):
                return ticket
            self._admission_check(tenant)
            self._tenant(tenant)["queue"].append(req)
        self._notify_work()
        return ticket

    def _admission_check(self, tenant: str) -> None:
        """Enforce the per-tenant and global queue bounds (lock held).

        Under ``shed_oldest``, overload evicts the oldest *queued* request
        (terminal status ``shed``) instead of rejecting the submitter;
        when nothing is shed-able (everything pending is active) the
        QueueFullError still raises.
        """
        cfg = self.config
        st = self._tenant(tenant)
        limit_t = cfg.max_pending_per_tenant
        if limit_t is not None:
            depth_t = len(st["queue"]) + len(st["active"])
            if depth_t >= limit_t and not (cfg.shed_oldest and self._shed_oldest(tenant)):
                raise QueueFullError(
                    tenant=tenant,
                    depth=depth_t,
                    limit=limit_t,
                    retry_after_s=self._retry_after(depth_t),
                    scope="tenant",
                )
        depth = self._pending()
        if depth >= cfg.max_pending and not (cfg.shed_oldest and self._shed_oldest()):
            raise QueueFullError(
                tenant=tenant,
                depth=depth,
                limit=cfg.max_pending,
                retry_after_s=self._retry_after(depth),
                scope="service",
            )

    def _shed_oldest(self, tenant: Optional[str] = None) -> bool:
        """Evict the oldest queued request (scoped to ``tenant`` if given)."""
        heads = [st["queue"][0]
                 for name, st in self._tenants.items()
                 if st["queue"] and (tenant is None or name == tenant)]
        if not heads:
            return False  # everything pending is active: nothing shed-able
        victim = min(heads, key=lambda r: r.ticket.id)
        self._terminate(victim, SHED,
                        "shed by the overload policy (shed_oldest) to admit newer work")
        return True

    def _notify_work(self) -> None:
        self._idle_evt.clear()
        self._wake.set()

    # ----------------------------------------------------------- result memo
    @staticmethod
    def _memo_key(req: _Request) -> tuple:
        # the full stream identity: same family (in submission order — the
        # result's template columns follow it), same coloring stream
        # (key, batch), same budget / stopping rule.  Anything less and the
        # cached answer would differ from a recomputation.
        return (req.sigs, req.key_fp, req.batch, req.n_iter, req.delta,
                req.eps, req.target_rsd)

    def _memo_hit(self, req: _Request) -> bool:
        """Serve ``req`` from the finished-result memo; True on a hit."""
        if self.config.result_cache_capacity < 1:
            return False
        snap = self._result_cache.get(self._memo_key(req))
        if snap is None:
            self._stats["result_misses"] += 1
            return False
        self._result_cache.move_to_end(self._memo_key(req))
        self._stats["result_hits"] += 1
        t = req.ticket
        # restore the request's sampling state too, so ticket.state()
        # exports the same solo-compatible EstimatorState a recomputation
        # would have produced
        req.samples = snap["samples"].copy()
        req.cursor = snap["cursor"]
        req.satisfied = snap["satisfied"]
        t._result = snap["result"]
        t._finish("done")
        self.completed.append(t)
        return True

    def _memo_store(self, req: _Request) -> None:
        # a degraded (quarantined) answer is never memoized; neither is a
        # cancelled/expired request's partial state (its _result is None
        # and it never reaches here — guarded for belt and braces)
        if (self.config.result_cache_capacity < 1 or req.quarantined
                or req.ticket.status != "done"):
            return
        self._result_cache[self._memo_key(req)] = {
            "result": req.ticket._result,
            "samples": req.samples.copy(),
            "cursor": req.cursor,
            "satisfied": req.satisfied,
        }
        while len(self._result_cache) > self.config.result_cache_capacity:
            self._result_cache.popitem(last=False)
            self._stats["result_evictions"] += 1

    # ---------------------------------------------------------- plan cache
    def _entry_for(self, sigs: Sequence[tuple]) -> dict:
        """Compiled family plan + sample_fn for a signature set (cached)."""
        from repro.core.templates import family_signature

        canon = tuple(sorted(set(sigs)))
        trees = tuple(self._rep[s] for s in canon)
        fam_sig = family_signature(trees, n_colors=self.k)

        def build():
            st = self._counter._family(trees)
            if st["sample_fn"] is None:  # distributed: keyed shard_map
                from repro.core.distributed import keyed_sample_fn

                st["sample_fn"] = keyed_sample_fn(
                    st["plan"], self._counter._mesh, **self._counter._fn_kw
                )
            return {
                "trees": trees,
                "sample_fn": st["sample_fn"],
                "columns": {s: i for i, s in enumerate(canon)},
                "sigs": canon,
            }

        return self.plan_cache.get(fam_sig, build)

    # ------------------------------------------------------------- sampling
    def _fault_sites(self, fn):
        """Wrap a family sample_fn with the §20 service fault sites.

        ``service.slow_pass`` stalls the dispatch (the supervisor's
        per-batch timeout fires, transient); ``service.pass_poison``
        corrupts the payload with NaN (§16 hard fault, quarantined without
        retry).  Inactive sites cost one ``is None`` check.
        """

        def wrapped(key, batch):
            spec = faults.fire("service.slow_pass")
            if spec is not None:
                t = self.config.timeout_s
                self._sleep(spec.payload if spec.payload is not None else (4.0 * t if t else 0.25))
            out = np.asarray(fn(key, batch), np.float64)
            spec = faults.fire("service.pass_poison")
            if spec is not None:
                out = out.copy()
                out.reshape(-1)[0] = np.nan
            return out

        return wrapped

    def _call(self, entry: dict, key: jax.Array, batch: int, call_index: int):
        """One supervised backend dispatch over ``entry``'s family.

        Every pass call routes through a §16 :class:`Supervisor`: a raise,
        hang, or corrupt payload quarantines this one batch (or retries it
        at the SAME key, so a retried success is bit-identical) instead of
        unwinding the scheduler and every co-riding request.

        Returns ``(cols_by_sig, quarantine_record_or_None)``.
        """
        sup = Supervisor(self._fault_sites(entry["sample_fn"]), self._policy,
                         sleep=self._sleep, clock=self._clock)
        out = sup(key, batch, call_index=call_index)
        if isinstance(out, QuarantinedBatch):
            self._stats["quarantined"] += 1
            return {}, out
        out = np.asarray(out, np.float64)
        if out.ndim != 2:
            raise ValueError(f"family sample_fn must return [batch, T]; got {out.shape}")
        cols = {s: out[:, entry["columns"][s]] for s in entry["sigs"]}
        return cols, None

    def _consume(self, req: _Request, cols: Dict[tuple, np.ndarray],
                 quarantine: Optional[QuarantinedBatch]) -> None:
        """Bank one call's outcome into a request and stream an update."""
        if quarantine is not None:
            req.quarantined = req.quarantined + (quarantine,)
        else:
            chunk = np.stack([cols[s] for s in req.sigs], axis=1)
            req.samples = (chunk.copy() if req.samples.shape[0] == 0
                           else np.concatenate([req.samples, chunk], axis=0))
        req.cursor += 1
        done = int(req.samples.shape[0])
        if done:
            rse = relative_se(req.samples)
            ests = tuple(
                float(np.atleast_1d(median_of_means(
                    req.samples[:, i][: req.n_iter],
                    num_groups_for(req.delta, min(done, req.n_iter)),
                ))[0])
                for i in range(req.samples.shape[1])
            )
            req.ticket.updates.append(ProgressUpdate(
                niter=min(done, req.n_iter), estimates=ests, rse=rse,
                target_met=(req.target_rsd is not None
                            and rse <= req.target_rsd),
            ))

    def _stop_now(self, req: _Request) -> bool:
        """The solo loop's pre-call early-stop predicate, verbatim."""
        return req.target_rsd is not None and relative_se(req.samples) <= req.target_rsd

    # ------------------------------------------------------------ lifecycle
    def _expire_if_due(self, req: _Request) -> bool:
        """Terminate a past-deadline request; True when it left the flow
        (expired now, or already terminal — e.g. cancelled concurrently)."""
        if req.ticket.done:
            return True
        if req.deadline is not None and self._clock() >= req.deadline:
            self._terminate(req, DEADLINE_EXCEEDED,
                            f"deadline exceeded after {req.cursor} of "
                            f"{req.n_calls} calls")
            return True
        return False

    def _terminate(self, req: _Request, status: str, error: Optional[str] = None) -> None:
        """Move a request to a terminal control-plane status (cancelled /
        deadline_exceeded / shed): detach it from its queue, active slot,
        and coalesced pass — co-riders and the pass history are untouched,
        the mid-stream *leave* mirroring §17's mid-stream join — and keep
        its banked partial state for ``ticket.state()`` export."""
        t = req.ticket
        if t.done:
            return
        st = self._tenants.get(req.tenant)
        if st is not None:
            if req in st["queue"]:
                st["queue"].remove(req)
            if req in st["active"]:
                st["active"].remove(req)
        pa = self._passes.get(req.key_fp)
        if pa is not None and req in pa.requests:
            pa.requests.remove(req)
            if not pa.requests:
                self._maybe_drop_pass(pa)
        t._finish(status, error)
        self._stats[status] += 1
        self.completed.append(t)

    def _catch_up(self, req: _Request, pa: _Pass) -> bool:
        """Advance ``req`` through the pass's banked history — the
        mid-stream-join backfill (also run when a request joined while a
        call was in flight).  Applies the solo stop rule and the deadline
        check before each consumed call.  Returns True when the request
        reached a terminal state (and must not ride the pass further)."""
        own_entry = None
        while req.cursor < min(pa.cursor, req.n_calls):
            if self._expire_if_due(req):
                return True
            if self._stop_now(req):
                req.satisfied = True
                break
            i = req.cursor
            slot = pa.history[i]
            if slot["quarantine"] is not None:
                self._consume(req, {}, slot["quarantine"])
                continue
            have = slot["cols"]
            if all(s in have for s in req.sigs):
                self._stats["history_rides"] += 1
                self._consume(req, have, None)
                continue
            # recompute this call for the request's own family only —
            # prefix-stable keys make the values the solo values
            if own_entry is None:
                own_entry = self._entry_for(req.sigs)
            cols, q = self._call(own_entry, call_key(pa.key, i), pa.batch, call_index=i)
            self._stats["backfill_calls"] += 1
            have.update(cols)  # future joiners ride free
            self._consume(req, cols, q)
        if req.ticket.done:
            return True
        return self._finalize_if_done(req)

    def _attach(self, req: _Request) -> None:
        """Admit a request: join (or open) its key's pass, backfilling the
        pass history call by call with the solo stop rule applied before
        each consumed call — the mid-stream-join consistency contract."""
        req.ticket.status = "active"
        pa = self._passes.get(req.key_fp)
        if pa is None:
            pa = self._passes[req.key_fp] = _Pass(req.key, req.key_fp, req.batch)
        if self._catch_up(req, pa):
            if not pa.requests and not pa.active():
                self._maybe_drop_pass(pa)
            return
        pa.requests.append(req)

    def _maybe_drop_pass(self, pa: _Pass) -> None:
        if not pa.requests and not pa.inflight:
            self._passes.pop(pa.key_fp, None)

    def _finalize_if_done(self, req: _Request) -> bool:
        if req.satisfied or req.cursor >= req.n_calls:
            self._finalize(req)
            return True
        return False

    def _finalize(self, req: _Request) -> None:
        from repro.api import CountResult, MultiCountResult

        t = req.ticket
        if req.samples.reshape(-1)[: req.n_iter].shape[0] == 0:
            t._finish("failed",
                      f"all {len(req.quarantined)} batches were quarantined: "
                      + "; ".join(str(q) for q in req.quarantined))
            self._stats["failed"] += 1
            self.completed.append(t)
            self._remove_active(req)
            return
        elapsed = time.perf_counter() - t.submitted_at
        if not req.is_multi:
            mom, mean, rsd, used, ests = aggregate_single(req.samples, req.n_iter, req.delta)
            t._result = CountResult(
                estimate=mom,
                mean=mean,
                relative_sd=rsd,
                niter=used,
                samples=ests,
                backend=self.backend,
                template=t.templates[0],
                graph=self.graph.name,
                delta=req.delta,
                eps=req.eps,
                elapsed_s=elapsed,
                quarantined=req.quarantined,
            )
        else:
            from repro.core.templates import template_program

            ests = req.samples[: req.n_iter]
            used = int(ests.shape[0])
            mom = np.atleast_1d(median_of_means(ests, num_groups_for(req.delta, used)))
            means = ests.mean(axis=0)
            with np.errstate(divide="ignore", invalid="ignore"):
                rsds = np.where(means != 0, ests.std(axis=0) / np.abs(means), np.inf)
            entry = self._entry_for(req.sigs)  # cache hit: already compiled
            plan = self._counter._families[entry["trees"]]["plan"]
            dag = plan.dag if self.backend == "single" else plan.program
            t._result = MultiCountResult(
                templates=t.templates,
                estimates=mom,
                means=means,
                relative_sds=rsds,
                samples=ests,
                niter=used,
                backend=self.backend,
                graph=self.graph.name,
                k=self.k,
                unique_tables=len(dag.nodes),
                chain_tables=sum(len(template_program(tr).nodes) for tr in plan.templates),
                delta=req.delta,
                eps=req.eps,
                elapsed_s=elapsed,
                quarantined=req.quarantined,
            )
        t._finish("done")
        self._stats["completed"] += 1
        self.completed.append(t)
        self._memo_store(req)
        self._remove_active(req)

    def _remove_active(self, req: _Request) -> None:
        st = self._tenants.get(req.tenant)
        if st is not None and req in st["active"]:
            st["active"].remove(req)

    # ------------------------------------------------------------ the loop
    def _expire_sweep(self) -> None:
        """Expire past-deadline requests wherever they sit (queued work
        never touches a pass, so this is its only deadline checkpoint)."""
        for st in list(self._tenants.values()):
            for r in list(st["queue"]) + list(st["active"]):
                self._expire_if_due(r)

    def _admit_round(self) -> int:
        """Round-robin admission into free active slots."""
        n_active = sum(len(t["active"]) for t in self._tenants.values())
        admitted = 0
        order = self._tenant_order
        if not order:
            return 0
        visits = 0
        while n_active + admitted < self.config.max_active:
            if visits >= len(order):
                break
            name = order[self._admit_ptr % len(order)]
            self._admit_ptr += 1
            st = self._tenants[name]
            if not st["queue"]:
                visits += 1
                continue
            visits = 0
            req = st["queue"].popleft()
            st["active"].append(req)
            self._attach(req)  # may finalize (and deregister) immediately
            admitted += 1
        return admitted

    def _runnable(self, st: dict) -> List[_Request]:
        out = []
        for r in st["active"]:
            if r.ticket.done or r.satisfied or r.cursor >= r.n_calls:
                continue
            pa = self._passes.get(r.key_fp)
            if pa is not None and pa.inflight:
                continue  # a concurrent stepper owns this pass right now
            out.append(r)
        return out

    def step(self) -> bool:
        """One scheduling decision: admit, then advance one pass by one
        call on behalf of the deficit-round-robin-selected tenant.

        Deficit round-robin proper: each replenish round credits every
        *runnable* tenant ``quantum * weight``, and a tenant is served
        (one backend call each visit) for as long as its deficit lasts —
        a weight-3 tenant gets three calls per round to a weight-1
        tenant's one.  Idle tenants forfeit their deficit (the classic
        rule: credit never accumulates across idle periods).

        Thread-safe (the service lock is held except across the backend
        dispatch itself); the driver thread runs exactly this method.
        Returns ``False`` when the service is idle (nothing queued or
        active) — the ``run_until_idle`` termination condition.
        """
        with self._lock:
            spec = faults.fire("service.step_crash")
            if spec is not None:
                raise faults.InjectedFault("injected service step crash")
            self._expire_sweep()
            self._admit_round()
            order = self._tenant_order
            while order:
                for _ in range(len(order)):
                    name = order[self._drr_ptr % len(order)]
                    st = self._tenants[name]
                    runnable = self._runnable(st)
                    if runnable and st["deficit"] >= 1.0:
                        st["deficit"] -= 1.0
                        st["charged"] += 1
                        self._advance_pass(self._passes.get(runnable[0].key_fp))
                        self._drr_ptr += 1
                        return True
                    self._drr_ptr += 1
                # no tenant is both runnable and funded: replenish one round
                rates = []
                for name in order:
                    st = self._tenants[name]
                    if self._runnable(st):
                        inc = self.config.quantum * st["weight"]
                        st["deficit"] += inc
                        rates.append(inc)
                    else:
                        st["deficit"] = 0.0
                if not rates:
                    # nothing active; not idle while queued work remains
                    # (admission picks it up next step)
                    return self._pending() > 0
                if max(rates) <= 0:
                    raise RuntimeError(
                        "deadlock: every runnable tenant has a non-positive "
                        "DRR weight/quantum"
                    )
            return self._pending() > 0

    def _advance_pass(self, pa: _Pass) -> None:
        """One live backend call; every active request in the pass rides.

        The service lock is RELEASED across the dispatch itself (the §20
        responsiveness contract: submits, cancellations, and stats reads
        never wait on a backend call), so membership is reconciled at the
        call boundary: requests that joined while the call was in flight
        catch up through the banked history, requests that cancelled or
        expired mid-call simply do not consume it.
        """
        for r in list(pa.requests):
            if r.ticket.done or self._expire_if_due(r):
                if r in pa.requests:
                    pa.requests.remove(r)
                continue
            if r.cursor < pa.cursor:  # joined while a call was in flight
                if self._catch_up(r, pa):
                    pa.requests.remove(r)
                    continue
            if not r.satisfied and self._stop_now(r):
                r.satisfied = True
            if r.satisfied or r.cursor >= r.n_calls:
                self._finalize_if_done(r)
                pa.requests.remove(r)
        active = pa.active()
        if not active:
            self._maybe_drop_pass(pa)
            return
        union = tuple(sorted(set(s for r in active for s in r.sigs)))
        entry = self._entry_for(union)
        i = pa.cursor
        pa.inflight = True
        t0 = self._clock()
        self._lock.release()
        try:
            cols, q = self._call(entry, call_key(pa.key, i), pa.batch, call_index=i)
        finally:
            self._lock.acquire()
            pa.inflight = False
        dt = self._clock() - t0
        self._call_ewma_s = dt if self._call_ewma_s is None else 0.8 * self._call_ewma_s + 0.2 * dt
        pa.history.append({"cols": dict(cols), "quarantine": q})
        pa.cursor += 1
        self._stats["pass_calls"] += 1
        # only riders still attached at cursor i consume: a request
        # cancelled or expired while the call ran already detached
        riders = [r for r in active
                  if r in pa.requests and not r.ticket.done and r.cursor == i]
        self._stats["request_calls"] += len(riders)
        for r in riders:
            self._consume(r, cols, q)
            if self._stop_now(r):
                r.satisfied = True
            if r.satisfied or r.cursor >= r.n_calls:
                if self._finalize_if_done(r):
                    pa.requests.remove(r)
                continue
            self._expire_if_due(r)  # detaches via _terminate when due
        if not pa.requests:
            self._maybe_drop_pass(pa)

    def run_until_idle(self, max_steps: int = 1_000_000) -> List[Ticket]:
        """Drive the loop to quiescence; returns tickets completed so far.

        With a driver thread running this does not step (two schedulers
        would interleave nondeterministically) — it waits for the driver
        to drain instead.
        """
        if self.running:
            self.join_idle()
            return self.completed
        for _ in range(max_steps):
            if not self.step():
                break
        return self.completed

    def run_until(self, ticket: Ticket, max_steps: int = 1_000_000) -> Ticket:
        if self.running:
            ticket.wait()
            return ticket
        for _ in range(max_steps):
            if ticket.done or not self.step():
                break
        return ticket

    # ------------------------------------------------------- driver thread
    @property
    def running(self) -> bool:
        th = self._driver
        return th is not None and th.is_alive()

    def start(self) -> "CountingService":
        """Run the scheduling loop on a background driver thread.

        The thread drives the SAME deterministic ``step()`` the synchronous
        path uses; it parks on an event when idle (woken by ``submit``)
        and isolates scheduler faults: an exception out of ``step()`` is
        recorded in ``driver_errors`` / ``stats()['driver']`` and the
        loop continues — one poisoned scheduling round never kills the
        service (exercised by the ``service.step_crash`` fault site).
        """
        with self._lock:
            if self.running:
                return self
            self._stop_evt.clear()
            self._idle_evt.clear()
            self._driver = threading.Thread(
                target=self._drive, name="counting-service-driver", daemon=True
            )
            self._driver.start()
        return self

    def stop(self, join: bool = True, timeout: Optional[float] = None) -> None:
        """Stop the driver thread (in-flight backend call completes first)."""
        self._stop_evt.set()
        self._wake.set()
        th = self._driver
        if join and th is not None and th is not threading.current_thread():
            th.join(timeout)

    def join_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until the service is idle (no queued or active request);
        True on idle, False on timeout.  Without a driver this drains
        synchronously."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if not self.running:
                for _ in range(1_000_000):
                    if not self.step():
                        break
                return True
            if self._idle_evt.is_set():
                with self._lock:
                    if self._pending() == 0:
                        return True
            if deadline is not None and time.monotonic() >= deadline:
                return False
            self._idle_evt.wait(self.config.poll_s)

    def _drive(self) -> None:
        while not self._stop_evt.is_set():
            try:
                busy = self.step()
            except Exception as e:  # fault isolation: the driver never dies
                with self._lock:
                    self.driver_errors.append(f"{type(e).__name__}: {e}")
                    self._stats["driver_errors"] += 1
                busy = True
            if busy:
                self._idle_evt.clear()
                continue
            self._idle_evt.set()
            self._wake.wait(self.config.poll_s)
            self._wake.clear()
        self._idle_evt.set()

    # ------------------------------------------------------------ plumbing
    def _export_state(self, req: _Request) -> EstimatorState:
        """A solo-compatible EstimatorState for the request's progress.

        The signature matches what ``Counter.estimate`` (single template,
        ``n_colors=k``) / ``estimate_many`` computes for the same workload,
        so the exported state resumes under the stand-alone estimator —
        including the partial state of a cancelled or deadline-expired
        ticket, whose terminal status rides along as provenance."""
        with self._lock:
            g = self.graph
            if req.is_multi:
                names = ",".join(req.ticket.templates)
                what = f"family={names}|k={self.k}"
                extra = (f"{g.name}|V={g.n}|E={g.num_edges}|{what}|{self.backend}")
            else:
                extra = (f"{g.name}|V={g.n}|E={g.num_edges}|"
                         f"{req.ticket.templates[0]}|{self.backend}|k={self.k}")
            samples = req.samples if req.is_multi else req.samples.reshape(-1)
            return EstimatorState(
                signature=run_signature(req.n_iter, req.batch, req.delta, req.key, extra=extra),
                n_iter=req.n_iter,
                batch=req.batch,
                delta=req.delta,
                cursor=req.cursor,
                samples=samples.copy(),
                quarantined=req.quarantined,
                status=req.ticket.status,
            )

    def stats(self) -> dict:
        """Service counters: cache behavior, coalescing, fairness, volume,
        and the §20 control plane (backpressure depths, shed/cancel/expiry
        counts, driver health)."""
        with self._lock:
            s = dict(self._stats)
            pass_calls = s.get("pass_calls", 0)
            s["coalescing_factor"] = s.get("request_calls", 0) / pass_calls if pass_calls else 0.0
            s["cache"] = {
                "hits": self.plan_cache.hits,
                "misses": self.plan_cache.misses,
                "evictions": self.plan_cache.evictions,
                "hit_rate": self.plan_cache.hit_rate,
                "entries": len(self.plan_cache),
            }
            r_hits = s.get("result_hits", 0)
            r_total = r_hits + s.get("result_misses", 0)
            s["results"] = {
                "hits": r_hits,
                "misses": s.get("result_misses", 0),
                "evictions": s.get("result_evictions", 0),
                "hit_rate": r_hits / r_total if r_total else 0.0,
                "entries": len(self._result_cache),
            }
            limit_t = self.config.max_pending_per_tenant
            s["tenants"] = {}
            for name, st in self._tenants.items():
                depth = len(st["queue"]) + len(st["active"])
                limit = limit_t if limit_t is not None else self.config.max_pending
                s["tenants"][name] = {
                    "charged": st["charged"], "queued": len(st["queue"]),
                    "active": len(st["active"]), "weight": st["weight"],
                    # backpressure signals: how full this tenant's admission
                    # budget is and how long one slot takes to drain
                    "depth": depth, "limit": limit,
                    "saturation": depth / limit if limit else 0.0,
                    "retry_after_s": self._retry_after(depth),
                }
            s["driver"] = {
                "running": self.running,
                "errors": len(self.driver_errors),
            }
            return s
