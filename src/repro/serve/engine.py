"""Minimal batched serving engine: prefill once, decode in lock-step.

One jitted prefill function and one jitted decode step (the functions the
decode_* dry-run cells lower).  Requests are batched to a fixed batch size;
generation runs greedy or with temperature sampling.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.factory import Model

__all__ = ["ServeConfig", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_size: int = 2
    max_context: int = 128
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0


class ServingEngine:
    def __init__(self, model: Model, cfg: ServeConfig, params=None):
        self.model = model
        self.cfg = cfg
        self.params = params if params is not None else jax.jit(model.init_fn)(
            jax.random.key(cfg.seed)
        )
        self._prefill = jax.jit(model.prefill_fn)
        self._decode = jax.jit(model.decode_fn, donate_argnums=())

    def generate(self, prompts: np.ndarray, context: Optional[np.ndarray] = None):
        """prompts: int32 [B, L]; returns int32 [B, max_new_tokens]."""
        b, l = prompts.shape
        assert b == self.cfg.batch_size, (b, self.cfg.batch_size)
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if context is not None:
            batch["context"] = jnp.asarray(context)
        logits, caches = self._prefill(self.params, batch)
        out = []
        key = jax.random.key(self.cfg.seed)
        tok = self._sample(logits, key)
        for t in range(self.cfg.max_new_tokens):
            out.append(np.asarray(tok))
            step_batch = {
                "tokens": tok[:, None],
                "pos": jnp.asarray(l + t, jnp.int32),
                "caches": caches,
            }
            logits, caches = self._decode(self.params, step_batch)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return np.stack(out, axis=1)

    def _sample(self, logits, key):
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.cfg.temperature, axis=-1).astype(
            jnp.int32
        )
