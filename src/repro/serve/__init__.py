"""Serving substrate: prefill/decode engine with batched requests."""

from .engine import ServeConfig, ServingEngine  # noqa: F401
