"""Serving layer: the resident multi-tenant counting service."""

from .counting_service import (  # noqa: F401
    CANCELLED,
    DEADLINE_EXCEEDED,
    SHED,
    TERMINAL_STATUSES,
    CountingService,
    PlanCache,
    ProgressUpdate,
    QueueFullError,
    ServiceClient,
    ServiceConfig,
    Ticket,
    UnsatisfiableRequestError,
)

__all__ = [
    "CANCELLED",
    "DEADLINE_EXCEEDED",
    "SHED",
    "TERMINAL_STATUSES",
    "CountingService",
    "PlanCache",
    "ProgressUpdate",
    "QueueFullError",
    "ServiceClient",
    "ServiceConfig",
    "Ticket",
    "UnsatisfiableRequestError",
]
