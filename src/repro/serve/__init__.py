"""Serving layer: the resident multi-tenant counting service."""

from .counting_service import (  # noqa: F401
    CountingService,
    PlanCache,
    ProgressUpdate,
    QueueFullError,
    ServiceClient,
    ServiceConfig,
    Ticket,
    UnsatisfiableRequestError,
)

__all__ = [
    "CountingService",
    "PlanCache",
    "ProgressUpdate",
    "QueueFullError",
    "ServiceClient",
    "ServiceConfig",
    "Ticket",
    "UnsatisfiableRequestError",
]
