"""Unified counting API: one ``Counter`` facade over every backend.

The paper's workload is a single logical operation — estimate the number of
copies of a tree template in a graph to (eps, delta) — so this module
exposes exactly one front-end for it, regardless of where the counting
runs:

>>> from repro.api import Counter
>>> counter = Counter.from_graph(g, "u5-2", backend="auto")
>>> result = counter.estimate(n_iter=500, delta=0.1, key=jax.random.key(0))
>>> result.estimate, result.relative_sd

Backends
--------
``single``
    The in-core engine (:mod:`repro.core.count_engine`): batched/fused
    per-coloring DP on one device.
``distributed``
    The shard_map engine (:mod:`repro.core.distributed`): vertex-sharded
    tables, pipelined adaptive-group exchange, colorings sampled on-device
    from the iteration key.
``auto``
    ``distributed`` when more than one device is visible, else ``single``.

Both backends are adapted to one protocol — ``sample_fn(key, batch) ->
float64 [batch]`` per-coloring copy estimates — and every aggregate
(median-of-means, RSD, progress) is computed by the shared estimator
(:mod:`repro.core.estimator`), so the two stacks cannot drift apart in what
they report.  New backends (multi-host, remote, cached) only need to
implement ``sample_fn``.

Plan construction is lazy: building a ``Counter`` is cheap; the first
counting call builds and caches the backend plan and its jitted functions.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, Iterator, Mapping, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.count_engine import (
    build_counting_plan,
    build_multi_counting_plan,
    colorful_map_count,
    colorful_map_count_checked,
    colorful_map_count_many,
    colorful_map_count_many_checked,
    multi_sample_fn,
    plan_sample_fn,
)
from repro.core.estimator import (
    EstimatorState,
    estimate_counts,
    estimate_counts_many,
    niter_bound,
)
from repro.core.graphs import Graph
from repro.core.supervisor import RetryPolicy
from repro.core.templates import Tree, template_program, template as resolve_template
from repro.train.checkpoint import CheckpointManager

__all__ = [
    "CountRequest",
    "CountResult",
    "MultiCountResult",
    "Counter",
    "run",
    # serving layer (lazy re-exports; see module __getattr__)
    "CountingService",
    "ServiceClient",
    "ServiceConfig",
    "Ticket",
]

#: plan_opts understood by the single-device backend (``n_colors`` widens
#: the color budget past the template size — the shared-k contract of
#: family counting, see ``estimate_many``; ``compact``/``density_threshold``/
#: ``capacity_factor``/``probes`` drive active-frontier compaction, §15)
_SINGLE_OPTS = frozenset(
    {"root", "spmm_kind", "impl", "fuse", "tile_size", "block_size", "lane",
     "n_colors", "compact", "density_threshold", "capacity_factor", "probes"}
)
#: plan_opts understood by the distributed backend (``impl``/``fuse`` carry
#: the same kernel-routing semantics as the single-device engine;
#: ``bucket_tile`` is the §3.3 task size of the tiled bucket layout; the
#: compaction knobs compact the exchange slabs too; ``wire_dtype`` narrows
#: the exchange payload and ``adaptive`` selects the router's cost model,
#: §18)
_DIST_OPTS = frozenset(
    {"root", "bucket_tile", "num_shards", "mode", "group_factor", "impl",
     "fuse", "mesh", "data_axis", "iter_axis", "n_colors",
     "compact", "density_threshold", "capacity_factor", "probes",
     "wire_dtype", "adaptive"}
)
#: opts consumed by build_distributed_plan (rest go to make_count_fn)
_DIST_PLAN_OPTS = frozenset(
    {"root", "bucket_tile", "num_shards", "n_colors",
     "compact", "density_threshold", "capacity_factor", "probes"}
)


@dataclasses.dataclass(frozen=True)
class CountRequest:
    """A fully-specified counting job: what to count, where, how hard.

    ``plan_opts`` may carry options for either backend (e.g. a config row
    resolves to one request usable as single OR distributed); the facade
    selects the subset its chosen backend understands and rejects keys
    neither backend knows.
    """

    graph: Graph
    template: Union[str, Tree]
    backend: str = "auto"
    n_iter: Optional[int] = None
    eps: Optional[float] = None
    delta: float = 0.1
    batch: Optional[int] = None
    plan_opts: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    #: robustness spec (DESIGN.md §16): bounded retry of transient sample
    #: faults, checkpoint cadence (iterations; needs a checkpoint dir at run
    #: time), and optional early stop at a target relative standard error
    max_retries: Optional[int] = None
    checkpoint_every: int = 0
    target_rsd: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class CountResult:
    """Estimate plus the provenance needed to read it."""

    estimate: float  # median-of-means copy estimate (the paper's output)
    mean: float  # plain mean estimate
    relative_sd: float  # empirical RSD of per-iteration estimates
    niter: int
    samples: np.ndarray  # per-iteration copy estimates
    backend: str  # "single" | "distributed"
    template: str
    graph: str
    delta: float
    eps: Optional[float]
    elapsed_s: float
    #: batches the supervisor gave up on (QuarantinedBatch records) — their
    #: iterations are EXCLUDED from the aggregates above, never silently
    #: folded in; an empty tuple means every dispatched batch contributed
    quarantined: tuple = ()
    #: iterations restored from a checkpoint before this call ran (0 on a
    #: fresh run) — progress and RSD already account for them
    resumed_from: int = 0

    def __str__(self) -> str:
        extra = ""
        if self.resumed_from:
            extra += f", resumed at {self.resumed_from}"
        if self.quarantined:
            extra += f", {len(self.quarantined)} batch(es) quarantined"
        return (
            f"CountResult({self.template} in {self.graph or 'graph'}: "
            f"{self.estimate:.6g} via {self.backend}, "
            f"RSD {self.relative_sd:.2f}, {self.niter} colorings, "
            f"{self.elapsed_s:.2f}s{extra})"
        )


@dataclasses.dataclass(frozen=True)
class MultiCountResult:
    """One family run: per-template estimates from shared colorings.

    All array fields are indexed ``[template]`` (``samples`` is
    ``[niter, template]``); ``result[i]`` gives template ``i``'s view as a
    plain :class:`CountResult`.  ``unique_tables``/``chain_tables`` record
    the cross-template reuse the compiled DAG achieved: unique subtree
    tables computed per coloring vs. the sum of the per-template chains.
    """

    templates: tuple  # template names
    estimates: np.ndarray  # [T] median-of-means copy estimates
    means: np.ndarray  # [T]
    relative_sds: np.ndarray  # [T]
    samples: np.ndarray  # [niter, T] per-iteration copy estimates
    niter: int
    backend: str
    graph: str
    k: int  # shared color budget
    unique_tables: int  # nodes in the deduplicated DAG
    chain_tables: int  # sum of per-template chain nodes
    delta: float
    eps: Optional[float]
    elapsed_s: float
    quarantined: tuple = ()  # excluded batches (shared by all templates)
    resumed_from: int = 0  # iterations restored from checkpoint

    def __len__(self) -> int:
        return len(self.templates)

    def __getitem__(self, i: int) -> CountResult:
        return CountResult(
            estimate=float(self.estimates[i]),
            mean=float(self.means[i]),
            relative_sd=float(self.relative_sds[i]),
            niter=self.niter,
            samples=self.samples[:, i],
            backend=self.backend,
            template=self.templates[i],
            graph=self.graph,
            delta=self.delta,
            eps=self.eps,
            elapsed_s=self.elapsed_s,
            quarantined=self.quarantined,
            resumed_from=self.resumed_from,
        )

    def __iter__(self):
        return (self[i] for i in range(len(self)))

    def __str__(self) -> str:
        per = ", ".join(f"{t}={e:.6g}" for t, e in zip(self.templates, self.estimates))
        return (
            f"MultiCountResult({per} in {self.graph or 'graph'} via "
            f"{self.backend}, k={self.k}, {self.unique_tables}/"
            f"{self.chain_tables} unique tables, {self.niter} colorings, "
            f"{self.elapsed_s:.2f}s)"
        )


def _retry_policy(
    retry: Optional[RetryPolicy], max_retries: Optional[int]
) -> Optional[RetryPolicy]:
    if retry is not None:
        return retry
    if max_retries is not None:
        return RetryPolicy(max_retries=max_retries)
    return None


def _resolve_checkpointing(checkpoint, resume):
    """Normalize the (checkpoint, resume) knobs into (manager, state).

    ``checkpoint`` is a directory path or a ready
    :class:`~repro.train.checkpoint.CheckpointManager`; ``resume`` is a
    bool (use the checkpoint's latest readable state) or a directory path
    (which doubles as the checkpoint destination — the ``--resume DIR``
    CLI contract).  Managers built here write synchronously: estimator
    state is tiny, and a synchronous save is what makes "killed after the
    save at iteration N" a well-defined resume point.
    """
    if isinstance(resume, (str, os.PathLike)):
        checkpoint = checkpoint if checkpoint is not None else resume
        resume = True
    mgr = None
    if checkpoint is not None:
        mgr = checkpoint if isinstance(checkpoint, CheckpointManager) \
            else CheckpointManager(str(checkpoint), async_save=False)
    state = None
    if resume:
        if mgr is None:
            raise ValueError(
                "resume requires a checkpoint directory (checkpoint=DIR or "
                "resume=DIR) or a CheckpointManager"
            )
        latest = mgr.load_latest()
        if latest is not None:
            state = EstimatorState.from_arrays(latest[1]["estimator"])
    return mgr, state


def _resolve_backend(backend: str, plan_opts: Mapping[str, Any]) -> str:
    if backend == "auto":
        # an explicit mesh is an unambiguous request for the sharded engine;
        # otherwise shard only when this host actually has multiple devices
        multi = plan_opts.get("mesh") is not None or jax.device_count() > 1
        return "distributed" if multi else "single"
    if backend not in ("single", "distributed"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


class Counter:
    """Facade: one object that counts a template in a graph, anywhere.

    Construct with :meth:`from_graph` (or :meth:`from_request`); then

    * :meth:`estimate` — the (eps, delta) estimator (Algorithm 1);
    * :meth:`estimate_many` — a whole template family in one pass over the
      deduplicated subtree DAG (shared colorings, per-template estimates);
    * :meth:`count_one` — one coloring iteration from a key;
    * :meth:`count_coloring` — exact colorful map count for a FIXED
      coloring (backend-parity / oracle testing);
    * :meth:`count_coloring_many` — the family analogue, per-template;
    * :meth:`sample_stream` — endless stream of estimate batches for
      incremental consumption and serving;
    * :attr:`sample_fn` — the raw backend protocol, for compile warm-up
      and for composing with external aggregators.
    """

    def __init__(self, graph: Graph, tree: Tree, backend: str, plan_opts: Dict[str, Any]):
        self.graph = graph
        self.tree = tree
        self.backend = backend
        self.plan_opts = plan_opts
        self._plan = None
        self._mesh = None
        self._num_shards: Optional[int] = None
        self._fn_kw: Dict[str, Any] = {}
        self._plan_kw: Dict[str, Any] = {}
        self._sample_fn = None
        self._coloring_fn = None  # fixed-coloring counter (parity/oracle)
        self._families: Dict[tuple, Dict[str, Any]] = {}  # estimate_many state

    # ------------------------------------------------------------- builders
    @classmethod
    def from_graph(
        cls,
        graph: Graph,
        template: Union[str, Tree],
        *,
        backend: str = "auto",
        **plan_opts: Any,
    ) -> "Counter":
        """Build a counter for ``template`` (name or Tree) over ``graph``.

        ``plan_opts`` may mix options of both backends; keys the resolved
        backend does not understand are dropped (so one option set can feed
        either backend), but keys unknown to BOTH backends raise.
        """
        unknown = set(plan_opts) - (_SINGLE_OPTS | _DIST_OPTS)
        if unknown:
            raise TypeError(f"unknown plan_opts: {sorted(unknown)}")
        tree = resolve_template(template) if isinstance(template, str) else template
        resolved = _resolve_backend(backend, plan_opts)
        keep = _SINGLE_OPTS if resolved == "single" else _DIST_OPTS
        opts = {k: v for k, v in plan_opts.items() if k in keep}
        return cls(graph, tree, resolved, opts)

    @classmethod
    def from_request(cls, request: CountRequest) -> "Counter":
        return cls.from_graph(
            request.graph,
            request.template,
            backend=request.backend,
            **dict(request.plan_opts),
        )

    def with_options(self, **overrides: Any) -> "Counter":
        """A new Counter sharing this one's built plan, with different
        execution options (distributed backend only).

        Plan construction (edge tiling, request lists) is the expensive
        host-side step; ``with_options(mode=..., group_factor=..., impl=...,
        fuse=...)`` swaps only the communication schedule / kernel routing —
        e.g. comparing all four exchange modes costs one plan build, not
        four.  ``bucket_tile`` alone changes the §3.3 tiled bucket layout
        itself, so overriding it rebuilds the plan (lazily) instead of
        sharing it.
        """
        allowed = {"mode", "group_factor", "impl", "fuse", "iter_axis",
                   "bucket_tile", "wire_dtype", "adaptive"}
        if self.backend != "distributed":
            raise ValueError(
                f"with_options is for the distributed backend; this Counter "
                f"uses the {self.backend!r} backend"
            )
        bad = set(overrides) - allowed
        if bad:
            raise TypeError(
                f"with_options on the {self.backend!r} backend only swaps "
                f"{sorted(allowed)}; got {sorted(bad)}"
            )
        self._build_distributed()
        ax = overrides.get("iter_axis")
        if ax and ax not in self._mesh.axis_names:
            raise ValueError(
                f"iter_axis {ax!r} is not an axis of the mesh "
                f"{self._mesh.axis_names} — pass an explicit mesh containing "
                f"it to from_graph"
            )
        clone = Counter(self.graph, self.tree, self.backend, {**self.plan_opts, **overrides})
        if ("bucket_tile" in overrides and overrides["bucket_tile"] != self._plan.bucket_tile):
            return clone  # different tiling: plan rebuilds lazily
        clone._plan = self._plan
        clone._mesh = self._mesh
        fn_over = {k: v for k, v in overrides.items() if k != "bucket_tile"}
        clone._fn_kw = {**self._fn_kw, **fn_over}
        return clone

    # ------------------------------------------------------------- plumbing
    @property
    def k(self) -> int:
        return self.tree.n

    def _build_single(self):
        if self._plan is None:
            self._plan = build_counting_plan(self.graph, self.tree, **self.plan_opts)
        return self._plan

    def _dist_ctx(self):
        """Resolve the mesh, shard count, and option split ONCE — shared by
        the single-template plan and any ``estimate_many`` family plans."""
        if self._num_shards is not None:
            return
        from repro.launch.mesh import make_mesh

        opts = dict(self.plan_opts)
        mesh = self._mesh if self._mesh is not None else opts.pop("mesh", None)
        opts.pop("mesh", None)
        num_shards = opts.pop("num_shards", None)
        self._plan_kw = {k: v for k, v in opts.items() if k in _DIST_PLAN_OPTS}
        self._fn_kw = {k: v for k, v in opts.items() if k not in _DIST_PLAN_OPTS}
        data_axis = self._fn_kw.get("data_axis", "data")
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            num_shards = num_shards or sizes[data_axis]
            if num_shards != sizes[data_axis]:
                raise ValueError(
                    f"num_shards={num_shards} does not match the mesh's "
                    f"{data_axis!r} axis size {sizes[data_axis]}"
                )
        else:
            # a config may ask for more shards than this host has
            num_shards = min(num_shards or jax.device_count(),
                             jax.device_count())
            mesh = make_mesh((num_shards,), (data_axis,))
        ax = self._fn_kw.get("iter_axis")
        if ax and ax not in mesh.axis_names:
            raise ValueError(
                f"iter_axis {ax!r} is not an axis of the mesh "
                f"{mesh.axis_names} — pass an explicit mesh containing it"
            )
        self._mesh = mesh
        self._num_shards = num_shards

    def _build_distributed(self):
        if self._plan is None:
            from repro.core.distributed import build_distributed_plan

            self._dist_ctx()
            self._plan = build_distributed_plan(
                self.graph, self.tree, self._num_shards, **self._plan_kw
            )
        return self._plan

    def _iter_size(self) -> int:
        """Size of the iteration mesh axis (1 when colorings aren't sharded)."""
        ax = self._fn_kw.get("iter_axis")
        if not ax:
            return 1
        return dict(zip(self._mesh.axis_names, self._mesh.devices.shape))[ax]

    @property
    def sample_fn(self):
        """The backend protocol: ``sample_fn(key, batch) -> float64 [batch]``.

        Calling it once before timing a run warms the jit cache for that
        batch size (compile stays outside the measurement).
        """
        if self._sample_fn is None:
            if self.backend == "single":
                self._sample_fn = plan_sample_fn(self._build_single())
            else:
                from repro.core.distributed import keyed_sample_fn

                plan = self._build_distributed()
                self._sample_fn = keyed_sample_fn(plan, self._mesh, **self._fn_kw)
        return self._sample_fn

    @property
    def plan(self):
        """The lazily-built backend plan (CountingPlan or DistributedPlan)."""
        return self._build_single() if self.backend == "single" else self._build_distributed()

    @property
    def scale(self) -> float:
        """k^k / k! / |Aut| — maps colorful map counts to copy estimates."""
        return self.plan.scale

    def _signature_extra(self, *, family=None, k: Optional[int] = None) -> str:
        """Workload identity for checkpoint/resume safety.

        Deliberately does NOT include the shard count: the keyed coloring
        stream is shard-count-independent (``distributed.global_coloring``),
        so a checkpoint taken at P shards is a valid prefix of the same run
        resumed at P' — the ROADMAP elasticity contract.  A widened color
        budget (``n_colors``) DOES change the stream and is part of the
        identity.
        """
        what = f"family={','.join(family)}|k={k}" if family else self.tree.name
        extra = (f"{self.graph.name}|V={self.graph.n}|"
                 f"E={self.graph.num_edges}|{what}|{self.backend}")
        n_colors = self.plan_opts.get("n_colors")
        if not family and n_colors is not None:
            extra += f"|k={n_colors}"
        return extra

    # ------------------------------------------------------------- counting
    def estimate(
        self,
        n_iter: Optional[int] = None,
        *,
        eps: Optional[float] = None,
        delta: float = 0.1,
        key: Optional[jax.Array] = None,
        batch: Optional[int] = None,
        progress: bool = False,
        target_rsd: Optional[float] = None,
        checkpoint=None,
        checkpoint_every: int = 0,
        resume: Union[bool, str] = False,
        retry: Optional[RetryPolicy] = None,
        max_retries: Optional[int] = None,
    ) -> CountResult:
        """(eps, delta)-estimate of the copy count — Algorithm 1, any backend.

        ``n_iter`` defaults to the worst-case ``niter_bound(k, eps, delta)``
        when ``eps`` is given (beware: exponential in k); practical runs pass
        an explicit budget and read the empirical RSD, as the paper does.
        ``batch`` colorings are evaluated per backend dispatch (default 8).

        Robustness (DESIGN.md §16): ``checkpoint=DIR`` +
        ``checkpoint_every=N`` persist the estimator state every N
        iterations; ``resume=True`` (or ``resume=DIR``) continues a killed
        run from the latest readable checkpoint and returns the *same*
        result an uninterrupted run produces — progress, RSD, and the
        ``target_rsd`` early stop all start from the restored group sums,
        not from zero.  ``max_retries``/``retry`` supervise the backend:
        transient sample faults retry with backoff, corrupt payloads
        (NaN/Inf/negative) hard-fault, and persistently failing batches are
        quarantined and reported on the result.
        """
        if n_iter is None:
            if eps is None:
                raise ValueError("pass n_iter or eps (to derive the bound)")
            n_iter = niter_bound(self.k, eps, delta)
        if key is None:
            key = jax.random.key(0)
        b = batch or min(8, n_iter)
        sample = self.sample_fn  # builds the plan (and resolves shards)
        mgr, state = _resolve_checkpointing(checkpoint, resume)
        t0 = time.perf_counter()
        est = estimate_counts(
            sample,
            n_iter,
            key,
            delta=delta,
            batch=b,
            progress=progress,
            retry=_retry_policy(retry, max_retries),
            checkpoint=mgr,
            checkpoint_every=checkpoint_every,
            resume=state,
            target_rsd=target_rsd,
            signature_extra=self._signature_extra(),
        )
        elapsed = time.perf_counter() - t0
        return CountResult(
            estimate=est.estimate,
            mean=est.mean,
            relative_sd=est.relative_sd,
            niter=est.niter,
            samples=est.samples,
            backend=self.backend,
            template=self.tree.name,
            graph=self.graph.name,
            delta=delta,
            eps=eps,
            elapsed_s=elapsed,
            quarantined=est.quarantined,
            resumed_from=est.resumed_from,
        )

    def count_one(self, key: jax.Array) -> float:
        """One coloring iteration: an unbiased copy estimate from ``key``."""
        return float(self.sample_fn(key, 1)[0])

    def count_coloring(self, coloring: np.ndarray) -> float:
        """Exact colorful map count for a FIXED global coloring ``[n]``.

        This is the deterministic quantity both backends must agree on bit
        for bit (the backend-parity invariant); multiply by :attr:`scale`
        for the per-iteration copy estimate.
        """
        coloring = np.asarray(coloring, np.int32).reshape(-1)
        if coloring.shape[0] != self.graph.n:
            raise ValueError(f"coloring has {coloring.shape[0]} entries, "
                             f"graph has {self.graph.n} vertices")
        if self.backend == "single":
            plan = self._build_single()
            col = np.zeros(plan.n_pad, np.int32)
            col[: self.graph.n] = coloring
            if plan.compaction is not None and plan.compaction.enabled:
                maps, ok = colorful_map_count_checked(plan, jnp.asarray(col))
                if bool(ok):
                    return float(maps)
                # capacity overflow: recompute on the dense program
            return float(colorful_map_count(plan, jnp.asarray(col)))
        from repro.core.distributed import make_count_fn, shard_coloring

        plan = self._build_distributed()
        if self._coloring_fn is None:
            self._coloring_fn = make_count_fn(plan, self._mesh, **self._fn_kw)
        # replicate over the iteration axis (shard_map needs I divisible)
        cols = np.broadcast_to(
            shard_coloring(plan, coloring)[None],
            (self._iter_size(), plan.num_shards, plan.n_loc_pad),
        )
        return float(np.asarray(self._coloring_fn(jnp.asarray(cols)))[0])

    # ------------------------------------------------------- family counting
    def _family(self, templates) -> Dict[str, Any]:
        """Build (and cache) the shared-DAG state for a template family.

        The family is compiled once into a deduplicated
        :class:`~repro.core.templates.TemplateDag` (keyed by rooted
        canonical subtree signatures) and counted in ONE table-program pass
        per coloring on this Counter's backend — the cross-template subtree
        reuse of DESIGN.md §14.
        """
        trees = tuple(resolve_template(t) if isinstance(t, str) else t for t in templates)
        if not trees:
            raise ValueError("estimate_many needs at least one template")
        st = self._families.get(trees)
        if st is not None:
            return st
        if self.backend == "single":
            keep = {k: v for k, v in self.plan_opts.items() if k != "root"}
            plan = build_multi_counting_plan(self.graph, trees, **keep)
            st = {"plan": plan, "sample_fn": multi_sample_fn(plan), "coloring_fn": None}
        else:
            from repro.core.distributed import build_distributed_plan

            self._dist_ctx()
            plan_kw = {k: v for k, v in self._plan_kw.items() if k != "root"}
            plan = build_distributed_plan(self.graph, trees, self._num_shards, **plan_kw)
            st = {"plan": plan, "sample_fn": None, "coloring_fn": None}
        self._families[trees] = st
        return st

    def estimate_many(
        self,
        templates,
        n_iter: Optional[int] = None,
        *,
        eps: Optional[float] = None,
        delta: float = 0.1,
        key: Optional[jax.Array] = None,
        batch: Optional[int] = None,
        progress: bool = False,
        target_rsd: Optional[float] = None,
        checkpoint=None,
        checkpoint_every: int = 0,
        resume: Union[bool, str] = False,
        retry: Optional[RetryPolicy] = None,
        max_retries: Optional[int] = None,
    ) -> MultiCountResult:
        """(eps, delta)-estimates for a whole template family in one pass.

        Every coloring iteration runs the family's deduplicated DAG once:
        subtree tables shared across templates (canonically-identical
        rooted subtrees) are computed a single time and every template root
        reads its own entry — counting N related templates costs the
        unique-table work, not N chains.  All templates share one coloring
        of ``k = max template size`` colors (or ``n_colors``), and each
        gets its own unbiased scale ``k^t (k-t)!/k!/|Aut|``; per-template
        median-of-means/RSD come from the same vectorized estimator as the
        scalar path.  With the same ``key``, a per-template ``estimate`` on
        a Counter built with ``n_colors=k`` sees the identical colorings —
        the two agree sample for sample (the family-parity invariant).

        The robustness keywords (checkpoint/resume/retry/target_rsd) behave
        exactly as on :meth:`estimate`; the checkpointed state banks the
        full ``[iter, T]`` sample matrix, and ``target_rsd`` gates on the
        worst template.
        """
        st = self._family(templates)
        plan = st["plan"]
        if n_iter is None:
            if eps is None:
                raise ValueError("pass n_iter or eps (to derive the bound)")
            n_iter = niter_bound(plan.k, eps, delta)
        if key is None:
            key = jax.random.key(0)
        b = batch or min(8, n_iter)
        if st["sample_fn"] is None:  # distributed: keyed shard_map sampler
            from repro.core.distributed import keyed_sample_fn

            st["sample_fn"] = keyed_sample_fn(plan, self._mesh, **self._fn_kw)
        dag = plan.dag if self.backend == "single" else plan.program
        chain_tables = sum(len(template_program(t).nodes) for t in plan.templates)
        names = tuple(t.name or f"tree{i}" for i, t in enumerate(plan.templates))
        mgr, state = _resolve_checkpointing(checkpoint, resume)
        t0 = time.perf_counter()
        est = estimate_counts_many(
            st["sample_fn"],
            n_iter,
            key,
            delta=delta,
            batch=b,
            progress=progress,
            retry=_retry_policy(retry, max_retries),
            checkpoint=mgr,
            checkpoint_every=checkpoint_every,
            resume=state,
            target_rsd=target_rsd,
            signature_extra=self._signature_extra(family=names, k=plan.k),
        )
        elapsed = time.perf_counter() - t0
        return MultiCountResult(
            templates=names,
            estimates=est.estimates,
            means=est.means,
            relative_sds=est.relative_sds,
            samples=est.samples,
            niter=est.niter,
            backend=self.backend,
            graph=self.graph.name,
            k=plan.k,
            unique_tables=len(dag.nodes),
            chain_tables=chain_tables,
            delta=delta,
            eps=eps,
            elapsed_s=elapsed,
            quarantined=est.quarantined,
            resumed_from=est.resumed_from,
        )

    def count_coloring_many(self, templates, coloring: np.ndarray) -> np.ndarray:
        """Exact per-template colorful map counts for a FIXED coloring.

        The family analogue of :meth:`count_coloring` (the deterministic
        backend-parity quantity): one shared-DAG pass, float64
        ``[num_templates]``; multiply by the family plan's ``scales`` for
        copy estimates.  The coloring must use the family's shared color
        budget ``k``.
        """
        st = self._family(templates)
        plan = st["plan"]
        coloring = np.asarray(coloring, np.int32).reshape(-1)
        if coloring.shape[0] != self.graph.n:
            raise ValueError(f"coloring has {coloring.shape[0]} entries, "
                             f"graph has {self.graph.n} vertices")
        if self.backend == "single":
            col = np.zeros(plan.n_pad, np.int32)
            col[: self.graph.n] = coloring
            if plan.compaction is not None and plan.compaction.enabled:
                maps, ok = colorful_map_count_many_checked(plan, jnp.asarray(col))
                if bool(ok):
                    return np.asarray(maps, np.float64)
            return np.asarray(colorful_map_count_many(plan, jnp.asarray(col)), np.float64)
        from repro.core.distributed import make_count_fn, shard_coloring

        if st["coloring_fn"] is None:
            st["coloring_fn"] = make_count_fn(plan, self._mesh, **self._fn_kw)
        cols = np.broadcast_to(
            shard_coloring(plan, coloring)[None],
            (self._iter_size(), plan.num_shards, plan.n_loc_pad),
        )
        return np.asarray(st["coloring_fn"](jnp.asarray(cols)), np.float64)[0]

    def sample_stream(
        self, key: Optional[jax.Array] = None, *, batch: int = 8
    ) -> Iterator[np.ndarray]:
        """Endless stream of per-coloring estimate batches (float64 [batch]).

        For incremental/serving use: consume until the caller's own
        convergence criterion is met, feed a live dashboard, etc.  The key
        is split per step, so the stream is reproducible from ``key``.
        """
        if key is None:
            key = jax.random.key(0)
        while True:
            key, sub = jax.random.split(key)
            yield self.sample_fn(sub, batch)

    # ---------------------------------------------------------------- serving
    def serve(self, *, n_colors: Optional[int] = None, config=None,
              start: bool = False, **config_kw):
        """A resident :class:`~repro.serve.CountingService` on this graph.

        The service loads the graph once and serves a multi-tenant request
        stream: plan-cache reuse across requests, coalesced coloring
        passes, per-tenant fair scheduling (see DESIGN.md §17), and the §20
        hardening — driver thread, deadlines/cancellation, backpressure,
        supervised passes.  It runs with a fixed shared color budget —
        ``n_colors`` defaults to this Counter's own
        (``plan_opts['n_colors']`` or the template size), and every
        request's results are bit-identical to a solo
        ``Counter.estimate``/``estimate_many`` at that budget.

        ``start=True`` launches the background driver thread before
        returning; any extra keyword (``max_pending=...``,
        ``shed_oldest=True``, ``timeout_s=...``) builds the
        :class:`~repro.serve.ServiceConfig` in place of ``config``.
        """
        from repro.serve import CountingService, ServiceConfig

        if config_kw:
            if config is not None:
                raise ValueError("pass config= or ServiceConfig kwargs, not both")
            config = ServiceConfig(**config_kw)
        k = n_colors or self.plan_opts.get("n_colors") or self.k
        opts = {key: v for key, v in self.plan_opts.items() if key != "n_colors"}
        svc = CountingService(
            self.graph,
            n_colors=k,
            backend=self.backend,
            plan_opts=opts,
            config=config,
        )
        return svc.start() if start else svc


def __getattr__(name):
    # lazy serving re-exports: repro.serve imports repro.api at module
    # scope, so the reverse edge must resolve at attribute time
    if name in ("CountingService", "ServiceClient", "ServiceConfig", "Ticket",
                "QueueFullError", "UnsatisfiableRequestError"):
        import repro.serve as _serve

        return getattr(_serve, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def run(
    request: CountRequest,
    *,
    key: Optional[jax.Array] = None,
    progress: bool = False,
    checkpoint=None,
    resume: Union[bool, str] = False,
) -> CountResult:
    """One-shot: resolve a :class:`CountRequest` and run its estimate.

    The request's robustness spec (``max_retries``, ``checkpoint_every``,
    ``target_rsd``) applies; ``checkpoint``/``resume`` name where the state
    lives, since a directory is a property of the invocation, not of the
    workload.
    """
    counter = Counter.from_request(request)
    return counter.estimate(
        request.n_iter,
        eps=request.eps,
        delta=request.delta,
        key=key,
        batch=request.batch,
        progress=progress,
        max_retries=request.max_retries,
        target_rsd=request.target_rsd,
        checkpoint=checkpoint,
        checkpoint_every=request.checkpoint_every,
        resume=resume,
    )
