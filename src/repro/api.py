"""Unified counting API: one ``Counter`` facade over every backend.

The paper's workload is a single logical operation — estimate the number of
copies of a tree template in a graph to (eps, delta) — so this module
exposes exactly one front-end for it, regardless of where the counting
runs:

>>> from repro.api import Counter
>>> counter = Counter.from_graph(g, "u5-2", backend="auto")
>>> result = counter.estimate(n_iter=500, delta=0.1, key=jax.random.key(0))
>>> result.estimate, result.relative_sd

Backends
--------
``single``
    The in-core engine (:mod:`repro.core.count_engine`): batched/fused
    per-coloring DP on one device.
``distributed``
    The shard_map engine (:mod:`repro.core.distributed`): vertex-sharded
    tables, pipelined adaptive-group exchange, colorings sampled on-device
    from the iteration key.
``auto``
    ``distributed`` when more than one device is visible, else ``single``.

Both backends are adapted to one protocol — ``sample_fn(key, batch) ->
float64 [batch]`` per-coloring copy estimates — and every aggregate
(median-of-means, RSD, progress) is computed by the shared estimator
(:mod:`repro.core.estimator`), so the two stacks cannot drift apart in what
they report.  New backends (multi-host, remote, cached) only need to
implement ``sample_fn``.

Plan construction is lazy: building a ``Counter`` is cheap; the first
counting call builds and caches the backend plan and its jitted functions.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterator, Mapping, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.count_engine import build_counting_plan, colorful_map_count, plan_sample_fn
from repro.core.estimator import estimate_counts, niter_bound
from repro.core.graphs import Graph
from repro.core.templates import Tree, template as resolve_template

__all__ = ["CountRequest", "CountResult", "Counter", "run"]

#: plan_opts understood by the single-device backend
_SINGLE_OPTS = frozenset(
    {"root", "spmm_kind", "impl", "fuse", "tile_size", "block_size", "lane"}
)
#: plan_opts understood by the distributed backend (``impl``/``fuse`` carry
#: the same kernel-routing semantics as the single-device engine;
#: ``bucket_tile`` is the §3.3 task size of the tiled bucket layout)
_DIST_OPTS = frozenset(
    {"root", "bucket_tile", "num_shards", "mode", "group_factor", "impl",
     "fuse", "mesh", "data_axis", "iter_axis"}
)
#: opts consumed by build_distributed_plan (rest go to make_count_fn)
_DIST_PLAN_OPTS = frozenset({"root", "bucket_tile", "num_shards"})


@dataclasses.dataclass(frozen=True)
class CountRequest:
    """A fully-specified counting job: what to count, where, how hard.

    ``plan_opts`` may carry options for either backend (e.g. a config row
    resolves to one request usable as single OR distributed); the facade
    selects the subset its chosen backend understands and rejects keys
    neither backend knows.
    """

    graph: Graph
    template: Union[str, Tree]
    backend: str = "auto"
    n_iter: Optional[int] = None
    eps: Optional[float] = None
    delta: float = 0.1
    batch: Optional[int] = None
    plan_opts: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class CountResult:
    """Estimate plus the provenance needed to read it."""

    estimate: float  # median-of-means copy estimate (the paper's output)
    mean: float  # plain mean estimate
    relative_sd: float  # empirical RSD of per-iteration estimates
    niter: int
    samples: np.ndarray  # per-iteration copy estimates
    backend: str  # "single" | "distributed"
    template: str
    graph: str
    delta: float
    eps: Optional[float]
    elapsed_s: float

    def __str__(self) -> str:
        return (
            f"CountResult({self.template} in {self.graph or 'graph'}: "
            f"{self.estimate:.6g} via {self.backend}, "
            f"RSD {self.relative_sd:.2f}, {self.niter} colorings, "
            f"{self.elapsed_s:.2f}s)"
        )


def _resolve_backend(backend: str, plan_opts: Mapping[str, Any]) -> str:
    if backend == "auto":
        # an explicit mesh is an unambiguous request for the sharded engine;
        # otherwise shard only when this host actually has multiple devices
        multi = plan_opts.get("mesh") is not None or jax.device_count() > 1
        return "distributed" if multi else "single"
    if backend not in ("single", "distributed"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


class Counter:
    """Facade: one object that counts a template in a graph, anywhere.

    Construct with :meth:`from_graph` (or :meth:`from_request`); then

    * :meth:`estimate` — the (eps, delta) estimator (Algorithm 1);
    * :meth:`count_one` — one coloring iteration from a key;
    * :meth:`count_coloring` — exact colorful map count for a FIXED
      coloring (backend-parity / oracle testing);
    * :meth:`sample_stream` — endless stream of estimate batches for
      incremental consumption and serving;
    * :attr:`sample_fn` — the raw backend protocol, for compile warm-up
      and for composing with external aggregators.
    """

    def __init__(self, graph: Graph, tree: Tree, backend: str,
                 plan_opts: Dict[str, Any]):
        self.graph = graph
        self.tree = tree
        self.backend = backend
        self.plan_opts = plan_opts
        self._plan = None
        self._mesh = None
        self._fn_kw: Dict[str, Any] = {}
        self._sample_fn = None
        self._coloring_fn = None  # fixed-coloring counter (parity/oracle)

    # ------------------------------------------------------------- builders
    @classmethod
    def from_graph(
        cls,
        graph: Graph,
        template: Union[str, Tree],
        *,
        backend: str = "auto",
        **plan_opts: Any,
    ) -> "Counter":
        """Build a counter for ``template`` (name or Tree) over ``graph``.

        ``plan_opts`` may mix options of both backends; keys the resolved
        backend does not understand are dropped (so one option set can feed
        either backend), but keys unknown to BOTH backends raise.
        """
        unknown = set(plan_opts) - (_SINGLE_OPTS | _DIST_OPTS)
        if unknown:
            raise TypeError(f"unknown plan_opts: {sorted(unknown)}")
        tree = resolve_template(template) if isinstance(template, str) else template
        resolved = _resolve_backend(backend, plan_opts)
        keep = _SINGLE_OPTS if resolved == "single" else _DIST_OPTS
        opts = {k: v for k, v in plan_opts.items() if k in keep}
        return cls(graph, tree, resolved, opts)

    @classmethod
    def from_request(cls, request: CountRequest) -> "Counter":
        return cls.from_graph(
            request.graph, request.template, backend=request.backend,
            **dict(request.plan_opts),
        )

    def with_options(self, **overrides: Any) -> "Counter":
        """A new Counter sharing this one's built plan, with different
        execution options (distributed backend only).

        Plan construction (edge tiling, request lists) is the expensive
        host-side step; ``with_options(mode=..., group_factor=..., impl=...,
        fuse=...)`` swaps only the communication schedule / kernel routing —
        e.g. comparing all four exchange modes costs one plan build, not
        four.  ``bucket_tile`` alone changes the §3.3 tiled bucket layout
        itself, so overriding it rebuilds the plan (lazily) instead of
        sharing it.
        """
        allowed = {"mode", "group_factor", "impl", "fuse", "iter_axis",
                   "bucket_tile"}
        if self.backend != "distributed":
            raise ValueError(
                f"with_options is for the distributed backend; this Counter "
                f"uses the {self.backend!r} backend"
            )
        bad = set(overrides) - allowed
        if bad:
            raise TypeError(
                f"with_options on the {self.backend!r} backend only swaps "
                f"{sorted(allowed)}; got {sorted(bad)}"
            )
        self._build_distributed()
        ax = overrides.get("iter_axis")
        if ax and ax not in self._mesh.axis_names:
            raise ValueError(
                f"iter_axis {ax!r} is not an axis of the mesh "
                f"{self._mesh.axis_names} — pass an explicit mesh containing "
                f"it to from_graph"
            )
        clone = Counter(self.graph, self.tree, self.backend,
                        {**self.plan_opts, **overrides})
        if ("bucket_tile" in overrides
                and overrides["bucket_tile"] != self._plan.bucket_tile):
            return clone  # different tiling: plan rebuilds lazily
        clone._plan = self._plan
        clone._mesh = self._mesh
        fn_over = {k: v for k, v in overrides.items() if k != "bucket_tile"}
        clone._fn_kw = {**self._fn_kw, **fn_over}
        return clone

    # ------------------------------------------------------------- plumbing
    @property
    def k(self) -> int:
        return self.tree.n

    def _build_single(self):
        if self._plan is None:
            self._plan = build_counting_plan(self.graph, self.tree, **self.plan_opts)
        return self._plan

    def _build_distributed(self):
        if self._plan is None:
            from repro.core.distributed import build_distributed_plan
            from repro.launch.mesh import make_mesh

            opts = dict(self.plan_opts)
            mesh = opts.pop("mesh", None)
            num_shards = opts.pop("num_shards", None)
            plan_kw = {k: v for k, v in opts.items() if k in _DIST_PLAN_OPTS}
            self._fn_kw = {k: v for k, v in opts.items() if k not in _DIST_PLAN_OPTS}
            data_axis = self._fn_kw.get("data_axis", "data")
            if mesh is not None:
                sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
                num_shards = num_shards or sizes[data_axis]
                if num_shards != sizes[data_axis]:
                    raise ValueError(
                        f"num_shards={num_shards} does not match the mesh's "
                        f"{data_axis!r} axis size {sizes[data_axis]}"
                    )
            else:
                # a config may ask for more shards than this host has
                num_shards = min(num_shards or jax.device_count(),
                                 jax.device_count())
                mesh = make_mesh((num_shards,), (data_axis,))
            ax = self._fn_kw.get("iter_axis")
            if ax and ax not in mesh.axis_names:
                raise ValueError(
                    f"iter_axis {ax!r} is not an axis of the mesh "
                    f"{mesh.axis_names} — pass an explicit mesh containing it"
                )
            self._mesh = mesh
            self._plan = build_distributed_plan(
                self.graph, self.tree, num_shards, **plan_kw
            )
        return self._plan

    def _iter_size(self) -> int:
        """Size of the iteration mesh axis (1 when colorings aren't sharded)."""
        ax = self._fn_kw.get("iter_axis")
        if not ax:
            return 1
        return dict(zip(self._mesh.axis_names, self._mesh.devices.shape))[ax]

    @property
    def sample_fn(self):
        """The backend protocol: ``sample_fn(key, batch) -> float64 [batch]``.

        Calling it once before timing a run warms the jit cache for that
        batch size (compile stays outside the measurement).
        """
        if self._sample_fn is None:
            if self.backend == "single":
                self._sample_fn = plan_sample_fn(self._build_single())
            else:
                from repro.core.distributed import keyed_sample_fn

                plan = self._build_distributed()
                self._sample_fn = keyed_sample_fn(plan, self._mesh, **self._fn_kw)
        return self._sample_fn

    @property
    def plan(self):
        """The lazily-built backend plan (CountingPlan or DistributedPlan)."""
        return (self._build_single() if self.backend == "single"
                else self._build_distributed())

    @property
    def scale(self) -> float:
        """k^k / k! / |Aut| — maps colorful map counts to copy estimates."""
        return self.plan.scale

    # ------------------------------------------------------------- counting
    def estimate(
        self,
        n_iter: Optional[int] = None,
        *,
        eps: Optional[float] = None,
        delta: float = 0.1,
        key: Optional[jax.Array] = None,
        batch: Optional[int] = None,
        progress: bool = False,
    ) -> CountResult:
        """(eps, delta)-estimate of the copy count — Algorithm 1, any backend.

        ``n_iter`` defaults to the worst-case ``niter_bound(k, eps, delta)``
        when ``eps`` is given (beware: exponential in k); practical runs pass
        an explicit budget and read the empirical RSD, as the paper does.
        ``batch`` colorings are evaluated per backend dispatch (default 8).
        """
        if n_iter is None:
            if eps is None:
                raise ValueError("pass n_iter or eps (to derive the bound)")
            n_iter = niter_bound(self.k, eps, delta)
        if key is None:
            key = jax.random.key(0)
        b = batch or min(8, n_iter)
        t0 = time.perf_counter()
        est = estimate_counts(
            self.sample_fn, n_iter, key, delta=delta, batch=b, progress=progress
        )
        elapsed = time.perf_counter() - t0
        return CountResult(
            estimate=est.estimate,
            mean=est.mean,
            relative_sd=est.relative_sd,
            niter=est.niter,
            samples=est.samples,
            backend=self.backend,
            template=self.tree.name,
            graph=self.graph.name,
            delta=delta,
            eps=eps,
            elapsed_s=elapsed,
        )

    def count_one(self, key: jax.Array) -> float:
        """One coloring iteration: an unbiased copy estimate from ``key``."""
        return float(self.sample_fn(key, 1)[0])

    def count_coloring(self, coloring: np.ndarray) -> float:
        """Exact colorful map count for a FIXED global coloring ``[n]``.

        This is the deterministic quantity both backends must agree on bit
        for bit (the backend-parity invariant); multiply by :attr:`scale`
        for the per-iteration copy estimate.
        """
        coloring = np.asarray(coloring, np.int32).reshape(-1)
        if coloring.shape[0] != self.graph.n:
            raise ValueError(f"coloring has {coloring.shape[0]} entries, "
                             f"graph has {self.graph.n} vertices")
        if self.backend == "single":
            plan = self._build_single()
            col = np.zeros(plan.n_pad, np.int32)
            col[: self.graph.n] = coloring
            return float(colorful_map_count(plan, jnp.asarray(col)))
        from repro.core.distributed import make_count_fn, shard_coloring

        plan = self._build_distributed()
        if self._coloring_fn is None:
            self._coloring_fn = make_count_fn(plan, self._mesh, **self._fn_kw)
        # replicate over the iteration axis (shard_map needs I divisible)
        cols = np.broadcast_to(
            shard_coloring(plan, coloring)[None],
            (self._iter_size(), plan.num_shards, plan.n_loc_pad),
        )
        return float(np.asarray(self._coloring_fn(jnp.asarray(cols)))[0])

    def sample_stream(
        self, key: Optional[jax.Array] = None, *, batch: int = 8
    ) -> Iterator[np.ndarray]:
        """Endless stream of per-coloring estimate batches (float64 [batch]).

        For incremental/serving use: consume until the caller's own
        convergence criterion is met, feed a live dashboard, etc.  The key
        is split per step, so the stream is reproducible from ``key``.
        """
        if key is None:
            key = jax.random.key(0)
        while True:
            key, sub = jax.random.split(key)
            yield self.sample_fn(sub, batch)


def run(
    request: CountRequest,
    *,
    key: Optional[jax.Array] = None,
    progress: bool = False,
) -> CountResult:
    """One-shot: resolve a :class:`CountRequest` and run its estimate."""
    counter = Counter.from_request(request)
    return counter.estimate(
        request.n_iter, eps=request.eps, delta=request.delta, key=key,
        batch=request.batch, progress=progress,
    )
