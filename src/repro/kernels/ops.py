"""Jit'd dispatch wrappers for the Pallas kernels, with XLA fallbacks.

Every op has three implementations selected by ``impl``:

* ``"xla"`` — pure-jnp path (scatter/segment/einsum); the default off-TPU
  and the semantics oracle (it *is* ``ref.py`` modulo padding plumbing).
* ``"pallas"`` — the Pallas kernel, compiled on TPU, ``interpret=True``
  elsewhere (so CPU tests execute the actual kernel body).
* ``"auto"`` — pallas on TPU backends, xla otherwise.

Sparse ops consume a prebuilt :class:`SpmmPlan` (host-side preprocessing of
the graph into padded edge lists / block patches) so that jitted code sees
only static shapes.

Padding conventions (hardware-true even in interpret mode):
  * vertex dimension padded to a multiple of 128, ``n_pad > n`` strictly, so
    row ``n`` is a writable zero sentinel;
  * count-table column dimension padded to a multiple of 128; engine
    re-masks pad rows/cols after each combine (kernels may write garbage
    there).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import pvary_like

from . import ref
from .color_combine import color_combine_pallas
from .flash_attention import flash_attention_pallas
from .fused_count import fused_count_pallas, fused_count_xla
from .spmm_edgetile import spmm_block_pallas, spmm_edge_tile_pallas

__all__ = [
    "on_tpu",
    "resolve_impl",
    "pad_to",
    "SpmmPlan",
    "build_spmm_plan",
    "build_slab_layout",
    "build_bucket_tiles",
    "expected_patch_density",
    "spmm",
    "spmm_compact",
    "spmm_slabs",
    "CombineTables",
    "build_combine_tables",
    "color_combine",
    "fused_count",
    "fused_count_compact",
    "fused_count_slabs",
    "flash_attention",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if on_tpu() else "xla"
    return impl


_resolve = resolve_impl


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# SpMM (neighbor sum)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpmmPlan:
    """Static preprocessing of a graph for the neighbor-sum op.

    ``kind``: 'edges' (XLA scatter / Pallas edge-tiled gather) or 'blocks'
    (block-dense Pallas); ``"auto"`` at build time picks one from measured
    patch density.  All index arrays are np/jnp int32, padded; the sentinel
    row is ``n`` (< n_pad).

    The 'edges' plan carries two layouts of the same edge list:

    * flat ``rows``/``cols`` [E_pad] — XLA segment-sum path and oracles;
    * slab ``slab_dst``/``slab_cols`` [NRB * slabs_per_block, tile_size] —
      the paper's bounded neighbor-list tasks (§3.3): slabs of exactly
      ``tile_size`` edges grouped under the ``row_tile``-row output block
      of their destinations, consumed by ``spmm_edge_tile_pallas`` and the
      fused SpMM->combine kernels.  ``slab_dst`` holds block-local dst rows
      (-1 for pad slots), ``slab_cols`` global src rows (sentinel for pads).
    """

    kind: str
    n: int
    n_pad: int
    rows: Optional[jax.Array] = None  # [E_pad]
    cols: Optional[jax.Array] = None  # [E_pad]
    block_rows: Optional[jax.Array] = None  # [NB]
    block_cols: Optional[jax.Array] = None  # [NB]
    patches: Optional[jax.Array] = None  # [NB, VB, KB]
    block_size: int = 128
    #: rows the kernel actually writes (zero-degree rows are never visited
    #: by the block kernel, so its output there must be masked off)
    written_mask: Optional[jax.Array] = None  # bool [n_pad]
    # --- edge-slab layout (kind == 'edges') ---
    slab_dst: Optional[jax.Array] = None  # [NRB * spb, tile_size]
    slab_cols: Optional[jax.Array] = None  # [NRB * spb, tile_size]
    slabs_per_block: int = 0
    tile_size: int = 128
    row_tile: int = 128
    #: measured edges per occupied 128x128 patch (set by kind='auto')
    patch_density: Optional[float] = None


#: 'auto' picks the block-dense plan once occupied 128x128 patches average
#: this many edges: at that density one patch matmul (128 rows x B lanes per
#: nnz) costs about the same MXU time as the edge-slab scatter matmuls for
#: the same edges, and the dense-patch storage (64 KB) stops dominating the
#: slab metadata (8 B/edge).
AUTO_DENSITY_THRESHOLD = 64.0


def build_slab_layout(
    rows: np.ndarray,
    cols: np.ndarray,
    n_pad: int,
    tile_size: int,
    row_tile: int,
    *,
    sentinel_col: int,
    slabs_per_block: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Cut a (dst-sorted) edge list into uniform tile_size-edge slabs
    grouped by ``row_tile``-row destination block (the paper's §3.3
    bounded-task layout).

    ``rows`` are destination rows in ``[0, n_pad)``; ``cols`` may index any
    source table (the graph's own vertex table, or a concatenated exchange
    buffer in the distributed engine) — pad slots carry ``dst = -1`` and
    ``sentinel_col`` (which must name an all-zero source row).
    ``slabs_per_block`` forces a larger uniform slab count per block (so
    layouts built per shard can share one shape across shards).
    """
    nrb = n_pad // row_tile
    blk = rows // row_tile
    counts = np.bincount(blk, minlength=nrb)
    spb = max(1, int(-(-counts.max(initial=0) // tile_size)))
    if slabs_per_block is not None:
        assert slabs_per_block >= spb, (slabs_per_block, spb)
        spb = slabs_per_block
    slab_dst = np.full((nrb, spb * tile_size), -1, np.int32)
    slab_cols = np.full((nrb, spb * tile_size), sentinel_col, np.int32)
    starts = np.zeros(nrb, np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    pos = np.arange(len(rows)) - starts[blk]  # rows sorted => in-block rank
    slab_dst[blk, pos] = (rows % row_tile).astype(np.int32)
    slab_cols[blk, pos] = cols.astype(np.int32)
    return (
        slab_dst.reshape(nrb * spb, tile_size),
        slab_cols.reshape(nrb * spb, tile_size),
        spb,
    )


def build_bucket_tiles(
    bucket: np.ndarray,
    dst: np.ndarray,
    srcs: Tuple[np.ndarray, ...],
    num_buckets: int,
    tile_size: int,
    *,
    dst_sentinel: int,
    src_sentinels: Tuple[int, ...],
    num_tiles: Optional[int] = None,
) -> Tuple[np.ndarray, Tuple[np.ndarray, ...], np.ndarray]:
    """Cut a bucketed edge list into fixed-size tiles with CSR offsets.

    This is the §3.3 neighbor-list partitioning applied to the distributed
    engine's (src-shard) buckets: every bucket ``q`` becomes
    ``ceil(count_q / tile_size)`` tiles of exactly ``tile_size`` slots, laid
    out back to back, so storage is ``O(edges + num_buckets * tile_size)``
    — independent of the largest bucket — and every consume task is one
    uniform tile.  ``bucket`` must be nondecreasing (edges pre-sorted by
    bucket).  ``srcs`` is a tuple of parallel per-edge source-index arrays
    (the distributed plan carries both a shard-local and a compact-slot
    view of the same edges); each gets its own sentinel for pad slots.

    Returns ``(tile_dst [T, tile], tuple of tile_src [T, tile],
    tile_off [num_buckets + 1])``; ``num_tiles`` pads T to a caller-chosen
    value (uniform shape across shards).
    """
    counts = np.bincount(bucket, minlength=num_buckets)
    tiles_per = -(-counts // tile_size)  # ceil; empty buckets take 0 tiles
    tile_off = np.zeros(num_buckets + 1, np.int32)
    np.cumsum(tiles_per, out=tile_off[1:])
    t_need = int(tile_off[-1])
    t = t_need if num_tiles is None else num_tiles
    assert t >= t_need, (t, t_need)
    tile_dst = np.full((t, tile_size), dst_sentinel, np.int32)
    tile_srcs = tuple(np.full((t, tile_size), s, np.int32) for s in src_sentinels)
    starts = np.zeros(num_buckets, np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    # in-bucket rank -> (tile, slot); buckets own disjoint tile ranges
    rank = np.arange(len(bucket)) - starts[bucket]
    tidx = tile_off[bucket] + rank // tile_size
    slot = rank % tile_size
    tile_dst[tidx, slot] = dst.astype(np.int32)
    for out, src in zip(tile_srcs, srcs):
        out[tidx, slot] = src.astype(np.int32)
    return tile_dst, tile_srcs, tile_off


def _build_slabs(
    rows: np.ndarray,
    cols: np.ndarray,
    n: int,
    n_pad: int,
    tile_size: int,
    row_tile: int,
) -> Tuple[np.ndarray, np.ndarray, int]:
    return build_slab_layout(rows, cols, n_pad, tile_size, row_tile, sentinel_col=n)


def build_spmm_plan(
    rows: np.ndarray,
    cols: np.ndarray,
    n: int,
    *,
    kind: str = "edges",
    block_size: int = 128,
    tile_size: int = 128,
    row_tile: int = 128,
) -> SpmmPlan:
    """Build a plan from a directed edge list (rows sorted nondecreasing).

    ``tile_size`` is the paper's neighbor-list task size ``s`` — every slab
    of ``tile_size`` edge slots is one uniform unit of work regardless of
    degree skew.  ``kind="auto"`` measures the graph's density over occupied
    128x128 adjacency patches and picks 'blocks' (dense-patch MXU SpMM) for
    dense graphs, 'edges' (edge-tiled gather) for sparse ones — the
    GraphBLAS-style storage/format adaptivity.
    """
    n_pad = pad_to(n + 1, 128)
    sentinel = n
    e = len(rows)
    density = None
    if kind == "auto":
        if e:
            occupied = len(
                np.unique(
                    (rows // block_size).astype(np.int64) * (n_pad // block_size)
                    + cols // block_size
                )
            )
            density = e / occupied
        else:
            density = 0.0
        kind = "blocks" if density >= AUTO_DENSITY_THRESHOLD else "edges"
    if kind == "edges":
        e_pad = max(pad_to(e, tile_size), tile_size)
        r = np.full(e_pad, sentinel, np.int32)
        c = np.full(e_pad, sentinel, np.int32)
        r[:e] = rows
        c[:e] = cols
        written = np.zeros(n_pad, bool)
        written[r] = True
        slab_dst, slab_cols, spb = _build_slabs(
            np.asarray(rows), np.asarray(cols), n, n_pad, tile_size, row_tile
        )
        return SpmmPlan(
            "edges",
            n,
            n_pad,
            rows=jnp.asarray(r),
            cols=jnp.asarray(c),
            written_mask=jnp.asarray(written),
            slab_dst=jnp.asarray(slab_dst),
            slab_cols=jnp.asarray(slab_cols),
            slabs_per_block=spb,
            tile_size=tile_size,
            row_tile=row_tile,
            patch_density=density,
        )
    if kind == "blocks":
        vb = kb = block_size
        br = rows // vb
        bc = cols // kb
        key = br.astype(np.int64) * (n_pad // kb + 1) + bc
        uniq, inv = np.unique(key, return_inverse=True)
        nb = len(uniq)
        patches = np.zeros((nb, vb, kb), np.float32)
        patches[inv, rows % vb, cols % kb] += 1.0
        block_rows = (uniq // (n_pad // kb + 1)).astype(np.int32)
        block_cols = (uniq % (n_pad // kb + 1)).astype(np.int32)
        # append one sentinel (all-zero) patch so NB >= 1 and the final
        # output block flushes; sentinel row block = n_pad // vb.
        block_rows = np.concatenate([block_rows, [n_pad // vb]]).astype(np.int32)
        block_cols = np.concatenate([block_cols, [0]]).astype(np.int32)
        patches = np.concatenate([patches, np.zeros((1, vb, kb), np.float32)], 0)
        written = np.zeros(n_pad, bool)
        for rb in block_rows[:-1]:
            written[rb * vb : (rb + 1) * vb] = True
        return SpmmPlan(
            "blocks",
            n,
            n_pad,
            block_rows=jnp.asarray(block_rows),
            block_cols=jnp.asarray(block_cols),
            patches=jnp.asarray(patches),
            block_size=block_size,
            written_mask=jnp.asarray(written),
            patch_density=density,
        )
    raise ValueError(f"unknown spmm plan kind {kind!r}")


def expected_patch_density(n: int, e_directed: int, block: int = 128) -> float:
    """Model of the ``kind="auto"`` patch-density signal for shape-only
    plans (dry-run cells, where no edges exist to measure): expected edges
    per occupied ``block x block`` adjacency patch under uniform placement,
    ``E[occupied] = patches * (1 - exp(-e / patches))``.  Real plans carry
    the measured value in :attr:`SpmmPlan.patch_density` instead."""
    import math

    nb = max(1, pad_to(n + 1, block) // block)
    patches = float(nb) * float(nb)
    occupied = patches * (1.0 - math.exp(-float(e_directed) / patches))
    return float(e_directed) / max(occupied, 1.0)


def spmm(plan: SpmmPlan, table: jax.Array, impl: str = "auto") -> jax.Array:
    """Neighbor sum ``M[v] = sum_{(v,u) in E} table[u]``.

    ``table``: [n_pad, B_pad]; returns [n_pad, B_pad].  Rows >= plan.n of the
    input must be zero; output rows >= plan.n are unspecified (engine masks).
    """
    impl = _resolve(impl)
    n_pad, b = table.shape
    assert n_pad == plan.n_pad, (n_pad, plan.n_pad)
    if plan.kind == "edges":
        if impl == "xla":
            out = jax.ops.segment_sum(table[plan.cols], plan.rows, num_segments=plan.n_pad)
            return out
        # edge-tiled kernel writes every output block (pad slabs contribute
        # zeros), so zero-degree rows come out correctly zeroed
        return spmm_edge_tile_pallas(
            plan.slab_dst,
            plan.slab_cols,
            table,
            slabs_per_block=plan.slabs_per_block,
            row_tile=plan.row_tile,
            interpret=not on_tpu(),
        )
    # blocks
    if impl == "xla":
        # dense-block einsum fallback (oracle for the block kernel)
        kb = plan.block_size
        gathered = table.reshape(n_pad // kb, kb, b)[plan.block_cols]  # [NB,KB,B]
        prod = jnp.einsum("nvk,nkb->nvb", plan.patches, gathered)
        out = jnp.zeros((n_pad // kb + 1, kb, b), table.dtype)
        out = out.at[plan.block_rows].add(prod)
        return out[: n_pad // kb].reshape(n_pad, b)
    nb_rows = plan.n_pad // plan.block_size
    out = spmm_block_pallas(
        plan.block_rows,
        plan.block_cols,
        plan.patches,
        table,
        num_row_blocks=nb_rows,
        interpret=not on_tpu(),
    )[: plan.n_pad]
    return jnp.where(plan.written_mask[:, None], out, 0)


def spmm_compact(
    plan: SpmmPlan,
    table_c: jax.Array,  # [cap, B] compact source (active rows gathered)
    inv: jax.Array,  # [n_pad] int32 row -> compact slot (inactive -> zero slot)
    impl: str = "auto",
) -> jax.Array:
    """Neighbor sum driven through a row-index indirection: the same edge
    program as :func:`spmm`, but every source lookup goes ``row -> inv ->
    compact slot``, so the gathered table is the ``[cap, B]`` active-row
    form — inactive rows are never touched, and the Pallas kernel's
    VMEM-resident table shrinks from ``n_pad`` to ``cap`` rows.  Exact:
    rows outside the compact form are all-zero by construction, which is
    precisely what the dense gather would have contributed.

    Requires an edge plan (``kind == 'edges'``).  Returns ``[n_pad, B]``;
    output rows >= plan.n are unspecified (engine masks).
    """
    impl = _resolve(impl)
    assert plan.kind == "edges", "spmm_compact needs the edge-slab layout"
    if impl == "xla":
        gathered = jnp.take(table_c, jnp.take(inv, plan.cols), axis=0)
        return jax.ops.segment_sum(gathered, plan.rows, num_segments=plan.n_pad)
    return spmm_edge_tile_pallas(
        plan.slab_dst,
        jnp.take(inv, plan.slab_cols),
        table_c,
        slabs_per_block=plan.slabs_per_block,
        row_tile=plan.row_tile,
        out_rows=plan.n_pad,
        interpret=not on_tpu(),
    )


def spmm_slabs(
    slab_dst: jax.Array,  # [NRB * spb, tile] int32 block-local dst (-1 pad)
    slab_cols: jax.Array,  # [NRB * spb, tile] int32 rows of `table`
    table: jax.Array,  # [C, B] source table; sentinel cols must be zero rows
    *,
    out_rows: int,
    slabs_per_block: int,
    row_tile: int = 128,
    impl: str = "auto",
) -> jax.Array:
    """Neighbor sum over an explicit slab layout — the rectangular form of
    :func:`spmm` where the source table need not be the output table.

    The distributed engine routes its all-to-all consume through here: the
    slab columns index a ``[P * r_pad, B]`` concatenation of the received
    exchange chunks, while the output is this shard's ``[out_rows, B]``
    neighbor sum — the same edge-tile kernel as the single-device engine,
    one uniform ``tile``-edge task per grid step.  Returns [out_rows, B].

    ``table`` may arrive at narrow wire width (int16/int8 — the compressed
    exchange, DESIGN.md §18); it is widened to float32 here, once, so both
    kernel paths keep their float32 contract.
    """
    impl = _resolve(impl)
    if table.dtype != jnp.float32:
        table = table.astype(jnp.float32)
    num_slabs, tile = slab_dst.shape
    nrb = out_rows // row_tile
    assert num_slabs == nrb * slabs_per_block, (num_slabs, nrb, slabs_per_block)
    if impl == "xla":
        blk = (jnp.arange(num_slabs, dtype=jnp.int32) // slabs_per_block) * row_tile
        dst_g = jnp.where(slab_dst < 0, out_rows, slab_dst + blk[:, None])
        gathered = jnp.take(table, slab_cols.reshape(-1), axis=0)
        return jax.ops.segment_sum(
            gathered, dst_g.reshape(-1), num_segments=out_rows + 1
        )[:out_rows]
    return spmm_edge_tile_pallas(
        slab_dst,
        slab_cols,
        table,
        slabs_per_block=slabs_per_block,
        row_tile=row_tile,
        out_rows=out_rows,
        interpret=not on_tpu(),
    )


# ---------------------------------------------------------------------------
# Color-set combine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CombineTables:
    """Padded split tables for one partition node."""

    idx1: jax.Array  # [S, J] int32 (xla layout)
    idx2: jax.Array
    idx1_t: jax.Array  # [J_pad, S_pad] int32 (pallas layout)
    idx2_t: jax.Array
    s: int  # true output width C(k, t)
    j: int  # true split count C(t, t1)
    s_pad: int


def build_combine_tables(
    k: int, t1: int, t2: int, *, lane: int = 128, sublane: int = 8
) -> CombineTables:
    """``lane``/``sublane`` set the column/row padding multiples.

    The Pallas kernels need the TPU-native 128/8; the XLA paths work at any
    width, and ``lane=1`` (true table widths) saves the 12.8x column-padding
    waste of small tables (e.g. the k-wide leaf tables) on CPU/GPU.
    """
    from repro.core.colorsets import split_tables

    idx1, idx2 = split_tables(k, t1, t2)
    s, j = idx1.shape
    s_pad = pad_to(s, lane)
    j_pad = pad_to(j, sublane)
    idx1_t = np.zeros((j_pad, s_pad), np.int32)
    idx2_t = np.zeros((j_pad, s_pad), np.int32)
    idx1_t[:j, :s] = idx1.T
    idx2_t[:j, :s] = idx2.T
    return CombineTables(
        idx1=jnp.asarray(idx1),
        idx2=jnp.asarray(idx2),
        idx1_t=jnp.asarray(idx1_t),
        idx2_t=jnp.asarray(idx2_t),
        s=s,
        j=j,
        s_pad=s_pad,
    )


def color_combine(
    left: jax.Array,  # [n_pad, A_pad]
    m: jax.Array,  # [n_pad, B_pad]
    tables: CombineTables,
    impl: str = "auto",
    xla_chunk: int = 8,
) -> jax.Array:
    """``out[v, s] = sum_j left[v, idx1[s,j]] * m[v, idx2[s,j]]``.

    Returns [n_pad, S_pad]; pad rows/cols are unspecified (engine masks).
    """
    impl = _resolve(impl)
    if impl == "xla":
        n = left.shape[0]
        s, j = tables.idx1.shape
        # bound the [n, S, j_chunk] gather intermediate to ~2^27 elements
        # (the paper's bounded-intermediate principle, §3.2.1 / Eq. 7)
        budget = 1 << 27
        if n * s * j <= budget:
            out = ref.color_combine_ref(left, m, tables.idx1, tables.idx2)
        else:
            xla_chunk = max(1, min(xla_chunk, budget // max(n * s, 1)))

            # j-chunked accumulation to bound the [n, S, j] intermediate
            def body(jc, acc):
                i1 = jax.lax.dynamic_slice(tables.idx1, (0, jc), (s, xla_chunk))
                i2 = jax.lax.dynamic_slice(tables.idx2, (0, jc), (s, xla_chunk))
                return acc + jnp.einsum("vsj,vsj->vs", left[:, i1], m[:, i2])

            # iterate full chunks; handle the ragged tail separately
            acc = pvary_like(jnp.zeros((n, s), left.dtype), left)
            full = (j // xla_chunk) * xla_chunk
            acc = jax.lax.fori_loop(
                0,
                full // xla_chunk,
                lambda c, a: body(c * xla_chunk, a),
                acc,
            )
            if full < j:
                i1 = tables.idx1[:, full:]
                i2 = tables.idx2[:, full:]
                acc = acc + jnp.einsum("vsj,vsj->vs", left[:, i1], m[:, i2])
            out = acc
        s_out = tables.s_pad
        if out.shape[1] < s_out:
            out = jnp.pad(out, ((0, 0), (0, s_out - out.shape[1])))
        return out
    return color_combine_pallas(
        left,
        m,
        tables.idx1_t,
        tables.idx2_t,
        num_splits=tables.j,
        interpret=not on_tpu(),
    )


# ---------------------------------------------------------------------------
# Fused SpMM -> combine (fine-grained pipeline, §3.2)
# ---------------------------------------------------------------------------


def fused_count(
    plan: SpmmPlan,
    left: jax.Array,  # [n_pad, A_pad]
    right: jax.Array,  # [n_pad, B_pad]; rows >= plan.n must be zero
    tables: CombineTables,
    impl: str = "auto",
) -> jax.Array:
    """``out[v, s] = sum_j left[v, idx1[s,j]] * (A @ right)[v, idx2[s,j]]``
    without materializing the full neighbor-sum table ``M = A @ right``.

    Requires the edge-slab layout (``plan.kind == 'edges'``); a block plan
    falls back to the two-step spmm + combine path.  Returns
    ``[n_pad, S_pad]``; pad rows/cols are unspecified (engine masks).
    """
    impl = _resolve(impl)
    if plan.slab_dst is None:
        m = spmm(plan, right, impl=impl)
        mask = (jnp.arange(plan.n_pad) < plan.n).astype(m.dtype)[:, None]
        return color_combine(left, m * mask, tables, impl=impl)
    if impl == "xla":
        out = fused_count_xla(
            plan.slab_dst,
            plan.slab_cols,
            left,
            right,
            tables.idx1,
            tables.idx2,
            row_tile=plan.row_tile,
        )
        if out.shape[1] < tables.s_pad:
            out = jnp.pad(out, ((0, 0), (0, tables.s_pad - out.shape[1])))
        return out
    return fused_count_pallas(
        plan.slab_dst,
        plan.slab_cols,
        left,
        right,
        tables.idx1_t,
        tables.idx2_t,
        num_splits=tables.j,
        slabs_per_block=plan.slabs_per_block,
        row_tile=plan.row_tile,
        interpret=not on_tpu(),
    )


def fused_count_compact(
    plan: SpmmPlan,
    left: jax.Array,  # [n_pad, A_pad]
    right_c: jax.Array,  # [cap, B] compact right table (active rows)
    inv: jax.Array,  # [n_pad] int32 row -> compact slot (inactive -> zero slot)
    tables: CombineTables,
    impl: str = "auto",
) -> jax.Array:
    """:func:`fused_count` with the right operand in compact active-row
    form, routed through the same row-index indirection as
    :func:`spmm_compact` — the fused kernel's resident source table shrinks
    to ``cap`` rows and ``M`` still never materializes.  Requires the
    edge-slab layout.  Returns ``[n_pad, S_pad]`` (engine masks pads)."""
    impl = _resolve(impl)
    assert plan.slab_dst is not None, "fused_count_compact needs edge slabs"
    cols_c = jnp.take(inv, plan.slab_cols)
    if impl == "xla":
        out = fused_count_xla(
            plan.slab_dst,
            cols_c,
            left,
            right_c,
            tables.idx1,
            tables.idx2,
            row_tile=plan.row_tile,
        )
        if out.shape[1] < tables.s_pad:
            out = jnp.pad(out, ((0, 0), (0, tables.s_pad - out.shape[1])))
        return out
    return fused_count_pallas(
        plan.slab_dst,
        cols_c,
        left,
        right_c,
        tables.idx1_t,
        tables.idx2_t,
        num_splits=tables.j,
        slabs_per_block=plan.slabs_per_block,
        row_tile=plan.row_tile,
        interpret=not on_tpu(),
    )


def fused_count_slabs(
    slab_dst: jax.Array,  # [NRB * spb, tile] int32 block-local dst (-1 pad)
    slab_cols: jax.Array,  # [NRB * spb, tile] int32 rows of `right`
    left: jax.Array,  # [out_rows, A]
    right: jax.Array,  # [C, B] source table; sentinel cols must be zero rows
    tables: CombineTables,
    *,
    slabs_per_block: int,
    row_tile: int = 128,
    impl: str = "auto",
) -> jax.Array:
    """Rectangular form of :func:`fused_count` over an explicit slab layout.

    ``right`` may be any source table (the distributed engine passes the
    concatenated all-to-all exchange buffer); the ``[out_rows, B]`` neighbor
    sum is never materialized — each ``row_tile`` block of it lives only as
    the kernel scratch (or one ``lax.map`` step on XLA) before being
    contracted against the resident ``left`` block.  Returns
    ``[out_rows, S_pad]``; pad rows/cols unspecified (engine masks).

    ``right`` may arrive at narrow wire width (the compressed exchange,
    DESIGN.md §18); it is widened to float32 here, once, before dispatch.
    """
    impl = _resolve(impl)
    if right.dtype != jnp.float32:
        right = right.astype(jnp.float32)
    if impl == "xla":
        out = fused_count_xla(
            slab_dst,
            slab_cols,
            left,
            right,
            tables.idx1,
            tables.idx2,
            row_tile=row_tile,
        )
        if out.shape[1] < tables.s_pad:
            out = jnp.pad(out, ((0, 0), (0, tables.s_pad - out.shape[1])))
        return out
    return fused_count_pallas(
        slab_dst,
        slab_cols,
        left,
        right,
        tables.idx1_t,
        tables.idx2_t,
        num_splits=tables.j,
        slabs_per_block=slabs_per_block,
        row_tile=row_tile,
        interpret=not on_tpu(),
    )


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        interpret=not on_tpu(),
    )
