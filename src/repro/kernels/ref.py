"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth for kernel allclose tests and the default compute
path on non-TPU backends (the dry-run and CPU tests never execute Pallas
except in interpret mode).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "spmm_ref",
    "spmm_segment_ref",
    "color_combine_ref",
    "fused_count_ref",
    "flash_attention_ref",
]


def spmm_ref(rows: jax.Array, cols: jax.Array, table: jax.Array, num_rows: int) -> jax.Array:
    """Neighbor sum ``M[v] = sum_{(v,u)} table[u]`` via scatter-add.

    ``rows``/``cols`` are the expanded directed edge list (padded entries
    point at a zero sentinel row of ``table`` and at output row
    ``num_rows``); output has ``num_rows + 1`` rows, the last being the
    discarded sentinel row.
    """
    out = jnp.zeros((num_rows + 1, table.shape[1]), table.dtype)
    return out.at[rows].add(table[cols])


def spmm_segment_ref(
    rows: jax.Array, cols: jax.Array, table: jax.Array, num_rows: int
) -> jax.Array:
    """Same contract as :func:`spmm_ref` via gather + segment_sum."""
    gathered = table[cols]
    return jax.ops.segment_sum(gathered, rows, num_segments=num_rows + 1)


def color_combine_ref(left: jax.Array, m: jax.Array, idx1: jax.Array, idx2: jax.Array) -> jax.Array:
    """``out[v, s] = sum_j left[v, idx1[s, j]] * m[v, idx2[s, j]]``.

    ``idx1``/``idx2``: int32 [S, J] split tables (see core.colorsets).
    Output: [n, S] in ``left``'s dtype.
    """
    # [n, S, J] intermediates; fine for oracle use at test scale.
    lg = left[:, idx1]  # [n, S, J]
    mg = m[:, idx2]  # [n, S, J]
    return jnp.einsum("vsj,vsj->vs", lg, mg)


def fused_count_ref(
    rows: jax.Array,
    cols: jax.Array,
    left: jax.Array,
    right: jax.Array,
    idx1: jax.Array,
    idx2: jax.Array,
) -> jax.Array:
    """Unfused composition oracle for the fused SpMM->combine kernels:
    materialize the full neighbor sum ``M = A @ right``, then contract.

    ``rows``/``cols``: directed edge list (flat layout, see
    :func:`spmm_segment_ref`); output ``[n, S]`` with ``n = left.shape[0]``.
    """
    n = left.shape[0]
    m = spmm_segment_ref(rows, cols, right, n - 1)[:n]
    return color_combine_ref(left, m, idx1, idx2)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
) -> jax.Array:
    """Plain softmax attention oracle.

    Shapes: q [B, Hq, Lq, D], k/v [B, Hkv, Lk, D]; GQA by head repetition.
    ``window > 0`` restricts attention to the last ``window`` positions
    (sliding-window/local attention).  Query positions are aligned to the
    *end* of the key sequence (Lq == Lk for training; Lq < Lk for decode).
    """
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    if hq != hkv:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    lk = k.shape[2]
    if scale is None:
        scale = d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qpos = jnp.arange(lq)[:, None] + (lk - lq)
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # rows that mask out everything produce NaN from softmax; zero them.
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v).astype(q.dtype)
