"""Pallas TPU flash attention (online softmax), GQA/causal/sliding-window.

Grid: ``(batch, q_heads, nQ, nK)`` with the KV dimension innermost.  The
output block's index_map ignores the KV index, so the (TQ, D) output tile
stays resident in VMEM across the KV sweep; running max/denominator/
accumulator live in VMEM scratch (re-initialized at ``ik == 0``, finalized
at ``ik == nK - 1``).  GQA maps query head ``h`` to KV head ``h // group``
inside the K/V index_maps.

Masked logits use a large negative constant (not -inf) so fully-masked
blocks cannot poison the running max.  Fully-masked *rows* (possible with a
sliding window smaller than the block) are guarded by a zero-denominator
check at finalization.

Perf note (hillclimb hook): causal/windowed grids still visit fully masked
KV blocks; ``bounds`` prunes them by clamping the KV loop per Q block via
``@pl.when`` (DMAs still issue; a lower-triangular grid remap is the next
step if the collective/compute balance warrants it — see EXPERIMENTS.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level relevance: skip compute for fully masked blocks.
    q_start = iq * block_q
    k_start = ik * block_k
    relevant = True
    if causal:
        relevant = jnp.logical_and(relevant, k_start <= q_start + block_q - 1)
    if window > 0:
        relevant = jnp.logical_and(relevant, k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [TQ, D]
        k = k_ref[0, 0].astype(jnp.float32)  # [TK, D]
        v = v_ref[0, 0].astype(jnp.float32)  # [TK, D]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [TQ, TK]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        logits = jnp.where(mask, logits, _NEG)

        m_prev = m_scr[...][:, :1]  # [TQ, 1]
        m_cur = jnp.max(logits, axis=-1, keepdims=True)  # [TQ, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)  # [TQ, TK]
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)  # [TQ, 1]
        l_prev = l_scr[...][:, :1]
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...][:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, Hq, L, D]
    k: jax.Array,  # [B, Hkv, L, D]
    v: jax.Array,  # [B, Hkv, L, D]
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert lq == lk, "Pallas path is for self-attention prefill/train (Lq == Lk)"
    assert lq % block_q == 0 and lk % block_k == 0, (lq, lk, block_q, block_k)
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    nq = lq // block_q
    nk = lk // block_k
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
