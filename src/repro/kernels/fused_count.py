"""Fused SpMM -> color-combine kernel — the paper's fine-grained pipeline
(§3.2) realized at kernel granularity.

Computes, per sub-template split ``T_i -> (T_i', T_i'')``::

    out[v, s] = sum_j left[v, idx1[j, s]] * M[v, idx2[j, s]],
    M = A @ right   (neighbor sum)

WITHOUT ever writing the full ``[n_pad, B]`` neighbor-sum table ``M`` to
HBM.  The unfused engine materializes ``M`` between the SpMM and the
combine, so its per-node intermediate footprint is ``|C_left| + |C_right| +
|M| + |out|``; fusing drops the ``|M|`` term (``M`` exists only as one
``[row_tile, B]`` VMEM tile at a time), roughly halving the footprint for
large templates where ``B`` is the dominant table width.

Layout (shared with ``spmm_edge_tile_pallas``; built by
``ops.build_spmm_plan(kind='edges')``): the directed edge list is cut into
slabs of ``tile_size`` edges grouped under the ``row_tile``-row output block
of their destinations — the paper's bounded neighbor-list task size ``s``.

``fused_count_pallas``
    grid = (row_blocks, slabs_per_block), slab axis innermost.  Each step
    accumulates its slab into the resident ``[row_tile, B]`` scratch
    (gather + one-hot MXU scatter matmul, as in the SpMM kernel); the
    *last* slab of a row block runs the split-table contraction against the
    resident ``left`` block and writes the ``[row_tile, S]`` output tile.
    One pass over the edges, zero HBM traffic for ``M``.

``fused_count_xla``
    The same schedule for non-TPU backends: ``lax.map`` (a sequential scan)
    over row blocks, each computing its ``[row_tile, B]`` neighbor-sum
    block via segment-sum and contracting it immediately.  Peak live
    intermediate is one block's worth of ``M``; the jaxpr provably contains
    no ``[n_pad, B]`` value (asserted by tests/test_kernels.py).

Oracle: ``ref.fused_count_ref`` (segment-sum then dense combine).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_count_pallas", "fused_count_xla"]


def _fused_kernel(
    dst_ref,
    col_ref,
    right_ref,
    left_ref,
    idx1_ref,
    idx2_ref,
    out_ref,
    m_ref,
    *,
    num_splits: int,
    slabs_per_block: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.zeros_like(m_ref)

    dst = dst_ref[0]  # [tile] int32 local dst row (-1 pad)
    cols = col_ref[0]  # [tile] int32 global src row
    tab = right_ref[...]  # [n_pad, B] resident
    gathered = jnp.take(tab, cols, axis=0).astype(jnp.float32)  # [tile, B]
    row_tile = m_ref.shape[0]
    onehot = (
        dst[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], row_tile), 1)
    ).astype(jnp.float32)
    m_ref[...] += jax.lax.dot_general(
        onehot, gathered, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == slabs_per_block - 1)
    def _combine():
        lv = left_ref[...]  # [row_tile, A]
        mv = m_ref[...]  # [row_tile, B] — the only life M ever has

        def body(jj, acc):
            i1 = idx1_ref[jj, :]  # [S] int32 — sublane-axis dynamic slice
            i2 = idx2_ref[jj, :]
            g1 = jnp.take(lv, i1, axis=1)  # [row_tile, S] lane gather
            g2 = jnp.take(mv, i2, axis=1)
            return acc + g1 * g2

        acc0 = jnp.zeros(out_ref.shape, jnp.float32)
        acc = jax.lax.fori_loop(0, num_splits, body, acc0)
        out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("num_splits", "slabs_per_block", "row_tile", "interpret"),
)
def fused_count_pallas(
    slab_dst: jax.Array,  # [NRB * spb, tile] int32 local dst (-1 pad)
    slab_cols: jax.Array,  # [NRB * spb, tile] int32 src row of `right`
    left: jax.Array,  # [out_rows, A] — output height follows `left`
    right: jax.Array,  # [C, B]; sentinel source rows must be zero
    idx1_t: jax.Array,  # [J_pad, S_pad] int32 transposed split table (left)
    idx2_t: jax.Array,  # [J_pad, S_pad] int32 (neighbor-sum side)
    *,
    num_splits: int,  # true J (<= J_pad)
    slabs_per_block: int,
    row_tile: int = 128,
    interpret: bool = False,
) -> jax.Array:
    c, b = right.shape
    out_rows, a = left.shape
    s_pad = idx1_t.shape[1]
    nrb = out_rows // row_tile
    spb = slabs_per_block
    num_slabs, tile = slab_dst.shape
    assert num_slabs == nrb * spb, (num_slabs, nrb, spb)
    kernel = functools.partial(_fused_kernel, num_splits=num_splits, slabs_per_block=spb)
    return pl.pallas_call(
        kernel,
        grid=(nrb, spb),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j: (i * spb + j, 0)),
            pl.BlockSpec((1, tile), lambda i, j: (i * spb + j, 0)),
            pl.BlockSpec((c, b), lambda i, j: (0, 0)),
            pl.BlockSpec((row_tile, a), lambda i, j: (i, 0)),
            pl.BlockSpec((idx1_t.shape[0], s_pad), lambda i, j: (0, 0)),
            pl.BlockSpec((idx2_t.shape[0], s_pad), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, s_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows, s_pad), left.dtype),
        scratch_shapes=[pltpu.VMEM((row_tile, b), jnp.float32)],
        interpret=interpret,
    )(slab_dst, slab_cols, right, left, idx1_t, idx2_t)


@functools.partial(jax.jit, static_argnames=("row_tile",))
def fused_count_xla(
    slab_dst: jax.Array,  # [NRB * spb, tile] int32 local dst (-1 pad)
    slab_cols: jax.Array,  # [NRB * spb, tile] int32 global src
    left: jax.Array,  # [n_pad, A]
    right: jax.Array,  # [n_pad, B]; rows >= n must be zero
    idx1: jax.Array,  # [S, J] int32 split table (untransposed)
    idx2: jax.Array,
    *,
    row_tile: int = 128,
) -> jax.Array:
    """XLA realization of the fused schedule: sequential over row blocks.

    ``lax.map`` keeps one block in flight, so peak live intermediate is the
    ``[row_tile, B]`` neighbor-sum block — never the full ``[n_pad, B]``
    ``M``.  Under ``vmap`` (batched colorings) the map becomes a scan with a
    batched body: still one (batched) block of ``M`` alive at a time.
    """
    n_pad, a = left.shape
    nrb = n_pad // row_tile
    dst = slab_dst.reshape(nrb, -1)  # [NRB, spb * tile]
    cols = slab_cols.reshape(nrb, -1)
    left_blocks = left.reshape(nrb, row_tile, a)

    def block(xs):
        d, c, lblk = xs
        gathered = jnp.take(right, c, axis=0)  # [spb * tile, B]
        seg = jnp.where(d < 0, row_tile, d)  # pads -> discarded segment
        m_blk = jax.ops.segment_sum(gathered, seg, num_segments=row_tile + 1)[:row_tile]
        g1 = lblk[:, idx1]  # [row_tile, S, J]
        g2 = m_blk[:, idx2]
        return jnp.einsum("vsj,vsj->vs", g1, g2)

    out = jax.lax.map(block, (dst, cols, left_blocks))  # [NRB, row_tile, S]
    return out.reshape(n_pad, idx1.shape[0]).astype(left.dtype)
