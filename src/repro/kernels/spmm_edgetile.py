"""Pallas TPU kernels for the neighbor sum ``M = A @ C`` (SpMM).

This is the second hotspot of the color-coding DP: for every directed edge
``(v, u)``, ``M[v, :] += C[u, :]``.  Two TPU-native realizations, both
embodying the paper's *neighbor-list partitioning* (§3.3) — bounded,
uniform-size tasks independent of degree skew:

``spmm_block_pallas``
    Block-dense SpMM.  The adjacency is tiled into dense 128x128 0/1
    patches over (dst-block, src-block); only nonzero patches are stored
    (coordinates ``block_rows``/``block_cols``, sorted by dst block).  Each
    grid step issues one MXU matmul ``patch @ C[src_block]`` and accumulates
    into the resident output block.  A max-degree "supernode" row simply
    owns many patches — every task is exactly one 128x128 matmul, the
    MXU-aligned analogue of the paper's bounded task size ``s``.
    Output-block revisits are consecutive (sorted coordinates), which Pallas
    supports with read-modify-write + first-visit init.

``spmm_gather_pallas``
    Scalar-prefetch row-gather (megablox-style): one directed edge per grid
    step; the BlockSpec index_map reads the edge endpoints from prefetched
    scalar arrays, DMA-ing row ``C[u]`` in and accumulating into resident
    output row ``v`` (edges sorted by ``v`` => consecutive revisits).  Fully
    general sparsity; DMA granularity is one table row (>= 512B for t >= 2
    at k >= 10), documented as the fallback for graphs too sparse for
    profitable 128x128 patches.

Preprocessing helpers that build the patch/edge arrays live in ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["spmm_block_pallas", "spmm_gather_pallas"]


# ---------------------------------------------------------------------------
# Block-dense SpMM (MXU path)
# ---------------------------------------------------------------------------


def _block_kernel(block_rows_ref, block_cols_ref, patch_ref, table_ref, out_ref):
    nb = pl.program_id(0)
    row = block_rows_ref[nb]
    prev = block_rows_ref[jnp.maximum(nb - 1, 0)]
    first = jnp.logical_or(nb == 0, row != prev)

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    patch = patch_ref[0]  # [VB, KB]
    ctab = table_ref[...]  # [KB, B]
    out_ref[...] += jnp.dot(
        patch, ctab.astype(jnp.float32), preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_row_blocks", "interpret"))
def spmm_block_pallas(
    block_rows: jax.Array,  # [NB] int32, sorted; sentinel = num_row_blocks
    block_cols: jax.Array,  # [NB] int32; sentinel patches point at block 0
    patches: jax.Array,  # [NB, VB, KB] f32 0/1 (sentinel patches all-zero)
    table: jax.Array,  # [n_pad, B]  (n_pad % KB == 0, B % 128 == 0)
    *,
    num_row_blocks: int,  # output row blocks EXCLUDING the sentinel block
    interpret: bool = False,
) -> jax.Array:
    nb, vb, kb = patches.shape
    n_pad, b = table.shape
    assert n_pad % kb == 0 and b % 128 == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, vb, kb), lambda i, rows, cols: (i, 0, 0)),
            pl.BlockSpec((kb, b), lambda i, rows, cols: (cols[i], 0)),
        ],
        out_specs=pl.BlockSpec((vb, b), lambda i, rows, cols: (rows[i], 0)),
    )
    out = pl.pallas_call(
        _block_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(((num_row_blocks + 1) * vb, b), table.dtype),
        interpret=interpret,
    )(block_rows, block_cols, patches, table)
    return out


# ---------------------------------------------------------------------------
# Scalar-prefetch row-gather SpMM (general-sparsity fallback)
# ---------------------------------------------------------------------------


def _gather_kernel(rows_ref, cols_ref, table_row_ref, out_ref):
    e = pl.program_id(0)
    row = rows_ref[e]
    prev = rows_ref[jnp.maximum(e - 1, 0)]
    first = jnp.logical_or(e == 0, row != prev)

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_row_ref[...]


@functools.partial(jax.jit, static_argnames=("num_rows", "interpret"))
def spmm_gather_pallas(
    rows: jax.Array,  # [E] int32 sorted by dst; sentinel = num_rows
    cols: jax.Array,  # [E] int32; sentinel points at the zero row n_pad-1
    table: jax.Array,  # [n_pad, B]
    *,
    num_rows: int,
    interpret: bool = False,
) -> jax.Array:
    e = rows.shape[0]
    n_pad, b = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(e,),
        in_specs=[pl.BlockSpec((1, b), lambda i, rows, cols: (cols[i], 0))],
        out_specs=pl.BlockSpec((1, b), lambda i, rows, cols: (rows[i], 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_rows + 1, b), table.dtype),
        interpret=interpret,
    )(rows, cols, table)
