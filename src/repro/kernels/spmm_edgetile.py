"""Pallas TPU kernels for the neighbor sum ``M = A @ C`` (SpMM).

This is the second hotspot of the color-coding DP: for every directed edge
``(v, u)``, ``M[v, :] += C[u, :]``.  Two TPU-native realizations, both
embodying the paper's *neighbor-list partitioning* (§3.3) — bounded,
uniform-size tasks independent of degree skew:

``spmm_edge_tile_pallas``
    Edge-tiled gather SpMM.  The directed edge list is partitioned into
    *slabs* of exactly ``tile_size`` edges (the paper's bounded task size
    ``s``), grouped under the 128-row output block their destinations fall
    in; the grid is ``(row_blocks, slabs_per_block)`` with the slab axis
    innermost so output-block revisits are consecutive and a ``j == 0``
    first-visit check re-zeroes the resident accumulator.  Each grid step
    gathers the slab's ``tile_size`` source rows from the VMEM-resident
    table and scatters them into the output block with one
    ``[rows, tile] x [tile, B]`` one-hot MXU matmul — a max-degree
    "supernode" row simply owns many slabs, every task is the same two
    dense ops.  Padded slab slots carry ``dst = -1`` (all-zero one-hot row)
    and the zero sentinel source row, so they are arithmetic no-ops.
    The whole count table is held resident in VMEM (constant index_map), so
    this kernel is for tables up to a few MB; larger graphs take
    ``spmm_block_pallas`` or the XLA scatter path.

``spmm_block_pallas``
    Block-dense SpMM.  The adjacency is tiled into dense 128x128 0/1
    patches over (dst-block, src-block); only nonzero patches are stored
    (coordinates ``block_rows``/``block_cols``, sorted by dst block).  Each
    grid step issues one MXU matmul ``patch @ C[src_block]`` and accumulates
    into the resident output block.  Wins over the edge-tiled kernel when
    occupied patches are dense enough that the 64 KB/patch storage and the
    full 128x128 matmul beat per-edge slab metadata (``build_spmm_plan``'s
    ``"auto"`` kind measures exactly this).

Preprocessing helpers that build the slab/patch arrays live in ``ops.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["spmm_block_pallas", "spmm_edge_tile_pallas"]


# ---------------------------------------------------------------------------
# Block-dense SpMM (MXU path)
# ---------------------------------------------------------------------------


def _block_kernel(block_rows_ref, block_cols_ref, patch_ref, table_ref, out_ref):
    nb = pl.program_id(0)
    row = block_rows_ref[nb]
    prev = block_rows_ref[jnp.maximum(nb - 1, 0)]
    first = jnp.logical_or(nb == 0, row != prev)

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    patch = patch_ref[0]  # [VB, KB]
    ctab = table_ref[...]  # [KB, B]
    out_ref[...] += jnp.dot(
        patch, ctab.astype(jnp.float32), preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_row_blocks", "interpret"))
def spmm_block_pallas(
    block_rows: jax.Array,  # [NB] int32, sorted; sentinel = num_row_blocks
    block_cols: jax.Array,  # [NB] int32; sentinel patches point at block 0
    patches: jax.Array,  # [NB, VB, KB] f32 0/1 (sentinel patches all-zero)
    table: jax.Array,  # [n_pad, B]  (n_pad % KB == 0, B % 128 == 0)
    *,
    num_row_blocks: int,  # output row blocks EXCLUDING the sentinel block
    interpret: bool = False,
) -> jax.Array:
    nb, vb, kb = patches.shape
    n_pad, b = table.shape
    assert n_pad % kb == 0 and b % 128 == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, vb, kb), lambda i, rows, cols: (i, 0, 0)),
            pl.BlockSpec((kb, b), lambda i, rows, cols: (cols[i], 0)),
        ],
        out_specs=pl.BlockSpec((vb, b), lambda i, rows, cols: (rows[i], 0)),
    )
    out = pl.pallas_call(
        _block_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(((num_row_blocks + 1) * vb, b), table.dtype),
        interpret=interpret,
    )(block_rows, block_cols, patches, table)
    return out


# ---------------------------------------------------------------------------
# Edge-tiled gather SpMM (general-sparsity path, tile_size edges per step)
# ---------------------------------------------------------------------------


def _edge_tile_kernel(dst_ref, col_ref, table_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[0]  # [tile_size] int32 local dst row; -1 = pad slot
    cols = col_ref[0]  # [tile_size] int32 global src row; sentinel = zero row
    tab = table_ref[...]  # [n_pad, B] resident across the whole grid
    gathered = jnp.take(tab, cols, axis=0).astype(jnp.float32)  # [tile, B]
    row_tile = out_ref.shape[0]
    onehot = (
        dst[:, None]
        == jax.lax.broadcasted_iota(jnp.int32, (dst.shape[0], row_tile), 1)
    ).astype(jnp.float32)  # [tile, rows]; pad slots are all-zero rows
    # scatter-accumulate as one MXU matmul: out[r] += sum_i [dst_i == r] * C[col_i]
    out_ref[...] += jax.lax.dot_general(
        onehot,
        gathered,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("slabs_per_block", "row_tile", "out_rows", "interpret")
)
def spmm_edge_tile_pallas(
    slab_dst: jax.Array,  # [NRB * spb, tile_size] int32 local dst (-1 pad)
    slab_cols: jax.Array,  # [NRB * spb, tile_size] int32 src row of `table`
    table: jax.Array,  # [C, B]; sentinel source rows must be zero
    *,
    slabs_per_block: int,
    row_tile: int = 128,
    out_rows: int = None,
    interpret: bool = False,
) -> jax.Array:
    """``out_rows`` decouples the output height from the source table: the
    distributed engine scatters a ``[P * r_pad, B]`` exchange buffer into
    this shard's ``[n_loc_pad, B]`` neighbor sum; the single-device square
    case (``out_rows=None``) scatters the vertex table into itself."""
    c, b = table.shape
    if out_rows is None:
        out_rows = c
    nrb = out_rows // row_tile
    spb = slabs_per_block
    num_slabs, tile = slab_dst.shape
    assert num_slabs == nrb * spb, (num_slabs, nrb, spb)
    grid = (nrb, spb)
    return pl.pallas_call(
        _edge_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, j: (i * spb + j, 0)),
            pl.BlockSpec((1, tile), lambda i, j: (i * spb + j, 0)),
            pl.BlockSpec((c, b), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((out_rows, b), table.dtype),
        interpret=interpret,
    )(slab_dst, slab_cols, table)
