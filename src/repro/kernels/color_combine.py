"""Pallas TPU kernel for the color-set combine — the paper's compute hotspot.

Computes, per sub-template split ``T_i -> (T_i', T_i'')``::

    out[v, s] = sum_j left[v, idx1[j, s]] * m[v, idx2[j, s]]

where ``s`` ranks the output color set (|S| = t), ``j`` ranks the ordered
split ``S = S1 (+) S2`` and ``idx1/idx2`` map to ranks in the operand tables
(see ``core.colorsets.split_tables``; here they are TRANSPOSED to [J, S] so
the per-``j`` row lands on the sublane axis, letting the ``j`` loop use a
dynamic slice on the major dimension, which Mosaic supports).

TPU mapping (this is the Table-3 "computation complexity" term
``C(k,t) * C(t,t1)`` per vertex):

* grid = (n/TV, S/TS); each step holds the full operand rows for a TV-vertex
  tile in VMEM (worst case k=15: 2 x 128 x 6435 x 4B = 6.6 MB < 16 MB VMEM)
  and produces a (TV, TS) output tile.
* the inner ``j`` loop is a lane-dimension dynamic gather
  (``jnp.take(..., axis=1)``) + FMA: VPU work, 8x128 aligned.
* all column widths are padded to multiples of 128 by ``ops.py``; padded
  output columns are sliced off by the wrapper.

Validated against ``ref.color_combine_ref`` in interpret mode (CPU); on a
real TPU the same grid/block spec runs compiled.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["color_combine_pallas"]


def _combine_kernel(idx1_ref, idx2_ref, left_ref, m_ref, out_ref, *, num_splits: int):
    lv = left_ref[...]  # [TV, A]
    mv = m_ref[...]  # [TV, B]

    def body(j, acc):
        i1 = idx1_ref[j, :]  # [TS] int32 — dynamic slice on sublane axis
        i2 = idx2_ref[j, :]
        g1 = jnp.take(lv, i1, axis=1)  # [TV, TS] lane gather
        g2 = jnp.take(mv, i2, axis=1)
        return acc + g1 * g2

    acc0 = jnp.zeros(out_ref.shape, jnp.float32)
    acc = jax.lax.fori_loop(0, num_splits, body, acc0)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_v", "tile_s", "num_splits", "interpret"))
def color_combine_pallas(
    left: jax.Array,  # [n, A]   (n % tile_v == 0, A % 128 == 0)
    m: jax.Array,  # [n, B]
    idx1_t: jax.Array,  # [J_pad, S] int32, transposed split table
    idx2_t: jax.Array,  # [J_pad, S]
    *,
    num_splits: int,  # true J (<= J_pad)
    tile_v: int = 128,
    tile_s: int = 128,
    interpret: bool = False,
) -> jax.Array:
    n, a = left.shape
    _, b = m.shape
    s = idx1_t.shape[1]
    assert n % tile_v == 0 and s % tile_s == 0, (n, s, tile_v, tile_s)
    grid = (n // tile_v, s // tile_s)
    kernel = functools.partial(_combine_kernel, num_splits=num_splits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((idx1_t.shape[0], tile_s), lambda i, j: (0, j)),
            pl.BlockSpec((idx2_t.shape[0], tile_s), lambda i, j: (0, j)),
            pl.BlockSpec((tile_v, a), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_v, b), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_v, tile_s), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, s), left.dtype),
        interpret=interpret,
    )(idx1_t, idx2_t, left, m)
