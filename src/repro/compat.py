"""Version-compat shims for the JAX APIs this repo uses across releases.

The repo targets the newest stable JAX but must degrade onto the versions
actually baked into CI / test containers.  Two shims live here:

``shard_map``
    ``jax.shard_map`` (new spelling, ``check_vma`` kwarg) vs
    ``jax.experimental.shard_map.shard_map`` (old spelling, ``check_rep``).

``make_mesh``
    ``jax.make_mesh(..., axis_types=(AxisType.Auto, ...))`` vs releases
    that predate ``jax.sharding.AxisType`` (where plain ``make_mesh`` has
    the same auto-sharding semantics).

``pvary_like``
    Varying-manual-axes promotion for shard_map loop carries on releases
    with the ``vma`` type system; a no-op on releases without it.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "axis_size", "pvary_like"]


def axis_size(axis_name):
    """``jax.lax.axis_size`` with the classic ``psum(1, axis)`` fallback.

    Both return a static Python int for a named mesh axis inside a
    shard_map/pmap region.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def pvary_like(val, like):
    """Promote ``val``'s varying-manual-axes to match ``like`` (shard_map).

    Loop carries must have stable types under shard_map: a ``jnp.zeros``
    init is unvarying while permuted/sharded data is varying, so the init
    must be pcast before entering a ``fori_loop``/``while_loop``.  On JAX
    releases without the ``vma`` type system this is the identity.
    """
    try:
        need = set(jax.typeof(like).vma) - set(jax.typeof(val).vma)
    except AttributeError:  # no vma tracking, or not in a manual-axes context
        return val
    if need:
        val = jax.lax.pcast(val, tuple(sorted(need)), to="varying")
    return val


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, axis_names=None):
    if hasattr(jax, "shard_map"):
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        # old spelling: `auto` is the complement of the manual axis set
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh(shape, names):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, names)
    return jax.make_mesh(shape, names, axis_types=(axis_type.Auto,) * len(names))
