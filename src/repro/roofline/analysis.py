"""Roofline analysis from dry-run records (TPU v5e constants).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled artifact:

  compute    = per-device HLO FLOPs / peak_FLOPs
  memory     = per-device HLO bytes accessed / HBM bandwidth
  collective = per-device collective bytes / link bandwidth

XLA:CPU's cost analysis is per-device (post-SPMD program), so no chip
division is applied to the numerators.  The dominant term is the estimated
step time; MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) measures how
much of the compiled compute is "useful".

Hardware constants (task spec): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI; inter-pod DCI modeled at 25 GB/s.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import List, Optional

__all__ = ["RooflineTerms", "analyze_record", "analyze_dir", "format_table"]

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (per-device collective throughput model)
DCI_BW = 25e9


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float  # per device
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs * chips)
    temp_gib: float
    fits: bool
    note: str = ""

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute term / dominant term — 1.0 when compute-bound."""
        return self.compute_s / self.step_s if self.step_s > 0 else 0.0


def _model_flops(rec: dict) -> float:
    """6*N*D per step (train: fwd+bwd); decode/prefill: 2*N*D forward only."""
    n = rec.get("active_params", rec.get("params", 0))
    if "global_batch" not in rec:  # counting cells: no token-based model
        return 0.0
    kind = rec.get("kind", "train")
    if kind == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n * tokens
    tokens = rec["global_batch"]  # decode: one token per sequence
    return 2.0 * n * tokens


def analyze_record(rec: dict, hbm_gib: float = 16.0) -> Optional[RooflineTerms]:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    flops_dev = rec["cost"]["flops"]
    bytes_dev = rec["cost"]["bytes_accessed"]
    coll = rec.get("collectives", {})
    multi_pod = "2x16x16" in rec.get("mesh", "")
    coll_bytes = sum(v for k, v in coll.items() if k != "ops" and isinstance(v, (int, float)))
    link_bw = DCI_BW if multi_pod else ICI_BW
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_bytes / link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = _model_flops(rec)
    hlo_total = flops_dev * chips
    temp = rec.get("memory", {}).get("temp_bytes", 0)
    args = rec.get("memory", {}).get("argument_bytes", 0)
    return RooflineTerms(
        arch=rec["arch"],
        shape=rec.get("shape", ""),
        mesh=rec["mesh"],
        chips=chips,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        hlo_flops=flops_dev,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        temp_gib=temp / 2**30,
        fits=(temp + args) <= hbm_gib * 2**30,
    )


def analyze_dir(path: str) -> List[RooflineTerms]:
    out = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            rec = json.load(fh)
        t = analyze_record(rec)
        if t:
            out.append(t)
    return out


def format_table(terms: List[RooflineTerms]) -> str:
    hdr = (
        f"{'arch':<26}{'shape':<13}{'mesh':<9}{'comp_s':>10}{'mem_s':>10}"
        f"{'coll_s':>10}{'domin':>7}{'useful':>8}{'roofl%':>8}{'tempGiB':>9}{'fits':>6}"
    )
    lines = [hdr, "-" * len(hdr)]
    for t in terms:
        lines.append(
            f"{t.arch:<26}{t.shape:<13}{t.mesh:<9}{t.compute_s:>10.4f}"
            f"{t.memory_s:>10.4f}{t.collective_s:>10.4f}{t.dominant[:5]:>7}"
            f"{t.useful_ratio:>8.2f}{100 * t.roofline_fraction:>7.1f}%"
            f"{t.temp_gib:>9.2f}{str(t.fits):>6}"
        )
    return "\n".join(lines)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("dir", nargs="?", default="results/dryrun")
    args = ap.parse_args()
    terms = analyze_dir(args.dir)
    print(format_table(terms))


if __name__ == "__main__":
    main()
