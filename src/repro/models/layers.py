"""Shared layer primitives: norms, RoPE, MLPs, init helpers.

Pure-functional: params are plain pytrees of jnp arrays; every layer is
``apply(params, x, ...)``.  Compute dtype is bf16 by default (params stay
f32; casts happen at use sites), matching mixed-precision training.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "Initializer",
    "rmsnorm",
    "layernorm_params",
    "rope",
    "mlp_init",
    "mlp_apply",
    "dense_init",
]


class Initializer:
    """Split-once key fountain for parameter init."""

    def __init__(self, key: jax.Array):
        self._key = key

    def take(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def normal(self, shape, scale: float = 0.02, dtype=jnp.float32) -> jax.Array:
        return (jax.random.normal(self.take(), shape, jnp.float32) * scale).astype(dtype)

    def zeros(self, shape, dtype=jnp.float32) -> jax.Array:
        return jnp.zeros(shape, dtype)

    def ones(self, shape, dtype=jnp.float32) -> jax.Array:
        return jnp.ones(shape, dtype)


def dense_init(init: Initializer, d_in: int, d_out: int, *, bias: bool = False):
    p = {"w": init.normal((d_in, d_out), scale=d_in ** -0.5)}
    if bias:
        p["b"] = init.zeros((d_out,))
    return p


def dense_apply(p, x: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(x.dtype)


def layernorm_params(init: Initializer, d: int):
    return {"scale": init.ones((d,))}


def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Rotary embeddings.  x: [..., L, D] (D even); positions: [L] or [..., L]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., L, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast cos/sin over leading dims of x
    while cos.ndim < x.ndim:
        cos, sin = cos[None], sin[None]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_init(init: Initializer, d: int, f: int, act: str):
    if act == "swiglu":
        return {
            "w_gate": init.normal((d, f), scale=d ** -0.5),
            "w_up": init.normal((d, f), scale=d ** -0.5),
            "w_down": init.normal((f, d), scale=f ** -0.5),
        }
    return {
        "w_up": init.normal((d, f), scale=d ** -0.5),
        "w_down": init.normal((f, d), scale=f ** -0.5),
    }


def mlp_apply(p, x: jax.Array, act: str, dtype=jnp.bfloat16) -> jax.Array:
    xb = x.astype(dtype)
    if act == "swiglu":
        g = xb @ p["w_gate"].astype(dtype)
        u = xb @ p["w_up"].astype(dtype)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(xb @ p["w_up"].astype(dtype))
    return h @ p["w_down"].astype(dtype)
