"""Model zoo: generic transformer assembler covering all assigned families."""

from .factory import Model, build_model, chunked_ce_loss, param_pspecs  # noqa: F401
from .transformer import forward, init_caches, init_params, layer_plan  # noqa: F401
