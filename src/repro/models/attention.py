"""Attention blocks: GQA self-attention (full / sliding-window / local),
cross-attention, chunked (flash-style) XLA path, and KV-cache decode.

The chunked path is the memory-sane default for 32k+ prefill on any backend
(double lax.scan with online softmax — O(q_chunk * kv_chunk) live logits);
``repro.kernels.flash_attention`` is the Pallas TPU equivalent, selected via
``impl``.

GQA is computed with an explicit group dimension (no KV head repetition):
q reshaped to [B, Hkv, G, L, D] against k/v [B, Hkv, L, D].
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from .layers import Initializer, dense_init, rope

__all__ = [
    "attn_init",
    "attention_block",
    "chunked_attention",
    "decode_attention",
    "init_kv_cache",
]

_NEG = -1e30


def attn_init(init: Initializer, cfg, *, cross: bool = False):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    return {
        "wq": dense_init(init, d, h * hd, bias=cfg.attn_bias),
        "wk": dense_init(init, d, kv * hd, bias=cfg.attn_bias),
        "wv": dense_init(init, d, kv * hd, bias=cfg.attn_bias),
        "wo": dense_init(init, h * hd, d),
    }


def _project(p, x, heads, hd, dtype):
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    b, l, _ = y.shape
    return y.reshape(b, l, heads, hd).transpose(0, 2, 1, 3)  # [B, H, L, D]


def chunked_attention(
    q: jax.Array,  # [B, H, Lq, D]
    k: jax.Array,  # [B, Hkv, Lk, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    constrain=lambda a: a,  # sharding anchor for the 5-D carry tensors
) -> jax.Array:
    """Flash-style online-softmax attention in pure XLA (scan over chunks)."""
    b, h, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    lq_real, lk_real = lq, lk
    q_chunk = min(q_chunk, lq)
    kv_chunk = min(kv_chunk, lk)
    # pad ragged lengths (e.g. whisper's 1500-frame encoder context); padded
    # keys are masked out below, padded query rows are sliced off
    pad_q = (-lq) % q_chunk
    pad_k = (-lk) % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        lq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        lk += pad_k
    g = h // hkv
    scale = d ** -0.5
    nq, nk = lq // q_chunk, lk // kv_chunk
    offset = lk_real - lq_real  # queries aligned to the end of the real keys

    qg = q.reshape(b, hkv, g, lq, d)
    qs = qg.reshape(b, hkv, g, nq, q_chunk, d).transpose(3, 0, 1, 2, 4, 5)
    ks = k.reshape(b, hkv, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, hkv, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)

    def q_step(_, iq_qc):
        iq, qc = iq_qc  # qc: [b, hkv, g, q_chunk, d]
        q32 = qc.astype(jnp.float32) * scale

        # flash-attention semantics: recompute logits/probs in the backward
        # pass instead of saving a [.., q_chunk, kv_chunk] tensor per scan
        # step (without this the bwd residuals are O(L^2) again)
        @jax.checkpoint
        def kv_step(carry, ik_kc):
            acc, m, l = carry
            ik, kc, vc = ik_kc
            logits = jnp.einsum(
                "bkgqd,bkcd->bkgqc", q32, kc.astype(jnp.float32)
            )  # [b,hkv,g,qc,kc]
            qpos = offset + iq * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = kpos < lk_real  # padded keys never attended
            if causal:
                mask &= kpos <= qpos
            if window > 0:
                mask &= kpos > qpos - window
            logits = jnp.where(mask[None, None, None], logits, _NEG)
            m_new = jnp.maximum(m, logits.max(-1, keepdims=True))
            p = jnp.exp(logits - m_new)
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bkgqc,bkcd->bkgqd", p, vc.astype(jnp.float32))
            return (acc_new, m_new, l_new), None

        acc0 = constrain(jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32))
        m0 = constrain(jnp.full((b, hkv, g, q_chunk, 1), _NEG, jnp.float32))
        l0 = constrain(jnp.zeros((b, hkv, g, q_chunk, 1), jnp.float32))
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (jnp.arange(nk), ks, vs))
        l = jnp.where(l == 0.0, 1.0, l)
        return None, (acc / l).astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # out: [nq, b, hkv, g, q_chunk, d] -> [b, h, lq, d]
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, lq, d)
    return out[:, :, :lq_real]


def decode_attention(
    q: jax.Array,  # [B, H, 1, D]
    k_cache: jax.Array,  # [B, Hkv, S, D]
    v_cache: jax.Array,
    slot_pos: jax.Array,  # [S] absolute position stored in each slot (-1 empty)
    pos: jax.Array,  # scalar: index of the current token
    *,
    window: int = 0,
) -> jax.Array:
    b, h, _, d = q.shape
    hkv = k_cache.shape[1]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * (d ** -0.5)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache.astype(jnp.float32))
    mask = (slot_pos >= 0) & (slot_pos <= pos)
    if window > 0:
        mask &= slot_pos > pos - window
    logits = jnp.where(mask[None, None, None], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, 1, d).astype(q.dtype)


def init_kv_cache(batch: int, kv_heads: int, length: int, head_dim: int, dtype=jnp.bfloat16):
    """Circular KV cache; ``slot_pos`` tracks the absolute position held by
    each slot (windowed archs wrap: slot = pos % length)."""
    return {
        "k": jnp.zeros((batch, kv_heads, length, head_dim), dtype),
        "v": jnp.zeros((batch, kv_heads, length, head_dim), dtype),
        "slot_pos": jnp.full((length,), -1, jnp.int32),
    }


def attention_block(
    p,
    x: jax.Array,  # [B, L, D_model]
    cfg,
    *,
    causal: bool = True,
    window: int = 0,
    context: Optional[jax.Array] = None,  # cross-attention context [B, Lc, D]
    cache: Optional[dict] = None,  # decode KV cache
    pos: Optional[jax.Array] = None,  # decode position (scalar)
    positions: Optional[jax.Array] = None,  # rope positions for q [L]
    impl: str = "xla",
    dtype=jnp.bfloat16,
    build_cache_len: Optional[int] = None,  # prefill: build a cache this long
    shard=lambda a, kind: a,  # sharding anchors (factory._act_shard_fn)
) -> Tuple[jax.Array, Optional[dict]]:
    """One attention mix (no norm/residual — the transformer block owns those).

    Returns (output [B, L, D_model], updated cache or None).
    """
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    b, l, _ = x.shape

    q = _project(p["wq"], x, h, hd, dtype)
    kv_src = context if context is not None else x
    k = _project(p["wk"], kv_src, kv, hd, dtype)
    v = _project(p["wv"], kv_src, kv, hd, dtype)
    if (getattr(shard, "attn_repeat_kv", False) and context is None and cache is None and kv != h):
        # repeat KV to the q-head count so the head dim shards over the
        # model axis (memory cost is per-chunk; partitioner-thrash cost of
        # NOT doing it is replicated [b,h,qc,kc] logits)
        k = jnp.repeat(k, h // kv, axis=1)
        v = jnp.repeat(v, h // kv, axis=1)
    q = shard(q, "q4")
    if context is None and cache is None:
        k = shard(k, "kv4" if k.shape[1] != h else "q4")
        v = shard(v, "kv4" if v.shape[1] != h else "q4")

    is_cross = context is not None
    if not is_cross:
        if positions is None:
            positions = jnp.arange(l) if pos is None else jnp.full((l,), pos)
        q = rope(q, positions, cfg.rope_theta)
        # K rope is applied at *write* position (absolute), so circular
        # caches stay correct after wrap-around.
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and not is_cross:
        # decode: write the new K/V at slot (pos % S), attend over the cache
        s_buf = cache["k"].shape[2]
        slot = pos % s_buf
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0)
        )
        slot_pos = jax.lax.dynamic_update_slice(
            cache["slot_pos"], pos[None].astype(jnp.int32), (slot,)
        )
        new_cache = {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}
        out = decode_attention(q, k_cache, v_cache, slot_pos, pos, window=window)
    elif is_cross:
        out = chunked_attention(q, k, v, causal=False, window=0)
    else:
        if impl == "pallas" and q.shape[2] == k.shape[2]:
            out = kops.flash_attention(q, k, v, causal=causal, window=window, impl="pallas")
        else:
            ac = getattr(shard, "attn_chunk", 1024)
            out = chunked_attention(
                q, k, v, causal=causal, window=window,
                q_chunk=ac, kv_chunk=ac,
                constrain=lambda a: shard(a, "attn5"),
            )
        if build_cache_len is not None:
            s_buf = build_cache_len
            keep = min(l, s_buf)
            cache_dtype = jnp.bfloat16
            k_buf = jnp.zeros((b, kv, s_buf, hd), cache_dtype)
            v_buf = jnp.zeros((b, kv, s_buf, hd), cache_dtype)
            # store the last `keep` positions (windowed caches may be shorter
            # than the prompt); slots are absolute-position % s_buf
            k_tail = k[:, :, l - keep :].astype(cache_dtype)
            v_tail = v[:, :, l - keep :].astype(cache_dtype)
            abs_pos = jnp.arange(l - keep, l)
            slots = abs_pos % s_buf
            k_buf = k_buf.at[:, :, slots].set(k_tail)
            v_buf = v_buf.at[:, :, slots].set(v_tail)
            slot_pos = jnp.full((s_buf,), -1, jnp.int32).at[slots].set(abs_pos)
            new_cache = {"k": k_buf, "v": v_buf, "slot_pos": slot_pos}

    out = out.transpose(0, 2, 1, 3).reshape(b, l, h * hd)
    out = out @ p["wo"]["w"].astype(dtype)
    return out, new_cache
