"""Mixture-of-Experts FFN.

Two execution paths:

``moe_block``  (single-device / smoke tests)
    Dense capacity-based dispatch: top-k routing, position-in-expert via a
    stable argsort, one scatter into an ``[E, C, D]`` buffer, batched expert
    einsums, weighted combine.  The oracle for the distributed path.

``moe_block_manual``  (inside a fully-manual shard_map over (dp..., model))
    The distributed layer.  Token dispatch is where the paper's
    Adaptive-Group exchange applies verbatim (DESIGN.md §4/§5):

    * ``moe_sharding='ep'`` (phi3.5: E % axis == 0) — tokens are split over
      the model axis; each member routes its token slice into per-expert
      chunks and exchanges them with the expert owners.  With
      ``pipeline=True`` the exchange runs as the paper's grouped
      ``ppermute`` schedule with the *expert FFN computed per arriving
      chunk* (compute overlaps the remaining transfers — Algorithm 3's
      interleave); otherwise one fused ``all_to_all``.  Results return on
      the reverse schedule and token outputs are re-gathered.
    * ``moe_sharding='tp'`` (mixtral: 8 experts on a 16 axis) — expert FFN
      hidden dim is sharded over the model axis; tokens stay replicated,
      partial outputs ``psum`` over the axis (dense-TP semantics).
    * token counts not divisible by the axis (decode) fall back to
      replicated-token EP: every member computes its expert slice on all
      tokens, partial combines ``psum``.

    FSDP'd expert weights are explicitly all-gathered over the data axis at
    entry (the ZeRO-3 unshard).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

from .layers import Initializer

__all__ = ["moe_init", "moe_block", "moe_block_manual"]


def moe_init(init: Initializer, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": init.normal((d, e), scale=d ** -0.5),
        "w_gate": init.normal((e, d, f), scale=d ** -0.5),
        "w_up": init.normal((e, d, f), scale=d ** -0.5),
        "w_down": init.normal((e, f, d), scale=f ** -0.5),
    }


# ---------------------------------------------------------------------------
# Routing / dispatch primitives (shared)
# ---------------------------------------------------------------------------


def _route(xt, router, k):
    """Returns (top_w [T,k] f32 renormalized, top_e [T,k] i32, aux loss)."""
    t, _ = xt.shape
    e = router.shape[1]
    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    assign = jnp.zeros((t, e), jnp.float32).at[jnp.arange(t)[:, None], top_e].set(1.0)
    aux = e * jnp.sum(assign.mean(0) * probs.mean(0))
    return top_w, top_e.astype(jnp.int32), aux


def _dispatch(xt, top_e, capacity, num_experts, dtype):
    """Scatter tokens into [E, C, D]; returns (buf, e_flat, pos, keep, tok)."""
    t, d = xt.shape
    k = top_e.shape[1]
    e_flat = top_e.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    counts = jnp.bincount(e_flat, length=num_experts)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(t * k) - starts[sorted_e]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < capacity
    pos_c = jnp.minimum(pos, capacity - 1)
    tok = jnp.repeat(jnp.arange(t), k)
    payload = jnp.where(keep[:, None], xt[tok].astype(dtype), 0)
    buf = jnp.zeros((num_experts, capacity, d), dtype)
    buf = buf.at[e_flat, pos_c].add(payload)
    return buf, e_flat, pos_c, keep, tok


def _combine(out_buf, e_flat, pos_c, keep, tok, top_w, t, dtype):
    slot_out = out_buf[e_flat, pos_c]
    slot_out = jnp.where(keep[:, None], slot_out, 0)
    w_flat = top_w.reshape(-1).astype(dtype)
    return jnp.zeros((t, out_buf.shape[-1]), dtype).at[tok].add(slot_out * w_flat[:, None])


def _expert_ffn(buf, wg, wu, wd):
    """buf [E, C, D] x per-expert weights -> [E, C, D_out]."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _capacity(cfg, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.experts_per_token / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)


# ---------------------------------------------------------------------------
# Dense path (oracle / single device)
# ---------------------------------------------------------------------------


def moe_block(
    p,
    x: jax.Array,  # [B, L, D]
    cfg,
    *,
    shard_fn=lambda a, kind: a,  # unused on this path (kept for API compat)
    dtype=jnp.bfloat16,
) -> Tuple[jax.Array, jax.Array]:
    b, l, d = x.shape
    t = b * l
    xt = x.reshape(t, d)
    top_w, top_e, aux = _route(xt, p["router"], cfg.experts_per_token)
    buf, e_flat, pos_c, keep, tok = _dispatch(xt, top_e, _capacity(cfg, t), cfg.num_experts, dtype)
    out_buf = _expert_ffn(
        buf,
        p["w_gate"].astype(dtype),
        p["w_up"].astype(dtype),
        p["w_down"].astype(dtype),
    )
    combined = _combine(out_buf, e_flat, pos_c, keep, tok, top_w, t, dtype)
    return combined.reshape(b, l, d), aux


# ---------------------------------------------------------------------------
# Manual (distributed) path
# ---------------------------------------------------------------------------


def moe_block_manual(
    p,
    x: jax.Array,  # [B_loc, L, D] (replicated over the model axis)
    cfg,
    *,
    dp_axes: Tuple[str, ...],
    model_axis: str,
    fsdp_axis: Optional[str],
    pipeline: bool = False,
    group_factor: int = 1,
    dtype=jnp.bfloat16,
) -> Tuple[jax.Array, jax.Array]:
    ep = cfg.moe_sharding == "ep"
    pm = axis_size(model_axis)
    m = jax.lax.axis_index(model_axis)
    b, l, d = x.shape
    t = b * l
    xt = x.reshape(t, d)

    def unshard(w, dim):  # ZeRO-3 gather over the data axis
        if fsdp_axis is None:
            return w.astype(dtype)
        return jax.lax.all_gather(w, fsdp_axis, axis=dim, tiled=True).astype(dtype)

    router = unshard(p["router"], 0)
    wg = unshard(p["w_gate"], 1)
    wu = unshard(p["w_up"], 1)
    wd = unshard(p["w_down"], 2)

    if not ep:
        # TP experts: F sharded over model; tokens replicated; psum partials
        top_w, top_e, aux = _route(xt, router, cfg.experts_per_token)
        buf, e_flat, pos_c, keep, tok = _dispatch(
            xt, top_e, _capacity(cfg, t), cfg.num_experts, dtype
        )
        out_buf = _expert_ffn(buf, wg, wu, wd)  # [E, C, D] partial over F
        combined = _combine(out_buf, e_flat, pos_c, keep, tok, top_w, t, dtype)
        # f32 psum: XLA:CPU's AllReducePromotion crashes on bf16 all-reduce
        # clones in multi-pod replica groups (compiler bug workaround)
        combined = jax.lax.psum(combined.astype(jnp.float32), model_axis).astype(dtype)
        # aux is computed from replicated tokens: invarying over model (and
        # over data when the batch is unsharded) — pmean only over dp axes
        aux = jax.lax.pmean(aux, dp_axes) if dp_axes else aux
        return combined.reshape(b, l, d), aux

    e_loc = cfg.num_experts // pm  # local experts on this member

    if t % pm != 0:
        # replicated-token EP fallback (decode-sized batches)
        top_w, top_e, aux = _route(xt, router, cfg.experts_per_token)
        buf, e_flat, pos_c, keep, tok = _dispatch(
            xt, top_e, _capacity(cfg, t), cfg.num_experts, dtype
        )
        my = jax.lax.dynamic_slice_in_dim(buf, m * e_loc, e_loc, 0)
        out_my = _expert_ffn(my, wg, wu, wd)  # [E_loc, C, D]
        # scatter back only this member's experts; psum completes the sum
        out_buf = jnp.zeros_like(buf)
        out_buf = jax.lax.dynamic_update_slice_in_dim(out_buf, out_my, m * e_loc, 0)
        combined = _combine(out_buf, e_flat, pos_c, keep, tok, top_w, t, dtype)
        combined = jax.lax.psum(combined.astype(jnp.float32), model_axis).astype(dtype)
        aux = jax.lax.pmean(aux, dp_axes) if dp_axes else aux
        return combined.reshape(b, l, d), aux

    # --- token-sharded EP: the paper's exchange, chunk per model member ---
    tm = t // pm
    xt_m = jax.lax.dynamic_slice_in_dim(xt, m * tm, tm, 0)  # my token slice
    top_w, top_e, aux = _route(xt_m, router, cfg.experts_per_token)
    cap = _capacity(cfg, tm)
    buf, e_flat, pos_c, keep, tok = _dispatch(xt_m, top_e, cap, cfg.num_experts, dtype)
    chunks = buf.reshape(pm, e_loc, cap, d)  # chunk q -> member q's experts

    if pipeline:
        # Adaptive-Group pipelined all-to-all (Algorithm 3): each arriving
        # chunk's expert FFN runs while later chunks are still in flight.
        from repro.comm import grouped_exchange

        def consume(acc, chunk, src):
            out = _expert_ffn(chunk, wg, wu, wd)  # [E_loc, C, D]
            return jax.lax.dynamic_update_index_in_dim(acc, out, src, 0)

        acc0 = jnp.zeros((pm, e_loc, cap, d), dtype)
        out_chunks = grouped_exchange(chunks, model_axis, consume, acc0, group_factor=group_factor)
    else:
        recv = jax.lax.all_to_all(
            chunks, model_axis, split_axis=0, concat_axis=0
        )  # [pm, e_loc, cap, d]: member q's tokens for my experts
        # batch all received chunks through the local experts at once
        recv_flat = recv.transpose(1, 0, 2, 3).reshape(e_loc, pm * cap, d)
        out_flat = _expert_ffn(recv_flat, wg, wu, wd)
        out_chunks = out_flat.reshape(e_loc, pm, cap, d).transpose(1, 0, 2, 3)

    # reverse exchange: results of chunk q go back to member q
    back = jax.lax.all_to_all(out_chunks, model_axis, split_axis=0, concat_axis=0)
    out_buf = back.reshape(cfg.num_experts, cap, d)
    combined = _combine(out_buf, e_flat, pos_c, keep, tok, top_w, tm, dtype)
    # restore full token replication across the model axis
    full = jax.lax.all_gather(combined, model_axis, axis=0, tiled=True)  # [T, D]
    return full.reshape(b, l, d), jax.lax.pmean(aux, dp_axes + (model_axis,))
