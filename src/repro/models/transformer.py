"""Generic decoder LM assembled from an ArchConfig.

One code path covers every assigned family via the block pattern:

  attn        global causal self-attention (+ SWA window) + FFN (dense/MoE)
  local       windowed self-attention + FFN
  cross       cross-attention over stub context (llama-vision image layers)
  attn_cross  self-attn + cross-attn + FFN (whisper decoder layer)
  rwkv        RWKV6 time-mix + channel-mix
  rglru       RG-LRU temporal mix + FFN

Depth is organized as ``n_full`` repeats of the pattern (stacked params,
``lax.scan`` + optional remat — O(1) HLO in depth, which is what keeps the
100-layer dry-run compilable) plus an explicit ragged tail.  Whisper adds a
separate bidirectional encoder stack over stub frame embeddings.

Caches/states mirror the layer structure ({'groups': {pos_j: stacked},
'tail': [...]}) and thread through the same scan in decode.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from .attention import attn_init, attention_block, init_kv_cache
from .layers import Initializer, mlp_apply, mlp_init, rmsnorm
from .moe import moe_block, moe_init
from .rglru import init_rglru_state, rglru_block, rglru_init
from .rwkv6 import init_rwkv_state, rwkv_block, rwkv_channel_mix, rwkv_init

__all__ = ["init_params", "forward", "encode", "init_caches", "layer_plan"]

_NOOP = lambda x, kind: x


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


def layer_plan(cfg) -> Tuple[int, Tuple[str, ...], Tuple[str, ...]]:
    """(n_full_groups, pattern, tail_kinds)."""
    pat = cfg.block_pattern
    n_full = cfg.num_layers // len(pat)
    tail = pat[: cfg.num_layers % len(pat)]
    return n_full, pat, tail


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _ffn_init(init: Initializer, cfg):
    if cfg.num_experts:
        return moe_init(init, cfg)
    return mlp_init(init, cfg.d_model, cfg.d_ff, cfg.act)


def _block_init(init: Initializer, cfg, kind: str):
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": init.ones((d,))}
    if kind in ("attn", "local"):
        p["attn"] = attn_init(init, cfg)
        p["ln2"] = init.ones((d,))
        p["ffn"] = _ffn_init(init, cfg)
    elif kind == "cross":
        p["xattn"] = attn_init(init, cfg, cross=True)
        p["ln2"] = init.ones((d,))
        p["ffn"] = _ffn_init(init, cfg)
        p["xgate"] = init.zeros(())  # llama-vision style gated cross-attn
    elif kind == "attn_cross":
        p["attn"] = attn_init(init, cfg)
        p["ln_c"] = init.ones((d,))
        p["xattn"] = attn_init(init, cfg, cross=True)
        p["ln2"] = init.ones((d,))
        p["ffn"] = _ffn_init(init, cfg)
    elif kind == "rwkv":
        p.update(rwkv_init(init, cfg))
        p["ln2"] = init.ones((d,))
    elif kind == "rglru":
        p["rec"] = rglru_init(init, cfg)
        p["ln2"] = init.ones((d,))
        p["ffn"] = mlp_init(init, cfg.d_model, cfg.d_ff, cfg.act)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def init_params(cfg, key: jax.Array):
    """Pure init function — run under ``jax.eval_shape`` for the dry-run."""
    init = Initializer(key)
    d = cfg.d_model
    n_full, pat, tail = layer_plan(cfg)
    params: Dict[str, Any] = {
        "embed": init.normal((cfg.padded_vocab, d)),
        "final_norm": init.ones((d,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init.normal((d, cfg.padded_vocab))
    groups = {}
    for j, kind in enumerate(pat):
        stacked = [ _block_init(init, cfg, kind) for _ in range(n_full) ]
        groups[f"pos{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked) if n_full else None
    params["groups"] = {k: v for k, v in groups.items() if v is not None}
    params["tail"] = [_block_init(init, cfg, kind) for kind in tail]
    if cfg.encoder_layers:
        params["encoder"] = {
            "blocks": [
                {
                    "ln1": init.ones((d,)),
                    "attn": attn_init(init, cfg),
                    "ln2": init.ones((d,)),
                    "ffn": mlp_init(init, d, cfg.d_ff, cfg.act),
                }
                for _ in range(cfg.encoder_layers)
            ],
            "final_norm": init.ones((d,)),
        }
    return params


# ---------------------------------------------------------------------------
# Caches / recurrent state
# ---------------------------------------------------------------------------


def _block_cache(cfg, kind: str, batch: int, s_buf: int):
    hd = cfg.resolved_head_dim
    if kind in ("attn", "attn_cross"):
        c = init_kv_cache(batch, cfg.num_kv_heads, s_buf, hd)
        return c
    if kind == "local":
        win_buf = min(s_buf, cfg.local_window + 128)
        return init_kv_cache(batch, cfg.num_kv_heads, win_buf, hd)
    if kind == "cross":
        return {"ctx": None}  # filled with projected context at prefill
    if kind == "rwkv":
        h = cfg.d_model // hd
        return init_rwkv_state(batch, h, hd, cfg.d_model)
    if kind == "rglru":
        return init_rglru_state(batch, cfg.d_model)
    raise ValueError(kind)


def cache_buffer_len(cfg, seq_len: int) -> int:
    """Self-attn KV buffer length for decode at context ``seq_len``."""
    if cfg.window > 0:
        return min(seq_len + 128, cfg.window + 128)
    return seq_len + 128


def init_caches(cfg, batch: int, seq_len: int, *, context_len: int = 0):
    """Zero caches for decoding with ``seq_len`` tokens of context.

    Cross-attention caches hold the projected stub context (filled by
    ``forward`` at prefill); here they are zero tensors of the right shape.
    """
    s_buf = cache_buffer_len(cfg, seq_len)
    n_full, pat, tail = layer_plan(cfg)
    hd = cfg.resolved_head_dim

    def one(kind):
        c = _block_cache(cfg, kind, batch, s_buf)
        if kind == "cross":
            lc = context_len or cfg.num_image_tokens or cfg.encoder_context
            c = {
                "xk": jnp.zeros((batch, cfg.num_kv_heads, lc, hd), jnp.bfloat16),
                "xv": jnp.zeros((batch, cfg.num_kv_heads, lc, hd), jnp.bfloat16),
            }
        if kind == "attn_cross":
            lc = context_len or cfg.encoder_context
            c["xk"] = jnp.zeros((batch, cfg.num_kv_heads, lc, hd), jnp.bfloat16)
            c["xv"] = jnp.zeros((batch, cfg.num_kv_heads, lc, hd), jnp.bfloat16)
        return c

    groups = {}
    for j, kind in enumerate(pat):
        if n_full:
            stacked = [one(kind) for _ in range(n_full)]
            groups[f"pos{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    return {"groups": groups, "tail": [one(kind) for kind in tail]}


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _ffn_apply(p, x, cfg, shard, dtype):
    if not cfg.num_experts:
        return mlp_apply(p, x, cfg.act, dtype=dtype), jnp.zeros((), jnp.float32)

    mesh = getattr(shard, "mesh", None)
    dp = tuple(getattr(shard, "dp_axes", ()) or ())
    mdl = getattr(shard, "model_axis", "model")
    if mesh is None or mdl not in mesh.axis_names:
        out, aux = moe_block(p, x, cfg, dtype=dtype)
        return out, aux

    # Token dispatch (argsort/scatter) is sharding-hostile under plain SPMD
    # (XLA replicates the global sort), so the whole MoE FFN runs in a
    # fully-manual shard_map: routing/scatter local per data shard, token
    # chunks exchanged over the model axis with the paper's grouped
    # pipeline or a fused all_to_all (models.moe.moe_block_manual).
    from jax.sharding import PartitionSpec as P

    from .moe import moe_block_manual

    fsdp = getattr(shard, "fsdp_axis", None)
    ep = cfg.moe_sharding == "ep"
    pspecs = {
        "router": P(fsdp, None),
        "w_gate": P(mdl, fsdp, None) if ep else P(None, fsdp, mdl),
        "w_up": P(mdl, fsdp, None) if ep else P(None, fsdp, mdl),
        "w_down": P(mdl, None, fsdp) if ep else P(None, mdl, fsdp),
    }

    def body(p_, x_):
        return moe_block_manual(
            p_,
            x_,
            cfg,
            dp_axes=dp,
            model_axis=mdl,
            fsdp_axis=fsdp,
            pipeline=getattr(shard, "moe_pipeline", False),
            group_factor=getattr(shard, "moe_group_factor", 1),
            dtype=dtype,
        )

    manual = set(dp) | {mdl}
    if fsdp:
        manual.add(fsdp)  # weight specs mention the FSDP axis even when the
        # batch is unsharded (long_500k b=1): it must be manual here too
    mapped = shard_map(
        body,
        mesh=mesh,
        in_specs=(pspecs, P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
        axis_names=manual,
        # outputs ARE replicated over the model axis (psum / final
        # all_gather above), but vma can't infer it through all_gather
        check_vma=False,
    )
    return mapped(p, x)


def _apply_block(
    p,
    h,
    cfg,
    kind: str,
    *,
    context=None,
    cache=None,
    pos=None,
    mode="train",
    shard=_NOOP,
    impl="xla",
    dtype=jnp.bfloat16,
    s_buf: Optional[int] = None,
):
    """Pre-norm residual block.  Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps
    build_len = s_buf if mode == "prefill" else None
    decode_cache = cache if mode == "decode" else None

    if kind in ("attn", "local"):
        window = cfg.window if kind == "attn" else cfg.local_window
        mix, new_cache = attention_block(
            p["attn"],
            rmsnorm(p["ln1"], h, eps),
            cfg,
            causal=True,
            window=window,
            cache=decode_cache,
            pos=pos,
            impl=impl,
            dtype=dtype,
            build_cache_len=build_len if kind == "attn" else (
                min(s_buf, cfg.local_window + 128) if build_len else None
            ),
            shard=shard,
        )
        h = shard(h + mix, "act")
        ff, aux = _ffn_apply(p["ffn"], rmsnorm(p["ln2"], h, eps), cfg, shard, dtype)
        h = shard(h + ff, "act")
    elif kind == "cross":
        if mode == "decode":
            xk, xv = cache["xk"], cache["xv"]
            mix = _cross_from_cache(p["xattn"], rmsnorm(p["ln1"], h, eps), cfg, xk, xv, dtype)
            new_cache = cache
        else:
            mix, _ = attention_block(
                p["xattn"], rmsnorm(p["ln1"], h, eps), cfg, context=context, dtype=dtype
            )
            new_cache = _project_context(
                p["xattn"],
                cfg,
                context,
                dtype,
            ) if mode == "prefill" else None
        h = shard(h + jnp.tanh(p["xgate"]).astype(h.dtype) * mix, "act")
        ff, aux = _ffn_apply(p["ffn"], rmsnorm(p["ln2"], h, eps), cfg, shard, dtype)
        h = shard(h + ff, "act")
    elif kind == "attn_cross":
        sub_cache = ({k: cache[k] for k in ("k", "v", "slot_pos")} if mode == "decode" else None)
        mix, new_kv = attention_block(
            p["attn"],
            rmsnorm(p["ln1"], h, eps),
            cfg,
            causal=True,
            cache=sub_cache,
            pos=pos,
            impl=impl,
            dtype=dtype,
            build_cache_len=build_len,
            shard=shard,
        )
        h = shard(h + mix, "act")
        if mode == "decode":
            xmix = _cross_from_cache(
                p["xattn"], rmsnorm(p["ln_c"], h, eps), cfg, cache["xk"], cache["xv"], dtype
            )
        else:
            xmix, _ = attention_block(
                p["xattn"], rmsnorm(p["ln_c"], h, eps), cfg, context=context, dtype=dtype
            )
        h = shard(h + xmix, "act")
        ff, aux = _ffn_apply(p["ffn"], rmsnorm(p["ln2"], h, eps), cfg, shard, dtype)
        h = shard(h + ff, "act")
        new_cache = None
        if mode == "prefill":
            new_cache = dict(new_kv or {}, **_project_context(p["xattn"], cfg, context, dtype))
        elif mode == "decode":
            new_cache = dict(new_kv, xk=cache["xk"], xv=cache["xv"])
    elif kind == "rwkv":
        state = cache if mode in ("decode", "prefill") else None
        if state is None and mode in ("decode", "prefill"):
            raise ValueError("rwkv needs state in cache modes")
        mix, new_state = rwkv_block(p, rmsnorm(p["ln1"], h, eps), cfg, state=state, dtype=dtype)
        h = shard(h + mix, "act")
        cm, new_state2 = rwkv_channel_mix(
            p, rmsnorm(p["ln2"], h, eps), state=new_state, dtype=dtype
        )
        h = shard(h + cm, "act")
        new_cache = new_state2
    elif kind == "rglru":
        state = cache if mode in ("decode", "prefill") else None
        mix, new_state = rglru_block(
            p["rec"], rmsnorm(p["ln1"], h, eps), cfg, state=state, dtype=dtype
        )
        h = shard(h + mix, "act")
        ff = mlp_apply(p["ffn"], rmsnorm(p["ln2"], h, eps), cfg.act, dtype=dtype)
        h = shard(h + ff, "act")
        new_cache = new_state
    else:
        raise ValueError(kind)
    return h, new_cache, aux


def _project_context(p, cfg, context, dtype):
    """Precompute cross-attention K/V from the (stub) context for decode."""
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    b, lc, _ = context.shape

    def proj(w):
        y = context.astype(dtype) @ w["w"].astype(dtype)
        if "b" in w:
            y = y + w["b"].astype(dtype)
        return y.reshape(b, lc, kv, hd).transpose(0, 2, 1, 3)

    return {"xk": proj(p["wk"]), "xv": proj(p["wv"])}


def _cross_from_cache(p, x, cfg, xk, xv, dtype):
    from .attention import decode_attention

    hd = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    b, l, _ = x.shape
    q = (x.astype(dtype) @ p["wq"]["w"].astype(dtype))
    if "b" in p["wq"]:
        q = q + p["wq"]["b"].astype(dtype)
    q = q.reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    lc = xk.shape[2]
    slot_pos = jnp.arange(lc)
    out = decode_attention(q, xk, xv, slot_pos, jnp.asarray(lc, jnp.int32), window=0)
    out = out.transpose(0, 2, 1, 3).reshape(b, l, h * hd)
    return out @ p["wo"]["w"].astype(dtype)


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------


def encode(params, cfg, frames: jax.Array, *, shard=_NOOP, dtype=jnp.bfloat16):
    """Bidirectional encoder over stub frame embeddings [B, T, D]."""
    h = frames.astype(dtype)
    enc = params["encoder"]
    for blk in enc["blocks"]:
        mix, _ = attention_block(
            blk["attn"], rmsnorm(blk["ln1"], h, cfg.norm_eps), cfg, causal=False, dtype=dtype
        )
        h = shard(h + mix, "act")
        ff = mlp_apply(blk["ffn"], rmsnorm(blk["ln2"], h, cfg.norm_eps), cfg.act, dtype=dtype)
        h = shard(h + ff, "act")
    return rmsnorm(enc["final_norm"], h, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward(
    params,
    cfg,
    tokens: jax.Array,  # [B, L] int32
    *,
    context: Optional[jax.Array] = None,  # [B, Lc, D] stub image/audio embeds
    mode: str = "train",  # train | prefill | decode
    caches=None,
    pos=None,  # decode position (scalar int32)
    shard=_NOOP,
    impl: str = "xla",
    remat: str = "full",
    dtype=jnp.bfloat16,
    s_buf: Optional[int] = None,  # prefill cache buffer length
    return_hidden: bool = False,  # skip the LM head (caller chunks the loss)
    unroll: bool = False,  # python-loop the groups (dry-run flop probes)
    cast_params: bool = False,  # cast >=2D weights to compute dtype up front
):
    """Returns (logits-or-hidden [B, L, V|D], new_caches, aux_loss)."""
    n_full, pat, tail = layer_plan(cfg)
    if cast_params:
        # cast-before-gather: FSDP all-gathers (and any hoisted copies of
        # the stacked layer weights) move bf16, not f32 — halves both the
        # gather bytes and the gathered-weight temps.  Masters stay f32 in
        # the optimizer; 1-D params (norms, mixes, decay bases) keep f32
        # for numerics.
        params = jax.tree.map(
            lambda x: x.astype(dtype)
            if (hasattr(x, "dtype") and x.dtype == jnp.float32 and x.ndim >= 2)
            else x,
            params,
        )
    h = params["embed"].astype(dtype)[tokens]
    h = shard(h, "act")
    use_cache = mode in ("prefill", "decode")
    if mode == "prefill" and caches is None:
        # zero recurrent states; attention caches are rebuilt by the blocks
        caches = init_caches(
            cfg,
            tokens.shape[0],
            tokens.shape[1],
            context_len=context.shape[1] if context is not None else 0,
        )

    pc = getattr(shard, "param_constraint", None)
    gspecs = getattr(shard, "group_specs", None)

    def group_step(h, group_params, group_cache):
        if pc is not None and gspecs is not None:
            # keep per-layer weights sharded at the loop boundary so the
            # FSDP gather stays INSIDE the scan body (one layer at a time)
            group_params = {k: pc(v, gspecs[k]) for k, v in group_params.items()}
        new_cache = {}
        aux = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(pat):
            pj = f"pos{j}"
            c = group_cache.get(pj) if group_cache else None
            h, nc, a = _apply_block(
                group_params[pj],
                h,
                cfg,
                kind,
                context=context,
                cache=c,
                pos=pos,
                mode=mode,
                shard=shard,
                impl=impl,
                dtype=dtype,
                s_buf=s_buf,
            )
            aux = aux + a
            if use_cache:
                new_cache[pj] = nc
        return h, new_cache, aux

    if remat == "full":
        group_step = jax.checkpoint(group_step, static_argnums=())
    elif remat == "dots":
        group_step = jax.checkpoint(
            group_step, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {"groups": {}, "tail": []}
    if n_full:
        if unroll:
            auxes = []
            group_cache_list = []
            for gi in range(n_full):
                gp = jax.tree.map(lambda x: x[gi], params["groups"])
                gc = (
                    jax.tree.map(lambda x: x[gi], caches["groups"])
                    if use_cache
                    else None
                )
                h, nc, a = group_step(h, gp, gc)
                auxes.append(a)
                if use_cache:
                    group_cache_list.append(nc)
            if use_cache:
                new_caches["groups"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *group_cache_list
                )
            auxes = jnp.stack(auxes)
        elif use_cache:
            def scan_body(h, xs):
                gp, gc = xs
                h, nc, a = group_step(h, gp, gc)
                return h, (nc, a)

            h, (stacked_caches, auxes) = jax.lax.scan(
                scan_body, h, (params["groups"], caches["groups"])
            )
            new_caches["groups"] = stacked_caches
        else:
            def scan_body_nc(h, gp):
                h, _, a = group_step(h, gp, None)
                return h, a

            h, auxes = jax.lax.scan(scan_body_nc, h, params["groups"])
        aux_total = aux_total + jnp.sum(auxes)

    for i, kind in enumerate(tail):
        c = caches["tail"][i] if use_cache and caches is not None else None
        h, nc, a = _apply_block(
            params["tail"][i],
            h,
            cfg,
            kind,
            context=context,
            cache=c,
            pos=pos,
            mode=mode,
            shard=shard,
            impl=impl,
            dtype=dtype,
            s_buf=s_buf,
        )
        aux_total = aux_total + a
        if use_cache:
            new_caches["tail"].append(nc)

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, (new_caches if use_cache else None), aux_total
    head = params.get("lm_head", None)
    if head is None:
        logits = h.astype(jnp.float32) @ params["embed"].astype(jnp.float32).T
    else:
        logits = h.astype(jnp.float32) @ head.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size, 0.0, -1e30)
        logits = logits + pad_mask
    logits = shard(logits, "logits")
    return logits, (new_caches if use_cache else None), aux_total
