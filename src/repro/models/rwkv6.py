"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

The headline Finch feature — per-channel, per-step data-dependent decay
``w_t = exp(-exp(base + lora(x_t)))`` — is implemented faithfully; the
r/k/v/g token-shift interpolations use static learned mixes (the full
ddlerp double-LoRA is a parameter-efficiency refinement, noted as a
simplification in DESIGN.md §10).

Sequence processing is *chunk-parallel*: within a chunk of C steps the
recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T,     o_t = r_t S_{t-1} + (r_t.u.k_t) v_t

expands into an intra-chunk lower-triangular contraction with pairwise decay
ratios ``exp(lw_{i-1} - lw_j)`` (computed as exponentials of *differences* of
cumulative log-decays, which are <= 0 — numerically safe), plus an
inter-chunk state term.  A naive lax.scan reference (``wkv_scan_ref``) is
the test oracle.  Decode carries (state S, last token x) per layer.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .layers import Initializer, dense_init

__all__ = [
    "rwkv_init",
    "rwkv_block",
    "rwkv_decode",
    "wkv_chunked",
    "wkv_scan_ref",
    "init_rwkv_state",
]


def rwkv_init(init: Initializer, cfg):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h = d // hd
    f = cfg.d_ff
    lora = max(32, d // 16)
    return {
        "time": {
            "mix_r": init.normal((d,), 0.5),
            "mix_k": init.normal((d,), 0.5),
            "mix_v": init.normal((d,), 0.5),
            "mix_g": init.normal((d,), 0.5),
            "mix_w": init.normal((d,), 0.5),
            "wr": dense_init(init, d, d),
            "wk": dense_init(init, d, d),
            "wv": dense_init(init, d, d),
            "wg": dense_init(init, d, d),
            "wo": dense_init(init, d, d),
            # data-dependent decay: w = exp(-exp(base + tanh(x A) B))
            "w_base": init.normal((d,), 0.5) - 6.0,
            "w_lora_a": init.normal((d, lora), 0.02),
            "w_lora_b": init.normal((lora, d), 0.02),
            "u_bonus": init.normal((h, hd), 0.5),
            "ln_x": init.ones((d,)),  # per-head group-norm scale on output
        },
        "channel": {
            "mix_k": init.normal((d,), 0.5),
            "wk": dense_init(init, d, f),
            "wv": dense_init(init, f, d),
        },
    }


def init_rwkv_state(batch: int, num_heads: int, head_dim: int, d_model: int):
    return {
        "wkv": jnp.zeros((batch, num_heads, head_dim, head_dim), jnp.float32),
        "x_prev_t": jnp.zeros((batch, d_model), jnp.float32),  # time-mix shift
        "x_prev_c": jnp.zeros((batch, d_model), jnp.float32),  # channel-mix shift
    }


# ---------------------------------------------------------------------------
# WKV recurrence
# ---------------------------------------------------------------------------


def wkv_scan_ref(r, k, v, logw, u, s0):
    """Naive per-step scan (oracle).  r/k/v/logw: [B, H, L, D]; u: [H, D];
    s0: [B, H, D, D].  Returns (o [B,H,L,D], sT)."""

    def step(s, inp):
        r_t, k_t, v_t, lw_t = inp  # [B, H, D]
        w_t = jnp.exp(lw_t)
        kv = jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        o_t = jnp.einsum("bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, o_t

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (r, k, v, logw))
    sT, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 2), sT


def wkv_chunked(r, k, v, logw, u, s0, *, chunk: int = 32):
    """Chunk-parallel WKV.  Shapes as in :func:`wkv_scan_ref`."""
    b, h, l, d = r.shape
    c = min(chunk, l)
    assert l % c == 0, (l, c)
    n = l // c

    def to_chunks(a):
        return a.reshape(b, h, n, c, d).transpose(2, 0, 1, 3, 4)  # [n,b,h,c,d]

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))

    # recompute the [b,h,c,c,d] pairwise-decay tensor in the backward pass
    @jax.checkpoint
    def chunk_step(s, inp):
        rr, kk, vv, lw = (a.astype(jnp.float32) for a in inp)  # [b,h,c,d]
        lw_cum = jnp.cumsum(lw, axis=2)  # inclusive cumulative log-decay
        lw_ex = lw_cum - lw  # exclusive
        # inter-chunk: o_i += (r_i * exp(lw_ex_i)) @ S
        r_dec = rr * jnp.exp(lw_ex)
        o = jnp.einsum("bhcd,bhde->bhce", r_dec, s)
        # intra-chunk: A[i,j] = sum_d r[i,d] k[j,d] exp(lw_ex[i,d]-lw_cum[j,d]), j<i
        diff = lw_ex[:, :, :, None, :] - lw_cum[:, :, None, :, :]  # [b,h,c,c,d]
        iu = jnp.tril(jnp.ones((c, c), bool), k=-1)  # strict lower: j < i
        dec = jnp.where(iu[None, None, :, :, None], jnp.exp(diff), 0.0)
        a = jnp.einsum("bhid,bhijd,bhjd->bhij", rr, dec, kk)
        # current-token bonus (diagonal term)
        bonus = jnp.einsum("bhcd,hd->bhc", rr * kk, u)
        o = o + jnp.einsum("bhij,bhjd->bhid", a, vv) + bonus[..., None] * vv
        # state update: S' = diag(exp(lw_total)) S + sum_j exp(lw_total - lw_cum_j) k_j v_j^T
        lw_tot = lw_cum[:, :, -1:, :]  # [b,h,1,d]
        k_dec = kk * jnp.exp(lw_tot - lw_cum)
        s = jnp.exp(lw_tot[:, :, 0, :, None]) * s + jnp.einsum("bhcd,bhce->bhde", k_dec, vv)
        return s, o

    sT, oc = jax.lax.scan(chunk_step, s0.astype(jnp.float32), (rc, kc, vc, lwc))
    o = oc.transpose(1, 2, 0, 3, 4).reshape(b, h, l, d)
    return o.astype(r.dtype), sT


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _token_shift(x, x_prev):
    """[B, L, D] -> previous-token features (x_prev fills position 0)."""
    shifted = jnp.roll(x, 1, axis=1)
    return shifted.at[:, 0].set(x_prev) if x_prev is not None else shifted.at[:, 0].set(0.0)


def _mix(x, xx, mix):
    return x + (xx - x) * jax.nn.sigmoid(mix)[None, None, :]


def rwkv_block(p, x: jax.Array, cfg, *, state=None, dtype=jnp.bfloat16):
    """Time-mix over a full sequence (train/prefill).  x: [B, L, D].

    Returns (time_mix_out, channel_mix_fn, new_state).  The transformer block
    applies: h = x + time_mix(norm(x)); h = h + channel_mix(norm(h)).
    This function only computes the time-mix; channel-mix is separate
    (``rwkv_channel_mix``) so the caller owns norms/residuals.
    """
    t = p["time"]
    hd = cfg.resolved_head_dim
    h = cfg.d_model // hd
    b, l, d = x.shape
    x_prev = state["x_prev_t"] if state is not None else None
    xx = _token_shift(x, x_prev)

    def proj(name, mixname):
        xm = _mix(x, xx, t[mixname])
        return (xm.astype(dtype) @ t[name]["w"].astype(dtype)).astype(jnp.float32)

    r = proj("wr", "mix_r").reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    k = proj("wk", "mix_k").reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    v = proj("wv", "mix_v").reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    g = proj("wg", "mix_g").reshape(b, l, d)

    xw = _mix(x, xx, t["mix_w"]).astype(jnp.float32)
    lora = jnp.tanh(xw @ t["w_lora_a"].astype(jnp.float32)) @ t["w_lora_b"].astype(jnp.float32)
    logw = -jnp.exp(t["w_base"].astype(jnp.float32)[None, None] + lora)  # < 0
    logw = logw.reshape(b, l, h, hd).transpose(0, 2, 1, 3)

    s0 = state["wkv"] if state is not None else jnp.zeros((b, h, hd, hd), jnp.float32)
    o, sT = wkv_chunked(r, k, v, logw, t["u_bonus"].astype(jnp.float32), s0)
    o = o.transpose(0, 2, 1, 3).reshape(b, l, d)
    # per-head group norm
    oh = o.reshape(b, l, h, hd)
    oh = (oh - oh.mean(-1, keepdims=True)) * jax.lax.rsqrt(oh.var(-1, keepdims=True) + 1e-5)
    o = (oh.reshape(b, l, d) * t["ln_x"][None, None]).astype(dtype)
    o = o * jax.nn.silu(g.astype(dtype))
    out = o @ t["wo"]["w"].astype(dtype)

    new_state = None
    if state is not None:
        new_state = {
            "wkv": sT,
            "x_prev_t": x[:, -1].astype(jnp.float32),
            "x_prev_c": state["x_prev_c"],
        }
    return out, new_state


def rwkv_channel_mix(p, x: jax.Array, *, state=None, dtype=jnp.bfloat16):
    c = p["channel"]
    x_prev = state["x_prev_c"] if state is not None else None
    xx = _token_shift(x, x_prev)
    xk = _mix(x, xx, c["mix_k"]).astype(dtype)
    hidden = jnp.square(jax.nn.relu(xk @ c["wk"]["w"].astype(dtype)))
    out = hidden @ c["wv"]["w"].astype(dtype)
    new_state = None
    if state is not None:
        new_state = dict(state, x_prev_c=x[:, -1].astype(jnp.float32))
    return out, new_state


def rwkv_decode(p, x_t: jax.Array, cfg, state, *, dtype=jnp.bfloat16):
    """Single-token step.  x_t: [B, D]; returns (out [B, D], new_state) for
    the time-mix; channel mix handled by rwkv_channel_mix with L=1."""
    out, new_state = rwkv_block(p, x_t[:, None, :], cfg, state=state, dtype=dtype)
    return out[:, 0], new_state
