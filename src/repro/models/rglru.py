"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Temporal mix: two input projections (one GeLU-gated), a short causal
depthwise conv (width 4), then the Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(L) * r_t)     (data-dependent per-channel decay)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The linear recurrence runs as a ``jax.lax.associative_scan`` (O(log L)
depth) for train/prefill and a single fused step for decode.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import Initializer, dense_init

__all__ = ["rglru_init", "rglru_block", "rglru_decode", "init_rglru_state"]

_C = 8.0  # Griffin's recurrence sharpness constant


def rglru_init(init: Initializer, cfg):
    d = cfg.d_model
    return {
        "w_in": dense_init(init, d, d),
        "w_gate": dense_init(init, d, d),
        "conv_w": init.normal((4, d), 0.1),  # causal depthwise conv, width 4
        "conv_b": init.zeros((d,)),
        "lru_a": dense_init(init, d, d, bias=True),  # recurrence gate
        "lru_x": dense_init(init, d, d, bias=True),  # input gate
        "lambda_raw": init.normal((d,), 0.5),  # softplus -> decay magnitude
        "w_out": dense_init(init, d, d),
    }


def init_rglru_state(batch: int, d_model: int):
    return {
        "h": jnp.zeros((batch, d_model), jnp.float32),
        "conv": jnp.zeros((batch, 3, d_model), jnp.float32),  # last 3 inputs
    }


def _conv_causal(w, b, x, state_tail=None):
    """Depthwise causal conv width 4.  x: [B, L, D]."""
    b_, l, d = x.shape
    if state_tail is None:
        tail = jnp.zeros((b_, 3, d), x.dtype)
    else:
        tail = state_tail.astype(x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # [B, L+3, D]
    out = sum(xp[:, i : i + l] * w[i][None, None] for i in range(4))
    return out + b[None, None]


def _lru_scan(a: jax.Array, bx: jax.Array, h0: Optional[jax.Array]):
    """h_t = a_t h_{t-1} + bx_t via associative scan.  a/bx: [B, L, D]."""

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    a_s, b_s = jax.lax.associative_scan(op, (a, bx), axis=1)
    if h0 is not None:
        b_s = b_s + a_s * h0[:, None, :]
    return b_s


def rglru_block(p, x: jax.Array, cfg, *, state=None, dtype=jnp.bfloat16):
    """Temporal mix over a sequence.  x: [B, L, D]; returns (out, new_state)."""
    xb = x.astype(dtype)
    gate = jax.nn.gelu(xb @ p["w_gate"]["w"].astype(dtype))
    u_pre = xb @ p["w_in"]["w"].astype(dtype)  # pre-conv (the conv state)
    u = _conv_causal(
        p["conv_w"].astype(dtype),
        p["conv_b"].astype(dtype),
        u_pre,
        None if state is None else state["conv"],
    )
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["lru_a"]["w"].astype(jnp.float32) + p["lru_a"]["b"])
    i = jax.nn.sigmoid(uf @ p["lru_x"]["w"].astype(jnp.float32) + p["lru_x"]["b"])
    log_a = -_C * jax.nn.softplus(p["lambda_raw"].astype(jnp.float32))[None, None] * r
    a = jnp.exp(log_a)
    bx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    h0 = state["h"] if state is not None else None
    h = _lru_scan(a, bx, h0)
    out = (h.astype(dtype) * gate) @ p["w_out"]["w"].astype(dtype)
    new_state = None
    if state is not None:
        new_state = {
            "h": h[:, -1].astype(jnp.float32),
            # keep the last 3 *pre-conv* inputs (robust to any L incl. decode)
            "conv": jnp.concatenate(
                [state["conv"], u_pre.astype(jnp.float32)], axis=1
            )[:, -3:],
        }
    return out, new_state


def rglru_decode(p, x_t: jax.Array, cfg, state, *, dtype=jnp.bfloat16):
    out, new_state = rglru_block(p, x_t[:, None, :], cfg, state=state, dtype=dtype)
    return out[:, 0], new_state
