"""Model factory: ArchConfig -> callable model + sharding specs + input specs.

Everything the launcher needs for one architecture:

* ``init_fn(key) -> params``             (pure; eval_shape-able)
* ``loss_fn(params, batch) -> loss``     (train step objective)
* ``prefill_fn(params, batch) -> (logits, caches)``
* ``decode_fn(params, batch) -> (logits, caches)``  (one token)
* ``param_pspecs(params) -> pytree of PartitionSpec``
* ``input_specs(shape_spec) -> (ShapeDtypeStructs, PartitionSpecs)``

Sharding rules (DESIGN.md §7): TP over ``model`` (attention heads / FFN
hidden / vocab), DP over ``('pod','data')``, optional FSDP (ZeRO-3 weight
sharding) over ``data``, EP for MoE ('ep' mode), KV caches sequence-sharded
over ``model`` (kv-head counts don't divide the axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, ShardingConfig
from .transformer import cache_buffer_len, encode, forward, init_caches, init_params

__all__ = ["Model", "build_model", "chunked_ce_loss"]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def chunked_ce_loss(
    h: jax.Array,  # [B, S, D] final hidden (pre-logits)
    head: jax.Array,  # [D, V_pad]
    labels: jax.Array,  # [B, S] int32
    *,
    chunk: int = 512,
    shard=lambda x, kind: x,
    vocab_size: int = 0,  # true vocab; pad columns beyond it are masked
) -> jax.Array:
    """Cross-entropy with sequence-chunked logits (bounds the [B,c,V] temp)."""
    b, s, d = h.shape
    v_pad = head.shape[1]
    pad_mask = None
    if vocab_size and v_pad != vocab_size:
        pad_mask = jnp.where(jnp.arange(v_pad) < vocab_size, 0.0, -1e30)
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    n = s // chunk
    hs = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    # recompute the [B, chunk, V] logits in the backward pass (they would
    # otherwise be saved per scan step — the whole point of chunking)
    @jax.checkpoint
    def step(carry, xs):
        hc, lc = xs
        logits = shard(hc.astype(jnp.float32) @ head.astype(jnp.float32), "logits3")
        if pad_mask is not None:
            logits = logits + pad_mask
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def param_pspecs(params, cfg: ArchConfig, sh: ShardingConfig):
    """PartitionSpec pytree matching ``params`` (works on shape trees too)."""
    mdl = sh.model_axis
    fsdp = "data" if sh.fsdp else None

    def rule(pathstr: str, ndim: int):
        def pad(spec):
            return P(*([None] * (ndim - len(spec)) + list(spec)))

        leaf = pathstr.rsplit("/", 1)[-1]
        if leaf == "embed":
            return pad([mdl, fsdp])
        if leaf == "lm_head":
            return pad([fsdp, mdl])
        if leaf == "router":
            return pad([fsdp, None])
        if "ffn/" in pathstr and leaf in ("w_gate", "w_up", "w_down") and cfg.num_experts:
            ep = cfg.moe_sharding == "ep"
            if leaf in ("w_gate", "w_up"):  # [E, D, F]
                return pad([mdl, fsdp, None] if ep else [None, fsdp, mdl])
            return pad([mdl, None, fsdp] if ep else [None, mdl, fsdp])  # [E, F, D]
        if leaf in ("w_gate", "w_up"):  # dense MLP [D, F]
            return pad([fsdp, mdl])
        if leaf == "w_down":  # [F, D]
            return pad([mdl, fsdp])
        if "channel/wv" in pathstr:  # rwkv channel down-proj [F, D]
            return pad([mdl, fsdp])
        if pathstr.endswith("wo/w") or pathstr.endswith("w_out/w"):
            return pad([mdl, fsdp])
        if pathstr.endswith("/w") and any(
            f"/{n}/" in pathstr
            for n in ("wq", "wk", "wv", "wg", "wr", "w_in", "w_gate", "lru_a", "lru_x")
        ):
            # [D_in, D_out]: TP on the output dim
            return pad([fsdp, mdl])
        if pathstr.endswith("/b"):
            return pad([mdl])
        if leaf == "conv_w":  # [4, D]
            return pad([None, mdl])
        if leaf in ("lambda_raw", "conv_b"):
            return pad([mdl])
        if leaf in ("w_lora_a", "w_lora_b"):
            return pad([None, None])
        # norms, mixes, gates, u_bonus: replicated
        return P(*([None] * ndim))

    def assign(path, leaf):
        nd = len(leaf.shape)
        return rule(_path_str(path), nd)

    return jax.tree_util.tree_map_with_path(assign, params)


def _act_shard_fn(cfg: ArchConfig, sh: ShardingConfig, mesh):
    if mesh is None:
        return lambda x, kind: x
    dp = tuple(a for a in sh.batch_axes if a in mesh.axis_names)
    mdl = sh.model_axis if sh.model_axis in mesh.axis_names else None
    ep = cfg.moe_sharding == "ep"

    # Sequence parallelism (Megatron-style): with ``seq_axis`` the residual
    # stream (and hence every remat scan carry) shards its sequence dim over
    # the model axis — the difference between 40 x 537MB and 40 x 34MB of
    # carries on an 8B/4k train step (EXPERIMENTS.md §Perf).
    seq = sh.seq_axis if sh.seq_axis in mesh.axis_names else None
    mdl_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get(mdl, 1)
    q_div = mdl is not None and cfg.num_heads % mdl_size == 0
    kv_div = mdl is not None and cfg.num_kv_heads % mdl_size == 0
    act_spec = P(dp, seq, None) if sh.sp_dim == 1 else P(dp, None, seq)
    specs = {
        "act": act_spec,
        "logits": P(dp, None, mdl),
        "logits3": P(None, dp, mdl),  # chunked loss: [n?, B, c, V] -> (B,c,V)
    }
    # explicit head sharding through attention: without these anchors the
    # SPMD partitioner reshards the [b,h,qc,kc] logits between scan steps
    # ("involuntary full rematerialization" — 4 GiB replicated copies on the
    # 90B cell).  KV heads are repeated to the q-head count in the block
    # when they don't divide the axis (factory sets attn_repeat_kv).
    if sh.attn_anchor and q_div:
        specs["q4"] = P(dp, mdl, None, None)
        specs["attn5"] = P(dp, mdl, None, None, None)
    if sh.attn_anchor and (q_div or kv_div):
        specs["kv4"] = P(dp, mdl, None, None)

    def shard(x, kind):
        spec = specs.get(kind)
        if spec is None:
            return x
        spec = P(*list(spec)[: x.ndim])
        return jax.lax.with_sharding_constraint(x, jax.sharding.NamedSharding(mesh, spec))

    # MoE dispatch runs data-manual / model-auto (transformer._ffn_apply):
    # the inner constraints may only mention the (auto) model axis.
    inner_specs = {
        "moe_buffer": P(mdl, None, None) if ep else P(),
        "moe_hidden": P(mdl, None, None) if ep else P(None, None, mdl),
    }

    def moe_inner(x, kind):
        spec = inner_specs.get(kind)
        if spec is None:
            return x
        spec = P(*list(spec)[: x.ndim])
        # raw PartitionSpec: resolved against the ambient (abstract) mesh —
        # required inside the data-manual/model-auto shard_map region where
        # a concrete NamedSharding on auto axes is rejected
        return jax.lax.with_sharding_constraint(x, spec)

    shard.mesh = mesh
    shard.dp_axes = dp
    shard.model_axis = mdl
    # repeat KV heads up to q heads when that's what makes them shardable
    shard.attn_repeat_kv = sh.attn_anchor and q_div and not kv_div
    shard.attn_chunk = sh.attn_chunk
    shard.fsdp_axis = "data" if sh.fsdp else None
    shard.moe_inner = moe_inner
    shard.moe_pipeline = sh.moe_pipeline
    shard.moe_group_factor = 1

    def param_constraint(group_params, full_specs):
        """Re-assert the (sliced) per-layer param sharding inside a scan
        body: without it XLA may hoist the FSDP all-gather of the whole
        stacked parameter array out of the loop (n_layers x the memory)."""

        def fix(spec, leaf):
            sub = P(*list(spec)[1:]) if len(spec) > len(leaf.shape) else spec
            return jax.lax.with_sharding_constraint(leaf, jax.sharding.NamedSharding(mesh, sub))

        return jax.tree.map(lambda s_, l: fix(s_, l), full_specs, group_params)

    shard.param_constraint = param_constraint
    return shard


def cache_pspecs(caches, cfg: ArchConfig, sh: ShardingConfig):
    """KV caches: batch over DP, sequence over model; states: channel over model."""
    mdl = sh.model_axis
    dp = sh.batch_axes

    def assign(path, leaf):
        ps = _path_str(path)
        nd = len(leaf.shape)
        leafname = ps.rsplit("/", 1)[-1]
        has_group_dim = "groups/" in ps  # stacked leading layer dim

        def pad(spec):
            spec = list(spec)
            if has_group_dim:
                spec = [None] + spec
            spec = spec[:nd] + [None] * (nd - len(spec))
            return P(*spec)

        if leafname in ("k", "v"):  # [B, Hkv, S, hd]
            return pad([dp, None, mdl, None])
        if leafname in ("xk", "xv"):
            return pad([dp, None, None, None])
        if leafname == "slot_pos":
            return pad([None])
        if leafname == "wkv":  # [B, H, dk, dv]
            return pad([dp, None, None, mdl])
        if leafname in ("x_prev_t", "x_prev_c", "h"):  # [B, D]
            return pad([dp, mdl])
        if leafname == "conv":  # [B, 3, D]
            return pad([dp, None, mdl])
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(assign, caches)


# ---------------------------------------------------------------------------
# Model bundle
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    sharding: ShardingConfig
    mesh: Optional[Any]
    init_fn: Callable
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    init_caches_fn: Callable

    def param_specs(self, params_or_shapes):
        return param_pspecs(params_or_shapes, self.cfg, self.sharding)

    def cache_specs(self, cache_shapes):
        return cache_pspecs(cache_shapes, self.cfg, self.sharding)

    # ---- dry-run input construction ------------------------------------
    def input_specs(self, shape: ShapeSpec):
        """ShapeDtypeStruct stand-ins + PartitionSpecs for one shape cell."""
        cfg = self.cfg
        dp = self.sharding.batch_axes
        b, s = shape.global_batch, shape.seq_len
        structs: Dict[str, Any] = {}
        specs: Dict[str, Any] = {}
        ctx_len, ctx_needed = self._context_len()
        if shape.kind == "train":
            structs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            specs["tokens"] = P(dp, None)
        elif shape.kind == "prefill":
            structs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            specs["tokens"] = P(dp, None)
        else:  # decode
            structs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            specs["tokens"] = P(dp, None)
            structs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
            specs["pos"] = P()
            cache_shapes = jax.eval_shape(
                lambda: init_caches(cfg, b, s, context_len=ctx_len)
            )
            structs["caches"] = cache_shapes
            specs["caches"] = cache_pspecs(cache_shapes, cfg, self.sharding)
        if ctx_needed and shape.kind != "decode":
            structs["context"] = jax.ShapeDtypeStruct((b, ctx_len, cfg.d_model), jnp.bfloat16)
            specs["context"] = P(dp, None, None)
        return structs, specs

    def _context_len(self) -> Tuple[int, bool]:
        cfg = self.cfg
        if cfg.family == "vlm":
            return cfg.num_image_tokens, True
        if cfg.family == "audio":
            return cfg.encoder_context, True
        return 0, False


def build_model(
    cfg: ArchConfig,
    sharding: Optional[ShardingConfig] = None,
    mesh=None,
    *,
    impl: str = "xla",
    dtype=jnp.bfloat16,
    unroll: bool = False,  # python-loop depth groups (dry-run flop probes)
    cast_params: Optional[bool] = None,  # default: True iff mesh present
) -> Model:
    sh = sharding or ShardingConfig()
    shard = _act_shard_fn(cfg, sh, mesh)
    remat = sh.remat
    cast_once = (mesh is not None) if cast_params is None else cast_params
    if mesh is not None:
        _shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
        shard.group_specs = param_pspecs(_shapes, cfg, sh).get("groups", {})

    def init_fn(key):
        return init_params(cfg, key)

    def _context_of(batch):
        ctx = batch.get("context")
        if ctx is not None and cfg.family == "audio":
            # stub frame embeddings -> encoder -> cross-attn context
            return lambda params: encode(params, cfg, ctx, shard=shard, dtype=dtype)
        if ctx is not None:
            return lambda params: ctx.astype(dtype)
        return lambda params: None

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        ctx = _context_of(batch)(params)
        h, _, aux = forward(
            params,
            cfg,
            tokens,
            context=ctx,
            mode="train",
            shard=shard,
            impl=impl,
            remat=remat,
            dtype=dtype,
            return_hidden=True,
            unroll=unroll,
            cast_params=cast_once,
        )
        head = params.get("lm_head")
        if head is None:
            head = params["embed"].T
        loss = chunked_ce_loss(
            h[:, :-1], head, tokens[:, 1:], shard=shard, vocab_size=cfg.vocab_size
        )
        return loss + 0.01 * aux

    def prefill_fn(params, batch):
        tokens = batch["tokens"]
        ctx = _context_of(batch)(params)
        s_buf = cache_buffer_len(cfg, tokens.shape[1])
        logits, caches, _ = forward(
            params,
            cfg,
            tokens,
            context=ctx,
            mode="prefill",
            shard=shard,
            impl=impl,
            remat="none",
            dtype=dtype,
            s_buf=s_buf,
            unroll=unroll,
            cast_params=cast_once,
        )
        return logits[:, -1], caches

    def decode_fn(params, batch):
        tokens = batch["tokens"]  # [B, 1]
        pos = batch["pos"]
        caches = batch["caches"]
        logits, new_caches, _ = forward(
            params,
            cfg,
            tokens,
            mode="decode",
            caches=caches,
            pos=pos,
            shard=shard,
            impl=impl,
            remat="none",
            dtype=dtype,
            unroll=unroll,
            cast_params=cast_once,
        )
        return logits[:, -1], new_caches

    def init_caches_fn(batch_size, seq_len, context_len=0):
        return init_caches(cfg, batch_size, seq_len, context_len=context_len)

    return Model(
        cfg=cfg,
        sharding=sh,
        mesh=mesh,
        init_fn=init_fn,
        loss_fn=loss_fn,
        prefill_fn=prefill_fn,
        decode_fn=decode_fn,
        init_caches_fn=init_caches_fn,
    )
