"""One table program: THE partition-DP executor, shared by backends.

The color-coding DP is one *table program*: walk the partition nodes in
topological order, keep a table ``C_node [rows, width]`` per live node, and
at each internal node contract the left child against the neighbor sum of
the right child.  Until this module existed that recursion was written
twice — once in ``count_engine`` (in-core) and once inside ``distributed``
(shard_map) — and the two copies had already drifted (fusion, true-width
tables, and batched colorings only worked in-core).

Now the recursion lives here, once, over a *program* — either a single
template's :class:`~repro.core.templates.PartitionChain` or a whole family
compiled into a :class:`~repro.core.templates.TemplateDag` (deduplicated by
rooted-canonical subtree signature, so canonically-identical subtrees across
templates are computed once and read many times).  The backends differ only
in their **neighbor-sum strategy** — the ``node_fn`` callback that produces
one internal node's (unmasked) output table:

``local`` (:func:`local_node_fn`)
    ``M = spmm(A, C_right)`` over the whole in-core graph, or the fused
    SpMM->combine kernel that never materializes ``M``.

``exchange`` (built inside :mod:`repro.core.distributed`)
    ``M`` assembled from remote shards via one of the four exchange modes
    (``alltoall``/``pipeline``/``adaptive``/``ring``), consumed through the
    §3.3 tiled bucket layout — the same edge-tile/fused kernels, per chunk.

The executor owns everything the strategies must agree on: leaf
construction, pad-row/pad-column re-masking after every combine, table
lifetime (reference-counted: a table is freed the moment its last reader —
parent node or root delivery — has consumed it, the paper's sub-template
table lifetime management generalized to shared tables), and the root
reduction.  A strategy cannot forget to mask or leak a table; the backends
cannot drift.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .frontier import (
    CompactionSpec,
    Frontier,
    compact_combine,
    inverse_map,
)

__all__ = [
    "build_node_tables",
    "leaf_table",
    "run_table_program",
    "root_count",
    "local_node_fn",
    "BagFns",
]

#: strategy signature: (node_index, combine_tables, c_left, c_right,
#: f_left, f_right) -> unmasked output table [rows, >= s_pad] for that
#: internal node.  ``f_left``/``f_right`` are the children's
#: :class:`~repro.core.frontier.Frontier` records (None when dense).
NodeFn = Callable[
    [
        int,
        ops.CombineTables,
        jax.Array,
        jax.Array,
        Optional[Frontier],
        Optional[Frontier],
    ],
    jax.Array,
]

#: frontier hook: (node_index, masked table) -> Frontier or None; computed
#: once per produced table, shared by every consumer (see core.frontier)
FrontierFn = Callable[[int, jax.Array], Optional[Frontier]]


class BagFns(NamedTuple):
    """Backend strategy for the three bag-only node kinds (DESIGN.md §19).

    ``bag_combine`` nodes flow through the ordinary ``node_fn`` — the
    backend's neighbor-sum strategy reshapes ``[rows, x*W]`` tables to
    ``[rows*x, W]`` around its color convolution — so only the kinds with
    no tree analogue need callbacks here:

    * ``leaf_fn(i, nd)`` — build the bag leaf table ``[rows, x * k_pad]``
      (``pin=True`` multiplies the one-hot by the apex adjacency).
    * ``collapse_fn(i, child)`` — sum the finished forest-tree table over
      its vertex rows and apply the apex-color filter; returns ``[x, W]``.
    * ``join_fn(i, tbl, left, right)`` — disjoint color-set convolution of
      two collapsed ``[x, W]`` tables on aligned rows.
    """

    leaf_fn: Callable[[int, object], jax.Array]
    collapse_fn: Callable[[int, jax.Array], jax.Array]
    join_fn: Callable[[int, ops.CombineTables, jax.Array, jax.Array], jax.Array]


def build_node_tables(
    program, k: int, *, lane: int = 128, x_dim: Optional[int] = None
) -> Tuple[Dict[int, ops.CombineTables], Dict[int, int]]:
    """Per-node split tables + padded widths for one table program.

    ``program`` is a :class:`PartitionChain` or :class:`TemplateDag` (any
    object with ``.nodes`` of partition nodes).  ``lane`` is the
    column-padding multiple (128 for the Pallas kernels, 1 for true-width
    XLA tables).  Shared by both plan builders.

    ``x_dim`` (the host vertex count) is required when the program carries
    bag nodes: their stored tables are ``[rows, x_dim * W]`` row-major over
    the pinned-apex axis, so the recorded width is the *stored* column
    count — ``x_dim`` per-x blocks of the lane-padded block width ``W``.
    Collapsed/joined tables live on the ``x`` axis itself (one block wide).
    """
    combine: Dict[int, ops.CombineTables] = {}
    widths: Dict[int, int] = {}
    for i, nd in enumerate(program.nodes):
        kind = nd.kind
        if kind in ("bag_leaf", "bag_combine", "bag_collapse", "bag_join"):
            if x_dim is None:
                raise ValueError("bag-node programs need x_dim (host vertex count)")
        if kind == "leaf":
            widths[i] = ops.pad_to(k, lane)
        elif kind == "bag_leaf":
            widths[i] = ops.pad_to(k, lane) * x_dim
        elif kind == "bag_collapse":
            # per-x block of the child, on the x axis: one block wide
            widths[i] = widths[nd.left] // x_dim
        else:  # "combine" / "bag_combine" / "bag_join": a color convolution
            t1 = program.nodes[nd.left].size
            t2 = program.nodes[nd.right].size
            tables = ops.build_combine_tables(k, t1, t2, lane=lane)
            combine[i] = tables
            widths[i] = tables.s_pad * (x_dim if kind == "bag_combine" else 1)
    return combine, widths


def leaf_table(coloring: jax.Array, k_pad: int, row_mask: jax.Array) -> jax.Array:
    """Leaf tables: one-hot of the coloring, pad rows zeroed."""
    return jax.nn.one_hot(coloring, k_pad, dtype=jnp.float32) * row_mask


def run_table_program(
    program,
    combine: Mapping[int, ops.CombineTables],
    leaf: jax.Array,
    row_mask: jax.Array,
    node_fn: NodeFn,
    root_fn: Optional[Callable[[jax.Array], jax.Array]] = None,
    frontier_fn: Optional[FrontierFn] = None,
    bag: Optional[BagFns] = None,
) -> tuple:
    """Execute a table program; returns one value per ``program.roots`` entry.

    ``program`` is a :class:`PartitionChain` (one root) or a
    :class:`TemplateDag` (one root per compiled template).  This is the only
    copy of the node recursion in the codebase.  Every leaf shares the
    single ``leaf`` table; each internal node's output from ``node_fn`` is
    re-masked (pad rows via ``row_mask``, pad columns past the node's true
    width) before anyone reads it.

    Table lifetime is reference-counted from ``program.table_reads()``:
    each child read and each root delivery decrements the count, and the
    table is dropped at zero — for a chain this is exactly the
    free-both-children-at-the-parent order; for a DAG a shared subtree
    table stays live only until its last reader (keeping XLA liveness
    tight while still computing every unique table once).

    ``root_fn`` (e.g. :func:`root_count`) reduces each root table to its
    delivered value as soon as the root node is built, so wide root tables
    of sub-``k``-sized templates never outlive their reduction; without it
    the masked root tables themselves are returned.

    ``frontier_fn`` threads active-row frontiers through the program
    (DESIGN.md §15): each produced table's frontier is computed once, lives
    exactly as long as the table, and reaches every consumer via the
    ``f_left``/``f_right`` arguments of ``node_fn`` — a DAG table read by
    several parents never recomputes its activity.

    ``bag`` supplies the backend strategy for the treewidth-2 node kinds
    (:class:`BagFns`); required iff the program carries bag nodes.  A
    ``bag_combine`` is the same neighbor-sum contraction as ``combine`` and
    flows through ``node_fn`` (whose strategy handles the ``x`` axis), but
    its column mask repeats per ``x`` block.  Collapse/join outputs live on
    the ``x`` axis — every row is a real host vertex — so the vertex-row
    ``row_mask`` does not apply to them.
    """
    reads = list(program.table_reads())
    want: Dict[int, int] = {}
    for r in program.roots:
        want[r] = want.get(r, 0) + 1
    tables: Dict[int, jax.Array] = {}
    frontiers: Dict[int, Frontier] = {}
    delivered: Dict[int, jax.Array] = {}
    for i, nd in enumerate(program.nodes):
        kind = nd.kind
        if kind.startswith("bag_") and bag is None:
            raise ValueError("program has bag nodes but no BagFns strategy")
        if kind == "leaf":
            out = leaf  # leaves are dense: every vertex has a color
        elif kind == "bag_leaf":
            out = bag.leaf_fn(i, nd)
        elif kind == "bag_collapse":
            # strategy output is final (pad columns of the child are already
            # zero and survive the sum as zero); rows are the x axis
            out = bag.collapse_fn(i, tables[nd.left])
        elif kind == "bag_join":
            tbl = combine[i]
            raw = bag.join_fn(i, tbl, tables[nd.left], tables[nd.right])
            col_mask = (jnp.arange(raw.shape[1]) < tbl.s).astype(jnp.float32)[None, :]
            out = raw * col_mask
        else:  # "combine" / "bag_combine": the neighbor-sum contraction
            tbl = combine[i]
            raw = node_fn(
                i,
                tbl,
                tables[nd.left],
                tables[nd.right],
                frontiers.get(nd.left),
                frontiers.get(nd.right),
            )
            if kind == "bag_combine":
                # one true-width block per x: mask repeats every s_pad cols
                col_mask = (jnp.arange(raw.shape[1]) % tbl.s_pad < tbl.s).astype(
                    jnp.float32
                )[None, :]
            else:
                col_mask = (jnp.arange(raw.shape[1]) < tbl.s).astype(jnp.float32)[None, :]
            out = raw * row_mask * col_mask
        # the children just had one read each consumed; free at zero
        # (left may equal right for symmetric splits — counted twice)
        for c in nd.children[::-1]:
            reads[c] -= 1
            if reads[c] == 0:
                tables.pop(c, None)
                frontiers.pop(c, None)
        if i in want:
            delivered[i] = root_fn(out) if root_fn is not None else out
            reads[i] -= want[i]
        if reads[i] > 0:
            tables[i] = out
            if frontier_fn is not None and kind == "combine":
                fr = frontier_fn(i, out)
                if fr is not None:
                    frontiers[i] = fr
    return tuple(delivered[r] for r in program.roots)


def root_count(root: jax.Array) -> jax.Array:
    """Colorful map count from a root table: ``sum_{v, S} C_root[v, S]``.

    For a full-``k`` template the root table has the single full-color-set
    column; for a sub-``k`` template (family counting) every color set of
    the template's size contributes one column, and each colorful embedding
    lands in exactly one of them.  Pad rows/columns are already masked to
    zero by the executor, so the plain sum is exact either way.
    """
    acc_dtype = jnp.float64 if root.dtype == jnp.float64 else jnp.float32
    return jnp.sum(root, dtype=acc_dtype)


def local_node_fn(
    spmm_plan: ops.SpmmPlan,
    row_mask: jax.Array,
    *,
    impl: str = "auto",
    fuse: bool = False,
    compaction: Optional[CompactionSpec] = None,
    sentinel_row: Optional[int] = None,
    flags: Optional[List[jax.Array]] = None,
) -> NodeFn:
    """The in-core neighbor-sum strategy: SpMM over the whole graph.

    With ``fuse=True`` each node is one ``ops.fused_count`` call that
    contracts every ``row_tile``-row block of ``M`` as soon as it is
    produced and never materializes the full ``[n_pad, B]`` neighbor sum
    (the paper's fine-grained pipeline, §3.2, at kernel granularity).

    With ``compaction`` (DESIGN.md §15): a right child carrying a frontier
    feeds the SpMM/fused kernels in compact ``[cap, B]`` form through the
    row-index indirection (``ops.spmm_compact`` / ``fused_count_compact``),
    and nodes with a ``combine_caps`` entry contract only the rows where
    both the left table and the neighbor sum are active
    (:func:`~repro.core.frontier.compact_combine`), appending their
    no-overflow flags to ``flags``.  A compacted node takes the two-step
    path even under ``fuse`` — skipping inactive rows beats skipping the
    ``M`` materialization once the table is sparse.
    """

    def compact_right(c_right, f_right):
        """(compact table, inverse map) when the indirection applies."""
        if f_right is None or f_right.idx is None or spmm_plan.slab_dst is None:
            return None, None
        table_c = jnp.take(c_right, f_right.idx, axis=0)
        inv = inverse_map(f_right.idx, c_right.shape[0], f_right.cap - 1)
        return table_c, inv

    def neighbor_sum(c_right, f_right):
        right_c, inv = compact_right(c_right, f_right)
        if right_c is not None:
            return ops.spmm_compact(spmm_plan, right_c, inv, impl=impl)
        return ops.spmm(spmm_plan, c_right, impl=impl)

    def node_fn(i, tbl, c_left, c_right, f_left, f_right):
        cap = compaction.combine_caps.get(i) if compaction is not None else None
        if cap is not None:
            m = neighbor_sum(c_right, f_right)
            return compact_combine(
                c_left,
                m,
                tbl,
                cap,
                sentinel_row,
                impl,
                flags,
                left_mask=f_left.mask if f_left is not None else None,
            )
        if fuse:
            right_c, inv = compact_right(c_right, f_right)
            if right_c is not None:
                return ops.fused_count_compact(spmm_plan, c_left, right_c, inv, tbl, impl=impl)
            return ops.fused_count(spmm_plan, c_left, c_right, tbl, impl=impl)
        m = neighbor_sum(c_right, f_right)
        # mask pad rows of the neighbor sum before the combine
        m = m * row_mask
        return ops.color_combine(c_left, m, tbl, impl=impl)

    return node_fn
