"""One table program: THE partition-chain DP executor, shared by backends.

The color-coding DP is one *table program*: walk the partition chain in
postorder, keep a table ``C_node [rows, width]`` per live node, and at each
internal node contract the left child against the neighbor sum of the right
child.  Until this module existed that recursion was written twice — once in
``count_engine`` (in-core) and once inside ``distributed`` (shard_map) — and
the two copies had already drifted (fusion, true-width tables, and batched
colorings only worked in-core).

Now the recursion lives here, once, and the backends differ only in their
**neighbor-sum strategy** — the ``node_fn`` callback that produces one
internal node's (unmasked) output table:

``local`` (:func:`local_node_fn`)
    ``M = spmm(A, C_right)`` over the whole in-core graph, or the fused
    SpMM->combine kernel that never materializes ``M``.

``exchange`` (built inside :mod:`repro.core.distributed`)
    ``M`` assembled from remote shards via one of the four exchange modes
    (``alltoall``/``pipeline``/``adaptive``/``ring``), consumed through the
    §3.3 tiled bucket layout — the same edge-tile/fused kernels, per chunk.

The executor owns everything the strategies must agree on: leaf
construction, pad-row/pad-column re-masking after every combine, child
table lifetime (each chain node is the child of exactly one parent, so both
children die as soon as the parent is built — the paper's sub-template
table lifetime management), and the root reduction.  A strategy cannot
forget to mask or leak a table; the backends cannot drift.
"""

from __future__ import annotations

from typing import Callable, Dict, Mapping, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .templates import PartitionChain

__all__ = [
    "build_node_tables",
    "leaf_table",
    "run_table_program",
    "root_count",
    "local_node_fn",
]

#: strategy signature: (node_index, combine_tables, c_left, c_right) ->
#: unmasked output table [rows, >= s_pad] for that internal node
NodeFn = Callable[[int, ops.CombineTables, jax.Array, jax.Array], jax.Array]


def build_node_tables(
    chain: PartitionChain, k: int, *, lane: int = 128
) -> Tuple[Dict[int, ops.CombineTables], Dict[int, int]]:
    """Per-node split tables + padded widths for one partition chain.

    ``lane`` is the column-padding multiple (128 for the Pallas kernels,
    1 for true-width XLA tables).  Shared by both plan builders.
    """
    combine: Dict[int, ops.CombineTables] = {}
    widths: Dict[int, int] = {}
    for i, nd in enumerate(chain.nodes):
        if nd.is_leaf:
            widths[i] = ops.pad_to(k, lane)
        else:
            t1 = chain.nodes[nd.left].size
            t2 = chain.nodes[nd.right].size
            tables = ops.build_combine_tables(k, t1, t2, lane=lane)
            combine[i] = tables
            widths[i] = tables.s_pad
    return combine, widths


def leaf_table(
    coloring: jax.Array, k_pad: int, row_mask: jax.Array
) -> jax.Array:
    """Leaf tables: one-hot of the coloring, pad rows zeroed."""
    return jax.nn.one_hot(coloring, k_pad, dtype=jnp.float32) * row_mask


def run_table_program(
    chain: PartitionChain,
    combine: Mapping[int, ops.CombineTables],
    leaf: jax.Array,
    row_mask: jax.Array,
    node_fn: NodeFn,
) -> jax.Array:
    """Execute the partition-chain DP; returns the (masked) root table.

    This is the only copy of the node recursion in the codebase.  Every
    leaf shares the single ``leaf`` table; each internal node's output from
    ``node_fn`` is re-masked (pad rows via ``row_mask``, pad columns past
    the node's true width) and both children are freed immediately.
    """
    tables: Dict[int, jax.Array] = {}
    for i, nd in enumerate(chain.nodes):
        if nd.is_leaf:
            tables[i] = leaf
            continue
        tbl = combine[i]
        out = node_fn(i, tbl, tables[nd.left], tables[nd.right])
        col_mask = (jnp.arange(out.shape[1]) < tbl.s).astype(jnp.float32)[None, :]
        tables[i] = out * row_mask * col_mask
        # free children (keeps XLA liveness tight); every chain node is the
        # child of exactly one parent, so both entries are dead here.
        del tables[nd.right]
        del tables[nd.left]
    return tables[chain.root_index]


def root_count(root: jax.Array) -> jax.Array:
    """Colorful map count: ``sum_v C_root[v, 0]`` (the full color set has
    rank 0 in its singleton table)."""
    acc_dtype = jnp.float64 if root.dtype == jnp.float64 else jnp.float32
    return jnp.sum(root[:, 0], dtype=acc_dtype)


def local_node_fn(
    spmm_plan: ops.SpmmPlan,
    row_mask: jax.Array,
    *,
    impl: str = "auto",
    fuse: bool = False,
) -> NodeFn:
    """The in-core neighbor-sum strategy: SpMM over the whole graph.

    With ``fuse=True`` each node is one ``ops.fused_count`` call that
    contracts every ``row_tile``-row block of ``M`` as soon as it is
    produced and never materializes the full ``[n_pad, B]`` neighbor sum
    (the paper's fine-grained pipeline, §3.2, at kernel granularity).
    """

    def node_fn(i, tbl, c_left, c_right):
        if fuse:
            return ops.fused_count(spmm_plan, c_left, c_right, tbl, impl=impl)
        m = ops.spmm(spmm_plan, c_right, impl=impl)
        # mask pad rows of the neighbor sum before the combine
        m = m * row_mask
        return ops.color_combine(c_left, m, tbl, impl=impl)

    return node_fn
