"""(epsilon, delta)-estimation over any counting backend.

Each coloring iteration yields an unbiased estimate
``X_j = maps_j * k^k/k! / |Aut(T)|`` of the copy count.  Following the
paper (Algorithm 1 line 14), ``Niter`` estimates are split into
``t = O(log 1/delta)`` groups; the output is the median of the group means.

Backends plug in through one protocol: ``sample_fn(key, batch)`` returns
``batch`` independent per-coloring copy estimates (float64 ``[batch]``)
derived from a jax PRNG key.  :func:`estimate_counts` accepts either a
single-device :class:`~repro.core.count_engine.CountingPlan` (adapted via
:func:`~repro.core.count_engine.plan_sample_fn`) or any callable with that
signature — e.g. :func:`repro.core.distributed.keyed_sample_fn` for the
shard_map backend — so median-of-means, the RSD, and progress reporting are
computed in exactly one place no matter where the counting ran.

The worst-case bound ``Niter = O(e^k log(1/delta) / eps^2)`` is reported by
:func:`niter_bound` but — exactly as in the paper's experiments — practical
runs use a fixed iteration budget and report the empirical relative SD.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Union

import jax
import numpy as np

from .count_engine import CountingPlan, plan_sample_fn

__all__ = [
    "SampleFn",
    "niter_bound",
    "num_groups_for",
    "median_of_means",
    "CountEstimate",
    "estimate_counts",
]

#: The backend protocol: ``sample_fn(key, batch) -> float64 [batch]`` copy
#: estimates for ``batch`` independent colorings derived from ``key``.
SampleFn = Callable[[jax.Array, int], np.ndarray]


def niter_bound(k: int, eps: float, delta: float) -> int:
    """Worst-case iteration count from Alon et al. (reported, not enforced)."""
    return int(math.ceil(math.e ** k * math.log(1.0 / delta) / (eps ** 2)))


def num_groups_for(delta: float, n_iter: int) -> int:
    """Median-of-means group count: ``t = O(log 1/delta)``, clamped to n_iter."""
    return max(1, min(int(round(math.log(1.0 / delta))), n_iter))


def median_of_means(samples: np.ndarray, num_groups: int) -> float:
    samples = np.asarray(samples, np.float64)
    num_groups = max(1, min(num_groups, len(samples)))
    usable = (len(samples) // num_groups) * num_groups
    groups = samples[:usable].reshape(num_groups, -1)
    return float(np.median(groups.mean(axis=1)))


@dataclasses.dataclass
class CountEstimate:
    estimate: float  # median-of-means copy estimate
    mean: float  # plain mean estimate
    relative_sd: float  # empirical RSD of the per-iteration estimates
    samples: np.ndarray  # per-iteration estimates
    niter: int


def estimate_counts(
    source: Union[CountingPlan, SampleFn],
    n_iter: int,
    key: jax.Array,
    *,
    delta: float = 0.1,
    batch: Optional[int] = None,
    progress: bool = False,
) -> CountEstimate:
    """Run ``n_iter`` independent colorings and aggregate (Algorithm 1 l.14).

    ``source`` is either a single-device :class:`CountingPlan` or any
    ``sample_fn(key, batch)`` callable (the backend protocol above) — the
    aggregation is backend-agnostic.  ``batch=B`` evaluates B colorings per
    backend call, amortizing dispatch overhead over the embarrassingly
    parallel outer loop; the estimate is identical in distribution to the
    one-at-a-time loop.
    """
    sample = source if callable(source) else plan_sample_fn(source)
    b = batch if batch is not None and batch > 1 else 1
    n_calls = -(-n_iter // b)
    keys = jax.random.split(key, n_calls)
    chunks = []
    done = 0
    for i in range(n_calls):
        est = np.asarray(sample(keys[i], b), np.float64).reshape(-1)
        chunks.append(est)
        done += len(est)
        if progress and (i + 1) % max(1, n_calls // 10) == 0:
            cur = np.concatenate(chunks)
            print(
                f"  iter {min(done, n_iter)}/{n_iter}: "
                f"running mean {cur.mean():.6g}"
            )
    ests = np.concatenate(chunks)[:n_iter]
    mom = median_of_means(ests, num_groups_for(delta, n_iter))
    mean = float(ests.mean())
    rsd = float(ests.std() / mean) if mean != 0 else float("inf")
    return CountEstimate(mom, mean, rsd, ests, n_iter)
