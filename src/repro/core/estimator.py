"""(epsilon, delta)-estimation over any counting backend.

Each coloring iteration yields an unbiased estimate
``X_j = maps_j * scale`` of the copy count (``scale = k^t (k-t)!/k!/|Aut|``,
the paper's ``k^k/k!/|Aut|`` when the template uses the full color budget).
Following the paper (Algorithm 1 line 14), ``Niter`` estimates are split
into ``t = O(log 1/delta)`` groups; the output is the median of the group
means.

Backends plug in through one protocol: ``sample_fn(key, batch)`` returns
``batch`` independent per-coloring copy estimates (float64 ``[batch]``)
derived from a jax PRNG key.  :func:`estimate_counts` accepts either a
single-device :class:`~repro.core.count_engine.CountingPlan` (adapted via
:func:`~repro.core.count_engine.plan_sample_fn`) or any callable with that
signature — e.g. :func:`repro.core.distributed.keyed_sample_fn` for the
shard_map backend — so median-of-means, the RSD, and progress reporting are
computed in exactly one place no matter where the counting ran.

Family counting vectorizes the same aggregation: a multi-template backend
returns ``[batch, T]`` per-template estimates from one shared coloring
(:func:`~repro.core.count_engine.multi_sample_fn` /
``distributed.keyed_sample_fn`` on a family plan) and
:func:`estimate_counts_many` applies the identical median-of-means/RSD
math column-wise — one code path, scalar or vector.

The worst-case bound ``Niter = O(e^k log(1/delta) / eps^2)`` is reported by
:func:`niter_bound` but — exactly as in the paper's experiments — practical
runs use a fixed iteration budget and report the empirical relative SD.

Resumability (DESIGN.md §16)
----------------------------
Multi-hour estimates survive kills bit-exactly.  The whole run derives from
one key: backend call ``i`` uses :func:`call_key` — ``fold_in(key, i)``,
a *prefix-stable* stream whose ``i``-th key depends only on ``(key, i)``,
never on the total budget — and :class:`EstimatorState` banks the
per-iteration estimates plus the **cursor** — how many backend calls
completed.  A resumed run re-derives the same per-call keys, skips the
first ``cursor``, and continues; since the banked prefix and the
freshly-computed suffix are exactly the arrays an uninterrupted run would
have produced, every aggregate (median-of-means, mean, RSD, early-stop
decision) is bit-identical.  Prefix stability is also what lets the
counting service (serve/counting_service.py) coalesce requests with
*different* budgets into one shared coloring stream and let late requests
join a pass mid-stream.  The state is tiny — one
float64 per coloring — so checkpointing it every few batches (via
``checkpoint=CheckpointManager(...)``) costs microseconds against
multi-second iterations.  A :class:`~repro.core.supervisor.Supervisor` (or
``retry=RetryPolicy(...)``) additionally retries transient sample faults
and quarantines persistently-failing batches, which are reported on the
returned estimate instead of silently dropped.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Union

import jax
import numpy as np

from repro.testing import faults

from .count_engine import CountingPlan, plan_sample_fn
from .supervisor import QuarantinedBatch, RetryPolicy, Supervisor

__all__ = [
    "SampleFn",
    "niter_bound",
    "num_groups_for",
    "median_of_means",
    "call_key",
    "relative_se",
    "aggregate_single",
    "CountEstimate",
    "MultiCountEstimate",
    "EstimatorState",
    "ResumeMismatchError",
    "EstimationAborted",
    "run_signature",
    "estimate_counts",
    "estimate_counts_many",
]

#: The backend protocol: ``sample_fn(key, batch) -> float64 [batch]`` copy
#: estimates for ``batch`` independent colorings derived from ``key``
#: (``[batch, T]`` for family backends).
SampleFn = Callable[[jax.Array, int], np.ndarray]


def niter_bound(k: int, eps: float, delta: float) -> int:
    """Worst-case iteration count from Alon et al. (reported, not enforced)."""
    return int(math.ceil(math.e ** k * math.log(1.0 / delta) / (eps ** 2)))


def num_groups_for(delta: float, n_iter: int) -> int:
    """Median-of-means group count: ``t = O(log 1/delta)``, clamped to n_iter."""
    return max(1, min(int(round(math.log(1.0 / delta))), n_iter))


def call_key(key: jax.Array, index: int) -> jax.Array:
    """PRNG key for backend call ``index`` of a run keyed by ``key``.

    ``fold_in`` rather than a pre-split: the per-call key stream is
    *prefix-stable* — call ``i``'s key depends only on ``(key, i)``, never
    on the total call count (``jax.random.split(key, n)`` pairs counters as
    ``(i, n+i)``, so its streams differ across budgets).  Prefix stability
    is what makes a banked sample prefix valid under a different remaining
    budget: resume, per-request early exit inside a coalesced family pass,
    and mid-stream joins all rely on it.
    """
    return jax.random.fold_in(key, index)


def median_of_means(samples: np.ndarray, num_groups: int):
    """Median of group means along axis 0.

    ``samples`` is ``[n]`` (returns a float, the original contract) or
    ``[n, T]`` (returns a float64 ``[T]`` array, one value per template) —
    the grouping is identical, applied column-wise.
    """
    samples = np.asarray(samples, np.float64)
    num_groups = max(1, min(num_groups, samples.shape[0]))
    usable = (samples.shape[0] // num_groups) * num_groups
    groups = samples[:usable].reshape(num_groups, -1, *samples.shape[1:])
    med = np.median(groups.mean(axis=1), axis=0)
    return float(med) if np.ndim(med) == 0 else med


class ResumeMismatchError(ValueError):
    """A checkpoint does not belong to this run (fatal, never silent).

    Resuming under a different key, budget, batch size, graph, or template
    would splice two *different* sample streams and silently bias the
    estimate; the signature check turns that into a hard error.
    """


class EstimationAborted(RuntimeError):
    """Every batch was quarantined — there is no data to estimate from."""


@dataclasses.dataclass(frozen=True)
class CountEstimate:
    estimate: float  # median-of-means copy estimate
    mean: float  # plain mean estimate
    relative_sd: float  # empirical RSD of the per-iteration estimates
    samples: np.ndarray  # per-iteration estimates
    niter: int  # iterations actually aggregated
    quarantined: tuple = ()  # QuarantinedBatch records (excluded batches)
    resumed_from: int = 0  # iterations restored from checkpoint, if any


@dataclasses.dataclass(frozen=True)
class MultiCountEstimate:
    """Per-template aggregates of one family run (axis order [iter, T])."""

    estimates: np.ndarray  # [T] median-of-means copy estimates
    means: np.ndarray  # [T] plain means
    relative_sds: np.ndarray  # [T] empirical RSDs
    samples: np.ndarray  # [niter, T] per-iteration estimates
    niter: int
    quarantined: tuple = ()
    resumed_from: int = 0


def run_signature(n_iter: int, batch: int, delta: float, key: jax.Array, *, extra: str = "") -> str:
    """The identity of one estimation run, for resume safety.

    Two runs with equal signatures draw the identical per-call key sequence
    over the identical budget, so banked samples from one are a valid prefix
    of the other.  ``extra`` carries caller context (graph, template,
    backend — see ``Counter``) so a checkpoint can't cross workloads.
    """
    from .supervisor import key_fingerprint

    kd = ",".join(str(w) for w in key_fingerprint(key))
    base = f"n_iter={n_iter}|batch={batch}|delta={delta:g}|key={kd}"
    return f"{extra}|{base}" if extra else base


@dataclasses.dataclass(frozen=True)
class EstimatorState:
    """Everything needed to continue an interrupted estimate bit-exactly.

    ``samples`` banks the raw per-iteration estimates (``[done]`` scalar or
    ``[done, T]`` family) — one float64 per coloring, so even a 10^6-
    iteration budget checkpoints in megabytes.  The median-of-means group
    *sums* derive from it (:meth:`group_sums`) and power resumed progress /
    RSD reporting; the raw array is kept because the final grouping depends
    on the total iteration count, and bit-exact resume must reproduce the
    exact ``median(group means)`` an uninterrupted run computes.

    ``cursor`` is the PRNG position: how many backend calls of the
    per-call key sequence completed (including quarantined ones — their
    keys are consumed, their records kept, so a resumed run neither replays
    nor double-counts them).

    ``status`` is provenance, not identity: the terminal status of the run
    that exported this state (``""`` for a plain checkpoint, or a §20
    service ticket status such as ``"cancelled"``/``"deadline_exceeded"``).
    Resume ignores it — a cancelled or deadline-expired ticket's partial
    state is a valid prefix, which is exactly what lets ``--resume`` pick
    the abandoned work back up — but it rides ``to_arrays`` so a checkpoint
    directory records *why* the banked work stopped where it did.
    """

    signature: str  # run_signature() — checked on resume
    n_iter: int  # total planned iterations
    batch: int  # iterations per backend call
    delta: float
    cursor: int  # backend calls completed (PRNG key cursor)
    samples: np.ndarray  # [done] or [done, T] banked estimates
    quarantined: tuple = ()  # QuarantinedBatch records
    status: str = ""  # exporting run's terminal status (provenance only)

    @property
    def done(self) -> int:
        """Iterations banked so far."""
        return int(self.samples.shape[0])

    @property
    def n_calls(self) -> int:
        return -(-self.n_iter // self.batch)

    def group_sums(self, num_groups: Optional[int] = None):
        """Per-group partial sums (and counts) of the banked samples.

        The associative form of the median-of-means aggregate: group ``g``
        of the final estimate owns a contiguous slice of the sample stream,
        so its running sum/count is exact at any prefix.
        """
        g = num_groups_for(self.delta, self.n_iter) if num_groups is None else num_groups
        per = max(1, self.n_iter // g)
        done = self.done
        sums, counts = [], []
        for i in range(g):
            part = self.samples[i * per: min((i + 1) * per, done)]
            sums.append(part.sum(axis=0))
            counts.append(part.shape[0])
        return np.asarray(sums, np.float64), np.asarray(counts, np.int64)

    # ------------------------------------------------- checkpoint adapters
    def to_arrays(self) -> dict:
        """Flatten to named numpy arrays (the CheckpointManager payload)."""
        q = self.quarantined
        keys = np.asarray([r.key_data for r in q], np.uint32) if q else np.zeros((0, 0), np.uint32)
        reasons = "\n".join(r.reason.replace("\n", " ") for r in q)
        return {
            "signature": np.frombuffer(self.signature.encode("utf-8"), np.uint8).copy(),
            "n_iter": np.int64(self.n_iter),
            "batch": np.int64(self.batch),
            "delta": np.float64(self.delta),
            "cursor": np.int64(self.cursor),
            "samples": np.asarray(self.samples, np.float64),
            "q_call": np.asarray([r.call_index for r in q], np.int64),
            "q_attempts": np.asarray([r.attempts for r in q], np.int64),
            "q_keys": keys,
            "q_reasons": np.frombuffer(reasons.encode("utf-8"), np.uint8).copy(),
            "status": np.frombuffer(self.status.encode("utf-8"), np.uint8).copy(),
        }

    @classmethod
    def from_arrays(cls, flat: dict) -> "EstimatorState":
        reasons = bytes(np.asarray(flat["q_reasons"], np.uint8)).decode("utf-8")
        reason_list = reasons.split("\n") if reasons else []
        q = tuple(
            QuarantinedBatch(
                call_index=int(c),
                key_data=tuple(int(w) for w in np.atleast_1d(k)),
                reason=reason_list[i] if i < len(reason_list) else "",
                attempts=int(a),
            )
            for i, (c, a, k) in enumerate(
                zip(flat["q_call"], flat["q_attempts"], flat["q_keys"])
            )
        )
        return cls(
            signature=bytes(np.asarray(flat["signature"], np.uint8)).decode("utf-8"),
            n_iter=int(flat["n_iter"]),
            batch=int(flat["batch"]),
            delta=float(flat["delta"]),
            cursor=int(flat["cursor"]),
            samples=np.asarray(flat["samples"], np.float64),
            quarantined=q,
            # absent from pre-§20 checkpoints: plain in-progress state
            status=(bytes(np.asarray(flat["status"], np.uint8)).decode("utf-8")
                    if "status" in flat else ""),
        )


def relative_se(samples: np.ndarray) -> float:
    """Relative standard error of the running mean — the early-stop signal.

    Unlike the per-iteration RSD (which converges to the sampling noise
    level, not zero), this shrinks ~1/sqrt(n), so "stop at target" is
    meaningful.  Family runs stop when the *worst* template hits target.
    Exported because the counting service must apply the *identical*
    predicate per request inside a coalesced pass (bit-identical stopping
    decisions are part of the service's solo-equivalence contract).
    """
    n = samples.shape[0]
    if n < 2:
        return float("inf")
    means = np.atleast_1d(samples.mean(axis=0))
    sds = np.atleast_1d(samples.std(axis=0))
    with np.errstate(divide="ignore", invalid="ignore"):
        rse = np.where(means != 0, sds / np.abs(means) / math.sqrt(n), np.inf)
    return float(rse.max())


def aggregate_single(samples: np.ndarray, n_iter: int, delta: float):
    """The scalar tail aggregate of :func:`estimate_counts`, factored out.

    Returns ``(mom, mean, rsd, used, ests)`` over ``samples`` truncated to
    the ``n_iter`` budget.  The counting service computes each request's
    final numbers through this exact function, which is what makes a
    coalesced pass bit-identical to a solo run by construction rather than
    by coincidence.  ``samples`` must be non-empty.
    """
    ests = np.asarray(samples, np.float64).reshape(-1)[:n_iter]
    used = int(ests.shape[0])
    mom = median_of_means(ests, num_groups_for(delta, used))
    mean = float(ests.mean())
    rsd = float(ests.std() / mean) if mean != 0 else float("inf")
    return mom, mean, rsd, used, ests


def _append(bank: np.ndarray, chunk: np.ndarray) -> np.ndarray:
    if bank.shape[0] == 0:
        return chunk.copy()
    return np.concatenate([bank, chunk], axis=0)


def _collect_samples(
    sample: Union[SampleFn, Supervisor],
    key: jax.Array,
    state: EstimatorState,
    *,
    progress: bool,
    checkpoint=None,
    checkpoint_every: int = 0,
    target_rsd: Optional[float] = None,
    multi: bool = False,
) -> EstimatorState:
    """The shared sampling loop, resumable at any call boundary.

    Walks the :func:`call_key` sequence from ``state.cursor``, banking each
    batch into ``state``; saves the state to ``checkpoint`` every
    ``checkpoint_every`` iterations (rounded up to call boundaries) and
    once more on completion, so a finished directory restores to a no-op
    resume.  When ``sample`` is a :class:`Supervisor`, quarantined batches
    advance the cursor without contributing samples.
    """
    b, n_iter, n_calls = state.batch, state.n_iter, state.n_calls
    supervised = isinstance(sample, Supervisor)
    stride = max(1, n_calls // 10)
    ckpt_calls = max(1, -(-checkpoint_every // b)) if checkpoint_every else 0
    last_saved = state.cursor
    for i in range(state.cursor, n_calls):
        # the early-stop check sees banked + fresh samples alike, so a
        # resumed run stops exactly where the uninterrupted run would
        if target_rsd is not None and relative_se(state.samples) <= target_rsd:
            break
        ki = call_key(key, i)
        if supervised:
            out = sample(ki, b, call_index=i)
        else:
            out = np.asarray(sample(ki, b), np.float64)
        if isinstance(out, QuarantinedBatch):
            state = dataclasses.replace(state, cursor=i + 1, quarantined=state.quarantined + (out,))
        else:
            if multi:
                if out.ndim != 2:
                    raise ValueError(
                        f"family sample_fn must return [batch, T] estimates; "
                        f"got shape {out.shape}"
                    )
            else:
                out = out.reshape(-1)
            state = dataclasses.replace(state, cursor=i + 1, samples=_append(state.samples, out))
        if progress and (i + 1) % stride == 0:
            cur = state.samples
            mean = np.array2string(
                np.atleast_1d(cur.mean(axis=0)) if cur.size else np.zeros(1),
                precision=6,
                separator=", ",
            )
            print(f"  iter {min(state.done, n_iter)}/{n_iter}: "
                  f"running mean {mean}")
        if checkpoint is not None and ckpt_calls \
                and i + 1 - last_saved >= ckpt_calls and i + 1 < n_calls:
            checkpoint.save(i + 1, {"estimator": state.to_arrays()})
            last_saved = i + 1
            spec = faults.fire("estimator.kill")
            if spec is not None:
                checkpoint.wait()
                raise faults.InjectedCrash(f"injected kill after checkpoint at call {i + 1}")
    if checkpoint is not None and state.cursor != last_saved:
        checkpoint.save(state.cursor, {"estimator": state.to_arrays()})
        checkpoint.wait()
    return state


def _prepare(
    n_iter: int,
    key: jax.Array,
    delta: float,
    batch: Optional[int],
    resume: Optional[EstimatorState],
    signature_extra: str,
) -> EstimatorState:
    b = batch if batch is not None and batch > 1 else 1
    sig = run_signature(n_iter, b, delta, key, extra=signature_extra)
    if resume is not None:
        if resume.signature != sig:
            raise ResumeMismatchError(
                f"checkpoint does not match this run:\n"
                f"  checkpoint: {resume.signature}\n"
                f"  run:        {sig}\n"
                f"resume needs the same graph/template/backend, key, n_iter, "
                f"batch, and delta as the interrupted run"
            )
        return resume
    return EstimatorState(
        signature=sig,
        n_iter=n_iter,
        batch=b,
        delta=delta,
        cursor=0,
        samples=np.zeros((0,), np.float64),
    )


def _supervise(sample: SampleFn, retry: Optional[RetryPolicy]) -> Union[SampleFn, Supervisor]:
    if isinstance(sample, Supervisor) or retry is None:
        return sample
    return Supervisor(sample, retry)


def estimate_counts(
    source: Union[CountingPlan, SampleFn],
    n_iter: int,
    key: jax.Array,
    *,
    delta: float = 0.1,
    batch: Optional[int] = None,
    progress: bool = False,
    retry: Optional[RetryPolicy] = None,
    checkpoint=None,
    checkpoint_every: int = 0,
    resume: Optional[EstimatorState] = None,
    target_rsd: Optional[float] = None,
    signature_extra: str = "",
) -> CountEstimate:
    """Run ``n_iter`` independent colorings and aggregate (Algorithm 1 l.14).

    ``source`` is either a single-device :class:`CountingPlan` or any
    ``sample_fn(key, batch)`` callable (the backend protocol above) — the
    aggregation is backend-agnostic.  ``batch=B`` evaluates B colorings per
    backend call, amortizing dispatch overhead over the embarrassingly
    parallel outer loop; the estimate is identical in distribution to the
    one-at-a-time loop.

    Robustness (all optional, see module docstring / DESIGN.md §16):
    ``retry`` supervises the backend (bounded retry, timeout, validation,
    quarantine); ``checkpoint``/``checkpoint_every`` persist the
    :class:`EstimatorState` every N iterations via a
    :class:`~repro.train.checkpoint.CheckpointManager`; ``resume`` continues
    from a restored state (bit-exact — same aggregates as uninterrupted);
    ``target_rsd`` stops early once the running relative standard error of
    the mean reaches the target (banked iterations count).
    """
    sample = source if callable(source) else plan_sample_fn(source)
    state = _prepare(n_iter, key, delta, batch, resume, signature_extra)
    resumed_from = state.done
    state = _collect_samples(
        _supervise(sample, retry),
        key,
        state,
        progress=progress,
        checkpoint=checkpoint,
        checkpoint_every=checkpoint_every,
        target_rsd=target_rsd,
    )
    if state.samples.reshape(-1)[:n_iter].shape[0] == 0:
        raise EstimationAborted(
            f"all {len(state.quarantined)} batches were quarantined: "
            + "; ".join(str(q) for q in state.quarantined)
        )
    mom, mean, rsd, used, ests = aggregate_single(state.samples, n_iter, delta)
    return CountEstimate(
        mom,
        mean,
        rsd,
        ests,
        used,
        quarantined=state.quarantined,
        resumed_from=resumed_from,
    )


def estimate_counts_many(
    sample_fn: SampleFn,
    n_iter: int,
    key: jax.Array,
    *,
    delta: float = 0.1,
    batch: Optional[int] = None,
    progress: bool = False,
    retry: Optional[RetryPolicy] = None,
    checkpoint=None,
    checkpoint_every: int = 0,
    resume: Optional[EstimatorState] = None,
    target_rsd: Optional[float] = None,
    signature_extra: str = "",
) -> MultiCountEstimate:
    """The family variant: one shared-coloring pass, per-template aggregates.

    ``sample_fn(key, batch)`` must return ``[batch, T]`` per-template copy
    estimates (e.g. :func:`~repro.core.count_engine.multi_sample_fn`); the
    median-of-means/RSD math is the scalar path applied column-wise, so a
    family run and ``T`` independent runs report identical statistics on
    identical samples.  The robustness keywords behave exactly as on
    :func:`estimate_counts`; ``target_rsd`` gates on the worst template.
    """
    state = _prepare(n_iter, key, delta, batch, resume, signature_extra)
    resumed_from = state.done
    state = _collect_samples(
        _supervise(sample_fn, retry),
        key,
        state,
        progress=progress,
        checkpoint=checkpoint,
        checkpoint_every=checkpoint_every,
        target_rsd=target_rsd,
        multi=True,
    )
    ests = state.samples[:n_iter]
    if ests.shape[0] == 0:
        raise EstimationAborted(
            f"all {len(state.quarantined)} batches were quarantined: "
            + "; ".join(str(q) for q in state.quarantined)
        )
    if ests.ndim != 2:
        raise ValueError(
            f"family sample_fn must return [batch, T] estimates; got "
            f"shape {ests.shape}"
        )
    used = int(ests.shape[0])
    mom = np.atleast_1d(median_of_means(ests, num_groups_for(delta, used)))
    means = ests.mean(axis=0)
    with np.errstate(divide="ignore", invalid="ignore"):
        rsds = np.where(means != 0, ests.std(axis=0) / np.abs(means), np.inf)
    return MultiCountEstimate(
        mom,
        means,
        rsds,
        ests,
        used,
        quarantined=state.quarantined,
        resumed_from=resumed_from,
    )
