"""(epsilon, delta)-estimation over any counting backend.

Each coloring iteration yields an unbiased estimate
``X_j = maps_j * scale`` of the copy count (``scale = k^t (k-t)!/k!/|Aut|``,
the paper's ``k^k/k!/|Aut|`` when the template uses the full color budget).
Following the paper (Algorithm 1 line 14), ``Niter`` estimates are split
into ``t = O(log 1/delta)`` groups; the output is the median of the group
means.

Backends plug in through one protocol: ``sample_fn(key, batch)`` returns
``batch`` independent per-coloring copy estimates (float64 ``[batch]``)
derived from a jax PRNG key.  :func:`estimate_counts` accepts either a
single-device :class:`~repro.core.count_engine.CountingPlan` (adapted via
:func:`~repro.core.count_engine.plan_sample_fn`) or any callable with that
signature — e.g. :func:`repro.core.distributed.keyed_sample_fn` for the
shard_map backend — so median-of-means, the RSD, and progress reporting are
computed in exactly one place no matter where the counting ran.

Family counting vectorizes the same aggregation: a multi-template backend
returns ``[batch, T]`` per-template estimates from one shared coloring
(:func:`~repro.core.count_engine.multi_sample_fn` /
``distributed.keyed_sample_fn`` on a family plan) and
:func:`estimate_counts_many` applies the identical median-of-means/RSD
math column-wise — one code path, scalar or vector.

The worst-case bound ``Niter = O(e^k log(1/delta) / eps^2)`` is reported by
:func:`niter_bound` but — exactly as in the paper's experiments — practical
runs use a fixed iteration budget and report the empirical relative SD.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Union

import jax
import numpy as np

from .count_engine import CountingPlan, plan_sample_fn

__all__ = [
    "SampleFn",
    "niter_bound",
    "num_groups_for",
    "median_of_means",
    "CountEstimate",
    "MultiCountEstimate",
    "estimate_counts",
    "estimate_counts_many",
]

#: The backend protocol: ``sample_fn(key, batch) -> float64 [batch]`` copy
#: estimates for ``batch`` independent colorings derived from ``key``
#: (``[batch, T]`` for family backends).
SampleFn = Callable[[jax.Array, int], np.ndarray]


def niter_bound(k: int, eps: float, delta: float) -> int:
    """Worst-case iteration count from Alon et al. (reported, not enforced)."""
    return int(math.ceil(math.e ** k * math.log(1.0 / delta) / (eps ** 2)))


def num_groups_for(delta: float, n_iter: int) -> int:
    """Median-of-means group count: ``t = O(log 1/delta)``, clamped to n_iter."""
    return max(1, min(int(round(math.log(1.0 / delta))), n_iter))


def median_of_means(samples: np.ndarray, num_groups: int):
    """Median of group means along axis 0.

    ``samples`` is ``[n]`` (returns a float, the original contract) or
    ``[n, T]`` (returns a float64 ``[T]`` array, one value per template) —
    the grouping is identical, applied column-wise.
    """
    samples = np.asarray(samples, np.float64)
    num_groups = max(1, min(num_groups, samples.shape[0]))
    usable = (samples.shape[0] // num_groups) * num_groups
    groups = samples[:usable].reshape(num_groups, -1, *samples.shape[1:])
    med = np.median(groups.mean(axis=1), axis=0)
    return float(med) if np.ndim(med) == 0 else med


@dataclasses.dataclass
class CountEstimate:
    estimate: float  # median-of-means copy estimate
    mean: float  # plain mean estimate
    relative_sd: float  # empirical RSD of the per-iteration estimates
    samples: np.ndarray  # per-iteration estimates
    niter: int


@dataclasses.dataclass
class MultiCountEstimate:
    """Per-template aggregates of one family run (axis order [iter, T])."""

    estimates: np.ndarray  # [T] median-of-means copy estimates
    means: np.ndarray  # [T] plain means
    relative_sds: np.ndarray  # [T] empirical RSDs
    samples: np.ndarray  # [niter, T] per-iteration estimates
    niter: int


def _collect_samples(
    sample: SampleFn, n_iter: int, key: jax.Array, b: int, progress: bool
) -> np.ndarray:
    """The shared sampling loop: ``[n_iter]`` or ``[n_iter, T]`` estimates."""
    n_calls = -(-n_iter // b)
    keys = jax.random.split(key, n_calls)
    chunks = []
    done = 0
    for i in range(n_calls):
        est = np.asarray(sample(keys[i], b), np.float64)
        chunks.append(est)
        done += est.shape[0]
        if progress and (i + 1) % max(1, n_calls // 10) == 0:
            cur = np.concatenate(chunks, axis=0)
            mean = np.array2string(
                np.atleast_1d(cur.mean(axis=0)), precision=6, separator=", "
            )
            print(f"  iter {min(done, n_iter)}/{n_iter}: running mean {mean}")
    return np.concatenate(chunks, axis=0)[:n_iter]


def estimate_counts(
    source: Union[CountingPlan, SampleFn],
    n_iter: int,
    key: jax.Array,
    *,
    delta: float = 0.1,
    batch: Optional[int] = None,
    progress: bool = False,
) -> CountEstimate:
    """Run ``n_iter`` independent colorings and aggregate (Algorithm 1 l.14).

    ``source`` is either a single-device :class:`CountingPlan` or any
    ``sample_fn(key, batch)`` callable (the backend protocol above) — the
    aggregation is backend-agnostic.  ``batch=B`` evaluates B colorings per
    backend call, amortizing dispatch overhead over the embarrassingly
    parallel outer loop; the estimate is identical in distribution to the
    one-at-a-time loop.
    """
    sample = source if callable(source) else plan_sample_fn(source)
    b = batch if batch is not None and batch > 1 else 1
    ests = _collect_samples(sample, n_iter, key, b, progress).reshape(-1)
    mom = median_of_means(ests, num_groups_for(delta, n_iter))
    mean = float(ests.mean())
    rsd = float(ests.std() / mean) if mean != 0 else float("inf")
    return CountEstimate(mom, mean, rsd, ests, n_iter)


def estimate_counts_many(
    sample_fn: SampleFn,
    n_iter: int,
    key: jax.Array,
    *,
    delta: float = 0.1,
    batch: Optional[int] = None,
    progress: bool = False,
) -> MultiCountEstimate:
    """The family variant: one shared-coloring pass, per-template aggregates.

    ``sample_fn(key, batch)`` must return ``[batch, T]`` per-template copy
    estimates (e.g. :func:`~repro.core.count_engine.multi_sample_fn`); the
    median-of-means/RSD math is the scalar path applied column-wise, so a
    family run and ``T`` independent runs report identical statistics on
    identical samples.
    """
    b = batch if batch is not None and batch > 1 else 1
    ests = _collect_samples(sample_fn, n_iter, key, b, progress)
    if ests.ndim != 2:
        raise ValueError(
            f"family sample_fn must return [batch, T] estimates; got "
            f"shape {ests.shape}"
        )
    mom = np.atleast_1d(median_of_means(ests, num_groups_for(delta, n_iter)))
    means = ests.mean(axis=0)
    with np.errstate(divide="ignore", invalid="ignore"):
        rsds = np.where(means != 0, ests.std(axis=0) / np.abs(means), np.inf)
    return MultiCountEstimate(mom, means, rsds, ests, n_iter)
