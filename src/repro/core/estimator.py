"""(epsilon, delta)-estimation on top of the per-coloring DP.

Each coloring iteration yields an unbiased estimate
``X_j = maps_j * k^k/k! / |Aut(T)|`` of the copy count.  Following the
paper (Algorithm 1 line 14), ``Niter`` estimates are split into
``t = O(log 1/delta)`` groups; the output is the median of the group means.

The worst-case bound ``Niter = O(e^k log(1/delta) / eps^2)`` is reported by
:func:`niter_bound` but — exactly as in the paper's experiments — practical
runs use a fixed iteration budget and report the empirical relative SD.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .count_engine import CountingPlan, count_fn

__all__ = ["niter_bound", "median_of_means", "CountEstimate", "estimate_counts"]


def niter_bound(k: int, eps: float, delta: float) -> int:
    """Worst-case iteration count from Alon et al. (reported, not enforced)."""
    return int(math.ceil(math.e ** k * math.log(1.0 / delta) / (eps ** 2)))


def median_of_means(samples: np.ndarray, num_groups: int) -> float:
    samples = np.asarray(samples, np.float64)
    num_groups = max(1, min(num_groups, len(samples)))
    usable = (len(samples) // num_groups) * num_groups
    groups = samples[:usable].reshape(num_groups, -1)
    return float(np.median(groups.mean(axis=1)))


@dataclasses.dataclass
class CountEstimate:
    estimate: float  # median-of-means copy estimate
    mean: float  # plain mean estimate
    relative_sd: float  # empirical RSD of the per-iteration estimates
    samples: np.ndarray  # per-iteration estimates
    niter: int


def estimate_counts(
    plan: CountingPlan,
    n_iter: int,
    key: jax.Array,
    *,
    delta: float = 0.1,
    batch: Optional[int] = None,
    progress: bool = False,
) -> CountEstimate:
    """Run ``n_iter`` independent colorings and aggregate.

    ``batch=B`` evaluates B colorings per jit call (see
    :func:`repro.core.count_engine.count_fn`), amortizing dispatch overhead
    over the embarrassingly-parallel outer loop; the estimate is identical
    in distribution to the ``batch=None`` loop.
    """
    if batch is not None and batch > 1:
        f = count_fn(plan, batch=batch)
        n_calls = -(-n_iter // batch)
        keys = jax.random.split(key, n_calls)
        chunks = []
        for i in range(n_calls):
            _, est = f(keys[i])
            chunks.append(np.asarray(est, np.float64))
            if progress and (i + 1) % max(1, n_calls // 10) == 0:
                done = np.concatenate(chunks)
                print(
                    f"  iter {min((i + 1) * batch, n_iter)}/{n_iter}: "
                    f"running mean {done.mean():.6g}"
                )
        ests = np.concatenate(chunks)[:n_iter]
    else:
        f = count_fn(plan)
        keys = jax.random.split(key, n_iter)
        ests = np.zeros(n_iter, np.float64)
        for i in range(n_iter):
            _, est = f(keys[i])
            ests[i] = float(est)
            if progress and (i + 1) % max(1, n_iter // 10) == 0:
                print(f"  iter {i + 1}/{n_iter}: running mean {ests[: i + 1].mean():.6g}")
    num_groups = max(1, int(round(math.log(1.0 / delta))))
    mom = median_of_means(ests, num_groups)
    mean = float(ests.mean())
    rsd = float(ests.std() / mean) if mean != 0 else float("inf")
    return CountEstimate(mom, mean, rsd, ests, n_iter)
