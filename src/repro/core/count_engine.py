"""Single-device color-coding DP engine.

Pipeline per coloring iteration (Algorithm 1 of the paper):

1. sample a random coloring ``col(v) in {0..k-1}``;
2. leaf tables = one-hot of the coloring, ``[n_pad, k_pad]``;
3. for each internal partition node (topological order):
   ``M = spmm(A, C_right)`` (neighbor sum) then
   ``C_node = color_combine(C_left, M)`` (split-table contraction),
   with pad rows/cols re-masked — or, with ``fuse=True``, one
   ``ops.fused_count`` call that contracts each ``row_tile``-row block of
   ``M`` as soon as it is produced and never materializes the full
   ``[n_pad, B]`` neighbor sum (the paper's fine-grained pipeline, §3.2,
   at kernel granularity; see DESIGN.md §11);
4. colorful map count = ``sum_{v, S} C_root[v, S]`` (one column per color
   set of the template's size; the single full-set column when t == k).

Column padding is impl-dependent (``lane``): the Pallas kernels need
128-lane-aligned tables, while the XLA paths run at true table widths —
on CPU/GPU that alone removes the 12.8x waste of padding the k-wide leaf
tables to 128 columns.

Batched colorings: the outer color-coding loop is embarrassingly parallel,
so ``count_fn(plan, batch=B)`` evaluates B independent colorings per jit
call (vmap over the DP), amortizing dispatch and plan overheads across the
batch — the single-device mirror of the paper's multi-node outer loop.

Multi-template counting: :func:`build_multi_counting_plan` compiles a whole
template family into one deduplicated :class:`TemplateDag` (DESIGN.md §14)
and :func:`colorful_map_count_many` runs it as ONE table program per
coloring — every canonically-unique subtree table is computed once and
every template root reads its own entry, so counting N related templates
costs the unique-table work, not N independent chains.

The DP uses ``d = 1`` in the recurrence and divides the final count by
``|Aut(T)|`` once — equivalent to the paper's per-step over-counting factor
(see DESIGN.md §1) and exactly testable against the brute-force oracle.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.testing import faults
from .frontier import (
    DEFAULT_CAPACITY_FACTOR,
    DEFAULT_DENSITY_THRESHOLD,
    CompactionSpec,
    make_frontier_fn,
    single_device_compaction,
)
from .graphs import Graph, edge_list
from .table_program import (
    leaf_table,
    local_node_fn,
    build_node_tables,
    root_count,
    run_table_program,
)
from .templates import (
    PartitionChain,
    TemplateDag,
    Tree,
    automorphism_count,
    compile_templates,
    partition_tree,
)

__all__ = [
    "CountingPlan",
    "MultiCountingPlan",
    "build_counting_plan",
    "build_multi_counting_plan",
    "colorful_map_count",
    "colorful_map_count_checked",
    "colorful_map_count_many",
    "colorful_map_count_many_checked",
    "count_fn",
    "count_fn_many",
    "plan_sample_fn",
    "multi_sample_fn",
    "copy_scale",
]


def copy_scale(k: int, t: int, aut: int) -> float:
    """Per-iteration estimator scale for a size-``t`` template counted with
    ``k`` colors: ``k^t (k-t)! / k! / |Aut|`` — the inverse probability that
    the t image vertices of a copy draw pairwise-distinct colors, divided by
    the rooted-map over-count.  Reduces to the paper's ``k^k / k! / |Aut|``
    when ``t == k``."""
    return (k ** t) * math.factorial(k - t) / math.factorial(k) / aut


@dataclasses.dataclass(frozen=True)
class CountingPlan:
    """Static data for jit: graph plan + per-node combine tables."""

    tree: Tree
    chain: PartitionChain
    k: int  # color budget (== tree.n unless n_colors widened it)
    n: int
    n_pad: int
    aut: int
    spmm_plan: ops.SpmmPlan
    combine: Dict[int, ops.CombineTables]  # internal node index -> tables
    widths: Dict[int, int]  # node index -> padded table width
    impl: str = "auto"
    #: route each internal node through the fused SpMM->combine path
    fuse: bool = False
    #: column padding multiple the tables were built with (128 = pallas)
    lane: int = 128
    #: active-frontier compaction spec (None = dense; DESIGN.md §15)
    compaction: Optional[CompactionSpec] = None

    @property
    def scale(self) -> float:
        """Maps the colorful map count to the copy estimate."""
        return copy_scale(self.k, self.tree.n, self.aut)


@dataclasses.dataclass(frozen=True)
class MultiCountingPlan:
    """Static data for one-pass family counting: shared graph plan + the
    deduplicated template DAG's combine tables."""

    templates: Tuple[Tree, ...]
    dag: TemplateDag
    k: int  # shared color budget (max template size unless widened)
    n: int
    n_pad: int
    auts: Tuple[int, ...]
    spmm_plan: ops.SpmmPlan
    combine: Dict[int, ops.CombineTables]
    widths: Dict[int, int]
    impl: str = "auto"
    fuse: bool = False
    lane: int = 128
    compaction: Optional[CompactionSpec] = None

    @property
    def num_templates(self) -> int:
        return len(self.templates)

    @property
    def scales(self) -> Tuple[float, ...]:
        """Per-template copy-estimate scales (all against the shared k)."""
        return tuple(
            copy_scale(self.k, t.n, a) for t, a in zip(self.templates, self.auts)
        )


def _build_spmm(g, spmm_kind, tile_size, block_size):
    rows, cols = edge_list(g)
    return ops.build_spmm_plan(
        rows, cols, g.n, kind=spmm_kind, tile_size=tile_size, block_size=block_size
    )


def _resolve_lane(lane, impl):
    if lane is None:
        # Pallas kernels need 128-lane tables; XLA runs at true widths.
        lane = 128 if ops.resolve_impl(impl) == "pallas" else 1
    return lane


def _maybe_compaction(
    g, program, combine, k, spmm_plan, compact, density_threshold,
    capacity_factor, probes,
):
    if not compact:
        return None
    return single_device_compaction(
        g, program, combine, k,
        n_pad=spmm_plan.n_pad,
        threshold=density_threshold,
        capacity_factor=capacity_factor,
        probes=probes,
        # the SpMM indirection needs edge slabs; a blocks plan has none
        has_edge_slabs=spmm_plan.slab_dst is not None,
    )


def build_counting_plan(
    g: Graph,
    tree: Tree,
    *,
    root: int = 0,
    spmm_kind: str = "edges",
    impl: str = "auto",
    fuse: bool = False,
    tile_size: int = 128,
    block_size: int = 128,
    lane: Optional[int] = None,
    n_colors: Optional[int] = None,
    compact: bool = False,
    density_threshold: float = DEFAULT_DENSITY_THRESHOLD,
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR,
    probes: int = 2,
) -> CountingPlan:
    """``n_colors`` widens the color budget past the template size (used to
    compare single-template runs against a family counted with shared k).

    ``compact=True`` probes per-node table densities at build time and
    compacts every node below ``density_threshold`` (DESIGN.md §15):
    combines contract only active rows, the SpMM/fused kernels read sparse
    right tables through the compact row-index indirection, and the
    capacity headroom is ``capacity_factor`` (overflow falls back to the
    dense program, bit-exactly)."""
    chain = partition_tree(tree, root=root)
    k = n_colors if n_colors is not None else tree.n
    if k < tree.n:
        raise ValueError(f"n_colors={k} is smaller than the template ({tree.n})")
    plan = _build_spmm(g, spmm_kind, tile_size, block_size)
    lane = _resolve_lane(lane, impl)
    combine, widths = build_node_tables(chain, k, lane=lane)
    compaction = _maybe_compaction(
        g, chain, combine, k, plan, compact, density_threshold,
        capacity_factor, probes,
    )
    return CountingPlan(
        tree=tree,
        chain=chain,
        k=k,
        n=g.n,
        n_pad=plan.n_pad,
        aut=automorphism_count(tree),
        spmm_plan=plan,
        combine=combine,
        widths=widths,
        impl=impl,
        fuse=fuse,
        lane=lane,
        compaction=compaction,
    )


def build_multi_counting_plan(
    g: Graph,
    templates: Sequence,
    *,
    roots: Optional[Sequence[int]] = None,
    spmm_kind: str = "edges",
    impl: str = "auto",
    fuse: bool = False,
    tile_size: int = 128,
    block_size: int = 128,
    lane: Optional[int] = None,
    n_colors: Optional[int] = None,
    compact: bool = False,
    density_threshold: float = DEFAULT_DENSITY_THRESHOLD,
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR,
    probes: int = 2,
) -> MultiCountingPlan:
    """One plan for a whole template family: compile the set into a shared
    :class:`TemplateDag` and build each unique node's combine tables once."""
    dag = compile_templates(templates, n_colors=n_colors, roots=roots)
    plan = _build_spmm(g, spmm_kind, tile_size, block_size)
    lane = _resolve_lane(lane, impl)
    combine, widths = build_node_tables(dag, dag.k, lane=lane)
    compaction = _maybe_compaction(
        g, dag, combine, dag.k, plan, compact, density_threshold,
        capacity_factor, probes,
    )
    return MultiCountingPlan(
        templates=dag.templates,
        dag=dag,
        k=dag.k,
        n=g.n,
        n_pad=plan.n_pad,
        auts=tuple(automorphism_count(t) for t in dag.templates),
        spmm_plan=plan,
        combine=combine,
        widths=widths,
        impl=impl,
        fuse=fuse,
        lane=lane,
        compaction=compaction,
    )


def _program_counts(plan, program, coloring: jax.Array, *, checked=False):
    """Run ``program`` on one coloring; per-root colorful map counts.

    ``checked=True`` engages the plan's compaction spec and additionally
    returns the AND of every no-overflow flag — ``False`` means at least
    one static capacity overflowed and the counts must be recomputed on the
    dense program (the caller's responsibility; see :func:`count_fn`).
    """
    n_pad = plan.n_pad
    row_mask = (jnp.arange(n_pad) < plan.n).astype(jnp.float32)[:, None]
    leaf = leaf_table(coloring, ops.pad_to(plan.k, plan.lane), row_mask)
    spec = plan.compaction if checked else None
    if spec is not None and spec.enabled:
        flags: list = []
        frontier_fn = make_frontier_fn(spec.table_caps, plan.n, flags)
        node_fn = local_node_fn(
            plan.spmm_plan, row_mask, impl=plan.impl, fuse=plan.fuse,
            compaction=spec, sentinel_row=plan.n, flags=flags,
        )
        roots = run_table_program(
            program, plan.combine, leaf, row_mask, node_fn,
            root_fn=root_count, frontier_fn=frontier_fn,
        )
        ok = jnp.bool_(True)
        for f in flags:
            ok = jnp.logical_and(ok, f)
        return roots, ok
    node_fn = local_node_fn(plan.spmm_plan, row_mask, impl=plan.impl, fuse=plan.fuse)
    roots = run_table_program(
        program, plan.combine, leaf, row_mask, node_fn, root_fn=root_count
    )
    return (roots, jnp.bool_(True)) if checked else roots


def colorful_map_count(plan: CountingPlan, coloring: jax.Array) -> jax.Array:
    """Number of colorful rooted embedding maps for one coloring.

    ``coloring``: int32 [n_pad] (entries past plan.n ignored).
    Differentiable-free pure function of the coloring; jit with
    ``jax.jit(functools.partial(colorful_map_count, plan))`` or use
    :func:`count_fn`.  The DP itself is the shared table program
    (:mod:`repro.core.table_program`) with the ``local`` (whole-graph SpMM)
    neighbor-sum strategy.  Always executes the dense program — the
    compact path (which needs its overflow flag consumed) is
    :func:`colorful_map_count_checked`.
    """
    return _program_counts(plan, plan.chain, coloring)[0]


def colorful_map_count_checked(
    plan: CountingPlan, coloring: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Compact-path count plus its no-overflow flag ``(maps, ok)``.

    When ``ok`` is False some static capacity overflowed and ``maps`` is
    not trustworthy — recompute with :func:`colorful_map_count` (dense);
    when True the value is bit-identical to the dense program's.
    """
    roots, ok = _program_counts(plan, plan.chain, coloring, checked=True)
    return roots[0], ok


def colorful_map_count_many(
    plan: MultiCountingPlan, coloring: jax.Array
) -> jax.Array:
    """Per-template colorful map counts ``[num_templates]`` for ONE coloring.

    One pass over the deduplicated DAG: shared subtree tables are computed
    once; each template root reduces to its own count.  Dense program (see
    :func:`colorful_map_count`); the compact path is
    :func:`colorful_map_count_many_checked`.
    """
    return jnp.stack(_program_counts(plan, plan.dag, coloring))


def colorful_map_count_many_checked(
    plan: MultiCountingPlan, coloring: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Family analogue of :func:`colorful_map_count_checked`."""
    roots, ok = _program_counts(plan, plan.dag, coloring, checked=True)
    return jnp.stack(roots), ok


def _checked_fallback(compact_fn, make_dense):
    """Host-side overflow fallback around a jitted compact counter.

    The compact program is speculative: it returns its no-overflow flag
    alongside the counts, and on the rare batch where a static capacity
    overflowed the whole batch is re-dispatched on the lazily-built dense
    twin — bit-identical results either way, since the compact path equals
    the dense path exactly whenever its flag holds.
    """
    state: Dict[str, object] = {}

    def f(key: jax.Array):
        maps, est, ok = compact_fn(key)
        # the fault site forces an overflow storm so tests drive the dense
        # twin (and its interaction with resume) without a lucky coloring
        forced = faults.fire("compaction.overflow") is not None
        if not forced and bool(np.all(np.asarray(ok))):
            return maps, est
        fd = state.get("dense")
        if fd is None:
            fd = state["dense"] = make_dense()
        return fd(key)

    return f


def count_fn(plan: CountingPlan, batch: Optional[int] = None):
    """Jitted per-iteration counter.

    ``batch=None``: returns ``f(key) -> (maps, estimate)`` scalars for one
    coloring (the original contract).  ``batch=B``: returns
    ``f(key) -> (maps[B], estimates[B])`` evaluating B independent colorings
    in one jit call — the colorings are embarrassingly parallel, so vmapping
    the DP amortizes dispatch and SpMM-plan constant overheads across the
    batch.

    A compacted plan (``plan.compaction``) runs the active-frontier program
    and transparently re-dispatches the dense twin on capacity overflow
    (DESIGN.md §15) — the returned callable keeps the exact same contract.
    """
    compact = plan.compaction is not None and plan.compaction.enabled
    count1 = colorful_map_count_checked if compact else (
        lambda p, c: (colorful_map_count(p, c), None)
    )

    if batch is None:

        def f(key: jax.Array):
            coloring = jax.random.randint(
                key, (plan.n_pad,), 0, plan.k, dtype=jnp.int32
            )
            maps, ok = count1(plan, coloring)
            return (maps, maps * plan.scale) if ok is None else (
                maps, maps * plan.scale, ok
            )

    else:

        def f(key: jax.Array):
            colorings = jax.random.randint(
                key, (batch, plan.n_pad), 0, plan.k, dtype=jnp.int32
            )
            maps, ok = jax.vmap(lambda c: count1(plan, c))(colorings)
            return (maps, maps * plan.scale) if not compact else (
                maps, maps * plan.scale, ok
            )

    if not compact:
        return jax.jit(f)
    dense_plan = dataclasses.replace(plan, compaction=None)
    return _checked_fallback(jax.jit(f), lambda: count_fn(dense_plan, batch))


def count_fn_many(plan: MultiCountingPlan, batch: Optional[int] = None):
    """Jitted family counter: ``f(key) -> (maps, estimates)`` with shapes
    ``[R]`` (``batch=None``) or ``[B, R]`` — the same key-derived colorings
    as :func:`count_fn` with ``n_colors=plan.k``, so a family run and a
    per-template run from the same key see identical colorings.  Compacted
    plans fall back to the dense twin on overflow, like :func:`count_fn`."""
    scales = jnp.asarray(plan.scales)
    compact = plan.compaction is not None and plan.compaction.enabled
    count1 = colorful_map_count_many_checked if compact else (
        lambda p, c: (colorful_map_count_many(p, c), None)
    )

    if batch is None:

        def f(key: jax.Array):
            coloring = jax.random.randint(
                key, (plan.n_pad,), 0, plan.k, dtype=jnp.int32
            )
            maps, ok = count1(plan, coloring)
            return (maps, maps * scales) if ok is None else (
                maps, maps * scales, ok
            )

    else:

        def f(key: jax.Array):
            colorings = jax.random.randint(
                key, (batch, plan.n_pad), 0, plan.k, dtype=jnp.int32
            )
            maps, ok = jax.vmap(lambda c: count1(plan, c))(colorings)
            return (maps, maps * scales[None, :]) if not compact else (
                maps, maps * scales[None, :], ok
            )

    if not compact:
        return jax.jit(f)
    dense_plan = dataclasses.replace(plan, compaction=None)
    return _checked_fallback(
        jax.jit(f), lambda: count_fn_many(dense_plan, batch)
    )


def _cached_sampler(make_fn):
    cache: Dict[int, object] = {}

    def sample(key: jax.Array, batch: int) -> np.ndarray:
        f = cache.get(batch)
        if f is None:
            f = cache[batch] = make_fn(batch)
        _, est = f(key)
        return np.asarray(est, np.float64)

    return sample


def plan_sample_fn(plan: CountingPlan):
    """Adapt a single-device plan to the backend ``sample_fn`` protocol.

    The protocol (shared with the distributed backend and consumed by
    :func:`repro.core.estimator.estimate_counts`) is
    ``sample_fn(key, batch) -> float64 [batch]`` copy estimates for ``batch``
    independent colorings derived from ``key``.  Compiled ``count_fn``
    closures are cached per batch size so repeated calls reuse the jit cache.
    """
    sample = _cached_sampler(lambda b: count_fn(plan, batch=b))

    def sample1(key: jax.Array, batch: int) -> np.ndarray:
        return sample(key, batch).reshape(-1)

    return sample1


def multi_sample_fn(plan: MultiCountingPlan):
    """The family variant of the protocol: ``sample_fn(key, batch) ->
    float64 [batch, num_templates]`` per-coloring copy estimates, consumed
    by :func:`repro.core.estimator.estimate_counts_many`."""
    sample = _cached_sampler(lambda b: count_fn_many(plan, batch=b))

    def sample_many(key: jax.Array, batch: int) -> np.ndarray:
        return sample(key, batch).reshape(batch, plan.num_templates)

    return sample_many
