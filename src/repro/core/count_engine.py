"""Single-device color-coding DP engine.

Pipeline per coloring iteration (Algorithm 1 of the paper):

1. sample a random coloring ``col(v) in {0..k-1}``;
2. leaf tables = one-hot of the coloring, ``[n_pad, k_pad]``;
3. for each internal partition node (postorder):
   ``M = spmm(A, C_right)`` (neighbor sum) then
   ``C_node = color_combine(C_left, M)`` (split-table contraction),
   with pad rows/cols re-masked — or, with ``fuse=True``, one
   ``ops.fused_count`` call that contracts each ``row_tile``-row block of
   ``M`` as soon as it is produced and never materializes the full
   ``[n_pad, B]`` neighbor sum (the paper's fine-grained pipeline, §3.2,
   at kernel granularity; see DESIGN.md §11);
4. colorful map count = ``sum_v C_root[v, 0]`` (the full color set has rank
   0 in its singleton table).

Column padding is impl-dependent (``lane``): the Pallas kernels need
128-lane-aligned tables, while the XLA paths run at true table widths —
on CPU/GPU that alone removes the 12.8x waste of padding the k-wide leaf
tables to 128 columns.

Batched colorings: the outer color-coding loop is embarrassingly parallel,
so ``count_fn(plan, batch=B)`` evaluates B independent colorings per jit
call (vmap over the DP), amortizing dispatch and plan overheads across the
batch — the single-device mirror of the paper's multi-node outer loop.

The DP uses ``d = 1`` in the recurrence and divides the final count by
``|Aut(T)|`` once — equivalent to the paper's per-step over-counting factor
(see DESIGN.md §1) and exactly testable against the brute-force oracle.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from .graphs import Graph, edge_list
from .table_program import (
    leaf_table,
    local_node_fn,
    build_node_tables,
    root_count,
    run_table_program,
)
from .templates import PartitionChain, Tree, automorphism_count, partition_tree

__all__ = [
    "CountingPlan",
    "build_counting_plan",
    "colorful_map_count",
    "count_fn",
    "plan_sample_fn",
]


@dataclasses.dataclass(frozen=True)
class CountingPlan:
    """Static data for jit: graph plan + per-node combine tables."""

    tree: Tree
    chain: PartitionChain
    k: int
    n: int
    n_pad: int
    aut: int
    spmm_plan: ops.SpmmPlan
    combine: Dict[int, ops.CombineTables]  # internal node index -> tables
    widths: Dict[int, int]  # node index -> padded table width
    impl: str = "auto"
    #: route each internal node through the fused SpMM->combine path
    fuse: bool = False
    #: column padding multiple the tables were built with (128 = pallas)
    lane: int = 128

    @property
    def scale(self) -> float:
        """k^k / k! / |Aut| — maps colorful map count to copy estimate."""
        k = self.k
        return (k ** k) / math.factorial(k) / self.aut


def build_counting_plan(
    g: Graph,
    tree: Tree,
    *,
    root: int = 0,
    spmm_kind: str = "edges",
    impl: str = "auto",
    fuse: bool = False,
    tile_size: int = 128,
    block_size: int = 128,
    lane: Optional[int] = None,
) -> CountingPlan:
    chain = partition_tree(tree, root=root)
    k = tree.n
    rows, cols = edge_list(g)
    plan = ops.build_spmm_plan(
        rows, cols, g.n, kind=spmm_kind, tile_size=tile_size, block_size=block_size
    )
    if lane is None:
        # Pallas kernels need 128-lane tables; XLA runs at true widths.
        lane = 128 if ops.resolve_impl(impl) == "pallas" else 1
    combine, widths = build_node_tables(chain, k, lane=lane)
    return CountingPlan(
        tree=tree,
        chain=chain,
        k=k,
        n=g.n,
        n_pad=plan.n_pad,
        aut=automorphism_count(tree),
        spmm_plan=plan,
        combine=combine,
        widths=widths,
        impl=impl,
        fuse=fuse,
        lane=lane,
    )


def colorful_map_count(plan: CountingPlan, coloring: jax.Array) -> jax.Array:
    """Number of colorful rooted embedding maps for one coloring.

    ``coloring``: int32 [n_pad] (entries past plan.n ignored).
    Differentiable-free pure function of the coloring; jit with
    ``jax.jit(functools.partial(colorful_map_count, plan))`` or use
    :func:`count_fn`.  The DP itself is the shared table program
    (:mod:`repro.core.table_program`) with the ``local`` (whole-graph SpMM)
    neighbor-sum strategy.
    """
    n_pad = plan.n_pad
    row_mask = (jnp.arange(n_pad) < plan.n).astype(jnp.float32)[:, None]
    leaf = leaf_table(coloring, ops.pad_to(plan.k, plan.lane), row_mask)
    node_fn = local_node_fn(plan.spmm_plan, row_mask, impl=plan.impl, fuse=plan.fuse)
    root = run_table_program(plan.chain, plan.combine, leaf, row_mask, node_fn)
    return root_count(root)


def count_fn(plan: CountingPlan, batch: Optional[int] = None):
    """Jitted per-iteration counter.

    ``batch=None``: returns ``f(key) -> (maps, estimate)`` scalars for one
    coloring (the original contract).  ``batch=B``: returns
    ``f(key) -> (maps[B], estimates[B])`` evaluating B independent colorings
    in one jit call — the colorings are embarrassingly parallel, so vmapping
    the DP amortizes dispatch and SpMM-plan constant overheads across the
    batch.
    """
    if batch is None:

        def f(key: jax.Array):
            coloring = jax.random.randint(
                key, (plan.n_pad,), 0, plan.k, dtype=jnp.int32
            )
            maps = colorful_map_count(plan, coloring)
            return maps, maps * plan.scale

        return jax.jit(f)

    def fb(key: jax.Array):
        colorings = jax.random.randint(
            key, (batch, plan.n_pad), 0, plan.k, dtype=jnp.int32
        )
        maps = jax.vmap(lambda c: colorful_map_count(plan, c))(colorings)
        return maps, maps * plan.scale

    return jax.jit(fb)


def plan_sample_fn(plan: CountingPlan):
    """Adapt a single-device plan to the backend ``sample_fn`` protocol.

    The protocol (shared with the distributed backend and consumed by
    :func:`repro.core.estimator.estimate_counts`) is
    ``sample_fn(key, batch) -> float64 [batch]`` copy estimates for ``batch``
    independent colorings derived from ``key``.  Compiled ``count_fn``
    closures are cached per batch size so repeated calls reuse the jit cache.
    """
    cache: Dict[int, object] = {}

    def sample(key: jax.Array, batch: int) -> np.ndarray:
        f = cache.get(batch)
        if f is None:
            f = cache[batch] = count_fn(plan, batch=batch)
        _, est = f(key)
        return np.asarray(est, np.float64).reshape(-1)

    return sample
