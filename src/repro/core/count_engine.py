"""Single-device color-coding DP engine.

Pipeline per coloring iteration (Algorithm 1 of the paper):

1. sample a random coloring ``col(v) in {0..k-1}``;
2. leaf tables = one-hot of the coloring, ``[n_pad, k_pad]``;
3. for each internal partition node (topological order):
   ``M = spmm(A, C_right)`` (neighbor sum) then
   ``C_node = color_combine(C_left, M)`` (split-table contraction),
   with pad rows/cols re-masked — or, with ``fuse=True``, one
   ``ops.fused_count`` call that contracts each ``row_tile``-row block of
   ``M`` as soon as it is produced and never materializes the full
   ``[n_pad, B]`` neighbor sum (the paper's fine-grained pipeline, §3.2,
   at kernel granularity; see DESIGN.md §11);
4. colorful map count = ``sum_{v, S} C_root[v, S]`` (one column per color
   set of the template's size; the single full-set column when t == k).

Column padding is impl-dependent (``lane``): the Pallas kernels need
128-lane-aligned tables, while the XLA paths run at true table widths —
on CPU/GPU that alone removes the 12.8x waste of padding the k-wide leaf
tables to 128 columns.

Batched colorings: the outer color-coding loop is embarrassingly parallel,
so ``count_fn(plan, batch=B)`` evaluates B independent colorings per jit
call (vmap over the DP), amortizing dispatch and plan overheads across the
batch — the single-device mirror of the paper's multi-node outer loop.

Multi-template counting: :func:`build_multi_counting_plan` compiles a whole
template family into one deduplicated :class:`TemplateDag` (DESIGN.md §14)
and :func:`colorful_map_count_many` runs it as ONE table program per
coloring — every canonically-unique subtree table is computed once and
every template root reads its own entry, so counting N related templates
costs the unique-table work, not N independent chains.

The DP uses ``d = 1`` in the recurrence and divides the final count by
``|Aut(T)|`` once — equivalent to the paper's per-step over-counting factor
(see DESIGN.md §1) and exactly testable against the brute-force oracle.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.testing import faults
from .frontier import (
    DEFAULT_CAPACITY_FACTOR,
    DEFAULT_DENSITY_THRESHOLD,
    CompactionSpec,
    make_frontier_fn,
    single_device_compaction,
)
from .colorsets import excluded_color_mask
from .graphs import Graph, edge_list
from .table_program import (
    BagFns,
    leaf_table,
    local_node_fn,
    build_node_tables,
    root_count,
    run_table_program,
)
from .templates import (
    PartitionChain,
    Template,
    TemplateDag,
    Tree,
    automorphism_count,
    compile_templates,
    partition_tree,
    program_has_bags,
    template_program,
)

__all__ = [
    "CountingPlan",
    "MultiCountingPlan",
    "build_counting_plan",
    "build_multi_counting_plan",
    "colorful_map_count",
    "colorful_map_count_checked",
    "colorful_map_count_many",
    "colorful_map_count_many_checked",
    "count_fn",
    "count_fn_many",
    "plan_sample_fn",
    "multi_sample_fn",
    "copy_scale",
]


def copy_scale(k: int, t: int, aut: int) -> float:
    """Per-iteration estimator scale for a size-``t`` template counted with
    ``k`` colors: ``k^t (k-t)! / k! / |Aut|`` — the inverse probability that
    the t image vertices of a copy draw pairwise-distinct colors, divided by
    the rooted-map over-count.  Reduces to the paper's ``k^k / k! / |Aut|``
    when ``t == k``."""
    return (k ** t) * math.factorial(k - t) / math.factorial(k) / aut


@dataclasses.dataclass(frozen=True)
class CountingPlan:
    """Static data for jit: graph plan + per-node combine tables."""

    tree: Tree
    chain: PartitionChain
    k: int  # color budget (== tree.n unless n_colors widened it)
    n: int
    n_pad: int
    aut: int
    spmm_plan: ops.SpmmPlan
    combine: Dict[int, ops.CombineTables]  # internal node index -> tables
    widths: Dict[int, int]  # node index -> padded table width
    impl: str = "auto"
    #: route each internal node through the fused SpMM->combine path
    fuse: bool = False
    #: column padding multiple the tables were built with (128 = pallas)
    lane: int = 128
    #: active-frontier compaction spec (None = dense; DESIGN.md §15)
    compaction: Optional[CompactionSpec] = None
    #: dense host adjacency ``[n_pad, n]`` for pinned bag leaves (treewidth-2
    #: templates only; None for pure-tree programs — DESIGN.md §19)
    pin_adj: Optional[jax.Array] = None

    @property
    def scale(self) -> float:
        """Maps the colorful map count to the copy estimate."""
        return copy_scale(self.k, self.tree.n, self.aut)


@dataclasses.dataclass(frozen=True)
class MultiCountingPlan:
    """Static data for one-pass family counting: shared graph plan + the
    deduplicated template DAG's combine tables."""

    templates: Tuple[Tree, ...]
    dag: TemplateDag
    k: int  # shared color budget (max template size unless widened)
    n: int
    n_pad: int
    auts: Tuple[int, ...]
    spmm_plan: ops.SpmmPlan
    combine: Dict[int, ops.CombineTables]
    widths: Dict[int, int]
    impl: str = "auto"
    fuse: bool = False
    lane: int = 128
    compaction: Optional[CompactionSpec] = None
    pin_adj: Optional[jax.Array] = None

    @property
    def num_templates(self) -> int:
        return len(self.templates)

    @property
    def scales(self) -> Tuple[float, ...]:
        """Per-template copy-estimate scales (all against the shared k)."""
        return tuple(copy_scale(self.k, t.n, a) for t, a in zip(self.templates, self.auts))


def _build_spmm(g, spmm_kind, tile_size, block_size):
    rows, cols = edge_list(g)
    return ops.build_spmm_plan(
        rows, cols, g.n, kind=spmm_kind, tile_size=tile_size, block_size=block_size
    )


def _resolve_lane(lane, impl):
    if lane is None:
        # Pallas kernels need 128-lane tables; XLA runs at true widths.
        lane = 128 if ops.resolve_impl(impl) == "pallas" else 1
    return lane


def _build_pin_adj(g: Graph, n_pad: int) -> jax.Array:
    """Dense ``[n_pad, n]`` float32 host adjacency for pinned bag leaves.

    Pad rows stay zero, so a pinned leaf's pad rows are zero without extra
    masking (the §15/§18 pad-row invariant holds for bag tables too)."""
    rows, cols = edge_list(g)
    a = np.zeros((n_pad, g.n), np.float32)
    a[np.asarray(rows), np.asarray(cols)] = 1.0
    return jnp.asarray(a)


def _maybe_compaction(
    g,
    program,
    combine,
    k,
    spmm_plan,
    compact,
    density_threshold,
    capacity_factor,
    probes,
):
    if not compact:
        return None
    if program_has_bags(program):
        # §15's boolean activity probe models tree combines only; bag-table
        # programs run dense (DESIGN.md §19 documents the bypass)
        return None
    return single_device_compaction(
        g, program, combine, k,
        n_pad=spmm_plan.n_pad,
        threshold=density_threshold,
        capacity_factor=capacity_factor,
        probes=probes,
        # the SpMM indirection needs edge slabs; a blocks plan has none
        has_edge_slabs=spmm_plan.slab_dst is not None,
    )


def build_counting_plan(
    g: Graph,
    tree: Tree,
    *,
    root: int = 0,
    spmm_kind: str = "edges",
    impl: str = "auto",
    fuse: bool = False,
    tile_size: int = 128,
    block_size: int = 128,
    lane: Optional[int] = None,
    n_colors: Optional[int] = None,
    compact: bool = False,
    density_threshold: float = DEFAULT_DENSITY_THRESHOLD,
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR,
    probes: int = 2,
) -> CountingPlan:
    """``n_colors`` widens the color budget past the template size (used to
    compare single-template runs against a family counted with shared k).

    ``compact=True`` probes per-node table densities at build time and
    compacts every node below ``density_threshold`` (DESIGN.md §15):
    combines contract only active rows, the SpMM/fused kernels read sparse
    right tables through the compact row-index indirection, and the
    capacity headroom is ``capacity_factor`` (overflow falls back to the
    dense program, bit-exactly).

    ``tree`` may be a :class:`Tree` or a :class:`Template`: tree-shaped
    templates take the classic :func:`partition_tree` path bit-identically,
    non-trees compile to an apex-pinned bag program (DESIGN.md §19)."""
    if isinstance(tree, Template) and tree.is_tree:
        tree = tree.as_tree()
    chain = template_program(tree, root=root)
    has_bags = program_has_bags(chain)
    k = n_colors if n_colors is not None else tree.n
    if k < tree.n:
        raise ValueError(f"n_colors={k} is smaller than the template ({tree.n})")
    plan = _build_spmm(g, spmm_kind, tile_size, block_size)
    lane = _resolve_lane(lane, impl)
    combine, widths = build_node_tables(chain, k, lane=lane, x_dim=g.n if has_bags else None)
    compaction = _maybe_compaction(
        g,
        chain,
        combine,
        k,
        plan,
        compact,
        density_threshold,
        capacity_factor,
        probes,
    )
    return CountingPlan(
        tree=tree,
        chain=chain,
        k=k,
        n=g.n,
        n_pad=plan.n_pad,
        aut=automorphism_count(tree),
        spmm_plan=plan,
        combine=combine,
        widths=widths,
        impl=impl,
        fuse=fuse,
        lane=lane,
        compaction=compaction,
        pin_adj=_build_pin_adj(g, plan.n_pad) if has_bags else None,
    )


def build_multi_counting_plan(
    g: Graph,
    templates: Sequence,
    *,
    roots: Optional[Sequence[int]] = None,
    spmm_kind: str = "edges",
    impl: str = "auto",
    fuse: bool = False,
    tile_size: int = 128,
    block_size: int = 128,
    lane: Optional[int] = None,
    n_colors: Optional[int] = None,
    compact: bool = False,
    density_threshold: float = DEFAULT_DENSITY_THRESHOLD,
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR,
    probes: int = 2,
) -> MultiCountingPlan:
    """One plan for a whole template family: compile the set into a shared
    :class:`TemplateDag` and build each unique node's combine tables once."""
    dag = compile_templates(templates, n_colors=n_colors, roots=roots)
    has_bags = program_has_bags(dag)
    plan = _build_spmm(g, spmm_kind, tile_size, block_size)
    lane = _resolve_lane(lane, impl)
    combine, widths = build_node_tables(dag, dag.k, lane=lane, x_dim=g.n if has_bags else None)
    compaction = _maybe_compaction(
        g,
        dag,
        combine,
        dag.k,
        plan,
        compact,
        density_threshold,
        capacity_factor,
        probes,
    )
    return MultiCountingPlan(
        templates=dag.templates,
        dag=dag,
        k=dag.k,
        n=g.n,
        n_pad=plan.n_pad,
        auts=tuple(automorphism_count(t) for t in dag.templates),
        spmm_plan=plan,
        combine=combine,
        widths=widths,
        impl=impl,
        fuse=fuse,
        lane=lane,
        compaction=compaction,
        pin_adj=_build_pin_adj(g, plan.n_pad) if has_bags else None,
    )


def _program_counts(plan, program, coloring: jax.Array, *, checked=False):
    """Run ``program`` on one coloring; per-root colorful map counts.

    ``checked=True`` engages the plan's compaction spec and additionally
    returns the AND of every no-overflow flag — ``False`` means at least
    one static capacity overflowed and the counts must be recomputed on the
    dense program (the caller's responsibility; see :func:`count_fn`).
    """
    n_pad = plan.n_pad
    row_mask = (jnp.arange(n_pad) < plan.n).astype(jnp.float32)[:, None]
    k_pad = ops.pad_to(plan.k, plan.lane)
    leaf = leaf_table(coloring, k_pad, row_mask)
    bag = _bag_fns(plan, program, coloring, leaf) if program_has_bags(program) else None
    spec = plan.compaction if checked else None
    if spec is not None and spec.enabled:
        flags: list = []
        frontier_fn = make_frontier_fn(spec.table_caps, plan.n, flags)
        node_fn = local_node_fn(
            plan.spmm_plan,
            row_mask,
            impl=plan.impl,
            fuse=plan.fuse,
            compaction=spec,
            sentinel_row=plan.n,
            flags=flags,
        )
        roots = run_table_program(
            program,
            plan.combine,
            leaf,
            row_mask,
            node_fn,
            root_fn=root_count,
            frontier_fn=frontier_fn,
        )
        ok = jnp.bool_(True)
        for f in flags:
            ok = jnp.logical_and(ok, f)
        return roots, ok
    node_fn = local_node_fn(plan.spmm_plan, row_mask, impl=plan.impl, fuse=plan.fuse)
    if bag is not None:
        node_fn = _bag_node_fn(plan, program, row_mask, node_fn)
    roots = run_table_program(
        program, plan.combine, leaf, row_mask, node_fn, root_fn=root_count, bag=bag
    )
    return (roots, jnp.bool_(True)) if checked else roots


def _bag_node_fn(plan, program, row_mask, base_fn):
    """Wrap the in-core neighbor-sum strategy for ``bag_combine`` nodes.

    A bag table ``[rows, x * W]`` is, row-major, ``x`` contiguous blocks of
    width ``W`` per vertex row — so the whole-graph SpMM applies unchanged
    (it is width-agnostic), and the color convolution runs on the exact
    ``[rows * x, W]`` reshape.  Fusion is bypassed per bag node (the fused
    kernel contracts over vertex rows and cannot align the ``(v, x)`` pair
    axis); tree nodes of a mixed program keep their fused path.
    """
    x_dim = plan.n

    def node_fn(i, tbl, c_left, c_right, f_left, f_right):
        if program.nodes[i].kind != "bag_combine":
            return base_fn(i, tbl, c_left, c_right, f_left, f_right)
        m = ops.spmm(plan.spmm_plan, c_right, impl=plan.impl) * row_mask
        rows = c_left.shape[0]
        lhs = c_left.reshape(rows * x_dim, -1)
        rhs = m.reshape(rows * x_dim, -1)
        out = ops.color_combine(lhs, rhs, tbl, impl=plan.impl)
        return out.reshape(rows, x_dim * tbl.s_pad)

    return node_fn


def _bag_fns(plan, program, coloring: jax.Array, leaf: jax.Array) -> BagFns:
    """In-core strategy for the bag-only node kinds (DESIGN.md §19)."""
    n_pad, x_dim = plan.n_pad, plan.n
    k_pad = leaf.shape[1]
    pin_adj = plan.pin_adj  # [n_pad, n]; pad rows zero
    coloring_x = coloring[: plan.n]  # the x axis is the real host vertices

    def leaf_fn(i, nd):
        if nd.pin:
            t = leaf[:, None, :] * pin_adj[:, :, None]
        else:
            t = jnp.broadcast_to(leaf[:, None, :], (n_pad, x_dim, k_pad))
        return t.reshape(n_pad, x_dim * k_pad)

    def collapse_fn(i, child):
        w = child.shape[1] // x_dim
        r = child.reshape(n_pad, x_dim, w).sum(axis=0)  # pad v-rows are zero
        t = program.nodes[i].size
        filt = excluded_color_mask(plan.k, t)  # [k, C(k, t)]
        filt_pad = np.zeros((plan.k, w), np.float32)
        filt_pad[:, : filt.shape[1]] = filt
        # keep only the color sets that exclude the apex color col(x)
        return r * jnp.asarray(filt_pad)[coloring_x]

    def join_fn(i, tbl, left, right):
        return ops.color_combine(left, right, tbl, impl=plan.impl)

    return BagFns(leaf_fn, collapse_fn, join_fn)


def colorful_map_count(plan: CountingPlan, coloring: jax.Array) -> jax.Array:
    """Number of colorful rooted embedding maps for one coloring.

    ``coloring``: int32 [n_pad] (entries past plan.n ignored).
    Differentiable-free pure function of the coloring; jit with
    ``jax.jit(functools.partial(colorful_map_count, plan))`` or use
    :func:`count_fn`.  The DP itself is the shared table program
    (:mod:`repro.core.table_program`) with the ``local`` (whole-graph SpMM)
    neighbor-sum strategy.  Always executes the dense program — the
    compact path (which needs its overflow flag consumed) is
    :func:`colorful_map_count_checked`.
    """
    return _program_counts(plan, plan.chain, coloring)[0]


def colorful_map_count_checked(
    plan: CountingPlan, coloring: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Compact-path count plus its no-overflow flag ``(maps, ok)``.

    When ``ok`` is False some static capacity overflowed and ``maps`` is
    not trustworthy — recompute with :func:`colorful_map_count` (dense);
    when True the value is bit-identical to the dense program's.
    """
    roots, ok = _program_counts(plan, plan.chain, coloring, checked=True)
    return roots[0], ok


def colorful_map_count_many(plan: MultiCountingPlan, coloring: jax.Array) -> jax.Array:
    """Per-template colorful map counts ``[num_templates]`` for ONE coloring.

    One pass over the deduplicated DAG: shared subtree tables are computed
    once; each template root reduces to its own count.  Dense program (see
    :func:`colorful_map_count`); the compact path is
    :func:`colorful_map_count_many_checked`.
    """
    return jnp.stack(_program_counts(plan, plan.dag, coloring))


def colorful_map_count_many_checked(
    plan: MultiCountingPlan, coloring: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Family analogue of :func:`colorful_map_count_checked`."""
    roots, ok = _program_counts(plan, plan.dag, coloring, checked=True)
    return jnp.stack(roots), ok


def _checked_fallback(compact_fn, make_dense):
    """Host-side overflow fallback around a jitted compact counter.

    The compact program is speculative: it returns its no-overflow flag
    alongside the counts, and on the rare batch where a static capacity
    overflowed the whole batch is re-dispatched on the lazily-built dense
    twin — bit-identical results either way, since the compact path equals
    the dense path exactly whenever its flag holds.
    """
    state: Dict[str, object] = {}

    def f(key: jax.Array):
        maps, est, ok = compact_fn(key)
        # the fault site forces an overflow storm so tests drive the dense
        # twin (and its interaction with resume) without a lucky coloring
        forced = faults.fire("compaction.overflow") is not None
        if not forced and bool(np.all(np.asarray(ok))):
            return maps, est
        fd = state.get("dense")
        if fd is None:
            fd = state["dense"] = make_dense()
        return fd(key)

    return f


def count_fn(plan: CountingPlan, batch: Optional[int] = None):
    """Jitted per-iteration counter.

    ``batch=None``: returns ``f(key) -> (maps, estimate)`` scalars for one
    coloring (the original contract).  ``batch=B``: returns
    ``f(key) -> (maps[B], estimates[B])`` evaluating B independent colorings
    in one jit call — the colorings are embarrassingly parallel, so vmapping
    the DP amortizes dispatch and SpMM-plan constant overheads across the
    batch.

    A compacted plan (``plan.compaction``) runs the active-frontier program
    and transparently re-dispatches the dense twin on capacity overflow
    (DESIGN.md §15) — the returned callable keeps the exact same contract.
    """
    compact = plan.compaction is not None and plan.compaction.enabled
    count1 = colorful_map_count_checked if compact else (
        lambda p, c: (colorful_map_count(p, c), None)
    )

    if batch is None:

        def f(key: jax.Array):
            coloring = jax.random.randint(key, (plan.n_pad,), 0, plan.k, dtype=jnp.int32)
            maps, ok = count1(plan, coloring)
            return (maps, maps * plan.scale) if ok is None else (maps, maps * plan.scale, ok)

    else:

        def f(key: jax.Array):
            colorings = jax.random.randint(key, (batch, plan.n_pad), 0, plan.k, dtype=jnp.int32)
            maps, ok = jax.vmap(lambda c: count1(plan, c))(colorings)
            return (maps, maps * plan.scale) if not compact else (maps, maps * plan.scale, ok)

    if not compact:
        return jax.jit(f)
    dense_plan = dataclasses.replace(plan, compaction=None)
    return _checked_fallback(jax.jit(f), lambda: count_fn(dense_plan, batch))


def count_fn_many(plan: MultiCountingPlan, batch: Optional[int] = None):
    """Jitted family counter: ``f(key) -> (maps, estimates)`` with shapes
    ``[R]`` (``batch=None``) or ``[B, R]`` — the same key-derived colorings
    as :func:`count_fn` with ``n_colors=plan.k``, so a family run and a
    per-template run from the same key see identical colorings.  Compacted
    plans fall back to the dense twin on overflow, like :func:`count_fn`."""
    scales = jnp.asarray(plan.scales)
    compact = plan.compaction is not None and plan.compaction.enabled
    count1 = colorful_map_count_many_checked if compact else (
        lambda p, c: (colorful_map_count_many(p, c), None)
    )

    if batch is None:

        def f(key: jax.Array):
            coloring = jax.random.randint(key, (plan.n_pad,), 0, plan.k, dtype=jnp.int32)
            maps, ok = count1(plan, coloring)
            return (maps, maps * scales) if ok is None else (maps, maps * scales, ok)

    else:

        def f(key: jax.Array):
            colorings = jax.random.randint(key, (batch, plan.n_pad), 0, plan.k, dtype=jnp.int32)
            maps, ok = jax.vmap(lambda c: count1(plan, c))(colorings)
            return (maps, maps * scales[None, :]) if not compact else (
                maps, maps * scales[None, :], ok
            )

    if not compact:
        return jax.jit(f)
    dense_plan = dataclasses.replace(plan, compaction=None)
    return _checked_fallback(
        jax.jit(f), lambda: count_fn_many(dense_plan, batch)
    )


def _cached_sampler(make_fn):
    cache: Dict[int, object] = {}

    def sample(key: jax.Array, batch: int) -> np.ndarray:
        f = cache.get(batch)
        if f is None:
            f = cache[batch] = make_fn(batch)
        _, est = f(key)
        return np.asarray(est, np.float64)

    return sample


def plan_sample_fn(plan: CountingPlan):
    """Adapt a single-device plan to the backend ``sample_fn`` protocol.

    The protocol (shared with the distributed backend and consumed by
    :func:`repro.core.estimator.estimate_counts`) is
    ``sample_fn(key, batch) -> float64 [batch]`` copy estimates for ``batch``
    independent colorings derived from ``key``.  Compiled ``count_fn``
    closures are cached per batch size so repeated calls reuse the jit cache.
    """
    sample = _cached_sampler(lambda b: count_fn(plan, batch=b))

    def sample1(key: jax.Array, batch: int) -> np.ndarray:
        return sample(key, batch).reshape(-1)

    return sample1


def multi_sample_fn(plan: MultiCountingPlan):
    """The family variant of the protocol: ``sample_fn(key, batch) ->
    float64 [batch, num_templates]`` per-coloring copy estimates, consumed
    by :func:`repro.core.estimator.estimate_counts_many`."""
    sample = _cached_sampler(lambda b: count_fn_many(plan, batch=b))

    def sample_many(key: jax.Array, batch: int) -> np.ndarray:
        return sample(key, batch).reshape(batch, plan.num_templates)

    return sample_many
