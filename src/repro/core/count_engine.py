"""Single-device color-coding DP engine.

Pipeline per coloring iteration (Algorithm 1 of the paper):

1. sample a random coloring ``col(v) in {0..k-1}``;
2. leaf tables = one-hot of the coloring, ``[n_pad, k_pad]``;
3. for each internal partition node (topological order):
   ``M = spmm(A, C_right)`` (neighbor sum) then
   ``C_node = color_combine(C_left, M)`` (split-table contraction),
   with pad rows/cols re-masked — or, with ``fuse=True``, one
   ``ops.fused_count`` call that contracts each ``row_tile``-row block of
   ``M`` as soon as it is produced and never materializes the full
   ``[n_pad, B]`` neighbor sum (the paper's fine-grained pipeline, §3.2,
   at kernel granularity; see DESIGN.md §11);
4. colorful map count = ``sum_{v, S} C_root[v, S]`` (one column per color
   set of the template's size; the single full-set column when t == k).

Column padding is impl-dependent (``lane``): the Pallas kernels need
128-lane-aligned tables, while the XLA paths run at true table widths —
on CPU/GPU that alone removes the 12.8x waste of padding the k-wide leaf
tables to 128 columns.

Batched colorings: the outer color-coding loop is embarrassingly parallel,
so ``count_fn(plan, batch=B)`` evaluates B independent colorings per jit
call (vmap over the DP), amortizing dispatch and plan overheads across the
batch — the single-device mirror of the paper's multi-node outer loop.

Multi-template counting: :func:`build_multi_counting_plan` compiles a whole
template family into one deduplicated :class:`TemplateDag` (DESIGN.md §14)
and :func:`colorful_map_count_many` runs it as ONE table program per
coloring — every canonically-unique subtree table is computed once and
every template root reads its own entry, so counting N related templates
costs the unique-table work, not N independent chains.

The DP uses ``d = 1`` in the recurrence and divides the final count by
``|Aut(T)|`` once — equivalent to the paper's per-step over-counting factor
(see DESIGN.md §1) and exactly testable against the brute-force oracle.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from .graphs import Graph, edge_list
from .table_program import (
    leaf_table,
    local_node_fn,
    build_node_tables,
    root_count,
    run_table_program,
)
from .templates import (
    PartitionChain,
    TemplateDag,
    Tree,
    automorphism_count,
    compile_templates,
    partition_tree,
)

__all__ = [
    "CountingPlan",
    "MultiCountingPlan",
    "build_counting_plan",
    "build_multi_counting_plan",
    "colorful_map_count",
    "colorful_map_count_many",
    "count_fn",
    "count_fn_many",
    "plan_sample_fn",
    "multi_sample_fn",
    "copy_scale",
]


def copy_scale(k: int, t: int, aut: int) -> float:
    """Per-iteration estimator scale for a size-``t`` template counted with
    ``k`` colors: ``k^t (k-t)! / k! / |Aut|`` — the inverse probability that
    the t image vertices of a copy draw pairwise-distinct colors, divided by
    the rooted-map over-count.  Reduces to the paper's ``k^k / k! / |Aut|``
    when ``t == k``."""
    return (k ** t) * math.factorial(k - t) / math.factorial(k) / aut


@dataclasses.dataclass(frozen=True)
class CountingPlan:
    """Static data for jit: graph plan + per-node combine tables."""

    tree: Tree
    chain: PartitionChain
    k: int  # color budget (== tree.n unless n_colors widened it)
    n: int
    n_pad: int
    aut: int
    spmm_plan: ops.SpmmPlan
    combine: Dict[int, ops.CombineTables]  # internal node index -> tables
    widths: Dict[int, int]  # node index -> padded table width
    impl: str = "auto"
    #: route each internal node through the fused SpMM->combine path
    fuse: bool = False
    #: column padding multiple the tables were built with (128 = pallas)
    lane: int = 128

    @property
    def scale(self) -> float:
        """Maps the colorful map count to the copy estimate."""
        return copy_scale(self.k, self.tree.n, self.aut)


@dataclasses.dataclass(frozen=True)
class MultiCountingPlan:
    """Static data for one-pass family counting: shared graph plan + the
    deduplicated template DAG's combine tables."""

    templates: Tuple[Tree, ...]
    dag: TemplateDag
    k: int  # shared color budget (max template size unless widened)
    n: int
    n_pad: int
    auts: Tuple[int, ...]
    spmm_plan: ops.SpmmPlan
    combine: Dict[int, ops.CombineTables]
    widths: Dict[int, int]
    impl: str = "auto"
    fuse: bool = False
    lane: int = 128

    @property
    def num_templates(self) -> int:
        return len(self.templates)

    @property
    def scales(self) -> Tuple[float, ...]:
        """Per-template copy-estimate scales (all against the shared k)."""
        return tuple(
            copy_scale(self.k, t.n, a) for t, a in zip(self.templates, self.auts)
        )


def _build_spmm(g, spmm_kind, tile_size, block_size):
    rows, cols = edge_list(g)
    return ops.build_spmm_plan(
        rows, cols, g.n, kind=spmm_kind, tile_size=tile_size, block_size=block_size
    )


def _resolve_lane(lane, impl):
    if lane is None:
        # Pallas kernels need 128-lane tables; XLA runs at true widths.
        lane = 128 if ops.resolve_impl(impl) == "pallas" else 1
    return lane


def build_counting_plan(
    g: Graph,
    tree: Tree,
    *,
    root: int = 0,
    spmm_kind: str = "edges",
    impl: str = "auto",
    fuse: bool = False,
    tile_size: int = 128,
    block_size: int = 128,
    lane: Optional[int] = None,
    n_colors: Optional[int] = None,
) -> CountingPlan:
    """``n_colors`` widens the color budget past the template size (used to
    compare single-template runs against a family counted with shared k)."""
    chain = partition_tree(tree, root=root)
    k = n_colors if n_colors is not None else tree.n
    if k < tree.n:
        raise ValueError(f"n_colors={k} is smaller than the template ({tree.n})")
    plan = _build_spmm(g, spmm_kind, tile_size, block_size)
    lane = _resolve_lane(lane, impl)
    combine, widths = build_node_tables(chain, k, lane=lane)
    return CountingPlan(
        tree=tree,
        chain=chain,
        k=k,
        n=g.n,
        n_pad=plan.n_pad,
        aut=automorphism_count(tree),
        spmm_plan=plan,
        combine=combine,
        widths=widths,
        impl=impl,
        fuse=fuse,
        lane=lane,
    )


def build_multi_counting_plan(
    g: Graph,
    templates: Sequence,
    *,
    roots: Optional[Sequence[int]] = None,
    spmm_kind: str = "edges",
    impl: str = "auto",
    fuse: bool = False,
    tile_size: int = 128,
    block_size: int = 128,
    lane: Optional[int] = None,
    n_colors: Optional[int] = None,
) -> MultiCountingPlan:
    """One plan for a whole template family: compile the set into a shared
    :class:`TemplateDag` and build each unique node's combine tables once."""
    dag = compile_templates(templates, n_colors=n_colors, roots=roots)
    plan = _build_spmm(g, spmm_kind, tile_size, block_size)
    lane = _resolve_lane(lane, impl)
    combine, widths = build_node_tables(dag, dag.k, lane=lane)
    return MultiCountingPlan(
        templates=dag.templates,
        dag=dag,
        k=dag.k,
        n=g.n,
        n_pad=plan.n_pad,
        auts=tuple(automorphism_count(t) for t in dag.templates),
        spmm_plan=plan,
        combine=combine,
        widths=widths,
        impl=impl,
        fuse=fuse,
        lane=lane,
    )


def _program_counts(plan, program, coloring: jax.Array) -> tuple:
    """Run ``program`` on one coloring; per-root colorful map counts."""
    n_pad = plan.n_pad
    row_mask = (jnp.arange(n_pad) < plan.n).astype(jnp.float32)[:, None]
    leaf = leaf_table(coloring, ops.pad_to(plan.k, plan.lane), row_mask)
    node_fn = local_node_fn(plan.spmm_plan, row_mask, impl=plan.impl, fuse=plan.fuse)
    return run_table_program(
        program, plan.combine, leaf, row_mask, node_fn, root_fn=root_count
    )


def colorful_map_count(plan: CountingPlan, coloring: jax.Array) -> jax.Array:
    """Number of colorful rooted embedding maps for one coloring.

    ``coloring``: int32 [n_pad] (entries past plan.n ignored).
    Differentiable-free pure function of the coloring; jit with
    ``jax.jit(functools.partial(colorful_map_count, plan))`` or use
    :func:`count_fn`.  The DP itself is the shared table program
    (:mod:`repro.core.table_program`) with the ``local`` (whole-graph SpMM)
    neighbor-sum strategy.
    """
    return _program_counts(plan, plan.chain, coloring)[0]


def colorful_map_count_many(
    plan: MultiCountingPlan, coloring: jax.Array
) -> jax.Array:
    """Per-template colorful map counts ``[num_templates]`` for ONE coloring.

    One pass over the deduplicated DAG: shared subtree tables are computed
    once; each template root reduces to its own count.
    """
    return jnp.stack(_program_counts(plan, plan.dag, coloring))


def count_fn(plan: CountingPlan, batch: Optional[int] = None):
    """Jitted per-iteration counter.

    ``batch=None``: returns ``f(key) -> (maps, estimate)`` scalars for one
    coloring (the original contract).  ``batch=B``: returns
    ``f(key) -> (maps[B], estimates[B])`` evaluating B independent colorings
    in one jit call — the colorings are embarrassingly parallel, so vmapping
    the DP amortizes dispatch and SpMM-plan constant overheads across the
    batch.
    """
    if batch is None:

        def f(key: jax.Array):
            coloring = jax.random.randint(
                key, (plan.n_pad,), 0, plan.k, dtype=jnp.int32
            )
            maps = colorful_map_count(plan, coloring)
            return maps, maps * plan.scale

        return jax.jit(f)

    def fb(key: jax.Array):
        colorings = jax.random.randint(
            key, (batch, plan.n_pad), 0, plan.k, dtype=jnp.int32
        )
        maps = jax.vmap(lambda c: colorful_map_count(plan, c))(colorings)
        return maps, maps * plan.scale

    return jax.jit(fb)


def count_fn_many(plan: MultiCountingPlan, batch: Optional[int] = None):
    """Jitted family counter: ``f(key) -> (maps, estimates)`` with shapes
    ``[R]`` (``batch=None``) or ``[B, R]`` — the same key-derived colorings
    as :func:`count_fn` with ``n_colors=plan.k``, so a family run and a
    per-template run from the same key see identical colorings."""
    scales = jnp.asarray(plan.scales)

    if batch is None:

        def f(key: jax.Array):
            coloring = jax.random.randint(
                key, (plan.n_pad,), 0, plan.k, dtype=jnp.int32
            )
            maps = colorful_map_count_many(plan, coloring)
            return maps, maps * scales

        return jax.jit(f)

    def fb(key: jax.Array):
        colorings = jax.random.randint(
            key, (batch, plan.n_pad), 0, plan.k, dtype=jnp.int32
        )
        maps = jax.vmap(lambda c: colorful_map_count_many(plan, c))(colorings)
        return maps, maps * scales[None, :]

    return jax.jit(fb)


def _cached_sampler(make_fn):
    cache: Dict[int, object] = {}

    def sample(key: jax.Array, batch: int) -> np.ndarray:
        f = cache.get(batch)
        if f is None:
            f = cache[batch] = make_fn(batch)
        _, est = f(key)
        return np.asarray(est, np.float64)

    return sample


def plan_sample_fn(plan: CountingPlan):
    """Adapt a single-device plan to the backend ``sample_fn`` protocol.

    The protocol (shared with the distributed backend and consumed by
    :func:`repro.core.estimator.estimate_counts`) is
    ``sample_fn(key, batch) -> float64 [batch]`` copy estimates for ``batch``
    independent colorings derived from ``key``.  Compiled ``count_fn``
    closures are cached per batch size so repeated calls reuse the jit cache.
    """
    sample = _cached_sampler(lambda b: count_fn(plan, batch=b))

    def sample1(key: jax.Array, batch: int) -> np.ndarray:
        return sample(key, batch).reshape(-1)

    return sample1


def multi_sample_fn(plan: MultiCountingPlan):
    """The family variant of the protocol: ``sample_fn(key, batch) ->
    float64 [batch, num_templates]`` per-coloring copy estimates, consumed
    by :func:`repro.core.estimator.estimate_counts_many`."""
    sample = _cached_sampler(lambda b: count_fn_many(plan, batch=b))

    def sample_many(key: jax.Array, batch: int) -> np.ndarray:
        return sample(key, batch).reshape(batch, plan.num_templates)

    return sample_many
