"""Distributed color-coding under ``shard_map`` — the paper's Algorithms 2/3.

The graph is vertex-partitioned in contiguous blocks over the ``data`` mesh
axis (combine with :func:`repro.core.graphs.relabel_random` for the paper's
random partition).  Count tables are row-sharded alongside.  For each
internal partition node the neighbor sum needs remote rows of the child
table; four exchange modes are provided:

``alltoall``  (paper: Naive)
    Compact per-pair request lists exchanged with one fused
    ``lax.all_to_all``; all P received chunks are materialized before any
    compute (peak memory O(P * R * B) — Eq. 7's pathology).

``pipeline``  (paper: Pipeline, Algorithm 3)
    The same compact requests, but sent with W = ceil((P-1)/g) grouped
    ``ppermute`` steps; each step's transfer overlaps the previous chunk's
    segment-sum (peak memory O(g * R * B) — Eq. 12).

``adaptive``  (paper: Adaptive)
    Per-sub-template trace-time choice between the two via the Hockney
    model + computation intensity (comm.adaptive; the paper's |T_i|
    switch).

``ring``  (beyond paper)
    Shift-by-one relay of whole table shards in a ``fori_loop``
    (O(1) program size in P).  Trades the compact request lists for relayed
    full shards; this is what lets the engine shard over hundreds of
    devices where the unrolled direct-send schedule would explode compile
    time.  See DESIGN.md §4.

Iteration parallelism: the outer color-coding loop is embarrassingly
parallel, so independent colorings shard over a second mesh axis
(``iter_axis``), mirroring the paper's multi-node outer loop.

Coloring sampling runs **on-device** when the key-based contract is used
(``make_count_fn(..., keyed=True)`` / :func:`keyed_sample_fn`): each shard
folds its data-axis index into the iteration key and draws only its own
rows, giving the distributed backend the same ``f(key)`` interface as the
single-device engine (see DESIGN.md §12).  Host-side colorings via
:func:`shard_coloring` remain supported for fixed-coloring parity tests.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import (
    V5E_ICI,
    HockneyModel,
    choose_mode,
    fused_exchange,
    grouped_exchange,
    ring_allgather_overlap,
)
from repro.compat import shard_map
from repro.kernels import ops
from .graphs import Graph
from .templates import PartitionChain, Tree, automorphism_count, partition_tree

__all__ = [
    "DistributedPlan",
    "build_distributed_plan",
    "make_count_fn",
    "keyed_sample_fn",
    "shard_coloring",
]


@dataclasses.dataclass(frozen=True)
class DistributedPlan:
    tree: Tree
    chain: PartitionChain
    k: int
    n: int
    num_shards: int
    shard_size: int  # vertices per shard (last shard may be ragged)
    n_loc_pad: int  # padded local rows; row `shard_size` is the zero sentinel
    r_pad: int  # padded request-list length
    max_e: int  # padded per-bucket edge count
    aut: int
    combine: Dict[int, ops.CombineTables]
    widths: Dict[int, int]
    # host-global arrays; sharded over dim 0 by the data axis:
    bucket_rows: jax.Array  # [P, P, max_e] int32: local dst row
    bucket_cols_local: jax.Array  # [P, P, max_e] int32: src-local row (ring)
    bucket_cols_compact: jax.Array  # [P, P, max_e] int32: request slot (a2a)
    send_idx: jax.Array  # [P, P, r_pad] int32: rows this shard sends to q
    bucket_counts: np.ndarray  # [P, P] true bucket sizes (diagnostics)

    @property
    def scale(self) -> float:
        k = self.k
        return (k ** k) / math.factorial(k) / self.aut


def build_distributed_plan(
    g: Graph,
    tree: Tree,
    num_shards: int,
    *,
    root: int = 0,
    tile_size: int = 128,
) -> DistributedPlan:
    from .graphs import edge_list

    Pn = num_shards
    chain = partition_tree(tree, root=root)
    k = tree.n
    shard_size = (g.n + Pn - 1) // Pn
    n_loc_pad = ops.pad_to(shard_size + 1, 128)
    sentinel = shard_size

    rows, cols = edge_list(g)
    p_of = rows // shard_size
    q_of = cols // shard_size
    counts = np.zeros((Pn, Pn), np.int64)
    np.add.at(counts, (p_of, q_of), 1)
    max_e = int(counts.max(initial=0))
    max_e = max(ops.pad_to(max_e, tile_size), tile_size)

    b_rows = np.full((Pn, Pn, max_e), sentinel, np.int32)
    b_cols = np.full((Pn, Pn, max_e), sentinel, np.int32)
    key = p_of * Pn + q_of
    order = np.argsort(key, kind="stable")
    skey = key[order]
    group_start = np.zeros(Pn * Pn, np.int64)
    np.cumsum(np.bincount(skey, minlength=Pn * Pn)[:-1], out=group_start[1:])
    pos = np.arange(len(order)) - group_start[skey]
    fr = b_rows.reshape(Pn * Pn, max_e)
    fc = b_cols.reshape(Pn * Pn, max_e)
    fr[skey, pos] = (rows[order] - p_of[order] * shard_size).astype(np.int32)
    fc[skey, pos] = (cols[order] - q_of[order] * shard_size).astype(np.int32)

    # sort each bucket by dst row (keeps segment ids grouped; cheap locality)
    dst_order = np.argsort(fr, axis=1, kind="stable")
    fr = np.take_along_axis(fr, dst_order, axis=1)
    fc = np.take_along_axis(fc, dst_order, axis=1)
    b_rows = fr.reshape(Pn, Pn, max_e)
    b_cols = fc.reshape(Pn, Pn, max_e)

    # compact request lists: for bucket (p, q), the distinct src-local rows
    # (the counts device p requests from device q — paper's C_{q,p})
    r_len = 0
    uniq_lists = {}
    inv_store = np.zeros((Pn, Pn, max_e), np.int32)
    for pp in range(Pn):
        for qq in range(Pn):
            uniq, inv = np.unique(b_cols[pp, qq], return_inverse=True)
            uniq_lists[(pp, qq)] = uniq
            inv_store[pp, qq] = inv.astype(np.int32)
            r_len = max(r_len, len(uniq))
    r_pad = ops.pad_to(r_len, 128)
    send_idx = np.full((Pn, Pn, r_pad), sentinel, np.int32)
    for pp in range(Pn):
        for qq in range(Pn):
            u = uniq_lists[(pp, qq)]
            # device q sends rows u to device p: stored at send_idx[q, p]
            send_idx[qq, pp, : len(u)] = u

    combine: Dict[int, ops.CombineTables] = {}
    widths: Dict[int, int] = {}
    for i, nd in enumerate(chain.nodes):
        if nd.is_leaf:
            widths[i] = ops.pad_to(k, 128)
        else:
            t1 = chain.nodes[nd.left].size
            t2 = chain.nodes[nd.right].size
            tables = ops.build_combine_tables(k, t1, t2)
            combine[i] = tables
            widths[i] = tables.s_pad

    return DistributedPlan(
        tree=tree,
        chain=chain,
        k=k,
        n=g.n,
        num_shards=Pn,
        shard_size=shard_size,
        n_loc_pad=n_loc_pad,
        r_pad=r_pad,
        max_e=max_e,
        aut=automorphism_count(tree),
        combine=combine,
        widths=widths,
        bucket_rows=jnp.asarray(b_rows),
        bucket_cols_local=jnp.asarray(b_cols),
        bucket_cols_compact=jnp.asarray(inv_store),
        send_idx=jnp.asarray(send_idx),
        bucket_counts=counts,
    )


def abstract_plan(
    num_vertices: int,
    num_edges: int,
    tree: Tree,
    num_shards: int,
    *,
    root: int = 0,
    skew_headroom: float = 3.0,
    compact: bool = True,  # False (ring mode): compact-exchange arrays minimal
) -> DistributedPlan:
    """Shape-only plan for dry-run lowering at paper-scale graph sizes.

    Bucket/request sizes follow the paper's Eq. 5 expectation
    E[bucket] = |E_directed| / P^2 with a skew headroom factor (the padding a
    real relabeled-random partition needs); array fields are
    ShapeDtypeStructs — nothing is allocated.
    """
    Pn = num_shards
    chain = partition_tree(tree, root=root)
    k = tree.n
    shard_size = (num_vertices + Pn - 1) // Pn
    n_loc_pad = ops.pad_to(shard_size + 1, 128)
    avg_bucket = 2.0 * num_edges / (Pn * Pn)
    max_e = ops.pad_to(int(avg_bucket * skew_headroom) + 128, 128)
    r_pad = ops.pad_to(min(max_e, shard_size + 1), 128)

    combine: Dict[int, ops.CombineTables] = {}
    widths: Dict[int, int] = {}
    for i, nd in enumerate(chain.nodes):
        if nd.is_leaf:
            widths[i] = ops.pad_to(k, 128)
        else:
            t1 = chain.nodes[nd.left].size
            t2 = chain.nodes[nd.right].size
            tables = ops.build_combine_tables(k, t1, t2)
            combine[i] = tables
            widths[i] = tables.s_pad

    s = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)
    cmp_e = max_e if compact else 128
    if not compact:
        r_pad = 128
    return DistributedPlan(
        tree=tree,
        chain=chain,
        k=k,
        n=num_vertices,
        num_shards=Pn,
        shard_size=shard_size,
        n_loc_pad=n_loc_pad,
        r_pad=r_pad,
        max_e=max_e,
        aut=automorphism_count(tree),
        combine=combine,
        widths=widths,
        bucket_rows=s(Pn, Pn, max_e),
        bucket_cols_local=s(Pn, Pn, max_e),
        bucket_cols_compact=s(Pn, Pn, cmp_e),
        send_idx=s(Pn, Pn, r_pad),
        bucket_counts=np.zeros((Pn, Pn), np.int64),
    )


def shard_coloring(plan: DistributedPlan, coloring: np.ndarray) -> np.ndarray:
    """Global coloring [n] -> sharded layout [P, n_loc_pad].

    One pad+reshape: the global array is zero-padded to ``P * shard_size``
    (covering the ragged last shard), viewed as ``[P, shard_size]``, and
    dropped into the first ``shard_size`` columns of the padded layout.
    Kept exported for tests and host-side callers that bring their own
    colorings; the keyed path (``make_count_fn(..., keyed=True)``) samples
    directly on-device and never builds this layout.
    """
    Pn, ss = plan.num_shards, plan.shard_size
    coloring = np.asarray(coloring, np.int32).reshape(-1)[: plan.n]
    out = np.zeros((Pn, plan.n_loc_pad), np.int32)
    padded = np.zeros(Pn * ss, np.int32)
    padded[: plan.n] = coloring
    out[:, :ss] = padded.reshape(Pn, ss)
    return out


def _node_mode(
    plan: DistributedPlan,
    node_index: int,
    mode: str,
    hockney: HockneyModel,
    group_factor: int,
) -> str:
    if mode != "adaptive":
        return mode
    nd = plan.chain.nodes[node_index]
    tbl = plan.combine[node_index]
    b_width = plan.widths[nd.right]
    Pn = plan.num_shards
    total_bytes = (Pn - 1) * plan.r_pad * b_width * 4
    spmm_flops = 2.0 * Pn * plan.max_e * b_width
    combine_flops = 2.0 * plan.n_loc_pad * tbl.s * tbl.j
    picked, _ = choose_mode(
        total_bytes, spmm_flops + combine_flops, Pn, hockney, group_factor
    )
    return "alltoall" if picked == "alltoall" else "pipeline"


def make_count_fn(
    plan: DistributedPlan,
    mesh: jax.sharding.Mesh,
    *,
    mode: str = "adaptive",
    data_axis: str = "data",
    iter_axis: Optional[str] = None,
    group_factor: int = 1,
    impl: str = "xla",
    hockney: HockneyModel = V5E_ICI,
    return_raw: bool = False,
    keyed: bool = False,
):
    """Build the jitted distributed count function.

    Default contract: ``f(colorings) -> counts`` where ``colorings`` is int32
    ``[I, P, n_loc_pad]`` (I = number of parallel coloring iterations,
    sharded over ``iter_axis`` when given) and ``counts`` is float32 [I]
    (colorful map counts; multiply by ``plan.scale`` for copy estimates).

    ``keyed=True``: the same key-based contract as the single-device engine —
    ``f(keys) -> counts`` where ``keys`` is a jax PRNG key array ``[I]`` (or
    raw uint32 key data ``[I, 2]``).  Colorings are sampled **on-device**
    inside the shard_map: each shard folds its ``data``-axis index into the
    iteration key and draws its own ``[n_loc_pad]`` slice with
    ``jax.random.randint`` — per-vertex colors stay iid uniform over ``k``
    while no ``[n]`` host array, numpy loop, or host->device coloring
    transfer exists at all.

    ``return_raw=True`` (dry-run): returns ``(jitted_fn, structs, in_shard)``
    where the fn takes all plan arrays as explicit arguments so the plan may
    hold ShapeDtypeStructs (see :func:`abstract_plan`); ``iter_axis`` may be
    a tuple of mesh axes.
    """
    assert not (keyed and return_raw), "keyed and return_raw are exclusive"
    Pn = plan.num_shards
    n_loc_pad = plan.n_loc_pad
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assert axis_sizes[data_axis] == Pn, (axis_sizes, Pn)

    node_modes = {
        i: _node_mode(plan, i, mode, hockney, group_factor)
        for i, nd in enumerate(plan.chain.nodes)
        if not nd.is_leaf
    }

    edge_chunk = 1 << 19  # bound the [chunk, B] gather (paper §3.2.1)

    def consume_factory(bucket_rows, bucket_cols, n_rows):
        """bucket_* are this device's [P, max_e]; returns consume(acc, chunk, src)."""

        def consume(acc, chunk, src):
            ce = jax.lax.dynamic_index_in_dim(bucket_cols, src, 0, keepdims=False)
            re = jax.lax.dynamic_index_in_dim(bucket_rows, src, 0, keepdims=False)
            e = ce.shape[0]
            if e <= edge_chunk:
                gathered = jnp.take(chunk, ce, axis=0)
                return acc + jax.ops.segment_sum(gathered, re, num_segments=n_rows)

            # big buckets: chunked scatter-add keeps the gather bounded
            from repro.comm.ring import _pvary_like

            acc = _pvary_like(acc, chunk)
            n_chunks = (e + edge_chunk - 1) // edge_chunk
            pad = n_chunks * edge_chunk - e
            ce_p = jnp.pad(ce, (0, pad), constant_values=chunk.shape[0] - 1)
            re_p = jnp.pad(re, (0, pad), constant_values=n_rows - 1)

            def body(i, a):
                cs = jax.lax.dynamic_slice_in_dim(ce_p, i * edge_chunk, edge_chunk)
                rs = jax.lax.dynamic_slice_in_dim(re_p, i * edge_chunk, edge_chunk)
                return a.at[rs].add(jnp.take(chunk, cs, axis=0))

            return jax.lax.fori_loop(0, n_chunks, body, acc)

        return consume

    def local_count(coloring, b_rows, b_cols_loc, b_cols_cmp, s_idx):
        """One coloring iteration on this device's shard; returns partial sum."""
        row_mask = (jnp.arange(n_loc_pad) < plan.shard_size).astype(jnp.float32)[:, None]
        k_pad = ops.pad_to(plan.k, 128)
        leaf = jax.nn.one_hot(coloring, k_pad, dtype=jnp.float32) * row_mask
        tables: Dict[int, jax.Array] = {}
        for i, nd in enumerate(plan.chain.nodes):
            if nd.is_leaf:
                tables[i] = leaf
                continue
            tbl = plan.combine[i]
            c_right = tables[nd.right]
            init = jnp.zeros((n_loc_pad, c_right.shape[1]), c_right.dtype)
            nm = node_modes[i]
            if nm == "ring":
                consume = consume_factory(b_rows, b_cols_loc, n_loc_pad)
                m = ring_allgather_overlap(c_right, data_axis, consume, init)
            else:
                consume = consume_factory(b_rows, b_cols_cmp, n_loc_pad)
                chunks = jnp.take(c_right, s_idx, axis=0)  # [P, r_pad, B]
                if nm == "alltoall":
                    m = fused_exchange(chunks, data_axis, consume, init)
                else:
                    m = grouped_exchange(
                        chunks,
                        data_axis,
                        consume,
                        init,
                        group_factor=group_factor,
                    )
            m = m * row_mask
            out = ops.color_combine(tables[nd.left], m, tbl, impl=impl)
            col_mask = (jnp.arange(out.shape[1]) < tbl.s).astype(jnp.float32)[None, :]
            tables[i] = out * row_mask * col_mask
            del tables[nd.right]
            del tables[nd.left]
        root = tables[plan.chain.root_index]
        return jnp.sum(root[:, 0])

    def sharded_fn(colorings, b_rows, b_cols_loc, b_cols_cmp, s_idx):
        # local shapes: colorings [I_loc, 1, n_loc_pad]; buckets [1, P, ...]
        colorings = colorings[:, 0]
        b_rows_l = b_rows[0]
        b_cols_loc_l = b_cols_loc[0]
        b_cols_cmp_l = b_cols_cmp[0]
        s_idx_l = s_idx[0]
        f = lambda col: local_count(col, b_rows_l, b_cols_loc_l, b_cols_cmp_l, s_idx_l)
        partials = jax.vmap(f)(colorings)  # [I_loc]
        return jax.lax.psum(partials, data_axis)

    def sharded_fn_keyed(key_data, b_rows, b_cols_loc, b_cols_cmp, s_idx):
        # local shapes: key_data [I_loc, 2] uint32; buckets [1, P, ...]
        b_rows_l = b_rows[0]
        b_cols_loc_l = b_cols_loc[0]
        b_cols_cmp_l = b_cols_cmp[0]
        s_idx_l = s_idx[0]
        p = jax.lax.axis_index(data_axis)

        def one(kd):
            k = jax.random.fold_in(jax.random.wrap_key_data(kd), p)
            col = jax.random.randint(k, (n_loc_pad,), 0, plan.k, dtype=jnp.int32)
            return local_count(col, b_rows_l, b_cols_loc_l, b_cols_cmp_l, s_idx_l)

        partials = jax.vmap(one)(key_data)  # [I_loc]
        return jax.lax.psum(partials, data_axis)

    iter_spec = P(iter_axis) if iter_axis else P()
    lead_spec = (
        P(iter_axis) if keyed
        else (P(iter_axis, data_axis) if iter_axis else P(None, data_axis))
    )
    in_specs = (
        lead_spec,
        P(data_axis),
        P(data_axis),
        P(data_axis),
        P(data_axis),
    )
    mapped = shard_map(
        sharded_fn_keyed if keyed else sharded_fn,
        mesh=mesh, in_specs=in_specs, out_specs=iter_spec,
    )

    if return_raw:
        from jax.sharding import NamedSharding

        iter_size = 1
        for ax in (iter_axis if isinstance(iter_axis, tuple) else (iter_axis,)):
            if ax:
                iter_size *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
        as_struct = lambda a: jax.ShapeDtypeStruct(a.shape, jnp.int32)
        structs = (
            jax.ShapeDtypeStruct((iter_size, Pn, n_loc_pad), jnp.int32),
            as_struct(plan.bucket_rows),
            as_struct(plan.bucket_cols_local),
            as_struct(plan.bucket_cols_compact),
            as_struct(plan.send_idx),
        )
        in_shard = tuple(NamedSharding(mesh, s) for s in in_specs)
        fn = jax.jit(mapped, in_shardings=in_shard)
        return fn, structs, in_shard

    @jax.jit
    def f(colorings):
        return mapped(
            colorings,
            plan.bucket_rows,
            plan.bucket_cols_local,
            plan.bucket_cols_compact,
            plan.send_idx,
        )

    if not keyed:
        return f

    def f_keyed(keys):
        keys = jnp.asarray(keys)
        if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
            keys = jax.random.key_data(keys)
        return f(keys.astype(jnp.uint32))

    return f_keyed


def keyed_sample_fn(plan: DistributedPlan, mesh: jax.sharding.Mesh, **kw):
    """Adapt a distributed plan to the backend ``sample_fn`` protocol.

    Returns ``sample_fn(key, batch) -> float64 [batch]`` copy estimates —
    the same contract :func:`repro.core.count_engine.plan_sample_fn` gives
    the single-device engine, so :func:`repro.core.estimator.estimate_counts`
    (and anything else speaking the protocol) runs unmodified on top of the
    shard_map backend.  ``kw`` is forwarded to :func:`make_count_fn`
    (mode/group_factor/axes/...).  Each call evaluates ``batch`` coloring
    iterations in one jitted dispatch; jit caches per distinct batch size.
    When colorings shard over ``iter_axis`` the key count is rounded up to
    a multiple of the axis size (shard_map divisibility) and the surplus
    estimates are discarded.
    """
    f = make_count_fn(plan, mesh, keyed=True, **kw)
    iter_axis = kw.get("iter_axis")
    isz = 1
    if iter_axis:
        isz = dict(zip(mesh.axis_names, mesh.devices.shape))[iter_axis]

    def sample(key: jax.Array, batch: int) -> np.ndarray:
        b = -(-batch // isz) * isz
        counts = f(jax.random.split(key, b))
        return np.asarray(counts, np.float64).reshape(-1)[:batch] * plan.scale

    return sample
