"""Distributed color-coding under ``shard_map`` — the paper's Algorithms 2/3.

The graph is vertex-partitioned in contiguous blocks over the ``data`` mesh
axis (combine with :func:`repro.core.graphs.relabel_random` for the paper's
random partition).  Count tables are row-sharded alongside.  The DP itself
is the shared table program (:mod:`repro.core.table_program`); this module
contributes the *exchange* neighbor-sum strategy: for each internal
partition node the neighbor sum needs remote rows of the child table, and
four exchange modes provide them:

``alltoall``  (paper: Naive)
    Compact per-pair request lists exchanged with one fused
    ``lax.all_to_all``; all P received chunks are materialized before any
    compute (peak memory O(P * R * B) — Eq. 7's pathology).  Because the
    whole buffer exists anyway, the consume is one call of the SAME
    edge-tile / fused SpMM->combine kernels as the in-core engine
    (``ops.spmm_slabs`` / ``ops.fused_count_slabs``) over the concatenated
    ``[P * r_pad, B]`` buffer — ``impl="pallas"`` and ``fuse=True`` route
    through ``kernels/spmm_edgetile.py`` / ``kernels/fused_count.py``.

``pipeline``  (paper: Pipeline, Algorithm 3)
    The same compact requests, but sent with W = ceil((P-1)/g) grouped
    ``ppermute`` steps; each step's transfer overlaps the previous chunk's
    consume (peak memory O(g * R * B) — Eq. 12).

``adaptive``  (paper: Adaptive)
    Per-sub-template trace-time choice between the two via the Hockney
    model + computation intensity (comm.adaptive; the paper's |T_i|
    switch).

``ring``  (beyond paper)
    Shift-by-one relay of whole table shards in a ``fori_loop``
    (O(1) program size in P).  Trades the compact request lists for relayed
    full shards; this is what lets the engine shard over hundreds of
    devices where the unrolled direct-send schedule would explode compile
    time.  See DESIGN.md §4.

**Tiled buckets (§3.3).**  The per-(shard, shard) edge buckets are stored
as fixed-size ``bucket_tile``-edge tiles with CSR-style offsets
(``tile_off[p, q]``), so plan memory is O(E + tiles) — independent of the
largest bucket — and every incremental consume task is one uniform tile:
a gather of ``bucket_tile`` chunk rows plus one bounded scatter-add,
regardless of degree skew.  (The seed layout padded every bucket to the
global max, [P, P, max_e]: memory and per-chunk work scaled with skew.)
With ``fuse=True`` the incremental modes exploit the combine's linearity
in ``M`` to accumulate each tile's contribution **directly into the output
table** — the full ``[n_loc_pad, B]`` neighbor sum never exists, the
paper's fine-grained pipeline (§3.2) stretched across exchange chunks.

**Compacted exchange (§15).**  With ``compact=True`` the plan probes each
node table's active-row density at build time (``core.frontier``) and,
for sufficiently sparse exchanged tables, ships only active rows:
capacity-padded ``[rc, B+1]`` per-peer slabs (rows + a bitcast slot
column) on alltoall/pipeline and ``[cap, B+1]`` compacted whole-shard
relays on ring.  The receiver scatters into the zero-initialized dense
buffer, so the tiled consume below is byte-for-byte the dense code, and a
psum'd overflow flag re-dispatches the dense twin when a static capacity
is exceeded — bit-exact either way.

**Narrow wire (§18).**  Counts are nonnegative integers held in float32,
so with ``wire_dtype="int16"``/``"int8"`` every exchange payload ships at
integer width — 2x/4x less wire than float32 — with a per-slab saturation
flag riding the same speculate-check-redispatch contract: on overflow the
batch re-runs one rung up the int8 -> int16 -> float32 -> dense ladder,
bit-exact always.  Compacted slabs replace their float32 slot column with
bit-packed activity-bitmap columns of the wire dtype (``comm.compress``);
the receiver re-derives the slot indices deterministically.  This
composes multiplicatively with compaction.

Iteration parallelism: the outer color-coding loop is embarrassingly
parallel, so independent colorings shard over a second mesh axis
(``iter_axis``), mirroring the paper's multi-node outer loop.

Family counting: :func:`build_distributed_plan` accepts a sequence of
templates and compiles them into one shared
:class:`~repro.core.templates.TemplateDag` (DESIGN.md §14) — the count
function then returns per-template count vectors from ONE table-program
pass per coloring, with cross-template subtree tables exchanged and
computed once.

Coloring sampling runs **on-device** when the key-based contract is used
(``make_count_fn(..., keyed=True)`` / :func:`keyed_sample_fn`): each shard
folds its data-axis index into the iteration key and draws only its own
rows, giving the distributed backend the same ``f(key)`` interface as the
single-device engine (see DESIGN.md §12).  Host-side colorings via
:func:`shard_coloring` remain supported for fixed-coloring parity tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.comm import (
    V5E_ICI,
    WIRE_DTYPES,
    WIRE_ESCALATION,
    HockneyModel,
    calibrate,
    choose_mode_full,
    grouped_exchange,
    mask_columns,
    mask_from_columns,
    narrow_cast,
    ring_allgather_overlap,
    widen,
)
from repro.compat import pvary_like, shard_map
from repro.kernels import ops
from repro.testing import faults
from .count_engine import copy_scale
from .frontier import (
    DEFAULT_CAPACITY_FACTOR,
    DEFAULT_DENSITY_THRESHOLD,
    CompactionSpec,
    abstract_compaction,
    chunk_slots,
    compact_combine,
    decode_slots,
    distributed_compaction,
    encode_slots,
    make_frontier_fn,
    node_exchange_bytes,
)
from .colorsets import excluded_color_mask
from .graphs import Graph
from .table_program import (
    BagFns,
    build_node_tables,
    leaf_table,
    root_count,
    run_table_program,
)
from .templates import (
    Template,
    TemplateDag,
    Tree,
    automorphism_count,
    bag_program,
    compile_templates,
    partition_tree,
    program_has_bags,
)

__all__ = [
    "DistributedPlan",
    "build_distributed_plan",
    "make_count_fn",
    "keyed_sample_fn",
    "plan_route_report",
    "shard_coloring",
    "global_coloring",
]


@dataclasses.dataclass(frozen=True)
class DistributedPlan:
    #: the template family (a 1-tuple for single-template plans)
    templates: Tuple[Tree, ...]
    #: the table program: a PartitionChain (single template, the original
    #: contract) or a TemplateDag (family counting, DESIGN.md §14)
    program: object
    k: int
    n: int
    num_shards: int
    shard_size: int  # vertices per shard (last shard may be ragged)
    n_loc_pad: int  # padded local rows; row `shard_size` is the zero sentinel
    r_pad: int  # padded request-list length (slot r_pad-1 always a zero row)
    bucket_tile: int  # §3.3 task size: edges per bucket tile
    num_tiles: int  # T: per-shard tile-array height (uniform across shards)
    slabs_per_block: int  # alltoall slab layout (uniform across shards)
    auts: Tuple[int, ...]  # per-template |Aut|
    combine: Dict[int, ops.CombineTables]
    widths: Dict[int, int]
    # host-global arrays; sharded over dim 0 by the data axis.  The bucket
    # arrays are O(E + tiles): tiles are addressed via CSR offsets, never
    # padded to the largest bucket.
    tile_dst: jax.Array  # [P, T, tile] int32 local dst row (pad: shard_size)
    tile_src_local: jax.Array  # [P, T, tile] int32 src-shard-local row (ring)
    tile_src_compact: jax.Array  # [P, T, tile] int32 request slot (pipeline)
    tile_off: jax.Array  # [P, P+1] int32 CSR tile offsets by src shard
    send_idx: jax.Array  # [P, P, r_pad] int32: rows this shard sends to q
    a2a_slab_dst: jax.Array  # [P, NRB*spb, tile] int32 block-local dst (-1 pad)
    a2a_slab_cols: jax.Array  # [P, NRB*spb, tile] int32 col into [P*r_pad]
    bucket_counts: np.ndarray  # [P, P] true bucket sizes (diagnostics)
    #: active-frontier compaction spec (None = dense; DESIGN.md §15)
    compaction: Optional[CompactionSpec] = None
    #: sharded pinned-apex adjacency [P, n_loc_pad, n] (bag programs only;
    #: DESIGN.md §19) — row v_loc, column x is A[global(v), x]
    pin_adj: Optional[jax.Array] = None

    @property
    def tree(self) -> Tree:
        return self.templates[0]

    @property
    def aut(self) -> int:
        return self.auts[0]

    @property
    def num_templates(self) -> int:
        return len(self.templates)

    @property
    def is_multi(self) -> bool:
        """Family plans return per-template count vectors; single-template
        plans keep the original scalar-per-iteration contract."""
        return isinstance(self.program, TemplateDag)

    @property
    def scale(self) -> float:
        return copy_scale(self.k, self.templates[0].n, self.auts[0])

    @property
    def scales(self) -> Tuple[float, ...]:
        return tuple(copy_scale(self.k, t.n, a) for t, a in zip(self.templates, self.auts))

    @property
    def device_arrays(self) -> Tuple[jax.Array, ...]:
        """The per-shard plan arrays, in ``make_count_fn`` argument order."""
        base = (
            self.tile_dst,
            self.tile_src_local,
            self.tile_src_compact,
            self.tile_off,
            self.send_idx,
            self.a2a_slab_dst,
            self.a2a_slab_cols,
        )
        if self.pin_adj is not None:
            base = base + (self.pin_adj,)
        return base


def _resolve_program(tree, root: int, n_colors: Optional[int]):
    """One template -> its PartitionChain; a family -> the shared DAG.

    Returns ``(program, templates, k)``; ``n_colors`` widens the color
    budget past the (largest) template size.
    """
    if isinstance(tree, Template) and tree.is_tree:
        tree = tree.as_tree()
    if isinstance(tree, Tree):
        k = n_colors if n_colors is not None else tree.n
        if k < tree.n:
            raise ValueError(f"n_colors={k} is smaller than the template ({tree.n})")
        return partition_tree(tree, root=root), (tree,), k
    if isinstance(tree, Template):
        prog = bag_program(tree, n_colors=n_colors)
        return prog, (tree,), prog.k
    dag = compile_templates(tree, n_colors=n_colors)
    return dag, dag.templates, dag.k


def build_distributed_plan(
    g: Graph,
    tree,
    num_shards: int,
    *,
    root: int = 0,
    bucket_tile: int = 128,
    n_colors: Optional[int] = None,
    compact: bool = False,
    density_threshold: float = DEFAULT_DENSITY_THRESHOLD,
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR,
    probes: int = 2,
) -> DistributedPlan:
    """``tree`` is a single :class:`Tree` (original contract) or a sequence
    of trees / template names — a family compiled into one shared
    :class:`TemplateDag` counted in a single pass per coloring.

    ``compact=True`` probes per-node table densities at build time
    (DESIGN.md §15) and, for every exchanged table below
    ``density_threshold``, ships only its active rows: capacity-padded
    per-peer slabs plus an index column on alltoall/pipeline, compacted
    whole-shard relays on ring — shrinking the wire volume of all four
    modes by the measured sparsity, with a bit-exact dense fallback on
    capacity overflow."""
    from .graphs import edge_list

    Pn = num_shards
    program, templates, k = _resolve_program(tree, root, n_colors)
    shard_size = (g.n + Pn - 1) // Pn
    n_loc_pad = ops.pad_to(shard_size + 1, 128)
    sentinel = shard_size

    rows, cols = edge_list(g)
    p_of = (rows // shard_size).astype(np.int64)
    q_of = (cols // shard_size).astype(np.int64)
    counts = np.zeros((Pn, Pn), np.int64)
    np.add.at(counts, (p_of, q_of), 1)

    # --- compact request lists + per-edge request slots -------------------
    # bucket (p, q): the distinct src-local rows device p requests from
    # device q (paper's C_{q,p}); slot_of[e] is edge e's index into them.
    key = p_of * Pn + q_of
    order = np.argsort(key, kind="stable")  # rows sorted -> dst-sorted buckets
    bkt_start = np.zeros(Pn * Pn + 1, np.int64)
    np.cumsum(np.bincount(key, minlength=Pn * Pn), out=bkt_start[1:])
    slot_of = np.zeros(len(rows), np.int64)
    uniq_lists = {}
    r_len = 0
    for pp in range(Pn):
        for qq in range(Pn):
            sel = order[bkt_start[pp * Pn + qq] : bkt_start[pp * Pn + qq + 1]]
            uniq, inv = np.unique(cols[sel] - qq * shard_size, return_inverse=True)
            uniq_lists[(pp, qq)] = uniq
            slot_of[sel] = inv
            r_len = max(r_len, len(uniq))
    # strict +1: slot r_pad-1 is a pad slot in EVERY chunk, so it always
    # carries the zero sentinel row — the tile/slab pad sentinel points there
    r_pad = ops.pad_to(r_len + 1, 128)
    send_idx = np.full((Pn, Pn, r_pad), sentinel, np.int32)
    for (pp, qq), u in uniq_lists.items():
        # device q sends rows u to device p: stored at send_idx[q, p]
        send_idx[qq, pp, : len(u)] = u

    # --- §3.3 tiled buckets: fixed-size tiles + CSR offsets ---------------
    tiles_per_dev = (-(-counts // bucket_tile)).sum(axis=1)
    num_tiles = max(1, int(tiles_per_dev.max(initial=0)))
    tile_dst = np.full((Pn, num_tiles, bucket_tile), sentinel, np.int32)
    tile_src_local = np.full((Pn, num_tiles, bucket_tile), sentinel, np.int32)
    tile_src_compact = np.full((Pn, num_tiles, bucket_tile), r_pad - 1, np.int32)
    tile_off = np.zeros((Pn, Pn + 1), np.int32)
    # --- alltoall slab layout over the concatenated exchange buffer -------
    dev_slice = np.searchsorted(p_of, np.arange(Pn + 1))
    dst_local_all = (rows - p_of * shard_size).astype(np.int64)
    concat_col_all = q_of * r_pad + slot_of
    spb = 1
    nrb_loc = n_loc_pad // 128
    for pp in range(Pn):
        sl = slice(dev_slice[pp], dev_slice[pp + 1])
        blk_counts = np.bincount(dst_local_all[sl] // 128, minlength=nrb_loc)
        spb = max(spb, int(-(-blk_counts.max(initial=0) // bucket_tile)))
    a2a_slab_dst = np.empty((Pn, nrb_loc * spb, bucket_tile), np.int32)
    a2a_slab_cols = np.empty((Pn, nrb_loc * spb, bucket_tile), np.int32)
    for pp in range(Pn):
        sl = slice(dev_slice[pp], dev_slice[pp + 1])
        # tiled buckets: stable sort by src shard keeps dst order per bucket
        sub = np.argsort(q_of[sl], kind="stable")
        td, (tsl, tsc), toff = ops.build_bucket_tiles(
            q_of[sl][sub],
            dst_local_all[sl][sub],
            ((cols[sl] - q_of[sl] * shard_size)[sub], slot_of[sl][sub]),
            Pn,
            bucket_tile,
            dst_sentinel=sentinel,
            src_sentinels=(sentinel, r_pad - 1),
            num_tiles=num_tiles,
        )
        tile_dst[pp], tile_src_local[pp], tile_src_compact[pp] = td, tsl, tsc
        tile_off[pp] = toff
        # alltoall slabs: this shard's edges (already dst-sorted), columns
        # pointing into the [P * r_pad] concatenated compact buffer
        sd, sc, _ = ops.build_slab_layout(
            dst_local_all[sl],
            concat_col_all[sl],
            n_loc_pad,
            bucket_tile,
            128,
            sentinel_col=r_pad - 1,
            slabs_per_block=spb,
        )
        a2a_slab_dst[pp], a2a_slab_cols[pp] = sd, sc

    has_bags = program_has_bags(program)
    combine, widths = build_node_tables(program, k, lane=128, x_dim=g.n if has_bags else None)

    pin_adj = None
    if has_bags:
        # sharded dense apex adjacency [P, n_loc_pad, n]: for the local row
        # holding global vertex v, column x is A[v, x] (pad rows all-zero)
        pa = np.zeros((Pn, n_loc_pad, g.n), np.float32)
        pa[p_of, rows - p_of * shard_size, cols] = 1.0
        pin_adj = jnp.asarray(pa)

    compaction = None
    if compact and not has_bags:
        compaction = distributed_compaction(
            g,
            program,
            combine,
            k,
            num_shards=Pn,
            shard_size=shard_size,
            n_loc_pad=n_loc_pad,
            r_pad=r_pad,
            send_idx=send_idx,
            threshold=density_threshold,
            capacity_factor=capacity_factor,
            probes=probes,
        )

    return DistributedPlan(
        templates=templates,
        program=program,
        k=k,
        n=g.n,
        num_shards=Pn,
        shard_size=shard_size,
        n_loc_pad=n_loc_pad,
        r_pad=r_pad,
        bucket_tile=bucket_tile,
        num_tiles=num_tiles,
        slabs_per_block=spb,
        auts=tuple(automorphism_count(t) for t in templates),
        combine=combine,
        widths=widths,
        tile_dst=jnp.asarray(tile_dst),
        tile_src_local=jnp.asarray(tile_src_local),
        tile_src_compact=jnp.asarray(tile_src_compact),
        tile_off=jnp.asarray(tile_off),
        send_idx=jnp.asarray(send_idx),
        a2a_slab_dst=jnp.asarray(a2a_slab_dst),
        a2a_slab_cols=jnp.asarray(a2a_slab_cols),
        bucket_counts=counts,
        compaction=compaction,
        pin_adj=pin_adj,
    )


def abstract_plan(
    num_vertices: int,
    num_edges: int,
    tree,
    num_shards: int,
    *,
    root: int = 0,
    skew_headroom: float = 3.0,
    compact_requests: bool = True,  # False (ring): request arrays minimal
    bucket_tile: int = 128,
    n_colors: Optional[int] = None,
    compact: bool = False,
    density_threshold: float = DEFAULT_DENSITY_THRESHOLD,
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR,
) -> DistributedPlan:
    """Shape-only plan for dry-run lowering at paper-scale graph sizes.

    Tile/request sizes follow the paper's Eq. 5 expectation
    E[bucket] = |E_directed| / P^2 with a skew headroom factor; with tiled
    buckets the headroom costs O(E) extra tile slots, not O(P^2 * max_e).
    Array fields are ShapeDtypeStructs — nothing is allocated.  Arrays the
    requested mode never touches are kept minimal so the dry-run memory
    analysis reflects what the program actually ships.  ``tree`` may be a
    family (sequence of trees/names) — the lowered program is then the
    shared-DAG multi-template counter.

    ``compact=True`` sizes frontier-compaction capacities from the exact
    boolean-DP probe run on a small sampled same-degree subgraph
    (:func:`repro.core.frontier.sampled_density` — the paper-scale graph
    itself is never materialized), so dry-run cells lower and report the
    compacted exchange at paper scale with densities that track a real
    plan's measurements.
    """
    Pn = num_shards
    program, templates, k = _resolve_program(tree, root, n_colors)
    shard_size = (num_vertices + Pn - 1) // Pn
    n_loc_pad = ops.pad_to(shard_size + 1, 128)
    e_dev = 2.0 * num_edges / Pn
    avg_bucket = e_dev / Pn
    r_pad = ops.pad_to(min(int(avg_bucket * skew_headroom) + 128, shard_size + 1), 128)
    num_tiles = Pn * (int(avg_bucket * skew_headroom / bucket_tile) + 1)
    nrb_loc = n_loc_pad // 128
    spb = int(e_dev * skew_headroom / (nrb_loc * bucket_tile)) + 1

    has_bags = program_has_bags(program)
    combine, widths = build_node_tables(
        program, k, lane=128, x_dim=num_vertices if has_bags else None
    )
    compaction = None
    if compact and not has_bags:
        # densities from the exact boolean-DP probe on a sampled subgraph
        # (frontier.sampled_density) — the Markov bound saturated on dense
        # paper graphs, so dry-run capacities never engaged
        compaction = abstract_compaction(
            num_vertices,
            2.0 * num_edges / max(num_vertices, 1),
            program,
            k,
            r_pad=r_pad,
            n_loc_pad=n_loc_pad,
            threshold=density_threshold,
            capacity_factor=capacity_factor,
            combine=combine,
        )

    s = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)
    if compact_requests:
        tsl = s(Pn, 1, bucket_tile)  # ring-only array
        tsc = s(Pn, num_tiles, bucket_tile)
        sidx = s(Pn, Pn, r_pad)
        sd = sc = s(Pn, nrb_loc * spb, bucket_tile)
    else:
        tsl = s(Pn, num_tiles, bucket_tile)
        tsc = s(Pn, 1, bucket_tile)
        r_pad = 128
        sidx = s(Pn, Pn, r_pad)
        spb = 1
        sd = sc = s(Pn, 1, bucket_tile)
    return DistributedPlan(
        templates=templates,
        program=program,
        k=k,
        n=num_vertices,
        num_shards=Pn,
        shard_size=shard_size,
        n_loc_pad=n_loc_pad,
        r_pad=r_pad,
        bucket_tile=bucket_tile,
        num_tiles=num_tiles,
        slabs_per_block=spb,
        auts=tuple(automorphism_count(t) for t in templates),
        combine=combine,
        widths=widths,
        tile_dst=s(Pn, num_tiles, bucket_tile),
        tile_src_local=tsl,
        tile_src_compact=tsc,
        tile_off=s(Pn, Pn + 1),
        send_idx=sidx,
        a2a_slab_dst=sd,
        a2a_slab_cols=sc,
        bucket_counts=np.zeros((Pn, Pn), np.int64),
        compaction=compaction,
        pin_adj=(
            jax.ShapeDtypeStruct((Pn, n_loc_pad, num_vertices), jnp.float32)
            if has_bags
            else None
        ),
    )


def shard_coloring(plan: DistributedPlan, coloring: np.ndarray) -> np.ndarray:
    """Global coloring [n] -> sharded layout [P, n_loc_pad].

    One pad+reshape: the global array is zero-padded to ``P * shard_size``
    (covering the ragged last shard), viewed as ``[P, shard_size]``, and
    dropped into the first ``shard_size`` columns of the padded layout.
    Kept exported for tests and host-side callers that bring their own
    colorings; the keyed path (``make_count_fn(..., keyed=True)``) samples
    directly on-device and never builds this layout.
    """
    Pn, ss = plan.num_shards, plan.shard_size
    coloring = np.asarray(coloring, np.int32).reshape(-1)[: plan.n]
    out = np.zeros((Pn, plan.n_loc_pad), np.int32)
    padded = np.zeros(Pn * ss, np.int32)
    padded[: plan.n] = coloring
    out[:, :ss] = padded.reshape(Pn, ss)
    return out


def global_coloring(key: jax.Array, n: int, k: int) -> jax.Array:
    """The keyed backend's coloring for one iteration: int32 ``[n]``.

    Deliberately a function of ``(key, n, k)`` only — no shard count, no
    padding — so the coloring stream is identical on every mesh shape.
    ``sharded_fn_keyed`` slices this per shard on-device; tests and the
    elasticity contract (resume the same run on a different shard count)
    reconstruct it on the host to assert parity.
    """
    return jax.random.randint(key, (n,), 0, k, dtype=jnp.int32)


def _node_flops(plan: DistributedPlan, node_index: int) -> float:
    """Per-device compute consuming node ``node_index``'s exchange."""
    nd = plan.program.nodes[node_index]
    tbl = plan.combine[node_index]
    b_width = plan.widths[nd.right]
    edges_dev = float(plan.bucket_counts.sum()) / plan.num_shards
    if edges_dev <= 0:  # abstract plan: estimate from the tile capacity
        edges_dev = float(plan.num_tiles * plan.bucket_tile)
    spmm_flops = 2.0 * edges_dev * b_width
    x = plan.n if nd.kind == "bag_combine" else 1
    combine_flops = 2.0 * plan.n_loc_pad * x * tbl.s * tbl.j
    return spmm_flops + combine_flops


def _node_mode(
    plan: DistributedPlan,
    node_index: int,
    mode: str,
    hockney: HockneyModel,
    group_factor: int,
    wire_dtype: str = "float32",
) -> str:
    if mode != "adaptive":
        return mode
    # compacted+compressed byte counts: the slabs the wire actually ships
    _, a2a_bytes = node_exchange_bytes(plan, node_index, "alltoall", wire_dtype)
    _, ring_bytes = node_exchange_bytes(plan, node_index, "ring", wire_dtype)
    picked, _ = choose_mode_full(
        a2a_bytes,
        ring_bytes,
        _node_flops(plan, node_index),
        plan.num_shards,
        hockney,
        group_factor,
    )
    return picked


def plan_route_report(
    plan: DistributedPlan,
    *,
    mode: str = "adaptive",
    group_factor: int = 1,
    wire_dtype: str = "float32",
    adaptive: str = "model",
    hockney: HockneyModel = V5E_ICI,
    mesh: Optional[jax.sharding.Mesh] = None,
    data_axis: str = "data",
) -> dict:
    """Per-node routing decisions + predicted costs for plan reports.

    With ``adaptive="measured"`` and a mesh, the Hockney constants come
    from the one-shot calibration probe (``comm.adaptive.calibrate``);
    otherwise the assumed ``hockney`` model is used.  Per internal node
    the report carries the compacted+compressed byte counts of both wire
    layouts, the consuming flops, the modeled cost of each schedule, and
    the mode the router picks — the launcher plan report and the dry-run
    cells surface this verbatim.
    """
    model = hockney
    calibrated = False
    if adaptive == "measured" and mesh is not None:
        model = calibrate(mesh, data_axis, base=hockney)
        calibrated = model is not hockney
    per_node = {}
    for i, nd in enumerate(plan.program.nodes):
        if nd.kind not in ("combine", "bag_combine"):
            continue
        _, a2a_bytes = node_exchange_bytes(plan, i, "alltoall", wire_dtype)
        _, ring_bytes = node_exchange_bytes(plan, i, "ring", wire_dtype)
        flops = _node_flops(plan, i)
        picked, diag = choose_mode_full(
            a2a_bytes, ring_bytes, flops, plan.num_shards, model, group_factor
        )
        chosen = picked if mode == "adaptive" else mode
        per_node[i] = {
            "mode": chosen,
            "a2a_bytes": int(a2a_bytes),
            "ring_bytes": int(ring_bytes),
            "flops": float(flops),
            "costs_s": diag["costs_s"],
            "predicted_s": diag["costs_s"].get(chosen, diag["predicted_s"]),
        }
    return {
        "wire_dtype": wire_dtype,
        "adaptive": adaptive,
        "calibrated": calibrated,
        "model": {
            "alpha": model.alpha,
            "beta": model.beta,
            "flops_per_s": model.flops_per_s,
        },
        "per_node": per_node,
    }


def make_count_fn(
    plan: DistributedPlan,
    mesh: jax.sharding.Mesh,
    *,
    mode: str = "adaptive",
    data_axis: str = "data",
    iter_axis: Optional[str] = None,
    group_factor: int = 1,
    impl: str = "xla",
    fuse: bool = False,
    hockney: HockneyModel = V5E_ICI,
    wire_dtype: str = "float32",
    adaptive: str = "model",
    return_raw: bool = False,
    keyed: bool = False,
):
    """Build the jitted distributed count function.

    Default contract: ``f(colorings) -> counts`` where ``colorings`` is int32
    ``[I, P, n_loc_pad]`` (I = number of parallel coloring iterations,
    sharded over ``iter_axis`` when given) and ``counts`` is float32 [I]
    (colorful map counts; multiply by ``plan.scale`` for copy estimates).
    Family plans (``plan.is_multi``, built from a template sequence) return
    ``[I, R]`` per-template counts instead — ONE table-program pass per
    coloring, shared subtree tables computed once; multiply by
    ``plan.scales`` for per-template copy estimates.

    ``impl``/``fuse`` carry the same semantics as the in-core engine:
    ``impl`` routes the SpMM/combine kernels (``"pallas"`` engages the
    edge-tile and fused kernels on the alltoall consume and the Pallas
    combine everywhere), and ``fuse=True`` never materializes the full
    per-node neighbor sum ``M`` — via ``ops.fused_count_slabs`` on the
    materialized alltoall buffer, and via per-tile accumulation directly
    into the output table on the incremental (pipeline/ring) modes.

    ``keyed=True``: the same key-based contract as the single-device engine —
    ``f(keys) -> counts`` where ``keys`` is a jax PRNG key array ``[I]`` (or
    raw uint32 key data ``[I, 2]``).  Colorings are sampled **on-device**
    inside the shard_map: each shard folds its ``data``-axis index into the
    iteration key and draws its own ``[n_loc_pad]`` slice with
    ``jax.random.randint`` — per-vertex colors stay iid uniform over ``k``
    while no ``[n]`` host array, numpy loop, or host->device coloring
    transfer exists at all.

    ``return_raw=True`` (dry-run): returns ``(jitted_fn, structs, in_shard)``
    where the fn takes all plan arrays as explicit arguments so the plan may
    hold ShapeDtypeStructs (see :func:`abstract_plan`); ``iter_axis`` may be
    a tuple of mesh axes.

    A compacted plan (``plan.compaction``, DESIGN.md §15) ships every
    sufficiently sparse exchanged table as active rows only — per-peer
    ``[rc, B+1]`` slabs (rows + a bitcast slot column) on alltoall and
    pipeline, ``[cap, B+1]`` whole-shard relays on ring — and restricts the
    final combine to active rows.  The compact program is speculative: it
    also returns per-iteration overflow counts, and the returned callable
    transparently re-dispatches a dense twin when any static capacity
    overflowed (bit-exact either way).  With ``return_raw=True`` the raw
    ``(counts, overflow)`` function is returned instead (dry-run measures
    the compact program itself).

    ``wire_dtype`` (``"float32"`` | ``"int16"`` | ``"int8"``, DESIGN.md
    §18) narrows every exchange payload: counts are nonnegative integers,
    so in-range slabs round-trip through the integer wire bit-exactly,
    guarded by per-slab saturation flags riding the same
    speculate-check-redispatch contract as compaction.  On saturation the
    batch re-runs one rung up the escalation ladder
    (int8 -> int16 -> float32 -> dense twin).  Compacted slabs swap the
    float32 slot column for bit-packed activity-bitmap columns of the
    wire dtype; the receiver re-derives slot indices deterministically.

    ``adaptive="measured"`` replaces the assumed Hockney constants with a
    one-shot calibration probe on this mesh (``comm.adaptive.calibrate``,
    cached per device kind and axis size) before the per-node routing
    decision; ``"model"`` keeps the assumed ``hockney`` constants.
    """
    assert not (keyed and return_raw), "keyed and return_raw are exclusive"
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(f"wire_dtype={wire_dtype!r}; expected one of {sorted(WIRE_DTYPES)}")
    if adaptive not in ("model", "measured"):
        raise ValueError(f"adaptive={adaptive!r}; expected 'model' or 'measured'")
    Pn = plan.num_shards
    n_loc_pad = plan.n_loc_pad
    r_pad = plan.r_pad
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assert axis_sizes[data_axis] == Pn, (axis_sizes, Pn)
    wire_narrow = wire_dtype != "float32"

    if mode == "adaptive" and adaptive == "measured":
        hockney = calibrate(mesh, data_axis, base=hockney)
    node_modes = {
        i: _node_mode(plan, i, mode, hockney, group_factor, wire_dtype)
        for i, nd in enumerate(plan.program.nodes)
        if nd.kind in ("combine", "bag_combine")
    }

    spec = plan.compaction
    compact_on = spec is not None and spec.enabled
    # either narrowing makes the program speculative: it returns overflow
    # counts and the caller re-dispatches a wider twin on any saturation
    speculative = compact_on or wire_narrow
    # Which tables carry a frontier, and in which form, follows each
    # parent's resolved exchange mode: ring relays need the index form
    # (whole-shard compaction), alltoall/pipeline and the compact combine
    # only the activity mask.  Leaves are dense by construction.
    fr_caps: Dict[int, int] = {}
    mask_only = set()
    if compact_on:
        for i, nd in enumerate(plan.program.nodes):
            if nd.is_leaf:
                continue
            if node_modes[i] == "ring":
                if nd.right in spec.shard_caps:
                    fr_caps[nd.right] = spec.shard_caps[nd.right]
            elif nd.right in spec.exchange_caps:
                mask_only.add(nd.right)
            if i in spec.combine_caps and not fuse:
                mask_only.add(nd.left)
        keep = lambda j: not plan.program.nodes[j].is_leaf
        fr_caps = {j: c for j, c in fr_caps.items() if keep(j)}
        mask_only = frozenset(j for j in mask_only if keep(j) and j not in fr_caps)

    has_bags = program_has_bags(plan.program)

    def local_count(
        coloring,
        tile_dst,
        tile_src_loc,
        tile_src_cmp,
        tile_off,
        s_idx,
        slab_dst,
        slab_cols,
        pin_adj=None,
    ):
        """One coloring iteration on this device's shard; returns partial sum.

        The DP loop is the shared executor; only the neighbor-sum strategy
        below (exchange + tiled-bucket consume) is distributed-specific.
        """
        row_mask = (jnp.arange(n_loc_pad) < plan.shard_size).astype(jnp.float32)[:, None]
        leaf = leaf_table(coloring, ops.pad_to(plan.k, 128), row_mask)
        flags: list = []
        frontier_fn = (
            make_frontier_fn(fr_caps, plan.shard_size, flags, mask_only=mask_only)
            if compact_on else None
        )

        bag = None
        if has_bags:
            # treewidth-2 strategy (DESIGN.md §19), distributed form: bag
            # tables keep the [v_loc, x * W] sharded layout through every
            # exchange mode unchanged (the wire is width-agnostic); the
            # collapse reduces the local vertex rows and psums the [x, W]
            # result, so collapsed/joined tables are replicated — every
            # shard holds the full x axis.
            x_dim = plan.n
            k_pad = ops.pad_to(plan.k, 128)

            def bag_leaf_fn(i, nd):
                if nd.pin:
                    t = leaf[:, None, :] * pin_adj[:, :, None]
                else:
                    t = jnp.broadcast_to(leaf[:, None, :], (n_loc_pad, x_dim, k_pad))
                return t.reshape(n_loc_pad, x_dim * k_pad)

            def bag_collapse_fn(i, child):
                w = child.shape[1] // x_dim
                r = child.reshape(n_loc_pad, x_dim, w).sum(axis=0)
                r = jax.lax.psum(r, data_axis)  # [x, w], replicated
                t = plan.program.nodes[i].size
                filt = excluded_color_mask(plan.k, t)
                filt_pad = np.zeros((plan.k, w), np.float32)
                filt_pad[:, : filt.shape[1]] = filt
                # the apex filter needs the GLOBAL coloring: reassemble it
                # from the shards' true rows (ragged tail sliced off)
                col_glob = jax.lax.all_gather(
                    coloring[: plan.shard_size], data_axis, tiled=True
                )[: plan.n]
                return r * jnp.asarray(filt_pad)[col_glob]

            def bag_join_fn(i, tbl, left, right):
                # both inputs are replicated [x, w] tables; the disjoint
                # color convolution is pure local compute on aligned rows
                return ops.color_combine(left, right, tbl, impl=impl)

            bag = BagFns(bag_leaf_fn, bag_collapse_fn, bag_join_fn)

        def consume_into_m(tile_src):
            """Accumulate a chunk's bucket into the neighbor sum M.

            One uniform §3.3 task per tile: gather ``bucket_tile`` chunk
            rows, one bounded scatter-add — per-chunk work scales with the
            bucket's edge count, never with the globally largest bucket.
            """

            def consume(acc, chunk, src):
                acc = pvary_like(acc, chunk)

                def tile_task(t, a):
                    d = jax.lax.dynamic_index_in_dim(tile_dst, t, 0, keepdims=False)
                    s = jax.lax.dynamic_index_in_dim(tile_src, t, 0, keepdims=False)
                    return a.at[d].add(jnp.take(chunk, s, axis=0))

                return jax.lax.fori_loop(tile_off[src], tile_off[src + 1], tile_task, acc)

            return consume

        def consume_into_out(tile_src, c_left, tbl):
            """Fused incremental consume: the combine is linear in M, so each
            tile's contribution lands directly in the output table — the
            full [n_loc_pad, B] neighbor sum never exists (§3.2 across
            exchange chunks)."""

            def consume(acc, chunk, src):
                acc = pvary_like(acc, chunk)

                def tile_task(t, a):
                    d = jax.lax.dynamic_index_in_dim(tile_dst, t, 0, keepdims=False)
                    s = jax.lax.dynamic_index_in_dim(tile_src, t, 0, keepdims=False)
                    g1 = jnp.take(c_left, d, axis=0)  # [tile, A]
                    g2 = jnp.take(chunk, s, axis=0)  # [tile, B]
                    contrib = jnp.einsum("esj,esj->es", g1[:, tbl.idx1], g2[:, tbl.idx2])
                    contrib = jnp.pad(contrib, ((0, 0), (0, tbl.s_pad - tbl.s)))
                    return a.at[d].add(contrib)

                return jax.lax.fori_loop(tile_off[src], tile_off[src + 1], tile_task, acc)

            return consume

        def node_fn(i, tbl, c_left, c_right, f_left, f_right):
            nm = node_modes[i]
            bw = c_right.shape[1]
            nd_i = plan.program.nodes[i]
            # bag combines exchange/consume exactly like tree combines (the
            # wire is width-agnostic over the [v_loc, x * W] layout) but the
            # contraction must pair per-x blocks, which the fused kernels
            # cannot address — force the two-step path for these nodes only
            is_bag = nd_i.kind == "bag_combine"
            node_fuse = fuse and not is_bag
            rc = spec.exchange_caps.get(nd_i.right) if compact_on else None
            ring_cap = spec.shard_caps.get(nd_i.right) if compact_on else None
            ccap = spec.combine_caps.get(i) if compact_on and not fuse else None

            def final_combine(m):
                if ccap is not None:
                    return compact_combine(
                        c_left,
                        m,
                        tbl,
                        ccap,
                        plan.shard_size,
                        impl,
                        flags,
                        left_mask=f_left.mask if f_left is not None else None,
                    )
                if is_bag:
                    m = m * row_mask
                    rows = c_left.shape[0]
                    lhs = c_left.reshape(rows * plan.n, -1)
                    rhs = m.reshape(rows * plan.n, -1)
                    out = ops.color_combine(lhs, rhs, tbl, impl=impl)
                    return out.reshape(rows, plan.n * tbl.s_pad)
                return ops.color_combine(c_left, m * row_mask, tbl, impl=impl)

            def compact_chunks():
                """Compacted per-peer slabs: the active rows of each request
                chunk plus a slot carrier — a bitcast float32 slot column on
                the wide wire ([P, rc, B+1]), or bit-packed activity-bitmap
                columns of the wire dtype on a narrow one (the receiver
                re-derives the identical slots from the mask with the same
                deterministic capacity-padded nonzero the sender ran)."""
                act_chunks = jnp.take(f_right.mask, s_idx)  # [P, r_pad]
                counts = jnp.sum(act_chunks.astype(jnp.int32), axis=1)
                flags.append(jnp.max(counts) <= rc - 1)
                slots = chunk_slots(act_chunks, rc, r_pad - 1)  # [P, rc]
                rows = jnp.take(
                    c_right,
                    jnp.take_along_axis(s_idx, slots, axis=1).reshape(-1),
                    axis=0,
                ).reshape(Pn, rc, bw)
                if wire_narrow:
                    return jnp.concatenate(
                        [
                            narrow_cast(rows, wire_dtype, flags),
                            mask_columns(act_chunks, rc, wire_dtype),
                        ],
                        axis=-1,
                    )
                return jnp.concatenate([rows, encode_slots(slots)[..., None]], axis=-1)

            if nm == "alltoall":
                # Naive mode: the whole exchange buffer is materialized
                # anyway, so consume it with the in-core engine's kernels
                # over the [P * r_pad, B] concatenation (slab columns were
                # built against exactly this layout).
                if rc is not None and f_right is not None:
                    # compacted alltoall: ship [P, rc, B+extra], scatter the
                    # received rows back into the (zero-initialized) dense
                    # buffer — inactive slots stay exactly zero, which is
                    # what the dense exchange would have delivered there
                    payload = compact_chunks()
                    received = jax.lax.all_to_all(payload, data_axis, split_axis=0, concat_axis=0)
                    r_rows = widen(received[..., :bw]).reshape(Pn * rc, bw)
                    if wire_narrow:
                        masks = mask_from_columns(
                            received[..., bw:], r_pad, wire_dtype
                        )  # [P, r_pad] — the senders' chunk activity
                        r_slots = chunk_slots(masks, rc, r_pad - 1)
                    else:
                        r_slots = decode_slots(received[..., bw])  # [P, rc]
                    flat = r_slots + (jnp.arange(Pn, dtype=jnp.int32) * r_pad)[:, None]
                    remote = (
                        jnp.zeros((Pn * r_pad, bw), jnp.float32)
                        .at[flat.reshape(-1)]
                        .add(r_rows)
                    )
                else:
                    chunks = jnp.take(c_right, s_idx, axis=0)  # [P, r_pad, B]
                    received = jax.lax.all_to_all(
                        narrow_cast(chunks, wire_dtype, flags),
                        data_axis,
                        split_axis=0,
                        concat_axis=0,
                    )
                    # the slab kernels widen narrow tables at entry, so the
                    # received buffer feeds them without a separate copy
                    remote = received.reshape(Pn * r_pad, bw)
                if node_fuse:
                    return ops.fused_count_slabs(
                        slab_dst,
                        slab_cols,
                        c_left,
                        remote,
                        tbl,
                        slabs_per_block=plan.slabs_per_block,
                        impl=impl,
                    )
                m = ops.spmm_slabs(
                    slab_dst,
                    slab_cols,
                    remote,
                    out_rows=n_loc_pad,
                    slabs_per_block=plan.slabs_per_block,
                    impl=impl,
                )
                return final_combine(m)
            # incremental modes: per-chunk tiled-bucket consume
            if node_fuse:
                init = jnp.zeros((n_loc_pad, tbl.s_pad), jnp.float32)
            else:
                init = jnp.zeros((n_loc_pad, bw), c_right.dtype)
            if nm == "ring":
                src_arr = tile_src_loc  # chunks are whole remote shards
                consume_dense = (
                    consume_into_out(src_arr, c_left, tbl) if node_fuse
                    else consume_into_m(src_arr)
                )

                def consume(acc, chunk, src):
                    # relayed chunks arrive at wire width; the tiled
                    # consume runs on the (exactly) widened rows
                    return consume_dense(acc, widen(chunk), src)

                if ring_cap is not None and f_right is not None:
                    # compacted relay: the ring carries [cap, B+extra]
                    # active rows + their row ids (slot column on the wide
                    # wire, packed activity bitmap on a narrow one); each
                    # hop reconstructs the dense shard before the
                    # (unchanged) tiled consume
                    rows = jnp.take(c_right, f_right.idx, axis=0)
                    if wire_narrow:
                        payload = jnp.concatenate(
                            [
                                narrow_cast(rows, wire_dtype, flags),
                                mask_columns(
                                    f_right.mask, ring_cap, wire_dtype
                                ),
                            ],
                            axis=1,
                        )
                    else:
                        payload = jnp.concatenate(
                            [rows, encode_slots(f_right.idx)[:, None]], axis=1
                        )

                    def consume_compact(acc, chunk, src):
                        if wire_narrow:
                            mask = mask_from_columns(chunk[:, bw:], n_loc_pad, wire_dtype)
                            idx = jnp.nonzero(
                                mask, size=ring_cap,
                                fill_value=plan.shard_size,
                            )[0].astype(jnp.int32)
                        else:
                            idx = decode_slots(chunk[:, bw])
                        dense = (
                            jnp.zeros((n_loc_pad, bw), jnp.float32)
                            .at[idx]
                            .add(widen(chunk[:, :bw]))
                        )
                        return consume_dense(acc, dense, src)

                    out = ring_allgather_overlap(payload, data_axis, consume_compact, init)
                else:
                    out = ring_allgather_overlap(
                        narrow_cast(c_right, wire_dtype, flags),
                        data_axis,
                        consume,
                        init,
                    )
            else:  # pipeline
                src_arr = tile_src_cmp  # chunks are compact request lists
                consume_dense = (
                    consume_into_out(src_arr, c_left, tbl) if node_fuse
                    else consume_into_m(src_arr)
                )

                def consume(acc, chunk, src):
                    return consume_dense(acc, widen(chunk), src)

                if rc is not None and f_right is not None:
                    payload = compact_chunks()

                    def consume_compact(acc, chunk, src):
                        if wire_narrow:
                            mask = mask_from_columns(chunk[:, bw:], r_pad, wire_dtype)
                            slots = jnp.nonzero(
                                mask, size=rc, fill_value=r_pad - 1
                            )[0].astype(jnp.int32)
                        else:
                            slots = decode_slots(chunk[:, bw])
                        dense = (
                            jnp.zeros((r_pad, bw), jnp.float32)
                            .at[slots]
                            .add(widen(chunk[:, :bw]))
                        )
                        return consume_dense(acc, dense, src)

                    out = grouped_exchange(
                        payload,
                        data_axis,
                        consume_compact,
                        init,
                        group_factor=group_factor,
                    )
                else:
                    chunks = jnp.take(c_right, s_idx, axis=0)  # [P, r_pad, B]
                    out = grouped_exchange(
                        narrow_cast(chunks, wire_dtype, flags),
                        data_axis,
                        consume,
                        init,
                        group_factor=group_factor,
                    )
            if node_fuse:
                return out
            return final_combine(out)

        roots = run_table_program(
            plan.program,
            plan.combine,
            leaf,
            row_mask,
            node_fn,
            root_fn=root_count,
            frontier_fn=frontier_fn,
            bag=bag,
        )
        ok = jnp.bool_(True)
        for fl in flags:
            ok = jnp.logical_and(ok, fl)
        # [R] per-template counts plus this coloring's no-overflow flag
        return jnp.stack(roots), ok

    # bag roots (collapse/join) are psum'd inside local_count, so their
    # per-shard partials are already the replicated global count — summing
    # them again across shards would multiply by P.  Static 0/1 weights pick
    # the right reduction per root without any per-root control flow.
    w_root = np.array(
        [
            0.0
            if plan.program.nodes[r].kind in ("bag_collapse", "bag_join")
            else 1.0
            for r in plan.program.roots
        ],
        np.float32,
    )
    mixed_roots = bool((w_root == 0.0).any())

    def _reduce(partials, oks):
        if mixed_roots:
            w = jnp.asarray(w_root)
            counts = jax.lax.psum(partials * w, data_axis) + partials * (1.0 - w)
        else:
            counts = jax.lax.psum(partials, data_axis)  # [I_loc, R]
        if not speculative:
            return counts
        # per-iteration overflow/saturation counts, replicated across shards
        bad = jax.lax.psum(
            jnp.logical_not(oks).astype(jnp.int32), data_axis
        )
        return counts, bad

    def sharded_fn(colorings, *arrs):
        # local shapes: colorings [I_loc, 1, n_loc_pad]; plan arrays [1, ...]
        colorings = colorings[:, 0]
        local = tuple(a[0] for a in arrs)
        partials, oks = jax.vmap(lambda col: local_count(col, *local))(colorings)
        return _reduce(partials, oks)

    def sharded_fn_keyed(key_data, *arrs):
        # local shapes: key_data [I_loc, 2] uint32; plan arrays [1, ...]
        local = tuple(a[0] for a in arrs)
        p = jax.lax.axis_index(data_axis)

        def one(kd):
            # every shard draws the same GLOBAL coloring and slices its own
            # rows, so the coloring stream depends only on (key, n, k) —
            # never on the shard count.  That is what lets a checkpointed
            # run resume on a different shard count (ROADMAP elasticity)
            # and keeps service coloring streams portable across meshes.
            # Rows past the shard's true size (local pad, and global pad on
            # the ragged last shard) take a clipped color; they are either
            # masked (row >= shard_size) or edgeless, contributing zero to
            # every internal-node table, exactly like the zero color
            # shard_coloring pads with.
            col_glob = global_coloring(
                jax.random.wrap_key_data(kd), plan.n, plan.k
            )
            idx = p * plan.shard_size + jnp.arange(n_loc_pad)
            col = jnp.take(col_glob, jnp.minimum(idx, plan.n - 1))
            return local_count(col, *local)

        partials, oks = jax.vmap(one)(key_data)  # [I_loc, R]
        return _reduce(partials, oks)

    iter_spec = P(iter_axis) if iter_axis else P()
    out_spec = (iter_spec, iter_spec) if speculative else iter_spec
    lead_spec = (
        P(iter_axis) if keyed
        else (P(iter_axis, data_axis) if iter_axis else P(None, data_axis))
    )
    in_specs = (lead_spec,) + (P(data_axis),) * len(plan.device_arrays)
    # check_vma=False: the tiled-bucket consume iterates a traced CSR tile
    # range (a `while` under jit), which the replication checker cannot
    # type; outputs are psum-reduced, hence replicated by construction.
    mapped = shard_map(
        sharded_fn_keyed if keyed else sharded_fn,
        mesh=mesh, in_specs=in_specs, out_specs=out_spec, check_vma=False,
    )

    if return_raw:
        from jax.sharding import NamedSharding

        iter_size = 1
        for ax in (iter_axis if isinstance(iter_axis, tuple) else (iter_axis,)):
            if ax:
                iter_size *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
        as_struct = lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
        structs = (
            jax.ShapeDtypeStruct((iter_size, Pn, n_loc_pad), jnp.int32),
        ) + tuple(as_struct(a) for a in plan.device_arrays)
        in_shard = tuple(NamedSharding(mesh, s) for s in in_specs)
        fn = jax.jit(mapped, in_shardings=in_shard)
        return fn, structs, in_shard

    @jax.jit
    def fj(data):
        out = mapped(data, *plan.device_arrays)
        if speculative:
            counts, bad = out
            return (counts if plan.is_multi else counts[:, 0]), bad
        return out if plan.is_multi else out[:, 0]

    if speculative:
        # speculative dispatch: the narrow/compact program reports
        # per-iteration overflow counts; any overflow re-runs the batch one
        # rung up the escalation ladder — a narrow wire widens first
        # (int8 -> int16 -> float32, keeping the same compaction), then the
        # float32 compact program falls back to its dense twin.  Each twin
        # wraps itself the same way, so the ladder always terminates at the
        # dense float32 program (bit-exact — narrow == wide when flags hold).
        twin_state: Dict[str, object] = {}

        def run(data):
            res, bad = fj(data)
            # fault sites: force the saturation/overflow storm onto the twin
            forced = wire_narrow and (
                faults.fire("compression.saturate") is not None
            )
            forced = forced or (compact_on and faults.fire("compaction.overflow") is not None)
            if not forced and int(np.asarray(bad).sum()) == 0:
                return res
            ft = twin_state.get("fn")
            if ft is None:
                if wire_narrow:
                    twin_plan = plan
                    twin_wire = WIRE_ESCALATION[wire_dtype]
                else:
                    twin_plan = dataclasses.replace(plan, compaction=None)
                    twin_wire = "float32"
                ft = twin_state["fn"] = make_count_fn(
                    twin_plan, mesh,
                    mode=mode, data_axis=data_axis, iter_axis=iter_axis,
                    group_factor=group_factor, impl=impl, fuse=fuse,
                    hockney=hockney, wire_dtype=twin_wire, adaptive=adaptive,
                    keyed=keyed,
                )
            return ft(data)

    else:
        run = fj

    if not keyed:
        return run

    def f_keyed(keys):
        keys = jnp.asarray(keys)
        if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
            keys = jax.random.key_data(keys)
        return run(keys.astype(jnp.uint32))

    return f_keyed


def keyed_sample_fn(plan: DistributedPlan, mesh: jax.sharding.Mesh, **kw):
    """Adapt a distributed plan to the backend ``sample_fn`` protocol.

    Returns ``sample_fn(key, batch) -> float64 [batch]`` copy estimates —
    the same contract :func:`repro.core.count_engine.plan_sample_fn` gives
    the single-device engine, so :func:`repro.core.estimator.estimate_counts`
    (and anything else speaking the protocol) runs unmodified on top of the
    shard_map backend.  A family plan returns ``[batch, R]`` per-template
    estimates instead (the :func:`~repro.core.count_engine.multi_sample_fn`
    contract, consumed by ``estimate_counts_many``).  ``kw`` is forwarded to
    :func:`make_count_fn` (mode/group_factor/impl/fuse/axes/...).  Each call
    evaluates ``batch`` coloring iterations in one jitted dispatch; jit
    caches per distinct batch size.  When colorings shard over ``iter_axis``
    the key count is rounded up to a multiple of the axis size (shard_map
    divisibility) and the surplus estimates are discarded.
    """
    f = make_count_fn(plan, mesh, keyed=True, **kw)
    iter_axis = kw.get("iter_axis")
    isz = 1
    if iter_axis:
        isz = dict(zip(mesh.axis_names, mesh.devices.shape))[iter_axis]
    scales = np.asarray(plan.scales, np.float64)

    def sample(key: jax.Array, batch: int) -> np.ndarray:
        b = -(-batch // isz) * isz
        counts = np.asarray(f(jax.random.split(key, b)), np.float64)
        if plan.is_multi:
            return counts[:batch] * scales[None, :]
        return counts.reshape(-1)[:batch] * plan.scale

    return sample
