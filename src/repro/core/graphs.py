"""Graph substrate: CSR storage, generators, partitioning, edge tiles.

Graphs are undirected and stored in CSR with both edge directions, which is
what the color-coding neighbor sum consumes (``M[v] += C[u]`` for every
directed entry ``(v, u)``).

Two layouts feed the compute kernels:

* **expanded edges** ``(rows, cols)`` — one entry per directed edge, rows
  nondecreasing (CSR order).  This is the input to the XLA segment-sum path
  and to the Pallas gather kernel.
* **edge tiles** — the same arrays padded to a multiple of the tile size
  ``s`` with a sentinel row.  This is the TPU realization of the paper's
  *neighbor-list partitioning* (§3.3): every tile is a bounded, uniform unit
  of work no matter how skewed the degree distribution is; a max-degree
  vertex simply spans many tiles.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "Graph",
    "GraphFormatError",
    "from_edges",
    "erdos_renyi",
    "rmat",
    "relabel_random",
    "edge_list",
    "edge_tiles",
    "partition_edges_by_src_shard",
    "pad_vertices",
    "load_edge_file",
    "save_npz",
    "load_npz",
    "RMAT_SKEW",
]


class GraphFormatError(ValueError):
    """Malformed graph input, caught at ingestion with a precise message.

    Raised by :func:`load_edge_file` / :func:`load_npz` for non-integer or
    truncated lines (with the line number), out-of-range vertex ids, and
    missing/corrupt npz contents — so bad input fails at the door instead
    of crashing deep inside plan build.  Subclasses ``ValueError``, so
    pre-existing handlers keep working; pass ``validate=False`` to restore
    the old lenient behavior (skip unparseable lines, trust the arrays).
    """


@dataclass(frozen=True)
class Graph:
    """Undirected graph in CSR form (both directions stored)."""

    n: int
    indptr: np.ndarray  # int64 [n+1]
    indices: np.ndarray  # int32 [2m]
    name: str = ""

    @property
    def num_edges(self) -> int:
        """Number of undirected edges."""
        return int(self.indices.shape[0]) // 2

    @property
    def num_directed(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @property
    def max_degree(self) -> int:
        return int(self.degrees().max(initial=0))

    @property
    def avg_degree(self) -> float:
        return float(self.num_directed / max(self.n, 1))

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def skewness(self) -> float:
        """max degree / avg degree — the paper's workload-skew indicator."""
        return self.max_degree / max(self.avg_degree, 1e-12)


def from_edges(n: int, edges: np.ndarray, name: str = "") -> Graph:
    """Build a Graph from an array of undirected edges [m, 2].

    Self loops and duplicate edges are removed.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if edges.size:
        edges = edges[edges[:, 0] != edges[:, 1]]
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        key = lo * n + hi
        _, first = np.unique(key, return_index=True)
        edges = np.stack([lo[first], hi[first]], axis=1)
    both = np.concatenate([edges, edges[:, ::-1]], axis=0) if edges.size else edges
    order = np.lexsort((both[:, 1], both[:, 0])) if both.size else np.array([], np.int64)
    both = both[order] if both.size else both.reshape(0, 2)
    counts = np.bincount(both[:, 0], minlength=n) if both.size else np.zeros(n, np.int64)
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    indices = both[:, 1].astype(np.int32) if both.size else np.zeros(0, np.int32)
    return Graph(n, indptr, indices, name)


def load_edge_file(
    path: str,
    *,
    n: Optional[int] = None,
    comments: Tuple[str, ...] = ("#", "%"),
    zero_indexed: bool = True,
    name: str = "",
    validate: bool = True,
) -> Graph:
    """Load an undirected graph from a whitespace-separated edge-list file.

    The format accepted is the de-facto standard of SNAP / Network Repository
    dumps (the paper's Table 2 datasets ship this way): one ``u v`` pair per
    line, blank lines and lines starting with any prefix in ``comments``
    skipped, extra columns (weights, timestamps) ignored.  ``n`` defaults to
    ``max vertex id + 1``; ``zero_indexed=False`` shifts 1-based ids down.
    Self loops and duplicate edges are removed by :func:`from_edges`.

    With ``validate=True`` (default) malformed input raises
    :class:`GraphFormatError` naming the offending line: non-integer
    tokens, a single-column line (the signature of a truncated download),
    negative or out-of-range vertex ids.  ``validate=False`` is the escape
    hatch for dirty-but-known files: bad lines are skipped silently, as the
    pre-hardening loader did.
    """
    src, dst = [], []
    lo_bound = 0 if zero_indexed else 1
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line or line.startswith(comments):
                continue
            parts = line.split()
            if len(parts) < 2:
                if validate:
                    raise GraphFormatError(
                        f"{path}:{lineno}: expected 'u v', got {line!r} "
                        f"(truncated file?)"
                    )
                continue
            try:
                u, v = int(parts[0]), int(parts[1])
            except ValueError:
                if validate:
                    raise GraphFormatError(
                        f"{path}:{lineno}: non-integer vertex id in {line!r}"
                    ) from None
                continue
            if validate:
                if u < lo_bound or v < lo_bound:
                    raise GraphFormatError(
                        f"{path}:{lineno}: vertex id {min(u, v)} below "
                        f"{lo_bound} (zero_indexed={zero_indexed} wrong?)"
                    )
                if n is not None and max(u, v) - (0 if zero_indexed else 1) >= n:
                    raise GraphFormatError(
                        f"{path}:{lineno}: vertex id {max(u, v)} out of "
                        f"range for n={n}"
                    )
            src.append(u)
            dst.append(v)
    edges = np.array([src, dst], np.int64).T.reshape(-1, 2)
    if not zero_indexed and edges.size:
        edges -= 1
    if validate and edges.size == 0:
        raise GraphFormatError(
            f"{path}: no edges found (empty, truncated, or fully-commented "
            f"file) — pass validate=False if an empty graph is intended"
        )
    if edges.size and edges.min() < 0:
        raise GraphFormatError(f"negative vertex id in {path} (zero_indexed wrong?)")
    n_found = int(edges.max(initial=-1)) + 1
    if n is None:
        n = n_found
    elif n < n_found:
        raise GraphFormatError(f"n={n} smaller than max vertex id + 1 = {n_found}")
    return from_edges(n, edges, name or os.path.basename(path))


def save_npz(g: Graph, path: str) -> None:
    """Persist a graph's CSR arrays with ``np.savez_compressed``.

    Round-trips through :func:`load_npz`; the compressed CSR form loads
    orders of magnitude faster than re-parsing a text edge list, which is
    what makes repeat runs on real datasets practical.
    """
    np.savez_compressed(
        path,
        n=np.int64(g.n),
        indptr=g.indptr,
        indices=g.indices,
        name=np.str_(g.name),
    )


def load_npz(path: str, *, validate: bool = True) -> Graph:
    """Load a graph previously written by :func:`save_npz`.

    With ``validate=True`` (default) a file that is not a ``save_npz``
    graph fails with :class:`GraphFormatError` naming what's wrong — a
    missing key, a truncated/corrupt archive, an ``indptr`` that doesn't
    match ``indices``, or out-of-range vertex ids — instead of crashing
    deep in plan build.  ``validate=False`` trusts the arrays.
    """
    try:
        z = np.load(path, allow_pickle=False)
    except Exception as e:  # zipfile.BadZipFile, OSError, ...
        raise GraphFormatError(
            f"{path}: not a readable npz archive (truncated or corrupt? "
            f"{type(e).__name__}: {e})"
        ) from e
    with z:
        for k in ("n", "indptr", "indices"):
            if k not in z:
                raise GraphFormatError(f"{path}: missing npz key {k!r} — not a save_npz graph?")
        try:
            n = int(z["n"])
            indptr = z["indptr"].astype(np.int64)
            indices = z["indices"].astype(np.int32)
            graph_name = str(z["name"]) if "name" in z else ""
        except Exception as e:
            raise GraphFormatError(
                f"{path}: unreadable npz member (truncated archive? "
                f"{type(e).__name__}: {e})"
            ) from e
    if validate:
        if n < 0:
            raise GraphFormatError(f"{path}: negative vertex count n={n}")
        if indptr.shape != (n + 1,):
            raise GraphFormatError(
                f"{path}: indptr has shape {indptr.shape}, expected "
                f"({n + 1},) for n={n}"
            )
        if indptr.size and (indptr[0] != 0 or indptr[-1] != indices.shape[0]):
            raise GraphFormatError(
                f"{path}: indptr spans [{int(indptr[0])}, {int(indptr[-1])}] "
                f"but indices has {indices.shape[0]} entries (truncated "
                f"arrays?)"
            )
        if np.any(np.diff(indptr) < 0):
            raise GraphFormatError(f"{path}: indptr is not nondecreasing")
        if indices.size and (indices.min() < 0 or indices.max() >= n):
            raise GraphFormatError(
                f"{path}: vertex id {int(indices.max())} out of range "
                f"[0, {n})"
            )
    return Graph(n=n, indptr=indptr, indices=indices, name=graph_name)


def erdos_renyi(n: int, avg_degree: float, seed: int = 0, name: str = "") -> Graph:
    """G(n, m) with m ~= n*avg_degree/2 sampled uniformly."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    edges = rng.integers(0, n, size=(int(m * 1.15) + 8, 2), dtype=np.int64)
    return from_edges(n, edges[:m] if len(edges) >= m else edges, name or f"er-{n}-{avg_degree}")


#: Mapping of the paper's PaRMAT "skewness k" knob to RMAT (a, b, c, d).
#: Higher a = heavier-tailed degree distribution; k=1 is near-uniform
#: (matches the paper: R250K1 has max degree 170 at avg 100, R250K8 has
#: 433K max at avg 217).
RMAT_SKEW = {
    1: (0.30, 0.25, 0.25, 0.20),
    3: (0.45, 0.22, 0.22, 0.11),
    8: (0.57, 0.19, 0.19, 0.05),
}


def rmat(
    n: int,
    num_edges: int,
    skew: int = 3,
    seed: int = 0,
    probs: Optional[Tuple[float, float, float, float]] = None,
    name: str = "",
) -> Graph:
    """R-MAT generator (Chakrabarti et al.), vectorized bit-recursive sampling.

    ``n`` is rounded up to the next power of two internally; vertices beyond
    ``n`` are folded back with a modulo, matching common practice.
    """
    a, b, c, d = probs if probs is not None else RMAT_SKEW[skew]
    scale = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    rng = np.random.default_rng(seed)
    m = num_edges
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for _ in range(scale):
        r = rng.random(m)
        src <<= 1
        dst <<= 1
        # quadrant probabilities: a (0,0), b (0,1), c (1,0), d (1,1)
        q_b = (r >= a) & (r < a + b)
        q_c = (r >= a + b) & (r < a + b + c)
        q_d = r >= a + b + c
        dst += q_b | q_d
        src += q_c | q_d
    src %= n
    dst %= n
    return from_edges(n, np.stack([src, dst], 1), name or f"rmat-{n}-{num_edges}-s{skew}")


def relabel_random(g: Graph, seed: int = 0) -> Graph:
    """Random vertex relabeling — the paper's random-partition assumption.

    Contiguous block partitioning of a randomly relabeled graph is equivalent
    to random vertex partitioning (Eq. 5's E[N_r,w] = |E|/P^2 analysis).
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n).astype(np.int64)
    rows, cols = edge_list(g)
    return from_edges(g.n, np.stack([perm[rows], perm[cols]], 1), g.name + "-shuf")


def edge_list(g: Graph) -> Tuple[np.ndarray, np.ndarray]:
    """Expanded directed edge list (rows nondecreasing)."""
    rows = np.repeat(np.arange(g.n, dtype=np.int32), np.diff(g.indptr))
    return rows, g.indices.astype(np.int32)


def pad_vertices(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def edge_tiles(
    g: Graph, tile_size: int, n_pad: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Neighbor-list partitioning: fixed-size edge tiles with sentinel pad.

    Returns ``(rows, cols, num_tiles)`` with both arrays padded to
    ``num_tiles * tile_size``.  Padding entries point at the sentinel row
    ``n_pad`` (callers allocate ``n_pad + 1`` rows; the sentinel row of the
    operand table must be zero, and the sentinel output row is discarded).
    """
    rows, cols = edge_list(g)
    sentinel = g.n if n_pad is None else n_pad
    e = rows.shape[0]
    num_tiles = max((e + tile_size - 1) // tile_size, 1)
    padded = num_tiles * tile_size
    rows_p = np.full(padded, sentinel, np.int32)
    cols_p = np.full(padded, sentinel, np.int32)
    rows_p[:e] = rows
    cols_p[:e] = cols
    return rows_p, cols_p, num_tiles


def partition_edges_by_src_shard(
    g: Graph, num_shards: int, tile_size: int = 1
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Bucket each shard's incoming edges by the *source* shard of ``u``.

    For the pipelined (ring) exchange, device ``p`` processes, at ring step
    ``w``, only the edges ``(v, u)`` whose source vertex ``u`` lives in the
    shard arriving at step ``w``.  This routine builds, for every
    (dst-shard ``p``, src-shard ``q``) pair, the padded edge bucket:

    Returns ``(rows, cols, counts)``:
      * ``rows``  int32 [P, P, max_bucket] — local dst row (within shard p)
      * ``cols``  int32 [P, P, max_bucket] — local src row (within shard q)
      * ``counts`` int64 [P, P] — true bucket sizes (before padding)

    Padding entries use the sentinel local row ``shard_size`` (callers pad
    tables with one extra zero row).  ``max_bucket`` is rounded up to
    ``tile_size``.  Vertices are assigned to shards in contiguous blocks of
    ``ceil(n/P)``; combine with :func:`relabel_random` for the random
    partition of the paper.
    """
    P = num_shards
    shard_size = (g.n + P - 1) // P
    rows, cols = edge_list(g)
    p_of = rows // shard_size
    q_of = cols // shard_size
    counts = np.zeros((P, P), np.int64)
    np.add.at(counts, (p_of, q_of), 1)
    max_bucket = int(counts.max(initial=0))
    max_bucket = max(((max_bucket + tile_size - 1) // tile_size) * tile_size, tile_size)
    out_rows = np.full((P, P, max_bucket), shard_size, np.int32)
    out_cols = np.full((P, P, max_bucket), shard_size, np.int32)
    key = p_of * P + q_of
    order = np.argsort(key, kind="stable")
    skey = key[order]
    group_start = np.zeros(P * P, np.int64)
    np.cumsum(np.bincount(skey, minlength=P * P)[:-1], out=group_start[1:])
    pos_in_group = np.arange(len(order)) - group_start[skey]
    flat_rows = out_rows.reshape(P * P, max_bucket)
    flat_cols = out_cols.reshape(P * P, max_bucket)
    flat_rows[skey, pos_in_group] = (rows[order] - p_of[order] * shard_size).astype(np.int32)
    flat_cols[skey, pos_in_group] = (cols[order] - q_of[order] * shard_size).astype(np.int32)
    return out_rows, out_cols, counts
