"""Tree templates ("treelets"), partition chains, and automorphism counting.

A template is an unrooted tree ``T`` on ``k`` vertices.  Color-coding
partitions a rooted copy of ``T`` recursively: at each step the sub-template
``T_i`` (rooted at ``rho``) is split by cutting the edge to one child ``c``,
producing ``T_i'`` (same root, without ``c``'s subtree) and ``T_i''`` (``c``'s
subtree rooted at ``c``).  The result is a binary *partition chain* whose
leaves are single vertices.  The DP computes one count table per chain node,
in postorder.

The paper's Table 3 complexity figures are reproduced by
:func:`partition_complexity` with the paper's convention (sum over internal
nodes ``1 < |T_i| < k``):

    memory  = sum_i C(k, |T_i|)
    compute = sum_i C(k, |T_i|) * C(|T_i|, |T_i'|)

Because both quantities depend only on the *split profile* (the binary tree
of sizes), the named templates below are realized from profiles found to
exactly match Table 3 (see ``tools/find_templates.py``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Tree",
    "PartitionNode",
    "PartitionChain",
    "TemplateDag",
    "compile_templates",
    "rooted_signature",
    "family_signature",
    "partition_tree",
    "partition_complexity",
    "automorphism_count",
    "canonical_form",
    "path_tree",
    "star_tree",
    "spider_tree",
    "random_tree",
    "realize_profile",
    "TEMPLATES",
    "template",
]


# ---------------------------------------------------------------------------
# Tree representation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Tree:
    """An unrooted tree on ``n`` vertices given by an edge list.

    ``children_order`` matters only through the partition cut policy: the
    partition always cuts the *first* child (in adjacency insertion order) of
    the current root, which lets profile-realized trees reproduce their
    profile exactly.
    """

    n: int
    edges: Tuple[Tuple[int, int], ...]
    name: str = ""

    def __post_init__(self):
        if len(self.edges) != self.n - 1:
            raise ValueError(
                f"tree on {self.n} vertices needs {self.n - 1} edges, got {len(self.edges)}"
            )
        seen = set()
        adj = self.adjacency()
        # connectivity check (BFS)
        stack, seen = [0], {0}
        while stack:
            v = stack.pop()
            for u in adj[v]:
                if u not in seen:
                    seen.add(u)
                    stack.append(u)
        if len(seen) != self.n:
            raise ValueError("edge list does not describe a connected tree")

    def adjacency(self) -> List[List[int]]:
        adj: List[List[int]] = [[] for _ in range(self.n)]
        for a, b in self.edges:
            adj[a].append(b)
            adj[b].append(a)
        return adj

    @property
    def k(self) -> int:
        """Number of colors used for this template (== template size)."""
        return self.n


def path_tree(n: int, name: str = "") -> Tree:
    return Tree(n, tuple((i, i + 1) for i in range(n - 1)), name or f"path-{n}")


def star_tree(n: int, name: str = "") -> Tree:
    return Tree(n, tuple((0, i) for i in range(1, n)), name or f"star-{n}")


def spider_tree(legs: Sequence[int], name: str = "") -> Tree:
    """A root with ``len(legs)`` paths of the given lengths attached."""
    edges = []
    nxt = 1
    for L in legs:
        prev = 0
        for _ in range(L):
            edges.append((prev, nxt))
            prev = nxt
            nxt += 1
    return Tree(nxt, tuple(edges), name or f"spider-{'-'.join(map(str, legs))}")


def random_tree(n: int, seed: int = 0) -> Tree:
    """Uniform random labeled tree via a random Prufer sequence."""
    import numpy as np

    rng = np.random.default_rng(seed)
    if n == 1:
        return Tree(1, (), f"rand-{n}-{seed}")
    if n == 2:
        return Tree(2, ((0, 1),), f"rand-{n}-{seed}")
    prufer = rng.integers(0, n, size=n - 2)
    degree = [1] * n
    for p in prufer:
        degree[p] += 1
    edges = []
    import heapq

    leaves = [i for i in range(n) if degree[i] == 1]
    heapq.heapify(leaves)
    for p in prufer:
        leaf = heapq.heappop(leaves)
        edges.append((leaf, int(p)))
        degree[p] -= 1
        if degree[p] == 1:
            heapq.heappush(leaves, int(p))
    u = heapq.heappop(leaves)
    v = heapq.heappop(leaves)
    edges.append((u, v))
    return Tree(n, tuple(edges), f"rand-{n}-{seed}")


# ---------------------------------------------------------------------------
# Canonical form and automorphisms (AHU)
# ---------------------------------------------------------------------------


def _rooted_canon(
    adj: List[List[int]], v: int, parent: int, banned: frozenset = frozenset()
) -> tuple:
    subs = sorted(
        _rooted_canon(adj, u, v, banned)
        for u in adj[v]
        if u != parent and u not in banned
    )
    return tuple(subs)


def _rooted_aut(adj: List[List[int]], v: int, parent: int) -> int:
    """|Aut| of the rooted tree at v: products of child-group factorials."""
    groups: Dict[tuple, int] = {}
    total = 1
    for u in adj[v]:
        if u == parent:
            continue
        c = _rooted_canon(adj, u, v)
        groups[c] = groups.get(c, 0) + 1
        total *= _rooted_aut(adj, u, v)
    for mult in groups.values():
        total *= math.factorial(mult)
    return total


def _centroids(tree: Tree) -> List[int]:
    adj = tree.adjacency()
    n = tree.n
    if n == 1:
        return [0]
    size = [0] * n
    best = [n]
    cents: List[int] = []

    # iterative postorder to compute subtree sizes and max-component
    order = []
    parent = [-1] * n
    stack = [0]
    visited = [False] * n
    while stack:
        v = stack.pop()
        visited[v] = True
        order.append(v)
        for u in adj[v]:
            if not visited[u]:
                parent[u] = v
                stack.append(u)
    for v in reversed(order):
        size[v] = 1 + sum(size[u] for u in adj[v] if parent[u] == v)
    for v in range(n):
        comp = n - size[v]
        for u in adj[v]:
            if parent[u] == v:
                comp = max(comp, size[u])
        if comp < best[0]:
            best[0] = comp
            cents = [v]
        elif comp == best[0]:
            cents.append(v)
    return cents


def canonical_form(tree: Tree) -> tuple:
    """Canonical form of the unrooted tree (rooted at centroid)."""
    adj = tree.adjacency()
    cents = _centroids(tree)
    forms = sorted(_rooted_canon(adj, c, -1) for c in cents)
    return (len(cents),) + tuple(forms)


def automorphism_count(tree: Tree) -> int:
    """|Aut(T)| for the unrooted tree ``T`` (exact, via AHU at centroid)."""
    adj = tree.adjacency()
    cents = _centroids(tree)
    if len(cents) == 1:
        return _rooted_aut(adj, cents[0], -1)
    c1, c2 = cents
    a1 = _rooted_aut(adj, c1, c2)
    a2 = _rooted_aut(adj, c2, c1)
    if _rooted_canon(adj, c1, c2) == _rooted_canon(adj, c2, c1):
        return 2 * a1 * a2
    return a1 * a2


# ---------------------------------------------------------------------------
# Partition chain
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PartitionNode:
    """One node of the binary partition chain.

    ``left``/``right`` index into :class:`PartitionChain.nodes`; -1 for leaf
    nodes (size-1 sub-templates).  ``left`` keeps the root (``T_i'``);
    ``right`` is the cut child subtree (``T_i''``).
    """

    size: int
    left: int = -1
    right: int = -1

    @property
    def is_leaf(self) -> bool:
        return self.left < 0


@dataclass(frozen=True)
class PartitionChain:
    """Postorder list of partition nodes; the last node is the full template."""

    nodes: Tuple[PartitionNode, ...]
    k: int

    @property
    def root_index(self) -> int:
        return len(self.nodes) - 1

    @property
    def roots(self) -> Tuple[int, ...]:
        """Program protocol (shared with :class:`TemplateDag`): root nodes
        whose tables the executor must deliver — here, just the chain root."""
        return (self.root_index,)

    def table_reads(self) -> List[int]:
        """Program protocol: how many times each node's table is read (by
        parents, plus one read per root delivery).  In a chain every node is
        the child of exactly one parent, so every count is 1."""
        return _table_reads(self.nodes, self.roots)

    def postorder(self) -> Tuple[PartitionNode, ...]:
        return self.nodes

    def internal_nodes(self) -> List[Tuple[int, PartitionNode]]:
        return [(i, nd) for i, nd in enumerate(self.nodes) if not nd.is_leaf]

    def profile(self) -> tuple:
        """Nested size profile, e.g. (5, (2, 1, 1), (3, ...))."""

        def rec(i: int):
            nd = self.nodes[i]
            if nd.is_leaf:
                return 1
            return (nd.size, rec(nd.left), rec(nd.right))

        return rec(self.root_index)


def partition_tree(tree: Tree, root: int = 0) -> PartitionChain:
    """Build the partition chain, cutting the first-listed child each time."""
    adj = tree.adjacency()
    nodes: List[PartitionNode] = []

    def rec(v: int, parent: int, banned: frozenset) -> int:
        """Partition the subtree at ``v`` excluding ``banned`` vertices.

        Returns the chain index of the created node.
        """
        children = [u for u in adj[v] if u != parent and u not in banned]
        if not children:
            nodes.append(PartitionNode(1))
            return len(nodes) - 1
        cut = children[0]
        # T'' = subtree rooted at cut (within the current sub-template)
        right = rec(cut, v, banned)
        right_size = nodes[right].size
        # T' = current sub-template minus cut's subtree: ban the cut subtree
        cut_sub = _collect_subtree(adj, cut, v, banned)
        left = rec(v, parent, banned | cut_sub)
        left_size = nodes[left].size
        nodes.append(PartitionNode(left_size + right_size, left, right))
        return len(nodes) - 1

    rec(root, -1, frozenset())
    chain = PartitionChain(tuple(nodes), tree.n)
    assert chain.nodes[chain.root_index].size == tree.n
    return chain


def _collect_subtree(adj, v, parent, banned) -> frozenset:
    out = {v}
    stack = [(v, parent)]
    while stack:
        x, p = stack.pop()
        for u in adj[x]:
            if u != p and u not in banned and u not in out:
                out.add(u)
                stack.append((u, x))
    return frozenset(out)


def partition_complexity(chain: PartitionChain, paper_convention: bool = True):
    """(memory, compute) complexity of a chain; see module docstring.

    With ``paper_convention=True`` only internal nodes with ``1 < t < k``
    count (this reproduces the paper's Table 3); otherwise all non-leaf nodes
    count (the true total table/compute footprint).
    """
    k = chain.k
    mem = 0
    comp = 0
    for _, nd in chain.internal_nodes():
        t = nd.size
        if paper_convention and t >= k:
            continue
        t1 = chain.nodes[nd.left].size
        mem += math.comb(k, t)
        comp += math.comb(k, t) * math.comb(t, t1)
    return mem, comp


# ---------------------------------------------------------------------------
# Template-set compilation: one deduplicated DAG of partition nodes
# ---------------------------------------------------------------------------


def _table_reads(nodes: Sequence[PartitionNode], roots: Sequence[int]) -> List[int]:
    reads = [0] * len(nodes)
    for nd in nodes:
        if not nd.is_leaf:
            reads[nd.left] += 1
            reads[nd.right] += 1
    for r in roots:
        reads[r] += 1
    return reads


@dataclass(frozen=True)
class TemplateDag:
    """A set of partition chains compiled into one deduplicated DAG.

    Each node is a rooted sub-template keyed by its AHU canonical form
    (:func:`_rooted_canon`); canonically-identical subtrees across (and
    within) the compiled templates collapse to a single node, so the DP
    computes every unique subtree table exactly once and each template's
    root simply reads its own entry.  ``nodes`` is topologically ordered
    (children strictly precede parents); ``roots[i]`` is template ``i``'s
    root node; ``sigs[i]`` is node ``i``'s canonical signature.

    All tables are built against the shared color budget ``k`` (>= the
    largest template), which is what makes cross-template reuse sound: a
    node's table ``C[v, S]`` depends only on the rooted sub-template's
    isomorphism class and on ``k``, never on which template asked for it.
    """

    nodes: Tuple[PartitionNode, ...]
    sigs: Tuple[tuple, ...]
    k: int
    roots: Tuple[int, ...]
    templates: Tuple[Tree, ...]

    @property
    def num_templates(self) -> int:
        return len(self.roots)

    def table_reads(self) -> List[int]:
        """Program protocol: reference count per node table (parent reads
        plus root deliveries) — the executor frees a table at count zero."""
        return _table_reads(self.nodes, self.roots)

    def internal_nodes(self) -> List[Tuple[int, PartitionNode]]:
        return [(i, nd) for i, nd in enumerate(self.nodes) if not nd.is_leaf]


def compile_templates(
    templates: Sequence,
    *,
    n_colors: Optional[int] = None,
    roots: Optional[Sequence[int]] = None,
) -> TemplateDag:
    """Compile a family of tree templates into one shared :class:`TemplateDag`.

    ``templates`` are :class:`Tree` objects or registered template names.
    Every template is partitioned with the same first-child cut policy as
    :func:`partition_tree` (rooted at ``roots[i]``, default 0), but nodes are
    interned by rooted canonical signature: when a sub-template's signature
    was already produced — by an earlier template, by an earlier branch of
    the same template, or by a symmetric sibling — the existing node is
    reused instead of re-partitioning it.  A singleton family therefore
    yields a DAG whose root table equals the template's chain root table,
    with intra-template sharing (symmetric branches) already collapsed.

    ``n_colors`` fixes the shared color budget ``k`` (default: the largest
    template size); all compiled tables are indexed by color sets drawn
    from these ``k`` colors.
    """
    trees = tuple(
        template(t) if isinstance(t, str) else t for t in templates
    )
    if not trees:
        raise ValueError("compile_templates needs at least one template")
    k_min = max(t.n for t in trees)
    k = n_colors if n_colors is not None else k_min
    if k < k_min:
        raise ValueError(
            f"n_colors={k} is smaller than the largest template ({k_min})"
        )
    root_of = tuple(roots) if roots is not None else (0,) * len(trees)
    if len(root_of) != len(trees):
        raise ValueError("roots must match templates in length")

    sig2idx: Dict[tuple, int] = {}
    nodes: List[PartitionNode] = []
    sigs: List[tuple] = []

    def intern(sig: tuple, node: PartitionNode) -> int:
        nodes.append(node)
        sigs.append(sig)
        sig2idx[sig] = len(nodes) - 1
        return len(nodes) - 1

    def rec(adj, v: int, parent: int, banned: frozenset) -> int:
        sig = _rooted_canon(adj, v, parent, banned)
        idx = sig2idx.get(sig)
        if idx is not None:
            return idx  # canonically-identical subtree: reuse its table
        children = [u for u in adj[v] if u != parent and u not in banned]
        if not children:
            return intern(sig, PartitionNode(1))
        cut = children[0]
        right = rec(adj, cut, v, banned)
        cut_sub = _collect_subtree(adj, cut, v, banned)
        left = rec(adj, v, parent, banned | cut_sub)
        size = nodes[left].size + nodes[right].size
        return intern(sig, PartitionNode(size, left, right))

    root_ids = []
    for tree, r in zip(trees, root_of):
        adj = tree.adjacency()
        idx = rec(adj, r, -1, frozenset())
        assert nodes[idx].size == tree.n
        root_ids.append(idx)
    return TemplateDag(
        nodes=tuple(nodes),
        sigs=tuple(sigs),
        k=k,
        roots=tuple(root_ids),
        templates=trees,
    )


def rooted_signature(tree, root: int = 0) -> tuple:
    """AHU canonical signature of ``tree`` rooted at ``root``.

    The same signature :func:`compile_templates` interns partition nodes
    by: two templates with equal rooted signatures are isomorphic as rooted
    trees, so they compile to the same DAG node, read the same table
    column, and (being isomorphic unrooted too) carry the same ``|Aut|``
    and scale.  This is the cache key the counting service uses for
    cross-*request* plan reuse — a request never misses the plan cache
    because a tenant labeled its vertices differently.
    """
    t = template(tree) if isinstance(tree, str) else tree
    return _rooted_canon(t.adjacency(), root, -1)


def family_signature(templates: Sequence, n_colors: Optional[int] = None) -> tuple:
    """Order-insensitive identity of a compiled template family.

    ``(k, sorted unique rooted signatures)`` — the complete identity of the
    DAG :func:`compile_templates` produces up to column order: the node
    tables depend only on each rooted sub-template's isomorphism class and
    the shared color budget ``k``.  Families that differ only in template
    order or duplicates share one cache entry.
    """
    trees = tuple(template(t) if isinstance(t, str) else t for t in templates)
    if not trees:
        raise ValueError("family_signature needs at least one template")
    k_min = max(t.n for t in trees)
    k = n_colors if n_colors is not None else k_min
    if k < k_min:
        raise ValueError(
            f"n_colors={k} is smaller than the largest template ({k_min})"
        )
    return (k, tuple(sorted(set(rooted_signature(t) for t in trees))))


# ---------------------------------------------------------------------------
# Profile realization: build a tree whose first-child partition reproduces a
# given nested size profile.
# ---------------------------------------------------------------------------


def realize_profile(profile, name: str = "") -> Tree:
    """Build a Tree whose partition chain has the given nested profile.

    A profile is ``1`` (single vertex) or ``(t, left_profile, right_profile)``
    where left keeps the root.  The cut child is attached *first* so that
    :func:`partition_tree`'s first-child policy cuts it.
    """
    edges: List[Tuple[int, int]] = []
    counter = [0]

    def rec(prof) -> int:
        """Returns root vertex id of the realized sub-tree."""
        if prof == 1:
            v = counter[0]
            counter[0] += 1
            return v
        _, left, right = prof
        # Realize the cut subtree first so it is the first child of the root.
        # Order of construction: root comes from left profile; right subtree
        # attaches to it as the FIRST child in adjacency insertion order.
        # We must create the left root before the right subtree would claim
        # adjacency priority; edges are inserted right-first below.
        right_root_placeholder: List[int] = []

        def build_right():
            r = rec(right)
            right_root_placeholder.append(r)
            return r

        # build left structure, get its root id
        lroot = rec(left)
        rroot = build_right()
        # attach: insert edge so that rroot is FIRST child of lroot.
        edges.insert(0, (lroot, rroot))
        return lroot

    root = rec(profile)
    n = counter[0]
    t = Tree(n, tuple(edges), name)
    # sanity: the realized tree must reproduce the profile
    got = partition_tree(t, root=root).profile()
    want = profile
    if got != want:
        raise AssertionError(f"profile realization failed: got {got}, want {want}")
    return t


# NOTE on ordering: Tree.adjacency() inserts neighbors in edge-list order, so
# prepending the (root, cut-child) edge makes the cut child the first-listed
# child at every level. realize_profile asserts this invariant.


# ---------------------------------------------------------------------------
# Named templates (paper Fig. 5 / Table 3)
# ---------------------------------------------------------------------------
# Profiles found by tools/find_templates.py to exactly reproduce Table 3's
# (memory, compute) complexity figures under the paper's convention.  Shapes
# for u3-1/u5-2/u7-2 are derived analytically (path-3, path-5, 2-leg spider).
# Larger profiles are search results; see EXPERIMENTS.md for the comparison
# table.  Filled by _register_named_templates().

TEMPLATES: Dict[str, Tree] = {}
TEMPLATE_TABLE3 = {
    # name: (memory, compute) from paper Table 3
    "u3-1": (3, 6),
    "u5-2": (25, 70),
    "u7-2": (147, 434),
    "u10-2": (1047, 5610),
    "u12-1": (4082, 24552),
    "u12-2": (3135, 38016),
    "u13": (4823, 109603),
    "u14": (7371, 242515),
    "u15-1": (12383, 753375),
    "u15-2": (15773, 617820),
}

# Nested split profiles (filled in from the profile search; see
# tools/find_templates.py).  ``1`` = leaf; ``(t, left, right)`` = internal.
_P3 = (3, (2, 1, 1), 1)
_P5 = (5, (4, (3, (2, 1, 1), 1), 1), 1)
_P7 = (7, (4, (3, (2, 1, 1), 1), 1), (3, (2, 1, 1), 1))

_NAMED_PROFILES: Dict[str, tuple] = {
    "u3-1": _P3,
    "u5-2": _P5,
    "u7-2": _P7,
    # The remaining profiles are injected by tools/find_templates.py output;
    # see _SEARCHED_PROFILES below.
}


# Placeholder dict — populated with search results (kept as data so import
# never depends on the search tool).
_SEARCHED_PROFILES: Dict[str, tuple] = {}

try:  # pragma: no cover - exercised indirectly
    from repro.core._template_profiles import SEARCHED_PROFILES as _SP

    _SEARCHED_PROFILES.update(_SP)
except ImportError:
    pass

_NAMED_PROFILES.update(_SEARCHED_PROFILES)


def _register_named_templates() -> None:
    for nm, prof in _NAMED_PROFILES.items():
        try:
            TEMPLATES[nm] = realize_profile(prof, name=nm)
        except AssertionError:
            # refuse to register a broken realization
            raise


_register_named_templates()


def template(name: str) -> Tree:
    """Look up a named template (u3-1 .. u15-2)."""
    if name not in TEMPLATES:
        raise KeyError(f"unknown template {name!r}; have {sorted(TEMPLATES)}")
    return TEMPLATES[name]
