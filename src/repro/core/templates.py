"""Tree templates ("treelets"), partition chains, and automorphism counting.

A template is an unrooted tree ``T`` on ``k`` vertices.  Color-coding
partitions a rooted copy of ``T`` recursively: at each step the sub-template
``T_i`` (rooted at ``rho``) is split by cutting the edge to one child ``c``,
producing ``T_i'`` (same root, without ``c``'s subtree) and ``T_i''`` (``c``'s
subtree rooted at ``c``).  The result is a binary *partition chain* whose
leaves are single vertices.  The DP computes one count table per chain node,
in postorder.

The paper's Table 3 complexity figures are reproduced by
:func:`partition_complexity` with the paper's convention (sum over internal
nodes ``1 < |T_i| < k``):

    memory  = sum_i C(k, |T_i|)
    compute = sum_i C(k, |T_i|) * C(|T_i|, |T_i'|)

Because both quantities depend only on the *split profile* (the binary tree
of sizes), the named templates below are realized from profiles found to
exactly match Table 3 (see ``tools/find_templates.py``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Tree",
    "Template",
    "PartitionNode",
    "BagNode",
    "PartitionChain",
    "BagProgram",
    "TemplateDag",
    "compile_templates",
    "rooted_signature",
    "family_signature",
    "partition_tree",
    "bag_program",
    "template_program",
    "program_has_bags",
    "partition_complexity",
    "automorphism_count",
    "canonical_form",
    "path_tree",
    "star_tree",
    "spider_tree",
    "cycle_template",
    "random_tree",
    "realize_profile",
    "TEMPLATES",
    "template",
]


# ---------------------------------------------------------------------------
# Tree representation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Tree:
    """An unrooted tree on ``n`` vertices given by an edge list.

    ``children_order`` matters only through the partition cut policy: the
    partition always cuts the *first* child (in adjacency insertion order) of
    the current root, which lets profile-realized trees reproduce their
    profile exactly.
    """

    n: int
    edges: Tuple[Tuple[int, int], ...]
    name: str = ""

    def __post_init__(self):
        if len(self.edges) != self.n - 1:
            raise ValueError(
                f"tree on {self.n} vertices needs {self.n - 1} edges, got {len(self.edges)}"
            )
        seen = set()
        adj = self.adjacency()
        # connectivity check (BFS)
        stack, seen = [0], {0}
        while stack:
            v = stack.pop()
            for u in adj[v]:
                if u not in seen:
                    seen.add(u)
                    stack.append(u)
        if len(seen) != self.n:
            raise ValueError("edge list does not describe a connected tree")

    def adjacency(self) -> List[List[int]]:
        adj: List[List[int]] = [[] for _ in range(self.n)]
        for a, b in self.edges:
            adj[a].append(b)
            adj[b].append(a)
        return adj

    @property
    def k(self) -> int:
        """Number of colors used for this template (== template size)."""
        return self.n


def path_tree(n: int, name: str = "") -> Tree:
    return Tree(n, tuple((i, i + 1) for i in range(n - 1)), name or f"path-{n}")


def star_tree(n: int, name: str = "") -> Tree:
    return Tree(n, tuple((0, i) for i in range(1, n)), name or f"star-{n}")


def spider_tree(legs: Sequence[int], name: str = "") -> Tree:
    """A root with ``len(legs)`` paths of the given lengths attached."""
    edges = []
    nxt = 1
    for L in legs:
        prev = 0
        for _ in range(L):
            edges.append((prev, nxt))
            prev = nxt
            nxt += 1
    return Tree(nxt, tuple(edges), name or f"spider-{'-'.join(map(str, legs))}")


# ---------------------------------------------------------------------------
# General (non-tree) templates: connected simple graphs of small treewidth
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Template:
    """An unrooted connected simple graph on ``n`` vertices.

    The treewidth-2 front-end (:func:`bag_program`) compiles a ``Template``
    into a bag-table program by pinning an *apex* vertex whose removal leaves
    a forest — cycles, the diamond, the bowtie, the house, and every other
    small pattern with a one-vertex feedback set.  A ``Template`` that happens
    to be a tree (``is_tree``) is converted with :meth:`as_tree` and compiled
    through the ordinary :func:`partition_tree` path, bit-identically.
    """

    n: int
    edges: Tuple[Tuple[int, int], ...]
    name: str = ""

    def __post_init__(self):
        seen_edges = set()
        for a, b in self.edges:
            if a == b:
                raise ValueError(f"template has a self-loop at vertex {a}")
            if not (0 <= a < self.n and 0 <= b < self.n):
                raise ValueError(f"edge ({a}, {b}) out of range for n={self.n}")
            e = (min(a, b), max(a, b))
            if e in seen_edges:
                raise ValueError(f"duplicate edge {e} in template")
            seen_edges.add(e)
        adj = self.adjacency()
        stack, seen = [0], {0}
        while stack:
            v = stack.pop()
            for u in adj[v]:
                if u not in seen:
                    seen.add(u)
                    stack.append(u)
        if len(seen) != self.n:
            raise ValueError("edge list does not describe a connected graph")

    def adjacency(self) -> List[List[int]]:
        adj: List[List[int]] = [[] for _ in range(self.n)]
        for a, b in self.edges:
            adj[a].append(b)
            adj[b].append(a)
        return adj

    @property
    def k(self) -> int:
        """Number of colors used for this template (== template size)."""
        return self.n

    @property
    def is_tree(self) -> bool:
        return len(self.edges) == self.n - 1

    def as_tree(self) -> Tree:
        """The same graph as a :class:`Tree` (valid only when ``is_tree``)."""
        if not self.is_tree:
            raise ValueError(f"template {self.name!r} is not a tree")
        return Tree(self.n, self.edges, self.name)


def cycle_template(n: int, name: str = "") -> Template:
    if n < 3:
        raise ValueError("cycles need at least 3 vertices")
    return Template(n, tuple((i, (i + 1) % n) for i in range(n)), name or f"cycle{n}")


def random_tree(n: int, seed: int = 0) -> Tree:
    """Uniform random labeled tree via a random Prufer sequence."""
    import numpy as np

    rng = np.random.default_rng(seed)
    if n == 1:
        return Tree(1, (), f"rand-{n}-{seed}")
    if n == 2:
        return Tree(2, ((0, 1),), f"rand-{n}-{seed}")
    prufer = rng.integers(0, n, size=n - 2)
    degree = [1] * n
    for p in prufer:
        degree[p] += 1
    edges = []
    import heapq

    leaves = [i for i in range(n) if degree[i] == 1]
    heapq.heapify(leaves)
    for p in prufer:
        leaf = heapq.heappop(leaves)
        edges.append((leaf, int(p)))
        degree[p] -= 1
        if degree[p] == 1:
            heapq.heappush(leaves, int(p))
    u = heapq.heappop(leaves)
    v = heapq.heappop(leaves)
    edges.append((u, v))
    return Tree(n, tuple(edges), f"rand-{n}-{seed}")


# ---------------------------------------------------------------------------
# Canonical form and automorphisms (AHU)
# ---------------------------------------------------------------------------


def _rooted_canon(
    adj: List[List[int]], v: int, parent: int, banned: frozenset = frozenset()
) -> tuple:
    subs = sorted(
        _rooted_canon(adj, u, v, banned)
        for u in adj[v]
        if u != parent and u not in banned
    )
    return tuple(subs)


def _rooted_aut(adj: List[List[int]], v: int, parent: int) -> int:
    """|Aut| of the rooted tree at v: products of child-group factorials."""
    groups: Dict[tuple, int] = {}
    total = 1
    for u in adj[v]:
        if u == parent:
            continue
        c = _rooted_canon(adj, u, v)
        groups[c] = groups.get(c, 0) + 1
        total *= _rooted_aut(adj, u, v)
    for mult in groups.values():
        total *= math.factorial(mult)
    return total


def _centroids(tree: Tree) -> List[int]:
    adj = tree.adjacency()
    n = tree.n
    if n == 1:
        return [0]
    size = [0] * n
    best = [n]
    cents: List[int] = []

    # iterative postorder to compute subtree sizes and max-component
    order = []
    parent = [-1] * n
    stack = [0]
    visited = [False] * n
    while stack:
        v = stack.pop()
        visited[v] = True
        order.append(v)
        for u in adj[v]:
            if not visited[u]:
                parent[u] = v
                stack.append(u)
    for v in reversed(order):
        size[v] = 1 + sum(size[u] for u in adj[v] if parent[u] == v)
    for v in range(n):
        comp = n - size[v]
        for u in adj[v]:
            if parent[u] == v:
                comp = max(comp, size[u])
        if comp < best[0]:
            best[0] = comp
            cents = [v]
        elif comp == best[0]:
            cents.append(v)
    return cents


def canonical_form(tree: Tree) -> tuple:
    """Canonical form of the unrooted tree (rooted at centroid)."""
    adj = tree.adjacency()
    cents = _centroids(tree)
    forms = sorted(_rooted_canon(adj, c, -1) for c in cents)
    return (len(cents),) + tuple(forms)


def _graph_aut(t: Template) -> int:
    """|Aut| of a small general graph by degree-pruned backtracking."""
    if t.n > 10:
        raise ValueError(f"automorphism backtracking capped at n=10, got n={t.n}")
    adj = [set(ns) for ns in t.adjacency()]
    deg = [len(a) for a in adj]
    n = t.n
    perm = [-1] * n
    used = [False] * n
    count = 0

    def rec(i: int) -> None:
        nonlocal count
        if i == n:
            count += 1
            return
        for img in range(n):
            if used[img] or deg[img] != deg[i]:
                continue
            if all((j in adj[i]) == (perm[j] in adj[img]) for j in range(i)):
                perm[i] = img
                used[img] = True
                rec(i + 1)
                used[img] = False
        perm[i] = -1

    rec(0)
    return count


def automorphism_count(tree) -> int:
    """|Aut(T)| — AHU at the centroid for trees, backtracking for templates."""
    if isinstance(tree, Template):
        if tree.is_tree:
            return automorphism_count(tree.as_tree())
        return _graph_aut(tree)
    adj = tree.adjacency()
    cents = _centroids(tree)
    if len(cents) == 1:
        return _rooted_aut(adj, cents[0], -1)
    c1, c2 = cents
    a1 = _rooted_aut(adj, c1, c2)
    a2 = _rooted_aut(adj, c2, c1)
    if _rooted_canon(adj, c1, c2) == _rooted_canon(adj, c2, c1):
        return 2 * a1 * a2
    return a1 * a2


# ---------------------------------------------------------------------------
# Apex-pinned tree decomposition (treewidth <= 2)
# ---------------------------------------------------------------------------
# A non-tree Template is compiled by choosing an *apex* vertex ``a`` whose
# removal leaves a forest F (a one-vertex feedback set; every cycle of the
# template passes through ``a``).  This is a width-2 tree decomposition in
# normal form: the apex sits in every bag {a, v, parent(v)} along each
# forest tree's partition spine, so bag tables carry one extra index ``x``
# (the host vertex the apex is mapped to) next to the usual (v, S).


def _marked_canon(
    adj: List[List[int]],
    D: frozenset,
    v: int,
    parent: int,
    banned: frozenset = frozenset(),
) -> tuple:
    """AHU canonical form of a forest subtree with apex-adjacency marks.

    Like :func:`_rooted_canon` but each vertex additionally carries whether
    it is adjacent to the apex (``v in D``) — the mark changes the leaf
    table (pinned vs broadcast), so interning must distinguish it.
    """
    subs = sorted(
        _marked_canon(adj, D, u, v, banned)
        for u in adj[v]
        if u != parent and u not in banned
    )
    return (v in D, tuple(subs))


def _apex_plan(t: Template):
    """Choose the canonical apex of ``t`` and decompose the rest into trees.

    Returns ``(apex, forest_adj, D, roots)``: the apex vertex, the forest
    adjacency (apex removed), the set ``D`` of forest vertices adjacent to
    the apex, and one canonically-chosen root per forest tree, ordered by
    ``(size, marked canonical form)`` so equal decompositions — across
    templates and across vertex labelings — compile to identical programs.
    """
    best_key = None
    best = None
    for a in range(t.n):
        forest_adj: List[List[int]] = [[] for _ in range(t.n)]
        ok = True
        parent_uf = list(range(t.n))

        def find(x: int) -> int:
            while parent_uf[x] != x:
                parent_uf[x] = parent_uf[parent_uf[x]]
                x = parent_uf[x]
            return x

        for u, w in t.edges:
            if a in (u, w):
                continue
            ru, rw = find(u), find(w)
            if ru == rw:
                ok = False  # T - a still has a cycle: a is not an apex
                break
            parent_uf[ru] = rw
            forest_adj[u].append(w)
            forest_adj[w].append(u)
        if not ok:
            continue
        D = frozenset(u for u, w in t.edges if w == a) | frozenset(w for u, w in t.edges if u == a)
        # forest components, each rooted at its canonically-minimal vertex
        seen: set = set()
        trees = []
        for v0 in range(t.n):
            if v0 == a or v0 in seen:
                continue
            comp = [v0]
            seen.add(v0)
            stack = [v0]
            while stack:
                v = stack.pop()
                for u in forest_adj[v]:
                    if u not in seen:
                        seen.add(u)
                        comp.append(u)
                        stack.append(u)
            root = min(comp, key=lambda v: _marked_canon(forest_adj, D, v, -1))
            trees.append((len(comp), _marked_canon(forest_adj, D, root, -1), root))
        trees.sort(key=lambda e: (e[0], e[1]))
        key = tuple((s, c) for s, c, _ in trees)
        if best_key is None or key < best_key:
            best_key = key
            best = (a, forest_adj, D, tuple(r for _, _, r in trees))
    if best is None:
        raise ValueError(
            f"template {t.name or t.edges!r} is not apex-reducible: removing no "
            "single vertex leaves a forest.  The treewidth-2 front-end supports "
            "templates with a one-vertex feedback set (cycles, diamond, bowtie, "
            "house, chordal fans) — decompose wider patterns by hand or extend "
            "bag_program to multi-vertex bags."
        )
    return best


# ---------------------------------------------------------------------------
# Partition chain
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PartitionNode:
    """One node of the binary partition chain.

    ``left``/``right`` index into :class:`PartitionChain.nodes`; -1 for leaf
    nodes (size-1 sub-templates).  ``left`` keeps the root (``T_i'``);
    ``right`` is the cut child subtree (``T_i''``).
    """

    size: int
    left: int = -1
    right: int = -1

    @property
    def is_leaf(self) -> bool:
        return self.left < 0

    @property
    def kind(self) -> str:
        """Node-kind protocol shared with :class:`BagNode`."""
        return "leaf" if self.is_leaf else "combine"

    @property
    def children(self) -> Tuple[int, ...]:
        return () if self.is_leaf else (self.left, self.right)


@dataclass(frozen=True)
class BagNode:
    """One node of a bag-table program (treewidth-2 front-end).

    Bag tables are indexed by ``(v, x, S)``: the current sub-template root
    mapped to host vertex ``v``, the pinned apex mapped to ``x``, and the
    color set ``S`` of the *forest* vertices covered so far (``size`` of
    them; the apex color is outside ``S`` by the collapse filter).  Kinds:

    * ``bag_leaf`` — a single forest vertex; ``pin=True`` when it is
      adjacent to the apex in the template, which multiplies the one-hot
      color table by the host adjacency ``A[x, v]``.
    * ``bag_combine`` — the ordinary tree combine (cut-first-child) run on
      bag tables; same SpMM + color convolution, width ``x * s_pad``.
    * ``bag_collapse`` — sum the finished forest-tree table over ``v`` and
      apply the apex-color filter ``col(x) not in S``; output rows are
      the ``x`` axis (unary: only ``left`` is set).
    * ``bag_join`` — disjoint color-set convolution of two collapsed
      forest-tree tables on aligned ``x`` rows (multi-tree forests, e.g.
      the bowtie).
    """

    kind: str
    size: int
    left: int = -1
    right: int = -1
    pin: bool = False

    @property
    def is_leaf(self) -> bool:
        return self.kind == "bag_leaf"

    @property
    def children(self) -> Tuple[int, ...]:
        if self.kind == "bag_leaf":
            return ()
        if self.kind == "bag_collapse":
            return (self.left,)
        return (self.left, self.right)


@dataclass(frozen=True)
class PartitionChain:
    """Postorder list of partition nodes; the last node is the full template."""

    nodes: Tuple[PartitionNode, ...]
    k: int

    @property
    def root_index(self) -> int:
        return len(self.nodes) - 1

    @property
    def roots(self) -> Tuple[int, ...]:
        """Program protocol (shared with :class:`TemplateDag`): root nodes
        whose tables the executor must deliver — here, just the chain root."""
        return (self.root_index,)

    def table_reads(self) -> List[int]:
        """Program protocol: how many times each node's table is read (by
        parents, plus one read per root delivery).  In a chain every node is
        the child of exactly one parent, so every count is 1."""
        return _table_reads(self.nodes, self.roots)

    def postorder(self) -> Tuple[PartitionNode, ...]:
        return self.nodes

    def internal_nodes(self) -> List[Tuple[int, PartitionNode]]:
        return [(i, nd) for i, nd in enumerate(self.nodes) if not nd.is_leaf]

    def profile(self) -> tuple:
        """Nested size profile, e.g. (5, (2, 1, 1), (3, ...))."""

        def rec(i: int):
            nd = self.nodes[i]
            if nd.is_leaf:
                return 1
            return (nd.size, rec(nd.left), rec(nd.right))

        return rec(self.root_index)


def partition_tree(tree: Tree, root: int = 0) -> PartitionChain:
    """Build the partition chain, cutting the first-listed child each time."""
    adj = tree.adjacency()
    nodes: List[PartitionNode] = []

    def rec(v: int, parent: int, banned: frozenset) -> int:
        """Partition the subtree at ``v`` excluding ``banned`` vertices.

        Returns the chain index of the created node.
        """
        children = [u for u in adj[v] if u != parent and u not in banned]
        if not children:
            nodes.append(PartitionNode(1))
            return len(nodes) - 1
        cut = children[0]
        # T'' = subtree rooted at cut (within the current sub-template)
        right = rec(cut, v, banned)
        right_size = nodes[right].size
        # T' = current sub-template minus cut's subtree: ban the cut subtree
        cut_sub = _collect_subtree(adj, cut, v, banned)
        left = rec(v, parent, banned | cut_sub)
        left_size = nodes[left].size
        nodes.append(PartitionNode(left_size + right_size, left, right))
        return len(nodes) - 1

    rec(root, -1, frozenset())
    chain = PartitionChain(tuple(nodes), tree.n)
    assert chain.nodes[chain.root_index].size == tree.n
    return chain


def _collect_subtree(adj, v, parent, banned) -> frozenset:
    out = {v}
    stack = [(v, parent)]
    while stack:
        x, p = stack.pop()
        for u in adj[x]:
            if u != p and u not in banned and u not in out:
                out.add(u)
                stack.append((u, x))
    return frozenset(out)


def partition_complexity(chain: PartitionChain, paper_convention: bool = True):
    """(memory, compute) complexity of a chain; see module docstring.

    With ``paper_convention=True`` only internal nodes with ``1 < t < k``
    count (this reproduces the paper's Table 3); otherwise all non-leaf nodes
    count (the true total table/compute footprint).
    """
    k = chain.k
    mem = 0
    comp = 0
    for _, nd in chain.internal_nodes():
        t = nd.size
        if paper_convention and t >= k:
            continue
        t1 = chain.nodes[nd.left].size
        mem += math.comb(k, t)
        comp += math.comb(k, t) * math.comb(t, t1)
    return mem, comp


# ---------------------------------------------------------------------------
# Template-set compilation: one deduplicated DAG of partition nodes
# ---------------------------------------------------------------------------


def _table_reads(nodes: Sequence, roots: Sequence[int]) -> List[int]:
    reads = [0] * len(nodes)
    for nd in nodes:
        for c in nd.children:
            reads[c] += 1
    for r in roots:
        reads[r] += 1
    return reads


class _Interner:
    """Signature-keyed node interning shared by tree and bag compilation."""

    def __init__(self):
        self.sig2idx: Dict[tuple, int] = {}
        self.nodes: List = []
        self.sigs: List[tuple] = []

    def get(self, sig: tuple) -> Optional[int]:
        return self.sig2idx.get(sig)

    def put(self, sig: tuple, node) -> int:
        self.nodes.append(node)
        self.sigs.append(sig)
        self.sig2idx[sig] = len(self.nodes) - 1
        return len(self.nodes) - 1


def _compile_tree(it: _Interner, adj, v: int, parent: int, banned: frozenset) -> int:
    """Interned first-child partition of a (sub)tree; tree sigs are the raw
    AHU tuples, so they can never collide with the tagged bag signatures."""
    sig = _rooted_canon(adj, v, parent, banned)
    idx = it.get(sig)
    if idx is not None:
        return idx  # canonically-identical subtree: reuse its table
    children = [u for u in adj[v] if u != parent and u not in banned]
    if not children:
        return it.put(sig, PartitionNode(1))
    cut = children[0]
    right = _compile_tree(it, adj, cut, v, banned)
    cut_sub = _collect_subtree(adj, cut, v, banned)
    left = _compile_tree(it, adj, v, parent, banned | cut_sub)
    size = it.nodes[left].size + it.nodes[right].size
    return it.put(sig, PartitionNode(size, left, right))


def _compile_bag_tree(
    it: _Interner, adj, D: frozenset, v: int, parent: int, banned: frozenset
) -> int:
    """Forest-tree recursion on bag tables: same cut policy, marked sigs."""
    children = [u for u in adj[v] if u != parent and u not in banned]
    if not children:
        pin = v in D
        sig = ("bagleaf", pin)
        idx = it.get(sig)
        if idx is not None:
            return idx
        return it.put(sig, BagNode("bag_leaf", 1, pin=pin))
    sig = ("bagc", _marked_canon(adj, D, v, parent, banned))
    idx = it.get(sig)
    if idx is not None:
        return idx
    cut = children[0]
    right = _compile_bag_tree(it, adj, D, cut, v, banned)
    cut_sub = _collect_subtree(adj, cut, v, banned)
    left = _compile_bag_tree(it, adj, D, v, parent, banned | cut_sub)
    size = it.nodes[left].size + it.nodes[right].size
    return it.put(sig, BagNode("bag_combine", size, left, right))


def _compile_bag(it: _Interner, t: Template) -> int:
    """Compile one non-tree template: per-forest-tree DP, collapse, join."""
    _, forest_adj, D, roots = _apex_plan(t)
    collapsed = []
    for r in roots:
        root_idx = _compile_bag_tree(it, forest_adj, D, r, -1, frozenset())
        sig = ("bagcol", it.sigs[root_idx])
        idx = it.get(sig)
        if idx is None:
            idx = it.put(sig, BagNode("bag_collapse", it.nodes[root_idx].size, root_idx))
        collapsed.append((it.nodes[idx].size, sig, idx))
    # canonical left-deep join order: joins are commutative, so sort first
    collapsed.sort(key=lambda e: (e[0], e[1]))
    cur_size, cur_sig, cur = collapsed[0]
    for nxt_size, nxt_sig, nxt in collapsed[1:]:
        sig = ("bagjoin", cur_sig, nxt_sig)
        idx = it.get(sig)
        if idx is None:
            idx = it.put(sig, BagNode("bag_join", cur_size + nxt_size, cur, nxt))
        cur_size, cur_sig, cur = cur_size + nxt_size, sig, idx
    return cur


@dataclass(frozen=True)
class BagProgram:
    """Bag-table program for one apex-reducible (non-tree) template.

    Program-protocol sibling of :class:`PartitionChain` (``roots`` /
    ``table_reads``): a postorder list of :class:`BagNode` whose last node
    — the final collapse or join — is the root.  The root table's rows are
    the apex axis ``x``; summing it (after the apex-color filter) over
    ``(x, S)`` counts colorful template maps, exactly as summing a chain
    root over ``(v, S)`` does for trees.
    """

    nodes: Tuple[BagNode, ...]
    k: int
    template: Template

    @property
    def root_index(self) -> int:
        return len(self.nodes) - 1

    @property
    def roots(self) -> Tuple[int, ...]:
        return (self.root_index,)

    def table_reads(self) -> List[int]:
        return _table_reads(self.nodes, self.roots)

    def internal_nodes(self) -> List[Tuple[int, BagNode]]:
        return [(i, nd) for i, nd in enumerate(self.nodes) if not nd.is_leaf]


def bag_program(t: Template, *, n_colors: Optional[int] = None) -> BagProgram:
    """Compile a non-tree :class:`Template` into a :class:`BagProgram`."""
    if t.is_tree:
        raise ValueError(f"template {t.name!r} is a tree — use partition_tree(t.as_tree())")
    k = n_colors if n_colors is not None else t.n
    if k < t.n:
        raise ValueError(f"n_colors={k} is smaller than the template ({t.n})")
    it = _Interner()
    idx = _compile_bag(it, t)
    assert idx == len(it.nodes) - 1 and it.nodes[idx].size == t.n - 1
    return BagProgram(nodes=tuple(it.nodes), k=k, template=t)


def template_program(t, root: int = 0):
    """The single-template program, dispatching on template shape.

    Trees (and tree-shaped :class:`Template` objects) get the classic
    :func:`partition_tree` chain, bit-identically; apex-reducible non-trees
    get a :class:`BagProgram`.
    """
    t = template(t) if isinstance(t, str) else t
    if isinstance(t, Template):
        if not t.is_tree:
            return bag_program(t)
        t = t.as_tree()
    return partition_tree(t, root=root)


def program_has_bags(program) -> bool:
    """True when any node of the program needs the bag execution strategy."""
    return any(isinstance(nd, BagNode) for nd in program.nodes)


@dataclass(frozen=True)
class TemplateDag:
    """A set of partition chains compiled into one deduplicated DAG.

    Each node is a rooted sub-template keyed by its AHU canonical form
    (:func:`_rooted_canon`); canonically-identical subtrees across (and
    within) the compiled templates collapse to a single node, so the DP
    computes every unique subtree table exactly once and each template's
    root simply reads its own entry.  ``nodes`` is topologically ordered
    (children strictly precede parents); ``roots[i]`` is template ``i``'s
    root node; ``sigs[i]`` is node ``i``'s canonical signature.

    All tables are built against the shared color budget ``k`` (>= the
    largest template), which is what makes cross-template reuse sound: a
    node's table ``C[v, S]`` depends only on the rooted sub-template's
    isomorphism class and on ``k``, never on which template asked for it.
    """

    nodes: Tuple[PartitionNode, ...]
    sigs: Tuple[tuple, ...]
    k: int
    roots: Tuple[int, ...]
    templates: Tuple[Tree, ...]

    @property
    def num_templates(self) -> int:
        return len(self.roots)

    def table_reads(self) -> List[int]:
        """Program protocol: reference count per node table (parent reads
        plus root deliveries) — the executor frees a table at count zero."""
        return _table_reads(self.nodes, self.roots)

    def internal_nodes(self) -> List[Tuple[int, PartitionNode]]:
        return [(i, nd) for i, nd in enumerate(self.nodes) if not nd.is_leaf]


def compile_templates(
    templates: Sequence,
    *,
    n_colors: Optional[int] = None,
    roots: Optional[Sequence[int]] = None,
) -> TemplateDag:
    """Compile a family of tree templates into one shared :class:`TemplateDag`.

    ``templates`` are :class:`Tree` objects or registered template names.
    Every template is partitioned with the same first-child cut policy as
    :func:`partition_tree` (rooted at ``roots[i]``, default 0), but nodes are
    interned by rooted canonical signature: when a sub-template's signature
    was already produced — by an earlier template, by an earlier branch of
    the same template, or by a symmetric sibling — the existing node is
    reused instead of re-partitioning it.  A singleton family therefore
    yields a DAG whose root table equals the template's chain root table,
    with intra-template sharing (symmetric branches) already collapsed.

    Non-tree :class:`Template` members compile through the apex-pinned bag
    path (:func:`bag_program`'s machinery) into the same interner, so mixed
    tree+cycle families share one DAG: bag sub-trees intern across templates
    by marked canonical form, and tree-shaped ``Template`` objects are
    converted to :class:`Tree` up front so they land on the identical
    tree-node path (bit-identical degeneration).

    ``n_colors`` fixes the shared color budget ``k`` (default: the largest
    template size); all compiled tables are indexed by color sets drawn
    from these ``k`` colors.
    """
    trees = tuple(template(t) if isinstance(t, str) else t for t in templates)
    trees = tuple(t.as_tree() if isinstance(t, Template) and t.is_tree else t for t in trees)
    if not trees:
        raise ValueError("compile_templates needs at least one template")
    k_min = max(t.n for t in trees)
    k = n_colors if n_colors is not None else k_min
    if k < k_min:
        raise ValueError(f"n_colors={k} is smaller than the largest template ({k_min})")
    root_of = tuple(roots) if roots is not None else (0,) * len(trees)
    if len(root_of) != len(trees):
        raise ValueError("roots must match templates in length")

    it = _Interner()
    root_ids = []
    for tree, r in zip(trees, root_of):
        if isinstance(tree, Template):
            idx = _compile_bag(it, tree)
            # bag roots cover the forest (apex pinned on the x axis)
            assert it.nodes[idx].size == tree.n - 1
        else:
            idx = _compile_tree(it, tree.adjacency(), r, -1, frozenset())
            assert it.nodes[idx].size == tree.n
        root_ids.append(idx)
    return TemplateDag(
        nodes=tuple(it.nodes),
        sigs=tuple(it.sigs),
        k=k,
        roots=tuple(root_ids),
        templates=trees,
    )


def rooted_signature(tree, root: int = 0) -> tuple:
    """AHU canonical signature of ``tree`` rooted at ``root``.

    The same signature :func:`compile_templates` interns partition nodes
    by: two templates with equal rooted signatures are isomorphic as rooted
    trees, so they compile to the same DAG node, read the same table
    column, and (being isomorphic unrooted too) carry the same ``|Aut|``
    and scale.  This is the cache key the counting service uses for
    cross-*request* plan reuse — a request never misses the plan cache
    because a tenant labeled its vertices differently.

    Non-tree templates get a tagged apex-decomposition signature instead
    (``root`` is ignored — bag programs have a canonical apex, not a root
    choice); it is a complete isomorphism invariant for apex-reducible
    graphs, so the same cache-identity contract holds.
    """
    t = template(tree) if isinstance(tree, str) else tree
    if isinstance(t, Template):
        if not t.is_tree:
            return _bag_signature(t)
        t = t.as_tree()
    return _rooted_canon(t.adjacency(), root, -1)


def _bag_signature(t: Template) -> tuple:
    """Label-independent identity of the apex decomposition of ``t``.

    The marked forest (component canonical forms + apex-adjacency marks)
    reconstructs the template up to isomorphism, and the apex choice is
    itself canonical, so equal signatures mean isomorphic templates."""
    _, forest_adj, D, roots = _apex_plan(t)

    def canon_size(c: tuple) -> int:
        return 1 + sum(canon_size(s) for s in c[1])

    canons = [_marked_canon(forest_adj, D, r, -1) for r in roots]
    return ("bag", tuple(sorted((canon_size(c), c) for c in canons)))


def family_signature(templates: Sequence, n_colors: Optional[int] = None) -> tuple:
    """Order-insensitive identity of a compiled template family.

    ``(k, sorted unique rooted signatures)`` — the complete identity of the
    DAG :func:`compile_templates` produces up to column order: the node
    tables depend only on each rooted sub-template's isomorphism class and
    the shared color budget ``k``.  Families that differ only in template
    order or duplicates share one cache entry.
    """
    trees = tuple(template(t) if isinstance(t, str) else t for t in templates)
    if not trees:
        raise ValueError("family_signature needs at least one template")
    k_min = max(t.n for t in trees)
    k = n_colors if n_colors is not None else k_min
    if k < k_min:
        raise ValueError(f"n_colors={k} is smaller than the largest template ({k_min})")
    return (k, tuple(sorted(set(rooted_signature(t) for t in trees))))


# ---------------------------------------------------------------------------
# Profile realization: build a tree whose first-child partition reproduces a
# given nested size profile.
# ---------------------------------------------------------------------------


def realize_profile(profile, name: str = "") -> Tree:
    """Build a Tree whose partition chain has the given nested profile.

    A profile is ``1`` (single vertex) or ``(t, left_profile, right_profile)``
    where left keeps the root.  The cut child is attached *first* so that
    :func:`partition_tree`'s first-child policy cuts it.
    """
    edges: List[Tuple[int, int]] = []
    counter = [0]

    def rec(prof) -> int:
        """Returns root vertex id of the realized sub-tree."""
        if prof == 1:
            v = counter[0]
            counter[0] += 1
            return v
        _, left, right = prof
        # Realize the cut subtree first so it is the first child of the root.
        # Order of construction: root comes from left profile; right subtree
        # attaches to it as the FIRST child in adjacency insertion order.
        # We must create the left root before the right subtree would claim
        # adjacency priority; edges are inserted right-first below.
        right_root_placeholder: List[int] = []

        def build_right():
            r = rec(right)
            right_root_placeholder.append(r)
            return r

        # build left structure, get its root id
        lroot = rec(left)
        rroot = build_right()
        # attach: insert edge so that rroot is FIRST child of lroot.
        edges.insert(0, (lroot, rroot))
        return lroot

    root = rec(profile)
    n = counter[0]
    t = Tree(n, tuple(edges), name)
    # sanity: the realized tree must reproduce the profile
    got = partition_tree(t, root=root).profile()
    want = profile
    if got != want:
        raise AssertionError(f"profile realization failed: got {got}, want {want}")
    return t


# NOTE on ordering: Tree.adjacency() inserts neighbors in edge-list order, so
# prepending the (root, cut-child) edge makes the cut child the first-listed
# child at every level. realize_profile asserts this invariant.


# ---------------------------------------------------------------------------
# Named templates (paper Fig. 5 / Table 3)
# ---------------------------------------------------------------------------
# Profiles found by tools/find_templates.py to exactly reproduce Table 3's
# (memory, compute) complexity figures under the paper's convention.  Shapes
# for u3-1/u5-2/u7-2 are derived analytically (path-3, path-5, 2-leg spider).
# Larger profiles are search results; see EXPERIMENTS.md for the comparison
# table.  Filled by _register_named_templates().

TEMPLATES: Dict[str, object] = {}  # named Tree and (non-tree) Template entries
TEMPLATE_TABLE3 = {
    # name: (memory, compute) from paper Table 3
    "u3-1": (3, 6),
    "u5-2": (25, 70),
    "u7-2": (147, 434),
    "u10-2": (1047, 5610),
    "u12-1": (4082, 24552),
    "u12-2": (3135, 38016),
    "u13": (4823, 109603),
    "u14": (7371, 242515),
    "u15-1": (12383, 753375),
    "u15-2": (15773, 617820),
}

# Nested split profiles (filled in from the profile search; see
# tools/find_templates.py).  ``1`` = leaf; ``(t, left, right)`` = internal.
_P3 = (3, (2, 1, 1), 1)
_P5 = (5, (4, (3, (2, 1, 1), 1), 1), 1)
_P7 = (7, (4, (3, (2, 1, 1), 1), 1), (3, (2, 1, 1), 1))

_NAMED_PROFILES: Dict[str, tuple] = {
    "u3-1": _P3,
    "u5-2": _P5,
    "u7-2": _P7,
    # The remaining profiles are injected by tools/find_templates.py output;
    # see _SEARCHED_PROFILES below.
}


# Placeholder dict — populated with search results (kept as data so import
# never depends on the search tool).
_SEARCHED_PROFILES: Dict[str, tuple] = {}

try:  # pragma: no cover - exercised indirectly
    from repro.core._template_profiles import SEARCHED_PROFILES as _SP

    _SEARCHED_PROFILES.update(_SP)
except ImportError:
    pass

_NAMED_PROFILES.update(_SEARCHED_PROFILES)


def _register_named_templates() -> None:
    for nm, prof in _NAMED_PROFILES.items():
        try:
            TEMPLATES[nm] = realize_profile(prof, name=nm)
        except AssertionError:
            # refuse to register a broken realization
            raise


def _register_nontree_templates() -> None:
    """Treewidth-2 registry entries compiled via the apex-pinned bag path."""
    entries = (
        cycle_template(3, "cycle3"),  # triangle
        cycle_template(4, "cycle4"),
        cycle_template(5, "cycle5"),
        cycle_template(6, "cycle6"),
        # K4 minus an edge: two deg-3 apexes, forest = a path, all pinned
        Template(4, ((0, 1), (0, 2), (1, 2), (1, 3), (2, 3)), "diamond"),
        # two triangles sharing a vertex: the one 2-tree forest that joins
        Template(5, ((0, 1), (0, 2), (1, 2), (2, 3), (2, 4), (3, 4)), "bowtie"),
        # square with a triangular roof (chordal-ish; apex on the roof ridge)
        Template(5, ((0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (1, 4)), "house"),
    )
    for t in entries:
        _apex_plan(t)  # refuse to register a non-apex-reducible entry
        TEMPLATES[t.name] = t


_register_named_templates()
_register_nontree_templates()


def template(name: str):
    """Look up a named template: trees (u3-1 .. u15-2) or treewidth-2
    patterns (cycle3 .. cycle6, diamond, bowtie, house)."""
    if name not in TEMPLATES:
        raise KeyError(f"unknown template {name!r}; have {sorted(TEMPLATES)}")
    return TEMPLATES[name]
