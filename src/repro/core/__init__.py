"""Core library: color-coding subgraph counting (the paper's contribution).

Public API (most callers should go through the ``repro.api.Counter``
facade, which wraps all of this behind one backend-agnostic interface):
  - templates: Tree, template(name), partition_tree, automorphism_count
  - graphs: Graph, rmat, erdos_renyi, from_edges, load_edge_file,
    save_npz/load_npz
  - table_program: run_table_program — THE partition-chain DP executor,
    shared by both engines (backends supply a neighbor-sum strategy)
  - count_engine: build_counting_plan, colorful_map_count, count_fn,
    plan_sample_fn (the backend sample_fn protocol)
  - estimator: estimate_counts (plan OR sample_fn), niter_bound
  - distributed: build_distributed_plan, make_count_fn (colorings- or
    key-based), keyed_sample_fn (shard_map)
  - brute_force: exact oracles for testing
"""

from .templates import (  # noqa: F401
    TEMPLATES,
    TemplateDag,
    Tree,
    automorphism_count,
    compile_templates,
    partition_complexity,
    partition_tree,
    path_tree,
    random_tree,
    spider_tree,
    star_tree,
    template,
)
from .graphs import (  # noqa: F401
    Graph,
    GraphFormatError,
    erdos_renyi,
    from_edges,
    load_edge_file,
    load_npz,
    relabel_random,
    rmat,
    save_npz,
)
from .table_program import (  # noqa: F401
    build_node_tables,
    local_node_fn,
    root_count,
    run_table_program,
)
from .count_engine import (  # noqa: F401
    CountingPlan,
    MultiCountingPlan,
    build_counting_plan,
    build_multi_counting_plan,
    colorful_map_count,
    colorful_map_count_many,
    count_fn,
    count_fn_many,
    multi_sample_fn,
    plan_sample_fn,
)
from .estimator import (  # noqa: F401
    CountEstimate,
    EstimationAborted,
    EstimatorState,
    MultiCountEstimate,
    ResumeMismatchError,
    estimate_counts,
    estimate_counts_many,
    niter_bound,
    num_groups_for,
)
from .supervisor import (  # noqa: F401
    QuarantinedBatch,
    RetryPolicy,
    Supervisor,
)
