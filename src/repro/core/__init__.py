"""Core library: color-coding subgraph counting (the paper's contribution).

Public API:
  - templates: Tree, template(name), partition_tree, automorphism_count
  - graphs: Graph, rmat, erdos_renyi, from_edges
  - count_engine: build_counting_plan, colorful_map_count, count_fn
  - estimator: estimate_counts, niter_bound
  - distributed: build_distributed_plan, distributed_count_fn (shard_map)
  - brute_force: exact oracles for testing
"""

from .templates import (  # noqa: F401
    TEMPLATES,
    Tree,
    automorphism_count,
    partition_complexity,
    partition_tree,
    path_tree,
    random_tree,
    spider_tree,
    star_tree,
    template,
)
from .graphs import Graph, erdos_renyi, from_edges, relabel_random, rmat  # noqa: F401
from .count_engine import (  # noqa: F401
    CountingPlan,
    build_counting_plan,
    colorful_map_count,
    count_fn,
)
from .estimator import CountEstimate, estimate_counts, niter_bound  # noqa: F401
