"""Supervision layer for the ``sample_fn(key, batch)`` backend protocol.

Long estimates on real clusters see flaky shards: a batch dispatch can
raise (preempted worker, OOM, transport error), hang, or return garbage.
The estimator's contract with its backends is exactly one function, so one
wrapper hardens every backend at once: :class:`Supervisor` wraps any
``sample_fn`` with

* a **per-attempt timeout** (the attempt runs on a worker thread; a hung
  dispatch surfaces as :class:`SampleTimeout` instead of wedging the run);
* **bounded retry with exponential backoff** for transient faults
  (exceptions, timeouts) — the retried attempt re-uses the *same* PRNG key,
  so a retry that succeeds is bit-identical to a first try that succeeded;
* **payload validation**: per-coloring copy estimates are nonnegative and
  finite *by construction* (they are scaled colorful-map counts), so a
  NaN/Inf or negative entry is data corruption, not noise — a **hard
  fault** that is never retried;
* **graceful degradation**: a batch that keeps failing (or hard-faults) is
  *quarantined* — recorded as a :class:`QuarantinedBatch` and excluded from
  the estimate — rather than silently dropped or allowed to kill the run.
  The estimator surfaces the quarantine records in ``CountResult``.

Failure taxonomy and which layer handles what: DESIGN.md §16.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.testing import faults

__all__ = [
    "RetryPolicy",
    "SampleFault",
    "SampleTimeout",
    "SampleValidationError",
    "QuarantinedBatch",
    "Supervisor",
    "key_fingerprint",
]


class SampleFault(RuntimeError):
    """A supervised sample attempt failed."""


class SampleTimeout(SampleFault):
    """An attempt exceeded the policy's per-batch timeout (transient)."""


class SampleValidationError(SampleFault):
    """The returned payload violates the protocol invariants (hard fault).

    Copy estimates are nonnegative finite floats by construction; NaN/Inf
    or negative entries mean the backend computed garbage — retrying the
    same deterministic computation would return the same garbage, so the
    batch is quarantined immediately.
    """


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry-with-backoff for transient sample faults.

    ``max_retries`` counts *re*-tries: a batch gets ``1 + max_retries``
    attempts before quarantine.  ``timeout_s=None`` disables the worker
    thread entirely (attempts run inline — zero overhead, no timeout).
    """

    max_retries: int = 3
    backoff_s: float = 0.05  # first retry delay
    backoff_factor: float = 2.0
    max_backoff_s: float = 2.0
    timeout_s: Optional[float] = None  # per-attempt wall clock


@dataclasses.dataclass(frozen=True)
class QuarantinedBatch:
    """Provenance of one excluded batch: which keys, why, how hard we tried."""

    call_index: int  # index into the run's per-call key sequence
    key_data: Tuple[int, ...]  # PRNG key words (uint32) — replayable
    reason: str
    attempts: int

    def __str__(self) -> str:
        return (
            f"batch #{self.call_index} quarantined after {self.attempts} "
            f"attempt(s): {self.reason}"
        )


def key_fingerprint(key: jax.Array) -> Tuple[int, ...]:
    """The raw uint32 words of a PRNG key — a replayable, hashable id."""
    if hasattr(key, "dtype") and jax.numpy.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    data = np.asarray(key, np.uint32).reshape(-1)
    return tuple(int(w) for w in data)


class Supervisor:
    """Wrap a ``sample_fn`` with retry, timeout, validation, quarantine.

    The wrapped object speaks a superset of the protocol:
    ``supervisor(key, batch, call_index=i)`` returns the float64 samples on
    success, or the :class:`QuarantinedBatch` record when the batch was
    given up on.  All quarantine records also accumulate on
    :attr:`quarantined`.

    ``sleep`` and ``clock`` are injectable seams so retry- and timeout-path
    tests never wait on the wall clock: with the default ``clock``
    (``time.monotonic``) a timeout attempt runs on a worker thread and a
    genuinely hung dispatch is detected in real time; with an injected
    clock the attempt runs inline and "exceeded the timeout" is judged by
    comparing injected-clock readings around it (fault-site sleeps route
    through ``sleep``, so a virtual clock whose ``sleep`` advances it
    exercises the full timeout->retry path in zero wall time).
    """

    def __init__(
        self,
        sample_fn: Callable[[jax.Array, int], np.ndarray],
        policy: Optional[RetryPolicy] = None,
        *,
        sleep: Callable[[float], None] = time.sleep,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.fn = sample_fn
        self.policy = policy or RetryPolicy()
        self.quarantined: List[QuarantinedBatch] = []
        self._sleep = sleep
        self._clock = clock
        self._virtual_clock = clock is not time.monotonic

    # ---------------------------------------------------------- one attempt
    def _raw_attempt(self, key: jax.Array, batch: int) -> np.ndarray:
        spec = faults.fire("sample.raise")
        if spec is not None:
            raise faults.InjectedFault("injected sample failure")
        spec = faults.fire("sample.timeout")
        if spec is not None:
            t = self.policy.timeout_s
            self._sleep(spec.payload if spec.payload is not None else (4.0 * t if t else 0.5))
        out = np.asarray(self.fn(key, batch), np.float64)
        spec = faults.fire("sample.nan")
        if spec is not None:
            out = out.copy()
            out.reshape(-1)[0] = np.nan
        spec = faults.fire("sample.negative")
        if spec is not None:
            out = out.copy()
            out.reshape(-1)[0] = -1.0
        return out

    def _timed_attempt(self, key: jax.Array, batch: int) -> np.ndarray:
        t = self.policy.timeout_s
        if t is None:
            return self._raw_attempt(key, batch)
        if self._virtual_clock:
            # injected clock: run inline and judge the timeout from clock
            # readings — the deterministic test path (no worker thread, no
            # wall waiting); real hang detection needs the real clock below
            t0 = self._clock()
            out = self._raw_attempt(key, batch)
            if self._clock() - t0 > t:
                raise SampleTimeout(f"sample batch exceeded the {t}s timeout")
            return out
        box: dict = {}

        def work():
            try:
                box["out"] = self._raw_attempt(key, batch)
            except BaseException as e:  # propagated below
                box["err"] = e

        th = threading.Thread(target=work, daemon=True)
        th.start()
        th.join(t)
        if th.is_alive():
            # the attempt's thread lingers until its dispatch returns (python
            # threads are not killable); the *run* moves on and retries
            raise SampleTimeout(f"sample batch exceeded the {t}s timeout")
        if "err" in box:
            raise box["err"]
        return box["out"]

    @staticmethod
    def _validate(out: np.ndarray, batch: int) -> None:
        if out.ndim < 1 or out.shape[0] != batch:
            raise SampleValidationError(
                f"payload shape {out.shape} does not lead with batch={batch}"
            )
        if not np.all(np.isfinite(out)):
            raise SampleValidationError("non-finite (NaN/Inf) sample payload")
        if np.any(out < 0):
            raise SampleValidationError(
                "negative copy estimate — counts are nonnegative by "
                "construction, so this is data corruption, not noise"
            )

    # ------------------------------------------------------------- the loop
    def __call__(
        self, key: jax.Array, batch: int, call_index: int = 0
    ) -> Union[np.ndarray, QuarantinedBatch]:
        delay = self.policy.backoff_s
        attempts = 0
        while True:
            attempts += 1
            try:
                out = self._timed_attempt(key, batch)
                self._validate(out, batch)
                return out
            except SampleValidationError as e:
                reason = str(e)  # hard fault: never retried
                break
            except Exception as e:
                reason = f"{type(e).__name__}: {e}"
                if attempts > self.policy.max_retries:
                    break
                self._sleep(delay)
                delay = min(
                    delay * self.policy.backoff_factor,
                    self.policy.max_backoff_s,
                )
        record = QuarantinedBatch(
            call_index=call_index,
            key_data=key_fingerprint(key),
            reason=reason,
            attempts=attempts,
        )
        self.quarantined.append(record)
        return record
