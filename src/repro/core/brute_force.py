"""Exact (exponential) oracles for testing the color-coding DP.

``count_embedding_maps`` counts injective maps of the template into the
graph (rooted-anywhere, i.e. plain subgraph-isomorphism maps); the number
of subgraph *copies* is ``maps / |Aut(T)|``.  Templates may be trees or
general connected :class:`~repro.core.templates.Template` graphs — the
backtracking extends candidates along a BFS spanning tree and then checks
every remaining template edge, so cycles/diamonds/chordal patterns are
exact too (for trees the extra check is vacuous and the behavior is
unchanged).

``count_colorful_maps`` counts only maps whose image uses pairwise-distinct
colors under a fixed coloring — the quantity the DP computes exactly (for a
fixed coloring the DP is deterministic, so the two must agree exactly; this
is the strongest correctness oracle available and is exercised heavily by
the property tests).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .graphs import Graph

__all__ = ["count_embedding_maps", "count_colorful_maps", "count_copies"]


def _bfs_order(tree):
    """Template vertices in BFS order from 0, with parent pointers."""
    adj = tree.adjacency()
    order = [0]
    parent = {0: -1}
    i = 0
    while i < len(order):
        v = order[i]
        i += 1
        for u in adj[v]:
            if u not in parent:
                parent[u] = v
                order.append(u)
    return order, parent


def _count_maps(g: Graph, tree, coloring: Optional[np.ndarray]) -> int:
    order, parent = _bfs_order(tree)
    n = g.n
    k = tree.n
    total = 0
    assignment = np.full(k, -1, np.int64)
    used_vertices = set()
    used_colors = set()
    # host adjacency as sets, for the non-spanning-tree edge checks
    gadj = [set(int(u) for u in g.neighbors(v)) for v in range(n)]
    tadj = tree.adjacency()

    def rec(i: int) -> int:
        if i == len(order):
            return 1
        tv = order[i]
        tp = parent[tv]
        count = 0
        candidates = range(n) if tp < 0 else g.neighbors(assignment[tp])
        for gv in candidates:
            gv = int(gv)
            if gv in used_vertices:
                continue
            # every template edge whose other end is already placed must be
            # a host edge too (trees: only tp is placed, already satisfied)
            ok = True
            for tu in tadj[tv]:
                if tu == tp:
                    continue
                gu = assignment[tu]
                if gu >= 0 and gv not in gadj[int(gu)]:
                    ok = False
                    break
            if not ok:
                continue
            if coloring is not None:
                c = int(coloring[gv])
                if c in used_colors:
                    continue
                used_colors.add(c)
            used_vertices.add(gv)
            assignment[tv] = gv
            count += rec(i + 1)
            assignment[tv] = -1
            used_vertices.discard(gv)
            if coloring is not None:
                used_colors.discard(int(coloring[gv]))
        return count

    total = rec(0)
    return total


def count_embedding_maps(g: Graph, tree) -> int:
    """Number of injective maps (labeled embeddings) of the template into g."""
    return _count_maps(g, tree, None)


def count_colorful_maps(g: Graph, tree, coloring: np.ndarray) -> int:
    """Number of injective maps whose image is colorful under ``coloring``."""
    return _count_maps(g, tree, np.asarray(coloring))


def count_copies(g: Graph, tree) -> float:
    """Number of non-induced subgraph copies of the template in g."""
    from .templates import automorphism_count

    return count_embedding_maps(g, tree) / automorphism_count(tree)
