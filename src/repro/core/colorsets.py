"""Combinadic indexing of color sets and split tables for the color-coding DP.

The color-coding dynamic program stores, per sub-template ``T_i`` of size ``t``,
a count table ``C[v, S]`` indexed by vertex ``v`` and color set ``S`` with
``|S| = t`` drawn from ``k`` colors.  Color sets are ranked combinadically
(lexicographic order of the sorted color tuples), giving each table a dense
second axis of width ``C(k, t)``.

The combine step for ``T_i -> (T_i', T_i'')`` needs, for every output set
``S`` of size ``t = t1 + t2``, the list of ordered splits ``S = S1 (+) S2``
with ``|S1| = t1``.  ``split_tables`` precomputes these as two integer index
matrices of shape ``[C(k,t), C(t,t1)]`` mapping output rank -> (rank of S1 in
the t1 table, rank of S2 in the t2 table).  These tables are tiny (worst case
k=15, t=8, t1=4: 6435 x 70 int32) and are treated as constants by jit.
"""

from __future__ import annotations

import math
from functools import lru_cache
from itertools import combinations
from typing import Dict, Tuple

import numpy as np

__all__ = [
    "num_sets",
    "set_masks",
    "rank_of_mask",
    "split_tables",
    "full_set_rank",
    "singleton_ranks",
    "excluded_color_mask",
]


def num_sets(k: int, t: int) -> int:
    """Number of color sets of size ``t`` from ``k`` colors: C(k, t)."""
    return math.comb(k, t)


@lru_cache(maxsize=None)
def set_masks(k: int, t: int) -> Tuple[int, ...]:
    """All size-``t`` subsets of ``{0..k-1}`` as bitmasks, in rank order."""
    if not (0 <= t <= k):
        raise ValueError(f"invalid subset size t={t} for k={k}")
    masks = []
    for comb in combinations(range(k), t):
        m = 0
        for c in comb:
            m |= 1 << c
        masks.append(m)
    return tuple(masks)


@lru_cache(maxsize=None)
def _rank_lookup(k: int, t: int) -> Dict[int, int]:
    return {m: i for i, m in enumerate(set_masks(k, t))}


def rank_of_mask(k: int, t: int, mask: int) -> int:
    """Rank of a bitmask among size-``t`` subsets of ``{0..k-1}``."""
    return _rank_lookup(k, t)[mask]


@lru_cache(maxsize=None)
def split_tables(k: int, t1: int, t2: int) -> Tuple[np.ndarray, np.ndarray]:
    """Index tables for the color-set combine.

    Returns ``(idx1, idx2)`` of shape ``[C(k, t1+t2), C(t1+t2, t1)]`` such that
    for output rank ``s`` and split index ``j``::

        out[v, s] = sum_j left[v, idx1[s, j]] * right[v, idx2[s, j]]

    enumerates exactly the ordered splits ``S = S1 (+) S2``.
    """
    t = t1 + t2
    if t > k:
        raise ValueError(f"t1+t2={t} exceeds k={k}")
    out_masks = set_masks(k, t)
    r1 = _rank_lookup(k, t1)
    r2 = _rank_lookup(k, t2)
    n_out = len(out_masks)
    n_splits = math.comb(t, t1)
    idx1 = np.zeros((n_out, n_splits), np.int32)
    idx2 = np.zeros((n_out, n_splits), np.int32)
    for s, m in enumerate(out_masks):
        bits = [b for b in range(k) if (m >> b) & 1]
        for j, comb in enumerate(combinations(bits, t1)):
            m1 = 0
            for c in comb:
                m1 |= 1 << c
            m2 = m ^ m1
            idx1[s, j] = r1[m1]
            idx2[s, j] = r2[m2]
    return idx1, idx2


@lru_cache(maxsize=None)
def excluded_color_mask(k: int, t: int) -> np.ndarray:
    """``[k, C(k, t)]`` float32 mask: 1.0 where color ``c`` is NOT in set ``S``.

    The bag-table collapse of the treewidth-2 front-end pins the apex vertex's
    color outside the forest's color set; row ``c`` of this mask filters the
    size-``t`` table columns down to the sets that exclude ``c``.
    """
    masks = set_masks(k, t)
    out = np.ones((k, len(masks)), np.float32)
    for s, m in enumerate(masks):
        for c in range(k):
            if (m >> c) & 1:
                out[c, s] = 0.0
    return out


def full_set_rank(k: int) -> int:
    """Rank of the full color set (always 0: the only size-k subset)."""
    return 0


def singleton_ranks(k: int) -> np.ndarray:
    """rank of {c} in the size-1 table, for each color c (identity order)."""
    masks = set_masks(k, 1)
    return np.array([_rank_lookup(k, 1)[m] for m in masks], np.int32)
