"""Active-frontier compaction: sparsity-aware DP tables and exchange.

For deep sub-templates most rows of the per-node count table ``C_i [n, B]``
are exactly zero — a vertex is active only if a colorful embedding of
``T_i`` roots at it, which for a random coloring of a skewed graph is a
rare event once ``|T_i|`` grows.  The dense engines of PRs 1-4 pay full
cost regardless: every combine contracts all ``n`` rows and every exchange
ships all requested rows.  This module makes table sparsity a first-class
plan property, GraphBLAS-style (density-adaptive format choice, cf. the
existing ``spmm_kind="auto"`` and ``mode="adaptive"`` machinery):

* :func:`probe_activity` — an exact host-side boolean DP (counts are
  nonnegative, so zero/nonzero propagates without cancellation) measuring
  per-node active-row masks on a few probe colorings at plan-build time;
* :func:`CompactionSpec` — the static capacities derived from the probe:
  ``cap = pad(ceil(max_active * capacity_factor)) (+1 reserved zero slot)``
  for every node whose measured density falls below ``density_threshold``.
  Capacities are **static shapes**: jitted code gathers active rows into
  capacity-padded compact form and a runtime flag records overflow, on
  which the caller re-dispatches the dense program (bit-exact fallback);
* runtime helpers — :class:`Frontier` (the per-table active-row record the
  executor threads through the table program), :func:`compact_combine`
  (combine over active rows only, scattered back), slot encode/decode for
  the compacted exchange payloads.

Everything here is exact: compaction never changes a single bit of the
counts — inactive rows contribute exactly zero in the dense program, and
the compact program simply never multiplies or ships them.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterator, List, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DEFAULT_DENSITY_THRESHOLD",
    "DEFAULT_CAPACITY_FACTOR",
    "Frontier",
    "CompactionSpec",
    "probe_activity",
    "single_device_compaction",
    "distributed_compaction",
    "model_density",
    "sampled_density",
    "capacity_for",
    "node_exchange_bytes",
    "make_frontier_fn",
    "inverse_map",
    "compact_combine",
    "chunk_slots",
    "encode_slots",
    "decode_slots",
]


#: compact a node once its measured active-row fraction is at or below this
#: (the GraphBLAS-style density switch; override per plan)
DEFAULT_DENSITY_THRESHOLD = 0.25
#: headroom over the probed maximum before the static capacity overflows
#: into the dense fallback
DEFAULT_CAPACITY_FACTOR = 1.5
#: density alone does not decide profitability: skipping a row saves its
#: combine work (``S * J`` fused multiply-adds) but costs a slot of the
#: activity/gather/scatter plumbing, so narrow-table nodes (u7-2's widest
#: combine is 35 x 3) lose even when sparse.  Combine compaction engages
#: only when the per-row combine work clears this floor.
MIN_COMBINE_ELEMENTS = 256
#: same idea for the compact-source SpMM indirection: the gather/inverse
#: map overhead only pays once the right table is reasonably wide
MIN_TABLE_WIDTH = 64


# ---------------------------------------------------------------------------
# Runtime structures
# ---------------------------------------------------------------------------


class Frontier(NamedTuple):
    """Active-row record of one node table, computed once at production.

    ``idx`` holds the active row indices in capacity-padded form (pad slots
    carry the zero-sentinel row); slot ``cap - 1`` is reserved as a pad slot
    whenever ``ok`` holds, so an inverse map's default slot always names a
    zero row of the gathered compact table.  ``ok`` is the runtime
    no-overflow flag (``count <= cap - 1``); mask-only frontiers (used
    where just the activity mask is needed) carry ``None`` in the other
    fields.
    """

    mask: jax.Array  # [rows] bool — active rows (pad rows False)
    idx: Optional[jax.Array]  # [cap] int32 active rows, sentinel-padded
    count: Optional[jax.Array]  # [] int32 true active count
    cap: Optional[int]  # static capacity
    ok: Optional[jax.Array]  # [] bool: compact form valid


@dataclasses.dataclass(frozen=True)
class CompactionSpec:
    """Static compaction plan for one table program (both backends).

    All capacities are trace-time constants sized from probe measurements;
    a node absent from a ``*_caps`` mapping runs dense.  ``density`` /
    ``gather_density`` keep the probe measurements for reporting (dry-run
    cells, benchmarks) — the same signal the thresholds gated on.
    """

    threshold: float
    capacity_factor: float
    #: node -> measured table density (max over probes; internal nodes)
    density: Mapping[int, float]
    #: node -> measured combine-gather density (active left AND active M)
    gather_density: Mapping[int, float]
    #: node -> frontier capacity (active rows of its table; +1 zero slot)
    table_caps: Mapping[int, int]
    #: node -> combine-gather capacity (rows the combine contracts)
    combine_caps: Mapping[int, int]
    #: node -> per-peer compacted-chunk capacity (distributed a2a/pipeline)
    exchange_caps: Mapping[int, int] = dataclasses.field(default_factory=dict)
    #: node -> compacted relay capacity of a whole shard (distributed ring)
    shard_caps: Mapping[int, int] = dataclasses.field(default_factory=dict)
    probes: int = 0

    @property
    def enabled(self) -> bool:
        return bool(self.table_caps or self.combine_caps or self.exchange_caps or self.shard_caps)


def capacity_for(
    max_active: int, capacity_factor: float, limit: int, multiple: int = 128
) -> Optional[int]:
    """Static capacity for a measured active count: ``ceil(max * factor)``
    plus one reserved zero slot, padded to ``multiple``.  ``None`` when the
    padded capacity reaches ``limit`` (compaction would not shrink it)."""
    want = int(math.ceil(max_active * capacity_factor)) + 1
    want = max(want, 2)
    cap = ((want + multiple - 1) // multiple) * multiple
    return cap if cap < limit else None


def model_density(t: int, k: int, avg_degree: float) -> float:
    """Analytic stand-in for the probe at shape-only (dry-run) scale.

    Markov bound on the active-row fraction of a size-``t`` sub-template
    table: ``P(C_i[v] != 0) <= E[row sum] ~= d^(t-1) * falling(k, t)/k^t``
    (rooted tree maps times the probability the ``t`` vertices draw
    pairwise-distinct colors).  Exact enough to size dry-run capacities;
    real plans measure instead (:func:`probe_activity`).
    """
    if t <= 1:
        return 1.0
    emb = float(avg_degree) ** (t - 1)
    p = 1.0
    for i in range(t):
        p *= (k - i) / k
    return float(min(1.0, emb * p))


# ---------------------------------------------------------------------------
# Host-side probe: exact boolean activity DP
# ---------------------------------------------------------------------------

#: bound on the [n, S_chunk, J] boolean gather intermediate of the probe
_PROBE_BUDGET = 1 << 24


class NodeActivity(NamedTuple):
    table: np.ndarray  # [n] bool — active rows of the node's table
    gather: Optional[np.ndarray]  # [n] bool — active(left) & active(M)


def probe_activity(
    graph, program, combine, k: int, *, probes: int = 2, seed: int = 0
) -> Iterator[Dict[int, NodeActivity]]:
    """Yield per-probe-coloring activity masks for every internal node.

    Runs the partition DP over **booleans** on the host: counts are sums of
    products of nonnegative terms, so ``C_i[v, S] != 0`` iff the boolean
    recurrence holds — the probe is exact for its coloring, not a bound.
    ``combine`` supplies each internal node's true-width split tables
    (``CombineTables.idx1/idx2``), exactly as the real DP consumes them.
    """
    from .graphs import edge_list

    rows, cols = edge_list(graph)
    n = graph.n
    rng = np.random.default_rng(seed)
    for _ in range(probes):
        coloring = rng.integers(0, k, n)
        reads = list(program.table_reads())
        # boolean activity tables, keyed by node (NOT the DP recursion —
        # that lives exactly once, in core/table_program.py)
        acts: Dict[int, np.ndarray] = {}
        out: Dict[int, NodeActivity] = {}
        for i, nd in enumerate(program.nodes):
            if nd.is_leaf:
                t = np.zeros((n, k), bool)
                t[np.arange(n), coloring] = True
            else:
                right = acts[nd.right]
                left = acts[nd.left]
                m = np.zeros((n, right.shape[1]), bool)
                np.logical_or.at(m, rows, right[cols])
                idx1 = np.asarray(combine[i].idx1)  # [S, J] true widths
                idx2 = np.asarray(combine[i].idx2)
                s, j = idx1.shape
                chunk = max(1, min(s, _PROBE_BUDGET // max(n * j, 1)))
                t = np.empty((n, s), bool)
                for s0 in range(0, s, chunk):
                    i1 = idx1[s0 : s0 + chunk]
                    i2 = idx2[s0 : s0 + chunk]
                    t[:, s0 : s0 + chunk] = np.any(left[:, i1] & m[:, i2], axis=2)
                out[i] = NodeActivity(
                    table=t.any(axis=1),
                    gather=left.any(axis=1) & m.any(axis=1),
                )
                for c in (nd.right, nd.left):
                    reads[c] -= 1
                    if reads[c] == 0:
                        acts.pop(c, None)
            if i in getattr(program, "roots", ()):
                reads[i] -= list(program.roots).count(i)
            if reads[i] > 0:
                acts[i] = t
        yield out


def _child_roles(program) -> Tuple[set, set]:
    """(right-child node ids, left-child node ids) over internal parents."""
    rights, lefts = set(), set()
    for nd in program.nodes:
        if not nd.is_leaf:
            rights.add(nd.right)
            lefts.add(nd.left)
    return rights, lefts


def single_device_compaction(
    graph,
    program,
    combine,
    k: int,
    *,
    n_pad: int,
    threshold: float,
    capacity_factor: float,
    probes: int = 2,
    seed: int = 0,
    has_edge_slabs: bool = True,
) -> CompactionSpec:
    """Probe densities and size the in-core capacities.

    ``table_caps`` engage for internal nodes consumed as a *right* child
    (their compact form feeds the SpMM/fused kernels through the row-index
    indirection — which needs the edge-slab layout, so a block-dense plan
    passes ``has_edge_slabs=False`` and skips them entirely rather than
    paying frontier upkeep nothing consumes); ``combine_caps`` engage per
    internal node when the measured combine-gather density (active left
    rows that also have an active neighbor sum) is below the threshold.
    """
    n = graph.n
    rights, _ = _child_roles(program)
    if not has_edge_slabs:
        rights = set()
    max_act: Dict[int, int] = {}
    max_gath: Dict[int, int] = {}
    for masks in probe_activity(graph, program, combine, k, probes=probes, seed=seed):
        for i, a in masks.items():
            max_act[i] = max(max_act.get(i, 0), int(a.table.sum()))
            max_gath[i] = max(max_gath.get(i, 0), int(a.gather.sum()))
    density = {i: c / max(n, 1) for i, c in max_act.items()}
    gather_density = {i: c / max(n, 1) for i, c in max_gath.items()}
    table_caps = {}
    combine_caps = {}
    for i in max_act:
        if (i in rights and density[i] <= threshold and combine[i].s >= MIN_TABLE_WIDTH):
            cap = capacity_for(max_act[i], capacity_factor, n_pad)
            if cap is not None:
                table_caps[i] = cap
        if (gather_density[i] <= threshold and combine[i].s * combine[i].j >= MIN_COMBINE_ELEMENTS):
            cap = capacity_for(max_gath[i], capacity_factor, n_pad)
            if cap is not None:
                combine_caps[i] = cap
    return CompactionSpec(
        threshold=threshold,
        capacity_factor=capacity_factor,
        density=density,
        gather_density=gather_density,
        table_caps=table_caps,
        combine_caps=combine_caps,
        probes=probes,
    )


def distributed_compaction(
    graph,
    program,
    combine,
    k: int,
    *,
    num_shards: int,
    shard_size: int,
    n_loc_pad: int,
    r_pad: int,
    send_idx: np.ndarray,
    threshold: float,
    capacity_factor: float,
    probes: int = 2,
    seed: int = 0,
) -> CompactionSpec:
    """Probe densities and size the distributed capacities.

    ``exchange_caps`` bound the *per-peer* compacted chunk (active rows
    among the ``send_idx`` request lists — measured per (src, dst) pair, so
    hub-heavy request lists are sized by their own activity, not the global
    average); ``shard_caps`` bound the compacted whole-shard relay of the
    ring mode; ``combine_caps`` bound the per-shard combine gather.
    """
    n = graph.n
    Pn, ss = num_shards, shard_size
    rights, _ = _child_roles(program)
    max_act: Dict[int, int] = {}
    max_chunk: Dict[int, int] = {}
    max_shard: Dict[int, int] = {}
    max_gath_shard: Dict[int, int] = {}
    for masks in probe_activity(graph, program, combine, k, probes=probes, seed=seed):
        for i, a in masks.items():
            max_act[i] = max(max_act.get(i, 0), int(a.table.sum()))
            pad = np.zeros(Pn * ss + 1, bool)
            pad[:n] = a.table
            gpad = np.zeros(Pn * ss, bool)
            gpad[:n] = a.gather
            shard_counts = pad[: Pn * ss].reshape(Pn, ss).sum(axis=1)
            max_shard[i] = max(max_shard.get(i, 0), int(shard_counts.max()))
            max_gath_shard[i] = max(
                max_gath_shard.get(i, 0),
                int(gpad.reshape(Pn, ss).sum(axis=1).max()),
            )
            if i in rights:
                # per-(src q, dst p) chunk activity through q's send lists
                glob = send_idx + (np.arange(Pn) * ss)[:, None, None]
                valid = send_idx != ss
                counts = (pad[np.minimum(glob, Pn * ss)] & valid).sum(axis=2)
                max_chunk[i] = max(max_chunk.get(i, 0), int(counts.max()))
    density = {i: c / max(n, 1) for i, c in max_act.items()}
    gather_density = {i: c / max(ss, 1) for i, c in max_gath_shard.items()}
    exchange_caps = {}
    shard_caps = {}
    combine_caps = {}
    for i in max_act:
        # wire savings are pure win at any width: gate only by density
        if i in rights and density[i] <= threshold:
            cap = capacity_for(max_chunk[i], capacity_factor, r_pad, multiple=8)
            if cap is not None:
                exchange_caps[i] = cap
            cap = capacity_for(max_shard[i], capacity_factor, n_loc_pad, multiple=8)
            if cap is not None:
                shard_caps[i] = cap
        if (gather_density[i] <= threshold and combine[i].s * combine[i].j >= MIN_COMBINE_ELEMENTS):
            cap = capacity_for(max_gath_shard[i], capacity_factor, n_loc_pad)
            if cap is not None:
                combine_caps[i] = cap
    return CompactionSpec(
        threshold=threshold,
        capacity_factor=capacity_factor,
        density=density,
        gather_density=gather_density,
        table_caps={},
        combine_caps=combine_caps,
        exchange_caps=exchange_caps,
        shard_caps=shard_caps,
        probes=probes,
    )


def sampled_density(
    num_vertices: int,
    avg_degree: float,
    program,
    combine,
    k: int,
    *,
    sample_vertices: int = 2048,
    probes: int = 2,
    seed: int = 0,
) -> Dict[int, float]:
    """Per-node table densities from the boolean DP on a sampled subgraph.

    The Markov bound of :func:`model_density` saturates at 1.0 on dense
    paper graphs (``d^(t-1)`` blows through the colorful-probability
    discount), so dry-run capacities sized from it never engage.  Running
    the **exact** probe on a small same-degree synthetic R-MAT instead
    costs milliseconds at shape-only scale and tracks the measured
    densities of the real plan within the sampling noise — the densities
    are per-vertex probabilities, so they transfer across graph size at
    matched degree.
    """
    from .graphs import relabel_random, rmat

    n_s = int(min(max(sample_vertices, 64), max(num_vertices, 64)))
    m_s = max(n_s // 2, int(round(n_s * avg_degree / 2.0)))
    g_s = relabel_random(rmat(n_s, m_s, skew=3, seed=seed), seed=seed + 1)
    density: Dict[int, float] = {}
    for masks in probe_activity(g_s, program, combine, k, probes=probes, seed=seed):
        for i, a in masks.items():
            rho = float(a.table.sum()) / max(n_s, 1)
            density[i] = max(density.get(i, 0.0), rho)
    return density


def abstract_compaction(
    num_vertices: int,
    avg_degree: float,
    program,
    k: int,
    *,
    r_pad: int,
    n_loc_pad: int,
    threshold: float,
    capacity_factor: float,
    combine=None,
    sample_vertices: int = 2048,
    probes: int = 2,
    seed: int = 0,
) -> CompactionSpec:
    """Shape-only spec for dry-run lowering: nothing is materialized.

    With ``combine`` (the node split tables) the densities come from
    :func:`sampled_density` — the exact boolean DP on a sampled subgraph;
    without it, the analytic :func:`model_density` Markov bound."""
    rights, _ = _child_roles(program)
    if combine is not None:
        density = sampled_density(
            num_vertices,
            avg_degree,
            program,
            combine,
            k,
            sample_vertices=sample_vertices,
            probes=probes,
            seed=seed,
        )
    else:
        density = {
            i: model_density(nd.size, k, avg_degree)
            for i, nd in enumerate(program.nodes)
            if not nd.is_leaf
        }
    exchange_caps = {}
    shard_caps = {}
    combine_caps = {}
    for i, rho in density.items():
        if rho > threshold:
            continue
        cap = capacity_for(int(rho * r_pad), capacity_factor, r_pad, multiple=8)
        if i in rights and cap is not None:
            exchange_caps[i] = cap
        cap = capacity_for(int(rho * n_loc_pad), capacity_factor, n_loc_pad, multiple=8)
        if i in rights and cap is not None:
            shard_caps[i] = cap
        cap = capacity_for(int(rho * n_loc_pad), capacity_factor, n_loc_pad)
        if cap is not None:
            combine_caps[i] = cap
    return CompactionSpec(
        threshold=threshold,
        capacity_factor=capacity_factor,
        density=density,
        gather_density=dict(density),
        table_caps={},
        combine_caps=combine_caps,
        exchange_caps=exchange_caps,
        shard_caps=shard_caps,
    )


def node_exchange_bytes(plan, i: int, mode: str, wire_dtype: str = "float32") -> Tuple[int, int]:
    """``(dense, compact)`` per-device wire bytes node ``i``'s exchange
    moves each iteration under ``mode`` at ``wire_dtype`` width — THE
    formula for the compacted slab layout (``[cap, B+extra]`` active rows
    plus the slot/bitmap carrier columns vs the dense ``[rows, B]``),
    shared by the dry-run report, the sparsity bench, and the adaptive
    mode's Hockney bytes so they can never disagree.  A narrow wire
    replaces the float32 slot column with bit-packed activity-mask
    columns of the wire dtype (DESIGN.md §18).
    ``plan`` is a DistributedPlan (duck-typed to avoid a module cycle)."""
    from repro.comm.compress import mask_column_count, wire_itemsize

    nd = plan.program.nodes[i]
    b = plan.widths[nd.right]
    spec = plan.compaction
    if mode == "ring":
        rows = plan.n_loc_pad
        cap = spec.shard_caps.get(nd.right) if spec is not None else None
    else:
        rows = plan.r_pad
        cap = spec.exchange_caps.get(nd.right) if spec is not None else None
    ebytes = wire_itemsize(wire_dtype)
    dense = (plan.num_shards - 1) * rows * b * ebytes
    if cap:
        extra = 1 if wire_dtype == "float32" else mask_column_count(rows, cap, wire_dtype)
        compact = (plan.num_shards - 1) * cap * (b + extra) * ebytes
    else:
        compact = dense
    return dense, compact


# ---------------------------------------------------------------------------
# Traced helpers (used inside the jitted count programs)
# ---------------------------------------------------------------------------


def make_frontier_fn(
    table_caps: Mapping[int, int],
    sentinel_row: int,
    flags: List[jax.Array],
    mask_only: frozenset = frozenset(),
):
    """Frontier hook for :func:`repro.core.table_program.run_table_program`.

    Nodes in ``table_caps`` get the full capacity-padded index frontier
    (appending their no-overflow flag to ``flags``); nodes in ``mask_only``
    get just the activity mask (exchange/combine consumers that never need
    the index form); everything else returns ``None`` (dense).
    """

    def frontier_fn(i: int, table: jax.Array) -> Optional[Frontier]:
        cap = table_caps.get(i)
        if cap is None and i not in mask_only:
            return None
        mask = jnp.any(table != 0, axis=1)
        if cap is None:
            return Frontier(mask, None, None, None, None)
        idx = jnp.nonzero(mask, size=cap, fill_value=sentinel_row)[0].astype(jnp.int32)
        count = jnp.sum(mask.astype(jnp.int32))
        ok = count <= cap - 1
        flags.append(ok)
        return Frontier(mask, idx, count, cap, ok)

    return frontier_fn


def inverse_map(idx: jax.Array, n_rows: int, zero_slot: int) -> jax.Array:
    """Row index -> compact slot; unlisted rows map to ``zero_slot`` (which
    must name an all-zero row of the compact table — slot ``cap - 1`` is
    reserved for exactly this whenever the frontier's ``ok`` flag holds)."""
    return (
        jnp.full((n_rows,), zero_slot, jnp.int32)
        .at[idx]
        .set(jnp.arange(idx.shape[0], dtype=jnp.int32))
    )


def compact_combine(
    c_left: jax.Array,  # [rows, A]
    m: jax.Array,  # [rows, B] neighbor sum (pad rows may be garbage)
    tables,  # ops.CombineTables
    cap: int,
    sentinel_row: int,
    impl: str,
    flags: List[jax.Array],
    left_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Combine over active rows only, scattered back to the dense layout.

    The output row ``v`` of a combine is zero whenever ``left[v]`` is all
    zero or ``M[v]`` is all zero, so contracting just the rows where both
    are active — gathered into ``[cap, ...]`` compact form — computes the
    bit-identical table at ``cap/rows`` of the FLOPs.  Rows outside the
    gather stay exactly zero, which is what the dense combine would have
    produced there.  Appends the no-overflow flag to ``flags``.
    """
    from repro.kernels import ops

    act = left_mask if left_mask is not None else jnp.any(c_left != 0, axis=1)
    act = act & jnp.any(m != 0, axis=1)
    idx = jnp.nonzero(act, size=cap, fill_value=sentinel_row)[0].astype(jnp.int32)
    flags.append(jnp.sum(act.astype(jnp.int32)) <= cap - 1)
    lc = jnp.take(c_left, idx, axis=0)
    mc = jnp.take(m, idx, axis=0)
    outc = ops.color_combine(lc, mc, tables, impl=impl)
    out = jnp.zeros((c_left.shape[0], outc.shape[1]), outc.dtype)
    return out.at[idx].set(outc)


def chunk_slots(act_chunks: jax.Array, cap: int, fill: int) -> jax.Array:
    """Per-chunk active-slot indices ``[P, cap]`` (vmapped capacity-padded
    nonzero; pad slots carry ``fill``, which must name a zero row)."""
    return jax.vmap(
        lambda a: jnp.nonzero(a, size=cap, fill_value=fill)[0].astype(
            jnp.int32
        )
    )(act_chunks)


def encode_slots(slots: jax.Array) -> jax.Array:
    """int32 slot vector -> float32 carrier column (bitcast, lossless) so a
    compacted payload travels as ONE array through any exchange primitive."""
    return jax.lax.bitcast_convert_type(slots.astype(jnp.int32), jnp.float32)


def decode_slots(col: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(col, jnp.int32)
